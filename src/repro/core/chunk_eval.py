"""Chunk-level evaluation (paper §VI-D): TP collectives, PP stage transfers,
DP weight-update traffic, DRAM access, pipeline (micro-batch) efficiency —
combined with the op-level chunk latency into step time, throughput and
power (action-energy accounting, §VI-E).

The core math lives in `evaluate_step_batch`, which broadcasts every term
over a leading candidate axis given a `DesignBatch` (DESIGN.md §4); the
scalar `evaluate_step` delegates to it with a length-1 batch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core import components as C
from repro.core.compiler import ChunkGraph, Strategy
from repro.core.design_space import DesignBatch, WSCDesign
from repro.core.workload import BYTES, LLMWorkload


@dataclasses.dataclass
class StepResult:
    step_time_s: float
    throughput: float              # tokens/s
    power_w: float                 # average dynamic + static (per system)
    pipeline_eff: float
    breakdown: Dict[str, float]    # seconds per component
    energy_j: float
    feasible: bool = True
    reason: str = ""


def evaluate_step_batch(geom: DesignBatch, wl: LLMWorkload,
                        tp: np.ndarray, pp: np.ndarray, dp: np.ndarray,
                        mb: np.ndarray, chunk_latency_cycles: np.ndarray,
                        sram_bits_layer: np.ndarray,
                        noc_bytes_layer: np.ndarray, n_wafers: np.ndarray,
                        peak_power_w: Optional[float] = None,
                        legacy_dram_energy: bool = False,
                        ep: Optional[np.ndarray] = None,
                        recompute: Optional[np.ndarray] = None
                        ) -> Dict[str, np.ndarray]:
    """Batched chunk-level model over C candidates.

    geom holds the per-candidate design geometry (already gathered to the
    candidate axis); tp/pp/dp/mb are the strategy knobs; chunk_latency_cycles,
    sram_bits_layer (SRAM bits moved per layer across the chunk grid) and
    noc_bytes_layer (NoC byte-hops per layer) come from the tile/NoC stage.
    Returns a dict of (C,) arrays: step_time_s, throughput, power_w,
    pipeline_eff, energy_j, feasible, plus the per-component breakdown terms
    (compute_s/tp_s/pp_s/dram_s/dp_s are per-microbatch stage seconds).

    Joint-search extras (ISSUE 9): `ep` (expert parallel degree) and
    `recompute` (activation recomputation) are optional (C,) arrays. Every
    extra term is `np.where`-guarded so a lane with ep=1/recompute=False is
    bitwise identical to the legacy model (x + 0.0 == x, where(False, _, y)
    == y) — the grid-mode replay contract is preserved by construction.
    Recompute re-runs the forward in the backward pass (bwd 3x -> 4x,
    training only); ep shards the expert weights and adds per-layer
    dispatch/combine all-to-all over the inter-reticle fabric.
    """
    tp = np.asarray(tp, np.int64)
    pp = np.asarray(pp, np.int64)
    dp = np.asarray(dp, np.int64)
    mb = np.asarray(mb, np.int64)
    nw = np.asarray(n_wafers, np.int64)
    lat = np.asarray(chunk_latency_cycles, np.float64)

    train = wl.phase == "train"
    bwd_mult = 3.0 if train else 1.0
    if recompute is not None and train:
        bwd_mult = np.where(np.asarray(recompute, bool), 4.0, 3.0)
    ep_arr = None if ep is None else np.maximum(np.asarray(ep, np.int64), 1)
    mb_count = mb if train else np.ones_like(mb)
    mb_tokens = np.maximum(wl.tokens_per_step() // (dp * mb_count), 1)
    layers_per_stage = np.maximum(wl.n_layers // pp, 1)
    chunks = pp * dp
    act_bytes = (mb_tokens * wl.d_model).astype(np.float64) * BYTES
    p_bytes = wl.params_bytes()

    # --- per-microbatch stage time -----------------------------------------
    compute_s = lat * layers_per_stage / C.CLOCK_HZ * bwd_mult

    # TP all-reduce: 2 collectives per layer over the TP group (Megatron)
    cores_per_chunk = geom.total_cores * nw // np.maximum(chunks, 1)
    tp_vol = 2.0 * (tp - 1) / tp * act_bytes * 2.0
    tp_bw = np.where(cores_per_chunk <= geom.cores_per_reticle,
                     geom.reticle_bisection_Bps, geom.inter_reticle_bw_Bps)
    tp_s = np.where(tp <= 1, 0.0, tp_vol / np.maximum(tp_bw, 1.0)) \
        * layers_per_stage * bwd_mult

    pp_s = np.where(
        pp <= 1, 0.0,
        act_bytes / np.maximum(geom.inter_reticle_bw_Bps, 1.0)) * bwd_mult

    # DRAM: weight/KV streaming beyond SRAM capacity (per microbatch, chunk)
    sram_per_chunk = (geom.buffer_kb * 1024.0 * geom.total_cores * nw
                      / np.maximum(chunks, 1))
    w_bytes = p_bytes / np.maximum(pp, 1)
    if ep_arr is not None:
        # expert weights shard over the ep group (dense slice replicated)
        p_exp = wl.expert_params_bytes()
        w_bytes = np.where(ep_arr > 1,
                           ((p_bytes - p_exp) + p_exp / ep_arr)
                           / np.maximum(pp, 1), w_bytes)
    # KV-cache traffic per step (per chunk): a decode step streams the whole
    # resident cache to score one new token per sequence and appends that
    # token's K/V (per-token KV read + write); a prefill step writes the
    # whole prompt's K/V once. Training keeps no cache.
    kv_total = wl.kv_bytes_per_layer() * wl.n_layers / np.maximum(pp, 1)
    if wl.phase == "decode":
        kv_read, kv_write = kv_total, kv_total / max(wl.seq, 1)
    elif wl.phase == "prefill":
        kv_read, kv_write = 0.0, kv_total
    else:
        kv_read = kv_write = 0.0
    spill = np.maximum(w_bytes + kv_read - sram_per_chunk, 0.0)
    reticles_per_chunk = np.maximum(
        geom.n_reticles * nw / np.maximum(chunks, 1), 1e-9)
    stacked_bw = geom.dram_bw_Bps_per_reticle * reticles_per_chunk
    n_edge = 2 * (geom.ret_h + geom.ret_w)
    offchip_bw = n_edge * C.OFFCHIP_BW_PER_CTRL / np.maximum(chunks, 1)
    transit = geom.inter_reticle_bw_Bps * np.minimum(geom.ret_h, geom.ret_w) \
        / np.maximum(chunks, 1)
    dram_bw = np.where(geom.dram_on, stacked_bw,
                       np.minimum(offchip_bw, transit))
    # KV writes hit DRAM only when the cache cannot live in SRAM beside the
    # weights (otherwise appends land in the on-wafer buffers)
    kv_in_dram = (w_bytes + kv_total) > sram_per_chunk
    dram_traffic = spill + np.where(kv_in_dram, kv_write, 0.0)
    dram_s = np.where(dram_traffic <= 0, 0.0,
                      dram_traffic / np.maximum(dram_bw, 1.0))

    stage_s = compute_s + tp_s + pp_s + dram_s
    a2a_vol = None
    ep_s = np.zeros_like(stage_s)
    if ep_arr is not None:
        # MoE dispatch+combine all-to-all per layer (fwd, x2 directions,
        # top-k routed copies), over the inter-reticle fabric
        topk = max(wl.moe_topk, 1)
        a2a_vol = np.where(ep_arr > 1,
                           4.0 * (ep_arr - 1) / ep_arr * act_bytes * topk,
                           0.0)
        ep_s = (a2a_vol / np.maximum(geom.inter_reticle_bw_Bps, 1.0)
                * layers_per_stage * bwd_mult)
        stage_s = stage_s + ep_s

    # --- pipeline + step ----------------------------------------------------
    eff = mb_count / (mb_count + pp - 1.0)
    iter_s = stage_s * mb_count / eff
    # DP gradient all-reduce (training only)
    grad_vol = 2.0 * (dp - 1) / dp * w_bytes
    wafers_per_replica = np.maximum(nw / dp, 1e-9)
    dp_bw = np.where(wafers_per_replica >= 1.0,
                     n_edge * C.INTER_WAFER_BW_PER_NI,
                     geom.inter_reticle_bw_Bps
                     * np.minimum(geom.ret_h, geom.ret_w))
    dp_s = np.where((dp <= 1) | (not train), 0.0,
                    grad_vol / np.maximum(dp_bw, 1.0))
    step_s = iter_s + dp_s
    tokens = wl.tokens_per_step()
    throughput = tokens / np.maximum(step_s, 1e-12)

    # --- energy (action accounting, §VI-E) ----------------------------------
    E = C.ENERGY
    e_mac = wl.flops_per_step() / 2.0 * E.mac * 1e-12
    e_sram = (np.asarray(sram_bits_layer, np.float64) * wl.n_layers
              * mb_count * dp * bwd_mult * E.sram_read_bit * 1e-12)
    e_noc = (np.asarray(noc_bytes_layer, np.float64) * 8 * wl.n_layers
             * mb_count * dp * bwd_mult * E.noc_bit_hop * 1e-12)
    ir_bytes = (2.0 * (tp - 1) / np.maximum(tp, 1) * mb_tokens * wl.d_model
                * BYTES * 2 * wl.n_layers * mb_count * dp * bwd_mult)
    ir_bytes = ir_bytes + p_bytes * 2 * (dp > 1)
    if a2a_vol is not None:
        ir_bytes = ir_bytes + a2a_vol * wl.n_layers * mb_count * dp
    e_ir = ir_bytes * 8 * geom.ir_energy_pj_per_bit * 1e-12
    # DRAM energy charges the same per-step traffic as the latency term
    # above (SRAM pool sized per system — nw wafers — plus KV streaming).
    # legacy_dram_energy=True reproduces the inherited asymmetric model
    # bit-for-bit (capacity sized per wafer, no nw factor; KV ignored) so
    # the pre-fix behavior stays testable.
    if legacy_dram_energy:
        dram_bytes = np.maximum(
            p_bytes / np.maximum(pp, 1)
            - geom.buffer_kb * 1024.0 * geom.total_cores
            / np.maximum(chunks, 1),
            0.0) * mb_count * dp
    else:
        dram_bytes = dram_traffic * mb_count * dp
    e_dram = dram_bytes * 8 * np.where(geom.dram_on, E.dram_bit,
                                       E.offchip_bit) * 1e-12
    static_w = geom.static_power_w * nw
    energy = e_mac + e_sram + e_noc + e_ir + e_dram + static_w * step_s

    bad = ~(np.isfinite(step_s) & np.isfinite(energy))
    power = np.where(bad, np.inf, energy / np.maximum(step_s, 1e-12))
    limit = (peak_power_w if peak_power_w is not None
             else C.WAFER_POWER_W * nw)
    feasible = ~bad & (power <= limit) & np.isfinite(power)
    return {
        "step_time_s": np.where(bad, np.inf, step_s),
        "throughput": np.where(bad, 0.0, throughput),
        "power_w": power,
        "pipeline_eff": eff,
        "energy_j": np.where(bad, 0.0, energy),
        "feasible": feasible,
        "non_finite": bad,
        # per-microbatch stage components (for the winner's breakdown)
        "compute_s": compute_s, "tp_s": tp_s, "pp_s": pp_s,
        "dram_s": dram_s, "dp_s": dp_s, "ep_s": ep_s,
        "mb_count": mb_count,
    }


# NumPy oracle alias for the jitted pipeline (repro.core.eval_compiled)
evaluate_step_batch_ref = evaluate_step_batch


def step_result_at(out: Dict[str, np.ndarray], i: int) -> StepResult:
    """Materialize candidate i of an `evaluate_step_batch` result as the
    scalar StepResult (with its seconds-per-component breakdown)."""
    if bool(out["non_finite"][i]):
        return StepResult(float("inf"), 0.0, float("inf"),
                          float(out["pipeline_eff"][i]), {}, 0.0,
                          feasible=False, reason="non_finite")
    eff = float(out["pipeline_eff"][i])
    mbc = float(out["mb_count"][i])
    feasible = bool(out["feasible"][i])
    bd = {"compute": float(out["compute_s"][i]) * mbc / eff,
          "tp": float(out["tp_s"][i]) * mbc / eff,
          "pp": float(out["pp_s"][i]) * mbc / eff,
          "dram": float(out["dram_s"][i]) * mbc / eff,
          "dp": float(out["dp_s"][i])}
    ep_s = float(out["ep_s"][i]) if "ep_s" in out else 0.0
    if ep_s:
        # only when expert parallelism is active — grid-mode breakdowns
        # (and their recorded fingerprints) keep the legacy key set
        bd["ep"] = ep_s * mbc / eff
    return StepResult(
        step_time_s=float(out["step_time_s"][i]),
        throughput=float(out["throughput"][i]),
        power_w=float(out["power_w"][i]),
        pipeline_eff=eff,
        breakdown=bd,
        energy_j=float(out["energy_j"][i]),
        feasible=feasible,
        reason="" if feasible else "power",
    )


# batch-of-one geometry views, memoized per (hashable) design so the scalar
# path doesn't recompute the derived geometry once per strategy
_GEOM_CACHE: Dict[WSCDesign, DesignBatch] = {}


def _geom_for(design: WSCDesign) -> DesignBatch:
    g = _GEOM_CACHE.get(design)
    if g is None:
        if len(_GEOM_CACHE) >= 4096:
            _GEOM_CACHE.pop(next(iter(_GEOM_CACHE)))
        g = DesignBatch.from_designs([design])
        _GEOM_CACHE[design] = g
    return g


def evaluate_step(design: WSCDesign, wl: LLMWorkload, s: Strategy,
                  chunk_latency_cycles: float, graph: ChunkGraph,
                  n_wafers: int, peak_power_w: Optional[float] = None,
                  legacy_dram_energy: bool = False) -> StepResult:
    """Combine op-level chunk latency with chunk-level comm/DRAM/pipeline.
    Scalar wrapper over `evaluate_step_batch` (batch of one)."""
    geom = _geom_for(design)
    sram_bits_layer = sum(o.tile.sram_read_bits + o.tile.sram_write_bits
                          for o in graph.ops) * graph.n_cores
    noc_bytes_layer = float(graph.link_loads.sum())
    out = evaluate_step_batch(
        geom, wl, np.asarray([s.tp]), np.asarray([s.pp]), np.asarray([s.dp]),
        np.asarray([s.microbatches]), np.asarray([chunk_latency_cycles]),
        np.asarray([sram_bits_layer]), np.asarray([noc_bytes_layer]),
        np.asarray([n_wafers]), peak_power_w,
        legacy_dram_energy=legacy_dram_energy,
        ep=np.asarray([s.ep]), recompute=np.asarray([s.recompute]))
    return step_result_at(out, 0)
