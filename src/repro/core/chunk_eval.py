"""Chunk-level evaluation (paper §VI-D): TP collectives, PP stage transfers,
DP weight-update traffic, DRAM access, pipeline (micro-batch) efficiency —
combined with the op-level chunk latency into step time, throughput and
power (action-energy accounting, §VI-E).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.core import components as C
from repro.core.compiler import ChunkGraph, Strategy
from repro.core.design_space import WSCDesign
from repro.core.workload import BYTES, LLMWorkload


@dataclasses.dataclass
class StepResult:
    step_time_s: float
    throughput: float              # tokens/s
    power_w: float                 # average dynamic + static (per system)
    pipeline_eff: float
    breakdown: Dict[str, float]    # seconds per component
    energy_j: float
    feasible: bool = True
    reason: str = ""


def _tp_allreduce_s(design: WSCDesign, wl: LLMWorkload, s: Strategy,
                    mb_tokens: int, cores_per_chunk: int) -> float:
    """2 all-reduces per layer over the TP group (Megatron)."""
    if s.tp <= 1:
        return 0.0
    act_bytes = mb_tokens * wl.d_model * BYTES
    vol = 2.0 * (s.tp - 1) / s.tp * act_bytes * 2.0      # 2 collectives/layer
    cores_per_reticle = design.cores_per_reticle()
    if cores_per_chunk <= cores_per_reticle:
        bw = design.reticle_bisection_Bps()
    else:
        bw = design.inter_reticle_bw_Bps()
    return vol / max(bw, 1.0)


def _pp_transfer_s(design: WSCDesign, wl: LLMWorkload, s: Strategy,
                   mb_tokens: int) -> float:
    if s.pp <= 1:
        return 0.0
    act_bytes = mb_tokens * wl.d_model * BYTES
    return act_bytes / max(design.inter_reticle_bw_Bps(), 1.0)


def _dp_allreduce_s(design: WSCDesign, wl: LLMWorkload, s: Strategy,
                    n_wafers: int) -> float:
    if s.dp <= 1 or wl.phase != "train":
        return 0.0
    grad_bytes = wl.params_bytes() / max(s.pp, 1)
    vol = 2.0 * (s.dp - 1) / s.dp * grad_bytes
    wafers_per_replica = max(n_wafers / s.dp, 1e-9)
    if wafers_per_replica >= 1.0:
        # replicas on separate wafers: bottleneck is inter-wafer NIs
        n_ni = 2 * (design.reticle_array[0] + design.reticle_array[1])
        bw = n_ni * C.INTER_WAFER_BW_PER_NI
    else:
        bw = design.inter_reticle_bw_Bps() * min(design.reticle_array)
    return vol / max(bw, 1.0)


def _dram_access_s(design: WSCDesign, wl: LLMWorkload, s: Strategy,
                   mb_tokens: int, n_wafers: int) -> float:
    """Weight/KV streaming beyond SRAM capacity (per microbatch, per chunk)."""
    sram_per_chunk = (design.buffer_kb * 1024.0
                      * design.total_cores() * n_wafers / max(s.chunks() * 1, 1))
    w_bytes = wl.params_bytes() / max(s.pp * s.dp, 1) / max(s.tp, 1) * s.tp
    w_bytes = wl.params_bytes() / max(s.pp, 1)           # per pipeline stage
    kv_bytes = (wl.kv_bytes_per_layer() * wl.n_layers / max(s.pp, 1)
                if wl.phase == "decode" else 0.0)
    spill = max(w_bytes + kv_bytes - sram_per_chunk, 0.0)
    if spill <= 0:
        return 0.0
    reticles_per_chunk = max(
        design.n_reticles() * n_wafers / max(s.chunks(), 1), 1e-9)
    if design.use_stacked_dram:
        bw = design.dram_bw_Bps_per_reticle() * reticles_per_chunk
        return spill / max(bw, 1.0)
    # off-chip: edge memory controllers + transit over inter-reticle mesh
    n_ctrl = 2 * (design.reticle_array[0] + design.reticle_array[1])
    bw = n_ctrl * C.OFFCHIP_BW_PER_CTRL / max(s.chunks(), 1)
    transit = design.inter_reticle_bw_Bps() * min(design.reticle_array) \
        / max(s.chunks(), 1)
    return spill / max(min(bw, transit), 1.0)


def evaluate_step(design: WSCDesign, wl: LLMWorkload, s: Strategy,
                  chunk_latency_cycles: float, graph: ChunkGraph,
                  n_wafers: int, peak_power_w: Optional[float] = None
                  ) -> StepResult:
    """Combine op-level chunk latency with chunk-level comm/DRAM/pipeline."""
    mb_count = s.microbatches if wl.phase == "train" else 1
    mb_tokens = max(wl.tokens_per_step() // (s.dp * mb_count), 1)
    layers_per_stage = max(wl.n_layers // s.pp, 1)

    # --- per-microbatch stage time -----------------------------------------
    compute_s = (chunk_latency_cycles * layers_per_stage / C.CLOCK_HZ)
    bwd_mult = 3.0 if wl.phase == "train" else 1.0       # fwd+bwd
    compute_s *= bwd_mult
    tp_s = _tp_allreduce_s(design, wl, s, mb_tokens,
                           design.total_cores() * n_wafers // max(s.chunks(), 1)
                           ) * layers_per_stage * bwd_mult
    pp_s = _pp_transfer_s(design, wl, s, mb_tokens) * bwd_mult
    dram_s = _dram_access_s(design, wl, s, mb_tokens, n_wafers)
    stage_s = compute_s + tp_s + pp_s + dram_s

    # --- pipeline + step ----------------------------------------------------
    eff = mb_count / (mb_count + s.pp - 1.0)
    iter_s = stage_s * mb_count / eff
    dp_s = _dp_allreduce_s(design, wl, s, n_wafers)
    step_s = iter_s + dp_s
    tokens = wl.tokens_per_step()
    throughput = tokens / max(step_s, 1e-12)

    # --- energy (action accounting, §VI-E) ----------------------------------
    E = C.ENERGY
    flops = wl.flops_per_step()
    e_mac = flops / 2.0 * E.mac * 1e-12
    sram_bits_layer = sum(o.tile.sram_read_bits + o.tile.sram_write_bits
                          for o in graph.ops) * graph.n_cores
    e_sram = (sram_bits_layer * wl.n_layers * mb_count * s.dp
              * bwd_mult * E.sram_read_bit * 1e-12)
    noc_bytes_layer = float(graph.link_loads.sum())
    e_noc = (noc_bytes_layer * 8 * wl.n_layers * mb_count * s.dp * bwd_mult
             * E.noc_bit_hop * 1e-12)
    ir_bytes = (2.0 * (s.tp - 1) / max(s.tp, 1) * mb_tokens * wl.d_model
                * BYTES * 2 * wl.n_layers * mb_count * s.dp * bwd_mult)
    ir_bytes += wl.params_bytes() * 2 * (1 if s.dp > 1 else 0)
    e_ir = ir_bytes * 8 * E.ir_bit(design.integration) * 1e-12
    dram_bytes = max(wl.params_bytes() / max(s.pp, 1)
                     - design.buffer_kb * 1024.0 * design.total_cores()
                     / max(s.chunks(), 1), 0.0) * mb_count * s.dp
    e_dram = dram_bytes * 8 * (E.dram_bit if design.use_stacked_dram
                               else E.offchip_bit) * 1e-12
    static_w = design.static_power_w() * n_wafers
    energy = e_mac + e_sram + e_noc + e_ir + e_dram + static_w * step_s
    if not (math.isfinite(step_s) and math.isfinite(energy)):
        return StepResult(float("inf"), 0.0, float("inf"), eff, {}, 0.0,
                          feasible=False, reason="non_finite")
    power = energy / max(step_s, 1e-12)

    limit = (peak_power_w if peak_power_w is not None
             else C.WAFER_POWER_W * n_wafers)
    feasible = power <= limit and math.isfinite(power)
    return StepResult(
        step_time_s=step_s,
        throughput=throughput,
        power_w=power,
        pipeline_eff=eff,
        breakdown={"compute": compute_s * mb_count / eff,
                   "tp": tp_s * mb_count / eff,
                   "pp": pp_s * mb_count / eff,
                   "dram": dram_s * mb_count / eff,
                   "dp": dp_s},
        energy_j=energy,
        feasible=feasible,
        reason="" if feasible else "power",
    )
