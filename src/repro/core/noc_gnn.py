"""GNN-based NoC congestion model (paper §VI-C, Eq. 5-6), pure JAX.

Input: the core-topology graph from the Workload Compiler — nodes = routers
(feature: packet injection rate), directed edges = physical links (feature:
transmission volume in flits, link bandwidth). Message passing runs on BOTH
the graph and its reverse (upstream contention + downstream backpressure,
after Noception [30]) for T iterations; the congestion head predicts each
link's average channel waiting time:

    y_e = MLP(concat(h_u^T, h_v^T, h_e^0))                      (Eq. 5)
    t(k) = k + sum_{l in route} y_l                             (Eq. 6)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compiler import ChunkGraph, _xy_route
from repro.core.design_space import WSCDesign
from repro.core.noc_sim import packets_for_transfer, simulate

HIDDEN = 32
T_ITERS = 3
NODE_F = 3      # injection rate, out-degree, in-degree
EDGE_F = 3      # log flits, bandwidth (norm), flows


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------


def _mlp_init(key, sizes):
    ks = jax.random.split(key, len(sizes) - 1)
    return [{"w": jax.random.normal(k, (a, b)) * (2.0 / a) ** 0.5,
             "b": jnp.zeros(b)}
            for k, a, b in zip(ks, sizes[:-1], sizes[1:])]


def _mlp(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return x


def init_gnn(key) -> Dict:
    ks = jax.random.split(key, 6)
    return {
        "node_enc": _mlp_init(ks[0], (NODE_F, HIDDEN, HIDDEN)),
        "edge_enc": _mlp_init(ks[1], (EDGE_F, HIDDEN, HIDDEN)),
        "msg_fwd": _mlp_init(ks[2], (2 * HIDDEN, HIDDEN)),
        "msg_bwd": _mlp_init(ks[3], (2 * HIDDEN, HIDDEN)),
        "update": _mlp_init(ks[4], (3 * HIDDEN, HIDDEN, HIDDEN)),
        "head": _mlp_init(ks[5], (3 * HIDDEN, HIDDEN, 1)),
    }


def gnn_logits(params: Dict, node_x: jnp.ndarray, edge_x: jnp.ndarray,
               senders: jnp.ndarray, receivers: jnp.ndarray,
               n_nodes: int,
               edge_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Raw head output = predicted log1p(waiting time) per edge — the model
    regresses in log space, which conditions training across the 4-decade
    range of waiting times. `edge_mask` (1.0 = real edge, 0.0 = padding)
    zeroes padded edges' messages before the segment sums so padded graphs
    (LinkGraphBatch) aggregate exactly like their unpadded originals."""
    h_v = _mlp(params["node_enc"], node_x)
    h_e0 = _mlp(params["edge_enc"], edge_x)
    h_e = h_e0
    for _ in range(T_ITERS):
        m_in = _mlp(params["msg_fwd"],
                    jnp.concatenate([h_v[senders], h_e], axis=-1))
        m_out = _mlp(params["msg_bwd"],
                     jnp.concatenate([h_v[receivers], h_e], axis=-1))
        if edge_mask is not None:
            m_in = m_in * edge_mask[:, None]
            m_out = m_out * edge_mask[:, None]
        agg_in = jax.ops.segment_sum(m_in, receivers, n_nodes)
        agg_out = jax.ops.segment_sum(m_out, senders, n_nodes)
        h_v = _mlp(params["update"],
                   jnp.concatenate([h_v, agg_in, agg_out], axis=-1))
    y = _mlp(params["head"],
             jnp.concatenate([h_v[senders], h_v[receivers], h_e0], axis=-1))
    return y[:, 0]


def gnn_forward(params: Dict, node_x: jnp.ndarray, edge_x: jnp.ndarray,
                senders: jnp.ndarray, receivers: jnp.ndarray,
                n_nodes: int,
                edge_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Predicted average waiting time per edge (>= 0), Eq. 5. The log-space
    head is clipped at 30 (~1e13 cycles) so an out-of-distribution input
    can't overflow expm1 into inf/NaN downstream."""
    z = gnn_logits(params, node_x, edge_x, senders, receivers, n_nodes,
                   edge_mask)
    return jnp.expm1(jnp.clip(jax.nn.relu(z), 0.0, 30.0))


# ---------------------------------------------------------------------------
# graph featurization from a compiled chunk
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LinkGraph:
    node_x: np.ndarray
    edge_x: np.ndarray
    senders: np.ndarray
    receivers: np.ndarray
    links: List[Tuple[int, int]]
    n_nodes: int
    target: np.ndarray = None     # per-edge avg wait (from noc_sim)


def featurize_transfer(graph: ChunkGraph, design: WSCDesign, t_idx: int,
                       with_target: bool = False) -> LinkGraph:
    W = graph.array[1]
    n = graph.n_cores
    pkts = packets_for_transfer(graph, design, t_idx)

    link_flits: Dict[Tuple[int, int], float] = {}
    link_flows: Dict[Tuple[int, int], int] = {}
    inj = np.zeros(n)
    for p in pkts:
        inj[p.src] += p.flits
        for hop in _xy_route(p.src, p.dst, W):
            link_flits[hop] = link_flits.get(hop, 0.0) + p.flits
            link_flows[hop] = link_flows.get(hop, 0) + 1
    links = sorted(link_flits)
    senders = np.array([u for u, _ in links], np.int32)
    receivers = np.array([v for _, v in links], np.int32)

    dur = max(graph.ops[graph.transfers[t_idx].src_op].tile.cycles, 1.0)
    out_deg = np.zeros(n)
    in_deg = np.zeros(n)
    for u, v in links:
        out_deg[u] += 1
        in_deg[v] += 1
    node_x = np.stack([inj / dur, out_deg / 4.0, in_deg / 4.0], axis=1)
    edge_x = np.stack([
        np.log1p([link_flits[l] for l in links]),
        np.full(len(links), design.noc_bw / 4096.0),
        np.log1p([link_flows[l] for l in links]),
    ], axis=1)

    target = None
    if with_target:
        res = simulate(pkts, W)
        target = np.array([res.link_wait.get(l, 0.0) for l in links])
    return LinkGraph(node_x.astype(np.float32), edge_x.astype(np.float32),
                     senders, receivers, links, n, target)


# ---------------------------------------------------------------------------
# padded struct-of-arrays batching (DESIGN.md §4b)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LinkGraphBatch:
    """G link graphs padded to a common (n_nodes, n_edges) shape. Padded
    edges carry zero features, point at node 0, and are masked out of the
    message-passing aggregations (`edge_mask`); padded node rows are inert
    because no unmasked edge references them."""
    node_x: np.ndarray      # (G, n_nodes, NODE_F) float32
    edge_x: np.ndarray      # (G, n_edges, EDGE_F) float32
    senders: np.ndarray     # (G, n_edges) int32, padding -> 0
    receivers: np.ndarray   # (G, n_edges) int32, padding -> 0
    edge_mask: np.ndarray   # (G, n_edges) float32, 1 = real edge
    n_nodes: int            # static padded node count
    n_edges_real: np.ndarray  # (G,) real edge count per graph
    target: Optional[np.ndarray] = None   # (G, n_edges), 0 on padding

    def __len__(self) -> int:
        return self.node_x.shape[0]


def next_pow2(n: int) -> int:
    return 1 << max(int(n) - 1, 0).bit_length()


def pad_link_graphs(graphs: Sequence[LinkGraph],
                    n_nodes: Optional[int] = None,
                    n_edges: Optional[int] = None,
                    with_target: bool = False) -> LinkGraphBatch:
    """Stack LinkGraphs into one padded batch. Node/edge capacities default
    to the next power of two above the max in the batch, so repeated calls
    bucket onto a handful of jit-compiled shapes."""
    G = len(graphs)
    nn = n_nodes or next_pow2(max((g.n_nodes for g in graphs), default=1))
    ne = n_edges or next_pow2(max((len(g.links) for g in graphs), default=1))
    node_x = np.zeros((G, nn, NODE_F), np.float32)
    edge_x = np.zeros((G, ne, EDGE_F), np.float32)
    senders = np.zeros((G, ne), np.int32)
    receivers = np.zeros((G, ne), np.int32)
    mask = np.zeros((G, ne), np.float32)
    n_real = np.zeros(G, np.int64)
    target = np.zeros((G, ne), np.float32) if with_target else None
    for i, g in enumerate(graphs):
        e = len(g.links)
        n_real[i] = e
        node_x[i, :g.n_nodes] = g.node_x
        edge_x[i, :e] = g.edge_x
        senders[i, :e] = g.senders
        receivers[i, :e] = g.receivers
        mask[i, :e] = 1.0
        if with_target and g.target is not None:
            target[i, :e] = g.target
    return LinkGraphBatch(node_x, edge_x, senders, receivers, mask, nn,
                          n_real, target)


@functools.partial(jax.jit, static_argnames=("n_nodes",))
def _forward_batch_jit(params, node_x, edge_x, senders, receivers, edge_mask,
                       *, n_nodes):
    def one(nx, ex, s, r, m):
        return gnn_forward(params, nx, ex, s, r, n_nodes, edge_mask=m)
    return jax.vmap(one)(node_x, edge_x, senders, receivers, edge_mask)


def gnn_forward_batch(params: Dict, batch: LinkGraphBatch) -> np.ndarray:
    """Predicted waiting time for every edge of every graph in one XLA call.
    Returns (G, n_edges) float32; padded positions are meaningless."""
    out = _forward_batch_jit(
        jax.tree.map(jnp.asarray, params), jnp.asarray(batch.node_x),
        jnp.asarray(batch.edge_x), jnp.asarray(batch.senders),
        jnp.asarray(batch.receivers), jnp.asarray(batch.edge_mask),
        n_nodes=int(batch.n_nodes))
    return np.asarray(out)


@functools.partial(jax.jit, static_argnames=("n_nodes",))
def _val_batch_jit(params, node_x, edge_x, senders, receivers, edge_mask,
                   target, *, n_nodes):
    def one(nx, ex, s, r, m, tgt):
        z = gnn_logits(params, nx, ex, s, r, n_nodes, edge_mask=m)
        err = ((z - jnp.log1p(tgt)) ** 2) * m
        return jnp.sum(err) / jnp.maximum(jnp.sum(m), 1.0), z
    return jax.vmap(one)(node_x, edge_x, senders, receivers, edge_mask,
                         target)


def kendall_tau(a: np.ndarray, b: np.ndarray, max_n: int = 2000,
                seed: int = 0) -> float:
    """Kendall rank correlation, vectorized over all O(n^2) pairs (with a
    deterministic subsample above `max_n` elements)."""
    a = np.asarray(a, np.float64).ravel()
    b = np.asarray(b, np.float64).ravel()
    n = len(a)
    if n < 2:
        return 0.0
    if n > max_n:
        idx = np.random.default_rng(seed).choice(n, max_n, replace=False)
        a, b = a[idx], b[idx]
        n = max_n
    iu = np.triu_indices(n, 1)
    sa = np.sign(a[:, None] - a[None, :])[iu]
    sb = np.sign(b[:, None] - b[None, :])[iu]
    m = (sa != 0) & (sb != 0)
    den = int(m.sum())
    num = int(((sa == sb) & m).sum()) - int(((sa != sb) & m).sum())
    return num / max(den, 1)


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TrainHistory:
    """Per-epoch training record. `train_loss` is the averaged per-graph
    log-space MSE (the quantity the old API returned as a bare list);
    `val_loss` / `val_kendall_tau` are held-out metrics (empty when
    val_frac == 0). `best_epoch` indexes the epoch whose parameters were
    returned; `stopped_epoch` is set when early stopping fired."""
    train_loss: List[float] = dataclasses.field(default_factory=list)
    val_loss: List[float] = dataclasses.field(default_factory=list)
    val_kendall_tau: List[float] = dataclasses.field(default_factory=list)
    best_epoch: int = -1
    stopped_epoch: Optional[int] = None

    @property
    def best_val_loss(self) -> Optional[float]:
        """Validation loss of the epoch whose parameters were returned —
        NOT the last epoch's (early stopping returns the best checkpoint,
        so the stagnant tail's metrics would misstate its quality)."""
        return self.val_loss[self.best_epoch] \
            if self.val_loss and self.best_epoch >= 0 else None

    @property
    def best_val_kendall_tau(self) -> Optional[float]:
        return self.val_kendall_tau[self.best_epoch] \
            if self.val_kendall_tau and self.best_epoch >= 0 else None


def _val_metrics(params: Dict, batch: LinkGraphBatch) -> Tuple[float, float]:
    losses, zs = _val_batch_jit(
        jax.tree.map(jnp.asarray, params), jnp.asarray(batch.node_x),
        jnp.asarray(batch.edge_x), jnp.asarray(batch.senders),
        jnp.asarray(batch.receivers), jnp.asarray(batch.edge_mask),
        jnp.asarray(batch.target), n_nodes=int(batch.n_nodes))
    real = np.asarray(batch.edge_mask) > 0
    # rank what the deployed predictor actually outputs: gnn_forward applies
    # expm1(clip(relu(z))), so negative logits collapse to tied zero waits —
    # ranking raw z would credit orderings the model cannot express
    pred = np.clip(np.maximum(np.asarray(zs), 0.0), 0.0, 30.0)
    kt = kendall_tau(pred[real], np.asarray(batch.target)[real])
    return float(np.mean(np.asarray(losses))), kt


@functools.partial(jax.jit, static_argnames=("n_nodes",))
def _train_step_jit(params, m, v, step, lr, node_x, edge_x, senders,
                    receivers, edge_mask, target, *, n_nodes):
    """One fused (grad + Adam) update on a padded graph: masked-mean MSE in
    log space equals the unpadded per-graph mean, so bucketing graphs to
    pow2 shapes changes the compile count, not the optimization problem."""
    def loss_fn(p):
        z = gnn_logits(p, node_x, edge_x, senders, receivers, n_nodes,
                       edge_mask=edge_mask)
        err = ((z - jnp.log1p(target)) ** 2) * edge_mask
        return jnp.sum(err) / jnp.maximum(jnp.sum(edge_mask), 1.0)

    lval, grads = jax.value_and_grad(loss_fn)(params)
    b1, b2 = 0.9, 0.999
    m = jax.tree.map(lambda a, g_: b1 * a + (1 - b1) * g_, m, grads)
    v = jax.tree.map(lambda a, g_: b2 * a + (1 - b2) * g_ * g_, v, grads)
    bc1 = 1 - b1 ** step
    bc2 = 1 - b2 ** step
    params = jax.tree.map(
        lambda p_, m_, v_: p_ - lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + 1e-8),
        params, m, v)
    return params, m, v, lval


def train_gnn(params: Dict, dataset: List[LinkGraph], epochs: int = 60,
              lr: float = 3e-3, seed: int = 0, val_frac: float = 0.0,
              patience: Optional[int] = None) -> Tuple[Dict, TrainHistory]:
    """Full-batch-per-graph Adam on log1p(wait) MSE.

    With `val_frac` > 0 a deterministic held-out split is scored every epoch
    (log-space MSE + Kendall tau of predicted vs simulated waits); with
    `patience` set, training stops after that many epochs without val-loss
    improvement and the best-epoch parameters are returned — the signal the
    online calibration loop (calibration.py) early-stops on.
    """

    rng = np.random.default_rng(seed)

    usable = [g for g in dataset
              if g.target is not None and len(g.links) > 0]
    val: List[LinkGraph] = []
    train = list(dataset)
    if val_frac > 0.0 and len(usable) >= 2:
        n_val = max(1, int(round(val_frac * len(usable))))
        n_val = min(n_val, len(usable) - 1)
        picked = rng.permutation(len(usable))[:n_val]
        val = [usable[i] for i in picked]
        val_ids = {id(g) for g in val}
        train = [g for g in dataset if id(g) not in val_ids]
    val_batch = pad_link_graphs(val, with_target=True) if val else None

    # shape-bucketed fused train step: each graph is padded to pow2
    # node/edge capacities (masked-mean loss == the unpadded mean), and the
    # grad + Adam update runs as ONE jitted call per bucket — a handful of
    # compiles total instead of one per distinct graph shape, and none of
    # the per-step eager tree.map dispatch overhead
    padded = {}
    for g in dataset:
        if g.target is None or len(g.links) == 0:
            padded[id(g)] = None
            continue
        nn = next_pow2(g.n_nodes)
        ne = next_pow2(len(g.links))
        b = pad_link_graphs([g], n_nodes=nn, n_edges=ne, with_target=True)
        padded[id(g)] = (jnp.asarray(b.node_x[0]), jnp.asarray(b.edge_x[0]),
                         jnp.asarray(b.senders[0]), jnp.asarray(b.receivers[0]),
                         jnp.asarray(b.edge_mask[0]),
                         jnp.asarray(b.target[0]), nn)

    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    hist = TrainHistory()
    best_params = params
    best_val = float("inf")
    since_best = 0
    step = 0
    for ep in range(epochs):
        order = rng.permutation(len(train))
        ep_loss = 0.0
        for gi in order:
            arrs = padded.get(id(train[gi]))
            if arrs is None:
                continue
            step += 1
            params, m, v, lval = _train_step_jit(
                params, m, v, jnp.asarray(float(step)),
                jnp.asarray(lr, jnp.float32), *arrs[:6], n_nodes=arrs[6])
            ep_loss += float(lval)
        hist.train_loss.append(ep_loss / max(len(train), 1))
        if val_batch is not None:
            vl, kt = _val_metrics(params, val_batch)
            hist.val_loss.append(vl)
            hist.val_kendall_tau.append(kt)
            if vl < best_val - 1e-12:
                best_val, best_params, since_best = vl, params, 0
                hist.best_epoch = ep
            else:
                since_best += 1
                if patience is not None and since_best >= patience:
                    hist.stopped_epoch = ep
                    return best_params, hist
    if val_batch is not None:
        return best_params, hist
    hist.best_epoch = epochs - 1
    return params, hist


_gnn_forward_jit = jax.jit(gnn_forward, static_argnums=(5,))


def predict_transfer_makespan(params: Dict, graph: ChunkGraph,
                              design: WSCDesign, t_idx: int) -> float:
    """Eq. 6 reconstruction: per-packet t(k) = k + sum of predicted waits on
    its route; transfer makespan = max over packets of inject + latency."""
    g = featurize_transfer(graph, design, t_idx)
    if len(g.links) == 0:
        return 0.0
    wait = np.asarray(_gnn_forward_jit(
        jax.tree.map(jnp.asarray, params), jnp.asarray(g.node_x),
        jnp.asarray(g.edge_x), jnp.asarray(g.senders),
        jnp.asarray(g.receivers), int(g.n_nodes)))
    wait_by_link = {l: float(w) for l, w in zip(g.links, wait)}
    W = graph.array[1]
    pkts = packets_for_transfer(graph, design, t_idx)
    worst = 0.0
    for p in pkts:
        route = _xy_route(p.src, p.dst, W)
        t = p.flits + len(route) + sum(wait_by_link.get(h, 0.0) for h in route)
        worst = max(worst, p.inject + t)
    return worst


def chunk_latency_cycles_gnn(params: Dict, graph: ChunkGraph,
                             design: WSCDesign) -> float:
    total = 0.0
    for i, node in enumerate(graph.ops):
        total += node.tile.cycles
        if i < len(graph.transfers) and graph.transfers[i].pairs:
            total += predict_transfer_makespan(params, graph, design, i)
    return total
