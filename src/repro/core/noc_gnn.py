"""GNN-based NoC congestion model (paper §VI-C, Eq. 5-6), pure JAX.

Input: the core-topology graph from the Workload Compiler — nodes = routers
(feature: packet injection rate), directed edges = physical links (feature:
transmission volume in flits, link bandwidth). Message passing runs on BOTH
the graph and its reverse (upstream contention + downstream backpressure,
after Noception [30]) for T iterations; the congestion head predicts each
link's average channel waiting time:

    y_e = MLP(concat(h_u^T, h_v^T, h_e^0))                      (Eq. 5)
    t(k) = k + sum_{l in route} y_l                             (Eq. 6)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compiler import ChunkGraph, _xy_route
from repro.core.design_space import WSCDesign
from repro.core.noc_sim import packets_for_transfer, simulate

HIDDEN = 32
T_ITERS = 3
NODE_F = 3      # injection rate, out-degree, in-degree
EDGE_F = 3      # log flits, bandwidth (norm), flows


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------


def _mlp_init(key, sizes):
    ks = jax.random.split(key, len(sizes) - 1)
    return [{"w": jax.random.normal(k, (a, b)) * (2.0 / a) ** 0.5,
             "b": jnp.zeros(b)}
            for k, a, b in zip(ks, sizes[:-1], sizes[1:])]


def _mlp(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return x


def init_gnn(key) -> Dict:
    ks = jax.random.split(key, 6)
    return {
        "node_enc": _mlp_init(ks[0], (NODE_F, HIDDEN, HIDDEN)),
        "edge_enc": _mlp_init(ks[1], (EDGE_F, HIDDEN, HIDDEN)),
        "msg_fwd": _mlp_init(ks[2], (2 * HIDDEN, HIDDEN)),
        "msg_bwd": _mlp_init(ks[3], (2 * HIDDEN, HIDDEN)),
        "update": _mlp_init(ks[4], (3 * HIDDEN, HIDDEN, HIDDEN)),
        "head": _mlp_init(ks[5], (3 * HIDDEN, HIDDEN, 1)),
    }


def gnn_logits(params: Dict, node_x: jnp.ndarray, edge_x: jnp.ndarray,
               senders: jnp.ndarray, receivers: jnp.ndarray,
               n_nodes: int) -> jnp.ndarray:
    """Raw head output = predicted log1p(waiting time) per edge — the model
    regresses in log space, which conditions training across the 4-decade
    range of waiting times."""
    h_v = _mlp(params["node_enc"], node_x)
    h_e0 = _mlp(params["edge_enc"], edge_x)
    h_e = h_e0
    for _ in range(T_ITERS):
        m_in = _mlp(params["msg_fwd"],
                    jnp.concatenate([h_v[senders], h_e], axis=-1))
        agg_in = jax.ops.segment_sum(m_in, receivers, n_nodes)
        m_out = _mlp(params["msg_bwd"],
                     jnp.concatenate([h_v[receivers], h_e], axis=-1))
        agg_out = jax.ops.segment_sum(m_out, senders, n_nodes)
        h_v = _mlp(params["update"],
                   jnp.concatenate([h_v, agg_in, agg_out], axis=-1))
    y = _mlp(params["head"],
             jnp.concatenate([h_v[senders], h_v[receivers], h_e0], axis=-1))
    return y[:, 0]


def gnn_forward(params: Dict, node_x: jnp.ndarray, edge_x: jnp.ndarray,
                senders: jnp.ndarray, receivers: jnp.ndarray,
                n_nodes: int) -> jnp.ndarray:
    """Predicted average waiting time per edge (>= 0), Eq. 5. The log-space
    head is clipped at 30 (~1e13 cycles) so an out-of-distribution input
    can't overflow expm1 into inf/NaN downstream."""
    z = gnn_logits(params, node_x, edge_x, senders, receivers, n_nodes)
    return jnp.expm1(jnp.clip(jax.nn.relu(z), 0.0, 30.0))


# ---------------------------------------------------------------------------
# graph featurization from a compiled chunk
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LinkGraph:
    node_x: np.ndarray
    edge_x: np.ndarray
    senders: np.ndarray
    receivers: np.ndarray
    links: List[Tuple[int, int]]
    n_nodes: int
    target: np.ndarray = None     # per-edge avg wait (from noc_sim)


def featurize_transfer(graph: ChunkGraph, design: WSCDesign, t_idx: int,
                       with_target: bool = False) -> LinkGraph:
    W = graph.array[1]
    n = graph.n_cores
    pkts = packets_for_transfer(graph, design, t_idx)

    link_flits: Dict[Tuple[int, int], float] = {}
    link_flows: Dict[Tuple[int, int], int] = {}
    inj = np.zeros(n)
    for p in pkts:
        inj[p.src] += p.flits
        for hop in _xy_route(p.src, p.dst, W):
            link_flits[hop] = link_flits.get(hop, 0.0) + p.flits
            link_flows[hop] = link_flows.get(hop, 0) + 1
    links = sorted(link_flits)
    senders = np.array([u for u, _ in links], np.int32)
    receivers = np.array([v for _, v in links], np.int32)

    dur = max(graph.ops[graph.transfers[t_idx].src_op].tile.cycles, 1.0)
    out_deg = np.zeros(n)
    in_deg = np.zeros(n)
    for u, v in links:
        out_deg[u] += 1
        in_deg[v] += 1
    node_x = np.stack([inj / dur, out_deg / 4.0, in_deg / 4.0], axis=1)
    edge_x = np.stack([
        np.log1p([link_flits[l] for l in links]),
        np.full(len(links), design.noc_bw / 4096.0),
        np.log1p([link_flows[l] for l in links]),
    ], axis=1)

    target = None
    if with_target:
        res = simulate(pkts, W)
        target = np.array([res.link_wait.get(l, 0.0) for l in links])
    return LinkGraph(node_x.astype(np.float32), edge_x.astype(np.float32),
                     senders, receivers, links, n, target)


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------


def train_gnn(params: Dict, dataset: List[LinkGraph], epochs: int = 60,
              lr: float = 3e-3, seed: int = 0) -> Tuple[Dict, List[float]]:
    """Full-batch-per-graph Adam on log1p(wait) MSE."""

    def loss_one(p, node_x, edge_x, senders, receivers, target, n_nodes):
        z = gnn_logits(p, node_x, edge_x, senders, receivers, n_nodes)
        tgt = jnp.log1p(target)
        return jnp.mean((z - tgt) ** 2)

    grad_fn = jax.jit(jax.value_and_grad(loss_one), static_argnums=(6,))
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    losses = []
    step = 0
    rng = np.random.default_rng(seed)
    for ep in range(epochs):
        order = rng.permutation(len(dataset))
        ep_loss = 0.0
        for gi in order:
            g = dataset[gi]
            if g.target is None or len(g.links) == 0:
                continue
            step += 1
            lval, grads = grad_fn(params, jnp.asarray(g.node_x),
                                  jnp.asarray(g.edge_x),
                                  jnp.asarray(g.senders),
                                  jnp.asarray(g.receivers),
                                  jnp.asarray(g.target, jnp.float32),
                                  int(g.n_nodes))
            ep_loss += float(lval)
            b1, b2 = 0.9, 0.999
            m = jax.tree.map(lambda a, g_: b1 * a + (1 - b1) * g_, m, grads)
            v = jax.tree.map(lambda a, g_: b2 * a + (1 - b2) * g_ * g_, v, grads)
            bc1 = 1 - b1 ** step
            bc2 = 1 - b2 ** step
            params = jax.tree.map(
                lambda p_, m_, v_: p_ - lr * (m_ / bc1)
                / (jnp.sqrt(v_ / bc2) + 1e-8),
                params, m, v)
        losses.append(ep_loss / max(len(dataset), 1))
    return params, losses


_gnn_forward_jit = jax.jit(gnn_forward, static_argnums=(5,))


def predict_transfer_makespan(params: Dict, graph: ChunkGraph,
                              design: WSCDesign, t_idx: int) -> float:
    """Eq. 6 reconstruction: per-packet t(k) = k + sum of predicted waits on
    its route; transfer makespan = max over packets of inject + latency."""
    g = featurize_transfer(graph, design, t_idx)
    if len(g.links) == 0:
        return 0.0
    wait = np.asarray(_gnn_forward_jit(
        jax.tree.map(jnp.asarray, params), jnp.asarray(g.node_x),
        jnp.asarray(g.edge_x), jnp.asarray(g.senders),
        jnp.asarray(g.receivers), int(g.n_nodes)))
    wait_by_link = {l: float(w) for l, w in zip(g.links, wait)}
    W = graph.array[1]
    pkts = packets_for_transfer(graph, design, t_idx)
    worst = 0.0
    for p in pkts:
        route = _xy_route(p.src, p.dst, W)
        t = p.flits + len(route) + sum(wait_by_link.get(h, 0.0) for h in route)
        worst = max(worst, p.inject + t)
    return worst


def chunk_latency_cycles_gnn(params: Dict, graph: ChunkGraph,
                             design: WSCDesign) -> float:
    total = 0.0
    for i, node in enumerate(graph.ops):
        total += node.tile.cycles
        if i < len(graph.transfers) and graph.transfers[i].pairs:
            total += predict_transfer_makespan(params, graph, design, i)
    return total
