"""Request-level serving evaluation: analytical continuous batching.

The per-step evaluators score isolated prefill/decode `LLMWorkload`s; real
serving interleaves them: a fixed pool of decode slots runs batched decode
steps, finished slots are immediately refilled from the request queue, and
each admission runs a single-prompt prefill that stalls decode — exactly
`repro.serve.engine.ServeEngine`'s loop. This module composes the existing
per-step evaluations — through the fidelity registry, batched over the
candidate axis — into request-level metrics: TTFT, TPOT, tokens/s goodput
under a `ServingSLO`, for a `RequestMix` (DESIGN.md §8).

Key decomposition: decode steps all take the same time and admissions
happen at step boundaries, so the *discrete* schedule — which step each
request is admitted/finishes at, and how many prefills precede each step —
depends only on (mix, slots), never on the design. `continuous_batch_schedule`
computes it once by mirroring `ServeEngine.step`/`_admit` semantics
(cross-validated against a real engine run in tests/test_serving.py);
`serving_metrics` then broadcasts wall-clock TTFT/TPOT/goodput over the
candidate axis as pure array math against per-design step times.

`disaggregated_metrics` is the coupled-request counterpart for
prefill/decode disaggregation (heterogeneity.py): prefills run on their own
stage so decode never stalls, but admission is gated by prefill completion
plus the KV-cache transfer between stages.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.design_space import WSCDesign
from repro.core.fidelity import EvalResult, FidelityBackend, get_backend
from repro.core.workload import LLMWorkload, RequestMix

Fidelity = Union[str, FidelityBackend]


@dataclasses.dataclass(frozen=True)
class ServingSLO:
    """Service-level objective: a request counts toward goodput only if its
    time-to-first-token and time-per-output-token both meet the bound."""
    ttft_s: float
    tpot_s: float


@dataclasses.dataclass
class BatchSchedule:
    """Design-independent discrete schedule of one arrival batch under
    continuous batching with `slots` decode slots (ServeEngine semantics:
    admissions fill free slots in queue order at the start of each step;
    the admitted request's first token comes from its prefill; each decode
    step then generates one token per live slot)."""
    slots: int
    n_decode_steps: int
    admit_step: np.ndarray        # (R,) step at whose start r is admitted
    finish_step: np.ndarray       # (R,) step at whose end r completes
    decode_tokens: np.ndarray     # (R,) decode steps r occupies: max(out-1,1)


def continuous_batch_schedule(mix: RequestMix, slots: int) -> BatchSchedule:
    """Mirror `ServeEngine.step`/`_admit` on the request mix. The decode
    step count is the quantity cross-validated against a real engine run.

    Since the trace subsystem landed this is the degenerate case of
    `core.traces.trace_schedule` — every request arrives at step 0, one
    tenant, FIFO admission — and delegates to it (property-tested bitwise
    equal to the original loop, kept as `_continuous_batch_schedule_ref`,
    so PR 4 behavior and the fig11b numbers are provably unchanged)."""
    from repro.core.traces import RequestTrace, trace_schedule

    if slots < 1:
        raise ValueError("slots must be >= 1")
    if mix.n_requests == 0:
        return BatchSchedule(slots=slots, n_decode_steps=0,
                             admit_step=np.zeros(0, np.int64),
                             finish_step=np.zeros(0, np.int64),
                             decode_tokens=np.zeros(0, np.int64))
    ts = trace_schedule(RequestTrace.from_mix(mix), slots, "fifo")
    return BatchSchedule(slots=slots, n_decode_steps=ts.n_decode_steps,
                         admit_step=ts.admit_step,
                         finish_step=ts.finish_step,
                         decode_tokens=ts.decode_tokens)


def _continuous_batch_schedule_ref(mix: RequestMix,
                                   slots: int) -> BatchSchedule:
    """The original PR 4 per-step loop, kept as the reference for the
    degenerate-case bitwise property test in tests/test_traces.py."""
    if slots < 1:
        raise ValueError("slots must be >= 1")
    R = mix.n_requests
    decode_tokens = np.maximum(np.asarray(mix.out_lens, np.int64) - 1, 1)
    admit_step = np.zeros(R, np.int64)
    finish_step = np.zeros(R, np.int64)
    active: Dict[int, List[int]] = {}      # slot -> [rid, remaining]
    nxt = 0
    step = 0
    while nxt < R or active:
        for slot in range(slots):
            if slot not in active and nxt < R:
                admit_step[nxt] = step
                active[slot] = [nxt, int(decode_tokens[nxt])]
                nxt += 1
        for slot in list(active):
            active[slot][1] -= 1
            if active[slot][1] == 0:
                finish_step[active[slot][0]] = step
                del active[slot]
        step += 1
    return BatchSchedule(slots=slots, n_decode_steps=step,
                         admit_step=admit_step, finish_step=finish_step,
                         decode_tokens=decode_tokens)


def serving_metrics(sched: BatchSchedule, mix: RequestMix, slo: ServingSLO,
                    t_prefill_ref: np.ndarray, prompt_ref: int,
                    t_decode: np.ndarray) -> Dict[str, np.ndarray]:
    """Wall-clock request metrics for C candidates, broadcast over the
    candidate axis. `t_prefill_ref` (C,) is the prefill latency at prompt
    length `prompt_ref` — prefill is token-throughput bound, so per-request
    prefill time scales linearly with prompt length. `t_decode` (C,) is the
    batched decode step time. Returns (C,)/(C, R) arrays."""
    tp_ref = np.asarray(t_prefill_ref, np.float64).reshape(-1, 1)
    td = np.asarray(t_decode, np.float64).reshape(-1, 1)
    plens = np.asarray(mix.prompt_lens, np.float64)[None, :]
    t_p = tp_ref * plens / max(prompt_ref, 1)              # (C, R)
    cum_tp = np.cumsum(t_p, axis=1)                        # admission order

    # first token comes out of the admission prefill itself; before it, the
    # request waited through admit_step decode steps and every earlier
    # prefill (admission order == queue order)
    ttft = sched.admit_step[None, :] * td + cum_tp

    # prefill seconds elapsed by the end of step k = cumulative prefill time
    # of the last request admitted at a step <= k (admit_step nondecreasing)
    last_adm = np.searchsorted(sched.admit_step,
                               np.arange(sched.n_decode_steps),
                               side="right") - 1
    cum_tp_by_step = cum_tp[:, last_adm]                   # (C, n_steps)
    completion = ((sched.finish_step[None, :] + 1) * td
                  + cum_tp_by_step[:, sched.finish_step])
    # TPOT as a request observes it: decode-phase wall time (including
    # stalls from later admissions' prefills) per generated token
    tpot = (completion - ttft) / np.maximum(sched.decode_tokens[None, :], 1)

    total_time = cum_tp[:, -1] + sched.n_decode_steps * td[:, 0]
    out_toks = np.asarray(mix.out_lens, np.float64)[None, :]
    met = (ttft <= slo.ttft_s) & (tpot <= slo.tpot_s)
    return {
        "ttft": ttft, "tpot": tpot, "met": met,
        "total_time": total_time,
        "throughput": out_toks.sum() / np.maximum(total_time, 1e-12),
        "goodput": (out_toks * met).sum(axis=1)
        / np.maximum(total_time, 1e-12),
        "slo_attainment": met.mean(axis=1),
    }


# ---------------------------------------------------------------------------
# design evaluation: per-step evals (fidelity registry, batched) -> requests
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServingResult:
    feasible: bool
    goodput_tok_s: float
    throughput_tok_s: float
    ttft_s: float                 # mean over requests
    ttft_max_s: float
    tpot_s: float                 # mean over requests
    tpot_max_s: float
    slo_attainment: float
    total_time_s: float
    n_decode_steps: int
    power_w: float
    energy_j: float
    n_wafers: int
    prefill: Optional[EvalResult]
    decode: Optional[EvalResult]
    reason: str = ""


def serving_workloads(wl_base: LLMWorkload, mix: RequestMix, slots: int
                      ) -> Tuple[LLMWorkload, LLMWorkload, int]:
    """The two per-step workloads serving composes: a single-prompt prefill
    at the mix's mean prompt length (the engine prefills one request at a
    time) and a `slots`-wide decode step at the mid-generation context."""
    p_ref = max(1, int(round(mix.mean_prompt)))
    wl_p = dataclasses.replace(wl_base, phase="prefill", batch=1, seq=p_ref)
    wl_d = dataclasses.replace(wl_base, phase="decode", batch=slots,
                               seq=mix.context_len())
    return wl_p, wl_d, p_ref


def _infeasible(nw: int, reason: str) -> ServingResult:
    return ServingResult(
        feasible=False, goodput_tok_s=0.0, throughput_tok_s=0.0,
        ttft_s=float("inf"), ttft_max_s=float("inf"), tpot_s=float("inf"),
        tpot_max_s=float("inf"), slo_attainment=0.0,
        total_time_s=float("inf"), n_decode_steps=0, power_w=float("inf"),
        energy_j=0.0, n_wafers=nw, prefill=None, decode=None, reason=reason)


def evaluate_serving_batch(designs: Sequence[WSCDesign],
                           wl_base: LLMWorkload, mix: RequestMix,
                           slo: ServingSLO, *, slots: int = 8,
                           fidelity: Fidelity = "analytical",
                           gnn_params: Optional[Dict] = None,
                           n_wafers=None,
                           max_strategies: int = 24) -> List[ServingResult]:
    """Request-level serving metrics for N designs: two registry-batched
    per-step evaluations (prefill, decode) + the shared discrete schedule,
    composed per candidate as array math."""
    from repro.core.evaluator import evaluate_design_batch

    backend = get_backend(fidelity)
    designs = list(designs)
    if not designs:
        return []
    wl_p, wl_d, p_ref = serving_workloads(wl_base, mix, slots)
    rps = evaluate_design_batch(designs, wl_p, fidelity=backend,
                                gnn_params=gnn_params, n_wafers=n_wafers,
                                max_strategies=max_strategies)
    rds = evaluate_design_batch(designs, wl_d, fidelity=backend,
                                gnn_params=gnn_params, n_wafers=n_wafers,
                                max_strategies=max_strategies)
    sched = continuous_batch_schedule(mix, slots)

    feas = [i for i in range(len(designs))
            if rps[i].feasible and rds[i].feasible]
    feas_set = set(feas)
    results: List[Optional[ServingResult]] = [None] * len(designs)
    for i in range(len(designs)):
        if i not in feas_set:
            reason = ("prefill_" if not rps[i].feasible else "decode_") \
                + "infeasible"
            results[i] = _infeasible(rps[i].n_wafers, reason)
    if not feas:
        return results                      # type: ignore[return-value]

    t_p = np.array([rps[i].step.step_time_s for i in feas])
    t_d = np.array([rds[i].step.step_time_s for i in feas])
    e_p = np.array([rps[i].step.energy_j for i in feas])
    e_d = np.array([rds[i].step.energy_j for i in feas])
    m = serving_metrics(sched, mix, slo, t_p, p_ref, t_d)

    # energy: each prefill costs its prompt-scaled share of the reference
    # prefill step; each decode step costs the batched decode step's energy
    plens_sum = float(np.sum(mix.prompt_lens))
    energy = e_p * plens_sum / p_ref + e_d * sched.n_decode_steps
    power = energy / np.maximum(m["total_time"], 1e-12)

    for j, i in enumerate(feas):
        results[i] = ServingResult(
            feasible=True,
            goodput_tok_s=float(m["goodput"][j]),
            throughput_tok_s=float(m["throughput"][j]),
            ttft_s=float(m["ttft"][j].mean()),
            ttft_max_s=float(m["ttft"][j].max()),
            tpot_s=float(m["tpot"][j].mean()),
            tpot_max_s=float(m["tpot"][j].max()),
            slo_attainment=float(m["slo_attainment"][j]),
            total_time_s=float(m["total_time"][j]),
            n_decode_steps=sched.n_decode_steps,
            power_w=float(power[j]),
            energy_j=float(energy[j]),
            n_wafers=rds[i].n_wafers,
            prefill=rps[i], decode=rds[i])
    return results                          # type: ignore[return-value]


def evaluate_serving(design: WSCDesign, wl_base: LLMWorkload,
                     mix: RequestMix, slo: ServingSLO,
                     **kw) -> ServingResult:
    """Scalar wrapper: `evaluate_serving_batch` with a batch of one."""
    return evaluate_serving_batch([design], wl_base, mix, slo, **kw)[0]


def serving_objectives(wl_base: LLMWorkload, mix: RequestMix,
                       slo: ServingSLO, *, slots: int = 8,
                       fidelity: Fidelity = "analytical",
                       gnn_params: Optional[Dict] = None):
    """Batch-aware (SLO goodput, power-per-wafer) objective for the
    explorer; infeasible designs map to (0, peak wafer power). Subsumed by
    the campaign Objectives protocol — thin constructor for
    `repro.explore.objectives.ServingObjective` (lazy import: repro.explore
    layers on top of this module)."""
    from repro.explore.objectives import ServingObjective
    return ServingObjective(wl_base, mix, slo, slots=slots,
                            fidelity=fidelity, gnn_params=gnn_params)


# ---------------------------------------------------------------------------
# disaggregated (prefill/decode split) coupled request model
# ---------------------------------------------------------------------------


def disaggregated_metrics(mix: RequestMix, slo: ServingSLO, slots: int,
                          t_prefill: np.ndarray, kv_s: np.ndarray,
                          t_decode: float) -> Dict[str, float]:
    """Coupled request model for prefill/decode disaggregation: prompts
    prefill serially on the prefill stage (no decode stall), then the KV
    cache ships to the decode stage, and the request joins the decode pool
    when a slot frees. Admission stays in queue order (head-blocking, like
    the engine). `t_prefill`/`kv_s` are per-request seconds on the stages'
    actual resource shares; `t_decode` is the batched decode step time."""
    if slots < 1:
        raise ValueError("slots must be >= 1")
    R = mix.n_requests
    t_p = np.asarray(t_prefill, np.float64)
    kv = np.broadcast_to(np.asarray(kv_s, np.float64), (R,))
    ttft = np.cumsum(t_p)                  # first token from prefill stage
    ready = ttft + kv                      # decode-eligible time
    dtoks = np.maximum(np.asarray(mix.out_lens, np.int64) - 1, 1)
    completion = np.zeros(R)
    active: Dict[int, List[int]] = {}
    nxt = 0
    t = 0.0
    n_steps = 0
    while nxt < R or active:
        while (nxt < R and len(active) < slots
               and ready[nxt] <= t + 1e-12):
            slot = next(s for s in range(slots) if s not in active)
            active[slot] = [nxt, int(dtoks[nxt])]
            nxt += 1
        if not active:
            t = float(ready[nxt])
            continue
        t += t_decode
        n_steps += 1
        for slot in list(active):
            active[slot][1] -= 1
            if active[slot][1] == 0:
                completion[active[slot][0]] = t
                del active[slot]
    tpot = (completion - ttft) / dtoks
    total_time = float(max(completion.max(), ttft[-1]))
    out_toks = np.asarray(mix.out_lens, np.float64)
    met = (ttft <= slo.ttft_s) & (tpot <= slo.tpot_s)
    return {
        "ttft_s": float(ttft.mean()), "ttft_max_s": float(ttft.max()),
        "tpot_s": float(tpot.mean()), "tpot_max_s": float(tpot.max()),
        "total_time_s": total_time,
        "n_decode_steps": n_steps,
        "throughput_tok_s": float(out_toks.sum() / max(total_time, 1e-12)),
        "goodput_tok_s": float((out_toks * met).sum()
                               / max(total_time, 1e-12)),
        "slo_attainment": float(met.mean()),
    }


__all__ = [
    "BatchSchedule", "RequestMix", "ServingResult", "ServingSLO",
    "continuous_batch_schedule", "disaggregated_metrics",
    "evaluate_serving", "evaluate_serving_batch", "serving_metrics",
    "serving_objectives", "serving_workloads",
]
