"""Compiled analytical evaluation pipeline (DESIGN.md §12).

The analytical f1 backend used to run as vectorized NumPy: strategy-grid
enumeration (`compiler.feasible_strategy_arrays`), the tile model
(`tile_eval.evaluate_tile_batch`), the closed-form row-all-gather NoC costs
(`noc_analytical`), and the chunk-level step model
(`chunk_eval.evaluate_step_batch`), with a host round-trip between the
compiled MFMOBO proposal program and every evaluation. This module ports
that whole pipeline to jitted JAX with static shapes so analytical
`FidelityBackend.evaluate_batch` is ONE compiled program per
(workload, max_strategies) — and exposes a fused gather+evaluate entry
point that consumes the device-resident candidate indices
`mfmobo._acquire_scan_jit` produces, so a synchronous MFMOBO f1 iteration
never leaves XLA between proposal and evaluation.

Bit-exactness contract: every jnp expression mirrors its NumPy oracle
(`evaluate_tile_batch`, `evaluate_step_batch`,
`row_allgather_comm_cycles`, `row_allgather_byte_hops`,
`feasible_strategy_arrays` — retained verbatim and re-exported as `*_ref`)
operation for operation, in the same association order, in float64 under a
scoped `jax.experimental.enable_x64` (the rest of the process stays f32 —
the GP/EHVI programs are untouched). The analytical path uses only
exactly-rounded ops (+ - * / min max and integer arithmetic; the one log2
is the ±1-ulp-corrected exact `floor_log2`), so XLA CPU reproduces the
NumPy results bit for bit; `tests/test_eval_compiled.py` property-tests
equality, including bit-exact feasibility masks and strategy rows.

Static-shape conventions (the PR 6 capacity-bucket idiom):
  * the design axis is padded to a pow2 bucket (edge-replicated rows,
    sliced off on extraction), so a campaign touches a handful of
    programs, all pre-compilable via `warm_evaluator_kernels`;
  * the strategy axis is the per-workload sorted strategy grid, padded to
    pow2 with never-feasible rows; per-design selection of the first
    `max_strategies` feasible rows runs in-program as a cumsum +
    vmapped-searchsorted gather (identical rows, identical order, same
    Strategy(1,1,1,1) fallback as `feasible_strategy_arrays`).

When `host_devices` XLA host-platform lanes are exposed
(`--xla_force_host_platform_device_count`, see explore/fleet.py), the
padded design axis is sharded across the lanes with `pmap`; per-design
math is embarrassingly parallel, so sharding cannot change results.
`lane_stats()` reports per-lane row counts for the fleet probe.

Set REPRO_COMPILED_EVAL=0 to fall back to the NumPy reference pipeline.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import components as C
from repro.core.chunk_eval import StepResult
from repro.core.compiler import Strategy, _strategy_grid
from repro.core.design_space import DesignBatch
from repro.core.workload import BYTES, LLMWorkload

_ENV = "REPRO_COMPILED_EVAL"


def enabled() -> bool:
    return os.environ.get(_ENV, "1").lower() not in ("0", "false", "off")


def _pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


# per-lane dispatch accounting for the fleet probe (DESIGN.md §12)
_LANE_STATS = {"n_lanes": 0, "sharded_calls": 0, "rows_sharded": 0,
               "jit_calls": 0, "rows_jit": 0}


def lane_stats() -> Dict[str, int]:
    """XLA host-lane utilization counters: how many evaluator dispatches
    ran pmap-sharded vs single-lane, and the design rows each mode moved
    (sharded rows split evenly across `n_lanes` by construction)."""
    return dict(_LANE_STATS)


# ---------------------------------------------------------------------------
# exact integer helpers (jnp mirrors of design_space.floor_log2 /
# compiler.grid_for_batch / tile_eval._ceil_div — same correction steps,
# so the results are integer-exact, not merely close)
# ---------------------------------------------------------------------------


def _jnp():
    import jax.numpy as jnp
    return jnp


def _floor_log2_j(n):
    jnp = _jnp()
    n = jnp.maximum(n.astype(jnp.int64), 1)
    e = jnp.floor(jnp.log2(n.astype(jnp.float64))).astype(jnp.int64)
    e = jnp.where((jnp.int64(1) << jnp.minimum(e + 1, 62)) <= n, e + 1, e)
    e = jnp.where((jnp.int64(1) << jnp.minimum(e, 62)) > n, e - 1, e)
    return e


def _grid_for_j(n):
    jnp = _jnp()
    n = jnp.maximum(n.astype(jnp.int64), 1)
    gh = jnp.int64(1) << (_floor_log2_j(n) // 2)
    return gh, jnp.maximum(n // gh, 1)


def _ceil_div_j(a, b):
    return -(-a // b)


# ---------------------------------------------------------------------------
# compiled program per (workload, max_strategies, lanes)
# ---------------------------------------------------------------------------

# geometry fields the pipeline consumes, in DesignBatch attribute order
_GEOM_FIELDS = (
    "dataflow_code", "mac", "buffer_kb", "buffer_bw", "noc_bw",
    "total_cores", "cores_per_reticle", "n_reticles", "ret_h", "ret_w",
    "reticle_bisection_Bps", "inter_reticle_bw_Bps",
    "dram_bw_Bps_per_reticle", "dram_gb_per_reticle", "dram_on",
    "static_power_w", "ir_energy_pj_per_bit",
)

_PROGRAMS: Dict[Tuple, "_EvalProgram"] = {}
_PROGRAMS_MAX = 16


def _program_for(wl: LLMWorkload, max_strategies: int) -> "_EvalProgram":
    import jax
    lanes = jax.local_device_count()
    key = (wl, max_strategies, lanes)
    prog = _PROGRAMS.get(key)
    if prog is None:
        if len(_PROGRAMS) >= _PROGRAMS_MAX:
            _PROGRAMS.pop(next(iter(_PROGRAMS)))
        prog = _EvalProgram(wl, max_strategies, lanes)
        _PROGRAMS[key] = prog
    return prog


def clear_compiled_programs() -> None:
    _PROGRAMS.clear()
    _WARMED.clear()


class _EvalProgram:
    """One workload's compiled analytical pipeline: the sorted strategy
    grid (pow2-padded with never-feasible rows) baked in as constants,
    plus the jitted batch / fused-gather / pmap entry points."""

    def __init__(self, wl: LLMWorkload, max_strategies: int, lanes: int):
        import jax

        self.wl = wl
        self.K = int(max_strategies)
        self.lanes = int(lanes)

        g = _strategy_grid(wl)
        order = g["order"]
        tp_o = g["tp"][order]
        pp_o = g["pp"][order]
        dp_o = g["dp"][order]
        mb_o = g["mb"][order]
        need_o = g["need"][order]
        chunks_o = g["chunks"][order]
        G = len(order)
        Gp = _pow2(max(G, 1))
        pad = Gp - G
        big = np.int64(1) << 31          # pad rows: product stays < 2^63
        self._tp_o = np.concatenate([tp_o, np.full(pad, big)])
        self._pp_o = np.concatenate([pp_o, np.full(pad, big)])
        self._dp_o = np.concatenate([dp_o, np.full(pad, 1, np.int64)])
        self._mb_o = np.concatenate([mb_o, np.full(pad, 1, np.int64)])
        self._need_o = np.concatenate([need_o, np.full(pad, np.inf)])
        self._chunks_o = np.concatenate([chunks_o, np.full(pad, big)])
        fb = np.flatnonzero((tp_o == 1) & (pp_o == 1) & (dp_o == 1)
                            & (mb_o == 1))
        self._fb_idx = int(fb[0])        # Strategy(1,1,1,1) always exists

        # workload scalars (python numbers -> exact f64 constants)
        self._train = wl.phase == "train"
        self._bwd = 3.0 if self._train else 1.0
        self._tokens = wl.tokens_per_step()
        self._p_bytes = wl.params_bytes()
        self._p_exp = wl.expert_params_bytes()
        self._kvtot_num = wl.kv_bytes_per_layer() * wl.n_layers
        self._e_mac = wl.flops_per_step() / 2.0 * C.ENERGY.mac * 1e-12

        self._jit = jax.jit(self._body)
        self._pfn = (jax.pmap(self._body, in_axes=(0, 0, None))
                     if lanes > 1 else None)

        def fused(arrs, nw, zc, js):
            sub = {k: v[js] for k, v in arrs.items()}
            return self._body(sub, nw[js], zc)

        self._fused_jit = jax.jit(fused)

        # pinned-strategy (joint mode, ISSUE 9): same `_eval_core` trace,
        # no grid selection — the strategy arrays come in as inputs
        self._jit_pinned = jax.jit(self._body_pinned)
        self._pfn_pinned = (jax.pmap(self._body_pinned,
                                     in_axes=(0, 0, None, 0))
                            if lanes > 1 else None)

        def fused_pinned(arrs, nw, zc, strat, js):
            sub = {k: v[js] for k, v in arrs.items()}
            st = tuple(s[js] for s in strat)
            return self._body_pinned(sub, nw[js], zc, st)

        self._fused_pinned_jit = jax.jit(fused_pinned)

    def _zc(self):
        """Traced scalars for `_body`: the FMA-guard zero plus the inexact
        float constants whose multiplication order must stay fixed (XLA's
        algebraic simplifier folds adjacent constant factors into one —
        e.g. `/ CLOCK_HZ * bwd` into `* (bwd/CLOCK_HZ)` — which rounds
        once where the NumPy oracle rounds twice). Passing them as runtime
        values pins the op-for-op association. Device constants: built
        once, reused across dispatches."""
        zc = getattr(self, "_zc_cached", None)
        if zc is None:
            zc = self._zc_cached = (
                _dev64(np.float64(0.0)), _dev64(np.float64(self._bwd)),
                _dev64(np.float64(C.CLOCK_HZ)),
                _dev64(np.float64(self.wl.d_model)),
                _dev64(np.float64(1e-12)),
                _dev64(np.float64(self.wl.n_layers)))
        return zc

    # -- the pipeline body (traced under enable_x64) ------------------------

    def _body(self, arrs, nw, zc):
        jnp = _jnp()
        K = self.K
        z = zc[0]

        # `z` is a traced f64 zero. XLA CPU contracts `a*b + c` into an FMA
        # (skipping the product's rounding step), which NumPy never does;
        # neither --xla_cpu_enable_fast_math=false nor optimization_barrier
        # suppresses it (LLVM fuses below HLO). `fp(x) = x + z` pins a
        # product to its correctly rounded value: either the add contracts
        # to fma(a, b, 0) == round(a*b), or it runs as round(a*b) + 0 —
        # bit-identical either way (operands here are never -0.0). Apply it
        # to every float product whose result NumPy rounds before an
        # addition or subtraction.
        def fp(x):
            return x + z

        buffer_kb = arrs["buffer_kb"]
        total_cores = arrs["total_cores"].astype(jnp.int64)
        nw = nw.astype(jnp.int64)

        # --- strategy selection: first K feasible rows of the sorted grid
        # (mirrors feasible_strategy_arrays' mask + order + cap + fallback)
        tp_o = jnp.asarray(self._tp_o)
        pp_o = jnp.asarray(self._pp_o)
        dp_o = jnp.asarray(self._dp_o)
        mb_o = jnp.asarray(self._mb_o)
        need_o = jnp.asarray(self._need_o)
        chunks_o = jnp.asarray(self._chunks_o)
        Gp = tp_o.shape[0]

        tc = total_cores * nw                              # (N,) int64
        sram_total = buffer_kb * 1024.0 * total_cores * nw
        dram_total = (arrs["dram_gb_per_reticle"] * 1e9
                      * arrs["n_reticles"].astype(jnp.int64) * nw)
        budget = fp(sram_total) + fp(dram_total)           # (N,) f64

        mask = ((chunks_o[None, :] * tp_o[None, :] <= tc[:, None])
                & (tp_o[None, :] <= tc[:, None])
                & (need_o[None, :] <= budget[:, None]))    # (N, Gp)
        csum = jnp.cumsum(mask.astype(jnp.int32), axis=1)
        count = csum[:, -1]
        targets = jnp.arange(1, K + 1, dtype=jnp.int32)
        import jax
        pos = jax.vmap(
            lambda c: jnp.searchsorted(c, targets, side="left"))(csum)
        sel = jnp.minimum(pos, Gp - 1)                     # (N, K)
        ks = jnp.arange(K)
        selmask = ks[None, :] < count[:, None]
        nofeas = count == 0
        first = nofeas[:, None] & (ks[None, :] == 0)
        sel = jnp.where(first, self._fb_idx, sel)
        selmask = selmask | first

        cand = self._eval_core(arrs, nw, zc, tp_o[sel], pp_o[sel],
                               dp_o[sel], mb_o[sel], None)

        # --- per-design winner (first max wins, like np.argmax) ----------
        live = cand["feasible"] & selmask
        thpt_rank = jnp.where(live, cand["throughput"], -1.0)
        jw = jnp.argmax(thpt_rank, axis=1)

        def at(a):
            return jnp.take_along_axis(a, jw[:, None], axis=1)[:, 0]

        out = {"any_feasible": live.any(axis=1), "sel_g": at(sel)}
        for k in ("throughput", "power_w", "step_time_s", "pipeline_eff",
                  "energy_j", "compute_s", "tp_s", "pp_s", "dram_s",
                  "dp_s", "mb_count"):
            out[k] = at(cand[k])
        return out

    def _body_pinned(self, arrs, nw, zc, strat):
        """Joint-mode body: one pinned strategy per design, no grid argmin.
        `strat` = (tp, pp, dp, mb, ep, recompute) as (N,) arrays. Shares
        `_eval_core` with the grid body, so a pinned (tp, pp, dp, mb) with
        ep=1/recompute=False reproduces that grid row bit for bit."""
        jnp = _jnp()

        def col(a):
            return a.astype(jnp.int64)[:, None]

        tp, pp, dp, mb, ep, rc = strat
        cand = self._eval_core(arrs, nw.astype(jnp.int64), zc, col(tp),
                               col(pp), col(dp), col(mb),
                               (col(ep), rc.astype(bool)[:, None]))
        return {k: v[:, 0] for k, v in cand.items()}

    def _eval_core(self, arrs, nw, zc, tp, pp, dp, mb, extras):
        """Candidate axis + tile/NoC/chunk-step model for (N, K) strategy
        columns — the shared trace of the grid and pinned bodies. `extras`
        is None (grid mode: byte-identical trace to the pre-refactor body)
        or (ep, recompute) columns, every extra term `where`-guarded so
        ep=1/recompute=False lanes keep the legacy bits (the same guard
        discipline as `chunk_eval.evaluate_step_batch`)."""
        jnp = _jnp()
        wl = self.wl
        z, bwd_t, clock_t, dmod_t, p12, nl_t = zc

        def fp(x):
            return x + z

        code = arrs["dataflow_code"].astype(jnp.int64)
        mac = arrs["mac"].astype(jnp.int64)
        buffer_kb = arrs["buffer_kb"]
        buffer_bw = arrs["buffer_bw"].astype(jnp.int64)
        noc_bw = arrs["noc_bw"]
        total_cores = arrs["total_cores"].astype(jnp.int64)

        # --- candidate axis (build_candidate_axis mirror), shapes (N, K)
        chunks = pp * dp
        mb_count = mb if self._train else jnp.ones_like(mb)
        mb_tokens = jnp.maximum(self._tokens // (dp * mb_count), 1)
        tcn = (total_cores * nw)[:, None]
        cores_per_chunk = jnp.maximum(tcn // chunks, 1)
        gh_t, gw_t = _grid_for_j(cores_per_chunk)
        gh, gw = _grid_for_j(jnp.minimum(cores_per_chunk, 64))
        n_cores = gh * gw

        # layer_ops_batch mirror: the 6 GEMMs of one layer under tp
        D, F = wl.d_model, wl.d_ff
        hd = D // max(wl.n_heads, 1)
        e = wl.moe_topk if wl.moe_experts else 1
        heads_tp = jnp.maximum(wl.n_heads // tp, 1)
        M = mb_tokens
        m_attn = M * heads_tp // max(wl.n_heads, 1)
        kv_len = wl.seq
        zi = jnp.zeros_like(M)           # int broadcast helper (NOT `z`)
        ops = (
            (M, zi + D, (wl.n_heads + 2 * wl.n_kv) * hd // tp),
            (m_attn, zi + hd, zi + kv_len),
            (m_attn, zi + kv_len, zi + hd),
            (M, wl.n_heads * hd // tp, zi + D),
            (M * e, zi + D, 2 * F // tp),
            (M * e, F // tp, zi + D),
        )

        # tile stage per op (evaluate_tile_batch mirror), accumulated in
        # the same sequential order as the NumPy axis-0 sums
        bkb = buffer_kb[:, None]
        bbw = buffer_bw[:, None]
        nbw = noc_bw[:, None]
        mac2 = mac[:, None]
        code2 = code[:, None]
        ws = code2 == 0
        os_ = code2 == 2
        pr = jnp.int64(1) << (_floor_log2_j(mac2) // 2)
        pc = jnp.maximum(mac2, 1) // pr
        bkb_f = bkb.astype(jnp.float64)
        buf_bits = bkb_f * 1024 * 8

        def sel3(a, b, c):
            return jnp.where(ws, a, jnp.where(os_, b, c))

        cycles_sum = None
        sram_sum = None
        comm_sum = None
        hops_sum = None

        # NoC closed form shared terms (row_allgather_* mirrors)
        bw_bytes = nbw.astype(jnp.float64) / 8.0
        n_transfers = len(ops) - 1
        maxflow = (jnp.float64(n_transfers) * (gw // 2) * ((gw + 1) // 2))
        eq_bw = bw_bytes / jnp.maximum(maxflow, 1.0)
        hop_fac = gh * (gw * (gw * gw - 1)) / 3.0

        for oi, (Mo, Ko, No) in enumerate(ops):
            tM = jnp.maximum(jnp.maximum(Mo // gh_t, 1), 1)
            tK = jnp.maximum(Ko, 1)
            tN = jnp.maximum(jnp.maximum(No // gw_t, 1), 1)
            u1 = sel3(tK, tM, tM)
            u2 = sel3(tN, tN, tK)
            stream = sel3(tM, tK, tN)
            t1 = _ceil_div_j(u1, pr)
            t2 = _ceil_div_j(u2, pc)
            compute = (t1 * t2).astype(jnp.float64) * stream
            Mf = tM.astype(jnp.float64)
            Kf = tK.astype(jnp.float64)
            Nf = tN.astype(jnp.float64)
            reads = sel3(fp(Kf * Nf) + fp(Mf * Kf * t2),
                         fp(Mf * Kf * t2) + fp(Kf * Nf * t1),
                         fp(Mf * Kf) + fp(Kf * Nf * t1))
            writes = sel3(Mf * Nf * t1, Mf * Nf, Mf * Nf * t2)
            stat1 = sel3(jnp.minimum(tK, pr), jnp.minimum(tM, pr),
                         jnp.minimum(tM, pr))
            stat2 = sel3(jnp.minimum(tN, pc), jnp.minimum(tN, pc),
                         jnp.minimum(tK, pc))
            stat_bits = (stat1 * stat2).astype(jnp.float64) * BYTES * 8
            cap_factor = jnp.maximum(1.0, stat_bits
                                     / jnp.maximum(buf_bits, 1))
            read_bits = reads * BYTES * 8 * cap_factor
            write_bits = writes * BYTES * 8
            rw = fp(read_bits) + fp(write_bits)
            mem_cycles = rw / jnp.maximum(bbw, 1)
            cyc = jnp.maximum(compute, mem_cycles)
            cycles_sum = cyc if cycles_sum is None else cycles_sum + cyc
            sram_sum = rw if sram_sum is None else sram_sum + rw
            if oi < n_transfers:         # producer feeds a transfer
                out_b = (Mo * No).astype(jnp.float64) * BYTES
                per_pair = out_b / n_cores
                comm = per_pair / jnp.maximum(eq_bw, 1e-9) + (gw - 1)
                comm = jnp.where(gw > 1, comm, 0.0)
                comm_sum = comm if comm_sum is None else comm_sum + comm
                pph = jnp.where(gw > 1, out_b / (gh * gw), 0.0)
                hop = fp(pph * hop_fac)
                hops_sum = hop if hops_sum is None else hops_sum + hop

        lat = cycles_sum + comm_sum
        sram_bits_layer = sram_sum * n_cores
        noc_bytes_layer = hops_sum

        # --- chunk-level step model (evaluate_step_batch mirror) ---------
        nw2 = nw[:, None]
        bwd = bwd_t
        ep2 = rc2 = None
        if extras is not None:
            ep2 = jnp.maximum(extras[0], 1)
            rc2 = extras[1]
            if self._train:
                # recompute re-runs the forward in the backward: 3x -> 4x
                bwd = jnp.where(rc2, jnp.float64(4.0), bwd_t)
        layers_per_stage = jnp.maximum(wl.n_layers // pp, 1)
        act_bytes = (mb_tokens * wl.d_model).astype(jnp.float64) * BYTES
        p_bytes = self._p_bytes

        compute_s = lat * layers_per_stage / clock_t * bwd
        cpc_step = total_cores[:, None] * nw2 // jnp.maximum(chunks, 1)
        tp_vol = 2.0 * (tp - 1) / tp * act_bytes * 2.0
        tp_bw = jnp.where(cpc_step <= arrs["cores_per_reticle"][:, None],
                          arrs["reticle_bisection_Bps"][:, None],
                          arrs["inter_reticle_bw_Bps"][:, None])
        tp_s = jnp.where(tp <= 1, 0.0, tp_vol / jnp.maximum(tp_bw, 1.0)) \
            * layers_per_stage * bwd
        ir_bw = arrs["inter_reticle_bw_Bps"][:, None]
        pp_s = jnp.where(pp <= 1, 0.0,
                         act_bytes / jnp.maximum(ir_bw, 1.0)) * bwd

        sram_per_chunk = (buffer_kb[:, None] * 1024.0
                          * total_cores[:, None] * nw2
                          / jnp.maximum(chunks, 1))
        w_bytes = p_bytes / jnp.maximum(pp, 1)
        if ep2 is not None:
            p_exp = self._p_exp
            w_bytes = jnp.where(ep2 > 1,
                                ((p_bytes - p_exp) + p_exp / ep2)
                                / jnp.maximum(pp, 1), w_bytes)
        kv_total = self._kvtot_num / jnp.maximum(pp, 1)
        if wl.phase == "decode":
            kv_read, kv_write = kv_total, kv_total / max(wl.seq, 1)
        elif wl.phase == "prefill":
            kv_read, kv_write = 0.0, kv_total
        else:
            kv_read = kv_write = 0.0
        spill = jnp.maximum(w_bytes + kv_read - sram_per_chunk, 0.0)
        reticles_per_chunk = jnp.maximum(
            arrs["n_reticles"].astype(jnp.int64)[:, None] * nw2
            / jnp.maximum(chunks, 1), 1e-9)
        stacked_bw = (arrs["dram_bw_Bps_per_reticle"][:, None]
                      * reticles_per_chunk)
        ret_h = arrs["ret_h"].astype(jnp.int64)[:, None]
        ret_w = arrs["ret_w"].astype(jnp.int64)[:, None]
        n_edge = 2 * (ret_h + ret_w)
        offchip_bw = (n_edge * C.OFFCHIP_BW_PER_CTRL
                      / jnp.maximum(chunks, 1))
        transit = ir_bw * jnp.minimum(ret_h, ret_w) \
            / jnp.maximum(chunks, 1)
        dram_on = arrs["dram_on"][:, None].astype(bool)
        dram_bw = jnp.where(dram_on, stacked_bw,
                            jnp.minimum(offchip_bw, transit))
        kv_in_dram = (w_bytes + kv_total) > sram_per_chunk
        dram_traffic = spill + jnp.where(kv_in_dram, kv_write, 0.0)
        dram_s = jnp.where(dram_traffic <= 0, 0.0,
                           dram_traffic / jnp.maximum(dram_bw, 1.0))

        _s1 = fp(compute_s) + fp(tp_s)
        _s2 = _s1 + fp(pp_s)
        stage_s = _s2 + fp(dram_s)
        a2a_vol = None
        if ep2 is not None:
            # MoE dispatch+combine all-to-all (chunk_eval mirror); the
            # where-guard zeroes ep=1 lanes so stage_s + fp(0.0) keeps
            # the legacy bits
            topk = max(wl.moe_topk, 1)
            a2a_vol = jnp.where(ep2 > 1,
                                4.0 * (ep2 - 1) / ep2 * act_bytes * topk,
                                0.0)
            ep_s = (a2a_vol / jnp.maximum(ir_bw, 1.0) * layers_per_stage
                    * bwd)
            stage_s = stage_s + fp(ep_s)
        # fp() also blocks the `x / (a/b) -> x * (b/a)` divide rewrite on
        # iter_s below, which re-rounds against the NumPy association.
        eff = fp(mb_count / (mb_count + pp - 1.0))
        iter_s = stage_s * mb_count / eff
        grad_vol = 2.0 * (dp - 1) / dp * w_bytes
        wafers_per_replica = jnp.maximum(nw2 / dp, 1e-9)
        dp_bw = jnp.where(wafers_per_replica >= 1.0,
                          n_edge * C.INTER_WAFER_BW_PER_NI,
                          ir_bw * jnp.minimum(ret_h, ret_w))
        dp_s = jnp.where((dp <= 1) | (not self._train), 0.0,
                         grad_vol / jnp.maximum(dp_bw, 1.0))
        step_s = iter_s + dp_s
        throughput = self._tokens / jnp.maximum(step_s, 1e-12)

        E = C.ENERGY
        # `p12` (traced 1e-12) keeps the simplifier from folding the pJ
        # constants with the unit scale into one single-rounded factor.
        # pin every intermediate product: these bare mul chains get
        # reassociated under jit (each fp is fma(a, b, 0) == round(a*b),
        # i.e. exactly the NumPy left-to-right per-op rounding)
        e_sram = fp(fp(fp(fp(fp(fp(sram_bits_layer * nl_t) * mb_count)
                            * dp) * bwd) * E.sram_read_bit) * p12)
        e_noc = fp(fp(fp(fp(fp(fp(fp(noc_bytes_layer * 8) * nl_t)
                             * mb_count) * dp) * bwd) * E.noc_bit_hop)
                   * p12)
        ir_bytes = (2.0 * (tp - 1) / jnp.maximum(tp, 1) * mb_tokens
                    * dmod_t * BYTES * 2 * wl.n_layers * mb_count * dp
                    * bwd)
        ir_bytes = fp(ir_bytes) + fp(p_bytes * 2 * (dp > 1))
        if a2a_vol is not None:
            ir_bytes = ir_bytes + fp(fp(fp(a2a_vol * nl_t) * mb_count)
                                     * dp)
        e_ir = (ir_bytes * 8 * arrs["ir_energy_pj_per_bit"][:, None]
                * p12)
        dram_bytes = dram_traffic * mb_count * dp
        e_dram = dram_bytes * 8 * jnp.where(dram_on, E.dram_bit,
                                            E.offchip_bit) * p12
        static_w = arrs["static_power_w"][:, None] * nw2
        energy = (self._e_mac + fp(e_sram) + fp(e_noc) + fp(e_ir)
                  + fp(e_dram) + fp(static_w * step_s))

        bad = ~(jnp.isfinite(step_s) & jnp.isfinite(energy))
        power = jnp.where(bad, jnp.inf,
                          energy / jnp.maximum(step_s, 1e-12))
        limit = C.WAFER_POWER_W * nw2
        feasible = ~bad & (power <= limit) & jnp.isfinite(power)

        step_time_s = jnp.where(bad, jnp.inf, step_s)
        thpt_out = jnp.where(bad, 0.0, throughput)
        energy_out = jnp.where(bad, 0.0, energy)

        cand = {
            "feasible": feasible,
            "throughput": thpt_out,
            "power_w": power,
            "step_time_s": step_time_s,
            "pipeline_eff": eff,
            "energy_j": energy_out,
            "compute_s": compute_s,
            "tp_s": tp_s,
            "pp_s": pp_s,
            "dram_s": dram_s,
            "dp_s": dp_s,
            "mb_count": mb_count,
        }
        if extras is not None:
            cand["ep_s"] = ep_s
        return cand

    # -- host-side entry points --------------------------------------------

    def _pad_rows(self, arrs: Dict[str, np.ndarray], nw: np.ndarray,
                  npad: int):
        n = len(nw)
        if npad == n:
            return arrs, nw
        width = [(0, npad - n)]
        return ({k: np.pad(v, width, mode="edge") for k, v in arrs.items()},
                np.pad(nw, width, mode="edge"))

    def _bucket(self, n: int) -> int:
        npad = _pow2(max(n, 4))
        if self.lanes > 1:
            npad = -(-npad // self.lanes) * self.lanes
        return npad

    def run_batch(self, arrs: Dict[str, np.ndarray], nw: np.ndarray
                  ) -> Dict[str, np.ndarray]:
        """Evaluate N designs; returns winner arrays sliced back to N."""
        import jax
        from jax.experimental import enable_x64

        n = len(nw)
        npad = self._bucket(n)
        arrs, nwp = self._pad_rows(arrs, nw, npad)
        with enable_x64():
            ja = {k: _dev64(v) for k, v in arrs.items()}
            jn = _dev64(nwp)
            jz = self._zc()
            if self.lanes > 1 and npad % self.lanes == 0:
                shp = (self.lanes, npad // self.lanes)
                out = self._pfn(
                    {k: v.reshape(shp + v.shape[1:]) for k, v in ja.items()},
                    jn.reshape(shp), jz)
                out = {k: np.asarray(v).reshape(npad) for k, v in out.items()}
                _LANE_STATS["n_lanes"] = self.lanes
                _LANE_STATS["sharded_calls"] += 1
                _LANE_STATS["rows_sharded"] += npad
            else:
                out = self._jit(ja, jn, jz)
                out = {k: np.asarray(v) for k, v in out.items()}
                _LANE_STATS.setdefault("n_lanes", 1)
                _LANE_STATS["n_lanes"] = max(_LANE_STATS["n_lanes"], 1)
                _LANE_STATS["jit_calls"] += 1
                _LANE_STATS["rows_jit"] += npad
        return {k: v[:n] for k, v in out.items()}

    def dispatch_fused(self, arrs: Dict[str, np.ndarray], nw: np.ndarray,
                       js_dev) -> "_PendingEval":
        """Gather + evaluate the candidate-pool rows the device-resident
        `js_dev` indices name, without waiting for the indices to reach the
        host (the acquire scan's output feeds the evaluator inside XLA).
        Returns a pending handle; extraction is one host transfer."""
        from jax.experimental import enable_x64

        n = len(nw)
        npad = _pow2(max(n, 4))
        arrs, nwp = self._pad_rows(arrs, nw, npad)
        with enable_x64():
            ja = {k: _dev64(v) for k, v in arrs.items()}
            jn = _dev64(nwp)
            out = self._fused_jit(ja, jn, self._zc(), js_dev)
        _LANE_STATS["jit_calls"] += 1
        _LANE_STATS["rows_jit"] += int(js_dev.shape[0])
        return _PendingEval(self, out)

    def results_from(self, out: Dict[str, np.ndarray], nw: np.ndarray
                     ) -> List["EvalResult"]:
        """Materialize EvalResult/StepResult rows from extracted winner
        arrays — the same construction `_finish` + `step_result_at` do."""
        from repro.core.fidelity import EvalResult
        res: List[EvalResult] = []
        for i in range(len(nw)):
            if not bool(out["any_feasible"][i]):
                res.append(EvalResult(0.0, float("inf"), None, None,
                                      int(nw[i]), False,
                                      "no_feasible_strategy"))
                continue
            g = int(out["sel_g"][i])
            eff = float(out["pipeline_eff"][i])
            mbc = float(out["mb_count"][i])
            sr = StepResult(
                step_time_s=float(out["step_time_s"][i]),
                throughput=float(out["throughput"][i]),
                power_w=float(out["power_w"][i]),
                pipeline_eff=eff,
                breakdown={
                    "compute": float(out["compute_s"][i]) * mbc / eff,
                    "tp": float(out["tp_s"][i]) * mbc / eff,
                    "pp": float(out["pp_s"][i]) * mbc / eff,
                    "dram": float(out["dram_s"][i]) * mbc / eff,
                    "dp": float(out["dp_s"][i])},
                energy_j=float(out["energy_j"][i]),
                feasible=True, reason="")
            res.append(EvalResult(
                sr.throughput, sr.power_w,
                Strategy(int(self._tp_o[g]), int(self._pp_o[g]),
                         int(self._dp_o[g]), int(self._mb_o[g])),
                sr, int(nw[i]), True))
        return res

    # -- pinned-strategy (joint mode) entry points -------------------------

    def _pad_strat(self, strat, npad: int):
        n = len(strat[0])
        if npad == n:
            return strat
        return tuple(np.pad(s, [(0, npad - n)], mode="edge") for s in strat)

    def run_batch_pinned(self, arrs: Dict[str, np.ndarray], nw: np.ndarray,
                         strat) -> Dict[str, np.ndarray]:
        """Evaluate N (design, strategy) pairs; `strat` is the
        (tp, pp, dp, mb, ep, recompute) array tuple."""
        from jax.experimental import enable_x64

        n = len(nw)
        npad = self._bucket(n)
        arrs, nwp = self._pad_rows(arrs, nw, npad)
        strat = self._pad_strat(strat, npad)
        with enable_x64():
            ja = {k: _dev64(v) for k, v in arrs.items()}
            jn = _dev64(nwp)
            js = tuple(_dev64(s) for s in strat)
            jz = self._zc()
            if self.lanes > 1 and npad % self.lanes == 0:
                shp = (self.lanes, npad // self.lanes)
                out = self._pfn_pinned(
                    {k: v.reshape(shp + v.shape[1:]) for k, v in ja.items()},
                    jn.reshape(shp), jz,
                    tuple(s.reshape(shp) for s in js))
                out = {k: np.asarray(v).reshape(npad) for k, v in out.items()}
                _LANE_STATS["n_lanes"] = self.lanes
                _LANE_STATS["sharded_calls"] += 1
                _LANE_STATS["rows_sharded"] += npad
            else:
                out = self._jit_pinned(ja, jn, jz, js)
                out = {k: np.asarray(v) for k, v in out.items()}
                _LANE_STATS["jit_calls"] += 1
                _LANE_STATS["rows_jit"] += npad
        return {k: v[:n] for k, v in out.items()}

    def dispatch_fused_pinned(self, arrs: Dict[str, np.ndarray],
                              nw: np.ndarray, strat, js_dev
                              ) -> "_PendingPinnedEval":
        """Fused gather + pinned evaluation of the joint-pool rows named by
        the device-resident `js_dev` indices (joint-mode counterpart of
        `dispatch_fused`)."""
        from jax.experimental import enable_x64

        n = len(nw)
        npad = _pow2(max(n, 4))
        arrs, nwp = self._pad_rows(arrs, nw, npad)
        strat = self._pad_strat(strat, npad)
        with enable_x64():
            ja = {k: _dev64(v) for k, v in arrs.items()}
            jn = _dev64(nwp)
            js = tuple(_dev64(s) for s in strat)
            out = self._fused_pinned_jit(ja, jn, self._zc(), js, js_dev)
        _LANE_STATS["jit_calls"] += 1
        _LANE_STATS["rows_jit"] += int(js_dev.shape[0])
        return _PendingPinnedEval(self, out)

    def results_from_pinned(self, out: Dict[str, np.ndarray],
                            nw: np.ndarray, strategies,
                            res_ok: Optional[np.ndarray] = None
                            ) -> List["EvalResult"]:
        """Materialize pinned-mode EvalResults — the same construction the
        NumPy `_finish` does in pinned mode (strategy_resources when the
        host-computed grid resource-fit mask `res_ok` rejects the point,
        strategy_infeasible on a power/finiteness failure, breakdown gains
        "ep" only when the all-to-all term is nonzero, matching
        `step_result_at`)."""
        from repro.core.fidelity import EvalResult
        res: List[EvalResult] = []
        for i, s in enumerate(strategies):
            fit = res_ok is None or bool(res_ok[i])
            if not (fit and bool(out["feasible"][i])):
                res.append(EvalResult(0.0, float("inf"), s, None,
                                      int(nw[i]), False,
                                      "strategy_resources" if not fit
                                      else "strategy_infeasible"))
                continue
            eff = float(out["pipeline_eff"][i])
            mbc = float(out["mb_count"][i])
            bd = {"compute": float(out["compute_s"][i]) * mbc / eff,
                  "tp": float(out["tp_s"][i]) * mbc / eff,
                  "pp": float(out["pp_s"][i]) * mbc / eff,
                  "dram": float(out["dram_s"][i]) * mbc / eff,
                  "dp": float(out["dp_s"][i])}
            ep_v = float(out["ep_s"][i])
            if ep_v:
                bd["ep"] = ep_v * mbc / eff
            sr = StepResult(
                step_time_s=float(out["step_time_s"][i]),
                throughput=float(out["throughput"][i]),
                power_w=float(out["power_w"][i]),
                pipeline_eff=eff, breakdown=bd,
                energy_j=float(out["energy_j"][i]),
                feasible=True, reason="")
            res.append(EvalResult(sr.throughput, sr.power_w, s, sr,
                                  int(nw[i]), True))
        return res


def _dev64(v: np.ndarray):
    jnp = _jnp()
    a = np.asarray(v)
    if a.dtype == np.bool_:
        return jnp.asarray(a)
    if np.issubdtype(a.dtype, np.integer):
        return jnp.asarray(a, jnp.int64)
    return jnp.asarray(a, jnp.float64)


@dataclasses.dataclass
class _PendingEval:
    """In-flight fused evaluation: the program is dispatched; `finish`
    blocks on the single batched host extraction and builds EvalResults
    for the first q picks (position-aligned with the pick indices)."""
    prog: _EvalProgram
    out: Dict

    def finish(self, nw_picks: np.ndarray, q: int) -> List["EvalResult"]:
        host = {k: np.asarray(v)[:q] for k, v in self.out.items()}
        return self.prog.results_from(host, nw_picks[:q])


@dataclasses.dataclass
class _PendingPinnedEval:
    """In-flight fused pinned-strategy evaluation (joint mode)."""
    prog: _EvalProgram
    out: Dict

    def finish(self, nw_picks: np.ndarray, strategies, q: int,
               res_ok: Optional[np.ndarray] = None) -> List["EvalResult"]:
        host = {k: np.asarray(v)[:q] for k, v in self.out.items()}
        return self.prog.results_from_pinned(
            host, nw_picks[:q], strategies[:q],
            res_ok if res_ok is None else res_ok[:q])


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def geom_arrays(geom: DesignBatch) -> Dict[str, np.ndarray]:
    return {k: getattr(geom, k) for k in _GEOM_FIELDS}


def evaluate_batch_compiled(geom: DesignBatch, wl: LLMWorkload,
                            n_wafers: np.ndarray, max_strategies: int = 24
                            ) -> List["EvalResult"]:
    """Compiled analytical `evaluate_batch`: one jitted program over the
    pow2-padded design axis, bit-identical to the NumPy reference
    (`AnalyticalBackend.evaluate_batch_ref`)."""
    prog = _program_for(wl, max_strategies)
    nw = np.asarray(n_wafers, np.int64)
    out = prog.run_batch(geom_arrays(geom), nw)
    return prog.results_from(out, nw)


def strategy_arrays(strategies) -> Tuple[np.ndarray, ...]:
    """Columnize a list of Strategy into the (tp, pp, dp, mb, ep, recompute)
    array tuple the pinned program consumes."""
    return (np.array([s.tp for s in strategies], np.int64),
            np.array([s.pp for s in strategies], np.int64),
            np.array([s.dp for s in strategies], np.int64),
            np.array([s.microbatches for s in strategies], np.int64),
            np.array([s.ep for s in strategies], np.int64),
            np.array([s.recompute for s in strategies], np.bool_))


def evaluate_pinned_compiled(geom: DesignBatch, wl: LLMWorkload,
                             n_wafers: np.ndarray, strategies,
                             max_strategies: int = 24) -> List["EvalResult"]:
    """Compiled joint-mode `evaluate_batch`: each design is evaluated under
    its pinned Strategy (no grid argmin), bit-identical to the NumPy pinned
    reference path in `AnalyticalBackend.evaluate_batch_ref` — including
    the host-side grid resource-fit gate (`compiler.pinned_resource_ok`),
    computed by the same NumPy code both paths share."""
    from repro.core.compiler import pinned_resource_ok

    prog = _program_for(wl, max_strategies)
    nw = np.asarray(n_wafers, np.int64)
    cols = strategy_arrays(strategies)
    out = prog.run_batch_pinned(geom_arrays(geom), nw, cols)
    res_ok = pinned_resource_ok(wl, geom, nw, cols[0], cols[1], cols[2],
                                cols[3])
    return prog.results_from_pinned(out, nw, strategies, res_ok)


def dispatch_fused_eval_pinned(pool_geom: DesignBatch, wl: LLMWorkload,
                               nw_pool: np.ndarray, strategies, js_dev,
                               max_strategies: int = 24
                               ) -> _PendingPinnedEval:
    """Joint-mode fused propose→evaluate: gather the pool rows named by
    the device-resident `js_dev` indices together with their pinned
    strategy columns, evaluate without a host round-trip."""
    prog = _program_for(wl, max_strategies)
    return prog.dispatch_fused_pinned(geom_arrays(pool_geom),
                                      np.asarray(nw_pool, np.int64),
                                      strategy_arrays(strategies), js_dev)


def dispatch_fused_eval(pool_geom: DesignBatch, wl: LLMWorkload,
                        nw_pool: np.ndarray, js_dev,
                        max_strategies: int = 24) -> _PendingEval:
    """Fused propose→evaluate: evaluate the pool rows selected by the
    device-resident indices `js_dev` (the `_acquire_scan_jit` output)
    without a host round-trip between acquisition and evaluation."""
    prog = _program_for(wl, max_strategies)
    return prog.dispatch_fused(geom_arrays(pool_geom),
                               np.asarray(nw_pool, np.int64), js_dev)


# ---------------------------------------------------------------------------
# warm-up (satellite: evaluator programs join warm_optimizer_kernels)
# ---------------------------------------------------------------------------

_WARMED: set = set()


def warm_evaluator_kernels(wl: LLMWorkload, n_designs_max: int = 4,
                           max_strategies: int = 24,
                           pool_sizes: Tuple[int, ...] = (),
                           force: bool = False) -> int:
    """Pre-compile the analytical evaluator programs for every pow2 design
    bucket up to `n_designs_max`, plus the fused gather program for each
    candidate-pool size in `pool_sizes` (per (bucket, workload-shape)
    memoization; `force=True` re-warms). Returns buckets newly warmed."""
    if not enabled():
        return 0
    from jax.experimental import enable_x64

    from repro.core.design_space import decode_batch

    prog = _program_for(wl, max_strategies)
    d0 = decode_batch(np.full((1, 13), 0.5))[0]
    geom1 = DesignBatch.from_designs([d0])
    arrs1 = geom_arrays(geom1)
    warmed = 0
    n = 4
    buckets = []
    while n <= _pow2(max(int(n_designs_max), 4)):
        buckets.append(("batch", n))
        n *= 2
    for p in pool_sizes:
        for qp in (4,):                  # bucket_size(q<=4, minimum=4)
            buckets.append(("fused", _pow2(max(int(p), 4)), qp))
    for b in buckets:
        key = (wl, max_strategies, prog.lanes, b)
        if key in _WARMED and not force:
            continue
        _WARMED.add(key)
        warmed += 1
        if b[0] == "batch":
            npad = b[1]
            arrs = {k: np.repeat(v, npad, axis=0) for k, v in arrs1.items()}
            nw = np.ones(npad, np.int64)
            prog.run_batch(arrs, nw)
        else:
            npad, qp = b[1], b[2]
            arrs = {k: np.repeat(v, npad, axis=0) for k, v in arrs1.items()}
            nw = np.ones(npad, np.int64)
            with enable_x64():
                js = _jnp().arange(qp, dtype=_jnp().int64) % npad
            prog.dispatch_fused(arrs, nw, js).finish(nw, min(qp, npad))
    return warmed


__all__ = [
    "clear_compiled_programs", "dispatch_fused_eval",
    "dispatch_fused_eval_pinned", "enabled", "evaluate_batch_compiled",
    "evaluate_pinned_compiled", "geom_arrays", "lane_stats",
    "strategy_arrays", "warm_evaluator_kernels",
]
