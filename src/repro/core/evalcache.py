"""Pluggable eval-cache backends (DESIGN.md §6/§11).

The cross-call eval cache used to be a module-level dict in
`repro.core.evaluator`; fleet-scale campaign execution (repro.explore.fleet)
needs (a) bounded memory over long campaigns and (b) evaluation sharing
across *processes* and *successive campaigns* — fig8's methods revisit the
same candidates, so cross-campaign sharing is free hypervolume. Both live
behind the `EvalCacheBackend` protocol:

    InMemoryEvalCache     LRU dict with a configurable entry cap and an
                          eviction counter (the default backend — same
                          semantics the evaluator always had, plus LRU
                          instead of FIFO eviction).
    DiskSegmentEvalCache  the in-memory LRU fronting a shared directory of
                          append-only segment files, one per writer
                          process, merged on read. Writes never contend
                          (single writer per segment); readers pick up
                          other processes' entries by replaying segment
                          bytes they have not consumed yet, tolerating a
                          truncated in-flight tail record.

Keys are the evaluator's existing tuple
(design, workload, fidelity, n_wafers, max_strategies, params-digest) —
frozen dataclasses with content equality, so a pickled key round-trips
across processes and still compares equal. The params element must be the
content *digest* (`evaluator.gnn_params_digest`), never the process-local
pin token: tokens are monotonic per process and would alias across workers.

Every backend is thread-safe: async proposal mode (DESIGN.md §11)
evaluates batches on worker threads that hit the cache concurrently with
the proposer.
"""
from __future__ import annotations

import contextlib
import os
import pickle
import threading
import uuid
from collections import OrderedDict
from typing import Dict, Iterator, Optional, Tuple

Key = Tuple
SEGMENT_PREFIX = "seg-"
SEGMENT_SUFFIX = ".evalcache.pkl"

# per-thread cache-traffic accumulator (see `attribute_cache_traffic`):
# lets the exploration loop attribute hits/misses/entries to a fidelity
# stage even when async proposal mode evaluates batches on concurrent
# threads — global before/after counter snapshots would race.
_TLS = threading.local()


@contextlib.contextmanager
def attribute_cache_traffic():
    """Context manager yielding a {hits, misses, entries_added} dict that
    accumulates every cache access made by THIS thread inside the block
    (nested blocks stack: traffic lands in the innermost)."""
    acc = {"hits": 0, "misses": 0, "entries_added": 0}
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    stack.append(acc)
    try:
        yield acc
    finally:
        stack.pop()


def _bump(field: str, n: int = 1) -> None:
    stack = getattr(_TLS, "stack", None)
    if stack:
        stack[-1][field] += n


class EvalCacheBackend:
    """Protocol + shared bookkeeping for eval-cache backends. Subclasses
    implement `_get`/`_put`/`_clear`/`_extra_stats`; this base keeps the
    hit/miss counters and the lock."""

    def __init__(self) -> None:
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.set_many_calls = 0
        self.set_many_entries = 0

    # -- protocol ----------------------------------------------------------

    def get(self, key: Key):
        with self._lock:
            v = self._get(key)
            if v is None:
                self.misses += 1
                _bump("misses")
            else:
                self.hits += 1
                _bump("hits")
            return v

    def put(self, key: Key, value):
        with self._lock:
            self._put(key, value)
            _bump("entries_added")
        return value

    def set_many(self, items) -> int:
        """Batch insert of (key, value) pairs: one lock acquisition — and
        for disk-backed caches one segment append + flush — per call, so
        the fused evaluation path writes a whole iteration's results in a
        single operation. Returns the number of entries written."""
        items = list(items)
        with self._lock:
            self._put_many(items)
            self.set_many_calls += 1
            self.set_many_entries += len(items)
            _bump("entries_added", len(items))
        return len(items)

    def clear(self) -> None:
        with self._lock:
            self._clear()
            self.hits = self.misses = 0
            self.set_many_calls = self.set_many_entries = 0

    def stats(self) -> Dict[str, int]:
        with self._lock:
            s = {"hits": self.hits, "misses": self.misses,
                 "entries": self._entries(),
                 "set_many_calls": self.set_many_calls,
                 "set_many_entries": self.set_many_entries}
            s.update(self._extra_stats())
            return s

    # -- subclass surface --------------------------------------------------

    def _get(self, key: Key):
        raise NotImplementedError

    def _put(self, key: Key, value) -> None:
        raise NotImplementedError

    def _put_many(self, items) -> None:
        for key, value in items:
            self._put(key, value)

    def _clear(self) -> None:
        raise NotImplementedError

    def _entries(self) -> int:
        raise NotImplementedError

    def _extra_stats(self) -> Dict[str, int]:
        return {}


class InMemoryEvalCache(EvalCacheBackend):
    """Bounded LRU over an OrderedDict: a hit refreshes recency, inserts
    over `max_entries` evict the least-recently-used entry (counted in
    `evictions`) — long campaigns no longer grow the cache without bound."""

    def __init__(self, max_entries: int = 100_000) -> None:
        super().__init__()
        if max_entries < 1:
            raise ValueError("max_entries must be >= 1")
        self.max_entries = int(max_entries)
        self.evictions = 0
        self._d: "OrderedDict[Key, object]" = OrderedDict()

    def _get(self, key: Key):
        v = self._d.get(key)
        if v is not None:
            self._d.move_to_end(key)
        return v

    def _put(self, key: Key, value) -> None:
        if key in self._d:
            self._d.move_to_end(key)
        self._d[key] = value
        while len(self._d) > self.max_entries:
            self._d.popitem(last=False)
            self.evictions += 1

    def _clear(self) -> None:
        self._d.clear()
        self.evictions = 0

    def _entries(self) -> int:
        return len(self._d)

    def _extra_stats(self) -> Dict[str, int]:
        return {"evictions": self.evictions, "max_entries": self.max_entries}


def _iter_records(path: str, offset: int) -> Iterator[Tuple[Key, object,
                                                            int]]:
    """Replay (key, value) records appended to a segment file from
    `offset`, yielding the end offset of each good record. A truncated tail
    (a writer mid-append, or a crash mid-record) terminates the replay at
    the last complete record instead of raising."""
    try:
        with open(path, "rb") as f:
            f.seek(offset)
            while True:
                try:
                    key, value = pickle.load(f)
                except EOFError:
                    return
                except Exception:
                    # torn tail record: stop here; the consumed offset
                    # stays at the last good record so a later refresh
                    # retries once the writer finishes the append
                    return
                yield key, value, f.tell()
    except OSError:
        return


class DiskSegmentEvalCache(EvalCacheBackend):
    """Shared persistent cache: an in-memory LRU front + one append-only
    segment file per writer process in a shared directory, merged on read.

    put(): insert into the LRU and append the pickled (key, value) record
    to this process's own segment (single writer — no locking across
    processes). get(): LRU first; on a miss, re-scan the directory for
    segments that grew since the last merge and replay their new records,
    then retry. Eviction only trims the memory front — the on-disk
    history is append-only, so a cold process rebuilds the merged view by
    replaying every segment."""

    def __init__(self, cache_dir: str, max_entries: int = 100_000) -> None:
        super().__init__()
        self.cache_dir = os.path.abspath(cache_dir)
        os.makedirs(self.cache_dir, exist_ok=True)
        self.mem = InMemoryEvalCache(max_entries=max_entries)
        self._offsets: Dict[str, int] = {}      # segment path -> bytes read
        self._own_path: Optional[str] = None
        self._own_file = None
        self.merged_in = 0                      # records adopted from peers
        self.refreshes = 0
        self._refresh_locked()

    # -- segment plumbing --------------------------------------------------

    def _segments(self):
        try:
            names = sorted(os.listdir(self.cache_dir))
        except OSError:
            return []
        return [os.path.join(self.cache_dir, n) for n in names
                if n.startswith(SEGMENT_PREFIX)
                and n.endswith(SEGMENT_SUFFIX)]

    def _ensure_own(self):
        if self._own_file is None:
            name = (f"{SEGMENT_PREFIX}{os.getpid()}-"
                    f"{uuid.uuid4().hex[:8]}{SEGMENT_SUFFIX}")
            self._own_path = os.path.join(self.cache_dir, name)
            self._own_file = open(self._own_path, "ab")
        return self._own_file

    def _refresh_locked(self) -> int:
        """Replay new bytes from peer segments into the memory front.
        Returns the number of records merged."""
        n = 0
        for path in self._segments():
            if path == self._own_path:
                continue
            off = self._offsets.get(path, 0)
            try:
                size = os.path.getsize(path)
            except OSError:
                continue
            if size <= off:
                continue
            for key, value, end in _iter_records(path, off):
                # peers' entries refresh the LRU like local inserts
                self.mem._put(key, value)
                off = end
                n += 1
            self._offsets[path] = off
        self.merged_in += n
        self.refreshes += 1
        return n

    def refresh(self) -> int:
        with self._lock:
            return self._refresh_locked()

    # -- backend surface ---------------------------------------------------

    def _get(self, key: Key):
        v = self.mem._get(key)
        if v is not None:
            return v
        if self._refresh_locked():
            return self.mem._get(key)
        return None

    def _put(self, key: Key, value) -> None:
        self.mem._put(key, value)
        f = self._ensure_own()
        pickle.dump((key, value), f, protocol=pickle.HIGHEST_PROTOCOL)
        f.flush()

    def _put_many(self, items) -> None:
        """One buffered append + flush for the whole batch; the record
        stream stays `_iter_records`-compatible (back-to-back pickles)."""
        for key, value in items:
            self.mem._put(key, value)
        if not items:
            return
        f = self._ensure_own()
        f.write(b"".join(
            pickle.dumps(rec, protocol=pickle.HIGHEST_PROTOCOL)
            for rec in items))
        f.flush()

    def _clear(self) -> None:
        """Drop the memory front and forget merge offsets. Segment files
        are append-only shared state — other workers may be reading them —
        so clear() never deletes from disk; use `purge()` for that."""
        self.mem._clear()
        self._offsets.clear()
        self.merged_in = 0
        # skip our own already-written records on the next refresh: clear()
        # means "forget what this process has seen", not "unshare it"
        if self._own_path is not None:
            try:
                self._offsets[self._own_path] = os.path.getsize(
                    self._own_path)
            except OSError:
                pass

    def purge(self) -> None:
        """Delete every segment file (tests / explicit cache resets)."""
        with self._lock:
            self.close()
            for path in self._segments():
                try:
                    os.remove(path)
                except OSError:
                    pass
            self.mem._clear()
            self._offsets.clear()
            self.merged_in = 0
            self.hits = self.misses = 0

    def close(self) -> None:
        if self._own_file is not None:
            self._own_file.close()
            self._own_file = None
            self._own_path = None

    def _entries(self) -> int:
        return self.mem._entries()

    def _extra_stats(self) -> Dict[str, int]:
        return {"evictions": self.mem.evictions,
                "max_entries": self.mem.max_entries,
                "segments": len(self._segments()),
                "merged_in": self.merged_in,
                "refreshes": self.refreshes}


__all__ = ["DiskSegmentEvalCache", "EvalCacheBackend", "InMemoryEvalCache",
           "SEGMENT_PREFIX", "SEGMENT_SUFFIX", "attribute_cache_traffic"]
