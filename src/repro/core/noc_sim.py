"""Cycle-approximate NoC simulator — the ground-truth oracle standing in for
the paper's extended BookSim2 (§VIII-A; see DESIGN.md §3 for the fidelity
argument). Wormhole-approximate queueing at packet granularity:

  - each directed mesh link transmits 1 flit/cycle (flit = noc_bw bits);
  - a packet's head advances hop-by-hop, queueing on per-link next-free
    times (contention), paying 1 router-cycle per hop;
  - serialization (flit count) is paid on each link's occupancy and once on
    delivery (wormhole pipelining);
  - per-link waiting times are accumulated — they are the GNN's regression
    targets, and packet latencies validate Eq. 6 reconstruction.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Tuple

import numpy as np

from repro.core.compiler import ChunkGraph, _xy_route
from repro.core.design_space import WSCDesign


@dataclasses.dataclass
class Packet:
    src: int
    dst: int
    flits: int
    inject: float


@dataclasses.dataclass
class SimResult:
    makespan: float
    avg_latency: float
    link_wait: Dict[Tuple[int, int], float]     # avg waiting per link
    link_util: Dict[Tuple[int, int], float]


def packets_for_transfer(graph: ChunkGraph, design: WSCDesign, t_idx: int
                         ) -> List[Packet]:
    t = graph.transfers[t_idx]
    interval = graph.ops[t.src_op].tile.out_interval_cycles
    flit_bits = design.noc_bw
    pkts = []
    per_src_seq: Dict[int, int] = {}
    for s, d, b in t.pairs:
        seq = per_src_seq.get(s, 0)
        per_src_seq[s] = seq + 1
        flits = max(int(np.ceil(b * 8.0 / flit_bits)), 1)
        pkts.append(Packet(s, d, flits, inject=seq * interval))
    return pkts


def simulate(packets: List[Packet], W: int) -> SimResult:
    """Event-ordered single-pass queueing simulation."""
    link_free: Dict[Tuple[int, int], float] = {}
    wait_sum: Dict[Tuple[int, int], float] = {}
    wait_cnt: Dict[Tuple[int, int], int] = {}
    busy: Dict[Tuple[int, int], float] = {}

    done_t = []
    # process in inject order (heap keyed by current head time)
    heap = [(p.inject, i) for i, p in enumerate(packets)]
    heapq.heapify(heap)
    while heap:
        t0, i = heapq.heappop(heap)
        p = packets[i]
        t = t0
        for hop in _xy_route(p.src, p.dst, W):
            free = link_free.get(hop, 0.0)
            start = max(t, free)
            wait_sum[hop] = wait_sum.get(hop, 0.0) + (start - t)
            wait_cnt[hop] = wait_cnt.get(hop, 0) + 1
            link_free[hop] = start + p.flits          # serialization occupancy
            busy[hop] = busy.get(hop, 0.0) + p.flits
            t = start + 1.0                            # head advances (wormhole)
        done_t.append(t + p.flits)                     # tail arrives

    makespan = max(done_t) if done_t else 0.0
    lat = [dt - p.inject for dt, p in zip(done_t, packets)]
    link_wait = {k: wait_sum[k] / max(wait_cnt[k], 1) for k in wait_sum}
    util = {k: busy[k] / max(makespan, 1.0) for k in busy}
    return SimResult(makespan=makespan,
                     avg_latency=float(np.mean(lat)) if lat else 0.0,
                     link_wait=link_wait, link_util=util)


def chunk_latency_cycles_sim(graph: ChunkGraph, design: WSCDesign) -> float:
    """High-fidelity chunk latency: compute + simulated comm makespans."""
    total = 0.0
    for i, node in enumerate(graph.ops):
        total += node.tile.cycles
        if i < len(graph.transfers) and graph.transfers[i].pairs:
            pkts = packets_for_transfer(graph, design, i)
            total += simulate(pkts, graph.array[1]).makespan
    return total
