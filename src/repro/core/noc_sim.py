"""Cycle-approximate NoC simulator — the ground-truth oracle standing in for
the paper's extended BookSim2 (§VIII-A; see DESIGN.md §3 for the fidelity
argument). Wormhole-approximate queueing at packet granularity:

  - each directed mesh link transmits 1 flit/cycle (flit = noc_bw bits);
  - a packet's head advances hop-by-hop, queueing on per-link next-free
    times (contention), paying 1 router-cycle per hop;
  - serialization (flit count) is paid on each link's occupancy and once on
    delivery (wormhole pipelining);
  - per-link waiting times are accumulated — they are the GNN's regression
    targets, and packet latencies validate Eq. 6 reconstruction.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Tuple

import numpy as np

from repro.core.compiler import ChunkGraph, _xy_route
from repro.core.design_space import WSCDesign


@dataclasses.dataclass
class Packet:
    src: int
    dst: int
    flits: int
    inject: float


@dataclasses.dataclass
class SimResult:
    makespan: float
    avg_latency: float
    link_wait: Dict[Tuple[int, int], float]     # avg waiting per link
    link_util: Dict[Tuple[int, int], float]


def packets_for_transfer(graph: ChunkGraph, design: WSCDesign, t_idx: int
                         ) -> List[Packet]:
    t = graph.transfers[t_idx]
    interval = graph.ops[t.src_op].tile.out_interval_cycles
    flit_bits = design.noc_bw
    pkts = []
    per_src_seq: Dict[int, int] = {}
    for s, d, b in t.pairs:
        seq = per_src_seq.get(s, 0)
        per_src_seq[s] = seq + 1
        flits = max(int(np.ceil(b * 8.0 / flit_bits)), 1)
        pkts.append(Packet(s, d, flits, inject=seq * interval))
    return pkts


def simulate(packets: List[Packet], W: int) -> SimResult:
    """Event-ordered single-pass queueing simulation."""
    link_free: Dict[Tuple[int, int], float] = {}
    wait_sum: Dict[Tuple[int, int], float] = {}
    wait_cnt: Dict[Tuple[int, int], int] = {}
    busy: Dict[Tuple[int, int], float] = {}

    done_t = []
    # process in inject order (heap keyed by current head time)
    heap = [(p.inject, i) for i, p in enumerate(packets)]
    heapq.heapify(heap)
    while heap:
        t0, i = heapq.heappop(heap)
        p = packets[i]
        t = t0
        for hop in _xy_route(p.src, p.dst, W):
            free = link_free.get(hop, 0.0)
            start = max(t, free)
            wait_sum[hop] = wait_sum.get(hop, 0.0) + (start - t)
            wait_cnt[hop] = wait_cnt.get(hop, 0) + 1
            link_free[hop] = start + p.flits          # serialization occupancy
            busy[hop] = busy.get(hop, 0.0) + p.flits
            t = start + 1.0                            # head advances (wormhole)
        done_t.append(t + p.flits)                     # tail arrives

    makespan = max(done_t) if done_t else 0.0
    lat = [dt - p.inject for dt, p in zip(done_t, packets)]
    link_wait = {k: wait_sum[k] / max(wait_cnt[k], 1) for k in wait_sum}
    util = {k: busy[k] / max(makespan, 1.0) for k in busy}
    return SimResult(makespan=makespan,
                     avg_latency=float(np.mean(lat)) if lat else 0.0,
                     link_wait=link_wait, link_util=util)


# ---------------------------------------------------------------------------
# vectorized multi-lane simulation (DESIGN.md §4b)
#
# B independent packet sets ("lanes" — e.g. one per (design, transfer)
# candidate) advance in lockstep: the packet loop and the hop loop stay
# sequential (each lane's queueing is inherently ordered) but every step is
# one NumPy op over all lanes at once, against per-link next-free-time
# arrays indexed by a global slot id. Lanes must use disjoint slot ranges,
# which also makes the scatter writes collision-free. Per lane the arithmetic
# and ordering are identical to `simulate`, so results match bit-for-bit.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SimBatchResult:
    makespan: np.ndarray       # (B,)
    avg_latency: np.ndarray    # (B,)
    wait_sum: np.ndarray       # (n_slots,) total waiting per link slot
    wait_cnt: np.ndarray       # (n_slots,) packets that crossed each slot
    busy: np.ndarray           # (n_slots,) flit-cycles of occupancy


def simulate_batch(flits: np.ndarray, inject: np.ndarray,
                   route_slots: np.ndarray, route_len: np.ndarray,
                   n_pkts: np.ndarray, n_slots: int) -> SimBatchResult:
    """Lockstep simulation of B independent lanes.

    flits/inject: (B, P) per-packet, already sorted by (inject, index) within
    each lane (the order `simulate`'s heap pops); route_slots: (B, P, L)
    global link-slot ids per hop (disjoint ranges per lane, entries beyond
    route_len unread); route_len: (B, P); n_pkts: (B,) real packets per lane.
    """
    flits = np.asarray(flits, np.float64)
    inject = np.asarray(inject, np.float64)
    route_len = np.asarray(route_len, np.int64)
    n_pkts = np.asarray(n_pkts, np.int64)
    B, P = flits.shape
    # slot n_slots is a scratch slot for masked-off lanes
    link_free = np.zeros(n_slots + 1)
    wait_sum = np.zeros(n_slots + 1)
    wait_cnt = np.zeros(n_slots + 1, np.int64)
    busy = np.zeros(n_slots + 1)
    makespan = np.zeros(B)
    lat_sum = np.zeros(B)
    for p in range(P):
        act = p < n_pkts
        if not act.any():
            break
        t = inject[:, p].copy()
        fl = flits[:, p]
        rl = route_len[:, p]
        for l in range(int(rl.max(initial=0))):
            valid = act & (l < rl)
            slot = np.where(valid, route_slots[:, p, l], n_slots)
            free = link_free[slot]
            start = np.maximum(t, free)
            wait_sum[slot] += np.where(valid, start - t, 0.0)
            wait_cnt[slot] += valid
            link_free[slot] = np.where(valid, start + fl, free)
            busy[slot] += np.where(valid, fl, 0.0)
            t = np.where(valid, start + 1.0, t)
        done = t + fl
        makespan = np.where(act, np.maximum(makespan, done), makespan)
        lat_sum += np.where(act, done - inject[:, p], 0.0)
    return SimBatchResult(
        makespan=makespan,
        avg_latency=lat_sum / np.maximum(n_pkts, 1),
        wait_sum=wait_sum[:n_slots], wait_cnt=wait_cnt[:n_slots],
        busy=busy[:n_slots])


def simulate_many(packet_lists: List[List[Packet]], Ws: List[int]
                  ) -> List[SimResult]:
    """Run B independent `simulate` calls as one `simulate_batch` pass.
    Lane i reproduces `simulate(packet_lists[i], Ws[i])` bit-for-bit."""
    B = len(packet_lists)
    if B == 0:
        return []
    lanes = []
    for pkts, W in zip(packet_lists, Ws):
        order = sorted(range(len(pkts)), key=lambda i: (pkts[i].inject, i))
        routes = [_xy_route(pkts[i].src, pkts[i].dst, W) for i in order]
        links = sorted({h for r in routes for h in r})
        eid = {l: j for j, l in enumerate(links)}
        lanes.append((pkts, order, routes, links, eid))
    P = max(len(p) for p, *_ in lanes)
    L = max((len(r) for _, _, rs, _, _ in lanes for r in rs), default=0)
    offs = np.concatenate([[0], np.cumsum([len(l[3]) for l in lanes])])
    n_slots = int(offs[-1])
    flits = np.zeros((B, P))
    inject = np.zeros((B, P))
    route_slots = np.zeros((B, P, max(L, 1)), np.int64)
    route_len = np.zeros((B, P), np.int64)
    n_pkts = np.array([len(p) for p, *_ in lanes], np.int64)
    for b, (pkts, order, routes, links, eid) in enumerate(lanes):
        for j, (i, r) in enumerate(zip(order, routes)):
            flits[b, j] = pkts[i].flits
            inject[b, j] = pkts[i].inject
            route_len[b, j] = len(r)
            for l, hop in enumerate(r):
                route_slots[b, j, l] = offs[b] + eid[hop]
    out = simulate_batch(flits, inject, route_slots, route_len, n_pkts,
                         n_slots)
    results = []
    for b, (pkts, _, _, links, _) in enumerate(lanes):
        lo = int(offs[b])
        ws = out.wait_sum[lo:lo + len(links)]
        wc = out.wait_cnt[lo:lo + len(links)]
        bz = out.busy[lo:lo + len(links)]
        mk = float(out.makespan[b])
        link_wait = {l: ws[j] / max(wc[j], 1)
                     for j, l in enumerate(links) if wc[j] > 0}
        util = {l: bz[j] / max(mk, 1.0)
                for j, l in enumerate(links) if bz[j] > 0}
        results.append(SimResult(
            makespan=mk,
            avg_latency=float(out.avg_latency[b]) if len(pkts) else 0.0,
            link_wait=link_wait, link_util=util))
    return results


def chunk_latency_cycles_sim(graph: ChunkGraph, design: WSCDesign) -> float:
    """High-fidelity chunk latency: compute + simulated comm makespans."""
    total = 0.0
    for i, node in enumerate(graph.ops):
        total += node.tile.cycles
        if i < len(graph.transfers) and graph.transfers[i].pairs:
            pkts = packets_for_transfer(graph, design, i)
            total += simulate(pkts, graph.array[1]).makespan
    return total
