"""Design Point Validator (paper §V-E): area, power, yield, SRAM-compiler
feasibility, and TSV stress constraints. Resolves the redundancy (spares per
row) needed for the 0.9 yield target as a side effect.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core import components as C
from repro.core.design_space import WSCDesign
from repro.core.yield_model import YIELD_TARGET, min_spares_for_target


@dataclasses.dataclass
class ValidationResult:
    ok: bool
    reason: str = ""
    design: Optional[WSCDesign] = None       # with spares_per_row resolved
    wafer_yield: float = 0.0


def sram_feasible(buffer_kb: int, buffer_bw: int) -> bool:
    """SRAM-compiler feasibility: tiny macros can't supply very wide ports,
    huge macros can't be both dense and wide (paper: 'some combinations of
    SRAM configurations are infeasible')."""
    if buffer_bw > 64 * buffer_kb:          # > 64 bits/cycle per KB: too wide
        return False
    if buffer_kb >= 1024 and buffer_bw > 2048:
        return False
    return True


def validate(d: WSCDesign, peak_power_w: float = C.WAFER_POWER_W
             ) -> ValidationResult:
    # --- SRAM constraint ----------------------------------------------------
    if not sram_feasible(d.buffer_kb, d.buffer_bw):
        return ValidationResult(False, "sram_infeasible")

    # --- stress constraint (TSV area ratio) ----------------------------------
    if d.use_stacked_dram:
        ratio = d.tsv_area_mm2() / max(d.reticle_area_mm2(), 1e-9)
        if ratio > C.TSV_AREA_RATIO_MAX:
            return ValidationResult(False, "tsv_stress")

    # --- reticle area constraint ---------------------------------------------
    r_area = d.reticle_area_mm2()
    if r_area > C.RETICLE_AREA_MM2:
        return ValidationResult(False, "reticle_area")

    # --- wafer area constraint ----------------------------------------------
    if d.wafer_area_mm2() > C.WAFER_AREA_MM2:
        return ValidationResult(False, "wafer_area")

    # --- yield constraint (resolve redundancy) -------------------------------
    ch, cw = d.core_dims_mm()
    spares, wy = min_spares_for_target(
        ch, cw, d.core_array,
        (d.core_array[0] * ch, d.core_array[1] * cw),
        d.tsv_area_mm2(), d.n_reticles(), d.integration,
        target=YIELD_TARGET)
    if spares < 0:
        return ValidationResult(False, "yield")
    resolved = dataclasses.replace(d, spares_per_row=spares)
    # re-check reticle area with the spare columns added
    if resolved.reticle_area_mm2() > C.RETICLE_AREA_MM2:
        return ValidationResult(False, "reticle_area_with_spares")
    if resolved.wafer_area_mm2() > C.WAFER_AREA_MM2:
        return ValidationResult(False, "wafer_area_with_spares")

    # --- static power sanity (dynamic power checked post-evaluation) --------
    if resolved.static_power_w() > peak_power_w:
        return ValidationResult(False, "static_power")

    return ValidationResult(True, "", resolved, wy)
