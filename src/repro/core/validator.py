"""Design Point Validator (paper §V-E): area, power, yield, SRAM-compiler
feasibility, and TSV stress constraints. Resolves the redundancy (spares per
row) needed for the 0.9 yield target as a side effect.

`validate` is the scalar reference; `validate_batch` applies the same
constraint chain to N designs with vectorized geometry (DesignBatch) and one
batched yield resolution — the candidate-generation hot path in the
exploration loop.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core import components as C
from repro.core.design_space import DesignBatch, WSCDesign
from repro.core.yield_model import (YIELD_TARGET, min_spares_for_target,
                                    min_spares_for_target_batch)


@dataclasses.dataclass
class ValidationResult:
    ok: bool
    reason: str = ""
    design: Optional[WSCDesign] = None       # with spares_per_row resolved
    wafer_yield: float = 0.0


def sram_feasible(buffer_kb: int, buffer_bw: int) -> bool:
    """SRAM-compiler feasibility: tiny macros can't supply very wide ports,
    huge macros can't be both dense and wide (paper: 'some combinations of
    SRAM configurations are infeasible')."""
    if buffer_bw > 64 * buffer_kb:          # > 64 bits/cycle per KB: too wide
        return False
    if buffer_kb >= 1024 and buffer_bw > 2048:
        return False
    return True


def validate(d: WSCDesign, peak_power_w: float = C.WAFER_POWER_W
             ) -> ValidationResult:
    # --- SRAM constraint ----------------------------------------------------
    if not sram_feasible(d.buffer_kb, d.buffer_bw):
        return ValidationResult(False, "sram_infeasible")

    # --- stress constraint (TSV area ratio) ----------------------------------
    if d.use_stacked_dram:
        ratio = d.tsv_area_mm2() / max(d.reticle_area_mm2(), 1e-9)
        if ratio > C.TSV_AREA_RATIO_MAX:
            return ValidationResult(False, "tsv_stress")

    # --- reticle area constraint ---------------------------------------------
    r_area = d.reticle_area_mm2()
    if r_area > C.RETICLE_AREA_MM2:
        return ValidationResult(False, "reticle_area")

    # --- wafer area constraint ----------------------------------------------
    if d.wafer_area_mm2() > C.WAFER_AREA_MM2:
        return ValidationResult(False, "wafer_area")

    # --- yield constraint (resolve redundancy) -------------------------------
    ch, cw = d.core_dims_mm()
    spares, wy = min_spares_for_target(
        ch, cw, d.core_array,
        (d.core_array[0] * ch, d.core_array[1] * cw),
        d.tsv_area_mm2(), d.n_reticles(), d.integration,
        target=YIELD_TARGET)
    if spares < 0:
        return ValidationResult(False, "yield")
    resolved = dataclasses.replace(d, spares_per_row=spares)
    # re-check reticle area with the spare columns added
    if resolved.reticle_area_mm2() > C.RETICLE_AREA_MM2:
        return ValidationResult(False, "reticle_area_with_spares")
    if resolved.wafer_area_mm2() > C.WAFER_AREA_MM2:
        return ValidationResult(False, "wafer_area_with_spares")

    # --- static power sanity (dynamic power checked post-evaluation) --------
    if resolved.static_power_w() > peak_power_w:
        return ValidationResult(False, "static_power")

    return ValidationResult(True, "", resolved, wy)


def validate_batch(designs: Sequence[WSCDesign],
                   peak_power_w: float = C.WAFER_POWER_W
                   ) -> List[ValidationResult]:
    """Vectorized `validate`: result i matches validate(designs[i]) — same
    constraint order, same first-failing reason, same resolved spares (the
    scalar spares resolver delegates to the batched one, so the two paths
    agree bitwise)."""
    designs = list(designs)
    if not designs:
        return []
    N = len(designs)
    db = DesignBatch.from_designs(designs)
    reason = np.full(N, "", object)

    def fail(mask: np.ndarray, why: str) -> None:
        hit = mask & (reason == "")
        reason[hit] = why

    fail((db.buffer_bw > 64 * db.buffer_kb)
         | ((db.buffer_kb >= 1024) & (db.buffer_bw > 2048)), "sram_infeasible")
    tsv_area = np.where(db.dram_on,
                        C.tsv_area_mm2(db.dram_bw_Bps_per_reticle), 0.0)
    fail(db.dram_on & (tsv_area / np.maximum(db.reticle_area_mm2, 1e-9)
                       > C.TSV_AREA_RATIO_MAX), "tsv_stress")
    fail(db.reticle_area_mm2 > C.RETICLE_AREA_MM2, "reticle_area")
    fail(db.wafer_area_mm2 > C.WAFER_AREA_MM2, "wafer_area")

    # --- yield resolution for the survivors ---------------------------------
    spares = np.zeros(N, np.int64)
    wy = np.zeros(N)
    live = reason == ""
    if live.any():
        idx = np.flatnonzero(live)
        side = np.sqrt(db.core_area_mm2[idx])       # core_dims_mm: square
        s_res, w_res = min_spares_for_target_batch(
            side, side, db.core_h[idx], db.core_w[idx],
            db.core_h[idx] * side, db.core_w[idx] * side,
            tsv_area[idx], db.n_reticles[idx], db.integ_code[idx] == 1,
            target=YIELD_TARGET)
        spares[idx] = s_res
        wy[idx] = w_res
        fail(live & (spares < 0), "yield")

        # --- re-check areas / static power with the spare columns added -----
        phy = (4.0 * db.inter_reticle_bw_Bps) * 8e-9 * np.where(
            db.integ_code == 1, C.IR_AREA_UM2_PER_GBPS["infosow"],
            C.IR_AREA_UM2_PER_GBPS["die_stitching"]) * 1e-6
        base2 = (db.core_w + np.maximum(spares, 0)) * db.core_h \
            * db.core_area_mm2 + phy
        r_area2 = np.where(
            db.dram_on,
            base2 / np.maximum(1.0 - C.tsv_area_ratio(db.dram_bw_tbps), 1e-3),
            base2)
        fail((reason == "") & (r_area2 > C.RETICLE_AREA_MM2),
             "reticle_area_with_spares")
        fail((reason == "") & (db.n_reticles * r_area2 > C.WAFER_AREA_MM2),
             "wafer_area_with_spares")

        dram_gb2 = np.where(db.dram_on,
                            C.dram_gb_at_bw(db.dram_bw_tbps) * r_area2 / 100.0,
                            0.0)
        static2 = C.core_static_w(db.mac, db.buffer_kb) * db.total_cores \
            + C.DRAM_STATIC_W_PER_GB * dram_gb2 * db.n_reticles
        fail((reason == "") & (static2 > peak_power_w), "static_power")

    out: List[ValidationResult] = []
    for i, d in enumerate(designs):
        if reason[i]:
            out.append(ValidationResult(False, str(reason[i])))
        else:
            out.append(ValidationResult(
                True, "", dataclasses.replace(d, spares_per_row=int(spares[i])),
                float(wy[i])))
    return out


# ---------------------------------------------------------------------------
# joint (design, strategy) validation — strategy–architecture co-exploration
# ---------------------------------------------------------------------------


def validate_joint_batch(points, wl, peak_power_w: float = C.WAFER_POWER_W,
                         use_oracle: bool = True,
                         n_wafers=None) -> List[ValidationResult]:
    """Vectorized validation of N `JointDesign` points: the architecture
    half goes through `validate_batch` unchanged (same constraint order and
    reasons), then surviving points get their pinned Strategy checked —
    static legality and resource fit first (vectorized), then the
    `repro.dist` shardability oracle (`param_specs`/`batch_specs`
    instantiable on a (dp, tp) mesh; memoized per unique (tp, dp, ep), so
    N points cost a handful of spec-tree builds). Strategy failure
    reasons, in precedence order:

        "strategy_pp"           pp exceeds the workload's layer count
        "strategy_tokens"       dp x microbatches over-splits the step
        "strategy_batch_div"    dp x microbatches does not divide the
                                global batch (grid-mode enumeration's
                                divisibility constraint)
        "strategy_cores"        tp x pp x dp exceeds the system's cores
                                (area-matched wafer count, or `n_wafers`)
        "strategy_memory"       the recompute/schedule/ep-aware v2 memory
                                footprint (`compiler.strategy_memory_need`)
                                exceeds the system's SRAM+DRAM capacity —
                                this is where recompute (saves activation
                                memory at 4x backward cost) and the GPipe
                                schedule (keeps all microbatches in
                                flight) become live search trade-offs
        "strategy_ep"/"strategy_unshardable"/...  oracle verdicts,
            prefixed "strategy_" (ep_experts, dp_batch, tp_dead)

    `n_wafers` overrides the per-design system size; by default each
    design gets the same area-matched wafer count evaluation will use
    (`evaluator.wafers_for_budget` on the spares-resolved design)."""
    points = list(points)
    if not points:
        return []
    import numpy as _np

    from repro.core.compiler import strategy_memory_need

    arch = validate_batch([p.design for p in points],
                          peak_power_w=peak_power_w)

    tp = _np.array([p.strategy.tp for p in points], _np.int64)
    pp = _np.array([p.strategy.pp for p in points], _np.int64)
    dp = _np.array([p.strategy.dp for p in points], _np.int64)
    mb = _np.array([p.strategy.microbatches for p in points], _np.int64)
    ep = _np.array([p.strategy.ep for p in points], _np.int64)
    rc = _np.array([p.strategy.recompute for p in points], bool)
    gp = _np.array([p.strategy.schedule == "gpipe" for p in points], bool)
    mb_count = mb if wl.phase == "train" else _np.ones_like(mb)

    # system size and capacity: the spares-resolved design where arch
    # validation succeeded (matching what evaluation will score), the raw
    # design otherwise (value unused — the arch reason wins below)
    resolved = [ar.design if ar.ok else p.design
                for p, ar in zip(points, arch)]
    if n_wafers is None:
        from repro.core.evaluator import wafers_for_budget
        nw = _np.array([wafers_for_budget(d, wl) for d in resolved],
                       _np.int64)
    else:
        nw = _np.broadcast_to(_np.asarray(n_wafers, _np.int64),
                              (len(points),))
    total_cores = _np.array([d.total_cores() for d in resolved],
                            _np.int64) * nw
    mem_budget = _np.array(
        [d.buffer_kb * 1024.0 * d.total_cores()
         + d.dram_gb_per_reticle() * 1e9 * d.n_reticles()
         for d in resolved]) * nw
    need = strategy_memory_need(wl, tp, pp, dp, mb, ep=ep, recompute=rc,
                                gpipe=gp)

    reason = _np.full(len(points), "", object)
    reason[(reason == "") & (pp > wl.n_layers)] = "strategy_pp"
    reason[(reason == "") & (dp * mb_count > wl.tokens_per_step())] = \
        "strategy_tokens"
    reason[(reason == "") & (wl.batch % (dp * mb_count) != 0)] = \
        "strategy_batch_div"
    reason[(reason == "")
           & ((pp * dp * tp > total_cores) | (tp > total_cores))] = \
        "strategy_cores"
    reason[(reason == "") & (need > mem_budget)] = "strategy_memory"

    out: List[ValidationResult] = []
    for i, (p, ar) in enumerate(zip(points, arch)):
        if not ar.ok:
            out.append(ar)
            continue
        why = str(reason[i])
        if not why and use_oracle:
            from repro.dist import oracle
            ok, o_why = oracle.strategy_shardable(wl, p.strategy)
            if not ok:
                why = f"strategy_{o_why}"
        if why:
            out.append(ValidationResult(False, why))
        else:
            out.append(ar)
    return out
