"""Pluggable fidelity backends for the evaluation engine (DESIGN.md §3/§4b).

Every chunk-latency fidelity (paper §VI-C/§VII: f1 = analytical, f0 = GNN,
CA-sim = ground truth) is a `FidelityBackend` registered by name. A backend
exposes the scalar reference path (`chunk_latency`, a walk over an explicit
ChunkGraph — what `evaluator.evaluate_design` uses) and the batched path
(`evaluate_batch`, the whole (design, strategy) candidate axis in array
form — what `evaluator.evaluate_design_batch` dispatches to). The registry
makes the fidelity axis open: `register_backend` accepts anything that
quacks, and unknown names fail loudly with the registered list.

The batched graph fidelities never materialize ChunkGraph objects. The
transfers `compile_chunk` emits are row all-gathers whose structure depends
only on the (gh, gw) NoC grid, so `compiler.row_allgather_pattern` tables
(pairs, injection sequences, link sets, per-pair routes) plus per-candidate
scalars (flit count, producer interval/duration, NoC bandwidth) reconstruct
exactly the per-transfer link graphs / packet sets the scalar path builds —
see `_transfer_lanes`. The GNN backend then scores every lane in one padded
`gnn_forward_batch` call per grid bucket; the sim backend runs every lane
through one lockstep `simulate_batch` pass per bucket.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Protocol, Tuple, Union

import numpy as np

from repro.core.chunk_eval import (
    StepResult,
    evaluate_step_batch,
    step_result_at,
)
from repro.core.compiler import (
    ChunkGraph,
    RowAllGatherPattern,
    Strategy,
    feasible_strategy_arrays,
    grid_for_batch,
    pinned_resource_ok,
    row_allgather_pattern,
)
from repro.core.design_space import DesignBatch, WSCDesign
from repro.core.noc_analytical import (
    chunk_latency_cycles,
    chunk_latency_cycles_closed,
    row_allgather_byte_hops,
)
from repro.core.noc_gnn import (
    LinkGraphBatch,
    chunk_latency_cycles_gnn,
    gnn_forward_batch,
    next_pow2,
)
from repro.core.noc_sim import chunk_latency_cycles_sim, simulate_batch
from repro.core.tile_eval import evaluate_tile_batch
from repro.core.workload import BYTES, LLMWorkload


@dataclasses.dataclass
class EvalResult:
    throughput: float
    power_w: float
    strategy: Optional[Strategy]
    step: Optional[StepResult]
    n_wafers: int
    feasible: bool
    reason: str = ""


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


class FidelityBackend(Protocol):
    """One chunk-latency fidelity. `chunk_latency` is the scalar reference
    (explicit ChunkGraph walk); `evaluate_batch` scores N designs' full
    strategy spaces as one array pass and must reproduce the scalar search
    (same winner, float-tolerance objectives)."""

    name: str

    def chunk_latency(self, graph: ChunkGraph, design: WSCDesign,
                      gnn_params: Optional[Dict] = None) -> float: ...

    def evaluate_batch(self, geom: DesignBatch, wl: LLMWorkload,
                       n_wafers: np.ndarray, max_strategies: int = 24,
                       gnn_params: Optional[Dict] = None,
                       strategies: Optional[List[Strategy]] = None
                       ) -> List[EvalResult]: ...


_REGISTRY: Dict[str, FidelityBackend] = {}


def register_backend(backend: FidelityBackend) -> FidelityBackend:
    """Register (or replace) a backend under `backend.name`."""
    _REGISTRY[backend.name] = backend
    return backend


def registered_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_backend(fidelity: Union[str, FidelityBackend]) -> FidelityBackend:
    """Resolve a fidelity name (or pass a backend instance through). Unknown
    names raise with the registered list so typos fail loudly instead of
    silently degrading to some default."""
    if not isinstance(fidelity, str):
        return fidelity
    backend = _REGISTRY.get(fidelity)
    if backend is None:
        raise ValueError(
            f"unknown fidelity {fidelity!r}; registered backends: "
            f"{', '.join(registered_backends())}")
    return backend


# ---------------------------------------------------------------------------
# shared candidate axis: every design's strategy list flattened onto one
# (design, strategy) axis with the tile stage already evaluated
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CandidateAxis:
    geom: DesignBatch              # per-design geometry (N rows)
    cg: DesignBatch                # candidate-axis geometry (C rows)
    nw: np.ndarray                 # (N,) wafers per design
    nw_c: np.ndarray               # (C,)
    offsets: np.ndarray            # (N+1,) candidate ranges per design
    didx: np.ndarray               # (C,) design index per candidate
    tp: np.ndarray
    pp: np.ndarray
    dp: np.ndarray
    mb: np.ndarray
    mb_tokens: np.ndarray          # (C,)
    cores_per_chunk: np.ndarray    # (C,) true chunk grid size
    gh: np.ndarray                 # (C,) capped NoC grid (compile_chunk cap)
    gw: np.ndarray
    n_cores: np.ndarray            # (C,) gh * gw
    tiles: Dict[str, np.ndarray]   # (n_ops, C) tile stage outputs
    out_bytes: np.ndarray          # (n_ops, C) producer output bytes
    sram_bits_layer: np.ndarray    # (C,)
    noc_bytes_layer: np.ndarray    # (C,)
    # pinned-strategy (joint) mode: the original Strategy per design plus
    # the extra knob columns; None in grid mode (ISSUE 9)
    pinned: Optional[List[Strategy]] = None
    ep: Optional[np.ndarray] = None
    rc: Optional[np.ndarray] = None


def build_candidate_axis(geom: DesignBatch, wl: LLMWorkload, nw: np.ndarray,
                         max_strategies: int,
                         strategies: Optional[List[Strategy]] = None
                         ) -> CandidateAxis:
    """Flatten per-design strategy lists and run the tile stage — the part
    of the pipeline every fidelity shares (DESIGN.md §4). Per-core tiles are
    sized by the TRUE chunk grid; the NoC grid is the capped representative
    one (compile_chunk's hierarchical scale reduction).

    When `strategies` is given (joint mode, one Strategy per design) the
    grid enumeration is skipped entirely: the candidate axis is exactly one
    pinned candidate per design, with the ep/recompute extras threaded
    through to the chunk-level model."""
    designs = geom.designs

    if strategies is not None:
        counts = np.ones(len(designs), np.int64)
        offsets = np.arange(len(designs) + 1, dtype=np.int64)
        didx = np.arange(len(designs), dtype=np.int64)
        tp = np.array([s.tp for s in strategies], np.int64)
        pp = np.array([s.pp for s in strategies], np.int64)
        dp = np.array([s.dp for s in strategies], np.int64)
        mb = np.array([s.microbatches for s in strategies], np.int64)
    else:
        sram_total = geom.buffer_kb * 1024.0 * geom.total_cores * nw
        dram_total = geom.dram_gb_per_reticle * 1e9 * geom.n_reticles * nw
        strat_arrays = [
            feasible_strategy_arrays(wl, int(geom.total_cores[i] * nw[i]),
                                     float(sram_total[i] + dram_total[i]),
                                     max_strategies)
            for i in range(len(designs))
        ]
        counts = np.array([len(a) for a in strat_arrays], np.int64)
        offsets = np.concatenate([[0], np.cumsum(counts)])
        didx = np.repeat(np.arange(len(designs), dtype=np.int64), counts)
        sa = np.concatenate(strat_arrays, axis=0)
        tp, pp, dp, mb = sa[:, 0], sa[:, 1], sa[:, 2], sa[:, 3]

    cg = geom.take(didx)                     # candidate-axis geometry
    nw_c = nw[didx]
    chunks = pp * dp
    mb_count = mb if wl.phase == "train" else np.ones_like(mb)
    mb_tokens = np.maximum(wl.tokens_per_step() // (dp * mb_count), 1)
    cores_per_chunk = np.maximum(cg.total_cores * nw_c // chunks, 1)

    gh_t, gw_t = grid_for_batch(cores_per_chunk)
    gh, gw = grid_for_batch(np.minimum(cores_per_chunk, 64))
    n_cores = gh * gw
    ops = wl.layer_ops_batch(tp, mb_tokens)
    tile_M = np.maximum(ops["M"] // gh_t, 1)
    tile_N = np.maximum(ops["N"] // gw_t, 1)
    tiles = evaluate_tile_batch(tile_M, ops["K"], tile_N,
                                cg.mac[None, :], cg.buffer_kb[None, :],
                                cg.buffer_bw[None, :],
                                cg.dataflow_code[None, :])

    out_bytes = (ops["M"] * ops["N"]).astype(np.float64) * BYTES
    sram_bits_layer = (tiles["sram_read_bits"]
                       + tiles["sram_write_bits"]).sum(axis=0) * n_cores
    noc_bytes_layer = row_allgather_byte_hops(out_bytes[:-1], gh, gw)

    return CandidateAxis(
        geom=geom, cg=cg, nw=nw, nw_c=nw_c, offsets=offsets, didx=didx,
        tp=tp, pp=pp, dp=dp, mb=mb, mb_tokens=mb_tokens,
        cores_per_chunk=cores_per_chunk, gh=gh, gw=gw, n_cores=n_cores,
        tiles=tiles, out_bytes=out_bytes, sram_bits_layer=sram_bits_layer,
        noc_bytes_layer=noc_bytes_layer,
        pinned=list(strategies) if strategies is not None else None,
        ep=(np.array([s.ep for s in strategies], np.int64)
            if strategies is not None else None),
        rc=(np.array([s.recompute for s in strategies], bool)
            if strategies is not None else None))


def _finish(ax: CandidateAxis, wl: LLMWorkload, lat: np.ndarray
            ) -> List[EvalResult]:
    """Chunk-level stage + per-design best-feasible reduction (first max
    wins, matching the scalar search order — candidates are already
    strategy-sorted). In pinned mode (ax.pinned) there is exactly one
    candidate per design and no argmin: the EvalResult carries the original
    searched Strategy. A pinned strategy that fails the grid resource-fit
    arithmetic (cores / memory capacity, `compiler.pinned_resource_ok`)
    reports "strategy_resources"; one that fails the step model's
    power/finiteness check reports "strategy_infeasible"."""
    step = evaluate_step_batch(ax.cg, wl, ax.tp, ax.pp, ax.dp, ax.mb, lat,
                               ax.sram_bits_layer, ax.noc_bytes_layer,
                               ax.nw_c, ep=ax.ep, recompute=ax.rc)
    results: List[EvalResult] = []
    if ax.pinned is not None:
        res_ok = pinned_resource_ok(wl, ax.geom, ax.nw, ax.tp, ax.pp, ax.dp,
                                    ax.mb)
        for i, s in enumerate(ax.pinned):
            if not (res_ok[i] and step["feasible"][i]):
                results.append(EvalResult(
                    0.0, float("inf"), s, None, int(ax.nw[i]), False,
                    "strategy_resources" if not res_ok[i]
                    else "strategy_infeasible"))
                continue
            sr = step_result_at(step, i)
            results.append(EvalResult(sr.throughput, sr.power_w, s, sr,
                                      int(ax.nw[i]), True))
        return results
    thpt = np.where(step["feasible"], step["throughput"], -1.0)
    for i in range(len(ax.geom.designs)):
        lo, hi = ax.offsets[i], ax.offsets[i + 1]
        if hi == lo or not step["feasible"][lo:hi].any():
            results.append(EvalResult(0.0, float("inf"), None, None,
                                      int(ax.nw[i]), False,
                                      "no_feasible_strategy"))
            continue
        j = lo + int(np.argmax(thpt[lo:hi]))
        sr = step_result_at(step, j)
        results.append(EvalResult(
            sr.throughput, sr.power_w,
            Strategy(int(ax.tp[j]), int(ax.pp[j]), int(ax.dp[j]),
                     int(ax.mb[j])),
            sr, int(ax.nw[i]), True))
    return results


# ---------------------------------------------------------------------------
# (candidate, transfer) lanes for the graph fidelities
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _GridLanes:
    """All (unique candidate, transfer) lanes sharing one NoC grid. Each
    lane is one row-all-gather transfer: uniform per-packet flit count,
    producer interval/duration, and the lane's NoC bandwidth — everything
    the pattern tables need to reconstruct the scalar path's link graph
    (featurize_transfer) and packet set (packets_for_transfer)."""
    pattern: RowAllGatherPattern
    u_lane: np.ndarray             # (B,) unique-candidate index per lane
    flits: np.ndarray              # (B,) flits per packet (uniform in lane)
    interval: np.ndarray           # (B,) producer output interval (cycles)
    dur: np.ndarray                # (B,) producer duration, >= 1
    noc_bw: np.ndarray             # (B,) bits/cycle


@dataclasses.dataclass
class _TransferLanes:
    uniq_first: np.ndarray         # (U,) candidate index of each unique rep
    inverse: np.ndarray            # (C,) candidate -> unique index
    n_unique: int
    buckets: List[_GridLanes]


def _transfer_lanes(ax: CandidateAxis) -> _TransferLanes:
    """Dedupe candidates that share a compiled graph — the batch analogue of
    the scalar path's per-design `graph_cache` keyed by
    (tp, mb_tokens, cores_per_chunk) — then group the per-transfer lanes of
    the unique candidates by NoC grid width.

    Row decomposition: every route of a row all-gather is horizontal, so the
    (gh, gw) transfer graph is gh disjoint copies of the (1, gw) path graph
    with identical features, packets, and injections. Per-edge GNN
    predictions and per-row simulations are therefore equal across rows, and
    a transfer's makespan on the full grid equals its makespan on one row —
    lanes run on the (1, gw) pattern, a gh-fold compute reduction."""
    key = np.stack([ax.didx, ax.tp, ax.mb_tokens, ax.cores_per_chunk],
                   axis=1)
    _, first, inv = np.unique(key, axis=0, return_index=True,
                              return_inverse=True)
    U = len(first)
    gw_u = ax.gw[first]
    nc_u = ax.n_cores[first].astype(np.float64)
    bw_u = ax.cg.noc_bw[first].astype(np.float64)

    n_transfers = ax.out_bytes.shape[0] - 1
    per_pair = ax.out_bytes[:-1, first] / nc_u        # (T, U)
    flits = np.maximum(np.ceil(per_pair * 8.0 / bw_u), 1.0)
    interval = ax.tiles["out_interval_cycles"][:-1, first]
    dur = np.maximum(ax.tiles["cycles"][:-1, first], 1.0)

    buckets: List[_GridLanes] = []
    for gw0 in np.unique(gw_u[gw_u > 1]):
        members = np.flatnonzero(gw_u == gw0)
        shape = (n_transfers, len(members))
        buckets.append(_GridLanes(
            pattern=row_allgather_pattern(1, int(gw0)),
            u_lane=np.broadcast_to(members, shape).ravel(),
            flits=flits[:, members].ravel(),
            interval=interval[:, members].ravel(),
            dur=dur[:, members].ravel(),
            noc_bw=np.broadcast_to(bw_u[members], shape).ravel()))
    return _TransferLanes(uniq_first=first, inverse=inv, n_unique=U,
                          buckets=buckets)


def _pattern_features(b: _GridLanes) -> Tuple[np.ndarray, np.ndarray]:
    """Node/edge feature tensors for every lane of one grid bucket —
    bit-identical to `featurize_transfer` on the corresponding compiled
    chunk (all packets of a row all-gather share one flit count, so
    link_flits = flits * flows and inj = flits * (gw - 1))."""
    pat = b.pattern
    B = len(b.flits)
    n, E = pat.n_cores, len(pat.links)
    node_x = np.empty((B, n, 3), np.float64)
    node_x[:, :, 0] = (b.flits * (pat.gw - 1) / b.dur)[:, None]
    node_x[:, :, 1] = pat.out_deg[None, :] / 4.0
    node_x[:, :, 2] = pat.in_deg[None, :] / 4.0
    edge_x = np.empty((B, E, 3), np.float64)
    edge_x[:, :, 0] = np.log1p(b.flits[:, None] * pat.flows[None, :])
    edge_x[:, :, 1] = (b.noc_bw / 4096.0)[:, None]
    edge_x[:, :, 2] = np.log1p(pat.flows)[None, :]
    return node_x.astype(np.float32), edge_x.astype(np.float32)


def _gnn_lane_makespans(params: Dict, b: _GridLanes) -> np.ndarray:
    """Eq. 6 for every lane of one bucket: one padded vmapped forward pass
    scores all lanes' link graphs, then the per-packet reconstruction
    (inject + flits + hops + summed predicted waits, max over packets) runs
    as array math against the pattern's route table. The forward only sees
    (flits, dur, noc_bw) — lanes sharing that triple (common across designs
    and strategies) are collapsed before the XLA call."""
    pat = b.pattern
    fkey = np.stack([b.flits, b.dur, b.noc_bw], axis=1)
    uniq, uinv = np.unique(fkey, axis=0, return_inverse=True)
    ub = _GridLanes(pattern=pat, u_lane=np.zeros(0), flits=uniq[:, 0],
                    interval=np.zeros(len(uniq)), dur=uniq[:, 1],
                    noc_bw=uniq[:, 2])
    node_x, edge_x = _pattern_features(ub)
    F, E = len(uniq), len(pat.links)
    Fp = next_pow2(F)               # bounded set of jit shapes per pattern
    if Fp > F:
        node_x = np.concatenate(
            [node_x, np.zeros((Fp - F,) + node_x.shape[1:], np.float32)])
        edge_x = np.concatenate(
            [edge_x, np.zeros((Fp - F,) + edge_x.shape[1:], np.float32)])
    batch = LinkGraphBatch(
        node_x=node_x, edge_x=edge_x,
        senders=np.broadcast_to(pat.senders, (Fp, E)),
        receivers=np.broadcast_to(pat.receivers, (Fp, E)),
        edge_mask=np.ones((Fp, E), np.float32),
        n_nodes=pat.n_cores, n_edges_real=np.full(Fp, E, np.int64))
    wait = gnn_forward_batch(params, batch)[:F].astype(np.float64)
    wait_pad = np.concatenate([wait, np.zeros((F, 1))], axis=1)
    pkt_wait = wait_pad[:, pat.route_eids].sum(axis=2)          # (F, P)
    t = uniq[:, 0][:, None] + pat.route_len[None, :] + pkt_wait
    inject = pat.seq[None, :].astype(np.float64) * b.interval[:, None]
    return np.max(inject + t[uinv], axis=1)


def _sim_lane_makespans(b: _GridLanes) -> np.ndarray:
    """Lockstep simulation of every lane of one bucket: per-lane packets in
    the (inject, index) order `simulate`'s heap pops, per-lane link slots
    disjoint by construction. A lane's outcome only depends on
    (flits, interval), so duplicate lanes simulate once."""
    pat = b.pattern
    fkey = np.stack([b.flits, b.interval], axis=1)
    uniq, uinv = np.unique(fkey, axis=0, return_inverse=True)
    B = len(uniq)
    P, E = len(pat.src), len(pat.links)
    inject = pat.seq[None, :].astype(np.float64) * uniq[:, 1][:, None]
    order = np.argsort(inject, axis=1, kind="stable")
    inj_s = np.take_along_axis(inject, order, axis=1)
    route_eids_s = pat.route_eids[order]                        # (B, P, L)
    route_len_s = pat.route_len[order]
    slots = route_eids_s.astype(np.int64) \
        + (np.arange(B, dtype=np.int64) * E)[:, None, None]
    flits = np.broadcast_to(uniq[:, 0][:, None], (B, P))
    res = simulate_batch(flits, inj_s, slots, route_len_s,
                         np.full(B, P, np.int64), B * E)
    return res.makespan[uinv]


def _graph_latency(ax: CandidateAxis, lane_fn) -> np.ndarray:
    """Per-candidate chunk latency for a graph fidelity: true-grid tile
    cycles plus the per-transfer comm makespans `lane_fn` computes for the
    unique candidates, gathered back to the full candidate axis."""
    lanes = _transfer_lanes(ax)
    comm = np.zeros(lanes.n_unique)
    for b in lanes.buckets:
        np.add.at(comm, b.u_lane, lane_fn(b))
    return ax.tiles["cycles"].sum(axis=0) + comm[lanes.inverse]


# ---------------------------------------------------------------------------
# the three built-in backends
# ---------------------------------------------------------------------------


class AnalyticalBackend:
    """f1: equivalent-bandwidth NoC model, closed form on the batch axis.

    `evaluate_batch` dispatches to the jitted pipeline
    (repro.core.eval_compiled, DESIGN.md §12) — one compiled XLA program
    over the pow2-padded (design, strategy) axes, bit-identical to the
    NumPy reference retained as `evaluate_batch_ref` (property-tested in
    tests/test_eval_compiled.py). REPRO_COMPILED_EVAL=0 falls back."""

    name = "analytical"

    def chunk_latency(self, graph: ChunkGraph, design: WSCDesign,
                      gnn_params: Optional[Dict] = None) -> float:
        return chunk_latency_cycles(graph, design)

    def evaluate_batch(self, geom: DesignBatch, wl: LLMWorkload,
                       n_wafers: np.ndarray, max_strategies: int = 24,
                       gnn_params: Optional[Dict] = None,
                       strategies: Optional[List[Strategy]] = None
                       ) -> List[EvalResult]:
        from repro.core import eval_compiled
        if eval_compiled.enabled():
            if strategies is not None:
                return eval_compiled.evaluate_pinned_compiled(
                    geom, wl, np.asarray(n_wafers, np.int64), strategies)
            return eval_compiled.evaluate_batch_compiled(
                geom, wl, np.asarray(n_wafers, np.int64), max_strategies)
        return self.evaluate_batch_ref(geom, wl, n_wafers, max_strategies,
                                       gnn_params, strategies)

    def evaluate_batch_ref(self, geom: DesignBatch, wl: LLMWorkload,
                           n_wafers: np.ndarray, max_strategies: int = 24,
                           gnn_params: Optional[Dict] = None,
                           strategies: Optional[List[Strategy]] = None
                           ) -> List[EvalResult]:
        """NumPy reference pipeline (the pre-compiled implementation,
        kept verbatim as the oracle for the jitted path)."""
        ax = build_candidate_axis(geom, wl, n_wafers, max_strategies,
                                  strategies)
        lat = chunk_latency_cycles_closed(ax.tiles["cycles"], ax.out_bytes,
                                          ax.gh, ax.gw, ax.cg.noc_bw)
        return _finish(ax, wl, lat)


class GNNBackend:
    """f0: learned congestion model. Without params it degrades to the
    analytical estimate, exactly like the scalar path."""

    name = "gnn"

    def chunk_latency(self, graph: ChunkGraph, design: WSCDesign,
                      gnn_params: Optional[Dict] = None) -> float:
        if gnn_params is None:
            return chunk_latency_cycles(graph, design)
        return chunk_latency_cycles_gnn(gnn_params, graph, design)

    def evaluate_batch(self, geom: DesignBatch, wl: LLMWorkload,
                       n_wafers: np.ndarray, max_strategies: int = 24,
                       gnn_params: Optional[Dict] = None,
                       strategies: Optional[List[Strategy]] = None
                       ) -> List[EvalResult]:
        if gnn_params is None:
            return get_backend("analytical").evaluate_batch(
                geom, wl, n_wafers, max_strategies, strategies=strategies)
        ax = build_candidate_axis(geom, wl, n_wafers, max_strategies,
                                  strategies)
        lat = _graph_latency(
            ax, lambda b: _gnn_lane_makespans(gnn_params, b))
        return _finish(ax, wl, lat)


class SimBackend:
    """Cycle-approximate simulator (ground truth)."""

    name = "sim"

    def chunk_latency(self, graph: ChunkGraph, design: WSCDesign,
                      gnn_params: Optional[Dict] = None) -> float:
        return chunk_latency_cycles_sim(graph, design)

    def evaluate_batch(self, geom: DesignBatch, wl: LLMWorkload,
                       n_wafers: np.ndarray, max_strategies: int = 24,
                       gnn_params: Optional[Dict] = None,
                       strategies: Optional[List[Strategy]] = None
                       ) -> List[EvalResult]:
        ax = build_candidate_axis(geom, wl, n_wafers, max_strategies,
                                  strategies)
        lat = _graph_latency(ax, _sim_lane_makespans)
        return _finish(ax, wl, lat)


register_backend(AnalyticalBackend())
register_backend(GNNBackend())
register_backend(SimBackend())


def evaluate_serving_batch(designs, wl, mix, slo, **kw):
    """Request-level serving evaluation (TTFT / TPOT / SLO goodput) against
    any registered backend — every fidelity that can score per-step
    prefill/decode workloads can score a serving workload. Forwarder to
    `repro.core.serving` (lazy import: serving builds on this registry)."""
    from repro.core.serving import evaluate_serving_batch as _impl
    return _impl(designs, wl, mix, slo, **kw)


def evaluate_trace_serving_batch(designs, wl, trace, **kw):
    """Trace-driven, multi-tenant serving evaluation (timed arrivals,
    per-tenant SLOs, admission/routing policies) against any registered
    backend — the timed counterpart of `evaluate_serving_batch`. Forwarder
    to `repro.core.traces` (lazy import: traces builds on this registry)."""
    from repro.core.traces import evaluate_trace_serving_batch as _impl
    return _impl(designs, wl, trace, **kw)
