"""NumPy reference GP — the pre-compilation implementation of core/gp.py,
retained verbatim as the property-test oracle for the jitted path
(DESIGN.md §9). Per-candidate NumPy linear algebra, eager JAX autodiff for
the hyperparameter fit; O(n^3) re-solve in `condition_on`.

Not used by the exploration loop: `repro.core.gp.GP` is the production
surrogate. Tests assert the two agree within float32 tolerance.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _matern52(x1, x2, ls, sf):
    d = jnp.sqrt(jnp.maximum(
        jnp.sum(((x1[:, None, :] - x2[None, :, :]) / ls) ** 2, -1), 1e-12))
    s5 = jnp.sqrt(5.0) * d
    return sf * (1 + s5 + 5.0 * d * d / 3.0) * jnp.exp(-s5)


def _nll(raw, X, y):
    ls = jnp.exp(raw["log_ls"])
    sf = jnp.exp(raw["log_sf"])
    noise = jnp.exp(raw["log_noise"]) + 1e-6
    K = _matern52(X, X, ls, sf) + noise * jnp.eye(len(X))
    L = jnp.linalg.cholesky(K)
    a = jax.scipy.linalg.cho_solve((L, True), y)
    return (0.5 * y @ a + jnp.sum(jnp.log(jnp.diag(L)))
            + 0.5 * len(X) * jnp.log(2 * jnp.pi))


@dataclasses.dataclass
class NumpyGP:
    X: np.ndarray
    y: np.ndarray
    params: dict
    mean: float
    std: float
    chol: np.ndarray
    alpha: np.ndarray

    @staticmethod
    def fit(X: np.ndarray, y: np.ndarray, iters: int = 80,
            lr: float = 0.05, seed: int = 0) -> "NumpyGP":
        X = jnp.asarray(X, jnp.float32)
        mean, std = float(np.mean(y)), float(np.std(y) + 1e-9)
        yn = jnp.asarray((np.asarray(y) - mean) / std, jnp.float32)
        d = X.shape[1]
        raw = {"log_ls": jnp.zeros(d) + jnp.log(0.3),
               "log_sf": jnp.asarray(0.0),
               "log_noise": jnp.asarray(jnp.log(0.05))}
        grad_fn = jax.jit(jax.value_and_grad(lambda r: _nll(r, X, yn)))
        m = jax.tree.map(jnp.zeros_like, raw)
        v = jax.tree.map(jnp.zeros_like, raw)
        for t in range(1, iters + 1):
            val, g = grad_fn(raw)
            if not np.isfinite(float(val)):
                break
            m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
            v = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
            raw = jax.tree.map(
                lambda p, m_, v_: p - lr * (m_ / (1 - 0.9 ** t))
                / (jnp.sqrt(v_ / (1 - 0.999 ** t)) + 1e-8), raw, m, v)
        ls = jnp.exp(raw["log_ls"])
        sf = jnp.exp(raw["log_sf"])
        noise = jnp.exp(raw["log_noise"]) + 1e-6
        K = _matern52(X, X, ls, sf) + noise * jnp.eye(len(X))
        L = np.asarray(jnp.linalg.cholesky(K))
        alpha = np.asarray(jax.scipy.linalg.cho_solve((jnp.asarray(L), True), yn))
        return NumpyGP(np.asarray(X), np.asarray(yn),
                       jax.tree.map(np.asarray, raw), mean, std, L, alpha)

    def predict(self, Xs: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Posterior mean/std at Xs (de-normalized), batched over rows."""
        ls = np.exp(self.params["log_ls"])
        sf = np.exp(self.params["log_sf"])
        Ks = np.asarray(_matern52(jnp.asarray(Xs, jnp.float32),
                                  jnp.asarray(self.X), jnp.asarray(ls),
                                  jnp.asarray(sf)))
        mu = Ks @ self.alpha
        v = np.linalg.solve(self.chol, Ks.T)
        var = np.maximum(sf - np.sum(v * v, axis=0), 1e-10)
        return mu * self.std + self.mean, np.sqrt(var) * self.std

    def condition_on(self, x: np.ndarray, y: float) -> "NumpyGP":
        """Fantasy update: rank-1 Cholesky append + full re-solve."""
        ls = np.exp(self.params["log_ls"])
        sf = float(np.exp(self.params["log_sf"]))
        noise = float(np.exp(self.params["log_noise"])) + 1e-6
        x = np.asarray(x, np.float32).reshape(1, -1)
        k = np.asarray(_matern52(jnp.asarray(x), jnp.asarray(self.X),
                                 jnp.asarray(ls), jnp.asarray(sf)))[0]
        c = np.linalg.solve(self.chol, k)
        d = math.sqrt(max(sf + noise - float(c @ c), 1e-10))
        n = len(self.X)
        L = np.zeros((n + 1, n + 1), dtype=self.chol.dtype)
        L[:n, :n] = self.chol
        L[n, :n] = c
        L[n, n] = d
        X2 = np.concatenate([self.X, x.astype(self.X.dtype)], axis=0)
        yn = (float(y) - self.mean) / self.std
        y2 = np.concatenate([self.y, np.asarray([yn], self.y.dtype)])
        alpha = np.linalg.solve(L.T, np.linalg.solve(L, y2))
        return NumpyGP(X2, y2, self.params, self.mean, self.std, L, alpha)


def acquire_batch_ref(models: Tuple[NumpyGP, NumpyGP], cand_x: np.ndarray,
                      evaluated: np.ndarray, ref: np.ndarray,
                      q: int = 1) -> List[int]:
    """Greedy q-EHVI with rank-1 fantasization — the pre-compilation
    `_acquire_batch` loop, kept as the oracle for the scanned JAX version."""
    from repro.core.ehvi import ehvi_2d_ref
    from repro.core.pareto import pareto_front

    g_t, g_p = models
    fantasy_pts = np.asarray(evaluated, float).reshape(-1, 2)
    chosen: List[int] = []
    q = max(1, min(q, len(cand_x)))
    while len(chosen) < q:
        mu_t, s_t = g_t.predict(cand_x)
        mu_p, s_p = g_p.predict(cand_x)
        mu = np.stack([mu_t, mu_p], 1)
        sg = np.stack([s_t, s_p], 1)
        front = (pareto_front(fantasy_pts) if len(fantasy_pts)
                 else np.zeros((0, 2)))
        scores = ehvi_2d_ref(mu, sg, front, np.asarray(ref, float))
        if chosen:
            scores[np.asarray(chosen)] = -np.inf
        j = int(np.argmax(scores))
        chosen.append(j)
        if len(chosen) == q:
            break
        g_t = g_t.condition_on(cand_x[j], float(mu_t[j]))
        g_p = g_p.condition_on(cand_x[j], float(mu_p[j]))
        fantasy_pts = np.concatenate([fantasy_pts, mu[j:j + 1]], axis=0)
    return chosen
