"""LLM workload descriptors for the DSE (paper §VIII-A, Table II) + bridge
from the runtime's ModelConfig so every assigned architecture is a DSE
benchmark too.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig

BYTES = 2          # bf16 activations/weights on-wafer


@dataclasses.dataclass(frozen=True)
class GEMMOp:
    name: str
    M: int            # tokens (rows)
    K: int
    N: int
    weight: bool = True          # K x N is a resident weight (vs act x act)

    def flops(self) -> float:
        return 2.0 * self.M * self.K * self.N

    def in_bytes(self) -> float:
        return (self.M * self.K + self.K * self.N) * BYTES

    def out_bytes(self) -> float:
        return self.M * self.N * BYTES


@dataclasses.dataclass(frozen=True)
class LLMWorkload:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    seq: int
    batch: int
    phase: str                     # train | prefill | decode
    moe_experts: int = 0
    moe_topk: int = 0
    gpu_budget: int = 1            # baseline GPU count (area matching)

    # ------------------------------------------------------------------

    def params_bytes(self) -> float:
        D, F, L = self.d_model, self.d_ff, self.n_layers
        per = 4 * D * D + 3 * D * F * max(self.moe_experts, 1)
        return (L * per + 2 * self.vocab * D) * BYTES

    def expert_params_bytes(self) -> float:
        """Bytes of MoE expert weights (the `ep`-shardable slice of
        `params_bytes`); 0 for dense models."""
        if not self.moe_experts:
            return 0.0
        D, F, L = self.d_model, self.d_ff, self.n_layers
        return L * 3 * D * F * self.moe_experts * BYTES

    def active_params(self) -> float:
        D, F, L = self.d_model, self.d_ff, self.n_layers
        e = self.moe_topk if self.moe_experts else 1
        return L * (4 * D * D + 3 * D * F * e) + self.vocab * D

    def tokens_per_step(self) -> int:
        if self.phase == "decode":
            return self.batch
        return self.batch * self.seq

    def layer_ops(self, tp: int = 1, mb_tokens: Optional[int] = None
                  ) -> List[GEMMOp]:
        """One layer's GEMMs under tensor parallelism `tp` (Megatron split:
        heads/ffn sharded; two collectives per layer accounted by chunk_eval).
        M = tokens per microbatch."""
        D, F = self.d_model, self.d_ff
        hd = D // max(self.n_heads, 1)
        M = mb_tokens if mb_tokens is not None else self.tokens_per_step()
        # Attention context length is the full sequence in every phase:
        # decode reads the whole KV cache, and a prefill/train token attends
        # over its prompt no matter how the M tokens are sharded across
        # dp/microbatch splits (M // batch would shrink the KV with the
        # split, underestimating scores/attnv FLOPs and traffic).
        kv_len = self.seq
        e = self.moe_topk if self.moe_experts else 1
        ops = [
            GEMMOp("qkv", M, D, (self.n_heads + 2 * self.n_kv) * hd // tp),
            GEMMOp("scores", M * max(self.n_heads // tp, 1) // max(self.n_heads, 1),
                   hd, kv_len, weight=False),
            GEMMOp("attnv", M * max(self.n_heads // tp, 1) // max(self.n_heads, 1),
                   kv_len, hd, weight=False),
            GEMMOp("attn_out", M, self.n_heads * hd // tp, D),
            GEMMOp("mlp_in", M * e, D, 2 * F // tp),
            GEMMOp("mlp_out", M * e, F // tp, D),
        ]
        return ops

    def layer_ops_batch(self, tp, mb_tokens):
        """Vectorized `layer_ops`: `tp`/`mb_tokens` are (C,) int arrays, the
        result is a dict of (n_ops, C) int arrays M/K/N plus the static
        `weight` flags — column c reproduces layer_ops(tp[c], mb_tokens[c])
        exactly (integer semantics included)."""
        tp = np.asarray(tp, np.int64)
        M = np.asarray(mb_tokens, np.int64)
        D, F = self.d_model, self.d_ff
        hd = D // max(self.n_heads, 1)
        kv_len = np.full_like(M, self.seq)   # full context in every phase
        e = self.moe_topk if self.moe_experts else 1
        heads_tp = np.maximum(self.n_heads // tp, 1)
        m_attn = M * heads_tp // max(self.n_heads, 1)
        zeros = np.zeros_like(M)
        Ms = np.stack([M, m_attn, m_attn, M, M * e, M * e])
        Ks = np.stack([zeros + D, zeros + hd, kv_len,
                       self.n_heads * hd // tp, zeros + D, F // tp])
        Ns = np.stack([(self.n_heads + 2 * self.n_kv) * hd // tp, kv_len,
                       zeros + hd, zeros + D, 2 * F // tp, zeros + D])
        weight = (True, False, False, True, True, True)
        names = ("qkv", "scores", "attnv", "attn_out", "mlp_in", "mlp_out")
        return {"M": Ms, "K": Ks, "N": Ns, "weight": weight, "names": names}

    def flops_per_step(self) -> float:
        mult = 3.0 if self.phase == "train" else 1.0   # fwd+bwd ~ 3x fwd
        return 2.0 * self.active_params() * self.tokens_per_step() * mult

    def kv_bytes_per_layer(self) -> float:
        hd = self.d_model // max(self.n_heads, 1)
        return 2 * self.batch * self.seq * self.n_kv * hd * BYTES

    def act_bytes_per_layer(self, mb_tokens: int) -> float:
        return mb_tokens * self.d_model * BYTES


# ---------------------------------------------------------------------------
# request-level serving descriptor (ISSUE 4 tentpole; consumed by
# repro.core.serving) — one arrival batch of requests, each a prompt to
# prefill and a number of tokens to decode under continuous batching.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RequestMix:
    """Prompt/output length distribution for one serving arrival batch.

    All requests arrive at t=0 in queue order (matching
    `repro.serve.engine.ServeEngine.run`). Frozen + tuple fields so a mix is
    hashable and can key caches alongside `LLMWorkload`.
    """
    prompt_lens: Tuple[int, ...]
    out_lens: Tuple[int, ...]         # max_new_tokens per request

    def __post_init__(self):
        # coerce to tuples so list inputs keep the hashability contract
        object.__setattr__(self, "prompt_lens", tuple(self.prompt_lens))
        object.__setattr__(self, "out_lens", tuple(self.out_lens))
        if len(self.prompt_lens) != len(self.out_lens):
            raise ValueError("prompt_lens and out_lens must align")
        if not self.prompt_lens:
            raise ValueError("RequestMix needs at least one request")
        if min(self.prompt_lens) < 1 or min(self.out_lens) < 1:
            raise ValueError("prompt/output lengths must be >= 1")

    @property
    def n_requests(self) -> int:
        return len(self.prompt_lens)

    @property
    def mean_prompt(self) -> float:
        return float(np.mean(self.prompt_lens))

    @property
    def mean_out(self) -> float:
        return float(np.mean(self.out_lens))

    def total_out_tokens(self) -> int:
        return int(sum(self.out_lens))

    def context_len(self) -> int:
        """Representative mid-generation context (KV length) for sizing the
        steady-state decode step: prompt plus half the generated tokens."""
        return max(1, int(round(self.mean_prompt + 0.5 * self.mean_out)))

    @classmethod
    def uniform(cls, n_requests: int, prompt_len: int,
                out_len: int) -> "RequestMix":
        return cls((prompt_len,) * n_requests, (out_len,) * n_requests)

    @classmethod
    def sampled(cls, rng: np.random.Generator, n_requests: int,
                prompt_range: Tuple[int, int],
                out_range: Tuple[int, int]) -> "RequestMix":
        p = rng.integers(prompt_range[0], prompt_range[1] + 1, n_requests)
        o = rng.integers(out_range[0], out_range[1] + 1, n_requests)
        return cls(tuple(int(x) for x in p), tuple(int(x) for x in o))

    def as_trace(self, tenant=None):
        """Lift this one-batch mix into the timed-arrival frame: a
        `core.traces.RequestTrace` with every request at step 0 under a
        single tenant — the degenerate case `trace_schedule` reduces to
        `continuous_batch_schedule` on. Lazy import: traces layers on top
        of this module."""
        from repro.core.traces import DEFAULT_TENANT, RequestTrace
        return RequestTrace.from_mix(
            self, DEFAULT_TENANT if tenant is None else tenant)


# ---------------------------------------------------------------------------
# paper Table II benchmarks (Megatron-LM / GPT-3 / ZeRO-Infinity scalings)
# ---------------------------------------------------------------------------

def _gpt(name, params_b, layers, hidden, heads, gpus, batch) -> LLMWorkload:
    return LLMWorkload(
        name=name, n_layers=layers, d_model=hidden, n_heads=heads,
        n_kv=heads, d_ff=4 * hidden, vocab=51200, seq=2048, batch=batch,
        phase="train", gpu_budget=gpus)


GPT_BENCHMARKS: Tuple[LLMWorkload, ...] = (
    _gpt("GPT-1.7B", 1.7, 24, 2304, 24, 32, 512),
    _gpt("GPT-3.6B", 3.6, 30, 3072, 32, 64, 512),
    _gpt("GPT-7.5B", 7.5, 36, 4096, 32, 128, 512),
    _gpt("GPT-18B", 18.4, 40, 6144, 48, 256, 1024),
    _gpt("GPT-39B", 39.1, 48, 8192, 64, 512, 1536),
    _gpt("GPT-76B", 76.1, 60, 10240, 80, 1024, 1792),
    _gpt("GPT-145B", 145.6, 80, 12288, 96, 1536, 2304),
    _gpt("GPT-175B", 175.0, 96, 12288, 96, 1000, 2048),
    _gpt("GPT-310B", 310.1, 96, 16384, 128, 1920, 2160),
    _gpt("GPT-530B", 529.6, 105, 20480, 128, 2520, 2520),
    _gpt("GPT-1T", 1008.0, 128, 25600, 160, 3072, 3072),
    _gpt("GPT-2.2T", 2244.5, 192, 32768, 256, 6000, 3072),
    _gpt("GPT-4T", 4066.6, 192, 43008, 432, 12000, 5500),
    _gpt("GPT-9.6T", 9588.2, 195, 65536, 512, 30000, 10000),
    _gpt("GPT-18T", 18436.5, 240, 81920, 620, 60000, 15000),
    _gpt("GPT-32T", 32405.7, 270, 102400, 850, 100000, 20000),
)


def inference_workload(base: LLMWorkload, phase: str, batch: int = 32,
                       seq: int = 2048) -> LLMWorkload:
    return dataclasses.replace(base, phase=phase, batch=batch, seq=seq)


def from_model_config(cfg: ModelConfig, shape: ShapeConfig) -> LLMWorkload:
    """Bridge: assigned runtime architectures as DSE benchmarks."""
    heads = max(cfg.n_heads, 1)
    d_ff = cfg.d_ff
    if cfg.family in ("ssm", "hybrid") and d_ff == 0:
        d_ff = 2 * cfg.d_model      # SSD GEMM-equivalent inner width
    return LLMWorkload(
        name=cfg.name,
        n_layers=cfg.num_layers,
        d_model=cfg.d_model,
        n_heads=heads,
        n_kv=max(cfg.n_kv, 1),
        d_ff=d_ff,
        vocab=cfg.vocab,
        seq=shape.seq_len,
        batch=shape.global_batch,
        phase=shape.kind,
        moe_experts=cfg.moe.num_experts if cfg.moe else 0,
        moe_topk=cfg.moe.top_k if cfg.moe else 0,
        gpu_budget=max(1, cfg.param_count() * 8 // (80 * 2 ** 30)),
    )
