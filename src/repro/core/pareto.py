"""Pareto utilities + hypervolume for the 2-objective (maximize throughput,
minimize power) setting. Internally we work in 'maximize both' space by
negating power.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def pareto_mask(points: np.ndarray) -> np.ndarray:
    """points (N, 2) in maximize-maximize space -> boolean mask of the front."""
    n = len(points)
    mask = np.ones(n, bool)
    for i in range(n):
        if not mask[i]:
            continue
        dominated = np.all(points >= points[i], axis=1) & np.any(
            points > points[i], axis=1)
        if dominated.any():
            mask[i] = False
            continue
        dominates = np.all(points[i] >= points, axis=1) & np.any(
            points[i] > points, axis=1)
        mask[dominates] = False
        mask[i] = True
    return mask


def pareto_front(points: np.ndarray) -> np.ndarray:
    pts = np.asarray(points, float)
    return pts[pareto_mask(pts)]


def hypervolume_2d(points: np.ndarray, ref: Sequence[float]) -> float:
    """Exact 2-D hypervolume wrt reference point (maximize-maximize).
    Paper §VII: ref = (throughput 0, -peak power)."""
    pts = np.asarray(points, float)
    if len(pts) == 0:
        return 0.0
    pts = pts[(pts[:, 0] > ref[0]) & (pts[:, 1] > ref[1])]
    if len(pts) == 0:
        return 0.0
    front = pareto_front(pts)
    order = np.argsort(-front[:, 0])
    front = front[order]
    hv = 0.0
    prev_y = ref[1]
    for x, y in front:
        if y > prev_y:
            hv += (x - ref[0]) * (y - prev_y)
            prev_y = y
    return float(hv)


def to_max_space(throughput: np.ndarray, power: np.ndarray) -> np.ndarray:
    return np.stack([np.asarray(throughput, float),
                     -np.asarray(power, float)], axis=1)
