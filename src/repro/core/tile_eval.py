"""Tile-level evaluation (paper §VI-B): fixed-dataflow loop-nest model for a
GEMM tile on one core (Timeloop/MAESTRO-style, simplified to the three
canonical dataflows).

For a core with `mac` MACs arranged as a pr x pc array and an SRAM of
`buffer_kb`, a (M, K, N) GEMM tile yields:
    - compute cycles (with dataflow-dependent utilization),
    - SRAM traffic (data reuse bounded by buffer capacity),
    - the output-production interval used by the NoC estimators.

The core math lives in `evaluate_tile_batch`, which broadcasts over a
leading batch axis (DESIGN.md §4); `evaluate_tile` is the scalar wrapper.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core.design_space import floor_log2
from repro.core.workload import BYTES, GEMMOp

# dataflow codes shared with design_space.DATAFLOWS order
DATAFLOW_CODE = {"WS": 0, "IS": 1, "OS": 2}


@dataclasses.dataclass(frozen=True)
class TileResult:
    cycles: float
    util: float
    sram_read_bits: float
    sram_write_bits: float
    out_interval_cycles: float     # avg cycles between output flit batches


def _ceil_div(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return -(-np.asarray(a, np.int64) // np.asarray(b, np.int64))


def pe_dims(mac: np.ndarray):
    """Vectorized PE-array factorization: pr x pc with pr = 2^(log2(mac)//2)."""
    pr = np.int64(1) << (floor_log2(mac) // 2)
    return pr, np.maximum(np.asarray(mac, np.int64), 1) // pr


def evaluate_tile_batch(M: np.ndarray, K: np.ndarray, N: np.ndarray,
                        mac: np.ndarray, buffer_kb: np.ndarray,
                        buffer_bw: np.ndarray, dataflow_code: np.ndarray
                        ) -> Dict[str, np.ndarray]:
    """Batched tile model. All inputs broadcastable arrays; `dataflow_code`
    follows DATAFLOW_CODE (0=WS, 1=IS, 2=OS). Returns a dict of float64
    arrays: cycles, util, sram_read_bits, sram_write_bits,
    out_interval_cycles."""
    M = np.maximum(np.asarray(M, np.int64), 1)
    K = np.maximum(np.asarray(K, np.int64), 1)
    N = np.maximum(np.asarray(N, np.int64), 1)
    mac = np.asarray(mac, np.int64)
    code = np.asarray(dataflow_code, np.int64)
    M, K, N, mac, buffer_kb, buffer_bw, code = np.broadcast_arrays(
        M, K, N, mac, np.asarray(buffer_kb, np.float64),
        np.asarray(buffer_bw, np.int64), code)
    pr, pc = pe_dims(mac)

    ws, os_ = code == 0, code == 2             # IS is the select default
    # spatial mapping per dataflow: which two dims are laid across the array
    u1 = np.select([ws, os_], [K, M], default=M)          # IS: M
    u2 = np.select([ws, os_], [N, N], default=K)          # IS: K
    stream = np.select([ws, os_], [M, K], default=N)      # IS: N

    util = (np.minimum(u1, pr) / pr) * (np.minimum(u2, pc) / pc)
    t1, t2 = _ceil_div(u1, pr), _ceil_div(u2, pc)
    compute_cycles = (t1 * t2).astype(np.float64) * stream

    # SRAM traffic: stationary operand loaded once; streaming operand
    # re-read once per stationary tile swap
    Mf, Kf, Nf = (M.astype(np.float64), K.astype(np.float64),
                  N.astype(np.float64))
    reads = np.select(
        [ws, os_],
        [Kf * Nf + Mf * Kf * t2, Mf * Kf * t2 + Kf * Nf * t1],
        default=Mf * Kf + Kf * Nf * t1)
    writes = np.select([ws, os_], [Mf * Nf * t1, Mf * Nf],
                       default=Mf * Nf * t2)

    # buffer capacity check: if the stationary tile exceeds SRAM, extra
    # re-fetches (capacity factor)
    buf_bits = buffer_kb * 1024 * 8
    stat1 = np.select([ws, os_], [np.minimum(K, pr), np.minimum(M, pr)],
                      default=np.minimum(M, pr))
    stat2 = np.select([ws, os_], [np.minimum(N, pc), np.minimum(N, pc)],
                      default=np.minimum(K, pc))
    stat_bits = (stat1 * stat2).astype(np.float64) * BYTES * 8
    cap_factor = np.maximum(1.0, stat_bits / np.maximum(buf_bits, 1))

    read_bits = reads * BYTES * 8 * cap_factor
    write_bits = writes * BYTES * 8
    mem_cycles = (read_bits + write_bits) / np.maximum(buffer_bw, 1)

    cycles = np.maximum(compute_cycles, mem_cycles)
    n_out_batches = np.maximum(t1 * t2, 1)
    return {
        "cycles": cycles,
        "util": util.astype(np.float64),
        "sram_read_bits": read_bits,
        "sram_write_bits": write_bits,
        "out_interval_cycles": cycles / n_out_batches,
    }


# NumPy oracle alias for the jitted pipeline (repro.core.eval_compiled):
# the implementation above IS the reference; the compiled path mirrors it
# op for op and is property-tested bit-exact against this name.
evaluate_tile_batch_ref = evaluate_tile_batch


def evaluate_tile(op: GEMMOp, mac: int, buffer_kb: float, buffer_bw: int,
                  dataflow: str) -> TileResult:
    """Scalar wrapper: delegates to the batched kernel with a length-1 axis."""
    r = evaluate_tile_batch(np.asarray([op.M]), np.asarray([op.K]),
                            np.asarray([op.N]), np.asarray([mac]),
                            np.asarray([buffer_kb], np.float64),
                            np.asarray([buffer_bw]),
                            np.asarray([DATAFLOW_CODE[dataflow]]))
    return TileResult(
        cycles=float(r["cycles"][0]),
        util=float(r["util"][0]),
        sram_read_bits=float(r["sram_read_bits"][0]),
        sram_write_bits=float(r["sram_write_bits"][0]),
        out_interval_cycles=float(r["out_interval_cycles"][0]),
    )
