"""Tile-level evaluation (paper §VI-B): fixed-dataflow loop-nest model for a
GEMM tile on one core (Timeloop/MAESTRO-style, simplified to the three
canonical dataflows).

For a core with `mac` MACs arranged as a pr x pc array and an SRAM of
`buffer_kb`, a (M, K, N) GEMM tile yields:
    - compute cycles (with dataflow-dependent utilization),
    - SRAM traffic (data reuse bounded by buffer capacity),
    - the output-production interval used by the NoC estimators.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.workload import BYTES, GEMMOp


@dataclasses.dataclass(frozen=True)
class TileResult:
    cycles: float
    util: float
    sram_read_bits: float
    sram_write_bits: float
    out_interval_cycles: float     # avg cycles between output flit batches


def _pe_dims(mac: int):
    pr = 2 ** (int(math.log2(mac)) // 2)
    return pr, mac // pr


def evaluate_tile(op: GEMMOp, mac: int, buffer_kb: float, buffer_bw: int,
                  dataflow: str) -> TileResult:
    M, K, N = max(op.M, 1), max(op.K, 1), max(op.N, 1)
    pr, pc = _pe_dims(mac)

    # spatial mapping per dataflow: which two dims are laid across the array
    if dataflow == "WS":        # weights (K x N) stationary
        u1, u2, stream = K, N, M
    elif dataflow == "OS":      # outputs (M x N) stationary
        u1, u2, stream = M, N, K
    else:                       # IS: inputs (M x K) stationary
        u1, u2, stream = M, K, N

    util = (min(u1, pr) / pr) * (min(u2, pc) / pc)
    lanes = min(u1, pr) * min(u2, pc)
    compute_cycles = math.ceil(u1 / pr) * math.ceil(u2 / pc) * stream

    # SRAM traffic: stationary operand loaded ceil(stream-tiles) times less;
    # streaming operand re-read once per stationary tile swap
    t1, t2 = math.ceil(u1 / pr), math.ceil(u2 / pc)
    if dataflow == "WS":
        reads = (K * N            # weights once
                 + M * K * t2     # acts re-read per N-tile
                 + 0)
        writes = M * N * t1       # partial sums per K-tile
    elif dataflow == "OS":
        reads = (M * K * t2 + K * N * t1)
        writes = M * N
    else:  # IS
        reads = (M * K + K * N * t1)
        writes = M * N * t2

    # buffer capacity check: if the stationary tile exceeds SRAM, extra
    # re-fetches (capacity factor)
    buf_bits = buffer_kb * 1024 * 8
    stat_bits = {"WS": min(K, pr) * min(N, pc),
                 "OS": min(M, pr) * min(N, pc),
                 "IS": min(M, pr) * min(K, pc)}[dataflow] * BYTES * 8
    cap_factor = max(1.0, stat_bits / max(buf_bits, 1))

    read_bits = reads * BYTES * 8 * cap_factor
    write_bits = writes * BYTES * 8
    mem_cycles = (read_bits + write_bits) / max(buffer_bw, 1)

    cycles = max(compute_cycles, mem_cycles)
    n_out_batches = max(t1 * t2, 1)
    return TileResult(
        cycles=float(cycles),
        util=float(util),
        sram_read_bits=float(read_bits),
        sram_write_bits=float(write_bits),
        out_interval_cycles=float(cycles / n_out_batches),
    )
