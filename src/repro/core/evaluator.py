"""Hierarchical Evaluation Engine (paper §VI, Fig. 6).

evaluate_design(design, workload, fidelity) walks tile -> op -> chunk level
and searches the parallel-strategy space (TP x DP x PP x micro-batch),
returning the best-throughput feasible (throughput, power) point. It is the
scalar *reference* path: explicit ChunkGraphs, per-graph latency through the
fidelity backend's `chunk_latency`.

evaluate_design_batch(designs, workload, fidelity) dispatches to the
fidelity backend registry (repro.core.fidelity, DESIGN.md §4b): every
registered fidelity — analytical closed form, padded-graph GNN, lockstep
simulator — scores the whole flattened (design, strategy) candidate axis in
one array pass. There is no scalar per-design fallback; an unknown fidelity
raises with the registered list.

Fidelities (paper §VII: f1 = analytical, f0 = GNN; CA-sim for validation):
    "analytical"  fast equivalent-bandwidth NoC model
    "gnn"         GNN congestion model (needs trained params)
    "sim"         cycle-approximate NoC simulator (ground truth)

All entry points share a cross-call eval cache keyed by
(design, workload, fidelity, system size, params version) so repeated
explorer visits to the same point never recompile or re-evaluate
(DESIGN.md §6).
"""
from __future__ import annotations

import hashlib
import itertools
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.core import components as C
from repro.core.evalcache import (
    DiskSegmentEvalCache,
    EvalCacheBackend,
    InMemoryEvalCache,
)
from repro.core.chunk_eval import evaluate_step
from repro.core.compiler import (
    ChunkGraph,
    compile_chunk,
    enumerate_strategies,
    strategy_sort_key,
)
from repro.core.compiler import pinned_resource_ok as \
    compiler_pinned_resource_ok
from repro.core.design_space import DesignBatch, WSCDesign
from repro.core.fidelity import (
    EvalResult,
    FidelityBackend,
    get_backend,
    registered_backends,
)
from repro.core.workload import LLMWorkload

H100_AREA_MM2 = 814.0

_strategy_order = strategy_sort_key        # kept name: search-order heuristic

Fidelity = Union[str, FidelityBackend]


def wafers_for_budget(design: WSCDesign, wl: LLMWorkload) -> int:
    """Area-matched system size: same total silicon as the GPU baseline
    (paper: 'total area of the WSCs consistent with the corresponding number
    of GPUs')."""
    total = wl.gpu_budget * H100_AREA_MM2
    return max(1, round(total / max(design.wafer_area_mm2(), 1.0)))


def _wafers_for_budget_batch(geom: DesignBatch, wl: LLMWorkload) -> np.ndarray:
    total = wl.gpu_budget * H100_AREA_MM2
    return np.maximum(
        1, np.round(total / np.maximum(geom.wafer_area_mm2, 1.0))
    ).astype(np.int64)


# ---------------------------------------------------------------------------
# cross-call eval cache (DESIGN.md §6/§11) — replaces the old per-call
# compile_cache: WSCDesign and LLMWorkload are frozen/hashable, so the
# full evaluation outcome is memoized across explorer iterations. The
# store itself is a pluggable `EvalCacheBackend` (repro.core.evalcache):
# the default is the bounded in-memory LRU; fleet workers install a
# `DiskSegmentEvalCache` so concurrent workers and successive campaigns
# share evaluations through a common cache directory.
# ---------------------------------------------------------------------------

_EVAL_CACHE_MAX = 100_000
_BACKEND: EvalCacheBackend = InMemoryEvalCache(max_entries=_EVAL_CACHE_MAX)

# GNN params are unhashable pytrees, so cache keys carry an explicit
# version element per params object. Two mechanisms, one pin table:
#
#  * `gnn_params_token` — process-local monotonic counter. The params are
#    pinned (strong ref) while tokenized, so a live object's id cannot be
#    reused; once a pin is evicted its token is *retired* — the counter
#    never hands it out again — so a new object reusing the freed id can
#    never alias the old object's cache entries (the failure mode of the
#    previous id()-keyed scheme).
#  * `gnn_params_digest` — content hash of the pytree's array leaves,
#    memoized on the same pin. This is what cache KEYS use: digests are
#    stable across processes (required by the shared disk backend, where a
#    per-process counter would alias entries between workers) and across
#    re-pins, while calibration replacing the pytree still lands in a
#    fresh namespace because the content changed.
_PARAMS_TOKENS: Dict[int, Tuple[int, str, object]] = {}
_PARAMS_TOKENS_MAX = 16
_params_counter = itertools.count(1)


def _digest_params(gnn_params) -> str:
    h = hashlib.sha1()
    leaves, _ = jax.tree_util.tree_flatten(gnn_params)
    for leaf in leaves:
        a = np.asarray(leaf)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


def _pin_params(gnn_params) -> Tuple[int, str]:
    pid = id(gnn_params)
    entry = _PARAMS_TOKENS.get(pid)
    if entry is None:
        if len(_PARAMS_TOKENS) >= _PARAMS_TOKENS_MAX:
            _PARAMS_TOKENS.pop(next(iter(_PARAMS_TOKENS)))
        entry = (next(_params_counter), _digest_params(gnn_params),
                 gnn_params)
        _PARAMS_TOKENS[pid] = entry
    return entry[0], entry[1]


def gnn_params_token(gnn_params) -> Optional[int]:
    """Process-local monotonic version token for a params pytree
    (None -> None). A params object keeps its token for as long as it stays
    pinned; calling this after mutating-and-replacing params (e.g. online
    calibration) naturally yields a new token for the new object."""
    if gnn_params is None:
        return None
    return _pin_params(gnn_params)[0]


def gnn_params_digest(gnn_params) -> Optional[str]:
    """Content digest of a params pytree (None -> None): stable across
    processes and runs, so it is the params element of eval-cache keys —
    a worker fleet sharing a disk cache agrees on the namespace of every
    entry (DESIGN.md §11)."""
    if gnn_params is None:
        return None
    return _pin_params(gnn_params)[1]


def _cache_key(design: WSCDesign, wl: LLMWorkload, fidelity: str,
               n_wafers: int, max_strategies: int, gnn_params,
               strategy=None) -> Tuple:
    # Grid-mode keys keep the historical 6-tuple shape so existing disk
    # caches stay valid; joint mode (pinned Strategy, frozen/hashable)
    # appends the strategy so the same design under two strategies never
    # aliases one entry.
    if strategy is None:
        return (design, wl, fidelity, n_wafers, max_strategies,
                gnn_params_digest(gnn_params))
    return (design, wl, fidelity, n_wafers, max_strategies,
            gnn_params_digest(gnn_params), strategy)


def get_eval_cache_backend() -> EvalCacheBackend:
    return _BACKEND


def set_eval_cache_backend(backend: EvalCacheBackend) -> EvalCacheBackend:
    """Install a cache backend (e.g. a fleet worker pointing a
    `DiskSegmentEvalCache` at the shared cache directory). Returns the
    previous backend so callers can restore it."""
    global _BACKEND
    prev = _BACKEND
    _BACKEND = backend
    return prev


def configure_eval_cache(cache_dir: Optional[str] = None,
                         max_entries: int = _EVAL_CACHE_MAX
                         ) -> EvalCacheBackend:
    """Convenience: install the disk-segment backend rooted at `cache_dir`
    (shared, persistent), or a fresh bounded in-memory LRU when
    `cache_dir` is None."""
    if cache_dir is None:
        set_eval_cache_backend(InMemoryEvalCache(max_entries=max_entries))
    else:
        set_eval_cache_backend(
            DiskSegmentEvalCache(cache_dir, max_entries=max_entries))
    return _BACKEND


def clear_eval_cache() -> None:
    _BACKEND.clear()
    _PARAMS_TOKENS.clear()


def eval_cache_stats() -> Dict[str, int]:
    # `entries` == `size` (live cache entries); both names kept — `size`
    # predates campaign reporting, `entries` is the documented key campaign
    # traces diff per fidelity stage (DESIGN.md §9). Backends add
    # `evictions` (LRU) and, for the disk backend, segment/merge counters.
    s = _BACKEND.stats()
    s["size"] = s["entries"]
    return s


# ---------------------------------------------------------------------------
# scalar reference path (graph-based)
# ---------------------------------------------------------------------------


def evaluate_design(design: WSCDesign, wl: LLMWorkload,
                    fidelity: Fidelity = "analytical",
                    gnn_params: Optional[Dict] = None,
                    n_wafers: Optional[int] = None,
                    max_strategies: int = 24) -> EvalResult:
    backend = get_backend(fidelity)
    nw = n_wafers if n_wafers is not None else wafers_for_budget(design, wl)
    key = _cache_key(design, wl, backend.name, nw, max_strategies,
                     gnn_params)
    hit = _BACKEND.get(key)
    if hit is not None:
        return hit

    # memory_model="grid": the scalar path must stay element-identical to
    # the batched grid (`feasible_strategy_arrays`), which bakes the frozen
    # legacy memory check; the recompute-aware v2 model is the joint path.
    strategies = enumerate_strategies(design, wl, n_wafers=nw,
                                      memory_model="grid")
    strategies = sorted(strategies, key=_strategy_order)[:max_strategies]

    graph_cache: Dict[Tuple[int, int, int], Tuple[ChunkGraph, float]] = {}
    best: Optional[EvalResult] = None
    for s in strategies:
        mb_count = s.microbatches if wl.phase == "train" else 1
        mb_tokens = max(wl.tokens_per_step() // (s.dp * mb_count), 1)
        cores_per_chunk = max(design.total_cores() * nw // s.chunks(), 1)
        gkey = (s.tp, mb_tokens, cores_per_chunk)
        if gkey not in graph_cache:
            graph = compile_chunk(design, wl, s.tp, mb_tokens,
                                  cores_per_chunk)
            lat = backend.chunk_latency(graph, design, gnn_params)
            graph_cache[gkey] = (graph, lat)
        graph, lat = graph_cache[gkey]
        step = evaluate_step(design, wl, s, lat, graph, nw)
        if not step.feasible:
            continue
        cand = EvalResult(step.throughput, step.power_w, s, step, nw, True)
        if best is None or cand.throughput > best.throughput:
            best = cand
    if best is None:
        best = EvalResult(0.0, float("inf"), None, None, nw, False,
                          "no_feasible_strategy")
    return _BACKEND.put(key, best)


# ---------------------------------------------------------------------------
# batched path: registry dispatch (DESIGN.md §4/§4b)
# ---------------------------------------------------------------------------


def evaluate_design_batch(designs: Sequence[WSCDesign], wl: LLMWorkload,
                          fidelity: Fidelity = "analytical",
                          gnn_params: Optional[Dict] = None,
                          n_wafers: Optional[Union[int, np.ndarray]] = None,
                          max_strategies: int = 24) -> List[EvalResult]:
    """Evaluate N designs at once through the fidelity backend registry:
    every fidelity runs its vectorized pipeline over the flattened
    (design, strategy) candidate axis. Cache hits are filtered out first;
    only the misses reach the backend."""
    backend = get_backend(fidelity)
    designs = list(designs)
    if not designs:
        return []

    geom0 = DesignBatch.from_designs(designs)
    if n_wafers is None:
        nw = _wafers_for_budget_batch(geom0, wl)
    else:
        nw = np.broadcast_to(np.asarray(n_wafers, np.int64),
                             (len(designs),)).copy()

    keys = [_cache_key(d, wl, backend.name, int(nw[i]), max_strategies,
                       gnn_params)
            for i, d in enumerate(designs)]
    results: List[Optional[EvalResult]] = [_BACKEND.get(k) for k in keys]
    todo = [i for i, r in enumerate(results) if r is None]
    if todo:
        fresh = backend.evaluate_batch(geom0.take(np.asarray(todo)), wl,
                                       nw[todo], max_strategies, gnn_params)
        for i, r in zip(todo, fresh):
            results[i] = r
        # one batched cache write (single segment append on disk backends)
        _BACKEND.set_many([(keys[i], results[i]) for i in todo])
    return results            # type: ignore[return-value]


def evaluate_pool_fused(pool_designs: Sequence[WSCDesign], wl: LLMWorkload,
                        js_dev, q_eff: int,
                        gnn_params: Optional[Dict] = None,
                        n_wafers: Optional[int] = None,
                        max_strategies: int = 24
                        ) -> Tuple[List[int], List[EvalResult]]:
    """Fused propose→evaluate for the analytical fidelity (DESIGN.md §12):
    `js_dev` is the device-resident padded index vector the compiled
    q-EHVI scan produced (`mfmobo._acquire_batch_device`); the compiled
    evaluator gathers those candidate-pool rows and scores them inside the
    same XLA dispatch chain, so the host never synchronizes between
    proposal and evaluation. Returns (first q_eff pick indices, their
    EvalResults).

    Cache protocol (same counters as `evaluate_design_batch`): one `get`
    per pick — hits keep the cached result, misses take the fused
    program's rows — then one batched `set_many` write for the misses.
    The evaluation itself is NOT skipped on hits (it already ran inside
    the fused program); that is the documented consulted-vs-bypassed
    trade: re-scoring q rows in-program is cheaper than a host round-trip
    to decide whether to score them. Values are interchangeable because
    the compiled pipeline is bit-identical to the reference."""
    from repro.core import eval_compiled

    pool = list(pool_designs)
    geom = DesignBatch.from_designs(pool)
    if n_wafers is None:
        nw = _wafers_for_budget_batch(geom, wl)
    else:
        nw = np.broadcast_to(np.asarray(n_wafers, np.int64),
                             (len(pool),)).copy()
    pending = eval_compiled.dispatch_fused_eval(
        geom, wl, nw, js_dev, max_strategies=max_strategies)
    # one host sync for the indices — the fused evaluation is already
    # enqueued behind the acquire scan by the time this completes
    js_all = np.asarray(js_dev)
    js = [int(j) for j in js_all[:q_eff]]
    fresh = pending.finish(nw[js_all], q_eff)
    keys = [_cache_key(pool[j], wl, "analytical", int(nw[j]),
                       max_strategies, gnn_params) for j in js]
    results: List[EvalResult] = []
    new = []
    for k, r in zip(keys, fresh):
        hit = _BACKEND.get(k)
        if hit is None:
            results.append(r)
            new.append((k, r))
        else:
            results.append(hit)
    if new:
        _BACKEND.set_many(new)
    return js, results


# ---------------------------------------------------------------------------
# joint (strategy-pinned) path: strategy–architecture co-exploration
# (DESIGN.md §13) — each point carries its own Strategy, no grid argmin
# ---------------------------------------------------------------------------


def evaluate_joint_batch(points, wl: LLMWorkload,
                         fidelity: Fidelity = "analytical",
                         gnn_params: Optional[Dict] = None,
                         n_wafers: Optional[Union[int, np.ndarray]] = None,
                         max_strategies: int = 24) -> List[EvalResult]:
    """Evaluate N (design, strategy) joint points at once: each design is
    scored under its pinned Strategy (`JointDesign.strategy`), skipping the
    per-design strategy-grid argmin. Same cache protocol as
    `evaluate_design_batch`; keys carry the pinned Strategy so a design
    evaluated under two strategies occupies two entries."""
    backend = get_backend(fidelity)
    points = list(points)
    if not points:
        return []
    designs = [p.design for p in points]
    strategies = [p.strategy for p in points]

    geom0 = DesignBatch.from_designs(designs)
    if n_wafers is None:
        nw = _wafers_for_budget_batch(geom0, wl)
    else:
        nw = np.broadcast_to(np.asarray(n_wafers, np.int64),
                             (len(points),)).copy()

    keys = [_cache_key(d, wl, backend.name, int(nw[i]), max_strategies,
                       gnn_params, strategy=strategies[i])
            for i, d in enumerate(designs)]
    results: List[Optional[EvalResult]] = [_BACKEND.get(k) for k in keys]
    todo = [i for i, r in enumerate(results) if r is None]
    if todo:
        fresh = backend.evaluate_batch(
            geom0.take(np.asarray(todo)), wl, nw[todo], max_strategies,
            gnn_params, strategies=[strategies[i] for i in todo])
        for i, r in zip(todo, fresh):
            results[i] = r
        _BACKEND.set_many([(keys[i], results[i]) for i in todo])
    return results            # type: ignore[return-value]


def evaluate_pool_fused_joint(pool_points, wl: LLMWorkload,
                              js_dev, q_eff: int,
                              gnn_params: Optional[Dict] = None,
                              n_wafers: Optional[int] = None,
                              max_strategies: int = 24
                              ) -> Tuple[List[int], List[EvalResult]]:
    """Joint-mode counterpart of `evaluate_pool_fused`: the candidate pool
    is (design, strategy) points, and the fused program gathers both the
    geometry rows and the pinned strategy columns by the device-resident
    pick indices. Same get-per-pick / batched set_many cache protocol."""
    from repro.core import eval_compiled

    points = list(pool_points)
    designs = [p.design for p in points]
    strategies = [p.strategy for p in points]
    geom = DesignBatch.from_designs(designs)
    if n_wafers is None:
        nw = _wafers_for_budget_batch(geom, wl)
    else:
        nw = np.broadcast_to(np.asarray(n_wafers, np.int64),
                             (len(points),)).copy()
    pending = eval_compiled.dispatch_fused_eval_pinned(
        geom, wl, nw, strategies, js_dev, max_strategies=max_strategies)
    js_all = np.asarray(js_dev)
    js = [int(j) for j in js_all[:q_eff]]
    # grid resource-fit gate over the pool, gathered to the pick order —
    # the same host-computed mask the batch pinned path applies
    cols = eval_compiled.strategy_arrays(strategies)
    res_ok = compiler_pinned_resource_ok(wl, geom, nw, cols[0], cols[1],
                                         cols[2], cols[3])[js_all]
    fresh = pending.finish(nw[js_all], [strategies[j] for j in js_all],
                           q_eff, res_ok=res_ok)
    keys = [_cache_key(designs[j], wl, "analytical", int(nw[j]),
                       max_strategies, gnn_params,
                       strategy=strategies[j]) for j in js]
    results: List[EvalResult] = []
    new = []
    for k, r in zip(keys, fresh):
        hit = _BACKEND.get(k)
        if hit is None:
            results.append(r)
            new.append((k, r))
        else:
            results.append(hit)
    if new:
        _BACKEND.set_many(new)
    return js, results


def evaluate_objectives(design: WSCDesign, wl: LLMWorkload,
                        fidelity: Fidelity = "analytical",
                        gnn_params: Optional[Dict] = None
                        ) -> Tuple[float, float]:
    """(throughput, power) pair for the explorer; infeasible -> (0, peak)."""
    r = evaluate_design(design, wl, fidelity=fidelity, gnn_params=gnn_params)
    if not r.feasible:
        return 0.0, C.WAFER_POWER_W
    return r.throughput, r.power_w / max(r.n_wafers, 1)


def evaluate_objectives_batch(designs: Sequence[WSCDesign], wl: LLMWorkload,
                              fidelity: Fidelity = "analytical",
                              gnn_params: Optional[Dict] = None
                              ) -> List[Tuple[float, float]]:
    out = []
    for r in evaluate_design_batch(designs, wl, fidelity=fidelity,
                                   gnn_params=gnn_params):
        if not r.feasible:
            out.append((0.0, C.WAFER_POWER_W))
        else:
            out.append((r.throughput, r.power_w / max(r.n_wafers, 1)))
    return out


def evaluate_serving_batch(designs: Sequence[WSCDesign],
                           wl_base: LLMWorkload, mix, slo, **kw):
    """Request-level serving metrics (TTFT / TPOT / SLO goodput) for N
    designs through the fidelity registry — the serving counterpart of
    `evaluate_design_batch`. Thin forwarder to `repro.core.serving`
    (imported lazily: serving composes this module's batched per-step
    evaluations, so a top-level import would be circular)."""
    from repro.core import serving
    return serving.evaluate_serving_batch(designs, wl_base, mix, slo, **kw)


def evaluate_trace_serving_batch(designs, wl_base: LLMWorkload, trace,
                                 **kw):
    """Trace-driven multi-tenant serving metrics (per-tenant SLO goodput,
    worst-window goodput, admission/routing policies) for N designs — the
    timed-arrival counterpart of `evaluate_serving_batch`. Thin forwarder
    to `repro.core.traces` (lazy import, same layering as serving)."""
    from repro.core import traces
    return traces.evaluate_trace_serving_batch(designs, wl_base, trace,
                                               **kw)


def serving_objectives(wl_base: LLMWorkload, mix, slo, **kw):
    """Batch-aware (SLO goodput, power) explorer objective — forwarder to
    `repro.core.serving.serving_objectives` (lazy import, see above)."""
    from repro.core import serving
    return serving.serving_objectives(wl_base, mix, slo, **kw)


def batched_objectives(wl: LLMWorkload, fidelity: Fidelity = "analytical",
                       gnn_params: Optional[Dict] = None):
    """Batch-aware (throughput, power-per-wafer) objective for the
    explorer. Subsumed by the campaign Objectives protocol — this is now a
    thin constructor for `repro.explore.objectives.EvaluatorObjective`
    (lazy import: repro.explore layers on top of this module). `fidelity`
    may be a registered name or a FidelityBackend instance."""
    from repro.explore.objectives import EvaluatorObjective
    return EvaluatorObjective(wl, fidelity, gnn_params=gnn_params)


__all__ = [
    "EvalResult", "Fidelity", "batched_objectives", "clear_eval_cache",
    "configure_eval_cache", "eval_cache_stats", "evaluate_design",
    "evaluate_design_batch", "evaluate_joint_batch", "evaluate_objectives",
    "evaluate_objectives_batch", "evaluate_pool_fused",
    "evaluate_pool_fused_joint", "evaluate_serving_batch",
    "evaluate_trace_serving_batch",
    "get_backend", "get_eval_cache_backend", "gnn_params_digest",
    "gnn_params_token", "registered_backends", "serving_objectives",
    "set_eval_cache_backend", "wafers_for_budget",
]
