"""Hierarchical Evaluation Engine (paper §VI, Fig. 6).

evaluate_design(design, workload, fidelity) walks tile -> op -> chunk level
and searches the parallel-strategy space (TP x DP x PP x micro-batch),
returning the best-throughput feasible (throughput, power) point.

Fidelities (paper §VII: f1 = analytical, f0 = GNN; CA-sim for validation):
    "analytical"  fast equivalent-bandwidth NoC model
    "gnn"         GNN congestion model (needs trained params)
    "sim"         cycle-approximate NoC simulator (ground truth, slow)
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, List, Optional, Tuple

from repro.core import components as C
from repro.core.chunk_eval import StepResult, evaluate_step
from repro.core.compiler import (
    ChunkGraph,
    Strategy,
    compile_chunk,
    enumerate_strategies,
)
from repro.core.design_space import WSCDesign
from repro.core.noc_analytical import chunk_latency_cycles
from repro.core.noc_gnn import chunk_latency_cycles_gnn
from repro.core.noc_sim import chunk_latency_cycles_sim
from repro.core.workload import LLMWorkload

H100_AREA_MM2 = 814.0


@dataclasses.dataclass
class EvalResult:
    throughput: float
    power_w: float
    strategy: Optional[Strategy]
    step: Optional[StepResult]
    n_wafers: int
    feasible: bool
    reason: str = ""


def wafers_for_budget(design: WSCDesign, wl: LLMWorkload) -> int:
    """Area-matched system size: same total silicon as the GPU baseline
    (paper: 'total area of the WSCs consistent with the corresponding number
    of GPUs')."""
    total = wl.gpu_budget * H100_AREA_MM2
    return max(1, round(total / max(design.wafer_area_mm2(), 1.0)))


def _strategy_order(s: Strategy) -> Tuple:
    # prefer modest TP, deep pipelines last; purely a search-order heuristic
    return (abs(math.log2(max(s.tp, 1)) - 5), s.pp, -s.microbatches)


def evaluate_design(design: WSCDesign, wl: LLMWorkload,
                    fidelity: str = "analytical",
                    gnn_params: Optional[Dict] = None,
                    n_wafers: Optional[int] = None,
                    max_strategies: int = 24) -> EvalResult:
    nw = n_wafers if n_wafers is not None else wafers_for_budget(design, wl)
    strategies = enumerate_strategies(design, wl, n_wafers=nw)
    strategies = sorted(strategies, key=_strategy_order)[:max_strategies]

    compile_cache: Dict[Tuple[int, int, int], Tuple[ChunkGraph, float]] = {}
    best: Optional[EvalResult] = None
    for s in strategies:
        mb_count = s.microbatches if wl.phase == "train" else 1
        mb_tokens = max(wl.tokens_per_step() // (s.dp * mb_count), 1)
        cores_per_chunk = max(design.total_cores() * nw // s.chunks(), 1)
        key = (s.tp, mb_tokens, cores_per_chunk)
        if key not in compile_cache:
            graph = compile_chunk(design, wl, s.tp, mb_tokens,
                                  cores_per_chunk)
            if fidelity == "sim":
                lat = chunk_latency_cycles_sim(graph, design)
            elif fidelity == "gnn" and gnn_params is not None:
                lat = chunk_latency_cycles_gnn(gnn_params, graph, design)
            else:
                lat = chunk_latency_cycles(graph, design)
            compile_cache[key] = (graph, lat)
        graph, lat = compile_cache[key]
        step = evaluate_step(design, wl, s, lat, graph, nw)
        if not step.feasible:
            continue
        cand = EvalResult(step.throughput, step.power_w, s, step, nw, True)
        if best is None or cand.throughput > best.throughput:
            best = cand
    if best is None:
        return EvalResult(0.0, float("inf"), None, None, nw, False,
                          "no_feasible_strategy")
    return best


def evaluate_objectives(design: WSCDesign, wl: LLMWorkload,
                        fidelity: str = "analytical",
                        gnn_params: Optional[Dict] = None
                        ) -> Tuple[float, float]:
    """(throughput, power) pair for the explorer; infeasible -> (0, peak)."""
    r = evaluate_design(design, wl, fidelity=fidelity, gnn_params=gnn_params)
    if not r.feasible:
        return 0.0, C.WAFER_POWER_W
    return r.throughput, r.power_w / max(r.n_wafers, 1)
