"""Hierarchical Evaluation Engine (paper §VI, Fig. 6).

evaluate_design(design, workload, fidelity) walks tile -> op -> chunk level
and searches the parallel-strategy space (TP x DP x PP x micro-batch),
returning the best-throughput feasible (throughput, power) point.

evaluate_design_batch(designs, workload, fidelity) is the batched backend
(DESIGN.md §4): it flattens every design's strategy list onto one
(design, strategy) candidate axis and scores all analytical-fidelity
candidates in a single vectorized NumPy pass — no ChunkGraph objects, no
per-candidate Python loops — then reduces to the per-design best feasible
point. Non-analytical fidelities (GNN / simulator) need explicit graphs and
fall back to the scalar path per design.

Fidelities (paper §VII: f1 = analytical, f0 = GNN; CA-sim for validation):
    "analytical"  fast equivalent-bandwidth NoC model
    "gnn"         GNN congestion model (needs trained params)
    "sim"         cycle-approximate NoC simulator (ground truth, slow)

All entry points share a cross-call eval cache keyed by
(design, workload, fidelity, system size) so repeated explorer visits to the
same point never recompile or re-evaluate (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import components as C
from repro.core.chunk_eval import (
    StepResult,
    evaluate_step,
    evaluate_step_batch,
    step_result_at,
)
from repro.core.compiler import (
    ChunkGraph,
    Strategy,
    compile_chunk,
    enumerate_strategies,
    feasible_strategy_arrays,
    grid_for_batch,
    strategy_sort_key,
)
from repro.core.design_space import DesignBatch, WSCDesign
from repro.core.noc_analytical import (
    chunk_latency_cycles,
    chunk_latency_cycles_closed,
    row_allgather_byte_hops,
)
from repro.core.noc_gnn import chunk_latency_cycles_gnn
from repro.core.noc_sim import chunk_latency_cycles_sim
from repro.core.tile_eval import evaluate_tile_batch
from repro.core.workload import BYTES, LLMWorkload

H100_AREA_MM2 = 814.0

_strategy_order = strategy_sort_key        # kept name: search-order heuristic


@dataclasses.dataclass
class EvalResult:
    throughput: float
    power_w: float
    strategy: Optional[Strategy]
    step: Optional[StepResult]
    n_wafers: int
    feasible: bool
    reason: str = ""


def wafers_for_budget(design: WSCDesign, wl: LLMWorkload) -> int:
    """Area-matched system size: same total silicon as the GPU baseline
    (paper: 'total area of the WSCs consistent with the corresponding number
    of GPUs')."""
    total = wl.gpu_budget * H100_AREA_MM2
    return max(1, round(total / max(design.wafer_area_mm2(), 1.0)))


# ---------------------------------------------------------------------------
# cross-call eval cache (DESIGN.md §6) — replaces the old per-call
# compile_cache: WSCDesign and LLMWorkload are frozen/hashable, so the
# full evaluation outcome is memoized across explorer iterations.
# ---------------------------------------------------------------------------

_EVAL_CACHE: Dict[Tuple, EvalResult] = {}
_EVAL_CACHE_MAX = 100_000
_CACHE_STATS = {"hits": 0, "misses": 0}
_PINNED_PARAMS: Dict[int, object] = {}   # id -> params, kept alive so the
                                         # id()-based cache key stays unique
_PINNED_PARAMS_MAX = 16


def _cache_key(design: WSCDesign, wl: LLMWorkload, fidelity: str,
               n_wafers: int, max_strategies: int, gnn_params) -> Tuple:
    if gnn_params is None:
        gid = None
    else:
        gid = id(gnn_params)
        if gid not in _PINNED_PARAMS and \
                len(_PINNED_PARAMS) >= _PINNED_PARAMS_MAX:
            # unpinning frees the old params object, so its id may be
            # reused — drop every cache entry keyed by it first
            old = next(iter(_PINNED_PARAMS))
            _PINNED_PARAMS.pop(old)
            for k in [k for k in _EVAL_CACHE if k[-1] == old]:
                _EVAL_CACHE.pop(k)
        _PINNED_PARAMS.setdefault(gid, gnn_params)
    return (design, wl, fidelity, n_wafers, max_strategies, gid)


def _cache_put(key: Tuple, value: EvalResult) -> EvalResult:
    if len(_EVAL_CACHE) >= _EVAL_CACHE_MAX:
        _EVAL_CACHE.pop(next(iter(_EVAL_CACHE)))
    _EVAL_CACHE[key] = value
    return value


def clear_eval_cache() -> None:
    _EVAL_CACHE.clear()
    _PINNED_PARAMS.clear()
    _CACHE_STATS["hits"] = _CACHE_STATS["misses"] = 0


def eval_cache_stats() -> Dict[str, int]:
    return dict(_CACHE_STATS, size=len(_EVAL_CACHE))


# ---------------------------------------------------------------------------
# scalar reference path (graph-based; also the only path for gnn/sim)
# ---------------------------------------------------------------------------


def evaluate_design(design: WSCDesign, wl: LLMWorkload,
                    fidelity: str = "analytical",
                    gnn_params: Optional[Dict] = None,
                    n_wafers: Optional[int] = None,
                    max_strategies: int = 24) -> EvalResult:
    nw = n_wafers if n_wafers is not None else wafers_for_budget(design, wl)
    key = _cache_key(design, wl, fidelity, nw, max_strategies, gnn_params)
    hit = _EVAL_CACHE.get(key)
    if hit is not None:
        _CACHE_STATS["hits"] += 1
        return hit
    _CACHE_STATS["misses"] += 1

    strategies = enumerate_strategies(design, wl, n_wafers=nw)
    strategies = sorted(strategies, key=_strategy_order)[:max_strategies]

    graph_cache: Dict[Tuple[int, int, int], Tuple[ChunkGraph, float]] = {}
    best: Optional[EvalResult] = None
    for s in strategies:
        mb_count = s.microbatches if wl.phase == "train" else 1
        mb_tokens = max(wl.tokens_per_step() // (s.dp * mb_count), 1)
        cores_per_chunk = max(design.total_cores() * nw // s.chunks(), 1)
        gkey = (s.tp, mb_tokens, cores_per_chunk)
        if gkey not in graph_cache:
            graph = compile_chunk(design, wl, s.tp, mb_tokens,
                                  cores_per_chunk)
            if fidelity == "sim":
                lat = chunk_latency_cycles_sim(graph, design)
            elif fidelity == "gnn" and gnn_params is not None:
                lat = chunk_latency_cycles_gnn(gnn_params, graph, design)
            else:
                lat = chunk_latency_cycles(graph, design)
            graph_cache[gkey] = (graph, lat)
        graph, lat = graph_cache[gkey]
        step = evaluate_step(design, wl, s, lat, graph, nw)
        if not step.feasible:
            continue
        cand = EvalResult(step.throughput, step.power_w, s, step, nw, True)
        if best is None or cand.throughput > best.throughput:
            best = cand
    if best is None:
        best = EvalResult(0.0, float("inf"), None, None, nw, False,
                          "no_feasible_strategy")
    return _cache_put(key, best)


# ---------------------------------------------------------------------------
# batched path (analytical fidelity; DESIGN.md §4)
# ---------------------------------------------------------------------------


def _wafers_for_budget_batch(geom: DesignBatch, wl: LLMWorkload) -> np.ndarray:
    total = wl.gpu_budget * H100_AREA_MM2
    return np.maximum(
        1, np.round(total / np.maximum(geom.wafer_area_mm2, 1.0))
    ).astype(np.int64)


def _evaluate_batch_analytical(geom: DesignBatch, wl: LLMWorkload,
                               nw: np.ndarray, max_strategies: int
                               ) -> List[EvalResult]:
    designs = geom.designs

    # per-design strategy lists, flattened to one candidate axis
    sram_total = geom.buffer_kb * 1024.0 * geom.total_cores * nw
    dram_total = geom.dram_gb_per_reticle * 1e9 * geom.n_reticles * nw
    strat_arrays = [
        feasible_strategy_arrays(wl, int(geom.total_cores[i] * nw[i]),
                                 float(sram_total[i] + dram_total[i]),
                                 max_strategies)
        for i in range(len(designs))
    ]
    counts = np.array([len(a) for a in strat_arrays], np.int64)
    offsets = np.concatenate([[0], np.cumsum(counts)])
    didx = np.repeat(np.arange(len(designs), dtype=np.int64), counts)
    sa = np.concatenate(strat_arrays, axis=0)
    tp, pp, dp, mb = sa[:, 0], sa[:, 1], sa[:, 2], sa[:, 3]

    cg = geom.take(didx)                     # candidate-axis geometry
    nw_c = nw[didx]
    chunks = pp * dp
    mb_count = mb if wl.phase == "train" else np.ones_like(mb)
    mb_tokens = np.maximum(wl.tokens_per_step() // (dp * mb_count), 1)
    cores_per_chunk = np.maximum(cg.total_cores * nw_c // chunks, 1)

    # tile stage: per-core tiles sized by the true chunk grid, NoC graph on
    # the capped representative grid (compile_chunk's scale reduction)
    gh_t, gw_t = grid_for_batch(cores_per_chunk)
    gh, gw = grid_for_batch(np.minimum(cores_per_chunk, 64))
    n_cores = gh * gw
    ops = wl.layer_ops_batch(tp, mb_tokens)
    tile_M = np.maximum(ops["M"] // gh_t, 1)
    tile_N = np.maximum(ops["N"] // gw_t, 1)
    tiles = evaluate_tile_batch(tile_M, ops["K"], tile_N,
                                cg.mac[None, :], cg.buffer_kb[None, :],
                                cg.buffer_bw[None, :],
                                cg.dataflow_code[None, :])

    # NoC stage: closed-form row-all-gather congestion on the capped grid
    out_bytes = (ops["M"] * ops["N"]).astype(np.float64) * BYTES
    lat = chunk_latency_cycles_closed(tiles["cycles"], out_bytes, gh, gw,
                                      cg.noc_bw)
    sram_bits_layer = (tiles["sram_read_bits"]
                       + tiles["sram_write_bits"]).sum(axis=0) * n_cores
    noc_bytes_layer = row_allgather_byte_hops(out_bytes[:-1], gh, gw)

    step = evaluate_step_batch(cg, wl, tp, pp, dp, mb, lat, sram_bits_layer,
                               noc_bytes_layer, nw_c)

    # reduce: per-design best feasible throughput (first max wins, matching
    # the scalar search order — candidates are already strategy-sorted)
    results: List[EvalResult] = []
    thpt = np.where(step["feasible"], step["throughput"], -1.0)
    for i in range(len(designs)):
        lo, hi = offsets[i], offsets[i + 1]
        if hi == lo or not step["feasible"][lo:hi].any():
            results.append(EvalResult(0.0, float("inf"), None, None,
                                      int(nw[i]), False,
                                      "no_feasible_strategy"))
            continue
        j = lo + int(np.argmax(thpt[lo:hi]))
        sr = step_result_at(step, j)
        results.append(EvalResult(
            sr.throughput, sr.power_w,
            Strategy(int(tp[j]), int(pp[j]), int(dp[j]), int(mb[j])),
            sr, int(nw[i]), True))
    return results


def evaluate_design_batch(designs: Sequence[WSCDesign], wl: LLMWorkload,
                          fidelity: str = "analytical",
                          gnn_params: Optional[Dict] = None,
                          n_wafers: Optional[Union[int, np.ndarray]] = None,
                          max_strategies: int = 24) -> List[EvalResult]:
    """Evaluate N designs at once. Analytical fidelity runs the vectorized
    pipeline over the flattened (design, strategy) candidate axis; other
    fidelities evaluate per design (both share the cross-call cache)."""
    designs = list(designs)
    if not designs:
        return []
    if fidelity != "analytical":
        if n_wafers is None:
            nws: List[Optional[int]] = [None] * len(designs)
        else:
            nws = [int(v) for v in np.broadcast_to(
                np.asarray(n_wafers, np.int64), (len(designs),))]
        return [evaluate_design(d, wl, fidelity=fidelity,
                                gnn_params=gnn_params, n_wafers=nws[i],
                                max_strategies=max_strategies)
                for i, d in enumerate(designs)]

    geom0 = DesignBatch.from_designs(designs)
    if n_wafers is None:
        nw = _wafers_for_budget_batch(geom0, wl)
    else:
        nw = np.broadcast_to(np.asarray(n_wafers, np.int64),
                             (len(designs),)).copy()

    keys = [_cache_key(d, wl, fidelity, int(nw[i]), max_strategies, None)
            for i, d in enumerate(designs)]
    results: List[Optional[EvalResult]] = [_EVAL_CACHE.get(k) for k in keys]
    todo = [i for i, r in enumerate(results) if r is None]
    _CACHE_STATS["hits"] += len(designs) - len(todo)
    _CACHE_STATS["misses"] += len(todo)
    if todo:
        fresh = _evaluate_batch_analytical(geom0.take(np.asarray(todo)), wl,
                                           nw[todo], max_strategies)
        for i, r in zip(todo, fresh):
            results[i] = _cache_put(keys[i], r)
    return results            # type: ignore[return-value]


def evaluate_objectives(design: WSCDesign, wl: LLMWorkload,
                        fidelity: str = "analytical",
                        gnn_params: Optional[Dict] = None
                        ) -> Tuple[float, float]:
    """(throughput, power) pair for the explorer; infeasible -> (0, peak)."""
    r = evaluate_design(design, wl, fidelity=fidelity, gnn_params=gnn_params)
    if not r.feasible:
        return 0.0, C.WAFER_POWER_W
    return r.throughput, r.power_w / max(r.n_wafers, 1)


def evaluate_objectives_batch(designs: Sequence[WSCDesign], wl: LLMWorkload,
                              fidelity: str = "analytical",
                              gnn_params: Optional[Dict] = None
                              ) -> List[Tuple[float, float]]:
    out = []
    for r in evaluate_design_batch(designs, wl, fidelity=fidelity,
                                   gnn_params=gnn_params):
        if not r.feasible:
            out.append((0.0, C.WAFER_POWER_W))
        else:
            out.append((r.throughput, r.power_w / max(r.n_wafers, 1)))
    return out


def batched_objectives(wl: LLMWorkload, fidelity: str = "analytical",
                       gnn_params: Optional[Dict] = None):
    """Batch-aware objective function for the explorer: call with a list of
    designs, get a list of (throughput, power). The `.batched` marker lets
    run_mfmobo/run_mobo evaluate whole proposals in one vectorized pass."""
    def f(designs):
        if isinstance(designs, WSCDesign):
            return evaluate_objectives(designs, wl, fidelity=fidelity,
                                       gnn_params=gnn_params)
        return evaluate_objectives_batch(designs, wl, fidelity=fidelity,
                                         gnn_params=gnn_params)
    f.batched = True
    f.fidelity = fidelity
    return f
