"""Online GNN calibration — active learning at the f1 -> f0 handover.

The paper trains the f0 congestion model offline on simulator traces
(§VI-C) and MFMOBO then trusts it for the bulk of the budget. A fixed
checkpoint is only as good as its training distribution, so this module
closes the loop: right before `run_mfmobo` evaluates its first GNN-fidelity
point (`on_handover` — fired ahead of the f0 prior batch, so no recorded
f0 objective ever comes from uncalibrated params), the calibrator

  1. picks the Pareto neighborhood of everything evaluated so far —
     the nondominated designs first, then the points closest to the front
     in (log throughput, -log power) space, which is exactly the region the
     remaining f0 evaluations will explore;
  2. compiles representative chunks for those designs, featurizes their
     transfers, and runs the cycle-approximate simulator for ground-truth
     per-link waiting times (`featurize_transfer(with_target=True)`);
  3. fine-tunes the current GNN parameters on those traces with a held-out
     validation split, early-stopping on validation loss (`train_gnn`'s
     patience machinery).

The calibrator's objective function reads `self.params` at call time, so
the fine-tuned parameters take effect for every f0 evaluation after the
handover — and the evaluator's params-version token gives the new pytree
its own cache namespace automatically.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.compiler import compile_chunk
from repro.core.design_space import WSCDesign
from repro.core.noc_gnn import LinkGraph, TrainHistory, featurize_transfer, train_gnn
from repro.core.pareto import pareto_front, to_max_space
from repro.core.workload import LLMWorkload

# representative (tp, mb_tokens) compilations per selected design — the same
# operating points the offline corpus uses (benchmarks.common.trained_gnn),
# so fine-tuning shifts the design distribution, not the task
CALIBRATION_POINTS: Tuple[Tuple[int, int], ...] = ((16, 4096), (64, 1024))


def pareto_neighborhood(designs: Sequence[WSCDesign],
                        ys: Sequence[Tuple[float, float]],
                        k: int) -> List[WSCDesign]:
    """Up to k distinct designs: the nondominated set first, then the
    closest dominated points to the front (Euclidean, objectives
    standardized in max-space)."""
    if not designs:
        return []
    t = np.array([y[0] for y in ys], np.float64)
    p = np.array([y[1] for y in ys], np.float64)
    pts = to_max_space(t, p)
    scale = np.maximum(pts.max(axis=0) - pts.min(axis=0), 1e-9)
    norm = (pts - pts.min(axis=0)) / scale
    front = pareto_front(pts)
    on_front = np.array([any(np.allclose(pt, f) for f in front)
                         for pt in pts])
    if front.size:
        fnorm = (front - pts.min(axis=0)) / scale
        dist = np.min(np.linalg.norm(norm[:, None, :] - fnorm[None, :, :],
                                     axis=-1), axis=1)
    else:
        dist = np.zeros(len(pts))
    order = np.lexsort((dist, ~on_front))    # front members first, then near
    picked: List[WSCDesign] = []
    seen = set()
    for i in order:
        d = designs[i]
        if d in seen:
            continue
        seen.add(d)
        picked.append(d)
        if len(picked) >= k:
            break
    return picked


def build_calibration_set(designs: Sequence[WSCDesign], wl: LLMWorkload,
                          points: Sequence[Tuple[int, int]] =
                          CALIBRATION_POINTS,
                          cores_per_chunk: int = 64) -> List[LinkGraph]:
    """Simulator-labeled transfer graphs for the selected designs."""
    dataset: List[LinkGraph] = []
    for d in designs:
        for tp, mbt in points:
            g = compile_chunk(d, wl, tp=tp, mb_tokens=mbt,
                              cores_per_chunk=cores_per_chunk)
            for t in range(len(g.transfers)):
                if g.transfers[t].pairs:
                    dataset.append(
                        featurize_transfer(g, d, t, with_target=True))
    return dataset


@dataclasses.dataclass
class CalibrationRecord:
    n_designs: int
    n_graphs: int
    train_s: float
    history: TrainHistory


class GNNCalibrator:
    """Holds the live GNN parameters for the f0 objective and fine-tunes
    them at the fidelity handover. Use:

        cal = GNNCalibrator(params, wl)
        tr = run_mfmobo(cal.objectives(), f1, on_handover=cal.on_handover)
    """

    def __init__(self, params: Dict, wl: LLMWorkload, *,
                 n_designs: int = 6, epochs: int = 20, lr: float = 1e-3,
                 val_frac: float = 0.25, patience: Optional[int] = 5,
                 seed: int = 0):
        self.params = params
        self.wl = wl
        self.n_designs = n_designs
        self.epochs = epochs
        self.lr = lr
        self.val_frac = val_frac
        self.patience = patience
        self.seed = seed
        self.records: List[CalibrationRecord] = []

    def objectives(self):
        """Batch-aware f0 objective reading the latest calibrated params —
        an `EvaluatorObjective` whose `params_fn` dereferences this
        calibrator at call time, so post-handover evaluations automatically
        use the fine-tuned pytree (and its fresh cache namespace)."""
        from repro.explore.objectives import EvaluatorObjective
        return EvaluatorObjective(self.wl, "gnn",
                                  params_fn=lambda: self.params)

    def on_handover(self, designs: Sequence[WSCDesign],
                    ys: Sequence[Tuple[float, float]]) -> None:
        picked = pareto_neighborhood(designs, ys, self.n_designs)
        if not picked:
            return
        dataset = build_calibration_set(picked, self.wl)
        if not dataset:
            return
        t0 = time.time()
        self.params, hist = train_gnn(
            self.params, dataset, epochs=self.epochs, lr=self.lr,
            seed=self.seed, val_frac=self.val_frac, patience=self.patience)
        self.records.append(CalibrationRecord(
            n_designs=len(picked), n_graphs=len(dataset),
            train_s=time.time() - t0, history=hist))
