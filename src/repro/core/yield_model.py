"""Defective-core + redundancy yield models (paper §V-C, §V-D).

    Yield_Murphy = [(1 - e^{-A D0}) / (A D0)]^2                        (Eq. 1)
    Yield_str    = (loss/d_max) d + 1 - loss   for d < d_max           (Eq. 2)
    Yield_core   = Murphy x stress x TSV                               (Eq. 3)
    Y_PS         = sum_{i=p}^{p+n} C(p+n, i) y^i (1-y)^{p+n-i}         (Eq. 4)

Per-position yields over the reticle core grid (screw holes at reticle
corners, TSV field at reticle centre) + Monte-Carlo row-redundancy estimate
(Cerebras-style extra row connections, paper §VIII-A).
"""
from __future__ import annotations

import math
from functools import lru_cache
from typing import Tuple

import numpy as np

D0_PER_CM2 = 0.1                 # paper §VIII-A (IRDS)
STRESS_LOSS = 0.1
STRESS_DMAX_MM = 1.0
TSV_LOSS = 0.1
TSV_DMAX_MM = 1.0
YIELD_TARGET = 0.9


def murphy_yield(area_mm2: float, d0: float = D0_PER_CM2) -> float:
    a_cm2 = area_mm2 / 100.0
    ad = a_cm2 * d0
    if ad < 1e-12:
        return 1.0
    return ((1.0 - math.exp(-ad)) / ad) ** 2


def stress_yield(dist_mm: float, loss: float = STRESS_LOSS,
                 dmax: float = STRESS_DMAX_MM) -> float:
    if dist_mm >= dmax:
        return 1.0
    return (loss / dmax) * dist_mm + 1.0 - loss


def core_yield_grid(core_h_mm: float, core_w_mm: float,
                    array: Tuple[int, int],
                    reticle_mm: Tuple[float, float],
                    tsv_region_mm2: float = 0.0) -> np.ndarray:
    """Per-position core yield over an (H, W) array on one reticle.
    Screw holes sit at the four reticle corners (intersections of reticles on
    the wafer); the TSV field sits at the reticle centre."""
    H, W = array
    area = core_h_mm * core_w_mm
    base = murphy_yield(area)
    ys = np.full((H, W), base)

    # nearest-vertex distances of each core to the four corners
    ci = (np.arange(H)[:, None] + 0.5) * core_h_mm
    cj = (np.arange(W)[None, :] + 0.5) * core_w_mm
    rh, rw = reticle_mm
    for hy, hx in ((0, 0), (0, rw), (rh, 0), (rh, rw)):
        d = np.sqrt((ci - hy) ** 2 + (cj - hx) ** 2)
        d = np.maximum(d - 0.5 * math.hypot(core_h_mm, core_w_mm), 0.0)
        ys = ys * np.where(d < STRESS_DMAX_MM,
                           (STRESS_LOSS / STRESS_DMAX_MM) * d + 1 - STRESS_LOSS,
                           1.0)

    if tsv_region_mm2 > 0.0:
        r_tsv = math.sqrt(tsv_region_mm2 / math.pi)
        d = np.sqrt((ci - rh / 2) ** 2 + (cj - rw / 2) ** 2)
        d = np.maximum(d - r_tsv, 0.0)
        ys = ys * np.where(d < TSV_DMAX_MM,
                           (TSV_LOSS / TSV_DMAX_MM) * d + 1 - TSV_LOSS,
                           1.0)
    return np.clip(ys, 0.0, 1.0)


def binomial_redundancy_yield(p_cores: int, n_spare: int, y_core: float
                              ) -> float:
    """Eq. 4: reticle works if >= p of (p+n) cores are good (uniform yield)."""
    total = p_cores + n_spare
    acc = 0.0
    for i in range(p_cores, total + 1):
        acc += math.comb(total, i) * (y_core ** i) * ((1 - y_core) ** (total - i))
    return acc


def mc_row_redundancy_yield(ys: np.ndarray, spares_per_row: int,
                            n_samples: int = 2000, seed: int = 0) -> float:
    """Monte-Carlo with position-dependent yields and Cerebras-style row
    repair: a reticle works iff every row has <= spares_per_row failures."""
    rng = np.random.default_rng(seed)
    H, W = ys.shape
    fails = rng.random((n_samples, H, W)) > ys[None]
    per_row = fails.sum(axis=2)
    ok = (per_row <= spares_per_row).all(axis=1)
    return float(ok.mean())


@lru_cache(maxsize=4096)
def reticle_yield(core_h_mm: float, core_w_mm: float, array: Tuple[int, int],
                  reticle_mm: Tuple[float, float], tsv_region_mm2: float,
                  spares_per_row: int) -> float:
    ys = core_yield_grid(core_h_mm, core_w_mm, array, reticle_mm,
                         tsv_region_mm2)
    return mc_row_redundancy_yield(ys, spares_per_row)


# per-boundary yield of on-wafer field stitching (offset-exposure seams are
# fabricated blind — no KGD test before commit); InFO-SoW assembles tested
# dies on an RDL, so its assembly yield is near-unity
STITCH_BOUNDARY_YIELD = 0.9995


def min_spares_for_target(core_h_mm: float, core_w_mm: float,
                          array: Tuple[int, int],
                          reticle_mm: Tuple[float, float],
                          tsv_region_mm2: float,
                          n_reticles: int,
                          integration: str,
                          target: float = YIELD_TARGET,
                          max_spares: int = 4) -> Tuple[int, float]:
    """Smallest spares-per-row meeting the wafer yield target.

    InFO-SoW uses known-good-die: wafer yield == reticle yield (paper §VIII-A).
    Die stitching cannot discard bad reticles: wafer yield = reticle^n x
    the stitched-seam yield."""
    for spares in range(0, max_spares + 1):
        ry = reticle_yield(core_h_mm, core_w_mm, array, reticle_mm,
                           tsv_region_mm2, spares)
        if integration == "infosow":
            wy = ry
        else:
            n_seams = 2 * n_reticles        # ~2 shared boundaries per reticle
            wy = (ry ** n_reticles) * (STITCH_BOUNDARY_YIELD ** n_seams)
        if wy >= target:
            return spares, wy
    return -1, 0.0
