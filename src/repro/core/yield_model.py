"""Defective-core + redundancy yield models (paper §V-C, §V-D).

    Yield_Murphy = [(1 - e^{-A D0}) / (A D0)]^2                        (Eq. 1)
    Yield_str    = (loss/d_max) d + 1 - loss   for d < d_max           (Eq. 2)
    Yield_core   = Murphy x stress x TSV                               (Eq. 3)
    Y_PS         = sum_{i=p}^{p+n} C(p+n, i) y^i (1-y)^{p+n-i}         (Eq. 4)

Per-position yields over the reticle core grid (screw holes at reticle
corners, TSV field at reticle centre) + exact Poisson-binomial
row-redundancy yield (Cerebras-style extra row connections, paper §VIII-A).
The Monte-Carlo estimator is retained as a cross-check oracle for tests.
"""
from __future__ import annotations

import math
from functools import lru_cache
from typing import Sequence, Tuple, Union

import numpy as np

D0_PER_CM2 = 0.1                 # paper §VIII-A (IRDS)
STRESS_LOSS = 0.1
STRESS_DMAX_MM = 1.0
TSV_LOSS = 0.1
TSV_DMAX_MM = 1.0
YIELD_TARGET = 0.9


def murphy_yield(area_mm2: float, d0: float = D0_PER_CM2) -> float:
    a_cm2 = area_mm2 / 100.0
    ad = a_cm2 * d0
    if ad < 1e-12:
        return 1.0
    return ((1.0 - math.exp(-ad)) / ad) ** 2


def stress_yield(dist_mm: float, loss: float = STRESS_LOSS,
                 dmax: float = STRESS_DMAX_MM) -> float:
    if dist_mm >= dmax:
        return 1.0
    return (loss / dmax) * dist_mm + 1.0 - loss


def core_yield_grid(core_h_mm: float, core_w_mm: float,
                    array: Tuple[int, int],
                    reticle_mm: Tuple[float, float],
                    tsv_region_mm2: float = 0.0) -> np.ndarray:
    """Per-position core yield over an (H, W) array on one reticle.
    Screw holes sit at the four reticle corners (intersections of reticles on
    the wafer); the TSV field sits at the reticle centre."""
    H, W = array
    area = core_h_mm * core_w_mm
    base = murphy_yield(area)
    ys = np.full((H, W), base)

    # nearest-vertex distances of each core to the four corners
    ci = (np.arange(H)[:, None] + 0.5) * core_h_mm
    cj = (np.arange(W)[None, :] + 0.5) * core_w_mm
    rh, rw = reticle_mm
    for hy, hx in ((0, 0), (0, rw), (rh, 0), (rh, rw)):
        d = np.sqrt((ci - hy) ** 2 + (cj - hx) ** 2)
        d = np.maximum(d - 0.5 * math.hypot(core_h_mm, core_w_mm), 0.0)
        ys = ys * np.where(d < STRESS_DMAX_MM,
                           (STRESS_LOSS / STRESS_DMAX_MM) * d + 1 - STRESS_LOSS,
                           1.0)

    if tsv_region_mm2 > 0.0:
        r_tsv = math.sqrt(tsv_region_mm2 / math.pi)
        d = np.sqrt((ci - rh / 2) ** 2 + (cj - rw / 2) ** 2)
        d = np.maximum(d - r_tsv, 0.0)
        ys = ys * np.where(d < TSV_DMAX_MM,
                           (TSV_LOSS / TSV_DMAX_MM) * d + 1 - TSV_LOSS,
                           1.0)
    return np.clip(ys, 0.0, 1.0)


def binomial_redundancy_yield(p_cores: int, n_spare: int, y_core: float
                              ) -> float:
    """Eq. 4: reticle works if >= p of (p+n) cores are good (uniform yield)."""
    total = p_cores + n_spare
    acc = 0.0
    for i in range(p_cores, total + 1):
        acc += math.comb(total, i) * (y_core ** i) * ((1 - y_core) ** (total - i))
    return acc


def mc_row_redundancy_yield(ys: np.ndarray, spares_per_row: int,
                            n_samples: int = 2000, seed: int = 0) -> float:
    """Monte-Carlo with position-dependent yields and Cerebras-style row
    repair: a reticle works iff every row has <= spares_per_row failures.
    Superseded by the exact `row_redundancy_yield`; kept as the statistical
    oracle the exact DP is property-tested against."""
    rng = np.random.default_rng(seed)
    H, W = ys.shape
    fails = rng.random((n_samples, H, W)) > ys[None]
    per_row = fails.sum(axis=2)
    ok = (per_row <= spares_per_row).all(axis=1)
    return float(ok.mean())


def row_fail_cdf(ys: np.ndarray, max_count: int) -> np.ndarray:
    """Exact Poisson-binomial CDF of per-row failure counts.

    `ys` (..., W) holds per-cell yields; returns (..., max_count + 1) with
    entry k = P(#failed cells in the row <= k). The polynomial-convolution
    DP is truncated at max_count + 1 coefficients: dropped mass only ever
    moves to *higher* counts, so the retained coefficients stay exact.
    Padding cells with yield 1.0 leaves the DP bitwise unchanged, which is
    what makes the batched grids below exact despite ragged row lengths.
    """
    q = 1.0 - np.asarray(ys, np.float64)
    pmf = np.zeros(q.shape[:-1] + (max_count + 1,))
    pmf[..., 0] = 1.0
    for i in range(q.shape[-1]):
        qi = q[..., i, None]
        shifted = np.zeros_like(pmf)
        shifted[..., 1:] = pmf[..., :-1]
        pmf = pmf * (1.0 - qi) + shifted * qi
    return np.cumsum(pmf, axis=-1)


def row_redundancy_yield(ys: np.ndarray, spares_per_row: int) -> float:
    """Exact replacement for `mc_row_redundancy_yield`: rows fail
    independently, so P(reticle works) = prod over rows of
    P(row failures <= spares)."""
    cdf = row_fail_cdf(np.asarray(ys, np.float64), spares_per_row)
    return float(np.prod(cdf[..., -1], axis=-1))


@lru_cache(maxsize=4096)
def reticle_yield(core_h_mm: float, core_w_mm: float, array: Tuple[int, int],
                  reticle_mm: Tuple[float, float], tsv_region_mm2: float,
                  spares_per_row: int) -> float:
    ys = core_yield_grid(core_h_mm, core_w_mm, array, reticle_mm,
                         tsv_region_mm2)
    return row_redundancy_yield(ys, spares_per_row)


# per-boundary yield of on-wafer field stitching (offset-exposure seams are
# fabricated blind — no KGD test before commit); InFO-SoW assembles tested
# dies on an RDL, so its assembly yield is near-unity
STITCH_BOUNDARY_YIELD = 0.9995


def core_yield_grids_batch(core_h_mm: np.ndarray, core_w_mm: np.ndarray,
                           arr_h: np.ndarray, arr_w: np.ndarray,
                           reticle_h_mm: np.ndarray,
                           reticle_w_mm: np.ndarray,
                           tsv_region_mm2: np.ndarray) -> np.ndarray:
    """`core_yield_grid` for N designs at once, padded to the batch max
    (H, W) with yield 1.0 (a perfect cell never fails, so padding is inert
    through the row-failure DP). Cell values match the scalar grid bitwise:
    the scalar helpers (`murphy_yield`, math.hypot/sqrt) compute the
    per-design bases, and the per-cell arithmetic broadcasts the identical
    IEEE operations."""
    N = len(core_h_mm)
    maxH = int(arr_h.max())
    maxW = int(arr_w.max())
    base = np.array([murphy_yield(float(h) * float(w))
                     for h, w in zip(core_h_mm, core_w_mm)])
    ys = np.broadcast_to(base[:, None, None], (N, maxH, maxW)).copy()

    ci = (np.arange(maxH)[None, :] + 0.5) * core_h_mm[:, None]   # (N, maxH)
    cj = (np.arange(maxW)[None, :] + 0.5) * core_w_mm[:, None]   # (N, maxW)
    half_diag = np.array([0.5 * math.hypot(float(h), float(w))
                          for h, w in zip(core_h_mm, core_w_mm)])
    zero = np.zeros(N)
    for hy, hx in ((zero, zero), (zero, reticle_w_mm),
                   (reticle_h_mm, zero), (reticle_h_mm, reticle_w_mm)):
        d = np.sqrt((ci - hy[:, None])[:, :, None] ** 2
                    + (cj - hx[:, None])[:, None, :] ** 2)
        d = np.maximum(d - half_diag[:, None, None], 0.0)
        ys = ys * np.where(d < STRESS_DMAX_MM,
                           (STRESS_LOSS / STRESS_DMAX_MM) * d + 1 - STRESS_LOSS,
                           1.0)

    has_tsv = tsv_region_mm2 > 0.0
    if has_tsv.any():
        r_tsv = np.array([math.sqrt(float(a) / math.pi) if a > 0.0 else 0.0
                          for a in tsv_region_mm2])
        d = np.sqrt((ci - reticle_h_mm[:, None] / 2)[:, :, None] ** 2
                    + (cj - reticle_w_mm[:, None] / 2)[:, None, :] ** 2)
        d = np.maximum(d - r_tsv[:, None, None], 0.0)
        tsv_factor = np.where(d < TSV_DMAX_MM,
                              (TSV_LOSS / TSV_DMAX_MM) * d + 1 - TSV_LOSS,
                              1.0)
        ys = np.where(has_tsv[:, None, None], ys * tsv_factor, ys)

    ys = np.clip(ys, 0.0, 1.0)
    # neutralize padding: cells outside each design's own (H, W) are perfect
    row_pad = np.arange(maxH)[None, :] >= arr_h[:, None]
    col_pad = np.arange(maxW)[None, :] >= arr_w[:, None]
    ys[np.broadcast_to(row_pad[:, :, None], ys.shape)] = 1.0
    ys[np.broadcast_to(col_pad[:, None, :], ys.shape)] = 1.0
    return ys


def min_spares_for_target_batch(core_h_mm: np.ndarray, core_w_mm: np.ndarray,
                                arr_h: np.ndarray, arr_w: np.ndarray,
                                reticle_h_mm: np.ndarray,
                                reticle_w_mm: np.ndarray,
                                tsv_region_mm2: np.ndarray,
                                n_reticles: np.ndarray,
                                is_infosow: np.ndarray,
                                target: float = YIELD_TARGET,
                                max_spares: int = 4
                                ) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized `min_spares_for_target`: one yield-grid build + one
    row-failure DP per batch resolves every spares level 0..max_spares for
    every design simultaneously. Returns (spares (N,) int64 with -1 = no
    level meets the target, wafer_yield (N,) float64)."""
    core_h_mm = np.asarray(core_h_mm, np.float64)
    core_w_mm = np.asarray(core_w_mm, np.float64)
    arr_h = np.asarray(arr_h, np.int64)
    arr_w = np.asarray(arr_w, np.int64)
    N = len(core_h_mm)
    if N == 0:
        return np.zeros(0, np.int64), np.zeros(0)
    ys = core_yield_grids_batch(core_h_mm, core_w_mm, arr_h, arr_w,
                                np.asarray(reticle_h_mm, np.float64),
                                np.asarray(reticle_w_mm, np.float64),
                                np.asarray(tsv_region_mm2, np.float64))
    cdf = row_fail_cdf(ys, max_spares)              # (N, maxH, S+1)
    rys = np.prod(cdf, axis=1)                      # (N, S+1) reticle yield
    n_ret = np.asarray(n_reticles, np.int64)
    n_seams = 2 * n_ret                 # ~2 shared boundaries per reticle
    stitched = (rys ** n_ret[:, None]) * \
        (STITCH_BOUNDARY_YIELD ** n_seams[:, None].astype(np.float64))
    wy = np.where(np.asarray(is_infosow, bool)[:, None], rys, stitched)
    meets = wy >= target
    spares = np.where(meets.any(axis=1), meets.argmax(axis=1), -1)
    wy_out = np.where(spares >= 0,
                      wy[np.arange(N), np.maximum(spares, 0)], 0.0)
    return spares.astype(np.int64), wy_out


def min_spares_for_target(core_h_mm: float, core_w_mm: float,
                          array: Tuple[int, int],
                          reticle_mm: Tuple[float, float],
                          tsv_region_mm2: float,
                          n_reticles: int,
                          integration: str,
                          target: float = YIELD_TARGET,
                          max_spares: int = 4) -> Tuple[int, float]:
    """Smallest spares-per-row meeting the wafer yield target.

    InFO-SoW uses known-good-die: wafer yield == reticle yield (paper §VIII-A).
    Die stitching cannot discard bad reticles: wafer yield = reticle^n x
    the stitched-seam yield.

    Delegates to the batch-of-1 path so the scalar and batched validators
    resolve spares bitwise identically."""
    spares, wy = min_spares_for_target_batch(
        np.array([core_h_mm]), np.array([core_w_mm]),
        np.array([array[0]]), np.array([array[1]]),
        np.array([reticle_mm[0]]), np.array([reticle_mm[1]]),
        np.array([tsv_region_mm2]), np.array([n_reticles]),
        np.array([integration == "infosow"]),
        target=target, max_spares=max_spares)
    return int(spares[0]), float(wy[0])
