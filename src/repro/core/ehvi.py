"""Exact 2-objective Expected Hypervolume Improvement (paper §VII).

Derivation (max-max space, independent Gaussian posteriors):

    HVI(y) = integral_{a=ref1}^{y1} (y2 - U(a))^+ da,
    U(a)   = max(ref2, max{v_j : f_j >= a})     (front upper envelope)

so with y1 independent of y2:

    EHVI = sum_strips  [ integral_strip P(y1 > a) da ] x E[(y2 - b_s)^+]

where the front splits obj-1 into strips with constant envelope b_s.
Both factors are closed-form:
    integral_l^u (1 - Phi((a-mu)/s)) da = s [H(z_u) - H(z_l)],
        H(z) = z (1 - Phi(z)) - phi(z)
    E[(Y - b)^+] = (mu - b)(1 - Phi(z_b)) + s phi(z_b),  z_b = (b-mu)/s
"""
from __future__ import annotations

import math

import numpy as np


try:                                 # scipy ships with jax; fall back to a
    from scipy.special import erf as _erf      # per-element loop without it
except ImportError:                  # pragma: no cover
    _erf = np.vectorize(math.erf)


def _phi(z):
    return np.exp(-0.5 * z * z) / math.sqrt(2 * math.pi)


def _Phi(z):
    return 0.5 * (1.0 + _erf(np.asarray(z, float) / math.sqrt(2.0)))


def _H(z):
    return z * (1.0 - _Phi(z)) - _phi(z)


def _strip_mass(l, u, mu, s):
    """integral_l^u P(Y1 > a) da, broadcast over strips x candidates."""
    s = np.maximum(s, 1e-12)
    zl = (l - mu) / s
    hu = np.where(np.isinf(u), 0.0, _H(np.where(np.isinf(u), 0.0,
                                                (u - mu) / s)))
    return s * (hu - _H(zl))


def _excess(b, mu, s):
    """E[(Y2 - b)^+], broadcast over strips x candidates."""
    s = np.maximum(s, 1e-12)
    z = (b - mu) / s
    return (mu - b) * (1.0 - _Phi(z)) + s * _phi(z)


def ehvi_2d(mu: np.ndarray, sigma: np.ndarray, front: np.ndarray,
            ref: np.ndarray) -> np.ndarray:
    """EHVI for N candidates. mu/sigma (N, 2); front (F, 2) current Pareto
    set (may be empty); ref (2,). Returns (N,). Fully vectorized: strips x
    candidates in one broadcast rather than a per-strip Python loop."""
    mu = np.atleast_2d(np.asarray(mu, float))
    sigma = np.atleast_2d(np.asarray(sigma, float))
    ref = np.asarray(ref, float)
    if len(front) == 0:
        edges = np.array([ref[0], np.inf])
        bs = np.array([ref[1]])
    else:
        fr = np.asarray(front, float)
        order = np.argsort(fr[:, 0])            # ascending in obj1
        f = fr[order, 0]
        v = fr[order, 1]
        # envelope per strip: strip k = (edge_k, edge_{k+1}] with
        # edges = [ref1, f_1, ..., f_F, inf); U on (f_k, f_{k+1}] = v_{k+1}
        edges = np.concatenate([[ref[0]], f, [np.inf]])
        # strip k = (edge_k, edge_{k+1}]: level to beat is v_{k+1} (v is
        # descending in obj2 as obj1 ascends -> suffix max = next v);
        # strip F (beyond the front) only needs ref2
        bs = np.maximum(np.concatenate([v, [ref[1]]]), ref[1])
    l = edges[:-1, None]                        # (S, 1)
    u = edges[1:, None]
    b = bs[:, None]
    keep = (u > l)                              # degenerate strips drop out
    mass = np.maximum(_strip_mass(l, u, mu[None, :, 0], sigma[None, :, 0]),
                      0.0)
    exc = np.maximum(_excess(b, mu[None, :, 1], sigma[None, :, 1]), 0.0)
    return np.where(keep, mass * exc, 0.0).sum(axis=0)
