"""Exact 2-objective Expected Hypervolume Improvement (paper §VII).

Derivation (max-max space, independent Gaussian posteriors):

    HVI(y) = integral_{a=ref1}^{y1} (y2 - U(a))^+ da,
    U(a)   = max(ref2, max{v_j : f_j >= a})     (front upper envelope)

so with y1 independent of y2:

    EHVI = sum_strips  [ integral_strip P(y1 > a) da ] x E[(y2 - b_s)^+]

where the front splits obj-1 into strips with constant envelope b_s.
Both factors are closed-form:
    integral_l^u (1 - Phi((a-mu)/s)) da = s [H(z_u) - H(z_l)],
        H(z) = z (1 - Phi(z)) - phi(z)
    E[(Y - b)^+] = (mu - b)(1 - Phi(z_b)) + s phi(z_b),  z_b = (b-mu)/s

Two implementations (DESIGN.md §10): `ehvi_2d` is the jitted JAX kernel
(candidates x strips in one vmapped broadcast, front padded to a pow2
bucket with (+inf, -inf) sentinels that sort past the real points and
collapse to zero-width strips); `ehvi_2d_ref` is the retained NumPy
reference the JAX path is property-tested against.
"""
from __future__ import annotations

import math
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np
from scipy.special import erf as _erf

from repro.core.gp import bucket_size

_SQRT2 = math.sqrt(2.0)
_SQRT_2PI = math.sqrt(2.0 * math.pi)


# ---------------------------------------------------------------------------
# NumPy reference (property-test oracle)
# ---------------------------------------------------------------------------


def _phi(z):
    return np.exp(-0.5 * z * z) / _SQRT_2PI


def _Phi(z):
    return 0.5 * (1.0 + _erf(np.asarray(z, float) / _SQRT2))


def _H(z):
    return z * (1.0 - _Phi(z)) - _phi(z)


def _strip_mass(l, u, mu, s):
    """integral_l^u P(Y1 > a) da, broadcast over strips x candidates."""
    s = np.maximum(s, 1e-12)
    zl = (l - mu) / s
    hu = np.where(np.isinf(u), 0.0, _H(np.where(np.isinf(u), 0.0,
                                                (u - mu) / s)))
    return s * (hu - _H(zl))


def _excess(b, mu, s):
    """E[(Y2 - b)^+], broadcast over strips x candidates."""
    s = np.maximum(s, 1e-12)
    z = (b - mu) / s
    return (mu - b) * (1.0 - _Phi(z)) + s * _phi(z)


def ehvi_2d_ref(mu: np.ndarray, sigma: np.ndarray, front: np.ndarray,
                ref: np.ndarray) -> np.ndarray:
    """NumPy EHVI for N candidates. mu/sigma (N, 2); front (F, 2) current
    Pareto set (may be empty); ref (2,). Returns (N,)."""
    mu = np.atleast_2d(np.asarray(mu, float))
    sigma = np.atleast_2d(np.asarray(sigma, float))
    ref = np.asarray(ref, float)
    if len(front) == 0:
        edges = np.array([ref[0], np.inf])
        bs = np.array([ref[1]])
    else:
        fr = np.asarray(front, float)
        order = np.argsort(fr[:, 0])            # ascending in obj1
        f = fr[order, 0]
        v = fr[order, 1]
        # envelope per strip: strip k = (edge_k, edge_{k+1}] with
        # edges = [ref1, f_1, ..., f_F, inf); U on (f_k, f_{k+1}] = v_{k+1}
        edges = np.concatenate([[ref[0]], f, [np.inf]])
        # strip k = (edge_k, edge_{k+1}]: level to beat is v_{k+1} (v is
        # descending in obj2 as obj1 ascends -> suffix max = next v);
        # strip F (beyond the front) only needs ref2
        bs = np.maximum(np.concatenate([v, [ref[1]]]), ref[1])
    l = edges[:-1, None]                        # (S, 1)
    u = edges[1:, None]
    b = bs[:, None]
    keep = (u > l)                              # degenerate strips drop out
    mass = np.maximum(_strip_mass(l, u, mu[None, :, 0], sigma[None, :, 0]),
                      0.0)
    exc = np.maximum(_excess(b, mu[None, :, 1], sigma[None, :, 1]), 0.0)
    return np.where(keep, mass * exc, 0.0).sum(axis=0)


# ---------------------------------------------------------------------------
# jitted JAX kernel
# ---------------------------------------------------------------------------


def _Phi_j(z):
    return 0.5 * (1.0 + jax.scipy.special.erf(z / _SQRT2))


def _phi_j(z):
    return jnp.exp(-0.5 * z * z) / _SQRT_2PI


def _H_j(z):
    return z * (1.0 - _Phi_j(z)) - _phi_j(z)


def ehvi_padded(mu, sg, pts, pts_mask, ref):
    """Jit-safe EHVI core over a padded point buffer.

    `pts` (F, 2) with `pts_mask` flagging real rows; the Pareto filter runs
    inside (O(F^2) masked dominance), so callers can hand it the raw
    fantasy buffer. Masked/dominated rows become (+inf, -inf) sentinels:
    they sort after every real front point, form zero-width [inf, inf)
    strips that the `keep` mask drops, and leave the beyond-front strip's
    envelope at max(-inf, ref2) = ref2 — exactly the unpadded strip set.
    """
    valid = pts_mask > 0
    ge = (pts[:, None, :] >= pts[None, :, :]).all(-1)
    gt = (pts[:, None, :] > pts[None, :, :]).any(-1)
    dominated = (valid[:, None] & ge & gt).any(0)
    on_front = valid & ~dominated
    o1 = jnp.where(on_front, pts[:, 0], jnp.inf)
    o2 = jnp.where(on_front, pts[:, 1], -jnp.inf)
    order = jnp.argsort(o1)
    f = o1[order]
    v = o2[order]
    edges = jnp.concatenate([ref[0:1], f, jnp.asarray([jnp.inf], f.dtype)])
    bs = jnp.maximum(jnp.concatenate([v, ref[1:2]]), ref[1])
    l = edges[:-1, None]                        # (S, 1)
    u = edges[1:, None]
    b = bs[:, None]
    keep = u > l
    s1 = jnp.maximum(sg[None, :, 0], 1e-12)
    hu = jnp.where(jnp.isinf(u), 0.0,
                   _H_j(jnp.where(jnp.isinf(u), 0.0, (u - mu[None, :, 0]) / s1)))
    mass = jnp.maximum(s1 * (hu - _H_j((l - mu[None, :, 0]) / s1)), 0.0)
    s2 = jnp.maximum(sg[None, :, 1], 1e-12)
    z = (b - mu[None, :, 1]) / s2
    exc = jnp.maximum((mu[None, :, 1] - b) * (1.0 - _Phi_j(z))
                      + s2 * _phi_j(z), 0.0)
    return jnp.where(keep, mass * exc, 0.0).sum(axis=0)


_ehvi_jit = jax.jit(ehvi_padded)


def ehvi_2d(mu: np.ndarray, sigma: np.ndarray, front: np.ndarray,
            ref: np.ndarray) -> np.ndarray:
    """EHVI for N candidates, one jitted XLA call. Same contract as
    `ehvi_2d_ref` (the front is padded to a pow2 bucket, so repeated calls
    with growing fronts reuse a handful of compiled shapes)."""
    mu = np.atleast_2d(np.asarray(mu, np.float32))
    sigma = np.atleast_2d(np.asarray(sigma, np.float32))
    F = len(front)
    Fb = bucket_size(max(F, 1), minimum=4)
    pts = np.zeros((Fb, 2), np.float32)
    mask = np.zeros(Fb, np.float32)
    if F:
        pts[:F] = np.asarray(front, np.float32)
        mask[:F] = 1.0
    out = _ehvi_jit(jnp.asarray(mu), jnp.asarray(sigma), jnp.asarray(pts),
                    jnp.asarray(mask), jnp.asarray(np.asarray(ref, np.float32)))
    return np.array(out, np.float64)
