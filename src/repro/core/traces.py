"""Trace-driven multi-tenant serving: arrivals, tenant SLOs, policies.

The PR 4 serving model (repro.core.serving) scores one arrival batch —
every request lands at t=0 under a single global `ServingSLO`. Real
serving at the ROADMAP's millions-of-users scale is bursty arrivals,
diurnal load, and *mixed tenants* (interactive chat + embeddings + batch
offline) sharing one wafer. This module makes that workload a first-class,
searchable object (DESIGN.md §14):

  * `RequestTrace` — a frozen, hashable, JSON-round-trippable trace: per
    request an arrival step, a tenant tag, and prompt/output lengths.
    `TenantClass` carries each tenant's own `ServingSLO`, priority and
    interactive/offline flag. Seeded synthetic generators produce Poisson
    (`poisson_trace`), Markov-modulated spike (`spike_trace`) and
    sinusoidal diurnal (`diurnal_trace`) arrival processes.

  * `trace_schedule(trace, slots, policy)` — the timed-arrival
    generalization of `serving.continuous_batch_schedule` (which is now
    its degenerate all-arrivals-at-t=0 FIFO case, property-tested
    bitwise-equal). Arrivals are indexed to the *decode-step clock*, so
    the discrete schedule — admission step, finish step, the ordered list
    of prefill events — depends only on (trace, slots, policy), never on
    the design; `trace_serving_metrics` then broadcasts wall-clock
    TTFT/TPOT/goodput over the candidate axis as pure array math, exactly
    the PR 4 decomposition. Admission/routing policies are explicit:
    FIFO, strict priority, preempt-batch-for-interactive, and
    prefill/decode-disaggregated routing (scored through
    `heterogeneity.evaluate_hetero_trace_serving`'s coupled model).

  * `evaluate_trace_serving_batch` — registry-batched per-step evals
    (prefill, decode) composed with the shared schedule into per-tenant
    SLO goodput, plus *windowed* goodput: the trace's steps are cut into
    fixed windows and the worst window's interactive-tenant goodput is
    the spike-robustness objective campaigns search on
    (`explore.objectives.TraceServingObjective`, scenario
    ``"trace_serving"``). `PolicyDesign` pairs a design with a policy so
    the policy axis rides the search encoding next to the 13
    architecture dims.

The schedule semantics mirror `repro.serve.engine.ServeEngine` with timed
submission (`submit_at`) and the same policies; `serve.engine.replay_trace`
replays a trace on a real engine and the admit/finish step counts are
cross-validated exactly in tests/test_traces.py, as PR 4 did for t=0.
"""
from __future__ import annotations

import dataclasses
import heapq
import json
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.design_space import WSCDesign
from repro.core.fidelity import FidelityBackend
from repro.core.serving import ServingSLO
from repro.core.workload import LLMWorkload, RequestMix

Fidelity = Union[str, FidelityBackend]

#: Admission/routing policies `trace_schedule` (and the campaign policy
#: axis) understand. "disaggregated" routes prefills to their own stage
#: (heterogeneity coupled model) instead of sharing the decode pool.
POLICIES = ("fifo", "priority", "preempt", "disaggregated")

#: The subset `trace_schedule` itself implements (shared decode pool).
POOL_POLICIES = ("fifo", "priority", "preempt")


# ---------------------------------------------------------------------------
# tenants + traces
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TenantClass:
    """One tenant sharing the wafer: its own SLO, an admission priority
    (higher wins under the priority/preempt policies) and whether it is
    interactive (chat-like; counts toward the worst-window objective and
    may preempt) or offline/batch (preemptible backfill)."""
    name: str
    ttft_s: float
    tpot_s: float
    priority: int = 0
    interactive: bool = True

    def __post_init__(self):
        if not self.name:
            raise ValueError("tenant needs a name")
        if self.ttft_s <= 0 or self.tpot_s <= 0:
            raise ValueError(f"tenant {self.name!r} SLO bounds must be > 0")

    def slo(self) -> ServingSLO:
        return ServingSLO(ttft_s=self.ttft_s, tpot_s=self.tpot_s)

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d) -> "TenantClass":
        return cls(**dict(d))


DEFAULT_TENANT = TenantClass("default", ttft_s=5.0, tpot_s=0.05)


@dataclasses.dataclass(frozen=True)
class RequestTrace:
    """One replayable serving trace: per request an arrival step (on the
    decode-step clock — see `trace_schedule` for why that keeps the
    schedule design-independent), a tenant, and prompt/output lengths.

    Frozen + tuple fields: a trace is hashable (cache-keyable next to
    `LLMWorkload`) and round-trips through JSON. `arrival_steps` must be
    nondecreasing — request index order IS arrival order, which is what
    ties the FIFO policy, the engine replay and the t=0 degenerate case
    together.
    """
    arrival_steps: Tuple[int, ...]
    prompt_lens: Tuple[int, ...]
    out_lens: Tuple[int, ...]
    tenant_ids: Tuple[int, ...]
    tenants: Tuple[TenantClass, ...] = (DEFAULT_TENANT,)

    def __post_init__(self):
        object.__setattr__(self, "arrival_steps",
                           tuple(int(a) for a in self.arrival_steps))
        object.__setattr__(self, "prompt_lens",
                           tuple(int(p) for p in self.prompt_lens))
        object.__setattr__(self, "out_lens",
                           tuple(int(o) for o in self.out_lens))
        object.__setattr__(self, "tenant_ids",
                           tuple(int(t) for t in self.tenant_ids))
        object.__setattr__(self, "tenants", tuple(
            t if isinstance(t, TenantClass) else TenantClass.from_dict(t)
            for t in self.tenants))
        n = len(self.arrival_steps)
        if not n:
            raise ValueError("RequestTrace needs at least one request")
        if not (len(self.prompt_lens) == len(self.out_lens)
                == len(self.tenant_ids) == n):
            raise ValueError("trace fields must align "
                             f"(got {n}/{len(self.prompt_lens)}/"
                             f"{len(self.out_lens)}/{len(self.tenant_ids)})")
        if min(self.prompt_lens) < 1 or min(self.out_lens) < 1:
            raise ValueError("prompt/output lengths must be >= 1")
        if min(self.arrival_steps) < 0:
            raise ValueError("arrival steps must be >= 0")
        if any(a > b for a, b in zip(self.arrival_steps,
                                     self.arrival_steps[1:])):
            raise ValueError("arrival_steps must be nondecreasing "
                             "(request index order is arrival order)")
        if not self.tenants:
            raise ValueError("trace needs at least one tenant class")
        if min(self.tenant_ids) < 0 or \
                max(self.tenant_ids) >= len(self.tenants):
            raise ValueError(
                f"tenant_ids must index tenants (0..{len(self.tenants)-1})")

    # -- views -------------------------------------------------------------

    @property
    def n_requests(self) -> int:
        return len(self.arrival_steps)

    @property
    def mean_prompt(self) -> float:
        return float(np.mean(self.prompt_lens))

    @property
    def mean_out(self) -> float:
        return float(np.mean(self.out_lens))

    def total_out_tokens(self) -> int:
        return int(sum(self.out_lens))

    def context_len(self) -> int:
        """Representative mid-generation KV length (same convention as
        `RequestMix.context_len`)."""
        return max(1, int(round(self.mean_prompt + 0.5 * self.mean_out)))

    def tenant_of(self, r: int) -> TenantClass:
        return self.tenants[self.tenant_ids[r]]

    def priorities(self) -> np.ndarray:
        return np.array([t.priority for t in self.tenants],
                        np.int64)[np.array(self.tenant_ids, np.int64)]

    def interactive_mask(self) -> np.ndarray:
        """(R,) bool — requests from interactive tenants. Falls back to
        all-True when no tenant is marked interactive, so the windowed
        objective stays meaningful on single-class traces."""
        m = np.array([t.interactive for t in self.tenants],
                     bool)[np.array(self.tenant_ids, np.int64)]
        return m if m.any() else np.ones(self.n_requests, bool)

    def mix(self) -> RequestMix:
        """Drop arrival times/tenants: the PR 4 one-batch view."""
        return RequestMix(self.prompt_lens, self.out_lens)

    @classmethod
    def from_mix(cls, mix: RequestMix,
                 tenant: TenantClass = DEFAULT_TENANT) -> "RequestTrace":
        """The degenerate trace: every request arrives at step 0 in queue
        order under one tenant — `continuous_batch_schedule`'s world."""
        n = mix.n_requests
        return cls((0,) * n, mix.prompt_lens, mix.out_lens, (0,) * n,
                   (tenant,))

    # -- serialization -----------------------------------------------------

    def to_dict(self) -> Dict:
        return {
            "arrival_steps": list(self.arrival_steps),
            "prompt_lens": list(self.prompt_lens),
            "out_lens": list(self.out_lens),
            "tenant_ids": list(self.tenant_ids),
            "tenants": [t.to_dict() for t in self.tenants],
        }

    @classmethod
    def from_dict(cls, d) -> "RequestTrace":
        d = dict(d)
        d["tenants"] = tuple(TenantClass.from_dict(t)
                             for t in d.get("tenants", ()))
        return cls(**d)

    def to_json(self, path: Optional[str] = None, indent: int = 1) -> str:
        s = json.dumps(self.to_dict(), indent=indent)
        if path:
            with open(path, "w") as f:
                f.write(s + "\n")
        return s

    @classmethod
    def from_json(cls, path_or_str: str) -> "RequestTrace":
        if path_or_str.lstrip().startswith("{"):
            return cls.from_dict(json.loads(path_or_str))
        with open(path_or_str) as f:
            return cls.from_dict(json.load(f))


# ---------------------------------------------------------------------------
# seeded synthetic arrival-process generators
# ---------------------------------------------------------------------------


def _assemble(rng: np.random.Generator, steps: List[int],
              tenants: Sequence[TenantClass], shares: Sequence[float],
              prompt_ranges: Sequence[Tuple[int, int]],
              out_ranges: Sequence[Tuple[int, int]]) -> RequestTrace:
    tenants = tuple(tenants)
    n = len(steps)
    p = np.asarray(shares, np.float64)
    if len(p) != len(tenants) or (p <= 0).any():
        raise ValueError("tenant shares must be positive and align with "
                         "tenants")
    if not (len(prompt_ranges) == len(out_ranges) == len(tenants)):
        raise ValueError("prompt/out ranges must align with tenants")
    tid = rng.choice(len(tenants), size=n, p=p / p.sum())
    plen = np.empty(n, np.int64)
    olen = np.empty(n, np.int64)
    for k in range(len(tenants)):
        m = tid == k
        lo, hi = prompt_ranges[k]
        plen[m] = rng.integers(lo, hi + 1, int(m.sum()))
        lo, hi = out_ranges[k]
        olen[m] = rng.integers(lo, hi + 1, int(m.sum()))
    return RequestTrace(tuple(steps), tuple(int(x) for x in plen),
                        tuple(int(x) for x in olen),
                        tuple(int(x) for x in tid), tenants)


def _counts_to_steps(rng, n_requests: int, rate_at) -> List[int]:
    """Draw per-step Poisson arrival counts at `rate_at(step, state)` until
    n_requests have arrived; returns the per-request arrival steps."""
    steps: List[int] = []
    t = 0
    while len(steps) < n_requests:
        lam = max(float(rate_at(t)), 0.0)
        c = int(rng.poisson(lam)) if lam > 0 else 0
        steps.extend([t] * min(c, n_requests - len(steps)))
        t += 1
        if t > 100 * n_requests + 1_000_000:
            raise RuntimeError("arrival process generated (almost) no "
                               f"arrivals in {t} steps at rate {lam}")
    return steps


_ONE_TENANT = ((DEFAULT_TENANT,), (1.0,), ((256, 1024),), ((32, 128),))


def poisson_trace(n_requests: int, *, rate: float = 0.5,
                  tenants=None, shares=None, prompt_ranges=None,
                  out_ranges=None, seed: int = 0) -> RequestTrace:
    """Stationary Poisson arrivals at `rate` requests per decode step."""
    if rate <= 0:
        raise ValueError("rate must be > 0")
    rng = np.random.default_rng(seed)
    tn, sh, pr, orr = _tenant_defaults(tenants, shares, prompt_ranges,
                                       out_ranges)
    steps = _counts_to_steps(rng, n_requests, lambda t: rate)
    return _assemble(rng, steps, tn, sh, pr, orr)


def spike_trace(n_requests: int, *, rate: float = 0.25,
                spike_factor: float = 8.0, spike_len: int = 32,
                gap_len: int = 128, tenants=None, shares=None,
                prompt_ranges=None, out_ranges=None,
                seed: int = 0) -> RequestTrace:
    """Markov-modulated (bursty) arrivals: a two-state process alternates
    between a base rate and a `spike_factor`x spike rate, with expected
    spike/gap durations `spike_len`/`gap_len` steps — the 10x-load-spike
    scenario the worst-window objective is built for."""
    if rate <= 0 or spike_factor < 1 or spike_len < 1 or gap_len < 1:
        raise ValueError("spike trace needs rate>0, spike_factor>=1, "
                         "spike_len/gap_len >= 1")
    rng = np.random.default_rng(seed)
    tn, sh, pr, orr = _tenant_defaults(tenants, shares, prompt_ranges,
                                       out_ranges)
    state = {"spike": False}

    def rate_at(t):
        # transition first so the rng stream is one draw per step
        flip = rng.random() < (1.0 / spike_len if state["spike"]
                               else 1.0 / gap_len)
        if flip:
            state["spike"] = not state["spike"]
        return rate * (spike_factor if state["spike"] else 1.0)

    steps = _counts_to_steps(rng, n_requests, rate_at)
    return _assemble(rng, steps, tn, sh, pr, orr)


def diurnal_trace(n_requests: int, *, rate: float = 0.5,
                  period: int = 512, amplitude: float = 0.9,
                  tenants=None, shares=None, prompt_ranges=None,
                  out_ranges=None, seed: int = 0) -> RequestTrace:
    """Sinusoidal-rate arrivals: rate(t) = rate * (1 + amplitude *
    sin(2*pi*t/period)), clipped at 0 — long low-load troughs between
    peaks (the event-skip scheduler's fast path)."""
    if rate <= 0 or period < 2 or not (0.0 <= amplitude <= 1.0):
        raise ValueError("diurnal trace needs rate>0, period>=2, "
                         "0<=amplitude<=1")
    rng = np.random.default_rng(seed)
    tn, sh, pr, orr = _tenant_defaults(tenants, shares, prompt_ranges,
                                       out_ranges)
    w = 2.0 * np.pi / period
    steps = _counts_to_steps(
        rng, n_requests, lambda t: rate * (1.0 + amplitude * np.sin(w * t)))
    return _assemble(rng, steps, tn, sh, pr, orr)


def _tenant_defaults(tenants, shares, prompt_ranges, out_ranges):
    if tenants is None:
        return _ONE_TENANT
    tenants = tuple(tenants)
    if shares is None:
        shares = (1.0,) * len(tenants)
    if prompt_ranges is None:
        prompt_ranges = ((256, 1024),) * len(tenants)
    if out_ranges is None:
        out_ranges = ((32, 128),) * len(tenants)
    return tenants, tuple(shares), tuple(prompt_ranges), tuple(out_ranges)


_GENERATORS = {"poisson": poisson_trace, "spike": spike_trace,
               "diurnal": diurnal_trace}


def synth_trace(kind: str, n_requests: int, seed: int = 0,
                **kw) -> RequestTrace:
    """Dispatch on generator kind ("poisson" | "spike" | "diurnal")."""
    if kind not in _GENERATORS:
        raise ValueError(f"unknown trace kind {kind!r}; expected one of "
                         f"{tuple(_GENERATORS)}")
    return _GENERATORS[kind](n_requests, seed=seed, **kw)


# ---------------------------------------------------------------------------
# the timed, policy-aware discrete schedule
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TraceSchedule:
    """Design-independent discrete schedule of a trace under `slots` decode
    slots and an admission policy. Arrivals are indexed to the decode-step
    clock (request r becomes visible at the start of step
    ``arrival_steps[r]``), so which step each request is admitted/finishes
    at — and the ordered list of prefill events — is a pure function of
    (trace, slots, policy): the candidate axis only enters through step
    *times*, in `trace_serving_metrics`. Idle steps (no live slot) tick
    the clock but are counted separately (`n_steps` vs `n_decode_steps`)
    so they cost wall-clock, not decode energy."""
    slots: int
    policy: str
    n_steps: int                  # total clock ticks until the last finish
    n_decode_steps: int           # ticks with >= 1 live slot
    admit_step: np.ndarray        # (R,) step of FIRST admission
    finish_step: np.ndarray      # (R,) step at whose end r completes
    decode_tokens: np.ndarray     # (R,) decode ticks r occupies in total
    n_preemptions: int
    # prefill events in admission order (step nondecreasing): every
    # admission — fresh or post-preemption resume — prefills `event_ctx`
    # tokens (prompt, or prompt + generated-so-far on resume)
    event_step: np.ndarray        # (E,)
    event_req: np.ndarray         # (E,)
    event_ctx: np.ndarray         # (E,)
    first_event: np.ndarray       # (R,) index of r's first admission event


def _policy_key(policy: str, arrival, prio):
    if policy == "fifo":
        return lambda r: (arrival[r], r)
    return lambda r: (-prio[r], arrival[r], r)


def trace_schedule(trace: RequestTrace, slots: int,
                   policy: str = "fifo") -> TraceSchedule:
    """Event-skipping scheduler: between arrivals and slot completions the
    pool state only counts down, so whole quiescent stretches are jumped
    in O(1) instead of ticked O(steps x slots) — a 10k-request diurnal
    trace (long idle troughs) schedules in well under a second while
    staying bitwise-identical to the per-step reference loop
    (`_trace_schedule_ref`, property-tested).

    Per-step semantics (mirrored exactly by `ServeEngine` with timed
    submission): at the start of step t, requests with arrival <= t are
    eligible, ordered by the policy key (FIFO: arrival then index;
    priority/preempt: tenant priority desc, then arrival, then index).
    Eligible requests fill free slots in order; under "preempt" the
    remaining eligible may then evict the most-recently-admitted active
    offline (non-interactive) request of strictly lower priority — the
    victim keeps its generated tokens and re-prefills on re-admission.
    Each live slot then decodes one token; requests finish at the step
    where their decode-token budget is spent.
    """
    if slots < 1:
        raise ValueError("slots must be >= 1")
    if policy not in POOL_POLICIES:
        raise ValueError(f"trace_schedule policy {policy!r} not in "
                         f"{POOL_POLICIES} (use the heterogeneity path "
                         "for 'disaggregated')")
    R = trace.n_requests
    arrival = np.asarray(trace.arrival_steps, np.int64)
    out = np.asarray(trace.out_lens, np.int64)
    decode_tokens = np.maximum(out - 1, 1)
    prio = trace.priorities()
    inter = np.array([t.interactive for t in trace.tenants],
                     bool)[np.array(trace.tenant_ids, np.int64)]
    key = _policy_key(policy, arrival, prio)

    admit_step = np.full(R, -1, np.int64)
    finish_step = np.full(R, -1, np.int64)
    remaining = decode_tokens.copy()
    ev_step: List[int] = []
    ev_req: List[int] = []
    ev_ctx: List[int] = []
    first_event = np.full(R, -1, np.int64)

    heap: List[Tuple] = []            # (key, rid) of waiting requests
    active: Dict[int, int] = {}       # slot -> rid
    slot_event: Dict[int, int] = {}   # slot -> admission event index
    free = list(range(slots - 1, -1, -1))   # pop() yields lowest index
    nxt = 0                           # arrival pointer
    t = 0
    n_decode = 0
    n_preempt = 0
    n_done = 0

    def emit(rid: int) -> int:
        e = len(ev_step)
        ev_step.append(t)
        ev_req.append(rid)
        ctx = trace.prompt_lens[rid]
        if admit_step[rid] < 0:
            admit_step[rid] = t
            first_event[rid] = e
        else:
            # resume: re-prefill prompt + everything generated so far
            # (first token + survived decode ticks)
            ctx += 1 + int(decode_tokens[rid] - remaining[rid])
        ev_ctx.append(int(ctx))
        return e

    while n_done < R:
        while nxt < R and arrival[nxt] <= t:
            heapq.heappush(heap, (key(nxt), nxt))
            nxt += 1
        evicted_now: List[Tuple] = []
        while heap and free:
            _, rid = heapq.heappop(heap)
            s = free.pop()
            active[s] = rid
            slot_event[s] = emit(rid)
        if policy == "preempt":
            while heap:
                k, rid = heap[0]
                victims = [s for s, v in active.items()
                           if not inter[v] and prio[v] < prio[rid]]
                if not victims:
                    break
                heapq.heappop(heap)
                s = max(victims, key=lambda s: slot_event[s])
                # victim keeps progress, rejoins the waiting set — but not
                # before the next step (no same-step re-admission)
                evicted_now.append((key(active[s]), active[s]))
                n_preempt += 1
                active[s] = rid
                slot_event[s] = emit(rid)
        for item in evicted_now:
            heapq.heappush(heap, item)
        if active:
            n_decode += 1
            for s in list(active):
                rid = active[s]
                remaining[rid] -= 1
                if remaining[rid] == 0:
                    finish_step[rid] = t
                    n_done += 1
                    del active[s]
                    del slot_event[s]
                    free.append(s)
            free.sort(reverse=True)
        t += 1
        if n_done >= R:
            break
        # --- event skip: nothing can change until the next arrival or the
        # next slot completion, provided no admission/eviction is possible
        # right now (free slot + waiter, or — for preempt — a waiter that
        # can evict; evicted_now waiters only became eligible this tick,
        # so a nonempty eviction round never skips)
        can_admit = bool(heap) and (bool(free) or (
            policy == "preempt" and any(
                not inter[v] and prio[v] < -heap[0][0][0]
                for v in active.values())))
        if can_admit or evicted_now:
            continue
        horizon = []
        if nxt < R:
            horizon.append(int(arrival[nxt]))
        if active:
            horizon.append(t + int(min(remaining[r]
                                       for r in active.values()) - 1))
        if not horizon:
            continue
        jump = max(horizon[0] if nxt >= R or not active
                   else min(horizon), t)
        dt = jump - t
        if dt > 0 and active:
            # bulk decode: no slot finishes strictly before `jump`
            n_decode += dt
            for rid in active.values():
                remaining[rid] -= dt
        t = jump

    n_steps = int(finish_step.max()) + 1
    return TraceSchedule(
        slots=slots, policy=policy, n_steps=n_steps,
        n_decode_steps=n_decode, admit_step=admit_step,
        finish_step=finish_step, decode_tokens=decode_tokens,
        n_preemptions=n_preempt,
        event_step=np.asarray(ev_step, np.int64),
        event_req=np.asarray(ev_req, np.int64),
        event_ctx=np.asarray(ev_ctx, np.int64),
        first_event=first_event)


def _trace_schedule_ref(trace: RequestTrace, slots: int,
                        policy: str = "fifo") -> TraceSchedule:
    """Per-step reference loop — the semantic spec `trace_schedule` must
    reproduce bitwise (and the loop `ServeEngine._admit`/`step` mirror).
    O(steps x slots); kept for property tests."""
    if slots < 1:
        raise ValueError("slots must be >= 1")
    if policy not in POOL_POLICIES:
        raise ValueError(f"trace_schedule policy {policy!r} not in "
                         f"{POOL_POLICIES}")
    R = trace.n_requests
    arrival = np.asarray(trace.arrival_steps, np.int64)
    decode_tokens = np.maximum(np.asarray(trace.out_lens, np.int64) - 1, 1)
    prio = trace.priorities()
    inter = np.array([t.interactive for t in trace.tenants],
                     bool)[np.array(trace.tenant_ids, np.int64)]
    key = _policy_key(policy, arrival, prio)

    admit_step = np.full(R, -1, np.int64)
    finish_step = np.full(R, -1, np.int64)
    remaining = decode_tokens.copy()
    ev_step, ev_req, ev_ctx = [], [], []
    first_event = np.full(R, -1, np.int64)
    waiting: List[int] = []
    active: Dict[int, int] = {}
    slot_event: Dict[int, int] = {}
    nxt = 0
    t = 0
    n_decode = 0
    n_preempt = 0

    def emit(rid):
        e = len(ev_step)
        ev_step.append(t)
        ev_req.append(rid)
        ctx = trace.prompt_lens[rid]
        if admit_step[rid] < 0:
            admit_step[rid] = t
            first_event[rid] = e
        else:
            ctx += 1 + int(decode_tokens[rid] - remaining[rid])
        ev_ctx.append(int(ctx))
        return e

    while nxt < R or waiting or active:
        while nxt < R and arrival[nxt] <= t:
            waiting.append(nxt)
            nxt += 1
        elig = sorted(waiting, key=key)
        for rid in list(elig):
            s = next((s for s in range(slots) if s not in active), None)
            if s is None:
                break
            elig.remove(rid)
            waiting.remove(rid)
            active[s] = rid
            slot_event[s] = emit(rid)
        if policy == "preempt":
            for rid in elig:
                victims = [s for s, v in active.items()
                           if not inter[v] and prio[v] < prio[rid]]
                if not victims:
                    continue
                s = max(victims, key=lambda s: slot_event[s])
                waiting.append(active[s])
                n_preempt += 1
                waiting.remove(rid)
                active[s] = rid
                slot_event[s] = emit(rid)
        if active:
            n_decode += 1
            for s in list(active):
                rid = active[s]
                remaining[rid] -= 1
                if remaining[rid] == 0:
                    finish_step[rid] = t
                    del active[s]
                    del slot_event[s]
        t += 1

    return TraceSchedule(
        slots=slots, policy=policy, n_steps=int(finish_step.max()) + 1,
        n_decode_steps=n_decode, admit_step=admit_step,
        finish_step=finish_step, decode_tokens=decode_tokens,
        n_preemptions=n_preempt,
        event_step=np.asarray(ev_step, np.int64),
        event_req=np.asarray(ev_req, np.int64),
        event_ctx=np.asarray(ev_ctx, np.int64),
        first_event=first_event)


# ---------------------------------------------------------------------------
# wall-clock metrics: schedule x candidate-axis step times (array math)
# ---------------------------------------------------------------------------


def _prefill_before(cum_p: np.ndarray, event_step: np.ndarray,
                    steps: np.ndarray, inclusive: bool) -> np.ndarray:
    """(C, len(steps)) prefill seconds of events with step < k (or <= k
    when inclusive), for each queried step k."""
    side = "right" if inclusive else "left"
    idx = np.searchsorted(event_step, steps, side=side)
    padded = np.concatenate(
        [np.zeros((cum_p.shape[0], 1)), cum_p], axis=1)
    return padded[:, idx]


def trace_serving_metrics(sched: TraceSchedule, trace: RequestTrace,
                          t_prefill_ref: np.ndarray, prompt_ref: int,
                          t_decode: np.ndarray,
                          window_steps: int = 64) -> Dict[str, np.ndarray]:
    """Broadcast wall-clock metrics over the candidate axis, PR 4 style:
    the step clock is the time base (every tick — decode or idle — costs
    one decode-step time; admission prefills serialize at step starts), so
    everything is affine in the per-candidate (t_prefill_ref, t_decode)
    pair and evaluates as pure array math. Per-request SLOs come from each
    request's tenant; `window_steps`-wide windows over the step axis give
    the worst-window interactive goodput (spike robustness)."""
    if window_steps < 1:
        raise ValueError("window_steps must be >= 1")
    tp = np.asarray(t_prefill_ref, np.float64).reshape(-1, 1)
    td = np.asarray(t_decode, np.float64).reshape(-1, 1)
    C = tp.shape[0]
    R = trace.n_requests

    p_ev = tp * sched.event_ctx[None, :] / max(prompt_ref, 1)   # (C, E)
    cum_p = np.cumsum(p_ev, axis=1)

    arrival = np.asarray(trace.arrival_steps, np.int64)
    arr_wall = arrival[None, :] * td + _prefill_before(
        cum_p, sched.event_step, arrival, inclusive=False)
    e0 = sched.first_event
    first_token = sched.event_step[e0][None, :] * td + cum_p[:, e0]
    ttft = first_token - arr_wall

    fin = sched.finish_step
    completion = (fin[None, :] + 1) * td + _prefill_before(
        cum_p, sched.event_step, fin, inclusive=True)
    tpot = (completion - first_token) \
        / np.maximum(sched.decode_tokens[None, :], 1)

    total_time = sched.n_steps * td[:, 0] + cum_p[:, -1]
    out_toks = np.asarray(trace.out_lens, np.float64)[None, :]

    b_ttft = np.array([t.ttft_s for t in trace.tenants])[
        np.array(trace.tenant_ids, np.int64)][None, :]
    b_tpot = np.array([t.tpot_s for t in trace.tenants])[
        np.array(trace.tenant_ids, np.int64)][None, :]
    met = (ttft <= b_ttft) & (tpot <= b_tpot)

    inter = trace.interactive_mask()[None, :]
    goodput = (out_toks * met).sum(axis=1) / np.maximum(total_time, 1e-12)
    inter_good = (out_toks * met * inter).sum(axis=1) \
        / np.maximum(total_time, 1e-12)

    # windowed goodput: cut the step axis into fixed windows; a request's
    # tokens land in the window containing its finish step, the window's
    # wall duration is its ticks plus the prefill seconds inside it, and
    # only windows with interactive demand (an interactive request
    # arrived/unfinished in the window) count toward the worst-window min
    W = max(1, -(-sched.n_steps // window_steps))
    win_good = np.zeros((C, W))
    pending = np.zeros(W, bool)
    inter_r = trace.interactive_mask()
    for w in range(W):
        w0, w1 = w * window_steps, min((w + 1) * window_steps, sched.n_steps)
        dur = (w1 - w0) * td[:, 0] + (
            _prefill_before(cum_p, sched.event_step,
                            np.array([w1 - 1]), True)
            - _prefill_before(cum_p, sched.event_step,
                              np.array([w0]), False))[:, 0]
        in_w = (fin >= w0) & (fin < w1)
        win_good[:, w] = (out_toks * met * (inter_r & in_w)[None, :]) \
            .sum(axis=1) / np.maximum(dur, 1e-12)
        pending[w] = bool(np.any(inter_r & (arrival < w1) & (fin >= w0)))
    worst = (win_good[:, pending].min(axis=1) if pending.any()
             else inter_good)

    return {
        "ttft": ttft, "tpot": tpot, "met": met,
        "total_time": total_time,
        "throughput": out_toks.sum() / np.maximum(total_time, 1e-12),
        "goodput": goodput,
        "interactive_goodput": inter_good,
        "window_goodput": win_good,
        "window_pending": pending,
        "worst_window_goodput": worst,
        "slo_attainment": met.mean(axis=1),
    }


# ---------------------------------------------------------------------------
# disaggregated (prefill/decode split) coupled model with timed arrivals
# ---------------------------------------------------------------------------


def trace_disaggregated_metrics(trace: RequestTrace, slots: int,
                                t_prefill: np.ndarray, kv_s: np.ndarray,
                                t_decode: float,
                                window_steps: int = 64) -> Dict[str, float]:
    """Timed-arrival generalization of `serving.disaggregated_metrics`
    (one candidate at a time — the stage split makes the schedule
    design-dependent, so this is the coupled continuous-time model):
    prompts prefill serially on their own stage in priority-then-arrival
    order as they arrive (arrival r = ``arrival_steps[r] * t_decode`` on
    the shared step clock), the KV cache ships to the decode stage, and a
    request joins the decode pool when its KV has landed and a slot is
    free — decode never stalls for prefills. Per-tenant SLOs; windows are
    ``window_steps * t_decode`` seconds wide."""
    if slots < 1:
        raise ValueError("slots must be >= 1")
    R = trace.n_requests
    arrival_s = np.asarray(trace.arrival_steps, np.float64) * t_decode
    t_p = np.asarray(t_prefill, np.float64)
    kv = np.broadcast_to(np.asarray(kv_s, np.float64), (R,))
    prio = trace.priorities()

    # -- prefill stage: single server, priority-then-arrival order -------
    order = sorted(range(R), key=lambda r: (arrival_s[r],))
    done = np.zeros(R)
    clock = 0.0
    served = np.zeros(R, bool)
    pending: List[Tuple] = []
    i = 0
    for _ in range(R):
        while i < R and arrival_s[order[i]] <= clock + 1e-12:
            r = order[i]
            heapq.heappush(pending, ((-prio[r], arrival_s[r], r), r))
            i += 1
        if not pending:
            clock = arrival_s[order[i]]
            continue
        _, r = heapq.heappop(pending)
        clock = max(clock, arrival_s[r]) + t_p[r]
        done[r] = clock
        served[r] = True
    for _, r in pending:                     # drain any stragglers
        clock = max(clock, arrival_s[r]) + t_p[r]
        done[r] = clock
    ttft = done - arrival_s
    ready = done + kv

    # -- decode pool: admit by (priority, ready) when a slot frees -------
    dtoks = np.maximum(np.asarray(trace.out_lens, np.int64) - 1, 1)
    completion = np.zeros(R)
    active: Dict[int, List[int]] = {}
    admitted = np.zeros(R, bool)
    t = 0.0
    n_steps = 0
    n_fin = 0
    while n_fin < R:
        while len(active) < slots:
            cand = [r for r in range(R)
                    if not admitted[r] and ready[r] <= t + 1e-12]
            if not cand:
                break
            r = min(cand, key=lambda r: (-prio[r], ready[r], r))
            slot = next(s for s in range(slots) if s not in active)
            active[slot] = [r, int(dtoks[r])]
            admitted[r] = True
        if not active:
            t = float(min(ready[r] for r in range(R) if not admitted[r]))
            continue
        t += t_decode
        n_steps += 1
        for slot in list(active):
            active[slot][1] -= 1
            if active[slot][1] == 0:
                completion[active[slot][0]] = t
                n_fin += 1
                del active[slot]
    tpot = (completion - done) / dtoks
    total_time = float(max(completion.max(), done.max()))

    out_toks = np.asarray(trace.out_lens, np.float64)
    b_ttft = np.array([tc.ttft_s for tc in trace.tenants])[
        np.array(trace.tenant_ids, np.int64)]
    b_tpot = np.array([tc.tpot_s for tc in trace.tenants])[
        np.array(trace.tenant_ids, np.int64)]
    met = (ttft <= b_ttft) & (tpot <= b_tpot)
    inter = trace.interactive_mask()

    win_s = max(window_steps, 1) * t_decode
    W = max(1, int(np.ceil(total_time / max(win_s, 1e-12))))
    worst = None
    inter_good = float((out_toks * met * inter).sum()
                       / max(total_time, 1e-12))
    for w in range(W):
        w0, w1 = w * win_s, (w + 1) * win_s
        if not np.any(inter & (arrival_s < w1) & (completion >= w0)):
            continue
        g = float((out_toks * met * inter
                   * ((completion >= w0) & (completion < w1))).sum()
                  / max(w1 - w0, 1e-12))
        worst = g if worst is None else min(worst, g)
    if worst is None:
        worst = inter_good

    return {
        "ttft_s": float(ttft.mean()), "ttft_max_s": float(ttft.max()),
        "tpot_s": float(tpot.mean()), "tpot_max_s": float(tpot.max()),
        "total_time_s": total_time,
        "n_steps": n_steps, "n_decode_steps": n_steps,
        "throughput_tok_s": float(out_toks.sum() / max(total_time, 1e-12)),
        "goodput_tok_s": float((out_toks * met).sum()
                               / max(total_time, 1e-12)),
        "interactive_goodput_tok_s": inter_good,
        "worst_window_goodput_tok_s": float(worst),
        "slo_attainment": float(met.mean()),
        "met": met, "ttft": ttft, "tpot": tpot,
    }


# ---------------------------------------------------------------------------
# design evaluation: per-step evals (fidelity registry) -> trace metrics
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PolicyDesign:
    """One (architecture, admission policy) search point — the policy axis
    of a ``"trace_serving"`` campaign, riding next to the 13 architecture
    dims the way `JointDesign` carries a pinned Strategy."""
    design: WSCDesign
    policy: str

    def __post_init__(self):
        if self.policy not in POLICIES:
            raise ValueError(f"policy {self.policy!r} not in {POLICIES}")

    def describe(self) -> str:
        return f"{self.design.describe()} | policy={self.policy}"


def sample_policy_candidates(rng: np.random.Generator, n: int,
                             policies: Sequence[str] = POLICIES,
                             max_tries: int = 8
                             ) -> Tuple[np.ndarray, List[PolicyDesign]]:
    """`mfmobo._valid_candidates` with one extra unit-cube column decoding
    to an admission policy: returns ((n, 14) encoded points, PolicyDesigns)
    — campaigns with a searched policy axis install this as the
    exploration loop's candidate_fn."""
    from repro.core.design_space import decode_batch, sample
    from repro.core.validator import validate_batch

    policies = tuple(policies)
    if not policies or any(p not in POLICIES for p in policies):
        raise ValueError(f"policies must be a nonempty subset of {POLICIES} "
                         f"(got {policies})")
    xs, ds = [], []
    n_drawn = 0
    for _ in range(max_tries):
        us = sample(rng, n)
        up = rng.random((n, 1))
        n_drawn += len(us)
        for u, p, r in zip(us, up[:, 0], validate_batch(decode_batch(us))):
            if r.ok:
                xs.append(np.concatenate([u, [p]]))
                k = min(int(p * len(policies)), len(policies) - 1)
                ds.append(PolicyDesign(r.design, policies[k]))
            if len(xs) >= n:
                return np.array(xs), ds
    rate = len(xs) / max(n_drawn, 1)
    raise RuntimeError(
        f"policy-space sampling produced only {len(xs)}/{n} valid "
        f"candidates after {max_tries} rounds (acceptance rate {rate:.1%})")


@dataclasses.dataclass
class TraceServingResult:
    feasible: bool
    policy: str
    goodput_tok_s: float
    interactive_goodput_tok_s: float
    worst_window_goodput_tok_s: float
    throughput_tok_s: float
    ttft_s: float                 # mean over requests
    ttft_max_s: float
    tpot_s: float
    tpot_max_s: float
    slo_attainment: float
    total_time_s: float
    n_steps: int
    n_decode_steps: int
    n_preemptions: int
    power_w: float
    energy_j: float
    n_wafers: int
    per_tenant: Dict[str, Dict[str, float]]
    reason: str = ""


def trace_serving_workloads(wl_base: LLMWorkload, trace: RequestTrace,
                            slots: int
                            ) -> Tuple[LLMWorkload, LLMWorkload, int]:
    """The two per-step workloads trace serving composes — identical
    convention to `serving.serving_workloads`, sized from the trace."""
    p_ref = max(1, int(round(trace.mean_prompt)))
    wl_p = dataclasses.replace(wl_base, phase="prefill", batch=1, seq=p_ref)
    wl_d = dataclasses.replace(wl_base, phase="decode", batch=slots,
                               seq=trace.context_len())
    return wl_p, wl_d, p_ref


def _infeasible(policy: str, nw: int, reason: str) -> TraceServingResult:
    return TraceServingResult(
        feasible=False, policy=policy, goodput_tok_s=0.0,
        interactive_goodput_tok_s=0.0, worst_window_goodput_tok_s=0.0,
        throughput_tok_s=0.0, ttft_s=float("inf"), ttft_max_s=float("inf"),
        tpot_s=float("inf"), tpot_max_s=float("inf"), slo_attainment=0.0,
        total_time_s=float("inf"), n_steps=0, n_decode_steps=0,
        n_preemptions=0, power_w=float("inf"), energy_j=0.0, n_wafers=nw,
        per_tenant={}, reason=reason)


def _per_tenant(trace: RequestTrace, met: np.ndarray, ttft: np.ndarray,
                tpot: np.ndarray, total_time: float) -> Dict[str, Dict]:
    out = {}
    tids = np.array(trace.tenant_ids, np.int64)
    toks = np.asarray(trace.out_lens, np.float64)
    for k, tc in enumerate(trace.tenants):
        m = tids == k
        if not m.any():
            continue
        out[tc.name] = {
            "n_requests": int(m.sum()),
            "goodput_tok_s": float((toks[m] * met[m]).sum()
                                   / max(total_time, 1e-12)),
            "slo_attainment": float(met[m].mean()),
            "ttft_s": float(ttft[m].mean()),
            "tpot_s": float(tpot[m].mean()),
        }
    return out


_SCHED_CACHE: Dict[Tuple, TraceSchedule] = {}


def _schedule_cached(trace: RequestTrace, slots: int,
                     policy: str) -> TraceSchedule:
    key = (trace, slots, policy)
    if key not in _SCHED_CACHE:
        if len(_SCHED_CACHE) > 64:
            _SCHED_CACHE.clear()
        _SCHED_CACHE[key] = trace_schedule(trace, slots, policy)
    return _SCHED_CACHE[key]


def evaluate_trace_serving_batch(
        designs: Sequence[Union[WSCDesign, PolicyDesign]],
        wl_base: LLMWorkload, trace: RequestTrace, *, slots: int = 8,
        policy: str = "fifo", window_steps: int = 64,
        prefill_ratio: float = 0.5, fidelity: Fidelity = "analytical",
        gnn_params: Optional[Dict] = None, n_wafers=None,
        max_strategies: int = 24) -> List[TraceServingResult]:
    """Trace-driven serving metrics for N candidates. Candidates are
    `WSCDesign`s (scored under `policy`) or `PolicyDesign`s (each scored
    under its own policy — the searched axis). Pool policies share one
    design-independent `trace_schedule` per policy and broadcast
    `trace_serving_metrics` over the candidate axis; "disaggregated"
    routes through `heterogeneity.evaluate_hetero_trace_serving`'s coupled
    prefill/decode-split model (reticle granularity, `prefill_ratio`)."""
    from repro.core.evaluator import evaluate_design_batch
    from repro.core.fidelity import get_backend

    backend = get_backend(fidelity)
    designs = list(designs)
    if not designs:
        return []
    raw: List[WSCDesign] = []
    pols: List[str] = []
    for d in designs:
        if isinstance(d, PolicyDesign):
            raw.append(d.design)
            pols.append(d.policy)
        else:
            raw.append(d)
            pols.append(policy)
    for p in pols:
        if p not in POLICIES:
            raise ValueError(f"policy {p!r} not in {POLICIES}")

    results: List[Optional[TraceServingResult]] = [None] * len(designs)

    # ---- disaggregated candidates: coupled split model, per design -----
    dis = [i for i, p in enumerate(pols) if p == "disaggregated"]
    if dis:
        from repro.core.heterogeneity import evaluate_hetero_trace_serving
        for i in dis:
            results[i] = evaluate_hetero_trace_serving(
                raw[i], raw[i], wl_base, "reticle", prefill_ratio, trace,
                slots=slots, window_steps=window_steps, n_wafers=n_wafers,
                fidelity=backend, gnn_params=gnn_params)

    # ---- pool candidates: shared schedule per policy, broadcast math ---
    pool = [i for i, p in enumerate(pols) if p != "disaggregated"]
    if not pool:
        return results                      # type: ignore[return-value]
    wl_p, wl_d, p_ref = trace_serving_workloads(wl_base, trace, slots)
    rps = evaluate_design_batch([raw[i] for i in pool], wl_p,
                                fidelity=backend, gnn_params=gnn_params,
                                n_wafers=n_wafers,
                                max_strategies=max_strategies)
    rds = evaluate_design_batch([raw[i] for i in pool], wl_d,
                                fidelity=backend, gnn_params=gnn_params,
                                n_wafers=n_wafers,
                                max_strategies=max_strategies)
    for pol in sorted({pols[i] for i in pool}):
        grp = [j for j, i in enumerate(pool) if pols[i] == pol]
        feas = [j for j in grp if rps[j].feasible and rds[j].feasible]
        for j in grp:
            if j not in feas:
                reason = ("prefill_" if not rps[j].feasible else
                          "decode_") + "infeasible"
                results[pool[j]] = _infeasible(pol, rps[j].n_wafers, reason)
        if not feas:
            continue
        sched = _schedule_cached(trace, slots, pol)
        t_p = np.array([rps[j].step.step_time_s for j in feas])
        t_d = np.array([rds[j].step.step_time_s for j in feas])
        e_p = np.array([rps[j].step.energy_j for j in feas])
        e_d = np.array([rds[j].step.energy_j for j in feas])
        m = trace_serving_metrics(sched, trace, t_p, p_ref, t_d,
                                  window_steps=window_steps)
        # energy: each prefill event costs its context-scaled share of the
        # reference prefill step; each decode tick costs the batched
        # decode step (idle ticks cost wall-clock only)
        ctx_sum = float(np.sum(sched.event_ctx))
        energy = e_p * ctx_sum / p_ref + e_d * sched.n_decode_steps
        power = energy / np.maximum(m["total_time"], 1e-12)
        for c, j in enumerate(feas):
            results[pool[j]] = TraceServingResult(
                feasible=True, policy=pol,
                goodput_tok_s=float(m["goodput"][c]),
                interactive_goodput_tok_s=float(
                    m["interactive_goodput"][c]),
                worst_window_goodput_tok_s=float(
                    m["worst_window_goodput"][c]),
                throughput_tok_s=float(m["throughput"][c]),
                ttft_s=float(m["ttft"][c].mean()),
                ttft_max_s=float(m["ttft"][c].max()),
                tpot_s=float(m["tpot"][c].mean()),
                tpot_max_s=float(m["tpot"][c].max()),
                slo_attainment=float(m["slo_attainment"][c]),
                total_time_s=float(m["total_time"][c]),
                n_steps=sched.n_steps,
                n_decode_steps=sched.n_decode_steps,
                n_preemptions=sched.n_preemptions,
                power_w=float(power[c]), energy_j=float(energy[c]),
                n_wafers=rds[j].n_wafers,
                per_tenant=_per_tenant(trace, m["met"][c], m["ttft"][c],
                                       m["tpot"][c],
                                       float(m["total_time"][c])))
    return results                          # type: ignore[return-value]


def evaluate_trace_serving(design, wl_base: LLMWorkload,
                           trace: RequestTrace, **kw) -> TraceServingResult:
    """Scalar wrapper: `evaluate_trace_serving_batch` with a batch of
    one."""
    return evaluate_trace_serving_batch([design], wl_base, trace, **kw)[0]


__all__ = [
    "DEFAULT_TENANT", "POLICIES", "POOL_POLICIES", "PolicyDesign",
    "RequestTrace", "TenantClass", "TraceSchedule", "TraceServingResult",
    "diurnal_trace", "evaluate_trace_serving",
    "evaluate_trace_serving_batch", "poisson_trace",
    "sample_policy_candidates", "spike_trace", "synth_trace",
    "trace_disaggregated_metrics", "trace_schedule",
    "trace_serving_metrics", "trace_serving_workloads",
]
