"""Reference designs for the paper's comparisons (§IX): an H100-like GPU, a
Cerebras-WSE2-like WSC and a Tesla-Dojo-like WSC, all scaled to 14 nm like
the paper (Villa et al. scaling factors) and evaluated under the same
evaluator at matched total silicon area.

Published inputs: H100 [SXM spec sheet], WSE2 [Hot Chips '22], Dojo
[Hot Chips '22]. The paper ignores H100 yield + NVLink SerDes area (§IX-F);
we do the same.
"""
from __future__ import annotations

import dataclasses

import numpy as np
from typing import Dict, Optional, Tuple

from repro.core import components as C
from repro.core.design_space import WSCDesign
from repro.core.evaluator import EvalResult, Fidelity, evaluate_design
from repro.core.workload import BYTES, LLMWorkload

H100_AREA_MM2 = 814.0


@dataclasses.dataclass(frozen=True)
class GPUSpec:
    name: str = "H100-like"
    area_mm2: float = H100_AREA_MM2
    flops: float = 660e12          # bf16 dense, scaled to 14 nm clocks
    hbm_bw: float = 3.35e12
    hbm_gb: float = 80.0
    interconnect_bw: float = 450e9  # NVLink per direction
    power_w: float = 700.0
    sram_bytes: float = 50e6


def gpu_cluster_eval(wl: LLMWorkload, spec: GPUSpec = GPUSpec(),
                     mqa: bool = False) -> Tuple[float, float]:
    """Analytical GPU-cluster model (same methodology granularity as the
    WSC chunk level): compute, HBM, and interconnect terms."""
    n = wl.gpu_budget
    flops = wl.flops_per_step()

    kv_mult = (wl.n_kv / max(wl.n_heads, 1)) if not mqa else 1.0 / max(
        wl.n_heads, 1)
    if wl.phase == "decode":
        # Fixed total batch (paper §VIII-A: batch 32): extra same-area GPUs
        # beyond (model-holding replicas x batch) add nothing — this
        # under-utilization is precisely the paper's decode motivation.
        n_model = max(1, int(np.ceil(wl.params_bytes()
                                     / (spec.hbm_gb * 1e9 * 0.8))))
        n_model = max(n_model, 8) if wl.params_bytes() > 8e9 else n_model
        dp = min(wl.batch, max(n // n_model, 1))
        n = min(n, n_model * dp)
        compute_s = flops / (n * spec.flops * 0.45)
        # weights + KV read per emitted token (batch amortizes weights)
        w_bytes = wl.params_bytes() * dp       # each replica reads weights
        kv = wl.kv_bytes_per_layer() * wl.n_layers * kv_mult
        hbm_s = (w_bytes + kv) / (n * spec.hbm_bw)
    else:
        compute_s = flops / (n * spec.flops * 0.45)
        hbm_s = 2.5 * wl.params_bytes() / (n * spec.hbm_bw)

    # TP within a node (8 GPUs), DP across nodes
    tp = min(8, n)
    act = wl.tokens_per_step() * wl.d_model * BYTES
    coll_s = (2.0 * (tp - 1) / tp * act * 2 * wl.n_layers
              / (n * spec.interconnect_bw))
    if wl.phase == "train":
        coll_s += 2.0 * wl.params_bytes() / (n * spec.interconnect_bw)

    step_s = max(compute_s, hbm_s) + coll_s
    thpt = wl.tokens_per_step() / step_s
    util = min(compute_s / step_s, 1.0)
    power = n * spec.power_w * (0.35 + 0.65 * util)
    return thpt, power


# WSC baselines expressed as design points of OUR space (closest grid
# configuration to the published architectures)
WSE2_LIKE = WSCDesign(
    dataflow="WS", mac_num=16, buffer_kb=48, buffer_bw=512, noc_bw=256,
    core_array=(32, 32), inter_reticle_bw_ratio=1.0,
    use_stacked_dram=False, dram_bw_tbps_per_100mm2=0.25,
    reticle_array=(7, 12), integration="die_stitching",
)

DOJO_LIKE = WSCDesign(
    dataflow="OS", mac_num=512, buffer_kb=1024, buffer_bw=2048, noc_bw=512,
    core_array=(16, 20), inter_reticle_bw_ratio=0.5,
    use_stacked_dram=False, dram_bw_tbps_per_100mm2=0.25,
    reticle_array=(5, 5), integration="infosow",
)


def wsc_baseline_eval(design: WSCDesign, wl: LLMWorkload,
                      fidelity: Fidelity = "analytical",
                      gnn_params: Optional[Dict] = None) -> EvalResult:
    """Evaluate a published-architecture-like design point through the same
    engine (and fidelity backend registry) as the explored candidates."""
    return evaluate_design(design, wl, fidelity=fidelity,
                           gnn_params=gnn_params)
