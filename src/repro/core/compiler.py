"""Workload Compiler (paper §VI-A).

(1) Operator-graph generation: the LLM is segmented into model chunks by the
    parallel strategy (TP x PP x DP); compute resources divide evenly.
(2) Partition/allocation: each chunk's representative layer chain (uniform
    LLM stacks) is partitioned over the chunk's 2-D core grid.
(3) Task scheduling: ops are tiled per core (tile_eval) and inter-op
    redistribution transfers are generated at core granularity.
(4) Mapping & routing: logical cores map row-major onto the physical array;
    transfers take XY routes; per-link volumes and injection rates feed the
    op-level NoC estimators (analytical / GNN / simulator).

DRAM access and inter-chunk (TP/PP/DP) communication are handled at the
chunk level (paper §VI-D), not here.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.design_space import WSCDesign, floor_log2
from repro.core.tile_eval import TileResult, evaluate_tile
from repro.core.workload import BYTES, GEMMOp, LLMWorkload


@dataclasses.dataclass
class OpNode:
    op: GEMMOp
    tile: TileResult               # per-core tile evaluation
    grid: Tuple[int, int]          # (gh, gw) logical core grid


@dataclasses.dataclass
class Transfer:
    src_op: int
    dst_op: int
    pairs: List[Tuple[int, int, float]]    # (src_core, dst_core, bytes)

    def total_bytes(self) -> float:
        return sum(p[2] for p in self.pairs)


@dataclasses.dataclass
class ChunkGraph:
    array: Tuple[int, int]                 # physical chunk grid (H, W)
    ops: List[OpNode]
    transfers: List[Transfer]
    link_loads: np.ndarray                 # (n_links,) bytes per directed link
    link_flows: np.ndarray                 # (n_links,) flow count per link
    link_index: Dict[Tuple[int, int], int] # (core_u, core_v) -> link id
    n_cores: int
    routes: Optional[Dict[Tuple[int, int], List[Tuple[int, int]]]] = \
        dataclasses.field(default=None)                          # pair->hops

    def injection_rates(self, noc_bw_bits: int) -> np.ndarray:
        """flits/cycle injected per core, averaged over the chunk runtime.
        A chunk whose ops report zero compute cycles has no defined runtime
        to average over — injection is zero, not divided by a fake cycle."""
        inj = np.zeros(self.n_cores)
        total_cycles = sum(o.tile.cycles for o in self.ops)
        if total_cycles <= 0.0:
            return inj
        flit_bytes = noc_bw_bits / 8.0
        for t in self.transfers:
            for s, _, b in t.pairs:
                inj[s] += b / max(flit_bytes, 1.0)
        return inj / total_cycles


def _grid_for(n_cores: int) -> Tuple[int, int]:
    gh = 2 ** (int(math.log2(max(n_cores, 1))) // 2)
    return gh, max(n_cores // gh, 1)


def grid_for_batch(n_cores: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized `_grid_for` over an int array."""
    n = np.maximum(np.asarray(n_cores, np.int64), 1)
    gh = np.int64(1) << (floor_log2(n) // 2)
    return gh, np.maximum(n // gh, 1)


def _xy_route(src: int, dst: int, W: int) -> List[Tuple[int, int]]:
    """XY (row-first) route as a list of directed core-to-core hops."""
    r1, c1 = divmod(src, W)
    r2, c2 = divmod(dst, W)
    hops = []
    c = c1
    while c != c2:
        nc = c + (1 if c2 > c else -1)
        hops.append((r1 * W + c, r1 * W + nc))
        c = nc
    r = r1
    while r != r2:
        nr = r + (1 if r2 > r else -1)
        hops.append((r * W + c2, nr * W + c2))
        r = nr
    return hops


def compile_chunk(design: WSCDesign, wl: LLMWorkload, tp: int,
                  mb_tokens: int, cores_per_chunk: int,
                  grid_cap: int = 64) -> ChunkGraph:
    """Compile one model chunk's representative layer onto its core region.

    Hierarchical scale reduction (paper §VI): per-core tiles are sized by the
    TRUE chunk grid (cores_per_chunk), while the NoC graph is built on a
    capped representative grid — congestion patterns at equal per-core tile
    size are grid-size invariant for the row-redistribution pattern."""
    gh_t, gw_t = _grid_for(cores_per_chunk)
    gh, gw = _grid_for(min(cores_per_chunk, grid_cap))
    n_cores = gh * gw
    H, W = gh, gw

    ops = wl.layer_ops(tp=tp, mb_tokens=mb_tokens)
    nodes: List[OpNode] = []
    for op in ops:
        # per-core tile: split M over gh_t, N over gw_t (true grid)
        tile_gemm = GEMMOp(op.name,
                           max(op.M // gh_t, 1), op.K, max(op.N // gw_t, 1),
                           op.weight)
        tr = evaluate_tile(tile_gemm, design.mac_num, design.buffer_kb,
                           design.buffer_bw, design.dataflow)
        nodes.append(OpNode(op, tr, (gh_t, gw_t)))

    # inter-op redistribution: producer (a, b) -> consumers (a, b') in its
    # row (the next GEMM contracts over the previous output dim, so each
    # consumer needs the full row block = row-wise all-gather pattern)
    transfers: List[Transfer] = []
    link_index: Dict[Tuple[int, int], int] = {}
    loads: List[float] = []
    flows: List[float] = []
    routes: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}

    def link_id(u, v):
        key = (u, v)
        if key not in link_index:
            link_index[key] = len(loads)
            loads.append(0.0)
            flows.append(0.0)
        return link_index[key]

    for i in range(len(nodes) - 1):
        out_b = nodes[i].op.out_bytes()
        # row all-gather: each producer's tile (out_b / n_cores) goes to the
        # other gw-1 consumers in its row; total moved = (gw-1) x out_b
        per_pair = out_b / n_cores if gw > 1 else 0.0
        pairs = []
        if gw > 1:
            for a in range(gh):
                for b in range(gw):
                    src = a * W + b
                    for b2 in range(gw):
                        if b2 == b:
                            continue
                        dst = a * W + b2
                        pairs.append((src, dst, per_pair))
                        if (src, dst) not in routes:
                            routes[(src, dst)] = _xy_route(src, dst, W)
                        for (u, v) in routes[(src, dst)]:
                            lid = link_id(u, v)
                            loads[lid] += per_pair
                            flows[lid] += 1.0
        transfers.append(Transfer(i, i + 1, pairs))

    return ChunkGraph(array=(H, W), ops=nodes, transfers=transfers,
                      link_loads=np.array(loads), link_flows=np.array(flows),
                      link_index=link_index, n_cores=n_cores, routes=routes)


# ---------------------------------------------------------------------------
# row-all-gather transfer pattern (DESIGN.md §4b) — the design-independent
# structure of the transfers `compile_chunk` emits on a (gh, gw) grid:
# pair list, per-source injection sequence, link set and per-pair routes.
# The batched gnn/sim fidelity backends featurize/simulate from these tables
# instead of materializing ChunkGraph objects; `featurize_transfer` /
# `packets_for_transfer` remain the scalar reference the tables are tested
# against.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RowAllGatherPattern:
    gh: int
    gw: int
    n_cores: int
    src: np.ndarray          # (P,) producer core per pair, compile order
    dst: np.ndarray          # (P,)
    seq: np.ndarray          # (P,) per-source injection sequence number
    links: np.ndarray        # (E, 2) directed links, sorted lexicographically
    senders: np.ndarray      # (E,) int32 — links[:, 0]
    receivers: np.ndarray    # (E,) int32 — links[:, 1]
    flows: np.ndarray        # (E,) float64 — pair routes crossing each link
    out_deg: np.ndarray      # (n_cores,) float64
    in_deg: np.ndarray       # (n_cores,) float64
    route_eids: np.ndarray   # (P, Lmax) int32 link ids per hop, pad = E
    route_len: np.ndarray    # (P,) int32


_PATTERN_CACHE: Dict[Tuple[int, int], RowAllGatherPattern] = {}


def row_allgather_pattern(gh: int, gw: int) -> RowAllGatherPattern:
    """Memoized transfer structure of one `compile_chunk` inter-op edge on a
    (gh, gw) grid. Pair / sequence order matches `compile_chunk`'s loops and
    `packets_for_transfer`'s per-source numbering exactly; link order matches
    `featurize_transfer`'s `sorted(link_flits)`."""
    key = (int(gh), int(gw))
    hit = _PATTERN_CACHE.get(key)
    if hit is not None:
        return hit
    gh, gw = key
    W = gw
    n_cores = gh * gw
    srcs: List[int] = []
    dsts: List[int] = []
    seqs: List[int] = []
    routes: List[List[Tuple[int, int]]] = []
    link_flows: Dict[Tuple[int, int], float] = {}
    if gw > 1:
        for a in range(gh):
            for b in range(gw):
                src = a * W + b
                seq = 0
                for b2 in range(gw):
                    if b2 == b:
                        continue
                    dst = a * W + b2
                    hops = _xy_route(src, dst, W)
                    srcs.append(src)
                    dsts.append(dst)
                    seqs.append(seq)
                    seq += 1
                    routes.append(hops)
                    for hop in hops:
                        link_flows[hop] = link_flows.get(hop, 0.0) + 1.0
    links = sorted(link_flows)
    eid = {l: i for i, l in enumerate(links)}
    E = len(links)
    out_deg = np.zeros(n_cores)
    in_deg = np.zeros(n_cores)
    for u, v in links:
        out_deg[u] += 1
        in_deg[v] += 1
    lmax = max((len(r) for r in routes), default=0)
    route_eids = np.full((len(routes), max(lmax, 1)), E, np.int32)
    route_len = np.zeros(len(routes), np.int32)
    for i, r in enumerate(routes):
        route_len[i] = len(r)
        for j, hop in enumerate(r):
            route_eids[i, j] = eid[hop]
    pat = RowAllGatherPattern(
        gh=gh, gw=gw, n_cores=n_cores,
        src=np.array(srcs, np.int32), dst=np.array(dsts, np.int32),
        seq=np.array(seqs, np.int32),
        links=np.array(links, np.int32).reshape(-1, 2),
        senders=np.array([u for u, _ in links], np.int32),
        receivers=np.array([v for _, v in links], np.int32),
        flows=np.array([link_flows[l] for l in links], np.float64),
        out_deg=out_deg, in_deg=in_deg,
        route_eids=route_eids, route_len=route_len)
    if len(_PATTERN_CACHE) > 256:
        _PATTERN_CACHE.pop(next(iter(_PATTERN_CACHE)))
    _PATTERN_CACHE[key] = pat
    return pat


# ---------------------------------------------------------------------------
# parallel strategy enumeration (paper §VI-A last paragraph)
# ---------------------------------------------------------------------------


SCHEDULES = ("1f1b", "gpipe")


@dataclasses.dataclass(frozen=True)
class Strategy:
    tp: int
    pp: int
    dp: int
    microbatches: int
    # joint-search extensions (ISSUE 9): expert parallelism, activation
    # recomputation and the pipeline schedule. Defaults reproduce the
    # legacy 4-field strategies, so grid-mode campaigns and their cached
    # EvalResults are unchanged.
    ep: int = 1
    recompute: bool = False
    schedule: str = "1f1b"

    def chunks(self) -> int:
        return self.pp * self.dp


def strategy_memory_need(wl: LLMWorkload, tp, pp, dp, mb,
                         ep=1, recompute=False, gpipe=False):
    """System-wide memory footprint of a strategy (bytes), recompute- and
    schedule-aware. NumPy-polymorphic: scalars or broadcastable arrays.

    Terms (the v2 model — the legacy grid keeps the frozen PR 2 check so
    existing campaign traces replay bit-identically, see `_strategy_grid`):
      * weights+optimizer: dp replicas each hold params/pp; `opt_mult`
        (weights+grads+Adam moments) applies uniformly — the legacy check
        only applied it on the train branch;
      * MoE expert weights additionally divide by `ep`;
      * activations: each pipeline stage keeps one microbatch's
        activations per resident layer; recompute keeps only the stage
        boundary activation; GPipe keeps all `mb` microbatches in flight,
        1F1B at most `pp`;
      * KV cache (inference): splits across replicas, constant total.
    """
    pp = np.maximum(pp, 1)
    ep = np.maximum(ep, 1)
    train = wl.phase == "train"
    opt_mult = 6.0 if train else 1.0   # weights + grads + 2 Adam moments
    p_bytes = wl.params_bytes()
    p_exp = wl.expert_params_bytes()
    w_shard = np.where(ep > 1, (p_bytes - p_exp) + p_exp / ep, p_bytes)
    need = dp * w_shard * opt_mult / pp
    mb_count = mb if train else np.ones_like(np.asarray(mb))
    mb_tokens = np.maximum(wl.tokens_per_step() // (dp * mb_count), 1)
    layers_per_stage = np.maximum(wl.n_layers // pp, 1)
    stored_layers = np.where(recompute, 1, layers_per_stage)
    inflight = np.where(gpipe, mb_count, np.minimum(mb_count, pp))
    act = (wl.act_bytes_per_layer(mb_tokens) * stored_layers * inflight
           * pp * dp)
    need = need + act
    if not train:
        need = need + wl.kv_bytes_per_layer() * wl.n_layers
    return need


def pinned_resource_ok(wl: LLMWorkload, geom, n_wafers, tp, pp, dp, mb
                       ) -> np.ndarray:
    """Resource-fit mask for pinned (joint-mode) strategies: the exact
    feasibility arithmetic the grid path applies at enumeration
    (`feasible_strategy_arrays` / the compiled grid body) — core count
    (chunks x tp must fit the system) and the frozen legacy memory check —
    evaluated for one pinned strategy per design. Using the grid's own
    formulas (not the v2 model) keeps the replay contract intact: a
    strategy the grid argmin crowned can never be rejected here, while a
    physically impossible pinned point (cores or memory) can no longer be
    scored feasible. The recompute/schedule-aware v2 model gates the
    *search* side (`validator.validate_joint_batch`).

    One deliberate asymmetry: when *nothing* in the enumeration grid fits a
    system, `feasible_strategy_arrays` falls back to Strategy(1,1,1,1) and
    grid mode evaluates it anyway — so a pinned (1,1,1,1) is accepted
    exactly when that fallback would have fired, and only then.

    `geom` is a DesignBatch (duck-typed: buffer_kb / total_cores /
    dram_gb_per_reticle / n_reticles arrays); tp/pp/dp/mb are (N,) int
    arrays. Shared by the NumPy (`fidelity._finish`) and compiled
    (`eval_compiled`) pinned paths, so the two gates agree bitwise."""
    nw = np.asarray(n_wafers, np.int64)
    tp = np.asarray(tp, np.int64)
    pp = np.asarray(pp, np.int64)
    dp = np.asarray(dp, np.int64)
    mb = np.asarray(mb, np.int64)
    tc = np.asarray(geom.total_cores, np.int64) * nw
    sram_total = geom.buffer_kb * 1024.0 * geom.total_cores * nw
    dram_total = geom.dram_gb_per_reticle * 1e9 * geom.n_reticles * nw
    budget = sram_total + dram_total
    p_bytes = wl.params_bytes()
    if wl.phase == "train":
        need = dp * p_bytes * 6.0 / np.maximum(pp, 1)
    else:
        need = (dp * p_bytes / np.maximum(pp, 1)
                + wl.kv_bytes_per_layer() * wl.n_layers)
    fits = (pp * dp * tp <= tc) & (tp <= tc) & (need <= budget)
    g = _strategy_grid(wl)
    grid_has_fit = ((g["chunks"][None, :] * g["tp"][None, :]
                     <= tc[:, None])
                    & (g["tp"][None, :] <= tc[:, None])
                    & (g["need"][None, :] <= budget[:, None])).any(axis=1)
    is_fallback = (tp == 1) & (pp == 1) & (dp == 1) & (mb == 1)
    return fits | (is_fallback & ~grid_has_fit)


def derived_strategy_caps(wl: LLMWorkload, total_cores: int
                          ) -> Dict[str, int]:
    """Largest power-of-two value of each strategy axis the design/workload
    pair admits — replaces the historical magic constants (tp <= 4096,
    pp <= 64) with caps derived from the actual core count and layer
    count. `ep` caps at the expert count (1 for dense models)."""
    def p2(n: int) -> int:
        return 1 << max(int(n), 1).bit_length() - 1

    return {
        "tp": p2(max(total_cores, 1)),
        "pp": p2(min(wl.n_layers, max(total_cores, 1))),
        "dp": p2(max(wl.batch, 1)),
        "ep": p2(max(wl.moe_experts, 1)),
        "microbatches": 32 if wl.phase == "train" else 1,
    }


def enumerate_strategies(design: WSCDesign, wl: LLMWorkload,
                         n_wafers: int = 1,
                         memory_model: str = "v2") -> List[Strategy]:
    """All (TP, DP, PP, micro-batch) combos satisfying memory capacity
    (paper: iterate all combinations that satisfy the memory constraint).

    Caps are derived from the design (`total_cores`) and workload
    (`n_layers`, `batch`) — a 128-layer model can use pp=128, a
    million-core system tp > 4096. `memory_model` picks the feasibility
    check: "v2" (default) is the recompute-aware `strategy_memory_need`;
    "grid" is the frozen legacy check that `feasible_strategy_arrays` /
    the compiled evaluator bake in (kept so the scalar path stays
    element-identical to grid-mode evaluation and recorded campaign
    traces). Since ISSUE 9 this is a seeding/fallback path — joint-mode
    campaigns search the strategy axis directly (design_space.
    StrategySpace) and validate through `validator.validate_joint_batch`.
    """
    total_cores = design.total_cores() * n_wafers
    sram_total = design.buffer_kb * 1024.0 * total_cores
    dram_total = design.dram_gb_per_reticle() * 1e9 * design.n_reticles() * n_wafers
    mem_budget = sram_total + dram_total
    p_bytes = wl.params_bytes()
    opt_mult = 6.0 if wl.phase == "train" else 1.0   # weights+grads+adam
    out: List[Strategy] = []
    pows = [2 ** i for i in range(0, 17)]
    for pp in [p for p in pows if p <= wl.n_layers]:
        for dp in [d for d in pows if d <= max(wl.batch, 1)]:
            for tp in pows:
                chunks = pp * dp
                if chunks * tp > total_cores or tp > total_cores:
                    continue
                for mb in (1, 2, 4, 8, 16, 32):
                    if wl.phase != "train" and mb > 1:
                        continue
                    if wl.batch % (dp * (mb if wl.phase == "train" else 1)):
                        continue
                    if memory_model == "v2":
                        need = float(strategy_memory_need(wl, tp, pp, dp, mb))
                    else:
                        # frozen legacy check (see _strategy_grid)
                        need = dp * p_bytes * opt_mult / max(pp, 1)
                        if wl.phase != "train":
                            need = dp * p_bytes / max(pp, 1)
                            need += wl.kv_bytes_per_layer() * wl.n_layers
                    if need > mem_budget:
                        continue
                    out.append(Strategy(tp, pp, dp, mb))
    return out or [Strategy(1, 1, 1, 1)]


def strategy_sort_key(s: Strategy) -> Tuple:
    """Search-order heuristic: prefer modest TP, deep pipelines last."""
    return (abs(math.log2(max(s.tp, 1)) - 5), s.pp, -s.microbatches)


# --------------------------------------------------------------------------
# batched strategy enumeration (DESIGN.md §4) — the design-independent part
# of `enumerate_strategies` precomputed once per workload as a combo grid,
# so per-design feasibility is a couple of vectorized comparisons.
# --------------------------------------------------------------------------

_STRATEGY_GRID_CACHE: Dict[Tuple, Dict[str, np.ndarray]] = {}


def _strategy_grid(wl) -> Dict[str, np.ndarray]:
    key = (wl.n_layers, wl.batch, wl.phase, wl.params_bytes(),
           wl.kv_bytes_per_layer())
    hit = _STRATEGY_GRID_CACHE.get(key)
    if hit is not None:
        return hit
    p_bytes = wl.params_bytes()
    opt_mult = 6.0 if wl.phase == "train" else 1.0
    pows = [2 ** i for i in range(0, 17)]
    tps, pps, dps, mbs, needs = [], [], [], [], []
    # Caps derive from the workload (pp <= n_layers, tp unbounded up to the
    # per-design core-count mask applied later); the memory column `need`
    # stays the frozen PR 2 formula — this grid is the grid-mode replay
    # contract (recorded campaign traces, fig8 fixtures) and must keep the
    # exact historical feasibility bits. The recompute-aware v2 model
    # (`strategy_memory_need`) lives in the joint-search path.
    for pp in [p for p in pows if p <= wl.n_layers]:
        for dp in [d for d in pows if d <= max(wl.batch, 1)]:
            for tp in pows:
                if wl.phase == "train":
                    need = dp * p_bytes * opt_mult / max(pp, 1)
                else:
                    need = (dp * p_bytes / max(pp, 1)
                            + wl.kv_bytes_per_layer() * wl.n_layers)
                for mb in (1, 2, 4, 8, 16, 32):
                    if wl.phase != "train" and mb > 1:
                        continue
                    if wl.batch % (dp * (mb if wl.phase == "train" else 1)):
                        continue
                    tps.append(tp); pps.append(pp); dps.append(dp)
                    mbs.append(mb); needs.append(need)
    tp = np.array(tps, np.int64)
    pp = np.array(pps, np.int64)
    dp = np.array(dps, np.int64)
    mb = np.array(mbs, np.int64)
    need = np.array(needs, np.float64)
    # stable sort by strategy_sort_key; lexsort primary = last key
    order = np.lexsort((-mb, pp, np.abs(np.log2(np.maximum(tp, 1)) - 5.0)))
    grid = {"tp": tp, "pp": pp, "dp": dp, "mb": mb, "need": need,
            "chunks": pp * dp, "order": order}
    if len(_STRATEGY_GRID_CACHE) > 64:
        _STRATEGY_GRID_CACHE.pop(next(iter(_STRATEGY_GRID_CACHE)))
    _STRATEGY_GRID_CACHE[key] = grid
    return grid


def feasible_strategy_arrays(wl, total_cores: int, mem_budget: float,
                             max_strategies: int) -> np.ndarray:
    """(k, 4) int64 array of [tp, pp, dp, microbatches], sorted by
    `strategy_sort_key` and capped — element-wise identical to
    sorted(enumerate_strategies(...), key=strategy_sort_key)[:cap], with the
    same Strategy(1,1,1,1) fallback when nothing is feasible."""
    g = _strategy_grid(wl)
    mask = ((g["chunks"] * g["tp"] <= total_cores)
            & (g["tp"] <= total_cores) & (g["need"] <= mem_budget))
    idx = g["order"][mask[g["order"]]][:max_strategies]
    if len(idx) == 0:
        return np.array([[1, 1, 1, 1]], np.int64)
    return np.stack([g["tp"][idx], g["pp"][idx], g["dp"][idx],
                     g["mb"][idx]], axis=1)


# NumPy oracle alias for the jitted strategy-grid selection
# (repro.core.eval_compiled reproduces the mask, the sorted order, the cap
# and the (1,1,1,1) fallback bit-exactly in-program)
feasible_strategy_arrays_ref = feasible_strategy_arrays
