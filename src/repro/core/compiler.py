"""Workload Compiler (paper §VI-A).

(1) Operator-graph generation: the LLM is segmented into model chunks by the
    parallel strategy (TP x PP x DP); compute resources divide evenly.
(2) Partition/allocation: each chunk's representative layer chain (uniform
    LLM stacks) is partitioned over the chunk's 2-D core grid.
(3) Task scheduling: ops are tiled per core (tile_eval) and inter-op
    redistribution transfers are generated at core granularity.
(4) Mapping & routing: logical cores map row-major onto the physical array;
    transfers take XY routes; per-link volumes and injection rates feed the
    op-level NoC estimators (analytical / GNN / simulator).

DRAM access and inter-chunk (TP/PP/DP) communication are handled at the
chunk level (paper §VI-D), not here.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.design_space import WSCDesign, floor_log2
from repro.core.tile_eval import TileResult, evaluate_tile
from repro.core.workload import BYTES, GEMMOp, LLMWorkload


@dataclasses.dataclass
class OpNode:
    op: GEMMOp
    tile: TileResult               # per-core tile evaluation
    grid: Tuple[int, int]          # (gh, gw) logical core grid


@dataclasses.dataclass
class Transfer:
    src_op: int
    dst_op: int
    pairs: List[Tuple[int, int, float]]    # (src_core, dst_core, bytes)

    def total_bytes(self) -> float:
        return sum(p[2] for p in self.pairs)


@dataclasses.dataclass
class ChunkGraph:
    array: Tuple[int, int]                 # physical chunk grid (H, W)
    ops: List[OpNode]
    transfers: List[Transfer]
    link_loads: np.ndarray                 # (n_links,) bytes per directed link
    link_flows: np.ndarray                 # (n_links,) flow count per link
    link_index: Dict[Tuple[int, int], int] # (core_u, core_v) -> link id
    n_cores: int
    routes: Optional[Dict[Tuple[int, int], List[Tuple[int, int]]]] = \
        dataclasses.field(default=None)                          # pair->hops

    def injection_rates(self, noc_bw_bits: int) -> np.ndarray:
        """flits/cycle injected per core, averaged over the chunk runtime.
        A chunk whose ops report zero compute cycles has no defined runtime
        to average over — injection is zero, not divided by a fake cycle."""
        inj = np.zeros(self.n_cores)
        total_cycles = sum(o.tile.cycles for o in self.ops)
        if total_cycles <= 0.0:
            return inj
        flit_bytes = noc_bw_bits / 8.0
        for t in self.transfers:
            for s, _, b in t.pairs:
                inj[s] += b / max(flit_bytes, 1.0)
        return inj / total_cycles


def _grid_for(n_cores: int) -> Tuple[int, int]:
    gh = 2 ** (int(math.log2(max(n_cores, 1))) // 2)
    return gh, max(n_cores // gh, 1)


def grid_for_batch(n_cores: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Vectorized `_grid_for` over an int array."""
    n = np.maximum(np.asarray(n_cores, np.int64), 1)
    gh = np.int64(1) << (floor_log2(n) // 2)
    return gh, np.maximum(n // gh, 1)


def _xy_route(src: int, dst: int, W: int) -> List[Tuple[int, int]]:
    """XY (row-first) route as a list of directed core-to-core hops."""
    r1, c1 = divmod(src, W)
    r2, c2 = divmod(dst, W)
    hops = []
    c = c1
    while c != c2:
        nc = c + (1 if c2 > c else -1)
        hops.append((r1 * W + c, r1 * W + nc))
        c = nc
    r = r1
    while r != r2:
        nr = r + (1 if r2 > r else -1)
        hops.append((r * W + c2, nr * W + c2))
        r = nr
    return hops


def compile_chunk(design: WSCDesign, wl: LLMWorkload, tp: int,
                  mb_tokens: int, cores_per_chunk: int,
                  grid_cap: int = 64) -> ChunkGraph:
    """Compile one model chunk's representative layer onto its core region.

    Hierarchical scale reduction (paper §VI): per-core tiles are sized by the
    TRUE chunk grid (cores_per_chunk), while the NoC graph is built on a
    capped representative grid — congestion patterns at equal per-core tile
    size are grid-size invariant for the row-redistribution pattern."""
    gh_t, gw_t = _grid_for(cores_per_chunk)
    gh, gw = _grid_for(min(cores_per_chunk, grid_cap))
    n_cores = gh * gw
    H, W = gh, gw

    ops = wl.layer_ops(tp=tp, mb_tokens=mb_tokens)
    nodes: List[OpNode] = []
    for op in ops:
        # per-core tile: split M over gh_t, N over gw_t (true grid)
        tile_gemm = GEMMOp(op.name,
                           max(op.M // gh_t, 1), op.K, max(op.N // gw_t, 1),
                           op.weight)
        tr = evaluate_tile(tile_gemm, design.mac_num, design.buffer_kb,
                           design.buffer_bw, design.dataflow)
        nodes.append(OpNode(op, tr, (gh_t, gw_t)))

    # inter-op redistribution: producer (a, b) -> consumers (a, b') in its
    # row (the next GEMM contracts over the previous output dim, so each
    # consumer needs the full row block = row-wise all-gather pattern)
    transfers: List[Transfer] = []
    link_index: Dict[Tuple[int, int], int] = {}
    loads: List[float] = []
    flows: List[float] = []
    routes: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}

    def link_id(u, v):
        key = (u, v)
        if key not in link_index:
            link_index[key] = len(loads)
            loads.append(0.0)
            flows.append(0.0)
        return link_index[key]

    for i in range(len(nodes) - 1):
        out_b = nodes[i].op.out_bytes()
        # row all-gather: each producer's tile (out_b / n_cores) goes to the
        # other gw-1 consumers in its row; total moved = (gw-1) x out_b
        per_pair = out_b / n_cores if gw > 1 else 0.0
        pairs = []
        if gw > 1:
            for a in range(gh):
                for b in range(gw):
                    src = a * W + b
                    for b2 in range(gw):
                        if b2 == b:
                            continue
                        dst = a * W + b2
                        pairs.append((src, dst, per_pair))
                        if (src, dst) not in routes:
                            routes[(src, dst)] = _xy_route(src, dst, W)
                        for (u, v) in routes[(src, dst)]:
                            lid = link_id(u, v)
                            loads[lid] += per_pair
                            flows[lid] += 1.0
        transfers.append(Transfer(i, i + 1, pairs))

    return ChunkGraph(array=(H, W), ops=nodes, transfers=transfers,
                      link_loads=np.array(loads), link_flows=np.array(flows),
                      link_index=link_index, n_cores=n_cores, routes=routes)


# ---------------------------------------------------------------------------
# row-all-gather transfer pattern (DESIGN.md §4b) — the design-independent
# structure of the transfers `compile_chunk` emits on a (gh, gw) grid:
# pair list, per-source injection sequence, link set and per-pair routes.
# The batched gnn/sim fidelity backends featurize/simulate from these tables
# instead of materializing ChunkGraph objects; `featurize_transfer` /
# `packets_for_transfer` remain the scalar reference the tables are tested
# against.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RowAllGatherPattern:
    gh: int
    gw: int
    n_cores: int
    src: np.ndarray          # (P,) producer core per pair, compile order
    dst: np.ndarray          # (P,)
    seq: np.ndarray          # (P,) per-source injection sequence number
    links: np.ndarray        # (E, 2) directed links, sorted lexicographically
    senders: np.ndarray      # (E,) int32 — links[:, 0]
    receivers: np.ndarray    # (E,) int32 — links[:, 1]
    flows: np.ndarray        # (E,) float64 — pair routes crossing each link
    out_deg: np.ndarray      # (n_cores,) float64
    in_deg: np.ndarray       # (n_cores,) float64
    route_eids: np.ndarray   # (P, Lmax) int32 link ids per hop, pad = E
    route_len: np.ndarray    # (P,) int32


_PATTERN_CACHE: Dict[Tuple[int, int], RowAllGatherPattern] = {}


def row_allgather_pattern(gh: int, gw: int) -> RowAllGatherPattern:
    """Memoized transfer structure of one `compile_chunk` inter-op edge on a
    (gh, gw) grid. Pair / sequence order matches `compile_chunk`'s loops and
    `packets_for_transfer`'s per-source numbering exactly; link order matches
    `featurize_transfer`'s `sorted(link_flits)`."""
    key = (int(gh), int(gw))
    hit = _PATTERN_CACHE.get(key)
    if hit is not None:
        return hit
    gh, gw = key
    W = gw
    n_cores = gh * gw
    srcs: List[int] = []
    dsts: List[int] = []
    seqs: List[int] = []
    routes: List[List[Tuple[int, int]]] = []
    link_flows: Dict[Tuple[int, int], float] = {}
    if gw > 1:
        for a in range(gh):
            for b in range(gw):
                src = a * W + b
                seq = 0
                for b2 in range(gw):
                    if b2 == b:
                        continue
                    dst = a * W + b2
                    hops = _xy_route(src, dst, W)
                    srcs.append(src)
                    dsts.append(dst)
                    seqs.append(seq)
                    seq += 1
                    routes.append(hops)
                    for hop in hops:
                        link_flows[hop] = link_flows.get(hop, 0.0) + 1.0
    links = sorted(link_flows)
    eid = {l: i for i, l in enumerate(links)}
    E = len(links)
    out_deg = np.zeros(n_cores)
    in_deg = np.zeros(n_cores)
    for u, v in links:
        out_deg[u] += 1
        in_deg[v] += 1
    lmax = max((len(r) for r in routes), default=0)
    route_eids = np.full((len(routes), max(lmax, 1)), E, np.int32)
    route_len = np.zeros(len(routes), np.int32)
    for i, r in enumerate(routes):
        route_len[i] = len(r)
        for j, hop in enumerate(r):
            route_eids[i, j] = eid[hop]
    pat = RowAllGatherPattern(
        gh=gh, gw=gw, n_cores=n_cores,
        src=np.array(srcs, np.int32), dst=np.array(dsts, np.int32),
        seq=np.array(seqs, np.int32),
        links=np.array(links, np.int32).reshape(-1, 2),
        senders=np.array([u for u, _ in links], np.int32),
        receivers=np.array([v for _, v in links], np.int32),
        flows=np.array([link_flows[l] for l in links], np.float64),
        out_deg=out_deg, in_deg=in_deg,
        route_eids=route_eids, route_len=route_len)
    if len(_PATTERN_CACHE) > 256:
        _PATTERN_CACHE.pop(next(iter(_PATTERN_CACHE)))
    _PATTERN_CACHE[key] = pat
    return pat


# ---------------------------------------------------------------------------
# parallel strategy enumeration (paper §VI-A last paragraph)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Strategy:
    tp: int
    pp: int
    dp: int
    microbatches: int

    def chunks(self) -> int:
        return self.pp * self.dp


def enumerate_strategies(design: WSCDesign, wl: LLMWorkload,
                         n_wafers: int = 1) -> List[Strategy]:
    """All (TP, DP, PP, micro-batch) combos satisfying memory capacity
    (paper: iterate all combinations that satisfy the memory constraint)."""
    total_cores = design.total_cores() * n_wafers
    sram_total = design.buffer_kb * 1024.0 * total_cores
    dram_total = design.dram_gb_per_reticle() * 1e9 * design.n_reticles() * n_wafers
    mem_budget = sram_total + dram_total
    p_bytes = wl.params_bytes()
    opt_mult = 6.0 if wl.phase == "train" else 1.0   # weights+grads+adam
    out: List[Strategy] = []
    pows = [2 ** i for i in range(0, 17)]
    for pp in [p for p in pows if p <= min(wl.n_layers, 64)]:
        for dp in [d for d in pows if d <= max(wl.batch, 1)]:
            for tp in [t for t in pows if t <= 4096]:
                chunks = pp * dp
                if chunks * tp > total_cores or tp > total_cores:
                    continue
                # memory: dp replicas each hold params/pp (+ optimizer);
                # the KV cache splits across replicas (constant total)
                need = dp * p_bytes * opt_mult / max(pp, 1)
                if wl.phase != "train":
                    need = dp * p_bytes / max(pp, 1)
                    need += wl.kv_bytes_per_layer() * wl.n_layers
                if need > mem_budget:
                    continue
                for mb in (1, 2, 4, 8, 16, 32):
                    if wl.phase != "train" and mb > 1:
                        continue
                    if wl.batch % (dp * (mb if wl.phase == "train" else 1)):
                        continue
                    out.append(Strategy(tp, pp, dp, mb))
    return out or [Strategy(1, 1, 1, 1)]


def strategy_sort_key(s: Strategy) -> Tuple:
    """Search-order heuristic: prefer modest TP, deep pipelines last."""
    return (abs(math.log2(max(s.tp, 1)) - 5), s.pp, -s.microbatches)


# --------------------------------------------------------------------------
# batched strategy enumeration (DESIGN.md §4) — the design-independent part
# of `enumerate_strategies` precomputed once per workload as a combo grid,
# so per-design feasibility is a couple of vectorized comparisons.
# --------------------------------------------------------------------------

_STRATEGY_GRID_CACHE: Dict[Tuple, Dict[str, np.ndarray]] = {}


def _strategy_grid(wl) -> Dict[str, np.ndarray]:
    key = (wl.n_layers, wl.batch, wl.phase, wl.params_bytes(),
           wl.kv_bytes_per_layer())
    hit = _STRATEGY_GRID_CACHE.get(key)
    if hit is not None:
        return hit
    p_bytes = wl.params_bytes()
    opt_mult = 6.0 if wl.phase == "train" else 1.0
    pows = [2 ** i for i in range(0, 17)]
    tps, pps, dps, mbs, needs = [], [], [], [], []
    for pp in [p for p in pows if p <= min(wl.n_layers, 64)]:
        for dp in [d for d in pows if d <= max(wl.batch, 1)]:
            for tp in [t for t in pows if t <= 4096]:
                if wl.phase == "train":
                    need = dp * p_bytes * opt_mult / max(pp, 1)
                else:
                    need = (dp * p_bytes / max(pp, 1)
                            + wl.kv_bytes_per_layer() * wl.n_layers)
                for mb in (1, 2, 4, 8, 16, 32):
                    if wl.phase != "train" and mb > 1:
                        continue
                    if wl.batch % (dp * (mb if wl.phase == "train" else 1)):
                        continue
                    tps.append(tp); pps.append(pp); dps.append(dp)
                    mbs.append(mb); needs.append(need)
    tp = np.array(tps, np.int64)
    pp = np.array(pps, np.int64)
    dp = np.array(dps, np.int64)
    mb = np.array(mbs, np.int64)
    need = np.array(needs, np.float64)
    # stable sort by strategy_sort_key; lexsort primary = last key
    order = np.lexsort((-mb, pp, np.abs(np.log2(np.maximum(tp, 1)) - 5.0)))
    grid = {"tp": tp, "pp": pp, "dp": dp, "mb": mb, "need": need,
            "chunks": pp * dp, "order": order}
    if len(_STRATEGY_GRID_CACHE) > 64:
        _STRATEGY_GRID_CACHE.pop(next(iter(_STRATEGY_GRID_CACHE)))
    _STRATEGY_GRID_CACHE[key] = grid
    return grid


def feasible_strategy_arrays(wl, total_cores: int, mem_budget: float,
                             max_strategies: int) -> np.ndarray:
    """(k, 4) int64 array of [tp, pp, dp, microbatches], sorted by
    `strategy_sort_key` and capped — element-wise identical to
    sorted(enumerate_strategies(...), key=strategy_sort_key)[:cap], with the
    same Strategy(1,1,1,1) fallback when nothing is feasible."""
    g = _strategy_grid(wl)
    mask = ((g["chunks"] * g["tp"] <= total_cores)
            & (g["tp"] <= total_cores) & (g["need"] <= mem_budget))
    idx = g["order"][mask[g["order"]]][:max_strategies]
    if len(idx) == 0:
        return np.array([[1, 1, 1, 1]], np.int64)
    return np.stack([g["tp"][idx], g["pp"][idx], g["dp"][idx],
                     g["mb"][idx]], axis=1)


# NumPy oracle alias for the jitted strategy-grid selection
# (repro.core.eval_compiled reproduces the mask, the sorted order, the cap
# and the (1,1,1,1) fallback bit-exactly in-program)
feasible_strategy_arrays_ref = feasible_strategy_arrays
