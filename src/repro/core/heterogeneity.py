"""Heterogeneous WSC modeling for LLM inference (paper §V-B, §IX-E).

prefill_ratio splits compute resources between the prefill and decode
stages; `hetero` granularity sets where the split lives and what the
KV-cache transfer between stages costs:

    core     same reticle, software-scheduled      -> NoC bisection
    reticle  different reticles, one wafer          -> inter-reticle links
    wafer    different wafers                       -> inter-wafer NIs

Overall throughput = matched-rate pipeline of the two stages including the
KV transfer; each stage's design can tune its stacking-DRAM bandwidth
independently (reticle/wafer granularity) per the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core import components as C
from repro.core.design_space import WSCDesign
from repro.core.evaluator import Fidelity, evaluate_design, get_backend
from repro.core.workload import LLMWorkload, inference_workload


@dataclasses.dataclass
class HeteroResult:
    throughput: float           # tokens/s end-to-end
    power_w: float
    prefill_tps: float
    decode_tps: float
    kv_transfer_s: float
    granularity: str


def _kv_transfer_bw(design: WSCDesign, granularity: str) -> float:
    if granularity == "core":
        return design.reticle_bisection_Bps()
    if granularity == "reticle":
        # stage boundary crosses the wafer's inter-reticle bisection
        return design.inter_reticle_bw_Bps() * min(design.reticle_array)
    # wafer-level: KV leaves through the facing edge's network interfaces
    # at protocol-achievable utilization — the paper's inter-wafer
    # bottleneck (§IX-E)
    n_ni = design.reticle_array[0]
    return 0.5 * n_ni * C.INTER_WAFER_BW_PER_NI


def evaluate_hetero(design_prefill: WSCDesign, design_decode: WSCDesign,
                    wl_base: LLMWorkload, granularity: str,
                    prefill_ratio: float, out_tokens: int = 2048,
                    n_wafers: int = 1, fidelity: Fidelity = "analytical",
                    gnn_params: Optional[Dict] = None) -> HeteroResult:
    """Evaluate a prefill/decode split. At core/reticle granularity both
    stages share the wafer (resource fractions); at wafer granularity each
    stage gets whole wafers. `fidelity` is a registered backend name (or a
    FidelityBackend instance) — resolved up front so typos fail loudly."""
    fidelity = get_backend(fidelity)
    wl_p = inference_workload(wl_base, "prefill", batch=wl_base.batch,
                              seq=wl_base.seq)
    wl_d = inference_workload(wl_base, "decode", batch=wl_base.batch,
                              seq=wl_base.seq)

    if granularity == "wafer":
        nw_p = max(1, round(n_wafers * prefill_ratio))
        nw_d = max(1, n_wafers - nw_p)
        rp = evaluate_design(design_prefill, wl_p, fidelity, gnn_params,
                             n_wafers=nw_p)
        rd = evaluate_design(design_decode, wl_d, fidelity, gnn_params,
                             n_wafers=nw_d)
        scale_p = scale_d = 1.0
    else:
        rp = evaluate_design(design_prefill, wl_p, fidelity, gnn_params,
                             n_wafers=n_wafers)
        rd = evaluate_design(design_decode, wl_d, fidelity, gnn_params,
                             n_wafers=n_wafers)
        scale_p, scale_d = prefill_ratio, 1.0 - prefill_ratio

    # prefill produces prompts (seq tokens each); decode consumes them,
    # emitting out_tokens per prompt
    prefill_prompts_s = rp.throughput * scale_p / max(wl_base.seq, 1)
    decode_tokens_s = rd.throughput * scale_d
    decode_prompts_s = decode_tokens_s / max(out_tokens, 1)

    # KV transfer between stages per prompt
    kv_bytes = wl_base.kv_bytes_per_layer() * wl_base.n_layers / max(
        wl_base.batch, 1)
    bw = _kv_transfer_bw(design_decode, granularity)
    kv_s_per_prompt = kv_bytes / max(bw, 1.0)
    kv_prompts_s = 1.0 / max(kv_s_per_prompt, 1e-12)

    # core-level heterogeneity: flexible scheduling boosts utilization but
    # adds intra-reticle traffic + control overhead (paper §IX-E)
    eff = {"core": 0.92, "reticle": 1.0, "wafer": 1.0}[granularity]
    prompts_s = eff * min(prefill_prompts_s, decode_prompts_s, kv_prompts_s)
    thpt = prompts_s * out_tokens
    power = rp.power_w * scale_p + rd.power_w * scale_d
    return HeteroResult(
        throughput=thpt, power_w=power,
        prefill_tps=rp.throughput * scale_p,
        decode_tps=decode_tokens_s,
        kv_transfer_s=kv_s_per_prompt,
        granularity=granularity)
