"""Heterogeneous WSC modeling for LLM inference (paper §V-B, §IX-E).

prefill_ratio splits compute resources between the prefill and decode
stages; `hetero` granularity sets where the split lives and what the
KV-cache transfer between stages costs:

    core     same reticle, software-scheduled      -> NoC bisection
    reticle  different reticles, one wafer          -> inter-reticle links
    wafer    different wafers                       -> inter-wafer NIs

`evaluate_hetero` scores the split as a matched-rate pipeline of the two
stages including the KV transfer (the paper's model); each stage's design
can tune its stacking-DRAM bandwidth independently (reticle/wafer
granularity). `evaluate_hetero_serving` re-scores the same disaggregation
with the coupled request-level model (repro.core.serving): prefills run on
their own stage so decode never stalls, but each request's admission to the
decode pool is gated by its prefill completion plus the KV-cache transfer —
so TTFT/TPOT/SLO goodput are first-class instead of rate-matched stage
throughputs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core import components as C
from repro.core.design_space import WSCDesign
from repro.core.evaluator import Fidelity, evaluate_design, get_backend
from repro.core.serving import (
    RequestMix,
    ServingSLO,
    disaggregated_metrics,
    serving_workloads,
)
from repro.core.workload import LLMWorkload, inference_workload


@dataclasses.dataclass
class HeteroResult:
    throughput: float           # tokens/s end-to-end
    power_w: float
    prefill_tps: float
    decode_tps: float
    kv_transfer_s: float
    granularity: str


def wafer_split(n_wafers: int, prefill_ratio: float) -> Tuple[int, int]:
    """Wafer-granularity resource split with the area budget respected:
    nw_p + nw_d == n_wafers always. (The old `max(1, n_wafers - nw_p)`
    fallback let the two stages claim n_wafers + 1 wafers at extreme
    prefill ratios — silently granting extra silicon vs the area-matched
    budget.) Each stage needs at least one whole wafer."""
    if n_wafers < 2:
        raise ValueError(
            "wafer-granularity heterogeneity needs n_wafers >= 2 "
            f"(got {n_wafers}); use core/reticle granularity instead")
    nw_p = min(max(1, round(n_wafers * prefill_ratio)), n_wafers - 1)
    return nw_p, n_wafers - nw_p


def _kv_transfer_bw(design: WSCDesign, granularity: str) -> float:
    if granularity == "core":
        return design.reticle_bisection_Bps()
    if granularity == "reticle":
        # stage boundary crosses the wafer's inter-reticle bisection
        return design.inter_reticle_bw_Bps() * min(design.reticle_array)
    # wafer-level: KV leaves through the facing edge's network interfaces
    # at protocol-achievable utilization — the paper's inter-wafer
    # bottleneck (§IX-E)
    n_ni = design.reticle_array[0]
    return 0.5 * n_ni * C.INTER_WAFER_BW_PER_NI


def evaluate_hetero(design_prefill: WSCDesign, design_decode: WSCDesign,
                    wl_base: LLMWorkload, granularity: str,
                    prefill_ratio: float, out_tokens: int = 2048,
                    n_wafers: int = 1, fidelity: Fidelity = "analytical",
                    gnn_params: Optional[Dict] = None) -> HeteroResult:
    """Evaluate a prefill/decode split. At core/reticle granularity both
    stages share the wafer (resource fractions); at wafer granularity each
    stage gets whole wafers. `fidelity` is a registered backend name (or a
    FidelityBackend instance) — resolved up front so typos fail loudly."""
    fidelity = get_backend(fidelity)
    wl_p = inference_workload(wl_base, "prefill", batch=wl_base.batch,
                              seq=wl_base.seq)
    wl_d = inference_workload(wl_base, "decode", batch=wl_base.batch,
                              seq=wl_base.seq)

    if granularity == "wafer":
        nw_p, nw_d = wafer_split(n_wafers, prefill_ratio)
        rp = evaluate_design(design_prefill, wl_p, fidelity, gnn_params,
                             n_wafers=nw_p)
        rd = evaluate_design(design_decode, wl_d, fidelity, gnn_params,
                             n_wafers=nw_d)
        scale_p = scale_d = 1.0
    else:
        rp = evaluate_design(design_prefill, wl_p, fidelity, gnn_params,
                             n_wafers=n_wafers)
        rd = evaluate_design(design_decode, wl_d, fidelity, gnn_params,
                             n_wafers=n_wafers)
        scale_p, scale_d = prefill_ratio, 1.0 - prefill_ratio

    # prefill produces prompts (seq tokens each); decode consumes them,
    # emitting out_tokens per prompt
    prefill_prompts_s = rp.throughput * scale_p / max(wl_base.seq, 1)
    decode_tokens_s = rd.throughput * scale_d
    decode_prompts_s = decode_tokens_s / max(out_tokens, 1)

    # KV transfer between stages per prompt
    kv_bytes = wl_base.kv_bytes_per_layer() * wl_base.n_layers / max(
        wl_base.batch, 1)
    bw = _kv_transfer_bw(design_decode, granularity)
    kv_s_per_prompt = kv_bytes / max(bw, 1.0)
    kv_prompts_s = 1.0 / max(kv_s_per_prompt, 1e-12)

    # core-level heterogeneity: flexible scheduling boosts utilization but
    # adds intra-reticle traffic + control overhead (paper §IX-E)
    eff = {"core": 0.92, "reticle": 1.0, "wafer": 1.0}[granularity]
    prompts_s = eff * min(prefill_prompts_s, decode_prompts_s, kv_prompts_s)
    thpt = prompts_s * out_tokens
    power = rp.power_w * scale_p + rd.power_w * scale_d
    return HeteroResult(
        throughput=thpt, power_w=power,
        prefill_tps=rp.throughput * scale_p,
        decode_tps=decode_tokens_s,
        kv_transfer_s=kv_s_per_prompt,
        granularity=granularity)


# ---------------------------------------------------------------------------
# coupled request-level re-score (ISSUE 4 tentpole)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class HeteroServingResult:
    feasible: bool
    goodput_tok_s: float
    throughput_tok_s: float
    ttft_s: float                  # mean over the mix's requests
    tpot_s: float
    slo_attainment: float
    power_w: float
    kv_transfer_s: float           # mean per-request stage transfer
    n_decode_steps: int
    granularity: str
    reason: str = ""


def evaluate_hetero_serving(design_prefill: WSCDesign,
                            design_decode: WSCDesign,
                            wl_base: LLMWorkload, granularity: str,
                            prefill_ratio: float, mix: RequestMix,
                            slo: ServingSLO, slots: int = 8,
                            n_wafers: int = 1,
                            fidelity: Fidelity = "analytical",
                            gnn_params: Optional[Dict] = None
                            ) -> HeteroServingResult:
    """Re-score a prefill/decode disaggregation with the coupled request
    model instead of independent rate-matched stage throughputs: per-request
    prefill times on the prefill stage's resource share, per-request
    KV-cache shipping across the stage boundary, and a decode pool that only
    admits a request once its KV has landed and a slot is free."""
    fidelity = get_backend(fidelity)
    wl_p, wl_d, p_ref = serving_workloads(wl_base, mix, slots)

    if granularity == "wafer":
        nw_p, nw_d = wafer_split(n_wafers, prefill_ratio)
        rp = evaluate_design(design_prefill, wl_p, fidelity, gnn_params,
                             n_wafers=nw_p)
        rd = evaluate_design(design_decode, wl_d, fidelity, gnn_params,
                             n_wafers=nw_d)
        scale_p = scale_d = 1.0
    else:
        rp = evaluate_design(design_prefill, wl_p, fidelity, gnn_params,
                             n_wafers=n_wafers)
        rd = evaluate_design(design_decode, wl_d, fidelity, gnn_params,
                             n_wafers=n_wafers)
        scale_p, scale_d = prefill_ratio, 1.0 - prefill_ratio
    if not (rp.feasible and rd.feasible):
        return HeteroServingResult(
            feasible=False, goodput_tok_s=0.0, throughput_tok_s=0.0,
            ttft_s=float("inf"), tpot_s=float("inf"), slo_attainment=0.0,
            power_w=float("inf"), kv_transfer_s=float("inf"),
            n_decode_steps=0, granularity=granularity,
            reason="prefill_infeasible" if not rp.feasible
            else "decode_infeasible")

    # stage step times on the stage's actual resource share; core-level
    # scheduling flexibility costs control overhead (paper §IX-E), modeled
    # as time inflation rather than a rate discount
    eff = {"core": 0.92, "reticle": 1.0, "wafer": 1.0}[granularity]
    t_p_ref = rp.step.step_time_s / max(scale_p, 1e-9) / eff
    t_d = rd.step.step_time_s / max(scale_d, 1e-9) / eff

    plens = np.asarray(mix.prompt_lens, np.float64)
    t_prefill = t_p_ref * plens / max(p_ref, 1)
    # per-request K+V cache: the canonical per-layer formula, rescaled from
    # the workload's (batch, seq) footprint to one prompt of plens tokens
    kv_per_token = (wl_base.kv_bytes_per_layer() * wl_base.n_layers
                    / max(wl_base.batch * wl_base.seq, 1))
    kv_bytes = kv_per_token * plens
    bw = _kv_transfer_bw(design_decode, granularity)
    kv_s = kv_bytes / max(bw, 1.0)

    m = disaggregated_metrics(mix, slo, slots, t_prefill, kv_s, t_d)
    power = rp.power_w * scale_p + rd.power_w * scale_d
    return HeteroServingResult(
        feasible=True,
        goodput_tok_s=m["goodput_tok_s"],
        throughput_tok_s=m["throughput_tok_s"],
        ttft_s=m["ttft_s"], tpot_s=m["tpot_s"],
        slo_attainment=m["slo_attainment"],
        power_w=power,
        kv_transfer_s=float(np.mean(kv_s)),
        n_decode_steps=m["n_decode_steps"],
        granularity=granularity)


def evaluate_hetero_trace_serving(design_prefill: WSCDesign,
                                  design_decode: WSCDesign,
                                  wl_base: LLMWorkload, granularity: str,
                                  prefill_ratio: float, trace,
                                  slots: int = 8, window_steps: int = 64,
                                  n_wafers: Optional[int] = None,
                                  fidelity: Fidelity = "analytical",
                                  gnn_params: Optional[Dict] = None):
    """Timed-arrival, multi-tenant counterpart of `evaluate_hetero_serving`:
    the "disaggregated" routing policy of a trace-serving campaign
    (DESIGN.md §14). Stage evaluation and the resource split are identical;
    the coupled request model is `traces.trace_disaggregated_metrics` —
    prompts prefill on their own stage in priority-then-arrival order as
    they *arrive*, KV ships across the stage boundary, and the decode pool
    admits by priority once the KV lands. Returns a
    `traces.TraceServingResult` so disaggregated points score in the same
    frame as the shared-pool policies."""
    from repro.core.traces import (
        TraceServingResult,
        _per_tenant,
        trace_disaggregated_metrics,
        trace_serving_workloads,
    )

    fidelity = get_backend(fidelity)
    wl_p, wl_d, p_ref = trace_serving_workloads(wl_base, trace, slots)

    if granularity == "wafer":
        nw_p, nw_d = wafer_split(n_wafers if n_wafers is not None else 2,
                                 prefill_ratio)
        rp = evaluate_design(design_prefill, wl_p, fidelity, gnn_params,
                             n_wafers=nw_p)
        rd = evaluate_design(design_decode, wl_d, fidelity, gnn_params,
                             n_wafers=nw_d)
        scale_p = scale_d = 1.0
    else:
        rp = evaluate_design(design_prefill, wl_p, fidelity, gnn_params,
                             n_wafers=n_wafers)
        rd = evaluate_design(design_decode, wl_d, fidelity, gnn_params,
                             n_wafers=n_wafers)
        scale_p, scale_d = prefill_ratio, 1.0 - prefill_ratio
    if not (rp.feasible and rd.feasible):
        from repro.core.traces import _infeasible
        return _infeasible("disaggregated", rd.n_wafers,
                           "prefill_infeasible" if not rp.feasible
                           else "decode_infeasible")

    eff = {"core": 0.92, "reticle": 1.0, "wafer": 1.0}[granularity]
    t_p_ref = rp.step.step_time_s / max(scale_p, 1e-9) / eff
    t_d = rd.step.step_time_s / max(scale_d, 1e-9) / eff

    plens = np.asarray(trace.prompt_lens, np.float64)
    t_prefill = t_p_ref * plens / max(p_ref, 1)
    kv_per_token = (wl_base.kv_bytes_per_layer() * wl_base.n_layers
                    / max(wl_base.batch * wl_base.seq, 1))
    kv_s = kv_per_token * plens / max(
        _kv_transfer_bw(design_decode, granularity), 1.0)

    m = trace_disaggregated_metrics(trace, slots, t_prefill, kv_s, t_d,
                                    window_steps=window_steps)
    power = rp.power_w * scale_p + rd.power_w * scale_d
    energy = power * m["total_time_s"]
    return TraceServingResult(
        feasible=True, policy="disaggregated",
        goodput_tok_s=m["goodput_tok_s"],
        interactive_goodput_tok_s=m["interactive_goodput_tok_s"],
        worst_window_goodput_tok_s=m["worst_window_goodput_tok_s"],
        throughput_tok_s=m["throughput_tok_s"],
        ttft_s=m["ttft_s"], ttft_max_s=m["ttft_max_s"],
        tpot_s=m["tpot_s"], tpot_max_s=m["tpot_max_s"],
        slo_attainment=m["slo_attainment"],
        total_time_s=m["total_time_s"],
        n_steps=m["n_steps"], n_decode_steps=m["n_decode_steps"],
        n_preemptions=0, power_w=power, energy_j=energy,
        n_wafers=rd.n_wafers,
        per_tenant=_per_tenant(trace, m["met"], m["ttft"], m["tpot"],
                               m["total_time_s"]))


def hetero_serving_objectives(wl_base: LLMWorkload, mix: RequestMix,
                              slo: ServingSLO, *, granularity: str,
                              prefill_ratio: float = 0.5, slots: int = 8,
                              n_wafers: int = 8,
                              fidelity: Fidelity = "analytical",
                              gnn_params: Optional[Dict] = None):
    """(goodput, power-per-wafer) explorer objective for the disaggregated
    serving scenario — thin constructor for the campaign Objectives
    protocol (`repro.explore.objectives.HeteroServingObjective`, lazy
    import: repro.explore layers on top of this module). Campaigns declare
    the same thing with `scenario="hetero"` + a `HeteroSpec`."""
    from repro.explore.objectives import HeteroServingObjective
    return HeteroServingObjective(
        wl_base, mix, slo, granularity=granularity,
        prefill_ratio=prefill_ratio, slots=slots, n_wafers=n_wafers,
        fidelity=fidelity, gnn_params=gnn_params)
