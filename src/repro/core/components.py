"""Component Estimator (paper §VI-E): analytical area/power/energy tables for
WSC basic modules, calibrated to the paper's published constants and public
references (Aladdin/Orion3-style action energies, Cerebras/Dojo/GRS interconnect
numbers), all at 14 nm / 1 GHz / 0.9 V (paper §VIII-A).

The paper builds this table with an SRAM compiler + Synopsys DC + DREAMPlace;
offline we ship an analytic fit with the same interface — an updatable
area-power table (the paper itself frames it that way).
"""
from __future__ import annotations

import dataclasses

import numpy as np

# ---------------------------------------------------------------------------
# constants (14 nm, 1 GHz)
# ---------------------------------------------------------------------------

CLOCK_HZ = 1e9

# MAC: bf16 FMA incl. operand regs/pipeline, 14nm synthesis-class numbers
MAC_AREA_MM2 = 4.0e-4            # 400 um^2
MAC_ENERGY_PJ = 0.8              # per MAC (= 2 flops)

# SRAM (ssg, 0.9V): density incl. periphery; energies per bit.
# 0.09 um^2/bit = published 14nm high-density macro incl. periphery
# (bitcell 0.064 um^2 x ~1.4 overhead) — needed for the paper's
# SRAM-resident inference scenario (Fig. 11a) to be area-feasible.
SRAM_MM2_PER_KB = 0.75e-3
SRAM_READ_PJ_PER_BIT = 0.06
SRAM_WRITE_PJ_PER_BIT = 0.08
SRAM_STATIC_W_PER_MB = 0.015
# banking/port overhead: wider read ports cost area (SRAM-compiler feasibility
# constraint lives in validator.py)
SRAM_BW_AREA_FACTOR = 0.12       # area multiplier per log2(bw/256b)

# NoC router: 5-port, 8 VCs x 4 buffers (paper), Orion3-class
ROUTER_BASE_MM2 = 0.015
ROUTER_BW_EXP = 1.1              # area ~ (bw/128)^1.1
ROUTER_ENERGY_PJ_PER_BIT_HOP = 0.045
LINK_ENERGY_PJ_PER_BIT_MM = 0.06
ROUTER_STATIC_W = 0.012

# RISC-V control core per compute core
CTRL_AREA_MM2 = 0.05
CTRL_STATIC_W = 0.01

# inter-reticle PHY (paper §VIII-A)
IR_AREA_UM2_PER_GBPS = {"infosow": 3900.0, "die_stitching": 1300.0}
IR_ENERGY_PJ_PER_BIT = {"infosow": 1.5, "die_stitching": 0.45}

# 3D-stacked DRAM via TSV (paper: 5um TSV, 15um pitch). Effective signaling
# is calibrated to 5 Gbps/TSV (DDR pins) so the paper's own sweep range —
# 0.25..4 TB/s/100mm^2 "within the stress constraint" of 1.5% TSV area —
# is self-consistent: at 4 TB/s/100mm^2 the TSV field is 1.44% of area.
TSV_PITCH_UM = 15.0
TSV_GBPS = 5.0
DRAM_ENERGY_PJ_PER_BIT = 3.5
DRAM_STATIC_W_PER_GB = 0.05
# capacity/bandwidth linear trade (paper fits existing configs): at max bw
# (4 TB/s/100mm2) capacity tops at 8 GB/100mm2-class stacks; at 0.25 TB/s, 40 GB
DRAM_BW_RANGE = (0.25, 4.0)      # TB/s per 100 mm^2
DRAM_GB_RANGE = (40.0, 8.0)      # GB at the respective bw endpoints

# off-chip DRAM + inter-wafer (paper Table I)
OFFCHIP_BW_PER_CTRL = 160e9      # B/s
OFFCHIP_CTRL_AREA_MM2 = 6.0
OFFCHIP_ENERGY_PJ_PER_BIT = 10.0
INTER_WAFER_BW_PER_NI = 100e9    # B/s
NI_ENERGY_PJ_PER_BIT = 5.0

# physical limits (paper §VIII-A)
RETICLE_MM = (26.0, 33.0)
RETICLE_AREA_MM2 = RETICLE_MM[0] * RETICLE_MM[1]
WAFER_MM = (215.0, 215.0)
WAFER_AREA_MM2 = WAFER_MM[0] * WAFER_MM[1]
WAFER_POWER_W = 15000.0
TSV_AREA_RATIO_MAX = 0.015       # stress constraint


# ---------------------------------------------------------------------------
# derived component models
# ---------------------------------------------------------------------------


# the numeric helpers below are dtype-polymorphic: scalars in -> (np) scalar
# out, arrays in -> arrays out, so design_space.DesignBatch shares the exact
# same formulas (and constants) as the scalar WSCDesign methods.


def sram_area_mm2(buffer_kb: float, buffer_bw_bits: int) -> float:
    base = buffer_kb * SRAM_MM2_PER_KB
    widen = np.maximum(0.0, np.log2(np.maximum(buffer_bw_bits, 256) / 256.0))
    return base * (1.0 + SRAM_BW_AREA_FACTOR * widen)


def router_area_mm2(noc_bw_bits: int) -> float:
    return ROUTER_BASE_MM2 * (noc_bw_bits / 128.0) ** ROUTER_BW_EXP


def core_area_mm2(mac_num: int, buffer_kb: float, buffer_bw: int,
                  noc_bw: int) -> float:
    # operand-distribution networks grow super-linearly with array size
    # (broadcast wiring / accumulation trees) — the "module efficiency"
    # penalty of very large cores (paper §IX-A)
    dist = np.where(np.asarray(mac_num) > 512, (mac_num / 512.0) ** 0.10, 1.0)
    a = (mac_num * MAC_AREA_MM2 * dist
         + sram_area_mm2(buffer_kb, buffer_bw)
         + router_area_mm2(noc_bw)
         + CTRL_AREA_MM2)
    return a * 1.10                      # 10% place&route overhead


def core_peak_flops(mac_num: int) -> float:
    return 2.0 * mac_num * CLOCK_HZ


def core_static_w(mac_num: int, buffer_kb: float) -> float:
    return (buffer_kb / 1024.0 * SRAM_STATIC_W_PER_MB
            + ROUTER_STATIC_W + CTRL_STATIC_W
            + mac_num * 2e-6)


def dram_gb_at_bw(bw_tbps_per_100mm2: float) -> float:
    """Linear capacity/bandwidth trade-off (paper fits existing configs)."""
    lo_bw, hi_bw = DRAM_BW_RANGE
    lo_gb, hi_gb = DRAM_GB_RANGE
    t = (bw_tbps_per_100mm2 - lo_bw) / (hi_bw - lo_bw)
    t = np.clip(t, 0.0, 1.0)
    return lo_gb + t * (hi_gb - lo_gb)


def tsv_area_mm2(dram_bw_Bps: float) -> float:
    """TSV keep-out area for a given stacked-DRAM bandwidth."""
    tsvs = (dram_bw_Bps * 8.0) / (TSV_GBPS * 1e9)
    return tsvs * (TSV_PITCH_UM * 1e-3) ** 2


def tsv_area_ratio(dram_bw_tbps_per_100mm2: float) -> float:
    """TSV field area per unit reticle area at the given stacked-DRAM
    bandwidth density — the fixed-point factor in reticle sizing."""
    return (dram_bw_tbps_per_100mm2 * 1e12 / 100.0) * 8.0 \
        / (TSV_GBPS * 1e9) * (TSV_PITCH_UM * 1e-3) ** 2


def inter_reticle_area_mm2(bw_Bps: float, integration: str) -> float:
    return bw_Bps * 8e-9 * IR_AREA_UM2_PER_GBPS[integration] * 1e-6


@dataclasses.dataclass(frozen=True)
class ActionEnergies:
    """pJ per action — Aladdin-style power accounting (paper §VI-E)."""
    mac: float = MAC_ENERGY_PJ
    sram_read_bit: float = SRAM_READ_PJ_PER_BIT
    sram_write_bit: float = SRAM_WRITE_PJ_PER_BIT
    noc_bit_hop: float = ROUTER_ENERGY_PJ_PER_BIT_HOP + LINK_ENERGY_PJ_PER_BIT_MM
    dram_bit: float = DRAM_ENERGY_PJ_PER_BIT
    offchip_bit: float = OFFCHIP_ENERGY_PJ_PER_BIT
    ni_bit: float = NI_ENERGY_PJ_PER_BIT

    def ir_bit(self, integration: str) -> float:
        return IR_ENERGY_PJ_PER_BIT[integration]


ENERGY = ActionEnergies()
