"""WSC design-space construction (paper §V, Table I).

Candidate values (Table I):
    dataflow          WS | IS | OS
    mac_num           8 .. 4096            (per core)
    buffer_size       32 .. 2048 KB
    buffer_bw         32 .. 4096 bit/cycle
    noc_bw            32 .. 4096 bit/cycle
    inter_reticle_bw  0.2 .. 2.0 x reticle bisection bw
    stacking_DRAM_bw  0.25 .. 4 TB/s/100mm^2 (optional)
    stacking_DRAM sz  8 .. 40 GB (linear trade with bw)
    integration       die_stitching | InFO-SoW
    inter_wafer_bw    100 GB/s per network interface
    off_chip_mem_bw   160 GB/s per memory controller
    core/reticle arrays: 1 .. max under area constraints
Heterogeneous params (§V-B): prefill_ratio, hetero granularity.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import components as C

DATAFLOWS = ("WS", "IS", "OS")
INTEGRATIONS = ("die_stitching", "infosow")

MAC_RANGE = (8, 4096)
BUF_KB_RANGE = (32, 2048)
BUF_BW_RANGE = (32, 4096)
NOC_BW_RANGE = (32, 4096)
IR_RATIO_RANGE = (0.2, 2.0)
DRAM_BW_RANGE = C.DRAM_BW_RANGE


@dataclasses.dataclass(frozen=True)
class WSCDesign:
    # core level
    dataflow: str = "WS"
    mac_num: int = 512
    buffer_kb: int = 256
    buffer_bw: int = 1024          # bits/cycle
    noc_bw: int = 512              # bits/cycle
    # reticle level
    core_array: Tuple[int, int] = (8, 8)
    inter_reticle_bw_ratio: float = 1.0
    use_stacked_dram: bool = True
    dram_bw_tbps_per_100mm2: float = 1.0
    # wafer level
    reticle_array: Tuple[int, int] = (8, 8)
    integration: str = "infosow"
    # heterogeneity (inference only; §V-B)
    prefill_ratio: float = 0.5
    hetero: str = "none"           # none | core | reticle | wafer
    # resolved by the validator (spares needed for the yield target)
    spares_per_row: int = 1

    # ---------------- derived geometry ------------------------------------

    def core_area_mm2(self) -> float:
        return C.core_area_mm2(self.mac_num, self.buffer_kb, self.buffer_bw,
                               self.noc_bw)

    def core_dims_mm(self) -> Tuple[float, float]:
        a = self.core_area_mm2()
        s = math.sqrt(a)
        return (s, s)

    def cores_per_reticle(self) -> int:
        return self.core_array[0] * self.core_array[1]

    def reticle_bisection_Bps(self) -> float:
        """Bisection bandwidth of the core-array NoC (bits/cycle -> B/s)."""
        w = min(self.core_array)
        return w * self.noc_bw / 8.0 * C.CLOCK_HZ

    def inter_reticle_bw_Bps(self) -> float:
        return self.inter_reticle_bw_ratio * self.reticle_bisection_Bps()

    def reticle_compute_area_mm2(self) -> float:
        h, w = self.core_array
        spare_cols = self.spares_per_row
        return (w + spare_cols) * h * self.core_area_mm2()

    def dram_bw_Bps_per_reticle(self) -> float:
        if not self.use_stacked_dram:
            return 0.0
        return (self.dram_bw_tbps_per_100mm2 * 1e12
                * self.reticle_area_mm2() / 100.0)

    def dram_gb_per_reticle(self) -> float:
        if not self.use_stacked_dram:
            return 0.0
        return (C.dram_gb_at_bw(self.dram_bw_tbps_per_100mm2)
                * self.reticle_area_mm2() / 100.0)

    def tsv_area_mm2(self) -> float:
        if not self.use_stacked_dram:
            return 0.0
        return C.tsv_area_mm2(self.dram_bw_Bps_per_reticle())

    def reticle_area_mm2(self) -> float:
        """Compute + inter-reticle PHY + TSV keep-out."""
        phy = C.inter_reticle_area_mm2(
            4 * self.inter_reticle_bw_Bps(), self.integration)
        # TSV area depends on reticle area (bw per mm^2): solve fixed point
        base = self.reticle_compute_area_mm2() + phy
        if not self.use_stacked_dram:
            return base
        ratio = C.tsv_area_ratio(self.dram_bw_tbps_per_100mm2)
        return base / max(1.0 - ratio, 1e-3)

    def n_reticles(self) -> int:
        return self.reticle_array[0] * self.reticle_array[1]

    def wafer_area_mm2(self) -> float:
        return self.n_reticles() * self.reticle_area_mm2()

    def total_cores(self) -> int:
        return self.cores_per_reticle() * self.n_reticles()

    def core_flops(self) -> float:
        return C.core_peak_flops(self.mac_num)

    def reticle_flops(self) -> float:
        return self.core_flops() * self.cores_per_reticle()

    def wafer_flops(self) -> float:
        return self.reticle_flops() * self.n_reticles()

    def sram_per_reticle_bytes(self) -> float:
        return self.cores_per_reticle() * self.buffer_kb * 1024.0

    def static_power_w(self) -> float:
        per_core = C.core_static_w(self.mac_num, self.buffer_kb)
        dram = (C.DRAM_STATIC_W_PER_GB * self.dram_gb_per_reticle()
                * self.n_reticles())
        return per_core * self.total_cores() + dram

    def describe(self) -> str:
        return (f"{self.dataflow} mac={self.mac_num} buf={self.buffer_kb}KB "
                f"bw={self.buffer_bw}/{self.noc_bw}b "
                f"cores={self.core_array} ret={self.reticle_array} "
                f"ir={self.inter_reticle_bw_ratio:.2f}x "
                f"dram={'%.2fTB/s' % self.dram_bw_tbps_per_100mm2 if self.use_stacked_dram else 'off'} "
                f"{self.integration}")


# ---------------------------------------------------------------------------
# sampling / encoding for the explorer
# ---------------------------------------------------------------------------

# normalized [0,1]^d encoding: log-scaled for the exponential-range knobs
DIMS = ("dataflow", "mac", "buf_kb", "buf_bw", "noc_bw", "core_h", "core_w",
        "ir_ratio", "dram_on", "dram_bw", "ret_h", "ret_w", "integration")


def _log_scale(u: float, lo: float, hi: float) -> float:
    return lo * (hi / lo) ** u


def _log_unscale(v: float, lo: float, hi: float) -> float:
    return math.log(v / lo) / math.log(hi / lo)


def _pow2(v: float, lo: int, hi: int) -> int:
    p = int(round(math.log2(max(v, lo))))
    return int(min(max(2 ** p, lo), hi))


def decode(u: np.ndarray, max_core_dim: int = 32, max_ret_dim: int = 12
           ) -> WSCDesign:
    """[0,1]^13 -> WSCDesign (nearest feasible grid values)."""
    u = np.clip(np.asarray(u, dtype=np.float64), 0.0, 1.0)
    return WSCDesign(
        dataflow=DATAFLOWS[min(int(u[0] * 3), 2)],
        mac_num=_pow2(_log_scale(u[1], *MAC_RANGE), *MAC_RANGE),
        buffer_kb=_pow2(_log_scale(u[2], *BUF_KB_RANGE), *BUF_KB_RANGE),
        buffer_bw=_pow2(_log_scale(u[3], *BUF_BW_RANGE), *BUF_BW_RANGE),
        noc_bw=_pow2(_log_scale(u[4], *NOC_BW_RANGE), *NOC_BW_RANGE),
        core_array=(1 + int(u[5] * (max_core_dim - 1) + 0.5),
                    1 + int(u[6] * (max_core_dim - 1) + 0.5)),
        inter_reticle_bw_ratio=round(
            IR_RATIO_RANGE[0] + u[7] * (IR_RATIO_RANGE[1] - IR_RATIO_RANGE[0]), 2),
        use_stacked_dram=bool(u[8] >= 0.5),
        dram_bw_tbps_per_100mm2=round(
            _log_scale(u[9], *DRAM_BW_RANGE), 3),
        reticle_array=(1 + int(u[10] * (max_ret_dim - 1) + 0.5),
                       1 + int(u[11] * (max_ret_dim - 1) + 0.5)),
        integration=INTEGRATIONS[min(int(u[12] * 2), 1)],
    )


def encode(d: WSCDesign, max_core_dim: int = 32, max_ret_dim: int = 12
           ) -> np.ndarray:
    return np.array([
        DATAFLOWS.index(d.dataflow) / 2.0,
        _log_unscale(d.mac_num, *MAC_RANGE),
        _log_unscale(d.buffer_kb, *BUF_KB_RANGE),
        _log_unscale(d.buffer_bw, *BUF_BW_RANGE),
        _log_unscale(d.noc_bw, *NOC_BW_RANGE),
        (d.core_array[0] - 1) / (max_core_dim - 1),
        (d.core_array[1] - 1) / (max_core_dim - 1),
        (d.inter_reticle_bw_ratio - IR_RATIO_RANGE[0])
        / (IR_RATIO_RANGE[1] - IR_RATIO_RANGE[0]),
        1.0 if d.use_stacked_dram else 0.0,
        _log_unscale(d.dram_bw_tbps_per_100mm2, *DRAM_BW_RANGE),
        (d.reticle_array[0] - 1) / (max_ret_dim - 1),
        (d.reticle_array[1] - 1) / (max_ret_dim - 1),
        0.0 if d.integration == INTEGRATIONS[0] else 1.0,
    ])


def sample(rng: np.random.Generator, n: int) -> np.ndarray:
    """n raw points in [0,1]^13 (validator filters infeasible decodes)."""
    return rng.random((n, len(DIMS)))


def space_size_estimate() -> float:
    """Cardinality of the discrete grid (paper quotes ~8.4e14 feasible)."""
    return (3                      # dataflow
            * 10 * 7 * 8 * 8       # mac, buf, buf_bw, noc_bw (pow2 steps)
            * 32 * 32              # core array
            * 19                   # ir ratio grid 0.2..2.0 step .1
            * (1 + 13)             # dram off / bw grid
            * 12 * 12              # reticle array
            * 2)                   # integration


# ---------------------------------------------------------------------------
# batched (struct-of-arrays) backend — see DESIGN.md §4
# ---------------------------------------------------------------------------


def floor_log2(n: np.ndarray) -> np.ndarray:
    """Exact floor(log2(n)) for positive int arrays (float-log corrected)."""
    n = np.maximum(np.asarray(n, dtype=np.int64), 1)
    e = np.floor(np.log2(n.astype(np.float64))).astype(np.int64)
    # one ulp of float error can push e off by one either way
    e = np.where((np.int64(1) << np.minimum(e + 1, 62)) <= n, e + 1, e)
    e = np.where((np.int64(1) << np.minimum(e, 62)) > n, e - 1, e)
    return e


def decode_batch(U: np.ndarray, max_core_dim: int = 32, max_ret_dim: int = 12
                 ) -> List[WSCDesign]:
    """Vectorized decode of (N, 13) raw points; element i == decode(U[i])."""
    U = np.clip(np.atleast_2d(np.asarray(U, dtype=np.float64)), 0.0, 1.0)

    def pow2_col(u, lo, hi):
        v = np.maximum(lo * (hi / lo) ** u, lo)
        p = np.round(np.log2(v)).astype(np.int64)
        return np.clip(np.int64(1) << p, lo, hi)

    df = np.minimum((U[:, 0] * 3).astype(np.int64), 2)
    mac = pow2_col(U[:, 1], *MAC_RANGE)
    buf = pow2_col(U[:, 2], *BUF_KB_RANGE)
    bbw = pow2_col(U[:, 3], *BUF_BW_RANGE)
    nbw = pow2_col(U[:, 4], *NOC_BW_RANGE)
    ch = 1 + (U[:, 5] * (max_core_dim - 1) + 0.5).astype(np.int64)
    cw = 1 + (U[:, 6] * (max_core_dim - 1) + 0.5).astype(np.int64)
    ir = np.round(IR_RATIO_RANGE[0]
                  + U[:, 7] * (IR_RATIO_RANGE[1] - IR_RATIO_RANGE[0]), 2)
    don = U[:, 8] >= 0.5
    dbw = np.round(DRAM_BW_RANGE[0]
                   * (DRAM_BW_RANGE[1] / DRAM_BW_RANGE[0]) ** U[:, 9], 3)
    rh = 1 + (U[:, 10] * (max_ret_dim - 1) + 0.5).astype(np.int64)
    rw = 1 + (U[:, 11] * (max_ret_dim - 1) + 0.5).astype(np.int64)
    ig = np.minimum((U[:, 12] * 2).astype(np.int64), 1)
    return [WSCDesign(dataflow=DATAFLOWS[df[i]], mac_num=int(mac[i]),
                      buffer_kb=int(buf[i]), buffer_bw=int(bbw[i]),
                      noc_bw=int(nbw[i]), core_array=(int(ch[i]), int(cw[i])),
                      inter_reticle_bw_ratio=float(ir[i]),
                      use_stacked_dram=bool(don[i]),
                      dram_bw_tbps_per_100mm2=float(dbw[i]),
                      reticle_array=(int(rh[i]), int(rw[i])),
                      integration=INTEGRATIONS[ig[i]])
            for i in range(len(U))]


def encode_batch(designs: Sequence[WSCDesign], max_core_dim: int = 32,
                 max_ret_dim: int = 12) -> np.ndarray:
    """Vectorized encode: row i == encode(designs[i]). Returns (N, 13)."""
    def log_u(v, lo, hi):
        return np.log(np.asarray(v, np.float64) / lo) / math.log(hi / lo)

    cols = np.stack([
        np.array([DATAFLOWS.index(d.dataflow) for d in designs], np.float64) / 2.0,
        log_u([d.mac_num for d in designs], *MAC_RANGE),
        log_u([d.buffer_kb for d in designs], *BUF_KB_RANGE),
        log_u([d.buffer_bw for d in designs], *BUF_BW_RANGE),
        log_u([d.noc_bw for d in designs], *NOC_BW_RANGE),
        (np.array([d.core_array[0] for d in designs], np.float64) - 1)
        / (max_core_dim - 1),
        (np.array([d.core_array[1] for d in designs], np.float64) - 1)
        / (max_core_dim - 1),
        (np.array([d.inter_reticle_bw_ratio for d in designs]) - IR_RATIO_RANGE[0])
        / (IR_RATIO_RANGE[1] - IR_RATIO_RANGE[0]),
        np.array([1.0 if d.use_stacked_dram else 0.0 for d in designs]),
        log_u([d.dram_bw_tbps_per_100mm2 for d in designs], *DRAM_BW_RANGE),
        (np.array([d.reticle_array[0] for d in designs], np.float64) - 1)
        / (max_ret_dim - 1),
        (np.array([d.reticle_array[1] for d in designs], np.float64) - 1)
        / (max_ret_dim - 1),
        np.array([0.0 if d.integration == INTEGRATIONS[0] else 1.0
                  for d in designs]),
    ], axis=1)
    return cols


@dataclasses.dataclass
class DesignBatch:
    """Struct-of-arrays view of N designs: the vector encoding plus every
    derived geometry quantity the evaluation stack needs, all computed with
    vectorized NumPy so downstream kernels broadcast over a leading batch
    axis instead of calling per-design methods (DESIGN.md §4)."""
    designs: List[WSCDesign]
    # raw knobs
    dataflow_code: np.ndarray      # (N,) 0=WS 1=IS 2=OS
    mac: np.ndarray                # (N,) int64
    buffer_kb: np.ndarray
    buffer_bw: np.ndarray
    noc_bw: np.ndarray
    core_h: np.ndarray
    core_w: np.ndarray
    ir_ratio: np.ndarray
    dram_on: np.ndarray            # (N,) bool
    dram_bw_tbps: np.ndarray
    ret_h: np.ndarray
    ret_w: np.ndarray
    integ_code: np.ndarray         # 0=die_stitching 1=infosow
    spares_per_row: np.ndarray
    # derived geometry (all float64 unless noted)
    core_area_mm2: np.ndarray
    cores_per_reticle: np.ndarray  # int64
    n_reticles: np.ndarray         # int64
    total_cores: np.ndarray        # int64
    reticle_bisection_Bps: np.ndarray
    inter_reticle_bw_Bps: np.ndarray
    reticle_area_mm2: np.ndarray
    wafer_area_mm2: np.ndarray
    dram_bw_Bps_per_reticle: np.ndarray
    dram_gb_per_reticle: np.ndarray
    static_power_w: np.ndarray
    ir_energy_pj_per_bit: np.ndarray

    def __len__(self) -> int:
        return len(self.designs)

    @staticmethod
    def from_designs(designs: Sequence[WSCDesign]) -> "DesignBatch":
        designs = list(designs)
        df = np.array([DATAFLOWS.index(d.dataflow) for d in designs], np.int64)
        mac = np.array([d.mac_num for d in designs], np.int64)
        buf_kb = np.array([d.buffer_kb for d in designs], np.int64)
        buf_bw = np.array([d.buffer_bw for d in designs], np.int64)
        noc_bw = np.array([d.noc_bw for d in designs], np.int64)
        ch = np.array([d.core_array[0] for d in designs], np.int64)
        cw = np.array([d.core_array[1] for d in designs], np.int64)
        ir = np.array([d.inter_reticle_bw_ratio for d in designs], np.float64)
        don = np.array([d.use_stacked_dram for d in designs], bool)
        dbw = np.array([d.dram_bw_tbps_per_100mm2 for d in designs], np.float64)
        rh = np.array([d.reticle_array[0] for d in designs], np.int64)
        rw = np.array([d.reticle_array[1] for d in designs], np.int64)
        ig = np.array([INTEGRATIONS.index(d.integration) for d in designs],
                      np.int64)
        spares = np.array([d.spares_per_row for d in designs], np.int64)

        # components helpers are dtype-polymorphic: same formulas/constants
        # as the scalar WSCDesign methods, applied to the whole batch
        core_area = C.core_area_mm2(mac, buf_kb, buf_bw, noc_bw)

        cpr = ch * cw
        nret = rh * rw
        total = cpr * nret
        bisect = np.minimum(ch, cw) * noc_bw / 8.0 * C.CLOCK_HZ
        ir_bw = ir * bisect

        # --- reticle area fixed point (WSCDesign.reticle_area_mm2) ---------
        phy = (4.0 * ir_bw) * 8e-9 * np.where(
            ig == 1, C.IR_AREA_UM2_PER_GBPS["infosow"],
            C.IR_AREA_UM2_PER_GBPS["die_stitching"]) * 1e-6
        compute_a = (cw + spares) * ch * core_area
        base = compute_a + phy
        tsv_ratio = C.tsv_area_ratio(dbw)
        r_area = np.where(don, base / np.maximum(1.0 - tsv_ratio, 1e-3), base)

        dram_bw_Bps = np.where(don, dbw * 1e12 * r_area / 100.0, 0.0)
        dram_gb = np.where(don, C.dram_gb_at_bw(dbw) * r_area / 100.0, 0.0)

        per_core_w = C.core_static_w(mac, buf_kb)
        static_w = per_core_w * total + C.DRAM_STATIC_W_PER_GB * dram_gb * nret

        ir_pj = np.where(ig == 1, C.IR_ENERGY_PJ_PER_BIT["infosow"],
                         C.IR_ENERGY_PJ_PER_BIT["die_stitching"])

        return DesignBatch(
            designs=designs, dataflow_code=df, mac=mac, buffer_kb=buf_kb,
            buffer_bw=buf_bw, noc_bw=noc_bw, core_h=ch, core_w=cw,
            ir_ratio=ir, dram_on=don, dram_bw_tbps=dbw, ret_h=rh, ret_w=rw,
            integ_code=ig, spares_per_row=spares, core_area_mm2=core_area,
            cores_per_reticle=cpr, n_reticles=nret, total_cores=total,
            reticle_bisection_Bps=bisect, inter_reticle_bw_Bps=ir_bw,
            reticle_area_mm2=r_area, wafer_area_mm2=nret * r_area,
            dram_bw_Bps_per_reticle=dram_bw_Bps, dram_gb_per_reticle=dram_gb,
            static_power_w=static_w, ir_energy_pj_per_bit=ir_pj)

    def take(self, idx: np.ndarray) -> "DesignBatch":
        """Gather rows (with repetition) — used to expand designs to the
        flattened (design, strategy) candidate axis."""
        idx = np.asarray(idx, np.int64)
        kw = {}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if f.name == "designs":
                kw[f.name] = [self.designs[i] for i in idx]
            else:
                kw[f.name] = v[idx]
        return DesignBatch(**kw)


# ---------------------------------------------------------------------------
# joint (architecture, strategy) search space — ISSUE 9 tentpole.
#
# The parallelization strategy stops being a dense grid scored inside the
# evaluator and becomes extra normalized dimensions appended to the 13-dim
# architecture encoding, so MFMOBO proposes joint points directly.
# Power-of-two axes (tp/pp/dp/ep) encode as exponent fractions of a
# workload-derived cap; microbatch count indexes the discrete choice list;
# recompute and the pipeline schedule are threshold bits.
# ---------------------------------------------------------------------------

STRATEGY_DIMS = ("tp", "pp", "dp", "ep", "microbatches", "recompute",
                 "schedule")
MB_CHOICES = (1, 2, 4, 8, 16, 32)


def _exp_of(v: int) -> int:
    return max(int(v), 1).bit_length() - 1


@dataclasses.dataclass(frozen=True)
class StrategySpace:
    """Bounds of the strategy axes for one workload: max exponent of each
    power-of-two split and the microbatch choice list. Frozen/hashable so a
    space can key caches and compare for checkpoint-resume equality."""
    tp_exp: int = 16
    pp_exp: int = 6
    dp_exp: int = 9
    ep_exp: int = 0
    mb_choices: Tuple[int, ...] = MB_CHOICES
    train: bool = True

    @property
    def n_dims(self) -> int:
        return len(STRATEGY_DIMS)

    @classmethod
    def for_workload(cls, wl, total_cores: int) -> "StrategySpace":
        """Derive the axis caps from the workload and the largest system
        under search (`compiler.derived_strategy_caps`)."""
        from repro.core.compiler import derived_strategy_caps
        caps = derived_strategy_caps(wl, total_cores)
        train = wl.phase == "train"
        mbs = tuple(m for m in MB_CHOICES
                    if m <= caps["microbatches"]) or (1,)
        return cls(tp_exp=_exp_of(caps["tp"]), pp_exp=_exp_of(caps["pp"]),
                   dp_exp=_exp_of(caps["dp"]), ep_exp=_exp_of(caps["ep"]),
                   mb_choices=mbs, train=train)

    # -- JSON round-trip (CampaignSpec strategy-space bounds) --------------

    def to_json(self) -> Dict:
        return {"tp_exp": self.tp_exp, "pp_exp": self.pp_exp,
                "dp_exp": self.dp_exp, "ep_exp": self.ep_exp,
                "mb_choices": list(self.mb_choices), "train": self.train}

    @classmethod
    def from_json(cls, obj: Dict) -> "StrategySpace":
        return cls(tp_exp=int(obj["tp_exp"]), pp_exp=int(obj["pp_exp"]),
                   dp_exp=int(obj["dp_exp"]), ep_exp=int(obj["ep_exp"]),
                   mb_choices=tuple(int(m) for m in obj["mb_choices"]),
                   train=bool(obj["train"]))

    # -- codec -------------------------------------------------------------

    def decode_arrays(self, Us: np.ndarray) -> Dict[str, np.ndarray]:
        """(N, 7) strategy columns -> dict of per-axis arrays. Row i equals
        the scalar `decode_strategy(Us[i])`."""
        Us = np.clip(np.atleast_2d(np.asarray(Us, np.float64)), 0.0, 1.0)
        tp = np.int64(1) << np.round(Us[:, 0] * self.tp_exp).astype(np.int64)
        pp = np.int64(1) << np.round(Us[:, 1] * self.pp_exp).astype(np.int64)
        dp = np.int64(1) << np.round(Us[:, 2] * self.dp_exp).astype(np.int64)
        ep = np.int64(1) << np.round(Us[:, 3] * self.ep_exp).astype(np.int64)
        mbi = np.round(Us[:, 4] * (len(self.mb_choices) - 1)).astype(np.int64)
        mb = np.asarray(self.mb_choices, np.int64)[mbi]
        if not self.train:
            mb = np.ones_like(mb)
        rc = (Us[:, 5] >= 0.5) if self.train else np.zeros(len(Us), bool)
        gpipe = Us[:, 6] >= 0.5
        return {"tp": tp, "pp": pp, "dp": dp, "ep": ep, "mb": mb,
                "recompute": rc, "gpipe": gpipe}

    def decode_strategy(self, u_s: np.ndarray):
        """(7,) strategy columns -> compiler.Strategy."""
        from repro.core.compiler import Strategy
        a = self.decode_arrays(np.asarray(u_s)[None, :])
        return Strategy(int(a["tp"][0]), int(a["pp"][0]), int(a["dp"][0]),
                        int(a["mb"][0]), ep=int(a["ep"][0]),
                        recompute=bool(a["recompute"][0]),
                        schedule="gpipe" if a["gpipe"][0] else "1f1b")

    def encode_strategy(self, s) -> np.ndarray:
        """compiler.Strategy -> (7,) columns; decode_strategy round-trips any
        strategy inside the caps."""
        def frac(v, cap):
            return _exp_of(v) / cap if cap else 0.0

        mb = min(self.mb_choices, key=lambda m: abs(m - s.microbatches))
        mbi = self.mb_choices.index(mb)
        mb_f = mbi / (len(self.mb_choices) - 1) if len(self.mb_choices) > 1 \
            else 0.0
        return np.array([
            frac(s.tp, self.tp_exp), frac(s.pp, self.pp_exp),
            frac(s.dp, self.dp_exp), frac(s.ep, self.ep_exp), mb_f,
            1.0 if s.recompute else 0.0,
            1.0 if s.schedule == "gpipe" else 0.0])

    def encode_batch(self, strategies) -> np.ndarray:
        return np.stack([self.encode_strategy(s) for s in strategies]) \
            if strategies else np.zeros((0, self.n_dims))


@dataclasses.dataclass(frozen=True)
class JointDesign:
    """One joint (architecture, strategy) search point."""
    design: WSCDesign
    strategy: "object"             # compiler.Strategy (lazy to avoid cycle)

    def describe(self) -> str:
        s = self.strategy
        sched = f" {s.schedule}" if s.schedule != "1f1b" else ""
        rc = " rc" if s.recompute else ""
        ep = f" ep={s.ep}" if s.ep > 1 else ""
        return (f"{self.design.describe()} | tp={s.tp} pp={s.pp} dp={s.dp} "
                f"mb={s.microbatches}{ep}{rc}{sched}")


def joint_dims(space: StrategySpace) -> int:
    return len(DIMS) + space.n_dims


def sample_joint(rng: np.random.Generator, n: int,
                 space: StrategySpace) -> np.ndarray:
    """n raw points in [0,1]^(13+7) (joint validator filters infeasible)."""
    return rng.random((n, joint_dims(space)))


def decode_joint_batch(U: np.ndarray, space: StrategySpace,
                       max_core_dim: int = 32, max_ret_dim: int = 12
                       ) -> List[JointDesign]:
    """Vectorized joint decode: architecture columns through `decode_batch`,
    strategy columns through the space codec."""
    from repro.core.compiler import Strategy
    U = np.atleast_2d(np.asarray(U, np.float64))
    designs = decode_batch(U[:, :len(DIMS)], max_core_dim, max_ret_dim)
    a = space.decode_arrays(U[:, len(DIMS):])
    return [JointDesign(d, Strategy(
        int(a["tp"][i]), int(a["pp"][i]), int(a["dp"][i]), int(a["mb"][i]),
        ep=int(a["ep"][i]), recompute=bool(a["recompute"][i]),
        schedule="gpipe" if a["gpipe"][i] else "1f1b"))
        for i, d in enumerate(designs)]


def encode_joint_batch(points: Sequence[JointDesign], space: StrategySpace,
                       max_core_dim: int = 32, max_ret_dim: int = 12
                       ) -> np.ndarray:
    """Row i == concat(encode(design_i), encode_strategy(strategy_i))."""
    if not points:
        return np.zeros((0, joint_dims(space)))
    arch = encode_batch([p.design for p in points], max_core_dim,
                        max_ret_dim)
    strat = space.encode_batch([p.strategy for p in points])
    return np.concatenate([arch, strat], axis=1)
