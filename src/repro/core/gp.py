"""Gaussian-process surrogate in pure JAX (paper §VII: GP surrogates per
fidelity). Matern-5/2 ARD kernel, Cholesky posterior, marginal-likelihood
hyperparameter fit by Adam on (lengthscales, signal, noise).

Compiled hot path (DESIGN.md §10): every GP lives in a static-shape padded
buffer of pow2 capacity B >= n, with a 0/1 row mask. Padded rows are made
exactly inert by the block-diagonal trick — kernel rows/columns zeroed,
unit diagonal, zero targets — so the Cholesky factor of the padded matrix
is [[L, 0], [0, I]] and every downstream solve reproduces the unpadded
result bitwise. That lets:

  * `fit` run the whole Adam loop as one jitted `lax.scan` (one XLA call
    per (B, d, iters) bucket instead of `iters` eager dispatches),
  * `predict` run as a single jitted triangular solve,
  * `condition_on` append an observation as a rank-1 Cholesky update at a
    *traced* index — O(B^2), no re-factorization, no retrace as n grows
    within a bucket.

The pre-compilation NumPy implementation is retained verbatim in
`repro.core.gp_ref.NumpyGP` as the property-test oracle.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

MIN_BUCKET = 8


def bucket_size(n: int, minimum: int = MIN_BUCKET) -> int:
    """Smallest power of two >= max(n, minimum) — the static buffer
    capacities fit/condition_on compile against."""
    return max(minimum, 1 << max(int(n) - 1, 0).bit_length())


@dataclasses.dataclass
class GPParams:
    log_ls: jnp.ndarray        # (d,)
    log_sf: jnp.ndarray        # ()
    log_noise: jnp.ndarray     # ()


def _matern52(x1, x2, ls, sf):
    d = jnp.sqrt(jnp.maximum(
        jnp.sum(((x1[:, None, :] - x2[None, :, :]) / ls) ** 2, -1), 1e-12))
    s5 = jnp.sqrt(5.0) * d
    return sf * (1 + s5 + 5.0 * d * d / 3.0) * jnp.exp(-s5)


def _masked_kernel(X, mask, ls, sf, noise):
    """K over the padded buffer: real block intact, padded rows/cols = e_i
    (unit diagonal) so chol/solves factor through the padding untouched."""
    K = _matern52(X, X, ls, sf) * (mask[:, None] * mask[None, :])
    return K + jnp.diag(jnp.where(mask > 0, noise, 1.0))


def _nll_masked(raw, X, y, mask, n_real):
    ls = jnp.exp(raw["log_ls"])
    sf = jnp.exp(raw["log_sf"])
    noise = jnp.exp(raw["log_noise"]) + 1e-6
    K = _masked_kernel(X, mask, ls, sf, noise)
    L = jnp.linalg.cholesky(K)
    a = jax.scipy.linalg.cho_solve((L, True), y)
    return (0.5 * y @ a + jnp.sum(jnp.log(jnp.diag(L)))
            + 0.5 * n_real * jnp.log(2 * jnp.pi))


def _adam_scan(X, y, mask, n_real, lr, iters):
    """The reference Adam loop as a lax.scan. The eager loop `break`s (and
    keeps the pre-update params) the first time the NLL goes non-finite;
    here a `frozen` flag makes every subsequent update a no-op, which lands
    on the same parameters."""
    d = X.shape[1]
    raw = {"log_ls": jnp.zeros(d, X.dtype) + jnp.log(0.3),
           "log_sf": jnp.zeros((), X.dtype),
           "log_noise": jnp.zeros((), X.dtype) + jnp.log(0.05)}
    grad_fn = jax.value_and_grad(lambda r: _nll_masked(r, X, y, mask, n_real))
    m0 = jax.tree.map(jnp.zeros_like, raw)
    v0 = jax.tree.map(jnp.zeros_like, raw)

    def step(carry, t):
        raw, m, v, frozen = carry
        val, g = grad_fn(raw)
        frozen = frozen | ~jnp.isfinite(val)
        m2 = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v2 = jax.tree.map(lambda a, b: 0.999 * a + 0.001 * b * b, v, g)
        raw2 = jax.tree.map(
            lambda p, m_, v_: p - lr * (m_ / (1 - 0.9 ** t))
            / (jnp.sqrt(v_ / (1 - 0.999 ** t)) + 1e-8), raw, m2, v2)
        pick = lambda new, old: jax.tree.map(
            lambda a, b: jnp.where(frozen, b, a), new, old)
        return (pick(raw2, raw), pick(m2, m), pick(v2, v), frozen), None

    ts = jnp.arange(1, iters + 1, dtype=X.dtype)
    (raw, _, _, _), _ = jax.lax.scan(step, (raw, m0, v0, jnp.array(False)), ts)
    return raw


def _posterior(raw, X, y, mask):
    ls = jnp.exp(raw["log_ls"])
    sf = jnp.exp(raw["log_sf"])
    noise = jnp.exp(raw["log_noise"]) + 1e-6
    K = _masked_kernel(X, mask, ls, sf, noise)
    L = jnp.linalg.cholesky(K)
    alpha = jax.scipy.linalg.cho_solve((L, True), y)
    return L, alpha


@partial(jax.jit, static_argnames=("iters",))
def _fit_one_jit(X, y, mask, n_real, lr, iters):
    raw = _adam_scan(X, y, mask, n_real, lr, iters)
    L, alpha = _posterior(raw, X, y, mask)
    return raw, L, alpha


@partial(jax.jit, static_argnames=("iters",))
def _fit_pair_jit(X, Y2, mask, n_real, lr, iters):
    """Both objective GPs share X: vmap the whole fit over the target axis
    so one XLA program refits the (throughput, power) pair."""
    def one(y):
        raw = _adam_scan(X, y, mask, n_real, lr, iters)
        L, alpha = _posterior(raw, X, y, mask)
        return raw, L, alpha
    return jax.vmap(one)(Y2)


@jax.jit
def _predict_jit(Xs, X, mask, L, alpha, log_ls, log_sf, mean, std):
    ls = jnp.exp(log_ls)
    sf = jnp.exp(log_sf)
    Ks = _matern52(Xs, X, ls, sf) * mask[None, :]
    mu = Ks @ alpha
    v = jax.scipy.linalg.solve_triangular(L, Ks.T, lower=True)
    var = jnp.maximum(sf - jnp.sum(v * v, axis=0), 1e-10)
    return mu * std + mean, jnp.sqrt(var) * std


@jax.jit
def _rank1_jit(X, y, mask, L, log_ls, log_sf, log_noise, n, x_new, y_norm):
    """Append (x_new, y_norm) at traced row n of the padded buffer: one
    masked kernel row, one triangular solve for the new Cholesky row, two
    O(B^2) triangular solves for alpha. Row n of L is e_n before the
    update (padding identity), so overwriting it in place is exact."""
    ls = jnp.exp(log_ls)
    sf = jnp.exp(log_sf)
    noise = jnp.exp(log_noise) + 1e-6
    k = _matern52(x_new[None, :], X, ls, sf)[0] * mask
    c = jax.scipy.linalg.solve_triangular(L, k, lower=True)
    dd = jnp.sqrt(jnp.maximum(sf + noise - c @ c, 1e-10))
    L2 = L.at[n, :].set(c).at[n, n].set(dd)
    X2 = X.at[n, :].set(x_new)
    y2 = y.at[n].set(y_norm)
    mask2 = mask.at[n].set(1.0)
    alpha2 = jax.scipy.linalg.cho_solve((L2, True), y2)
    return X2, y2, mask2, L2, alpha2


@dataclasses.dataclass
class GP:
    """Fitted GP over a padded buffer of capacity B (pow2 bucket >= n).

    `X`/`y`(normalized)/`chol`/`alpha` are (B, ...) device arrays; `mask`
    flags the n real rows. `params` is a plain host dict shared (by object
    identity) across `condition_on` fantasies — no hyperparameter refit.
    """
    X: jnp.ndarray             # (B, d)
    y: jnp.ndarray             # (B,) normalized targets, 0 on padding
    params: dict
    mean: float
    std: float
    chol: jnp.ndarray          # (B, B) lower; identity on padded rows
    alpha: jnp.ndarray         # (B,)
    mask: jnp.ndarray = None   # (B,) 1.0 = real row
    n: int = 0                 # real observation count

    @staticmethod
    def _pad(X: np.ndarray, y_norm: np.ndarray, capacity: int, dtype
             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        n, d = X.shape
        Xp = np.zeros((capacity, d), dtype)
        Xp[:n] = X
        yp = np.zeros(capacity, dtype)
        yp[:n] = y_norm
        mask = np.zeros(capacity, dtype)
        mask[:n] = 1.0
        return jnp.asarray(Xp), jnp.asarray(yp), jnp.asarray(mask)

    @staticmethod
    def fit(X: np.ndarray, y: np.ndarray, iters: int = 80,
            lr: float = 0.05, seed: int = 0,
            dtype: np.dtype = np.float32) -> "GP":
        """One jitted XLA program per (bucket, d, iters) shape: Adam over
        the masked marginal likelihood via lax.scan, then the posterior
        factorization. `dtype` is threaded through the whole fit (float64
        needs JAX_ENABLE_X64/ jax.config x64 to take effect)."""
        X = np.asarray(X, dtype)
        mean, std = float(np.mean(y)), float(np.std(y) + 1e-9)
        yn = ((np.asarray(y) - mean) / std).astype(dtype)
        Xp, yp, mask = GP._pad(X, yn, bucket_size(len(X)), dtype)
        raw, L, alpha = _fit_one_jit(Xp, yp, mask, jnp.asarray(len(X), dtype),
                                     jnp.asarray(lr, dtype), iters)
        return GP(Xp, yp, jax.tree.map(np.asarray, raw), mean, std, L, alpha,
                  mask, len(X))

    @staticmethod
    def fit_pair(X: np.ndarray, ys: Tuple[np.ndarray, np.ndarray],
                 iters: int = 80, lr: float = 0.05,
                 dtype: np.dtype = np.float32) -> Tuple["GP", "GP"]:
        """Fit two GPs sharing the same inputs (the per-objective surrogate
        pair) in a single vmapped XLA call."""
        X = np.asarray(X, dtype)
        stats = [(float(np.mean(y)), float(np.std(y) + 1e-9)) for y in ys]
        Y2 = np.stack([((np.asarray(y) - m) / s).astype(dtype)
                       for y, (m, s) in zip(ys, stats)])
        B = bucket_size(len(X))
        Xp, _, mask = GP._pad(X, Y2[0], B, dtype)
        Yp = np.zeros((2, B), dtype)
        Yp[:, :len(X)] = Y2
        raw, L, alpha = _fit_pair_jit(Xp, jnp.asarray(Yp), mask,
                                      jnp.asarray(len(X), dtype),
                                      jnp.asarray(lr, dtype), iters)
        out = []
        for i, (m, s) in enumerate(stats):
            params = {k: np.asarray(v[i]) for k, v in raw.items()}
            out.append(GP(Xp, jnp.asarray(Yp[i]), params, m, s, L[i],
                          alpha[i], mask, len(X)))
        return out[0], out[1]

    @property
    def capacity(self) -> int:
        return self.X.shape[0]

    @property
    def dtype(self):
        return self.X.dtype

    def X_real(self) -> np.ndarray:
        return np.asarray(self.X[:self.n])

    def y_real(self) -> np.ndarray:
        return np.asarray(self.y[:self.n])

    def with_capacity(self, capacity: int) -> "GP":
        """Re-pad into a larger buffer. The padded kernel is block-diagonal
        [[K, 0], [0, I]], so the grown Cholesky/alpha are just the old ones
        with identity/zero padding — no refactorization."""
        B0 = self.capacity
        if capacity <= B0:
            return self
        d = self.X.shape[1]
        X2 = np.zeros((capacity, d), self.dtype)
        X2[:B0] = np.asarray(self.X)
        y2 = np.zeros(capacity, self.dtype)
        y2[:B0] = np.asarray(self.y)
        m2 = np.zeros(capacity, self.dtype)
        m2[:B0] = np.asarray(self.mask)
        L2 = np.eye(capacity, dtype=self.dtype)
        L2[:B0, :B0] = np.asarray(self.chol)
        a2 = np.zeros(capacity, self.dtype)
        a2[:B0] = np.asarray(self.alpha)
        return GP(jnp.asarray(X2), jnp.asarray(y2), self.params, self.mean,
                  self.std, jnp.asarray(L2), jnp.asarray(a2),
                  jnp.asarray(m2), self.n)

    def predict(self, Xs: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Posterior mean/std at Xs (de-normalized), one jitted call."""
        mu, sd = _predict_jit(
            jnp.asarray(np.asarray(Xs, self.dtype)), self.X, self.mask,
            self.chol, self.alpha, jnp.asarray(self.params["log_ls"]),
            jnp.asarray(self.params["log_sf"]),
            jnp.asarray(self.mean, self.dtype),
            jnp.asarray(self.std, self.dtype))
        return np.asarray(mu, np.float64), np.asarray(sd, np.float64)

    def condition_on(self, x: np.ndarray, y: float) -> "GP":
        """Posterior GP after observing (x, y) — a rank-1 Cholesky append
        at a traced index, no hyperparameter refit, no retrace while the
        observation count stays within the capacity bucket. This is the
        'fantasy' update used by greedy q-EHVI (DESIGN.md §5)."""
        g = self.with_capacity(bucket_size(self.n + 1))
        yn = (float(y) - g.mean) / g.std
        X2, y2, m2, L2, a2 = _rank1_jit(
            g.X, g.y, g.mask, g.chol, jnp.asarray(g.params["log_ls"]),
            jnp.asarray(g.params["log_sf"]),
            jnp.asarray(g.params["log_noise"]), g.n,
            jnp.asarray(np.asarray(x, g.dtype).reshape(-1)),
            jnp.asarray(yn, g.dtype))
        return GP(X2, y2, g.params, g.mean, g.std, L2, a2, m2, g.n + 1)
