"""Multi-Fidelity Multi-Objective Bayesian Optimization — paper Algorithm 1.

Two evaluation fidelities (f1 = analytical, f0 = GNN-based — paper §VII
notes CA simulation is kept out of the loop for cost), GP surrogates per
(fidelity x objective), EHVI acquisition with hypervolume reference
(throughput 0, peak power). The schedule:

    iterations [0, N1-d1):            evaluate f1, acquire with M1
    iterations [N1-d1, N1-d1+k):      evaluate f0, acquire with M1 (handover)
    iterations [N1-d1+k, ...):        evaluate f0, acquire with M0

Baselines for Fig. 8: random search and single-fidelity MOBO.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.design_space import WSCDesign, decode, sample
from repro.core.ehvi import ehvi_2d
from repro.core.gp import GP
from repro.core.pareto import hypervolume_2d, pareto_front, to_max_space
from repro.core.validator import validate

EvalFn = Callable[[WSCDesign], Tuple[float, float]]   # -> (throughput, power)


@dataclasses.dataclass
class Trace:
    xs: List[np.ndarray]
    designs: List[WSCDesign]
    ys: List[Tuple[float, float]]         # (throughput, power)
    hv: List[float]                       # hypervolume after each iteration
    wall_s: List[float]

    def points_max(self) -> np.ndarray:
        t = np.array([y[0] for y in self.ys])
        p = np.array([y[1] for y in self.ys])
        return to_max_space(t, p)

    def pareto(self) -> np.ndarray:
        return pareto_front(self.points_max())


def _valid_candidates(rng: np.random.Generator, n: int,
                      max_tries: int = 8) -> Tuple[np.ndarray, List[WSCDesign]]:
    xs, ds = [], []
    for _ in range(max_tries):
        for u in sample(rng, n):
            d = decode(u)
            r = validate(d)
            if r.ok:
                xs.append(u)
                ds.append(r.design)
            if len(xs) >= n:
                return np.array(xs), ds
    return np.array(xs), ds


def _fit_models(X: np.ndarray, Y: np.ndarray) -> Tuple[GP, GP]:
    g_t = GP.fit(X, np.log1p(np.maximum(Y[:, 0], 0.0)))
    g_p = GP.fit(X, -np.log(np.maximum(Y[:, 1], 1.0)))
    return g_t, g_p


def _acquire(models: Tuple[GP, GP], cand_x: np.ndarray,
             evaluated: np.ndarray, ref: np.ndarray) -> int:
    g_t, g_p = models
    mu_t, s_t = g_t.predict(cand_x)
    mu_p, s_p = g_p.predict(cand_x)
    mu = np.stack([mu_t, mu_p], 1)
    sg = np.stack([s_t, s_p], 1)
    front = pareto_front(evaluated) if len(evaluated) else np.zeros((0, 2))
    scores = ehvi_2d(mu, sg, front, ref)
    return int(np.argmax(scores))


def _obj_space(ys: List[Tuple[float, float]]) -> np.ndarray:
    """(log throughput, -log power) — the space GPs and HV operate in."""
    t = np.log1p(np.maximum(np.array([y[0] for y in ys]), 0.0))
    p = -np.log(np.maximum(np.array([y[1] for y in ys]), 1.0))
    return np.stack([t, p], 1)


def _hv_ref(peak_power: float) -> np.ndarray:
    return np.array([0.0, -np.log(max(peak_power, 1.0))])


def run_mfmobo(f0: EvalFn, f1: EvalFn, *, d0: int = 3, d1: int = 3,
               k: int = 5, N0: int = 20, N1: int = 30,
               peak_power: float = 15000.0, n_candidates: int = 256,
               seed: int = 0) -> Trace:
    rng = np.random.default_rng(seed)
    ref = _hv_ref(peak_power)
    tr = Trace([], [], [], [], [])

    X0, Y0, X1, Y1 = [], [], [], []

    def record(x, d, y):
        tr.xs.append(x)
        tr.designs.append(d)
        tr.ys.append(y)
        pts = _obj_space(tr.ys)
        tr.hv.append(hypervolume_2d(pts, ref))
        tr.wall_s.append(time.time())

    # priors
    init_x, init_d = _valid_candidates(rng, d0 + d1)
    for i in range(d1):
        y = f1(init_d[i])
        X1.append(init_x[i]); Y1.append(y)
    for i in range(d1, d1 + d0):
        y = f0(init_d[i])
        X0.append(init_x[i]); Y0.append(y)
        record(init_x[i], init_d[i], y)

    total = N0 + N1 - d0 - d1
    use_f0 = False
    use_m0 = False
    for i in range(total):
        if i == N1 - d1:
            use_f0 = True
        if i == N1 - d1 + k:
            use_m0 = True
        cand_x, cand_d = _valid_candidates(rng, n_candidates)
        if use_m0 and len(X0) >= 2:
            models = _fit_models(np.array(X0), np.array(Y0))
            ev = _obj_space(Y0)
        else:
            models = _fit_models(np.array(X1), np.array(Y1))
            ev = _obj_space(Y1) if not use_f0 or not Y0 else _obj_space(Y0)
        j = _acquire(models, cand_x, ev, ref)
        x, d = cand_x[j], cand_d[j]
        if use_f0:
            y = f0(d)
            X0.append(x); Y0.append(y)
            record(x, d, y)
        else:
            y = f1(d)
            X1.append(x); Y1.append(y)
    return tr


def run_mobo(f0: EvalFn, *, d0: int = 6, N: int = 20,
             peak_power: float = 15000.0, n_candidates: int = 256,
             seed: int = 0) -> Trace:
    """Single-fidelity MOBO baseline (paper Fig. 8)."""
    rng = np.random.default_rng(seed)
    ref = _hv_ref(peak_power)
    tr = Trace([], [], [], [], [])
    X, Y = [], []
    init_x, init_d = _valid_candidates(rng, d0)
    for i in range(len(init_x)):
        y = f0(init_d[i])
        X.append(init_x[i]); Y.append(y)
        tr.xs.append(init_x[i]); tr.designs.append(init_d[i]); tr.ys.append(y)
        tr.hv.append(hypervolume_2d(_obj_space(tr.ys), ref))
        tr.wall_s.append(time.time())
    for i in range(N - d0):
        models = _fit_models(np.array(X), np.array(Y))
        cand_x, cand_d = _valid_candidates(rng, n_candidates)
        j = _acquire(models, cand_x, _obj_space(Y), ref)
        y = f0(cand_d[j])
        X.append(cand_x[j]); Y.append(y)
        tr.xs.append(cand_x[j]); tr.designs.append(cand_d[j]); tr.ys.append(y)
        tr.hv.append(hypervolume_2d(_obj_space(tr.ys), ref))
        tr.wall_s.append(time.time())
    return tr


def run_random(f0: EvalFn, *, N: int = 20, peak_power: float = 15000.0,
               seed: int = 0) -> Trace:
    rng = np.random.default_rng(seed)
    ref = _hv_ref(peak_power)
    tr = Trace([], [], [], [], [])
    xs, ds = _valid_candidates(rng, N)
    for x, d in zip(xs, ds):
        y = f0(d)
        tr.xs.append(x); tr.designs.append(d); tr.ys.append(y)
        tr.hv.append(hypervolume_2d(_obj_space(tr.ys), ref))
        tr.wall_s.append(time.time())
    return tr
