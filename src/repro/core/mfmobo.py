"""Multi-Fidelity Multi-Objective Bayesian Optimization — paper Algorithm 1.

Two evaluation fidelities (f1 = analytical, f0 = GNN-based — paper §VII
notes CA simulation is kept out of the loop for cost), GP surrogates per
(fidelity x objective), EHVI acquisition with hypervolume reference
(throughput 0, peak power). The schedule:

    evaluations [0, N1-d1):           evaluate f1, acquire with M1
    evaluations [N1-d1, N1-d1+k):     evaluate f0, acquire with M1 (handover)
    evaluations [N1-d1+k, ...):       evaluate f0, acquire with M0

Each iteration proposes a batch of q candidates by greedy q-EHVI with
fantasized observations (DESIGN.md §5): pick the EHVI argmax, condition the
GPs on its posterior mean (GP.condition_on), extend the fantasy front, and
repeat — then evaluate the whole batch in one call. Evaluation functions
may be scalar (design -> (throughput, power)) or batch-aware (marked with
`.batched = True`, e.g. `evaluator.batched_objectives`), in which case the
whole proposal is scored in a single vectorized pass. With q=1 the loop is
the paper's serial Algorithm 1.

Baselines for Fig. 8: random search and single-fidelity MOBO.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.design_space import WSCDesign, decode_batch, sample
from repro.core.ehvi import ehvi_2d
from repro.core.gp import GP
from repro.core.pareto import hypervolume_2d, pareto_front, to_max_space
from repro.core.validator import validate

EvalFn = Callable[[WSCDesign], Tuple[float, float]]   # -> (throughput, power)


@dataclasses.dataclass
class Trace:
    xs: List[np.ndarray]
    designs: List[WSCDesign]
    ys: List[Tuple[float, float]]         # (throughput, power)
    hv: List[float]                       # hypervolume after each evaluation
    wall_s: List[float]
    n_evals: int = 0                      # total evals incl. f1-only points

    def points_max(self) -> np.ndarray:
        t = np.array([y[0] for y in self.ys])
        p = np.array([y[1] for y in self.ys])
        return to_max_space(t, p)

    def pareto(self) -> np.ndarray:
        return pareto_front(self.points_max())


def _eval_many(f: EvalFn, designs: Sequence[WSCDesign]
               ) -> List[Tuple[float, float]]:
    """Evaluate a proposal batch: one vectorized call for batch-aware
    objective functions, a scalar loop otherwise."""
    if getattr(f, "batched", False):
        return [(float(t), float(p)) for t, p in f(list(designs))]
    return [(float(y[0]), float(y[1])) for y in (f(d) for d in designs)]


def _valid_candidates(rng: np.random.Generator, n: int,
                      max_tries: int = 8) -> Tuple[np.ndarray, List[WSCDesign]]:
    xs, ds = [], []
    for _ in range(max_tries):
        us = sample(rng, n)
        for u, d in zip(us, decode_batch(us)):
            r = validate(d)
            if r.ok:
                xs.append(u)
                ds.append(r.design)
            if len(xs) >= n:
                return np.array(xs), ds
    return np.array(xs), ds


def _fit_models(X: np.ndarray, Y: np.ndarray) -> Tuple[GP, GP]:
    g_t = GP.fit(X, np.log1p(np.maximum(Y[:, 0], 0.0)))
    g_p = GP.fit(X, -np.log(np.maximum(Y[:, 1], 1.0)))
    return g_t, g_p


def _acquire_batch(models: Tuple[GP, GP], cand_x: np.ndarray,
                   evaluated: np.ndarray, ref: np.ndarray,
                   q: int = 1) -> List[int]:
    """Greedy q-EHVI with fantasized observations. Returns q distinct
    candidate indices; q=1 reduces exactly to the scalar EHVI argmax."""
    g_t, g_p = models
    fantasy_pts = np.asarray(evaluated, float).reshape(-1, 2)
    chosen: List[int] = []
    q = max(1, min(q, len(cand_x)))
    while len(chosen) < q:
        mu_t, s_t = g_t.predict(cand_x)
        mu_p, s_p = g_p.predict(cand_x)
        mu = np.stack([mu_t, mu_p], 1)
        sg = np.stack([s_t, s_p], 1)
        front = (pareto_front(fantasy_pts) if len(fantasy_pts)
                 else np.zeros((0, 2)))
        scores = ehvi_2d(mu, sg, front, ref)
        if chosen:
            scores[np.asarray(chosen)] = -np.inf
        j = int(np.argmax(scores))
        chosen.append(j)
        if len(chosen) == q:
            break
        # fantasize the observation at the posterior mean and condition
        g_t = g_t.condition_on(cand_x[j], float(mu_t[j]))
        g_p = g_p.condition_on(cand_x[j], float(mu_p[j]))
        fantasy_pts = np.concatenate([fantasy_pts, mu[j:j + 1]], axis=0)
    return chosen


def _acquire(models: Tuple[GP, GP], cand_x: np.ndarray,
             evaluated: np.ndarray, ref: np.ndarray) -> int:
    return _acquire_batch(models, cand_x, evaluated, ref, q=1)[0]


def obj_space(ys: List[Tuple[float, float]]) -> np.ndarray:
    """(log throughput, -log power) — the space GPs and HV operate in."""
    t = np.log1p(np.maximum(np.array([y[0] for y in ys]), 0.0))
    p = -np.log(np.maximum(np.array([y[1] for y in ys]), 1.0))
    return np.stack([t, p], 1)


def hv_ref(peak_power: float) -> np.ndarray:
    """Hypervolume reference point (throughput 0, peak power)."""
    return np.array([0.0, -np.log(max(peak_power, 1.0))])


# legacy underscore aliases (pre-existing tests import these)
_obj_space = obj_space
_hv_ref = hv_ref


def run_mfmobo(f0: EvalFn, f1: EvalFn, *, d0: int = 3, d1: int = 3,
               k: int = 5, N0: int = 20, N1: int = 30,
               peak_power: float = 15000.0, n_candidates: int = 256,
               q: int = 1, seed: int = 0,
               on_handover: Optional[Callable[
                   [List[WSCDesign], List[Tuple[float, float]]], None]] = None
               ) -> Trace:
    """Paper Algorithm 1 (+ q-batching, DESIGN.md §5). `on_handover`, if
    given, fires once immediately before the FIRST f0 evaluation (the d0
    prior batch), with every f1-evaluated design and its objectives — the
    hook the online GNN calibration loop (calibration.py) uses to fine-tune
    f0 on simulator traces from the current Pareto neighborhood, so every
    recorded f0 objective (priors included — they seed the trace, the front
    and M0's training set permanently) comes from calibrated params."""
    rng = np.random.default_rng(seed)
    ref = _hv_ref(peak_power)
    tr = Trace([], [], [], [], [])

    X0, Y0, X1, Y1 = [], [], [], []
    hist_d: List[WSCDesign] = []          # every evaluated design (f1 + f0)
    hist_y: List[Tuple[float, float]] = []
    handover_fired = False

    def record(x, d, y):
        tr.xs.append(x)
        tr.designs.append(d)
        tr.ys.append(y)
        pts = _obj_space(tr.ys)
        tr.hv.append(hypervolume_2d(pts, ref))
        tr.wall_s.append(time.time())

    # priors: the f1 warm-up batch and the f0 batch each evaluate together
    init_x, init_d = _valid_candidates(rng, d0 + d1)
    ys1 = _eval_many(f1, init_d[:d1])
    tr.n_evals += len(ys1)
    for x, d, y in zip(init_x[:d1], init_d[:d1], ys1):
        X1.append(x); Y1.append(y)
        hist_d.append(d); hist_y.append(y)
    if d0 > 0 and on_handover is not None:
        handover_fired = True
        on_handover(list(hist_d), list(hist_y))
    ys0 = _eval_many(f0, init_d[d1:d1 + d0])
    tr.n_evals += len(ys0)
    for x, d, y in zip(init_x[d1:d1 + d0], init_d[d1:d1 + d0], ys0):
        X0.append(x); Y0.append(y)
        hist_d.append(d); hist_y.append(y)
        record(x, d, y)

    total = N0 + N1 - d0 - d1
    done = 0
    while done < total:
        use_f0 = done >= N1 - d1
        use_m0 = done >= N1 - d1 + k
        if use_f0 and not handover_fired:
            handover_fired = True
            if on_handover is not None:
                on_handover(list(hist_d), list(hist_y))
        # batch size: q, clipped to the remaining budget and to the next
        # fidelity-schedule boundary so every evaluation in the batch runs
        # at the fidelity the schedule assigns it
        boundaries = [b for b in (N1 - d1, N1 - d1 + k, total) if b > done]
        q_eff = max(1, min(q, min(boundaries) - done))

        cand_x, cand_d = _valid_candidates(rng, n_candidates)
        if use_m0 and len(X0) >= 2:
            models = _fit_models(np.array(X0), np.array(Y0))
            ev = _obj_space(Y0)
        else:
            models = _fit_models(np.array(X1), np.array(Y1))
            ev = _obj_space(Y1) if not use_f0 or not Y0 else _obj_space(Y0)
        js = _acquire_batch(models, cand_x, ev, ref, q=q_eff)
        batch_d = [cand_d[j] for j in js]
        ys = _eval_many(f0 if use_f0 else f1, batch_d)
        tr.n_evals += len(ys)
        for j, y in zip(js, ys):
            hist_d.append(cand_d[j]); hist_y.append(y)
            if use_f0:
                X0.append(cand_x[j]); Y0.append(y)
                record(cand_x[j], cand_d[j], y)
            else:
                X1.append(cand_x[j]); Y1.append(y)
        done += len(js)
    return tr


def run_mobo(f0: EvalFn, *, d0: int = 6, N: int = 20,
             peak_power: float = 15000.0, n_candidates: int = 256,
             q: int = 1, seed: int = 0) -> Trace:
    """Single-fidelity MOBO baseline (paper Fig. 8)."""
    rng = np.random.default_rng(seed)
    ref = _hv_ref(peak_power)
    tr = Trace([], [], [], [], [])
    X, Y = [], []

    def record(x, d, y):
        X.append(x); Y.append(y)
        tr.xs.append(x); tr.designs.append(d); tr.ys.append(y)
        tr.hv.append(hypervolume_2d(_obj_space(tr.ys), ref))
        tr.wall_s.append(time.time())
        tr.n_evals += 1

    init_x, init_d = _valid_candidates(rng, d0)
    for x, d, y in zip(init_x, init_d, _eval_many(f0, init_d)):
        record(x, d, y)
    done = 0
    while done < N - d0:
        q_eff = max(1, min(q, N - d0 - done))
        models = _fit_models(np.array(X), np.array(Y))
        cand_x, cand_d = _valid_candidates(rng, n_candidates)
        js = _acquire_batch(models, cand_x, _obj_space(Y), ref, q=q_eff)
        for j, y in zip(js, _eval_many(f0, [cand_d[j] for j in js])):
            record(cand_x[j], cand_d[j], y)
        done += len(js)
    return tr


def run_random(f0: EvalFn, *, N: int = 20, peak_power: float = 15000.0,
               seed: int = 0) -> Trace:
    rng = np.random.default_rng(seed)
    ref = _hv_ref(peak_power)
    tr = Trace([], [], [], [], [])
    xs, ds = _valid_candidates(rng, N)
    for x, d, y in zip(xs, ds, _eval_many(f0, ds)):
        tr.xs.append(x); tr.designs.append(d); tr.ys.append(y)
        tr.hv.append(hypervolume_2d(_obj_space(tr.ys), ref))
        tr.wall_s.append(time.time())
        tr.n_evals += 1
    return tr
