"""Multi-Fidelity Multi-Objective Bayesian Optimization — paper Algorithm 1.

Two evaluation fidelities (f1 = analytical, f0 = GNN-based — paper §VII
notes CA simulation is kept out of the loop for cost), GP surrogates per
(fidelity x objective), EHVI acquisition with hypervolume reference
(throughput 0, peak power). The schedule:

    evaluations [0, N1-d1):           evaluate f1, acquire with M1
    evaluations [N1-d1, N1-d1+k):     evaluate f0, acquire with M1 (handover)
    evaluations [N1-d1+k, ...):       evaluate f0, acquire with M0

Each iteration proposes a batch of q candidates by greedy q-EHVI with
fantasized observations (DESIGN.md §5): pick the EHVI argmax, condition the
GPs on its posterior mean (GP.condition_on), extend the fantasy front, and
repeat — then evaluate the whole batch in one call. Objectives follow the
`repro.explore.objectives.Objective` protocol (`eval_many(designs)`);
legacy callables — scalar (design -> (throughput, power)) functions or
batch-aware functions marked `.batched = True` — are coerced at entry by
`as_objective`. With q=1 the loop is the paper's serial Algorithm 1.

This module keeps the algorithmic primitives (Trace, GP fitting in the
log-objective space, greedy q-EHVI acquisition, valid-candidate sampling);
the loop itself lives in `repro.explore.runner.ExplorationLoop` — a
resumable state machine that campaigns (repro.explore.campaign) checkpoint
and resume. `run_mfmobo` / `run_mobo` / `run_random` are thin wrappers
over that loop with their historical signatures and rng-consumption order
(traces are bit-identical to the pre-campaign implementations).

Baselines for Fig. 8: random search and single-fidelity MOBO.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.design_space import WSCDesign, decode_batch, sample
from repro.core.ehvi import ehvi_padded
from repro.core.gp import GP, _predict_jit, _rank1_jit, bucket_size
from repro.core.pareto import pareto_front, to_max_space
from repro.core.validator import validate_batch

EvalFn = Callable[[WSCDesign], Tuple[float, float]]   # -> (throughput, power)


@dataclasses.dataclass
class Trace:
    xs: List[np.ndarray]
    designs: List[WSCDesign]
    ys: List[Tuple[float, float]]         # (throughput, power)
    hv: List[float]                       # hypervolume after each evaluation
    wall_s: List[float]
    n_evals: int = 0                      # total evals incl. f1-only points
    # per-fidelity-stage eval-cache traffic ({"f0"/"f1": {hits, misses,
    # entries_added}}), recorded by the exploration loop so the cost of the
    # fidelity handover is visible in campaign artifacts / BENCH_dse.json
    stage_cache: Dict[str, Dict[str, int]] = dataclasses.field(
        default_factory=dict)

    def points_max(self) -> np.ndarray:
        t = np.array([y[0] for y in self.ys])
        p = np.array([y[1] for y in self.ys])
        return to_max_space(t, p)

    def pareto(self) -> np.ndarray:
        return pareto_front(self.points_max())

    def cache_hit_rates(self) -> Dict[str, float]:
        out = {}
        for stage, sc in self.stage_cache.items():
            n = sc.get("hits", 0) + sc.get("misses", 0)
            out[stage] = sc.get("hits", 0) / n if n else 0.0
        return out


def _eval_many(f: EvalFn, designs: Sequence[WSCDesign]
               ) -> List[Tuple[float, float]]:
    """Legacy shim: objective coercion (including the old `.batched`
    attribute sniff) now lives in `repro.explore.objectives.as_objective`;
    the exploration loop calls `Objective.eval_many` directly."""
    from repro.explore.objectives import as_objective
    return as_objective(f).eval_many(list(designs))


def _valid_candidates(rng: np.random.Generator, n: int,
                      max_tries: int = 8) -> Tuple[np.ndarray, List[WSCDesign]]:
    """Sample until n validator-approved candidates are collected, topping
    up with fresh batches for up to `max_tries` rounds. Each round decodes
    and validates the whole draw at once (`validate_batch`); the rng stream,
    accepted set, and ordering are identical to the retired per-design
    loop. A design space whose acceptance rate is too low to fill the
    request raises — with the observed rate — instead of silently handing
    the acquisition a short (or empty) candidate set."""
    xs, ds = [], []
    n_drawn = 0
    for _ in range(max_tries):
        us = sample(rng, n)
        n_drawn += len(us)
        for u, r in zip(us, validate_batch(decode_batch(us))):
            if r.ok:
                xs.append(u)
                ds.append(r.design)
            if len(xs) >= n:
                return np.array(xs), ds
    rate = len(xs) / max(n_drawn, 1)
    raise RuntimeError(
        f"design-space sampling produced only {len(xs)}/{n} valid "
        f"candidates after {max_tries} rounds of {n} draws (acceptance "
        f"rate {rate:.1%}) — the validator is rejecting (nearly) "
        "everything; loosen the design-space bounds or raise max_tries")


def _grid_seed_strategies(designs, wl, space):
    """Heuristic strategy seeds for joint sampling: each design's
    first-feasible row of the sorted strategy grid (what grid-mode
    evaluation would try first), as (N, 7) encoded strategy columns plus a
    found-mask. Vectorized over the cached `_strategy_grid`, at the same
    area-matched system size the validator gates on (`wafers_for_budget`
    per design). Each seed is then re-checked under the v2 memory model
    (`strategy_memory_need`); a training seed that only fits with
    activation recompute carries recompute=True into the search — the
    validator would reject the plain row with "strategy_memory", so the
    fallback keeps the seed alive and hands q-EHVI a live recompute
    signal."""
    from repro.core.compiler import (Strategy, _strategy_grid,
                                     strategy_memory_need)
    from repro.core.design_space import DesignBatch
    from repro.core.evaluator import wafers_for_budget

    g = _strategy_grid(wl)
    db = DesignBatch.from_designs(list(designs))
    nw = np.array([wafers_for_budget(d, wl) for d in designs], np.float64)
    tc = db.total_cores.astype(np.float64) * nw
    mem = (db.buffer_kb * 1024.0 * db.total_cores
           + db.dram_gb_per_reticle * 1e9 * db.n_reticles) * nw
    o = g["order"]
    m = ((g["chunks"][None, o] * g["tp"][None, o] <= tc[:, None])
         & (g["tp"][None, o] <= tc[:, None])
         & (g["need"][None, o] <= mem[:, None]))
    found = m.any(axis=1)
    idx = o[np.argmax(m, axis=1)]
    need_plain = strategy_memory_need(wl, g["tp"][idx], g["pp"][idx],
                                      g["dp"][idx], g["mb"][idx])
    need_rc = strategy_memory_need(wl, g["tp"][idx], g["pp"][idx],
                                   g["dp"][idx], g["mb"][idx],
                                   recompute=True)
    rc = ((wl.phase == "train") & (need_plain > mem) & (need_rc <= mem))
    enc = np.zeros((len(designs), space.n_dims))
    for i in np.flatnonzero(found):
        s = Strategy(int(g["tp"][idx[i]]), int(g["pp"][idx[i]]),
                     int(g["dp"][idx[i]]), int(g["mb"][idx[i]]),
                     recompute=bool(rc[i]))
        enc[i] = space.encode_strategy(s)
    return enc, found


def _valid_candidates_joint(rng: np.random.Generator, n: int, space, wl,
                            max_tries: int = 8
                            ) -> Tuple[np.ndarray, List]:
    """Joint-mode `_valid_candidates`: sample (13 + 7)-dim joint points,
    seed every other draw's strategy columns from the grid heuristic
    (`enumerate_strategies` demoted to seeding — the sorted grid's first
    feasible row), validate architecture + strategy together
    (`validate_joint_batch`, `repro.dist` oracle included), and return
    (encoded points, JointDesigns with spares resolved)."""
    from repro.core.design_space import (DIMS, JointDesign,
                                         decode_joint_batch, sample_joint)
    from repro.core.validator import validate_joint_batch

    nd = len(DIMS)
    xs, pts = [], []
    n_drawn = 0
    for _ in range(max_tries):
        us = sample_joint(rng, n, space)
        n_drawn += len(us)
        batch = decode_joint_batch(us, space)
        seeded = list(range(0, len(batch), 2))
        enc, found = _grid_seed_strategies(
            [batch[i].design for i in seeded], wl, space)
        for j, i in enumerate(seeded):
            if found[j]:
                us[i, nd:] = enc[j]
                batch[i] = JointDesign(
                    batch[i].design, space.decode_strategy(us[i, nd:]))
        for u, p, r in zip(us, batch, validate_joint_batch(batch, wl)):
            if r.ok:
                xs.append(u)
                pts.append(JointDesign(r.design, p.strategy))
            if len(xs) >= n:
                return np.array(xs), pts
    rate = len(xs) / max(n_drawn, 1)
    raise RuntimeError(
        f"joint-space sampling produced only {len(xs)}/{n} valid "
        f"candidates after {max_tries} rounds of {n} draws (acceptance "
        f"rate {rate:.1%}) — loosen the strategy-space bounds or raise "
        "max_tries")


def _fit_models(X: np.ndarray, Y: np.ndarray) -> Tuple[GP, GP]:
    # one vmapped XLA call refits both objective surrogates on the shared X
    return GP.fit_pair(X, (np.log1p(np.maximum(Y[:, 0], 0.0)),
                           -np.log(np.maximum(Y[:, 1], 1.0))))


@partial(jax.jit, static_argnames=("q",))
def _acquire_scan_jit(X, mask, n0, yt, Lt, at, ls_t, sf_t, noise_t, mt, st,
                      yp, Lp, ap, ls_p, sf_p, noise_p, mp, sp,
                      cand, fant, fant_mask, nf0, ref, q):
    """The whole greedy q-EHVI loop as one XLA program: lax.scan over the q
    picks, each step = batched posterior predict for both objectives +
    padded EHVI over the fantasy front + argmax + rank-1 fantasization of
    both GPs in the shared padded buffer."""

    def step(carry, _):
        (X, mask, n, yt, Lt, at, yp, Lp, ap, fant, fmask, nf, chosen) = carry
        mu_t, sd_t = _predict_jit(cand, X, mask, Lt, at, ls_t, sf_t, mt, st)
        mu_p, sd_p = _predict_jit(cand, X, mask, Lp, ap, ls_p, sf_p, mp, sp)
        mu = jnp.stack([mu_t, mu_p], 1)
        sg = jnp.stack([sd_t, sd_p], 1)
        scores = ehvi_padded(mu, sg, fant, fmask, ref)
        scores = jnp.where(chosen, -jnp.inf, scores)
        j = jnp.argmax(scores)
        chosen = chosen.at[j].set(True)
        # fantasize the observation at the posterior mean and condition
        X2, yt2, mask2, Lt2, at2 = _rank1_jit(
            X, yt, mask, Lt, ls_t, sf_t, noise_t, n, cand[j],
            (mu_t[j] - mt) / st)
        _, yp2, _, Lp2, ap2 = _rank1_jit(
            X, yp, mask, Lp, ls_p, sf_p, noise_p, n, cand[j],
            (mu_p[j] - mp) / sp)
        fant = fant.at[nf].set(mu[j])
        fmask = fmask.at[nf].set(1.0)
        return (X2, mask2, n + 1, yt2, Lt2, at2, yp2, Lp2, ap2,
                fant, fmask, nf + 1, chosen), j

    chosen0 = jnp.zeros(cand.shape[0], bool)
    carry0 = (X, mask, n0, yt, Lt, at, yp, Lp, ap, fant, fant_mask, nf0,
              chosen0)
    _, js = jax.lax.scan(step, carry0, None, length=q)
    return js


def _acquire_batch_device(models: Tuple[GP, GP], cand_x: np.ndarray,
                          evaluated: np.ndarray, ref: np.ndarray,
                          q: int = 1):
    """`_acquire_batch` without the host sync: returns the padded device
    index vector straight from `_acquire_scan_jit` (the first q entries
    are the picks). The fused analytical evaluator
    (`repro.core.eval_compiled.dispatch_fused_eval`) consumes it on
    device, so a synchronous f1 iteration never waits on the proposal
    before dispatching the evaluation."""
    g_t, g_p = models
    if g_t.n != g_p.n:
        raise ValueError("objective GPs must share the training set")
    q = max(1, min(q, len(cand_x)))
    # the scan length is bucketed too: greedy picks are a prefix-stable
    # sequence, so running a padded qpad-step scan and keeping the first q
    # indices returns exactly the q-step result while q_eff taking every
    # value in 1..q (budget/boundary clamping) reuses ONE compiled program
    qpad = bucket_size(q, minimum=4)
    B = bucket_size(g_t.n + qpad)       # room for qpad rank-1 appends
    g_t = g_t.with_capacity(B)
    g_p = g_p.with_capacity(B)
    dt = np.float32
    fantasy = np.asarray(evaluated, float).reshape(-1, 2)
    Bf = bucket_size(len(fantasy) + qpad, minimum=4)
    fant = np.zeros((Bf, 2), dt)
    fant[:len(fantasy)] = fantasy
    fmask = np.zeros(Bf, dt)
    fmask[:len(fantasy)] = 1.0
    p_t, p_p = g_t.params, g_p.params
    js = _acquire_scan_jit(
        g_t.X, g_t.mask, jnp.asarray(g_t.n),
        g_t.y, g_t.chol, g_t.alpha, jnp.asarray(p_t["log_ls"]),
        jnp.asarray(p_t["log_sf"]), jnp.asarray(p_t["log_noise"]),
        jnp.asarray(g_t.mean, dt), jnp.asarray(g_t.std, dt),
        g_p.y, g_p.chol, g_p.alpha, jnp.asarray(p_p["log_ls"]),
        jnp.asarray(p_p["log_sf"]), jnp.asarray(p_p["log_noise"]),
        jnp.asarray(g_p.mean, dt), jnp.asarray(g_p.std, dt),
        jnp.asarray(np.asarray(cand_x, dt)), jnp.asarray(fant),
        jnp.asarray(fmask), jnp.asarray(len(fantasy)),
        jnp.asarray(np.asarray(ref, dt)), qpad)
    return js


def _acquire_batch(models: Tuple[GP, GP], cand_x: np.ndarray,
                   evaluated: np.ndarray, ref: np.ndarray,
                   q: int = 1) -> List[int]:
    """Greedy q-EHVI with fantasized observations. Returns q distinct
    candidate indices; q=1 reduces exactly to the scalar EHVI argmax.
    The NumPy reference loop lives in `repro.core.gp_ref.acquire_batch_ref`
    (property-tested equivalent)."""
    q = max(1, min(q, len(cand_x)))
    js = _acquire_batch_device(models, cand_x, evaluated, ref, q=q)
    return [int(j) for j in np.asarray(js)[:q]]


def _acquire(models: Tuple[GP, GP], cand_x: np.ndarray,
             evaluated: np.ndarray, ref: np.ndarray) -> int:
    return _acquire_batch(models, cand_x, evaluated, ref, q=1)[0]


# shape buckets already pre-compiled in THIS process — campaign fleets run
# many campaigns per worker, so repeated `warm_optimizer_kernels` calls
# (fig8 used to pay one per campaign grid) skip buckets whose programs XLA
# already holds. Keyed by everything the compiled shapes depend on.
_WARMED_BUCKETS: set = set()


def warm_optimizer_kernels(n_obs_max: int, n_candidates: int = 256,
                           q: int = 1, dim: Optional[int] = None,
                           force: bool = False,
                           workload=None, n_designs_max: int = 0,
                           max_strategies: int = 24) -> int:
    """Pre-compile the jitted optimizer programs for every capacity bucket
    a campaign of up to `n_obs_max` observations touches (GP pair fit +
    scanned q-EHVI acquire, one compile per pow2 bucket). Compilation is a
    one-time ~1s/bucket cost; calling this before a timed region keeps it
    out of measured proposal walls. Warm-ups are memoized per process:
    buckets already compiled this process are skipped (`force=True`
    re-runs them), so per-campaign calls in a grid or a fleet worker cost
    nothing after the first. Returns the number of buckets *newly* warmed.
    Fantasy-front buffers track the training buffer in campaign use
    (evaluated count == observation count), so warming the training buckets
    covers the acquire shapes too.

    With `workload` set, the compiled analytical evaluator programs warm
    alongside the optimizer ones (`eval_compiled.warm_evaluator_kernels`,
    same per-(bucket, workload-shape) memoization and `force=` semantics):
    the design-axis buckets up to `n_designs_max` (defaults to the q
    bucket) plus the fused gather program for the `n_candidates` pool."""
    from repro.core.design_space import DIMS
    d = len(DIMS) if dim is None else dim
    rng = np.random.default_rng(0)
    qpad = bucket_size(max(1, min(q, n_candidates)), minimum=4)
    warmed = 0
    seen = set()
    for n in range(2, max(int(n_obs_max), 2) + 1):
        B = bucket_size(n + qpad)
        key = (B, n_candidates, qpad, d)
        if B in seen or (key in _WARMED_BUCKETS and not force):
            continue
        seen.add(B)
        _WARMED_BUCKETS.add(key)
        warmed += 1
        nn = max(2, B - qpad)           # largest n landing in this bucket
        X = rng.random((nn, d))
        Y = np.stack([1e3 * (1.0 + X[:, 0]), 1e3 * (2.0 - X[:, 1])], 1)
        models = _fit_models(X, Y)
        ev = obj_space([tuple(y) for y in Y])
        cand = rng.random((n_candidates, d))
        _acquire_batch(models, cand, ev, hv_ref(1e4), q=q)
    if workload is not None:
        from repro.core import eval_compiled
        warmed += eval_compiled.warm_evaluator_kernels(
            workload, n_designs_max=max(int(n_designs_max), qpad),
            max_strategies=max_strategies, pool_sizes=(n_candidates,),
            force=force)
    return warmed


def obj_space(ys: List[Tuple[float, float]]) -> np.ndarray:
    """(log throughput, -log power) — the space GPs and HV operate in."""
    t = np.log1p(np.maximum(np.array([y[0] for y in ys]), 0.0))
    p = -np.log(np.maximum(np.array([y[1] for y in ys]), 1.0))
    return np.stack([t, p], 1)


def hv_ref(peak_power: float) -> np.ndarray:
    """Hypervolume reference point (throughput 0, peak power)."""
    return np.array([0.0, -np.log(max(peak_power, 1.0))])


# legacy underscore aliases (pre-existing tests import these)
_obj_space = obj_space
_hv_ref = hv_ref


def run_mfmobo(f0: EvalFn, f1: EvalFn, *, d0: int = 3, d1: int = 3,
               k: int = 5, N0: int = 20, N1: int = 30,
               peak_power: float = 15000.0, n_candidates: int = 256,
               q: int = 1, seed: int = 0,
               on_handover: Optional[Callable[
                   [List[WSCDesign], List[Tuple[float, float]]], None]] = None
               ) -> Trace:
    """Paper Algorithm 1 (+ q-batching, DESIGN.md §5). `on_handover`, if
    given, fires once immediately before the FIRST f0 evaluation (the d0
    prior batch), with every f1-evaluated design and its objectives — the
    hook the online GNN calibration loop (calibration.py) uses to fine-tune
    f0 on simulator traces from the current Pareto neighborhood, so every
    recorded f0 objective (priors included — they seed the trace, the front
    and M0's training set permanently) comes from calibrated params.

    Thin wrapper over `repro.explore.runner.ExplorationLoop` (DESIGN.md
    §9); use a `repro.explore.Campaign` instead when the run should be
    serializable / checkpointable / resumable."""
    from repro.explore.runner import ExplorationLoop, LoopConfig
    cfg = LoopConfig(strategy="mfmobo", N0=N0, N1=N1, d0=d0, d1=d1, k=k,
                     q=q, n_candidates=n_candidates, peak_power=peak_power,
                     seed=seed)
    return ExplorationLoop(cfg, f0, f1=f1, on_handover=on_handover).run()


def run_mobo(f0: EvalFn, *, d0: int = 6, N: int = 20,
             peak_power: float = 15000.0, n_candidates: int = 256,
             q: int = 1, seed: int = 0) -> Trace:
    """Single-fidelity MOBO baseline (paper Fig. 8)."""
    from repro.explore.runner import ExplorationLoop, LoopConfig
    cfg = LoopConfig(strategy="mobo", N0=N, d0=d0, q=q,
                     n_candidates=n_candidates, peak_power=peak_power,
                     seed=seed)
    return ExplorationLoop(cfg, f0).run()


def run_random(f0: EvalFn, *, N: int = 20, peak_power: float = 15000.0,
               seed: int = 0) -> Trace:
    from repro.explore.runner import ExplorationLoop, LoopConfig
    # q=N: evaluate the whole sampled pool in one batch call, exactly like
    # the pre-campaign implementation (campaigns chunk by q instead, for
    # checkpoint granularity)
    cfg = LoopConfig(strategy="random", N0=N, q=N, peak_power=peak_power,
                     seed=seed)
    return ExplorationLoop(cfg, f0).run()
