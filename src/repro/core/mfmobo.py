"""Multi-Fidelity Multi-Objective Bayesian Optimization — paper Algorithm 1.

Two evaluation fidelities (f1 = analytical, f0 = GNN-based — paper §VII
notes CA simulation is kept out of the loop for cost), GP surrogates per
(fidelity x objective), EHVI acquisition with hypervolume reference
(throughput 0, peak power). The schedule:

    evaluations [0, N1-d1):           evaluate f1, acquire with M1
    evaluations [N1-d1, N1-d1+k):     evaluate f0, acquire with M1 (handover)
    evaluations [N1-d1+k, ...):       evaluate f0, acquire with M0

Each iteration proposes a batch of q candidates by greedy q-EHVI with
fantasized observations (DESIGN.md §5): pick the EHVI argmax, condition the
GPs on its posterior mean (GP.condition_on), extend the fantasy front, and
repeat — then evaluate the whole batch in one call. Objectives follow the
`repro.explore.objectives.Objective` protocol (`eval_many(designs)`);
legacy callables — scalar (design -> (throughput, power)) functions or
batch-aware functions marked `.batched = True` — are coerced at entry by
`as_objective`. With q=1 the loop is the paper's serial Algorithm 1.

This module keeps the algorithmic primitives (Trace, GP fitting in the
log-objective space, greedy q-EHVI acquisition, valid-candidate sampling);
the loop itself lives in `repro.explore.runner.ExplorationLoop` — a
resumable state machine that campaigns (repro.explore.campaign) checkpoint
and resume. `run_mfmobo` / `run_mobo` / `run_random` are thin wrappers
over that loop with their historical signatures and rng-consumption order
(traces are bit-identical to the pre-campaign implementations).

Baselines for Fig. 8: random search and single-fidelity MOBO.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.design_space import WSCDesign, decode_batch, sample
from repro.core.ehvi import ehvi_2d
from repro.core.gp import GP
from repro.core.pareto import pareto_front, to_max_space
from repro.core.validator import validate

EvalFn = Callable[[WSCDesign], Tuple[float, float]]   # -> (throughput, power)


@dataclasses.dataclass
class Trace:
    xs: List[np.ndarray]
    designs: List[WSCDesign]
    ys: List[Tuple[float, float]]         # (throughput, power)
    hv: List[float]                       # hypervolume after each evaluation
    wall_s: List[float]
    n_evals: int = 0                      # total evals incl. f1-only points
    # per-fidelity-stage eval-cache traffic ({"f0"/"f1": {hits, misses,
    # entries_added}}), recorded by the exploration loop so the cost of the
    # fidelity handover is visible in campaign artifacts / BENCH_dse.json
    stage_cache: Dict[str, Dict[str, int]] = dataclasses.field(
        default_factory=dict)

    def points_max(self) -> np.ndarray:
        t = np.array([y[0] for y in self.ys])
        p = np.array([y[1] for y in self.ys])
        return to_max_space(t, p)

    def pareto(self) -> np.ndarray:
        return pareto_front(self.points_max())

    def cache_hit_rates(self) -> Dict[str, float]:
        out = {}
        for stage, sc in self.stage_cache.items():
            n = sc.get("hits", 0) + sc.get("misses", 0)
            out[stage] = sc.get("hits", 0) / n if n else 0.0
        return out


def _eval_many(f: EvalFn, designs: Sequence[WSCDesign]
               ) -> List[Tuple[float, float]]:
    """Legacy shim: objective coercion (including the old `.batched`
    attribute sniff) now lives in `repro.explore.objectives.as_objective`;
    the exploration loop calls `Objective.eval_many` directly."""
    from repro.explore.objectives import as_objective
    return as_objective(f).eval_many(list(designs))


def _valid_candidates(rng: np.random.Generator, n: int,
                      max_tries: int = 8) -> Tuple[np.ndarray, List[WSCDesign]]:
    """Sample until n validator-approved candidates are collected, topping
    up with fresh batches for up to `max_tries` rounds. A design space whose
    acceptance rate is too low to fill the request raises instead of
    silently handing the acquisition a short (or empty) candidate set."""
    xs, ds = [], []
    for _ in range(max_tries):
        us = sample(rng, n)
        for u, d in zip(us, decode_batch(us)):
            r = validate(d)
            if r.ok:
                xs.append(u)
                ds.append(r.design)
            if len(xs) >= n:
                return np.array(xs), ds
    raise RuntimeError(
        f"design-space sampling produced only {len(xs)}/{n} valid "
        f"candidates after {max_tries} rounds of {n} draws — the validator "
        "is rejecting (nearly) everything; loosen the design-space bounds "
        "or raise max_tries")


def _fit_models(X: np.ndarray, Y: np.ndarray) -> Tuple[GP, GP]:
    g_t = GP.fit(X, np.log1p(np.maximum(Y[:, 0], 0.0)))
    g_p = GP.fit(X, -np.log(np.maximum(Y[:, 1], 1.0)))
    return g_t, g_p


def _acquire_batch(models: Tuple[GP, GP], cand_x: np.ndarray,
                   evaluated: np.ndarray, ref: np.ndarray,
                   q: int = 1) -> List[int]:
    """Greedy q-EHVI with fantasized observations. Returns q distinct
    candidate indices; q=1 reduces exactly to the scalar EHVI argmax."""
    g_t, g_p = models
    fantasy_pts = np.asarray(evaluated, float).reshape(-1, 2)
    chosen: List[int] = []
    q = max(1, min(q, len(cand_x)))
    while len(chosen) < q:
        mu_t, s_t = g_t.predict(cand_x)
        mu_p, s_p = g_p.predict(cand_x)
        mu = np.stack([mu_t, mu_p], 1)
        sg = np.stack([s_t, s_p], 1)
        front = (pareto_front(fantasy_pts) if len(fantasy_pts)
                 else np.zeros((0, 2)))
        scores = ehvi_2d(mu, sg, front, ref)
        if chosen:
            scores[np.asarray(chosen)] = -np.inf
        j = int(np.argmax(scores))
        chosen.append(j)
        if len(chosen) == q:
            break
        # fantasize the observation at the posterior mean and condition
        g_t = g_t.condition_on(cand_x[j], float(mu_t[j]))
        g_p = g_p.condition_on(cand_x[j], float(mu_p[j]))
        fantasy_pts = np.concatenate([fantasy_pts, mu[j:j + 1]], axis=0)
    return chosen


def _acquire(models: Tuple[GP, GP], cand_x: np.ndarray,
             evaluated: np.ndarray, ref: np.ndarray) -> int:
    return _acquire_batch(models, cand_x, evaluated, ref, q=1)[0]


def obj_space(ys: List[Tuple[float, float]]) -> np.ndarray:
    """(log throughput, -log power) — the space GPs and HV operate in."""
    t = np.log1p(np.maximum(np.array([y[0] for y in ys]), 0.0))
    p = -np.log(np.maximum(np.array([y[1] for y in ys]), 1.0))
    return np.stack([t, p], 1)


def hv_ref(peak_power: float) -> np.ndarray:
    """Hypervolume reference point (throughput 0, peak power)."""
    return np.array([0.0, -np.log(max(peak_power, 1.0))])


# legacy underscore aliases (pre-existing tests import these)
_obj_space = obj_space
_hv_ref = hv_ref


def run_mfmobo(f0: EvalFn, f1: EvalFn, *, d0: int = 3, d1: int = 3,
               k: int = 5, N0: int = 20, N1: int = 30,
               peak_power: float = 15000.0, n_candidates: int = 256,
               q: int = 1, seed: int = 0,
               on_handover: Optional[Callable[
                   [List[WSCDesign], List[Tuple[float, float]]], None]] = None
               ) -> Trace:
    """Paper Algorithm 1 (+ q-batching, DESIGN.md §5). `on_handover`, if
    given, fires once immediately before the FIRST f0 evaluation (the d0
    prior batch), with every f1-evaluated design and its objectives — the
    hook the online GNN calibration loop (calibration.py) uses to fine-tune
    f0 on simulator traces from the current Pareto neighborhood, so every
    recorded f0 objective (priors included — they seed the trace, the front
    and M0's training set permanently) comes from calibrated params.

    Thin wrapper over `repro.explore.runner.ExplorationLoop` (DESIGN.md
    §9); use a `repro.explore.Campaign` instead when the run should be
    serializable / checkpointable / resumable."""
    from repro.explore.runner import ExplorationLoop, LoopConfig
    cfg = LoopConfig(strategy="mfmobo", N0=N0, N1=N1, d0=d0, d1=d1, k=k,
                     q=q, n_candidates=n_candidates, peak_power=peak_power,
                     seed=seed)
    return ExplorationLoop(cfg, f0, f1=f1, on_handover=on_handover).run()


def run_mobo(f0: EvalFn, *, d0: int = 6, N: int = 20,
             peak_power: float = 15000.0, n_candidates: int = 256,
             q: int = 1, seed: int = 0) -> Trace:
    """Single-fidelity MOBO baseline (paper Fig. 8)."""
    from repro.explore.runner import ExplorationLoop, LoopConfig
    cfg = LoopConfig(strategy="mobo", N0=N, d0=d0, q=q,
                     n_candidates=n_candidates, peak_power=peak_power,
                     seed=seed)
    return ExplorationLoop(cfg, f0).run()


def run_random(f0: EvalFn, *, N: int = 20, peak_power: float = 15000.0,
               seed: int = 0) -> Trace:
    from repro.explore.runner import ExplorationLoop, LoopConfig
    # q=N: evaluate the whole sampled pool in one batch call, exactly like
    # the pre-campaign implementation (campaigns chunk by q instead, for
    # checkpoint granularity)
    cfg = LoopConfig(strategy="random", N0=N, q=N, peak_power=peak_power,
                     seed=seed)
    return ExplorationLoop(cfg, f0).run()
