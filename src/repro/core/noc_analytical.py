"""Op-level analytical NoC model (paper §VI-C, low fidelity / f1).

Per-link volumes from the Workload Compiler -> equivalent bandwidth per link
(noc_bw / #flows sharing it) -> per-edge communication delay -> chunk latency
as the longest path over the (chain-structured) logic core graph in
topological order. DRAM access + inter-chunk sync belong to chunk_eval.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core.compiler import ChunkGraph, _xy_route
from repro.core.design_space import WSCDesign


def transfer_delays(graph: ChunkGraph, design: WSCDesign) -> List[float]:
    """Per-transfer communication delay in cycles (equivalent-bandwidth)."""
    flows = graph.link_flows
    bw_bytes = design.noc_bw / 8.0          # bytes per cycle per link
    W = graph.array[1]
    delays = []
    for t in graph.transfers:
        worst = 0.0
        for s, d, b in t.pairs:
            eq_bw = bw_bytes
            hops = graph.routes.get((s, d)) or _xy_route(s, d, W)
            for hop in hops:
                f = max(flows[graph.link_index[hop]], 1.0)
                eq_bw = min(eq_bw, bw_bytes / f)
            pair_cycles = b / max(eq_bw, 1e-9) + len(hops)
            worst = max(worst, pair_cycles)
        delays.append(worst)
    return delays


def chunk_latency_cycles(graph: ChunkGraph, design: WSCDesign) -> float:
    """Longest path over the chain: node compute + edge comm delays."""
    comm = transfer_delays(graph, design)
    total = 0.0
    for i, node in enumerate(graph.ops):
        total += node.tile.cycles
        if i < len(comm):
            total += comm[i]
    return total
