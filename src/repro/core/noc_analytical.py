"""Op-level analytical NoC model (paper §VI-C, low fidelity / f1).

Per-link volumes from the Workload Compiler -> equivalent bandwidth per link
(noc_bw / #flows sharing it) -> per-edge communication delay -> chunk latency
as the longest path over the (chain-structured) logic core graph in
topological order. DRAM access + inter-chunk sync belong to chunk_eval.

Two entry points (DESIGN.md §4):
  - `chunk_latency_cycles(graph, design)` walks an explicit ChunkGraph —
    the reference path, used by the sim/GNN fidelities and tests;
  - `chunk_latency_cycles_closed(...)` is the batched closed form for the
    row-all-gather graphs `compile_chunk` emits, broadcasting over a leading
    candidate axis without materializing any graph.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core.compiler import ChunkGraph, _xy_route
from repro.core.design_space import WSCDesign


def transfer_delays(graph: ChunkGraph, design: WSCDesign) -> List[float]:
    """Per-transfer communication delay in cycles (equivalent-bandwidth)."""
    flows = graph.link_flows
    bw_bytes = design.noc_bw / 8.0          # bytes per cycle per link
    W = graph.array[1]
    routes = graph.routes or {}
    delays = []
    for t in graph.transfers:
        if not t.pairs:
            delays.append(0.0)
            continue
        # bottleneck flow count + hop count per pair, then one array op
        b = np.empty(len(t.pairs))
        fmax = np.empty(len(t.pairs))
        hops_n = np.empty(len(t.pairs))
        for i, (s, d, bb) in enumerate(t.pairs):
            hops = routes.get((s, d)) or _xy_route(s, d, W)
            f = 1.0
            for hop in hops:
                f = max(f, max(flows[graph.link_index[hop]], 1.0))
            b[i] = bb
            fmax[i] = f
            hops_n[i] = len(hops)
        eq_bw = bw_bytes / fmax
        pair_cycles = b / np.maximum(eq_bw, 1e-9) + hops_n
        delays.append(float(pair_cycles.max()))
    return delays


def chunk_latency_cycles(graph: ChunkGraph, design: WSCDesign) -> float:
    """Longest path over the chain: node compute + edge comm delays."""
    comm = transfer_delays(graph, design)
    total = 0.0
    for i, node in enumerate(graph.ops):
        total += node.tile.cycles
        if i < len(comm):
            total += comm[i]
    return total


def row_allgather_comm_cycles(out_bytes: np.ndarray, gh: np.ndarray,
                              gw: np.ndarray, noc_bw: np.ndarray,
                              n_transfers: int) -> np.ndarray:
    """Closed-form equivalent-bandwidth delay of the row all-gather transfers
    `compile_chunk` generates, summed over the op chain.

    For a (gh, gw) grid every producer tile (out_bytes / n_cores) goes to the
    gw-1 other columns of its row along XY routes, for all n_transfers
    inter-op edges at once, so the most loaded link (the row middle) carries
    n_transfers * floor(gw/2) * ceil(gw/2) flows and the worst pair is the
    full-span one (gw-1 hops through that middle link). Matches
    `transfer_delays` on the corresponding explicit graph bit-for-bit.

    out_bytes: (n_transfers, C) producer output bytes per inter-op edge;
    gh/gw/noc_bw: (C,). Returns (C,) total comm cycles.
    """
    gh = np.asarray(gh, np.int64)
    gw = np.asarray(gw, np.int64)
    bw_bytes = np.asarray(noc_bw, np.float64) / 8.0
    n_cores = gh * gw
    maxflow = np.float64(n_transfers) * (gw // 2) * ((gw + 1) // 2)
    eq_bw = bw_bytes / np.maximum(maxflow, 1.0)
    per_pair = np.asarray(out_bytes, np.float64) / n_cores
    comm = per_pair / np.maximum(eq_bw, 1e-9) + (gw - 1)
    return np.where(gw > 1, comm, 0.0).sum(axis=0)


def row_allgather_byte_hops(out_bytes: np.ndarray, gh: np.ndarray,
                            gw: np.ndarray) -> np.ndarray:
    """Closed-form `link_loads.sum()` of the row all-gather transfers: every
    (src, dst) row pair moves out_bytes/n_cores over |dst-src| hops, and the
    ordered pair distances on a row of gw cores sum to gw (gw^2 - 1) / 3.
    Feeds the NoC term of the energy model; keep in sync with
    `row_allgather_comm_cycles` and compile_chunk's pair generation.

    out_bytes: (n_transfers, C); gh/gw: (C,). Returns (C,) total byte-hops.
    """
    gh = np.asarray(gh, np.int64)
    gw = np.asarray(gw, np.int64)
    per_pair = np.where(gw > 1,
                        np.asarray(out_bytes, np.float64) / (gh * gw), 0.0)
    return (per_pair * (gh * (gw * (gw * gw - 1)) / 3.0)).sum(axis=0)


# NumPy oracle aliases for the jitted pipeline (repro.core.eval_compiled)
row_allgather_comm_cycles_ref = row_allgather_comm_cycles
row_allgather_byte_hops_ref = row_allgather_byte_hops


def chunk_latency_cycles_closed(tile_cycles: np.ndarray, out_bytes: np.ndarray,
                                gh: np.ndarray, gw: np.ndarray,
                                noc_bw: np.ndarray) -> np.ndarray:
    """Batched analytical chunk latency for compile_chunk-shaped chunks.

    tile_cycles: (n_ops, C) per-core tile cycles; out_bytes: (n_ops, C)
    producer output bytes (the last row feeds no transfer). Equals
    `chunk_latency_cycles(compile_chunk(...), design)` per candidate.
    """
    tile_cycles = np.asarray(tile_cycles, np.float64)
    n_ops = tile_cycles.shape[0]
    comm = row_allgather_comm_cycles(out_bytes[:-1], gh, gw, noc_bw,
                                     n_transfers=n_ops - 1)
    return tile_cycles.sum(axis=0) + comm
