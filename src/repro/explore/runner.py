"""Resumable exploration loop — the campaign-grade home of Algorithm 1.

The MFMOBO / MOBO / random-search loops that used to live inline in
`repro.core.mfmobo.run_*` are restructured here as an explicit state
machine: `LoopConfig` (strategy + budgets + schedule, validated up front so
budget-overshooting configurations fail loudly) drives `step()` transitions
over a picklable `LoopState` (the rng generator, the GP training sets, the
trace, the schedule position). The compiled optimizer hot path (jitted GP
refit, scanned q-EHVI acquisition — DESIGN.md §10) is a pure function of
that host-side state: LoopState holds only NumPy arrays / Python scalars,
never device buffers or fitted GPs. Because the surrogates are *refit from
the training set every iteration* (deterministically — fixed init, one
jitted Adam scan), the state is tiny and a checkpoint written at any step
boundary resumes bit-identically: the continuation consumes the identical
rng stream and refits the identical models, so a resumed trace equals the
uninterrupted one at a fixed seed (pinned by tests/test_campaign.py).

`repro.core.mfmobo.run_mfmobo/run_mobo/run_random` are thin wrappers over
this loop (same signatures, same rng-consumption order, hence bit-identical
traces vs their pre-refactor selves). Objectives are `Objective` protocol
instances (repro.explore.objectives); legacy callables are coerced at entry.

Per-evaluation bookkeeping: every batch evaluated at a fidelity stage
("f0"/"f1") runs under `attribute_cache_traffic`, so the trace records
eval-cache hit-rates per stage — the cost of the fidelity handover is
visible in campaign artifacts and BENCH_dse.json.

Async proposal mode (DESIGN.md §11): with `LoopConfig.async_depth > 0` the
mfmobo/mobo strategies dispatch evaluation batches to a thread pool and
propose the next batch while up to `async_depth` batches are in flight —
q-EHVI fantasizes over the in-flight candidates (rank-1 `GP.condition_on`
at their posterior means) so GP refits never block evaluation workers.
Determinism is preserved by construction: results are folded strictly in
dispatch order (FIFO), and every harvest point is a function of loop state
alone (pipeline depth, budget, fidelity boundaries) — never of executor
timing — so a fixed seed replays the same trace under any interleaving.
In-flight batches are part of `LoopState` (picklable, future-free); a
resumed checkpoint re-dispatches them, and deterministic objectives make
the resumed trace equal the uninterrupted one. `async_depth=0` (default)
is the synchronous loop, bit-identical to its pre-async self.
"""
from __future__ import annotations

import dataclasses
import glob
import os
import pickle
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.evalcache import attribute_cache_traffic
from repro.core.mfmobo import (
    Trace,
    _acquire_batch,
    _acquire_batch_device,
    _fit_models,
    _valid_candidates,
    hv_ref,
    obj_space,
)
from repro.core.design_space import WSCDesign
from repro.core.pareto import hypervolume_2d
from repro.explore.objectives import Objective, as_objective

STRATEGIES = ("mfmobo", "mobo", "random")

# v2: LoopState gained `inflight` + `dispatch_seq` (async proposal mode);
# v1 checkpoints still load (the new fields default to empty)
CHECKPOINT_VERSION = 2
_READABLE_VERSIONS = (1, CHECKPOINT_VERSION)


@dataclasses.dataclass(frozen=True)
class LoopConfig:
    """Strategy + budgets + fidelity schedule. N0 is the f0 evaluation
    budget (for mobo/random: the total budget); N1/d1/k only apply to
    mfmobo. Validation guarantees the budgets are satisfiable exactly —
    priors never exceed their stage budget, so the clamped proposal loop
    honors N0/N1 to the evaluation."""
    strategy: str = "mfmobo"
    N0: int = 20
    N1: int = 30
    d0: int = 3
    d1: int = 3
    k: int = 5
    q: int = 1
    n_candidates: int = 256
    peak_power: float = 15000.0
    seed: int = 0
    async_depth: int = 0      # max in-flight eval batches; 0 = synchronous

    def validate(self) -> "LoopConfig":
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}; "
                             f"expected one of {STRATEGIES}")
        if self.q < 1 or self.n_candidates < 1:
            raise ValueError("q and n_candidates must be >= 1")
        if self.async_depth < 0:
            raise ValueError("async_depth must be >= 0 (0 = synchronous)")
        if self.N0 < 1:
            raise ValueError("evaluation budget N0 must be >= 1")
        if self.strategy == "mfmobo":
            if not (0 <= self.d0 <= self.N0):
                raise ValueError(
                    f"f0 priors d0={self.d0} must fit the f0 budget "
                    f"N0={self.N0}")
            if not (0 < self.d1 <= self.N1):
                raise ValueError(
                    f"f1 priors d1={self.d1} must fit the f1 budget "
                    f"N1={self.N1}")
            if self.k < 0:
                raise ValueError("handover width k must be >= 0")
        elif self.strategy == "mobo":
            if not (2 <= self.d0 <= self.N0):
                raise ValueError(
                    f"priors d0={self.d0} must satisfy 2 <= d0 <= N0="
                    f"{self.N0} (the GP needs >= 2 points)")
        return self

    def total_evals(self) -> int:
        if self.strategy == "mfmobo":
            return self.N0 + self.N1
        return self.N0


@dataclasses.dataclass
class PendingBatch:
    """One dispatched-but-unfolded evaluation batch (async mode). Picklable
    and future-free: a checkpoint taken mid-flight stores the candidates,
    and the resumed loop re-dispatches them — the fantasy values q-EHVI
    conditions on are recomputed from the refit models, never stored, so
    they are a pure function of (evaluated data, inflight order)."""
    seq: int                          # dispatch order (FIFO fold key)
    xs: np.ndarray                    # (q_eff, d) encoded candidates
    designs: List[WSCDesign]
    stage: str                        # "f0" | "f1"


@dataclasses.dataclass
class LoopState:
    """Everything a checkpoint needs: picklable, GP-free (models are refit
    from X/Y each iteration)."""
    rng: np.random.Generator
    trace: Trace
    X0: List[np.ndarray]
    Y0: List[Tuple[float, float]]
    X1: List[np.ndarray]
    Y1: List[Tuple[float, float]]
    hist_d: List[WSCDesign]
    hist_y: List[Tuple[float, float]]
    done: int = 0                     # post-prior proposal evals dispatched
    steps: int = 0                    # completed step() transitions
    initialized: bool = False
    handover_fired: bool = False
    pending: Optional[List] = None    # random: sampled-but-unevaluated queue
    wall_s: float = 0.0               # accumulated across run() segments
    inflight: List[PendingBatch] = dataclasses.field(default_factory=list)
    dispatch_seq: int = 0             # next PendingBatch.seq


def _fresh_state(cfg: LoopConfig) -> LoopState:
    tr = Trace([], [], [], [], [])
    tr.stage_cache = {"f0": {"hits": 0, "misses": 0, "entries_added": 0},
                      "f1": {"hits": 0, "misses": 0, "entries_added": 0}}
    return LoopState(rng=np.random.default_rng(cfg.seed), trace=tr,
                     X0=[], Y0=[], X1=[], Y1=[], hist_d=[], hist_y=[])


def _eval_attributed(obj: Objective, designs):
    """Evaluate a batch with this thread's eval-cache traffic captured.
    Runs on the caller's thread in sync mode and on pool threads in async
    mode — thread-local attribution is what keeps concurrent batches from
    scribbling over each other's counters."""
    with attribute_cache_traffic() as acc:
        # host-side floats only: whatever array scalars the objective hands
        # back must not leak device buffers into the picklable LoopState
        ys = [(float(t), float(p))
              for t, p in obj.eval_many(list(designs))]
    return ys, acc


class ExplorationLoop:
    """Step-able exploration run. One `step()` = the prior batch (first
    call) or one proposal batch acquired + evaluated; checkpoints are legal
    at any step boundary."""

    def __init__(self, cfg: LoopConfig, f0, f1=None, *,
                 on_handover: Optional[Callable] = None,
                 state: Optional[LoopState] = None,
                 candidate_fn: Optional[Callable] = None):
        self.cfg = cfg.validate()
        self.f0: Objective = as_objective(f0)
        self.f1: Optional[Objective] = (as_objective(f1)
                                        if f1 is not None else None)
        if cfg.strategy == "mfmobo" and self.f1 is None:
            raise ValueError("mfmobo needs a low-fidelity objective f1")
        self.on_handover = on_handover
        # joint mode (strategy-architecture co-exploration): campaigns
        # install a sampler producing (encoded xs, JointDesign) pairs; the
        # default None keeps the grid-mode `_valid_candidates` call (and
        # its rng stream) byte-for-byte
        self._candidate_fn = candidate_fn
        self.ref = hv_ref(cfg.peak_power)
        self.state = state if state is not None else _fresh_state(cfg)
        self._executor: Optional[ThreadPoolExecutor] = None
        self._futures: Dict[int, object] = {}   # PendingBatch.seq -> Future

    # -- bookkeeping -------------------------------------------------------

    def _fold_traffic(self, stage: str, acc: Dict[str, int]):
        sc = self.state.trace.stage_cache.setdefault(
            stage, {"hits": 0, "misses": 0, "entries_added": 0})
        for k in ("hits", "misses", "entries_added"):
            sc[k] += acc[k]

    def _eval(self, obj: Objective, designs, stage: str):
        """Evaluate a batch at a fidelity stage synchronously, attributing
        eval-cache traffic (hits/misses/entries added) to the stage."""
        ys, acc = _eval_attributed(obj, designs)
        self._fold_traffic(stage, acc)
        self.state.trace.n_evals += len(ys)
        return ys

    @staticmethod
    def _fused_ok(obj: Objective) -> bool:
        fn = getattr(obj, "supports_fused", None)
        return bool(fn()) if callable(fn) else False

    def _acquire_eval_fused(self, obj: Objective, models, cand_x, cand_d,
                            ev, q_eff: int, stage: str):
        """One fused synchronous iteration (DESIGN.md §12): the compiled
        q-EHVI scan's device-resident pick indices feed the compiled
        analytical evaluator directly — propose → gather → evaluate in one
        XLA dispatch chain, one host extraction at the end. Returns
        (pick indices, ys) bit-identical to the unfused
        `_acquire_batch` + `_eval` pair."""
        js_dev = _acquire_batch_device(models, cand_x, ev, self.ref,
                                       q=q_eff)
        with attribute_cache_traffic() as acc:
            js, ys = obj.eval_many_fused(cand_d, js_dev, q_eff)
            ys = [(float(t), float(p)) for t, p in ys]
        self._fold_traffic(stage, acc)
        self.state.trace.n_evals += len(ys)
        return js, ys

    def _record(self, x, d, y):
        tr = self.state.trace
        tr.xs.append(x)
        tr.designs.append(d)
        tr.ys.append(y)
        tr.hv.append(hypervolume_2d(obj_space(tr.ys), self.ref))
        tr.wall_s.append(time.time())

    def _fire_handover(self):
        self.state.handover_fired = True
        if self.on_handover is not None:
            self.on_handover(list(self.state.hist_d),
                             list(self.state.hist_y))

    # -- async plumbing (DESIGN.md §11) ------------------------------------

    def _pool(self) -> ThreadPoolExecutor:
        if self._executor is None:
            self._executor = ThreadPoolExecutor(
                max_workers=max(1, self.cfg.async_depth),
                thread_name_prefix="eval")
        return self._executor

    def _objective(self, stage: str) -> Objective:
        return self.f0 if stage == "f0" else self.f1

    def _candidates(self, n: int):
        if self._candidate_fn is not None:
            return self._candidate_fn(self.state.rng, n)
        return _valid_candidates(self.state.rng, n)

    def _dispatch(self, xs, designs, stage: str) -> None:
        st = self.state
        pb = PendingBatch(seq=st.dispatch_seq, xs=np.asarray(xs),
                          designs=list(designs), stage=stage)
        st.dispatch_seq += 1
        st.inflight.append(pb)
        self._futures[pb.seq] = self._pool().submit(
            _eval_attributed, self._objective(stage), pb.designs)

    def _redispatch_orphans(self) -> None:
        """Resubmit inflight batches without a live future — the resume
        path: checkpoints pickle PendingBatches but not futures."""
        for pb in self.state.inflight:
            if pb.seq not in self._futures:
                self._futures[pb.seq] = self._pool().submit(
                    _eval_attributed, self._objective(pb.stage), pb.designs)

    def _harvest_one(self) -> None:
        """Block on the OLDEST inflight batch and fold its results into the
        trace/training sets. Strictly FIFO regardless of completion order —
        the fold sequence (hence the trace) is deterministic under any
        executor timing."""
        st, cfg = self.state, self.cfg
        pb = st.inflight.pop(0)
        fut = self._futures.pop(pb.seq, None)
        if fut is None:                  # resumed + never re-dispatched
            ys, acc = _eval_attributed(self._objective(pb.stage), pb.designs)
        else:
            ys, acc = fut.result()
        self._fold_traffic(pb.stage, acc)
        st.trace.n_evals += len(ys)
        for x, d, y in zip(np.asarray(pb.xs), pb.designs, ys):
            if cfg.strategy == "mfmobo":
                st.hist_d.append(d)
                st.hist_y.append(y)
            if pb.stage == "f0":
                st.X0.append(x)
                st.Y0.append(y)
                self._record(x, d, y)
            else:
                st.X1.append(x)
                st.Y1.append(y)

    def _fantasize_inflight(self, models):
        """Condition both GPs on every inflight candidate at its posterior
        mean (rank-1 appends, dispatch order) and return the conditioned
        models plus the fantasy objective rows to extend the EHVI front —
        the q-EHVI proposal accounts for work already in the pipeline."""
        g_t, g_p = models
        rows = []
        for pb in self.state.inflight:
            for x in np.asarray(pb.xs):
                mu_t, _ = g_t.predict(x[None])
                mu_p, _ = g_p.predict(x[None])
                g_t = g_t.condition_on(x, float(mu_t[0]))
                g_p = g_p.condition_on(x, float(mu_p[0]))
                rows.append((float(mu_t[0]), float(mu_p[0])))
        return (g_t, g_p), np.array(rows, float).reshape(-1, 2)

    # -- step machine ------------------------------------------------------

    @property
    def finished(self) -> bool:
        st, cfg = self.state, self.cfg
        if not st.initialized:
            return False
        if st.inflight:                  # async: dispatched != folded
            return False
        if cfg.strategy == "mfmobo":
            return st.done >= cfg.N0 + cfg.N1 - cfg.d0 - cfg.d1
        if cfg.strategy == "mobo":
            return st.done >= cfg.N0 - cfg.d0
        return not st.pending                         # random

    def step(self) -> bool:
        """Advance one batch; returns False once the budget is spent."""
        if self.finished:
            return False
        st, cfg = self.state, self.cfg
        use_async = cfg.async_depth > 0 and cfg.strategy in ("mfmobo",
                                                             "mobo")
        if not st.initialized:
            self._init_step()
        elif cfg.strategy == "mfmobo":
            self._mfmobo_step_async() if use_async else self._mfmobo_step()
        elif cfg.strategy == "mobo":
            self._mobo_step_async() if use_async else self._mobo_step()
        else:
            self._random_step()
        st.steps += 1
        return True

    def run(self, *, max_steps: Optional[int] = None,
            checkpoint_every: int = 0,
            checkpoint_cb: Optional[Callable[[], None]] = None) -> Trace:
        t0 = time.time()

        def flush_wall():
            # fold the running segment into state *before* any checkpoint
            # is pickled, so a crash-resume doesn't under-report wall time
            # (and overstate candidates/sec)
            nonlocal t0
            now = time.time()
            self.state.wall_s += now - t0
            t0 = now

        n = 0
        try:
            while (max_steps is None or n < max_steps) and self.step():
                n += 1
                if (checkpoint_cb is not None and checkpoint_every
                        and n % checkpoint_every == 0):
                    flush_wall()
                    checkpoint_cb()
        finally:
            flush_wall()
            if self._executor is not None and self.finished:
                self._executor.shutdown(wait=True)
                self._executor = None
        if checkpoint_cb is not None:
            checkpoint_cb()
        return self.state.trace

    # -- strategy bodies (rng-consumption order identical to the legacy
    #    repro.core.mfmobo.run_* loops, so traces are bit-identical) -------

    def _init_step(self):
        st, cfg = self.state, self.cfg
        if cfg.strategy == "mfmobo":
            init_x, init_d = self._candidates(cfg.d0 + cfg.d1)
            ys1 = self._eval(self.f1, init_d[:cfg.d1], "f1")
            for x, d, y in zip(init_x[:cfg.d1], init_d[:cfg.d1], ys1):
                st.X1.append(x)
                st.Y1.append(y)
                st.hist_d.append(d)
                st.hist_y.append(y)
            if cfg.d0 > 0 and self.on_handover is not None:
                self._fire_handover()
            ys0 = self._eval(self.f0, init_d[cfg.d1:cfg.d1 + cfg.d0], "f0")
            for x, d, y in zip(init_x[cfg.d1:cfg.d1 + cfg.d0],
                               init_d[cfg.d1:cfg.d1 + cfg.d0], ys0):
                st.X0.append(x)
                st.Y0.append(y)
                st.hist_d.append(d)
                st.hist_y.append(y)
                self._record(x, d, y)
        elif cfg.strategy == "mobo":
            init_x, init_d = self._candidates(cfg.d0)
            for x, d, y in zip(init_x, init_d,
                               self._eval(self.f0, init_d, "f0")):
                st.X0.append(x)
                st.Y0.append(y)
                self._record(x, d, y)
        else:                                         # random
            xs, ds = self._candidates(cfg.N0)
            st.pending = [(x, d) for x, d in zip(xs, ds)]
        st.initialized = True

    def _mfmobo_step(self):
        st, cfg = self.state, self.cfg
        total = cfg.N0 + cfg.N1 - cfg.d0 - cfg.d1
        use_f0 = st.done >= cfg.N1 - cfg.d1
        use_m0 = st.done >= cfg.N1 - cfg.d1 + cfg.k
        if use_f0 and not st.handover_fired:
            self._fire_handover()
        # batch size: q, clipped to the remaining budget and to the next
        # fidelity-schedule boundary so every evaluation in the batch runs
        # at the fidelity the schedule assigns it — the final batch is
        # clamped so the trace honors the N0/N1 budget exactly
        boundaries = [b for b in (cfg.N1 - cfg.d1, cfg.N1 - cfg.d1 + cfg.k,
                                  total) if b > st.done]
        q_eff = max(1, min(cfg.q, min(boundaries) - st.done))

        cand_x, cand_d = self._candidates(cfg.n_candidates)
        if use_m0 and len(st.X0) >= 2:
            models = _fit_models(np.array(st.X0), np.array(st.Y0))
            ev = obj_space(st.Y0)
        else:
            models = _fit_models(np.array(st.X1), np.array(st.Y1))
            ev = (obj_space(st.Y1) if not use_f0 or not st.Y0
                  else obj_space(st.Y0))
        obj = self.f0 if use_f0 else self.f1
        stage = "f0" if use_f0 else "f1"
        if self._fused_ok(obj):
            js, ys = self._acquire_eval_fused(obj, models, cand_x, cand_d,
                                              ev, q_eff, stage)
        else:
            js = _acquire_batch(models, cand_x, ev, self.ref, q=q_eff)
            ys = self._eval(obj, [cand_d[j] for j in js], stage)
        for j, y in zip(js, ys):
            st.hist_d.append(cand_d[j])
            st.hist_y.append(y)
            if use_f0:
                st.X0.append(cand_x[j])
                st.Y0.append(y)
                self._record(cand_x[j], cand_d[j], y)
            else:
                st.X1.append(cand_x[j])
                st.Y1.append(y)
        st.done += len(js)

    def _mobo_step(self):
        st, cfg = self.state, self.cfg
        q_eff = max(1, min(cfg.q, cfg.N0 - cfg.d0 - st.done))
        models = _fit_models(np.array(st.X0), np.array(st.Y0))
        cand_x, cand_d = self._candidates(cfg.n_candidates)
        ev = obj_space(st.Y0)
        if self._fused_ok(self.f0):
            js, ys = self._acquire_eval_fused(self.f0, models, cand_x,
                                              cand_d, ev, q_eff, "f0")
        else:
            js = _acquire_batch(models, cand_x, ev, self.ref, q=q_eff)
            ys = self._eval(self.f0, [cand_d[j] for j in js], "f0")
        for j, y in zip(js, ys):
            st.X0.append(cand_x[j])
            st.Y0.append(y)
            self._record(cand_x[j], cand_d[j], y)
        st.done += len(js)

    # -- async strategy bodies: propose with fantasized inflight batches,
    #    dispatch to the pool, fold strictly FIFO. `st.done` counts
    #    DISPATCHED proposal evals (folds lag by at most async_depth
    #    batches), so the q_eff boundary clamping is unchanged. ------------

    def _mfmobo_step_async(self):
        st, cfg = self.state, self.cfg
        self._redispatch_orphans()
        total = cfg.N0 + cfg.N1 - cfg.d0 - cfg.d1
        if st.done >= total:             # budget fully dispatched: drain
            self._harvest_one()
            return
        use_f0 = st.done >= cfg.N1 - cfg.d1
        use_m0 = st.done >= cfg.N1 - cfg.d1 + cfg.k
        if use_f0 and any(pb.stage == "f1" for pb in st.inflight):
            # fidelity boundary: every f1 result must be folded before the
            # first f0 dispatch — they train M1 and feed the handover hook
            self._harvest_one()
            return
        if use_f0 and not st.handover_fired:
            self._fire_handover()
        if len(st.inflight) >= cfg.async_depth:      # pipeline full
            self._harvest_one()
        boundaries = [b for b in (cfg.N1 - cfg.d1, cfg.N1 - cfg.d1 + cfg.k,
                                  total) if b > st.done]
        q_eff = max(1, min(cfg.q, min(boundaries) - st.done))
        cand_x, cand_d = self._candidates(cfg.n_candidates)
        if use_m0 and len(st.X0) >= 2:
            models = _fit_models(np.array(st.X0), np.array(st.Y0))
            ev = obj_space(st.Y0)
        else:
            models = _fit_models(np.array(st.X1), np.array(st.Y1))
            ev = (obj_space(st.Y1) if not use_f0 or not st.Y0
                  else obj_space(st.Y0))
        models, fant_rows = self._fantasize_inflight(models)
        ev = np.concatenate([ev, fant_rows], 0) if len(fant_rows) else ev
        js = _acquire_batch(models, cand_x, ev, self.ref, q=q_eff)
        self._dispatch(cand_x[js], [cand_d[j] for j in js],
                       "f0" if use_f0 else "f1")
        st.done += len(js)

    def _mobo_step_async(self):
        st, cfg = self.state, self.cfg
        self._redispatch_orphans()
        total = cfg.N0 - cfg.d0
        if st.done >= total:
            self._harvest_one()
            return
        if len(st.inflight) >= cfg.async_depth:
            self._harvest_one()
        q_eff = max(1, min(cfg.q, total - st.done))
        models = _fit_models(np.array(st.X0), np.array(st.Y0))
        models, fant_rows = self._fantasize_inflight(models)
        ev = obj_space(st.Y0)
        ev = np.concatenate([ev, fant_rows], 0) if len(fant_rows) else ev
        cand_x, cand_d = self._candidates(cfg.n_candidates)
        js = _acquire_batch(models, cand_x, ev, self.ref, q=q_eff)
        self._dispatch(cand_x[js], [cand_d[j] for j in js], "f0")
        st.done += len(js)

    def _random_step(self):
        st, cfg = self.state, self.cfg
        batch = st.pending[:max(cfg.q, 1)]
        st.pending = st.pending[len(batch):]
        ys = self._eval(self.f0, [d for _, d in batch], "f0")
        for (x, d), y in zip(batch, ys):
            self._record(x, d, y)
        st.done += len(batch)

    # -- checkpointing -----------------------------------------------------

    def save_state(self, path: str, extra: Optional[Dict] = None,
                   keep: int = 3) -> str:
        """Atomically write the checkpoint head at `path`, retaining the
        newest `keep - 1` step-stamped history files alongside it
        (`<path>.step<NNNNNNNN>`) — `load_state` falls back to them when
        the head is corrupt (torn disk write, bad copy). keep <= 1 keeps
        the single-file behavior."""
        blob = pickle.dumps({"version": CHECKPOINT_VERSION,
                             "cfg": dataclasses.asdict(self.cfg),
                             "state": self.state,
                             "extra": extra or {}})
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(blob)
        if keep > 1:
            hist = f"{path}.step{self.state.steps:08d}"
            try:
                os.link(tmp, hist)           # same bytes, no second write
            except OSError:                  # exists / fs without links
                with open(hist, "wb") as f:
                    f.write(blob)
            for old in sorted(glob.glob(path + ".step*"))[:-(keep - 1)]:
                try:
                    os.remove(old)
                except OSError:
                    pass
        os.replace(tmp, path)         # atomic: a crash mid-write can't
        return path                   # corrupt the last good checkpoint

    @staticmethod
    def _load_blob(path: str) -> Tuple[LoopConfig, LoopState, Dict]:
        with open(path, "rb") as f:
            blob = pickle.load(f)
        v = blob.get("version")
        if v not in _READABLE_VERSIONS:
            raise ValueError(f"checkpoint {path} has version {v!r}; this "
                             f"build reads versions {_READABLE_VERSIONS}")
        st = blob["state"]
        if not hasattr(st, "inflight"):      # v1 state: pre-async fields
            st.inflight = []
        if not hasattr(st, "dispatch_seq"):
            st.dispatch_seq = 0
        return (LoopConfig(**blob["cfg"]), st, blob.get("extra", {}))

    @staticmethod
    def load_state(path: str) -> Tuple[LoopConfig, LoopState, Dict]:
        """Load a checkpoint; if the head at `path` is unreadable (missing,
        truncated, unpicklable, wrong version), fall back to the newest
        loadable retained history file (`save_state(keep=...)`)."""
        try:
            return ExplorationLoop._load_blob(path)
        except Exception:
            for hist in sorted(glob.glob(path + ".step*"), reverse=True):
                try:
                    return ExplorationLoop._load_blob(hist)
                except Exception:
                    continue
            raise


__all__ = ["CHECKPOINT_VERSION", "ExplorationLoop", "LoopConfig",
           "LoopState", "PendingBatch", "STRATEGIES"]
