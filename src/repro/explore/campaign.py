"""Declarative, serializable, resumable DSE campaigns (DESIGN.md §9).

The paper's headline results (§VII–§VIII) are *campaigns*: a workload, a
scenario, an objective pair, constraints, a fidelity schedule, a strategy
and a budget. `CampaignSpec` makes that configuration the artifact of
record — a frozen dataclass that round-trips to JSON and fully determines
a run (fixed seed ⇒ reproducible trace) — and `Campaign` executes it with
periodic checkpointing:

    spec = CampaignSpec.from_json("examples/campaigns/quick_train_mfmobo.json")
    result = Campaign(spec).run(checkpoint_path="run.ckpt")
    ...
    result = Campaign.resume("run.ckpt").run()     # bit-identical continuation

Scenarios wire the objective adapters (repro.explore.objectives):
    train      evaluate_design_batch on the workload as-is (phase=train)
    inference  evaluate_design_batch on an isolated prefill/decode step
    serving    request-level continuous batching (TTFT/TPOT/SLO goodput)
    hetero     prefill/decode disaggregation under the coupled request model
    trace_serving  trace-driven multi-tenant serving: timed arrivals, per-
               tenant SLOs, searchable admission/routing policy (§14)

Workload refs resolve against `repro.core.workload.GPT_BENCHMARKS` by name
("GPT-175B") or against the runtime configs as "arch_id@shape_id"
(repro.configs.get_config / get_shape via `from_model_config`), so every
assigned architecture is a campaign target too.

The CLI lives in `repro.explore.__main__`:
    python -m repro.explore examples/campaigns/<spec>.json [--resume CKPT]
"""
from __future__ import annotations

import dataclasses
import json
import pickle
from typing import Dict, List, Mapping, Optional, Tuple

from repro.core.mfmobo import Trace
from repro.core.pareto import pareto_mask, to_max_space
from repro.core.workload import GPT_BENCHMARKS, LLMWorkload, RequestMix
from repro.explore.objectives import (
    ConstraintSpec,
    EvaluatorObjective,
    HeteroServingObjective,
    Objective,
    ObjectiveSpec,
    ServingObjective,
    default_objectives,
)
from repro.explore.runner import ExplorationLoop, LoopConfig, STRATEGIES

SCENARIOS = ("train", "inference", "serving", "hetero", "trace_serving")
HETERO_GRANULARITIES = ("core", "reticle", "wafer")
#: trace_serving admission/routing policies a spec may pin — or "search"
#: to make the policy a candidate dimension next to the architecture dims
TRACE_POLICIES = ("fifo", "priority", "preempt", "disaggregated", "search")
SPEC_VERSION = 1


# ---------------------------------------------------------------------------
# spec dataclasses
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FidelitySchedule:
    """Which fidelity evaluates which part of the budget (paper Algorithm
    1): d1 f1-priors, then f1 proposals until N1 is spent, then f0 with the
    low-fidelity surrogate for k evaluations (the handover), then f0 with
    its own surrogate. `calibrate_on_handover` fine-tunes the f0 GNN on
    simulator traces from the current Pareto neighborhood right before the
    first f0 evaluation (repro.core.calibration)."""
    f1: str = "analytical"
    f0: str = "analytical"
    d1: int = 3
    d0: int = 2
    k: int = 3
    calibrate_on_handover: bool = False
    params_path: Optional[str] = None      # pickled GNN params for f0/f1
    calibration: Optional[Dict] = None     # GNNCalibrator kwargs

    def needs_gnn_params(self) -> bool:
        return "gnn" in (self.f0, self.f1)

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "FidelitySchedule":
        return cls(**dict(d))


@dataclasses.dataclass(frozen=True)
class ServingSpec:
    """Request mix + SLO for serving / hetero scenarios: one arrival batch
    of `n_requests`, uniform (prompt_len -> out_len), `slots` decode slots,
    and the TTFT/TPOT bounds a request must meet to count toward goodput."""
    n_requests: int = 32
    prompt_len: int = 2048
    out_len: int = 256
    slots: int = 8
    ttft_s: float = 5.0
    tpot_s: float = 0.05

    def mix(self) -> RequestMix:
        return RequestMix.uniform(self.n_requests, prompt_len=self.prompt_len,
                                  out_len=self.out_len)

    def slo(self):
        from repro.core.serving import ServingSLO
        return ServingSLO(ttft_s=self.ttft_s, tpot_s=self.tpot_s)

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "ServingSpec":
        return cls(**dict(d))


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Trace-driven multi-tenant serving scenario (DESIGN.md §14): a seeded
    synthetic arrival process (`kind`: poisson | spike | diurnal), the
    tenant classes sharing the wafer, and the admission/routing policy —
    pinned to one of `core.traces.POLICIES`, or ``"search"`` to expose the
    policy as a candidate axis next to the 13 architecture dims
    (`sample_policy_candidates`). Each tenant dict carries its own SLO and
    scheduling class: ``{"name", "ttft_s", "tpot_s", "priority",
    "interactive", "share", "prompt_range", "out_range"}``."""
    kind: str = "spike"
    n_requests: int = 64
    rate: float = 0.25
    seed: int = 0
    slots: int = 8
    window_steps: int = 64
    policy: str = "fifo"
    policies: Tuple[str, ...] = ()       # searched subset ("" = all four)
    prefill_ratio: float = 0.5           # disaggregated stage split
    # spike (Markov-modulated) process knobs
    spike_factor: float = 8.0
    spike_len: int = 32
    gap_len: int = 128
    # diurnal (sinusoidal-rate) process knobs
    period: int = 512
    amplitude: float = 0.9
    tenants: Tuple[Dict, ...] = ()

    def __post_init__(self):
        norm = []
        for t in self.tenants:
            t = dict(t)
            for k in ("prompt_range", "out_range"):
                if k in t and t[k] is not None:
                    t[k] = tuple(int(x) for x in t[k])
            norm.append(t)
        object.__setattr__(self, "tenants", tuple(norm))
        object.__setattr__(self, "policies",
                           tuple(str(p) for p in self.policies))

    def tenant_classes(self):
        from repro.core.traces import DEFAULT_TENANT, TenantClass
        if not self.tenants:
            return (DEFAULT_TENANT,)
        return tuple(TenantClass(
            name=t["name"], ttft_s=float(t["ttft_s"]),
            tpot_s=float(t["tpot_s"]), priority=int(t.get("priority", 0)),
            interactive=bool(t.get("interactive", True)))
            for t in self.tenants)

    def trace(self):
        from repro.core.traces import synth_trace
        kw: Dict = {"rate": self.rate}
        if self.kind == "spike":
            kw.update(spike_factor=self.spike_factor,
                      spike_len=self.spike_len, gap_len=self.gap_len)
        elif self.kind == "diurnal":
            kw.update(period=self.period, amplitude=self.amplitude)
        if self.tenants:
            kw.update(
                tenants=self.tenant_classes(),
                shares=tuple(float(t.get("share", 1.0))
                             for t in self.tenants),
                prompt_ranges=tuple(t.get("prompt_range", (256, 1024))
                                    for t in self.tenants),
                out_ranges=tuple(t.get("out_range", (32, 128))
                                 for t in self.tenants))
        return synth_trace(self.kind, self.n_requests, seed=self.seed, **kw)

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "TraceSpec":
        return cls(**dict(d))


@dataclasses.dataclass(frozen=True)
class HeteroSpec:
    """Prefill/decode disaggregation knobs for the hetero scenario."""
    granularity: str = "reticle"
    prefill_ratio: float = 0.5
    n_wafers: int = 8

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "HeteroSpec":
        return cls(**dict(d))


@dataclasses.dataclass(frozen=True)
class CampaignSpec:
    """One DSE campaign, fully determined: JSON round-trip preserves every
    field, and (spec, seed) fixes the trace bit-for-bit."""
    name: str
    workload: str                              # "GPT-175B" | "arch@shape"
    scenario: str = "train"
    strategy: str = "mfmobo"                   # mfmobo | mobo | random
    objectives: Tuple[ObjectiveSpec, ObjectiveSpec] = ()
    constraints: Tuple[ConstraintSpec, ...] = ()
    fidelity: FidelitySchedule = FidelitySchedule()
    n_evals_f0: int = 20                       # N0 (total budget for
    n_evals_f1: int = 30                       # mobo/random); N1 (mfmobo)
    q: int = 1
    seed: int = 0
    n_candidates: int = 256
    max_strategies: int = 24
    peak_power_w: float = 15000.0
    workload_overrides: Optional[Dict] = None  # batch / seq / phase
    serving: Optional[ServingSpec] = None
    hetero: Optional[HeteroSpec] = None
    trace: Optional[TraceSpec] = None          # trace_serving scenario
    checkpoint_every: int = 0                  # steps; 0 = final only
    checkpoint_keep: int = 3                   # retained ckpt generations
    async_depth: int = 0                       # in-flight eval batches;
                                               # 0 = synchronous loop
    # strategy-architecture co-exploration (DESIGN.md §13): "grid" keeps
    # the per-design strategy-grid argmin (historical behavior, trace
    # replay contract); "joint" appends the 7 strategy axes to the search
    # encoding and pins each candidate's Strategy
    strategy_mode: str = "grid"
    strategy_space: Optional[Dict] = None      # StrategySpace.to_json()
                                               # bounds; None = derived

    def __post_init__(self):
        if not self.objectives:
            object.__setattr__(self, "objectives",
                               default_objectives(self.scenario))
        object.__setattr__(self, "objectives", tuple(self.objectives))
        object.__setattr__(self, "constraints", tuple(self.constraints))

    # -- validation --------------------------------------------------------

    def validate(self) -> "CampaignSpec":
        if self.scenario not in SCENARIOS:
            raise ValueError(f"unknown scenario {self.scenario!r}; expected "
                             f"one of {SCENARIOS}")
        if self.strategy not in STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}; expected "
                             f"one of {STRATEGIES}")
        from repro.core.fidelity import get_backend
        get_backend(self.fidelity.f0)
        if self.strategy == "mfmobo":
            get_backend(self.fidelity.f1)
        if self.scenario in ("serving", "hetero") and self.serving is None:
            raise ValueError(f"scenario {self.scenario!r} needs a `serving` "
                             "spec (request mix + SLO)")
        if self.scenario == "trace_serving":
            t = self.trace
            if t is None:
                raise ValueError("scenario 'trace_serving' needs a `trace` "
                                 "spec (arrival process + tenants + policy)")
            if t.policy not in TRACE_POLICIES:
                raise ValueError(f"trace policy {t.policy!r} not in "
                                 f"{TRACE_POLICIES}")
            from repro.core.traces import POLICIES
            if t.policies and (t.policy != "search"
                               or any(p not in POLICIES
                                      for p in t.policies)):
                raise ValueError(
                    "trace.policies narrows the searched policy set — it "
                    "requires policy='search' and a subset of "
                    f"{POLICIES} (got policy={t.policy!r}, "
                    f"policies={t.policies})")
            if t.kind not in ("poisson", "spike", "diurnal"):
                raise ValueError(f"trace kind {t.kind!r} not in "
                                 "('poisson', 'spike', 'diurnal')")
            t.trace()        # generator kwargs / tenant dicts raise here
        if self.scenario == "hetero":
            h = self.hetero or HeteroSpec()
            if h.granularity not in HETERO_GRANULARITIES:
                raise ValueError(
                    f"hetero granularity {h.granularity!r} not in "
                    f"{HETERO_GRANULARITIES}")
        if self.fidelity.calibrate_on_handover and self.fidelity.f0 != "gnn":
            raise ValueError("calibrate_on_handover requires f0='gnn'")
        if self.strategy_mode not in ("grid", "joint"):
            raise ValueError(f"strategy_mode {self.strategy_mode!r} not in "
                             "('grid', 'joint')")
        if self.strategy_mode == "joint":
            if self.scenario not in ("train", "inference"):
                raise ValueError(
                    "strategy_mode='joint' supports the train/inference "
                    f"scenarios (got {self.scenario!r}); serving/hetero "
                    "objectives do not pin strategies yet")
            if self.strategy_space is not None:
                from repro.core.design_space import StrategySpace
                StrategySpace.from_json(self.strategy_space)  # raises on bad
        self.loop_config().validate()
        resolve_workload(self)                       # raises on bad refs
        for c in self.constraints:
            if c.metric not in self.known_metrics():
                raise ValueError(
                    f"constraint metric {c.metric!r} not produced by the "
                    f"{self.scenario} scenario; known: "
                    f"{sorted(self.known_metrics())}")
        for o in self.objectives:
            if o.name not in self.known_metrics():
                raise ValueError(
                    f"objective metric {o.name!r} not produced by the "
                    f"{self.scenario} scenario; known: "
                    f"{sorted(self.known_metrics())}")
        dirs = tuple(o.direction for o in self.objectives)
        if dirs != ("max", "min"):
            raise ValueError(
                "objective pair must be (max, min) — maximize "
                "throughput/goodput against minimized power (got "
                f"{dirs}); swap the pair order")
        # the trace's hypervolume/acquisition space is fixed to the
        # canonical (log1p y0, -log y1) of mfmobo.obj_space; reject specs
        # declaring transforms the loop would silently not apply
        # ("identity" exists for CallableObjective's synthetic legacy fns,
        # which never come from specs)
        tfs = tuple(o.transform for o in self.objectives)
        if tfs != ("log1p", "neg_log"):
            raise ValueError(
                f"campaign objective transforms must be ('log1p', "
                f"'neg_log') — the trace HV space is fixed (got {tfs})")
        return self

    def known_metrics(self) -> Tuple[str, ...]:
        base = ("throughput", "power", "power_per_wafer", "n_wafers")
        if self.scenario == "serving":
            return base + ("goodput", "ttft", "tpot", "ttft_max",
                           "tpot_max", "slo_attainment")
        if self.scenario == "hetero":
            return base + ("goodput", "ttft", "tpot", "slo_attainment",
                           "kv_transfer_s")
        if self.scenario == "trace_serving":
            t = self.trace or TraceSpec()
            names = [d.get("name", "default") for d in t.tenants] \
                or ["default"]
            per_tenant = tuple(f"tenant:{n}:{m}" for n in names
                               for m in ("goodput", "slo_attainment"))
            return base + ("goodput", "interactive_goodput",
                           "worst_window_goodput", "ttft", "tpot",
                           "ttft_max", "tpot_max", "slo_attainment",
                           "n_preemptions") + per_tenant
        return base

    def loop_config(self) -> LoopConfig:
        f = self.fidelity
        return LoopConfig(
            strategy=self.strategy, N0=self.n_evals_f0, N1=self.n_evals_f1,
            d0=f.d0, d1=f.d1, k=f.k, q=self.q,
            n_candidates=self.n_candidates, peak_power=self.peak_power_w,
            seed=self.seed, async_depth=self.async_depth)

    # -- serialization -----------------------------------------------------

    def to_dict(self) -> Dict:
        d = {
            "version": SPEC_VERSION,
            "name": self.name,
            "workload": self.workload,
            "scenario": self.scenario,
            "strategy": self.strategy,
            "objectives": [o.to_dict() for o in self.objectives],
            "constraints": [c.to_dict() for c in self.constraints],
            "fidelity": self.fidelity.to_dict(),
            "n_evals_f0": self.n_evals_f0,
            "n_evals_f1": self.n_evals_f1,
            "q": self.q,
            "seed": self.seed,
            "n_candidates": self.n_candidates,
            "max_strategies": self.max_strategies,
            "peak_power_w": self.peak_power_w,
            "checkpoint_every": self.checkpoint_every,
            "checkpoint_keep": self.checkpoint_keep,
            "async_depth": self.async_depth,
        }
        # emitted only when non-default, so pre-joint spec JSON (and the
        # fixtures diffing it) stays byte-identical
        if self.strategy_mode != "grid":
            d["strategy_mode"] = self.strategy_mode
        if self.strategy_space is not None:
            d["strategy_space"] = dict(self.strategy_space)
        if self.workload_overrides:
            d["workload_overrides"] = dict(self.workload_overrides)
        if self.serving is not None:
            d["serving"] = self.serving.to_dict()
        if self.hetero is not None:
            d["hetero"] = self.hetero.to_dict()
        if self.trace is not None:
            d["trace"] = self.trace.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "CampaignSpec":
        d = dict(d)
        v = d.pop("version", SPEC_VERSION)
        if v != SPEC_VERSION:
            raise ValueError(f"campaign spec version {v!r} unsupported "
                             f"(this build reads version {SPEC_VERSION})")
        if "objectives" in d:
            d["objectives"] = tuple(ObjectiveSpec.from_dict(o)
                                    for o in d["objectives"])
        if "constraints" in d:
            d["constraints"] = tuple(ConstraintSpec.from_dict(c)
                                     for c in d["constraints"])
        if "fidelity" in d:
            d["fidelity"] = FidelitySchedule.from_dict(d["fidelity"])
        if d.get("serving") is not None:
            d["serving"] = ServingSpec.from_dict(d["serving"])
        if d.get("hetero") is not None:
            d["hetero"] = HeteroSpec.from_dict(d["hetero"])
        if d.get("trace") is not None:
            d["trace"] = TraceSpec.from_dict(d["trace"])
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValueError(f"unknown campaign spec fields: "
                             f"{sorted(unknown)}")
        return cls(**d)

    def to_json(self, path: Optional[str] = None, indent: int = 1) -> str:
        s = json.dumps(self.to_dict(), indent=indent)
        if path:
            with open(path, "w") as f:
                f.write(s + "\n")
        return s

    @classmethod
    def from_json(cls, path_or_str: str) -> "CampaignSpec":
        if path_or_str.lstrip().startswith("{"):
            return cls.from_dict(json.loads(path_or_str))
        with open(path_or_str) as f:
            return cls.from_dict(json.load(f))


# ---------------------------------------------------------------------------
# workload resolution
# ---------------------------------------------------------------------------

_GPT_BY_NAME = {w.name: w for w in GPT_BENCHMARKS}


def resolve_workload(spec: CampaignSpec) -> LLMWorkload:
    """Resolve the spec's workload ref: a paper benchmark by name
    ("GPT-175B") or a runtime architecture as "arch_id@shape_id" (bridged
    through `from_model_config`). Overrides (batch/seq/phase) and the
    scenario's phase convention are applied on top."""
    ref = spec.workload
    if ref in _GPT_BY_NAME:
        wl = _GPT_BY_NAME[ref]
    elif "@" in ref:
        from repro.configs import get_config, get_shape
        from repro.core.workload import from_model_config
        arch, shape = ref.split("@", 1)
        wl = from_model_config(get_config(arch), get_shape(shape))
    else:
        raise ValueError(
            f"unknown workload ref {ref!r}: expected one of "
            f"{sorted(_GPT_BY_NAME)} or an 'arch_id@shape_id' config ref")
    ov = dict(spec.workload_overrides or {})
    if spec.scenario == "train":
        ov.setdefault("phase", "train")
    elif spec.scenario == "inference":
        ov.setdefault("phase", "decode")
        if ov["phase"] not in ("prefill", "decode"):
            raise ValueError("inference scenario phase must be "
                             f"prefill|decode (got {ov['phase']!r})")
    bad = set(ov) - {"batch", "seq", "phase"}
    if bad:
        raise ValueError(f"unsupported workload overrides: {sorted(bad)}")
    return dataclasses.replace(wl, **ov) if ov else wl


# the densest wafer in the design space (32x32 cores x 12x12 reticles
# ~ 1.5e5 cores) on a handful of area-matched wafers — the default system
# bound the derived strategy caps assume when a spec doesn't pin bounds
DEFAULT_JOINT_CORES = 1 << 19


def resolve_strategy_space(spec: CampaignSpec, wl: LLMWorkload):
    """The joint campaign's `StrategySpace`: explicit bounds from the spec
    when given, else derived from the workload and the largest system under
    search (`StrategySpace.for_workload`)."""
    from repro.core.design_space import StrategySpace
    if spec.strategy_space is not None:
        return StrategySpace.from_json(spec.strategy_space)
    return StrategySpace.for_workload(wl, DEFAULT_JOINT_CORES)


# ---------------------------------------------------------------------------
# campaign runner
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CampaignResult:
    spec: CampaignSpec
    trace: Trace
    finished: bool
    wall_s: float
    n_evals: int
    candidates_per_sec: float
    hv_final: float
    front: List[Dict]
    stage_cache: Dict[str, Dict]
    objective_stats: Dict
    calibration: List[Dict]

    def to_dict(self) -> Dict:
        return {
            "spec": self.spec.to_dict(),
            "finished": self.finished,
            "wall_s": self.wall_s,
            "n_evals": self.n_evals,
            "candidates_per_sec": self.candidates_per_sec,
            "hv": list(self.trace.hv),
            "hv_final": self.hv_final,
            "front": self.front,
            "stage_cache": self.stage_cache,
            "objective_stats": self.objective_stats,
            "calibration": self.calibration,
        }

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1, default=float)
        return path


def _front_records(spec: CampaignSpec, trace: Trace) -> List[Dict]:
    """Nondominated trace points (penalty / zero-objective points never
    qualify: any feasible candidate dominates them)."""
    y0n, y1n = spec.objectives[0].name, spec.objectives[1].name
    rows = [(i, y) for i, y in enumerate(trace.ys) if y[0] > 0]
    if not rows:
        return []
    pts = to_max_space([y[0] for _, y in rows], [y[1] for _, y in rows])
    mask = pareto_mask(pts)
    out = []
    for (i, y), keep in zip(rows, mask):
        if keep:
            d = trace.designs[i]
            out.append({y0n: y[0], y1n: y[1],
                        "design": dataclasses.asdict(d),
                        "describe": d.describe()})
    return out


class Campaign:
    """Executes a `CampaignSpec`: builds the scenario's objectives, runs the
    resumable exploration loop, checkpoints periodically, and summarizes the
    outcome. `Campaign.resume(path)` reconstructs a mid-run campaign whose
    continuation is bit-identical to the uninterrupted run."""

    def __init__(self, spec: CampaignSpec, *,
                 gnn_params: Optional[Dict] = None,
                 _state=None, _calibration_records=None,
                 _objective_stats=None):
        self.spec = spec.validate()
        self.wl = resolve_workload(spec)
        self.gnn_params = self._load_params(gnn_params)
        self.calibrator = None
        on_handover = None
        if spec.fidelity.calibrate_on_handover:
            from repro.core.calibration import GNNCalibrator
            kw = dict(spec.fidelity.calibration or {})
            kw.setdefault("seed", spec.seed)
            self.calibrator = GNNCalibrator(self.gnn_params, self.wl, **kw)
            if _calibration_records:
                self.calibrator.records = list(_calibration_records)
            on_handover = self.calibrator.on_handover
        self.f0 = self._build_objective(spec.fidelity.f0)
        self.f1 = (self._build_objective(spec.fidelity.f1)
                   if spec.strategy == "mfmobo" else None)
        if _objective_stats:                 # resume: cumulative counters
            self.f0.load_stats(_objective_stats.get("f0", {}))
            if self.f1 is not None:
                self.f1.load_stats(_objective_stats.get("f1", {}))
        candidate_fn = None
        if spec.strategy_mode == "joint":
            from repro.core.mfmobo import _valid_candidates_joint
            space = resolve_strategy_space(spec, self.wl)
            wl = self.wl
            candidate_fn = (lambda rng, n:
                            _valid_candidates_joint(rng, n, space, wl))
        elif (spec.scenario == "trace_serving" and spec.trace is not None
                and spec.trace.policy == "search"):
            # the policy axis: 14-dim candidates, each a PolicyDesign
            from repro.core.traces import POLICIES, sample_policy_candidates
            pols = spec.trace.policies or POLICIES
            candidate_fn = (lambda rng, n:
                            sample_policy_candidates(rng, n, policies=pols))
        self.loop = ExplorationLoop(spec.loop_config(), self.f0, f1=self.f1,
                                    on_handover=on_handover, state=_state,
                                    candidate_fn=candidate_fn)

    # -- construction helpers ----------------------------------------------

    def _load_params(self, gnn_params):
        spec = self.spec
        if gnn_params is not None:
            return gnn_params
        if spec.fidelity.params_path:
            with open(spec.fidelity.params_path, "rb") as f:
                return pickle.load(f)
        if spec.fidelity.needs_gnn_params():
            raise ValueError(
                "the 'gnn' fidelity needs trained parameters: set "
                "fidelity.params_path in the spec or pass "
                "Campaign(spec, gnn_params=...)")
        return None

    def _params_fn(self):
        if self.calibrator is not None:
            cal = self.calibrator
            return lambda: cal.params
        if self.gnn_params is not None:
            params = self.gnn_params
            return lambda: params
        return None

    def _build_objective(self, fidelity: str) -> Objective:
        spec = self.spec
        kw = dict(objectives=spec.objectives, constraints=spec.constraints)
        # params only reach the fidelities that consume them, so e.g. the
        # analytical f1 stage's cache keys stay params-independent while
        # calibration swaps the f0 pytree mid-run
        params_fn = self._params_fn() if fidelity == "gnn" else None
        if spec.scenario in ("train", "inference"):
            return EvaluatorObjective(
                self.wl, fidelity, params_fn=params_fn,
                max_strategies=spec.max_strategies,
                strategy_mode=spec.strategy_mode, **kw)
        if spec.scenario == "trace_serving":
            from repro.explore.objectives import TraceServingObjective
            t = spec.trace
            return TraceServingObjective(
                self.wl, t.trace(),
                policy="fifo" if t.policy == "search" else t.policy,
                slots=t.slots, window_steps=t.window_steps,
                prefill_ratio=t.prefill_ratio, fidelity=fidelity,
                params_fn=params_fn,
                max_strategies=spec.max_strategies, **kw)
        sv = spec.serving
        if spec.scenario == "serving":
            return ServingObjective(
                self.wl, sv.mix(), sv.slo(), slots=sv.slots,
                fidelity=fidelity, params_fn=params_fn,
                max_strategies=spec.max_strategies, **kw)
        h = spec.hetero or HeteroSpec()
        return HeteroServingObjective(
            self.wl, sv.mix(), sv.slo(), granularity=h.granularity,
            prefill_ratio=h.prefill_ratio, slots=sv.slots,
            n_wafers=h.n_wafers, fidelity=fidelity,
            params_fn=params_fn, **kw)

    # -- execution ---------------------------------------------------------

    def _checkpoint(self, path: str):
        extra = {"spec": self.spec.to_dict(),
                 "objective_stats": {"f0": self.f0.stats(),
                                     **({"f1": self.f1.stats()}
                                        if self.f1 is not None else {})}}
        if self.calibrator is not None:
            extra["gnn_params"] = self.calibrator.params
            extra["calibration_records"] = list(self.calibrator.records)
        elif self.gnn_params is not None:
            extra["gnn_params"] = self.gnn_params
        self.loop.save_state(path, extra=extra,
                             keep=self.spec.checkpoint_keep)

    def run(self, checkpoint_path: Optional[str] = None,
            checkpoint_every: Optional[int] = None,
            max_steps: Optional[int] = None) -> CampaignResult:
        every = (checkpoint_every if checkpoint_every is not None
                 else self.spec.checkpoint_every)
        cb = ((lambda: self._checkpoint(checkpoint_path))
              if checkpoint_path else None)
        self.loop.run(max_steps=max_steps, checkpoint_every=every,
                      checkpoint_cb=cb)
        return self.result()

    @classmethod
    def resume(cls, checkpoint_path: str, *,
               gnn_params: Optional[Dict] = None) -> "Campaign":
        """Load a checkpoint into a campaign primed to continue: call
        `.run(checkpoint_path=...)` to finish it. The continuation consumes
        the checkpointed rng stream, so the completed trace is bit-identical
        to an uninterrupted run of the same spec. An explicit `gnn_params`
        overrides the checkpointed pytree (e.g. to resume under retrained
        params — which forfeits the bit-identity guarantee)."""
        cfg, state, extra = ExplorationLoop.load_state(checkpoint_path)
        spec = CampaignSpec.from_dict(extra["spec"])
        if spec.loop_config() != cfg:
            raise ValueError(
                f"checkpoint {checkpoint_path} was written by a different "
                "loop configuration than its embedded spec resolves to")
        if gnn_params is None:
            gnn_params = extra.get("gnn_params")
        return cls(spec,
                   gnn_params=gnn_params,
                   _state=state,
                   _calibration_records=extra.get("calibration_records"),
                   _objective_stats=extra.get("objective_stats"))

    # -- reporting ---------------------------------------------------------

    def result(self) -> CampaignResult:
        tr = self.loop.state.trace
        wall = self.loop.state.wall_s
        stage_cache = {}
        for stage, sc in tr.stage_cache.items():
            n = sc.get("hits", 0) + sc.get("misses", 0)
            stage_cache[stage] = dict(
                sc, hit_rate=(sc.get("hits", 0) / n if n else 0.0))
        stats = {"f0": self.f0.stats()}
        if self.f1 is not None:
            stats["f1"] = self.f1.stats()
        calibration = []
        if self.calibrator is not None:
            calibration = [{
                "n_designs": r.n_designs, "n_graphs": r.n_graphs,
                "train_s": r.train_s,
                "val_kendall_tau": r.history.best_val_kendall_tau,
            } for r in self.calibrator.records]
        return CampaignResult(
            spec=self.spec, trace=tr, finished=self.loop.finished,
            wall_s=wall, n_evals=tr.n_evals,
            candidates_per_sec=tr.n_evals / max(wall, 1e-9),
            hv_final=tr.hv[-1] if tr.hv else 0.0,
            front=_front_records(self.spec, tr),
            stage_cache=stage_cache, objective_stats=stats,
            calibration=calibration)


def run_campaign(spec: CampaignSpec, **kw) -> CampaignResult:
    """One-shot convenience: `Campaign(spec).run(**kw)`."""
    return Campaign(spec).run(**kw)


__all__ = [
    "Campaign", "CampaignResult", "CampaignSpec", "FidelitySchedule",
    "HeteroSpec", "SCENARIOS", "ServingSpec", "TRACE_POLICIES",
    "TraceSpec", "resolve_strategy_space", "resolve_workload",
    "run_campaign",
]
