"""Campaign CLI: run / resume / validate declarative DSE campaigns.

    # run a campaign spec (writes campaign_<name>.result.json + checkpoint)
    python -m repro.explore examples/campaigns/quick_train_mfmobo.json

    # resume an interrupted run from its checkpoint
    python -m repro.explore --resume campaign_quick-train-mfmobo.ckpt.pkl

    # parse + validate shipped specs without running anything (CI)
    python -m repro.explore --validate examples/campaigns/*.json

    # run a campaign FLEET (grid of specs across worker processes)
    python -m repro.explore fleet examples/campaigns/fleet_quick_grid.json
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.explore.campaign import Campaign, CampaignSpec


def _default_paths(name: str, out: Optional[str], ckpt: Optional[str]):
    slug = name.replace(" ", "-")
    return (out or f"campaign_{slug}.result.json",
            ckpt or f"campaign_{slug}.ckpt.pkl")


def _summarize(result) -> None:
    spec = result.spec
    print(f"\n=== campaign {spec.name!r}: {spec.strategy} on "
          f"{spec.workload} [{spec.scenario}] ===")
    print(f"evaluations: {result.n_evals}  wall: {result.wall_s:.1f}s  "
          f"({result.candidates_per_sec:.2f} candidates/sec)  "
          f"finished: {result.finished}")
    print(f"hypervolume: {result.hv_final:.3f}  front: "
          f"{len(result.front)} nondominated designs")
    for stage, sc in sorted(result.stage_cache.items()):
        n = sc["hits"] + sc["misses"]
        if n:
            print(f"eval cache [{stage}]: {sc['hits']}/{n} hits "
                  f"({100 * sc['hit_rate']:.0f}%), "
                  f"{sc['entries_added']} entries added")
    for stage, st in sorted(result.objective_stats.items()):
        if st["n_constraint_violations"] or st["n_infeasible"]:
            print(f"objective [{stage}]: {st['n_infeasible']} infeasible, "
                  f"{st['n_constraint_violations']} constraint-violating "
                  "candidates mapped to the penalty point")
    y0 = spec.objectives[0].name
    for p in result.front[:5]:
        print(f"  front: {y0}={p[y0]:.1f}  "
              f"{spec.objectives[1].name}={p[spec.objectives[1].name]:.1f}  "
              f"{p['describe']}")


def _fleet_main(argv: List[str]) -> int:
    """`python -m repro.explore fleet grid.json [...]` — run a FleetSpec
    across worker processes (repro.explore.fleet, DESIGN.md §11)."""
    from repro.explore.fleet import FleetSpec, run_fleet
    ap = argparse.ArgumentParser(
        prog="python -m repro.explore fleet",
        description="Fan a grid of campaign specs across worker "
                    "processes sharing a persistent eval cache.")
    ap.add_argument("spec", help="fleet spec JSON path")
    ap.add_argument("--workers", type=int, default=None,
                    help="override the spec's worker count")
    ap.add_argument("--validate", action="store_true",
                    help="parse + validate the fleet spec, run nothing")
    ap.add_argument("--out", help="result JSON path "
                                  "(default fleet_<name>.result.json)")
    args = ap.parse_args(argv)
    import dataclasses as _dc
    fspec = FleetSpec.from_json(args.spec)
    if args.workers is not None:
        fspec = _dc.replace(fspec, workers=args.workers)
    if args.validate:
        fspec.validate()
        print(f"OK {args.spec}: fleet {fspec.name!r} — "
              f"{len(fspec.campaigns)} campaigns x {fspec.workers} workers")
        return 0
    res = run_fleet(fspec, verbose=True)
    out = args.out or f"fleet_{fspec.name.replace(' ', '-')}.result.json"
    res.save(out)
    done = sum(1 for c in res.campaigns if c)
    print(f"\n=== fleet {fspec.name!r}: {done}/{len(res.campaigns)} "
          f"campaigns on {fspec.workers} workers ===")
    print(f"evaluations: {res.n_evals}  wall: {res.wall_s:.1f}s  "
          f"({res.fleet_candidates_per_sec:.2f} candidates/sec)  "
          f"crashes: {res.crashes}")
    for err in res.errors:
        print(f"ERROR {err}")
    print(f"result -> {out}")
    return 1 if res.errors else 0


def main(argv: Optional[List[str]] = None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    if argv and argv[0] == "fleet":
        return _fleet_main(argv[1:])
    ap = argparse.ArgumentParser(
        prog="python -m repro.explore",
        description="Run, resume, or validate DSE campaign specs "
                    "(DESIGN.md §9).")
    ap.add_argument("spec", nargs="*", help="campaign spec JSON path(s)")
    ap.add_argument("--validate", action="store_true",
                    help="parse + validate the specs, run nothing")
    ap.add_argument("--resume", metavar="CKPT",
                    help="resume a checkpointed campaign instead of "
                         "starting from a spec")
    ap.add_argument("--out", help="result JSON path "
                                  "(default campaign_<name>.result.json)")
    ap.add_argument("--checkpoint",
                    help="checkpoint path (default "
                         "campaign_<name>.ckpt.pkl)")
    ap.add_argument("--checkpoint-every", type=int, default=None,
                    help="checkpoint every N loop steps "
                         "(default: the spec's checkpoint_every)")
    ap.add_argument("--max-steps", type=int, default=None,
                    help="stop after N loop steps (the checkpoint can be "
                         "resumed later)")
    args = ap.parse_args(argv)

    if args.validate:
        if not args.spec:
            ap.error("--validate needs at least one spec path")
        import json
        for path in args.spec:
            with open(path) as f:
                raw = json.load(f)
            if "campaigns" in raw or "grid" in raw:  # fleet-shaped spec
                from repro.explore.fleet import FleetSpec
                fspec = FleetSpec.from_json(path)
                fspec.validate()
                print(f"OK {path}: fleet {fspec.name!r} — "
                      f"{len(fspec.campaigns)} campaigns x "
                      f"{fspec.workers} workers")
                continue
            spec = CampaignSpec.from_json(path).validate()
            cfg = spec.loop_config()
            print(f"OK {path}: {spec.name!r} ({spec.strategy} on "
                  f"{spec.workload} [{spec.scenario}], "
                  f"{cfg.total_evals()} evals, q={spec.q})")
        return 0

    if args.resume:
        if args.spec:
            ap.error("--resume continues the checkpoint's embedded spec; "
                     "don't also pass a spec path")
        campaign = Campaign.resume(args.resume)
    elif len(args.spec) == 1:
        campaign = Campaign(CampaignSpec.from_json(args.spec[0]))
    else:
        ap.error("pass exactly one spec path (or --resume CKPT / "
                 "--validate SPEC...)")
        return 2
    out, ckpt = _default_paths(campaign.spec.name, args.out,
                               args.resume or args.checkpoint)
    result = campaign.run(checkpoint_path=ckpt,
                          checkpoint_every=args.checkpoint_every,
                          max_steps=args.max_steps)
    result.save(out)
    _summarize(result)
    print(f"\nresult  -> {out}\ncheckpoint -> {ckpt}"
          + ("" if result.finished else
             f"\n(unfinished: resume with --resume {ckpt})"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
