"""Campaign CLI: run / resume / validate declarative DSE campaigns.

    # run a campaign spec (writes campaign_<name>.result.json + checkpoint)
    python -m repro.explore examples/campaigns/quick_train_mfmobo.json

    # resume an interrupted run from its checkpoint
    python -m repro.explore --resume campaign_quick-train-mfmobo.ckpt.pkl

    # parse + validate shipped specs without running anything (CI)
    python -m repro.explore --validate examples/campaigns/*.json
"""
from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.explore.campaign import Campaign, CampaignSpec


def _default_paths(name: str, out: Optional[str], ckpt: Optional[str]):
    slug = name.replace(" ", "-")
    return (out or f"campaign_{slug}.result.json",
            ckpt or f"campaign_{slug}.ckpt.pkl")


def _summarize(result) -> None:
    spec = result.spec
    print(f"\n=== campaign {spec.name!r}: {spec.strategy} on "
          f"{spec.workload} [{spec.scenario}] ===")
    print(f"evaluations: {result.n_evals}  wall: {result.wall_s:.1f}s  "
          f"({result.candidates_per_sec:.2f} candidates/sec)  "
          f"finished: {result.finished}")
    print(f"hypervolume: {result.hv_final:.3f}  front: "
          f"{len(result.front)} nondominated designs")
    for stage, sc in sorted(result.stage_cache.items()):
        n = sc["hits"] + sc["misses"]
        if n:
            print(f"eval cache [{stage}]: {sc['hits']}/{n} hits "
                  f"({100 * sc['hit_rate']:.0f}%), "
                  f"{sc['entries_added']} entries added")
    for stage, st in sorted(result.objective_stats.items()):
        if st["n_constraint_violations"] or st["n_infeasible"]:
            print(f"objective [{stage}]: {st['n_infeasible']} infeasible, "
                  f"{st['n_constraint_violations']} constraint-violating "
                  "candidates mapped to the penalty point")
    y0 = spec.objectives[0].name
    for p in result.front[:5]:
        print(f"  front: {y0}={p[y0]:.1f}  "
              f"{spec.objectives[1].name}={p[spec.objectives[1].name]:.1f}  "
              f"{p['describe']}")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.explore",
        description="Run, resume, or validate DSE campaign specs "
                    "(DESIGN.md §9).")
    ap.add_argument("spec", nargs="*", help="campaign spec JSON path(s)")
    ap.add_argument("--validate", action="store_true",
                    help="parse + validate the specs, run nothing")
    ap.add_argument("--resume", metavar="CKPT",
                    help="resume a checkpointed campaign instead of "
                         "starting from a spec")
    ap.add_argument("--out", help="result JSON path "
                                  "(default campaign_<name>.result.json)")
    ap.add_argument("--checkpoint",
                    help="checkpoint path (default "
                         "campaign_<name>.ckpt.pkl)")
    ap.add_argument("--checkpoint-every", type=int, default=None,
                    help="checkpoint every N loop steps "
                         "(default: the spec's checkpoint_every)")
    ap.add_argument("--max-steps", type=int, default=None,
                    help="stop after N loop steps (the checkpoint can be "
                         "resumed later)")
    args = ap.parse_args(argv)

    if args.validate:
        if not args.spec:
            ap.error("--validate needs at least one spec path")
        for path in args.spec:
            spec = CampaignSpec.from_json(path).validate()
            cfg = spec.loop_config()
            print(f"OK {path}: {spec.name!r} ({spec.strategy} on "
                  f"{spec.workload} [{spec.scenario}], "
                  f"{cfg.total_evals()} evals, q={spec.q})")
        return 0

    if args.resume:
        if args.spec:
            ap.error("--resume continues the checkpoint's embedded spec; "
                     "don't also pass a spec path")
        campaign = Campaign.resume(args.resume)
    elif len(args.spec) == 1:
        campaign = Campaign(CampaignSpec.from_json(args.spec[0]))
    else:
        ap.error("pass exactly one spec path (or --resume CKPT / "
                 "--validate SPEC...)")
        return 2
    out, ckpt = _default_paths(campaign.spec.name, args.out,
                               args.resume or args.checkpoint)
    result = campaign.run(checkpoint_path=ckpt,
                          checkpoint_every=args.checkpoint_every,
                          max_steps=args.max_steps)
    result.save(out)
    _summarize(result)
    print(f"\nresult  -> {out}\ncheckpoint -> {ckpt}"
          + ("" if result.finished else
             f"\n(unfinished: resume with --resume {ckpt})"))
    return 0


if __name__ == "__main__":
    sys.exit(main())
