"""repro.explore — declarative, serializable, resumable DSE campaigns.

One entry point for the paper's exploration experiments (DESIGN.md §9):

    from repro.explore import Campaign, CampaignSpec
    spec = CampaignSpec.from_json("examples/campaigns/quick_train_mfmobo.json")
    result = Campaign(spec).run(checkpoint_path="run.ckpt")
    result = Campaign.resume("run.ckpt").run()        # continue a run

Fleets fan a grid of campaigns across worker processes sharing a
persistent eval cache (DESIGN.md §11):

    from repro.explore import FleetSpec, run_fleet
    result = run_fleet(FleetSpec.from_json("grid.json"))

CLI: ``python -m repro.explore <spec>.json [--resume CKPT]`` or
``python -m repro.explore fleet grid.json``.
"""
from repro.explore.campaign import (  # noqa: F401
    Campaign,
    CampaignResult,
    CampaignSpec,
    FidelitySchedule,
    HeteroSpec,
    SCENARIOS,
    ServingSpec,
    TRACE_POLICIES,
    TraceSpec,
    resolve_workload,
    run_campaign,
)
from repro.explore.objectives import (  # noqa: F401
    ConstraintSpec,
    EvaluatorObjective,
    HeteroServingObjective,
    Objective,
    ObjectiveSpec,
    ServingObjective,
    TraceServingObjective,
    as_objective,
)
from repro.explore.fleet import (  # noqa: F401
    FleetResult,
    FleetSpec,
    expand_grid,
    run_fleet,
)
from repro.explore.runner import (  # noqa: F401
    ExplorationLoop,
    LoopConfig,
    LoopState,
    PendingBatch,
    STRATEGIES,
)
