"""Export a DSE strategy as a runnable `repro.launch.train` configuration.

Joint campaigns (strategy_mode="joint") end with a Pareto front of
(architecture, Strategy) points. `export_train_config` closes the loop
from exploration back to the production launcher: it projects a winning
`Strategy` onto the train CLI surface (`--data` = dp, `--model` = tp,
`--microbatches`), records the full strategy (pp/ep/recompute/schedule —
axes the single-pod launcher does not expose yet) alongside, and
round-trips through JSON.

`validate_train_config` is the acceptance gate: the argv must parse
against the real launcher surface (built by `train_argv`), the mesh must
be shardable by the `repro.dist` rule engine (`oracle.check_strategy`:
`param_specs`/`batch_specs` instantiable on a ("data", "model") =
(dp, tp) shim mesh for the arch's actual parameter shapes), and the
batch/microbatch arithmetic must divide. A config that validates runs
under `repro.launch.train.main(train_argv(cfg))` on a matching device
topology (CPU smoke: dp = tp = 1, `reduced=True`).
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple

from repro.configs import ARCH_IDS

EXPORT_VERSION = 1


def _strategy_of(point):
    return point.strategy if hasattr(point, "strategy") else point


def export_train_config(point, arch_id: str, *, steps: int = 300,
                        batch: Optional[int] = None,
                        seq: Optional[int] = None,
                        reduced: bool = False,
                        path: Optional[str] = None) -> Dict:
    """Map a `JointDesign` (or bare `Strategy`) onto the train launcher's
    configuration surface. `batch`/`seq` default to the launcher's own
    defaults when not given. Writes JSON to `path` when provided."""
    if arch_id not in ARCH_IDS:
        raise ValueError(f"unknown arch {arch_id!r}; known: "
                         f"{sorted(ARCH_IDS)}")
    s = _strategy_of(point)
    cfg = {
        "version": EXPORT_VERSION,
        "arch": arch_id,
        "reduced": bool(reduced),
        "steps": int(steps),
        "batch": int(batch) if batch is not None else 8,
        "seq": int(seq) if seq is not None else 256,
        # the runnable projection: the single-pod launcher exposes
        # (data, model, microbatches)
        "data": int(s.dp),
        "model": int(s.tp),
        "microbatches": int(s.microbatches),
        # the full strategy of record — pp/ep/recompute/schedule have no
        # launcher axis yet but stay attached to the artifact
        "strategy": {
            "tp": int(s.tp), "pp": int(s.pp), "dp": int(s.dp),
            "ep": int(s.ep), "microbatches": int(s.microbatches),
            "recompute": bool(s.recompute), "schedule": str(s.schedule),
        },
    }
    if path:
        with open(path, "w") as f:
            json.dump(cfg, f, indent=1)
            f.write("\n")
    return cfg


def train_argv(cfg: Dict) -> List[str]:
    """The exact `repro.launch.train` argv a config maps to."""
    argv = [
        "--arch", str(cfg["arch"]),
        "--steps", str(int(cfg["steps"])),
        "--batch", str(int(cfg["batch"])),
        "--seq", str(int(cfg["seq"])),
        "--data", str(int(cfg["data"])),
        "--model", str(int(cfg["model"])),
        "--microbatches", str(int(cfg["microbatches"])),
    ]
    if cfg.get("reduced"):
        argv.append("--reduced")
    return argv


def load_train_config(path_or_str: str) -> Dict:
    if path_or_str.lstrip().startswith("{"):
        cfg = json.loads(path_or_str)
    else:
        with open(path_or_str) as f:
            cfg = json.load(f)
    v = cfg.get("version", EXPORT_VERSION)
    if v != EXPORT_VERSION:
        raise ValueError(f"train-config version {v!r} unsupported (this "
                         f"build reads version {EXPORT_VERSION})")
    return cfg


def validate_train_config(cfg: Dict, reduced: Optional[bool] = None
                          ) -> Tuple[bool, str]:
    """Acceptance gate for an exported config: (ok, reason).

    Checks, in order: the arch resolves; the batch arithmetic divides
    (dp | batch, microbatches | per-dp examples); and the `repro.dist`
    rule engine can instantiate `param_specs`/`batch_specs` for the
    arch's real parameter shapes on the (dp, tp) mesh
    (`oracle.check_strategy` — reasons come back "dist_<verdict>").
    `reduced` overrides the config's flag (validate the CI-sized variant
    of a full-size export without re-exporting)."""
    from repro.configs import get_config, reduced_config
    from repro.dist import oracle

    arch = cfg.get("arch")
    if arch not in ARCH_IDS:
        return False, "unknown_arch"
    dp, tp, mb = int(cfg["data"]), int(cfg["model"]), int(cfg["microbatches"])
    batch, seq = int(cfg["batch"]), int(cfg["seq"])
    if min(dp, tp, mb, batch, seq, int(cfg["steps"])) < 1:
        return False, "non_positive_axis"
    if batch % dp:
        return False, "dp_batch_divide"
    if (batch // dp) % mb:
        return False, "microbatch_divide"
    use_reduced = cfg.get("reduced", False) if reduced is None else reduced
    mcfg = reduced_config(arch) if use_reduced else get_config(arch)
    ep = int(cfg.get("strategy", {}).get("ep", 1))
    ok, why = oracle.check_strategy(mcfg, tp, dp, ep, batch=batch, seq=seq)
    if not ok:
        return False, f"dist_{why}"
    return True, ""


__all__ = ["EXPORT_VERSION", "export_train_config", "load_train_config",
           "train_argv", "validate_train_config"]
