"""Campaign fleets — parallel workers over a campaign grid (DESIGN.md §11).

Theseus-style studies are *grids* of campaigns (fig8 is method×seed; WATOS
co-exploration multiplies that further), and PR 6 left the grid itself
serial: every campaign paid a cold process (imports, XLA compiles) and a
cold eval cache. `FleetSpec` names a grid of `CampaignSpec`s plus the
execution substrate, and `run_fleet` fans it across spawned worker
processes that share:

    - the persistent eval cache (`DiskSegmentEvalCache` on `cache_dir`,
      wired via `repro.core.evaluator.configure_eval_cache`) — concurrent
      workers and successive campaigns reuse each other's evaluations;
    - the JAX persistent compilation cache (`compile_cache_dir`) — one
      worker's XLA compiles warm every later worker's cold start;
    - per-process memoized `warm_optimizer_kernels` — each worker warms
      each shape bucket at most once across all its campaigns.

Workers are plain `multiprocessing` *spawn* processes (fork would deadlock
JAX's threads) driven over pipes: the scheduler sends one campaign at a
time and requeues the in-flight campaign of any worker that dies, so a
crashed/preempted worker costs at most the work since the campaign's last
checkpoint — workers always try `Campaign.resume` from the fleet's
checkpoint directory before starting fresh. `host_devices > 1` exposes
`--xla_force_host_platform_device_count` lanes to the workers (DESIGN.md
§10's XLA host-lanes note).

CLI: ``python -m repro.explore fleet grid.json [--workers N] [--out F]``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from collections import deque
from typing import Dict, List, Mapping, Optional, Tuple

from repro.explore.campaign import Campaign, CampaignSpec

FLEET_SPEC_VERSION = 1

# test hook: "<campaign-name>:<marker-path>" makes the worker that picks up
# that campaign checkpoint two steps and die hard (os._exit) — once, gated
# on the marker file — so tests can exercise the scheduler's crash-requeue
# + checkpoint-resume path with a real dead process.
_CRASH_ENV = "REPRO_FLEET_TEST_CRASH"


# ---------------------------------------------------------------------------
# spec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """A named grid of campaigns plus the execution substrate. Campaign
    names must be unique — they key the per-campaign checkpoint files the
    crash-resume path depends on."""
    name: str
    campaigns: Tuple[CampaignSpec, ...]
    workers: int = 2
    cache_dir: Optional[str] = None          # shared persistent eval cache
    compile_cache_dir: Optional[str] = None  # shared XLA compilation cache
    checkpoint_dir: Optional[str] = None     # per-campaign ckpts (resume)
    checkpoint_every: int = 2                # steps between worker ckpts
    host_devices: int = 1                    # XLA host-platform lanes
    warm_n_obs: int = 0                      # 0 = skip kernel pre-warm
    max_cache_entries: int = 100_000

    def validate(self) -> "FleetSpec":
        if not self.campaigns:
            raise ValueError("fleet has no campaigns")
        if self.workers < 1:
            raise ValueError("workers must be >= 1")
        if self.host_devices < 1:
            raise ValueError("host_devices must be >= 1")
        names = [c.name for c in self.campaigns]
        dupes = sorted({n for n in names if names.count(n) > 1})
        if dupes:
            raise ValueError(
                f"campaign names must be unique within a fleet (they key "
                f"checkpoint files); duplicated: {dupes}")
        for c in self.campaigns:
            c.validate()
        return self

    # -- serialization -----------------------------------------------------

    def to_dict(self) -> Dict:
        d = {"version": FLEET_SPEC_VERSION, "name": self.name,
             "workers": self.workers, "checkpoint_every":
             self.checkpoint_every, "host_devices": self.host_devices,
             "warm_n_obs": self.warm_n_obs,
             "max_cache_entries": self.max_cache_entries,
             "campaigns": [c.to_dict() for c in self.campaigns]}
        for k in ("cache_dir", "compile_cache_dir", "checkpoint_dir"):
            if getattr(self, k) is not None:
                d[k] = getattr(self, k)
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "FleetSpec":
        d = dict(d)
        v = d.pop("version", FLEET_SPEC_VERSION)
        if v != FLEET_SPEC_VERSION:
            raise ValueError(f"fleet spec version {v!r} unsupported (this "
                             f"build reads version {FLEET_SPEC_VERSION})")
        grid = d.pop("grid", None)
        campaigns = [CampaignSpec.from_dict(c)
                     for c in d.pop("campaigns", [])]
        if grid is not None:
            campaigns.extend(expand_grid(grid))
        unknown = set(d) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise ValueError(f"unknown fleet spec fields: {sorted(unknown)}")
        return cls(campaigns=tuple(campaigns), **d)

    def to_json(self, path: Optional[str] = None, indent: int = 1) -> str:
        s = json.dumps(self.to_dict(), indent=indent)
        if path:
            with open(path, "w") as f:
                f.write(s + "\n")
        return s

    @classmethod
    def from_json(cls, path_or_str: str) -> "FleetSpec":
        if path_or_str.lstrip().startswith("{"):
            return cls.from_dict(json.loads(path_or_str))
        with open(path_or_str) as f:
            return cls.from_dict(json.load(f))


def expand_grid(grid: Mapping) -> List[CampaignSpec]:
    """Expand `{"base": <partial spec>, "strategies": [...], "seeds":
    [...], "workloads": [...]}` into the method×seed×workload product of
    CampaignSpecs. Each axis defaults to the base spec's own value; names
    are `<base-name>-<workload>-<strategy>-s<seed>`."""
    g = dict(grid)
    base = dict(g.pop("base"))
    base.setdefault("name", "grid")
    base_name = base["name"]
    strategies = g.pop("strategies", [base.get("strategy", "mfmobo")])
    seeds = g.pop("seeds", [base.get("seed", 0)])
    workloads = g.pop("workloads", [base["workload"]])
    if g:
        raise ValueError(f"unknown grid fields: {sorted(g)} (expected "
                         "base / strategies / seeds / workloads)")
    out = []
    for wl in workloads:
        for strat in strategies:
            for seed in seeds:
                d = dict(base, workload=wl, strategy=strat, seed=seed,
                         name=f"{base_name}-{wl}-{strat}-s{seed}")
                out.append(CampaignSpec.from_dict(d))
    return out


# ---------------------------------------------------------------------------
# worker
# ---------------------------------------------------------------------------


def _worker_setup(cfg: Dict) -> None:
    """Per-process substrate: XLA host lanes, shared eval cache, shared
    XLA compilation cache. Runs once, before the first campaign."""
    lanes = int(cfg.get("host_devices") or 1)
    if lanes > 1:
        # must land before the worker's first jax import — spawn workers
        # import jax lazily, and this runs ahead of every jax touchpoint
        flags = os.environ.get("XLA_FLAGS", "")
        want = f"--xla_force_host_platform_device_count={lanes}"
        if want not in flags:
            os.environ["XLA_FLAGS"] = (flags + " " + want).strip()
    if cfg.get("compile_cache_dir"):
        import jax
        jax.config.update("jax_compilation_cache_dir",
                          cfg["compile_cache_dir"])
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    if cfg.get("cache_dir"):
        from repro.core.evaluator import configure_eval_cache
        configure_eval_cache(cache_dir=cfg["cache_dir"],
                             max_entries=cfg.get("max_cache_entries",
                                                 100_000))


def _campaign_ckpt(cfg: Dict, spec: CampaignSpec) -> Optional[str]:
    ckdir = cfg.get("checkpoint_dir")
    if not ckdir:
        return None
    os.makedirs(ckdir, exist_ok=True)
    slug = spec.name.replace(os.sep, "_").replace(" ", "-")
    return os.path.join(ckdir, f"{slug}.ckpt.pkl")


def _maybe_test_crash(cfg: Dict, spec: CampaignSpec, ck: Optional[str]):
    hook = os.environ.get(_CRASH_ENV, "")
    if not hook or ":" not in hook:
        return
    name, marker = hook.split(":", 1)
    if spec.name != name or os.path.exists(marker):
        return
    with open(marker, "w") as f:
        f.write(spec.name)
    Campaign(spec).run(checkpoint_path=ck, checkpoint_every=1, max_steps=2)
    os._exit(17)                     # die hard: no atexit, no cleanup


def _run_one(cfg: Dict, spec_dict: Dict) -> Dict:
    from repro.core import eval_compiled
    from repro.core.evaluator import eval_cache_stats
    from repro.core.mfmobo import warm_optimizer_kernels

    spec = CampaignSpec.from_dict(spec_dict)
    warm_s = 0.0
    if cfg.get("warm_n_obs"):
        from repro.explore.campaign import resolve_workload
        try:
            wl = resolve_workload(spec)
        except Exception:
            wl = None                # synthetic objective: no evaluator
        t0 = time.time()
        # memoized per process: only the first campaign compiles anything
        # (evaluator programs included, via `workload=`)
        warm_optimizer_kernels(cfg["warm_n_obs"],
                               n_candidates=spec.n_candidates, q=spec.q,
                               workload=wl,
                               n_designs_max=cfg["warm_n_obs"],
                               max_strategies=spec.max_strategies)
        warm_s = time.time() - t0
    lanes0 = eval_compiled.lane_stats()
    ck = _campaign_ckpt(cfg, spec)
    _maybe_test_crash(cfg, spec, ck)
    campaign = None
    if ck and os.path.exists(ck):
        try:
            campaign = Campaign.resume(ck)
        except Exception:
            campaign = None          # unreadable checkpoint: start fresh
    resumed = campaign is not None
    if campaign is None:
        campaign = Campaign(spec)
    result = campaign.run(checkpoint_path=ck,
                          checkpoint_every=cfg.get("checkpoint_every", 2))
    out = result.to_dict()
    out["resumed"] = resumed
    out["warm_s"] = warm_s
    out["eval_cache"] = dict(eval_cache_stats())
    # lane counters are process-global; report this campaign's delta so
    # fleet aggregation over campaigns doesn't double-count
    lanes1 = eval_compiled.lane_stats()
    out["eval_lanes"] = {
        k: (lanes1[k] if k == "n_lanes" else lanes1[k] - lanes0.get(k, 0))
        for k in lanes1}
    return out


def _fleet_worker(worker_id: int, cfg: Dict, conn) -> None:
    """Worker loop: receive (idx, spec_dict) tasks over the pipe, run each
    campaign (resuming its checkpoint if one exists), send (idx, result)
    back. A `None` task shuts the worker down."""
    _worker_setup(cfg)
    while True:
        task = conn.recv()
        if task is None:
            conn.close()
            return
        idx, spec_dict = task
        try:
            conn.send((idx, _run_one(cfg, spec_dict), None))
        except Exception as e:       # surface, don't kill the worker
            conn.send((idx, None, f"{type(e).__name__}: {e}"))


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetResult:
    spec: FleetSpec
    campaigns: List[Dict]            # per-campaign result dicts (spec order)
    wall_s: float
    n_evals: int
    fleet_candidates_per_sec: float
    crashes: int
    errors: List[str]

    def to_dict(self) -> Dict:
        return {"spec": self.spec.to_dict(), "campaigns": self.campaigns,
                "wall_s": self.wall_s, "n_evals": self.n_evals,
                "fleet_candidates_per_sec": self.fleet_candidates_per_sec,
                "workers": self.spec.workers, "crashes": self.crashes,
                "errors": self.errors}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1, default=float)
        return path


class _Worker:
    """Scheduler-side handle: the spawned process, its pipe end, and the
    index of the campaign it is currently running (None = idle)."""

    def __init__(self, ctx, worker_id: int, cfg: Dict):
        self.id = worker_id
        self.conn, child = ctx.Pipe()
        self.proc = ctx.Process(target=_fleet_worker,
                                args=(worker_id, cfg, child), daemon=True)
        self.proc.start()
        child.close()                # parent keeps only its own end
        self.current: Optional[int] = None

    def stop(self):
        try:
            if self.current is None and self.proc.is_alive():
                self.conn.send(None)
        except (BrokenPipeError, OSError):
            pass
        self.proc.join(timeout=10)
        if self.proc.is_alive():
            self.proc.terminate()
            self.proc.join(timeout=5)
        self.conn.close()


def run_fleet(spec: FleetSpec, *, verbose: bool = False) -> FleetResult:
    """Execute every campaign in the fleet across `spec.workers` spawned
    processes. Campaigns are handed out one at a time; a worker death
    requeues its in-flight campaign (resumed from its last checkpoint by
    the replacement worker). Returns per-campaign results in spec order
    plus fleet-level throughput."""
    import multiprocessing as mp

    spec.validate()
    ctx = mp.get_context("spawn")    # fork would deadlock JAX's threadpool
    cfg = {"cache_dir": spec.cache_dir,
           "compile_cache_dir": spec.compile_cache_dir,
           "checkpoint_dir": spec.checkpoint_dir,
           "checkpoint_every": spec.checkpoint_every,
           "warm_n_obs": spec.warm_n_obs,
           "host_devices": spec.host_devices,
           "max_cache_entries": spec.max_cache_entries}
    for k in ("cache_dir", "compile_cache_dir", "checkpoint_dir"):
        if cfg[k]:
            os.makedirs(cfg[k], exist_ok=True)

    # host lanes are configured inside each worker (`_worker_setup`), in
    # the spawned child before its first jax import — the parent env is
    # never mutated (DESIGN.md §10 host lanes)
    t0 = time.time()
    n_workers = min(spec.workers, len(spec.campaigns))
    pending = deque(range(len(spec.campaigns)))
    results: Dict[int, Optional[Dict]] = {}
    errors: List[str] = []
    crashes = 0
    # a worker that dies at startup would otherwise respawn forever; a few
    # deaths per campaign is the honest preemption budget
    max_crashes = 3 * len(spec.campaigns) + n_workers
    workers: List[_Worker] = []
    try:
        workers = [_Worker(ctx, w, cfg) for w in range(n_workers)]
        while len(results) < len(spec.campaigns):
            for w in workers:
                if w.current is None and pending:
                    idx = pending.popleft()
                    try:
                        w.conn.send((idx, spec.campaigns[idx].to_dict()))
                        w.current = idx
                    except (BrokenPipeError, OSError):
                        pending.appendleft(idx)
            progressed = False
            for i, w in enumerate(workers):
                crashed = False
                try:
                    ready = w.conn.poll(0.05)
                except (BrokenPipeError, OSError):
                    ready = False
                    crashed = not w.proc.is_alive()
                if ready:
                    try:
                        idx, res, err = w.conn.recv()
                    except (EOFError, OSError):
                        # a dead child leaves the pipe permanently "ready"
                        # at EOF — this IS the crash signal, handle it now
                        # (skipping it would poll-EOF-spin forever)
                        crashed = True
                    else:
                        w.current = None
                        results[idx] = res
                        if err is not None:
                            errors.append(
                                f"{spec.campaigns[idx].name}: {err}")
                        if verbose:
                            name = spec.campaigns[idx].name
                            print(f"[fleet] worker {w.id} finished "
                                  f"{name!r} ({len(results)}/"
                                  f"{len(spec.campaigns)})"
                                  + (f" ERROR {err}" if err else ""))
                        progressed = True
                elif not w.proc.is_alive():
                    crashed = True
                if crashed:
                    # crashed/preempted: requeue its campaign (the fresh
                    # worker resumes from the campaign's last checkpoint)
                    crashes += 1
                    if crashes > max_crashes:
                        raise RuntimeError(
                            f"fleet workers died {crashes} times (last "
                            f"exit code {w.proc.exitcode}); giving up — "
                            "the campaign grid or environment is broken")
                    if w.current is not None:
                        pending.appendleft(w.current)
                    if verbose:
                        print(f"[fleet] worker {w.id} died "
                              f"(exit {w.proc.exitcode}); respawning")
                    w.proc.join(timeout=5)     # reap the zombie
                    w.conn.close()
                    workers[i] = _Worker(ctx, w.id, cfg)
                    progressed = True
            if not progressed:
                time.sleep(0.01)
    finally:
        for w in workers:
            w.stop()

    wall = time.time() - t0
    ordered = [results.get(i) for i in range(len(spec.campaigns))]
    n_evals = sum(r["n_evals"] for r in ordered if r)
    return FleetResult(
        spec=spec, campaigns=ordered, wall_s=wall, n_evals=n_evals,
        fleet_candidates_per_sec=n_evals / max(wall, 1e-9),
        crashes=crashes, errors=errors)


__all__ = ["FLEET_SPEC_VERSION", "FleetResult", "FleetSpec", "expand_grid",
           "run_fleet"]
