"""Objectives protocol for DSE campaigns (DESIGN.md §9).

A campaign's objective pair and constraint set are *data* — `ObjectiveSpec`
(metric name, direction, GP/HV-space transform) and `ConstraintSpec`
(metric, op, bound) serialize with the rest of a `CampaignSpec` — and the
`Objective` classes here are the batch-aware adapters that turn those specs
into the callable the exploration loop evaluates. They subsume the old
free-function objective builders (`evaluator.batched_objectives`,
`serving.serving_objectives`, `GNNCalibrator.objectives()`), which are now
thin constructors delegating here.

The exploration loop (repro.explore.runner) operates on the `Objective`
protocol only: `eval_many(designs) -> [(y0, y1), ...]`. Legacy callables —
scalar ``f(design) -> (t, p)`` functions and ``.batched``-marked batch
functions — are coerced at the boundary by `as_objective`; the attribute
sniffing that used to live in `mfmobo._eval_many` is retired to that single
compat shim. Every `Objective` still *exposes* ``batched = True`` so older
external sniffers keep working.

Constraint semantics: a candidate whose metrics violate any constraint (or
whose evaluation is infeasible) maps to the penalty point — by default
``(0.0, WAFER_POWER_W)``, the same infeasibility point the evaluators
always used — so it can never enter the Pareto front, while still being
recorded in the trace. Violation/infeasibility counts are tracked on the
objective for campaign reporting.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import components as C
from repro.core.design_space import WSCDesign
from repro.core.fidelity import FidelityBackend, get_backend
from repro.core.workload import LLMWorkload

DIRECTIONS = ("max", "min")
# GP/HV-space transforms the trace operates in (mfmobo.obj_space): the
# maximized objective is log1p-compressed, the minimized one is -log
# (paper: log throughput vs -log power). "identity" is accepted for
# synthetic objectives already living in max-space.
TRANSFORMS = ("log1p", "neg_log", "identity")


@dataclasses.dataclass(frozen=True)
class ObjectiveSpec:
    """One objective: which metric, which direction, which HV-space
    transform. A campaign's pair is conventionally (max, min) — throughput
    vs power, goodput vs power — matching the paper's hypervolume setup."""
    name: str
    direction: str = "max"
    transform: str = "log1p"

    def __post_init__(self):
        if self.direction not in DIRECTIONS:
            raise ValueError(f"objective direction {self.direction!r} "
                             f"not in {DIRECTIONS}")
        if self.transform not in TRANSFORMS:
            raise ValueError(f"objective transform {self.transform!r} "
                             f"not in {TRANSFORMS}")

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Union[Dict, Sequence]) -> "ObjectiveSpec":
        if isinstance(d, (list, tuple)):              # ["throughput", "max"]
            return cls(*d)
        return cls(**d)


_OPS: Dict[str, Callable[[float, float], bool]] = {
    "<=": lambda v, b: v <= b,
    ">=": lambda v, b: v >= b,
}


@dataclasses.dataclass(frozen=True)
class ConstraintSpec:
    """A hard constraint on an evaluation metric: SLO bound, power cap,
    area budget. Violating candidates are mapped to the penalty point so
    they are excluded from the Pareto front."""
    metric: str
    op: str
    bound: float

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"constraint op {self.op!r} not in "
                             f"{tuple(_OPS)}")

    def ok(self, metrics: Dict[str, float]) -> bool:
        v = metrics.get(self.metric)
        if v is None:
            raise KeyError(
                f"constraint metric {self.metric!r} not produced by this "
                f"objective; available: {sorted(metrics)}")
        return bool(_OPS[self.op](float(v), float(self.bound)))

    def describe(self) -> str:
        return f"{self.metric} {self.op} {self.bound:g}"

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Union[Dict, Sequence]) -> "ConstraintSpec":
        if isinstance(d, (list, tuple)):              # ["ttft", "<=", 5.0]
            m, op, b = d
            return cls(str(m), str(op), float(b))
        return cls(**d)


def default_objectives(scenario: str) -> Tuple[ObjectiveSpec, ObjectiveSpec]:
    if scenario == "trace_serving":
        # spike robustness: worst load window's interactive-tenant goodput
        y0 = "worst_window_goodput"
    elif scenario in ("serving", "hetero"):
        y0 = "goodput"
    else:
        y0 = "throughput"
    return (ObjectiveSpec(y0, "max", "log1p"),
            ObjectiveSpec("power_per_wafer", "min", "neg_log"))


# ---------------------------------------------------------------------------
# the Objective protocol + adapters
# ---------------------------------------------------------------------------


PENALTY: Tuple[float, float] = (0.0, C.WAFER_POWER_W)


class Objective:
    """Batch-aware campaign objective. Subclasses implement
    `metrics(designs) -> List[Dict[str, float]]`; this base maps metric
    dicts to the (y0, y1) pairs the exploration loop consumes, applying
    constraints and the infeasibility penalty, and keeps running counters
    for campaign reporting."""

    batched = True            # legacy marker (pre-protocol sniffers)
    fidelity: Optional[str] = None

    def __init__(self, objectives: Optional[Sequence[ObjectiveSpec]] = None,
                 constraints: Sequence[ConstraintSpec] = (),
                 penalty: Tuple[float, float] = PENALTY,
                 scenario: str = "train"):
        specs = tuple(objectives) if objectives else \
            default_objectives(scenario)
        if len(specs) != 2:
            raise ValueError("exactly two objectives required "
                             f"(got {len(specs)})")
        if (specs[0].direction, specs[1].direction) != ("max", "min"):
            raise ValueError(
                "objective pair must be (max, min) — e.g. maximize "
                "throughput/goodput against minimized power (got "
                f"{specs[0].direction}, {specs[1].direction})")
        self.specs = specs
        self.constraints = tuple(constraints)
        self.penalty = (float(penalty[0]), float(penalty[1]))
        self.n_calls = 0
        self.n_evals = 0
        self.n_infeasible = 0
        self.n_violations = 0

    # -- subclass surface --------------------------------------------------

    def metrics(self, designs: List[WSCDesign]) -> List[Dict[str, float]]:
        raise NotImplementedError

    # -- protocol ----------------------------------------------------------

    def eval_many(self, designs: Sequence[WSCDesign]
                  ) -> List[Tuple[float, float]]:
        return self.fold_metrics(self.metrics(list(designs)))

    def fold_metrics(self, metrics: Sequence[Dict[str, float]]
                     ) -> List[Tuple[float, float]]:
        """Map metric dicts to (y0, y1) pairs — constraints, penalty,
        counters. Shared by `eval_many` and the fused evaluation path
        (which produces metric dicts from compiled-evaluator results
        without going through `metrics()`)."""
        out: List[Tuple[float, float]] = []
        for m in metrics:
            feasible = bool(m.get("feasible", True))
            if not feasible:
                self.n_infeasible += 1
                out.append(self.penalty)
                continue
            if not all(c.ok(m) for c in self.constraints):
                self.n_violations += 1
                out.append(self.penalty)
                continue
            y = (float(m[self.specs[0].name]), float(m[self.specs[1].name]))
            if not (math.isfinite(y[0]) and math.isfinite(y[1])):
                self.n_infeasible += 1
                y = self.penalty
            out.append(y)
        self.n_calls += 1
        self.n_evals += len(out)
        return out

    def __call__(self, designs):
        """Legacy calling convention: a single design returns one pair, a
        sequence returns a list of pairs."""
        if isinstance(designs, WSCDesign):
            return self.eval_many([designs])[0]
        return self.eval_many(list(designs))

    def stats(self) -> Dict[str, int]:
        return {"n_calls": self.n_calls, "n_evals": self.n_evals,
                "n_infeasible": self.n_infeasible,
                "n_constraint_violations": self.n_violations}

    def load_stats(self, d: Dict[str, int]) -> None:
        """Restore counters from a checkpoint (campaign resume), so a
        resumed run reports the same cumulative stats as an uninterrupted
        one."""
        self.n_calls = int(d.get("n_calls", 0))
        self.n_evals = int(d.get("n_evals", 0))
        self.n_infeasible = int(d.get("n_infeasible", 0))
        self.n_violations = int(d.get("n_constraint_violations", 0))


class EvaluatorObjective(Objective):
    """Train / inference objective: registry-batched `evaluate_design_batch`
    over the candidate set. Subsumes `evaluator.batched_objectives` and —
    with `params_fn` reading live parameters at call time —
    `GNNCalibrator.objectives()`."""

    def __init__(self, wl: LLMWorkload,
                 fidelity: Union[str, FidelityBackend] = "analytical",
                 gnn_params: Optional[Dict] = None,
                 params_fn: Optional[Callable[[], Optional[Dict]]] = None,
                 objectives: Optional[Sequence[ObjectiveSpec]] = None,
                 constraints: Sequence[ConstraintSpec] = (),
                 max_strategies: int = 24,
                 n_wafers: Optional[int] = None,
                 penalty: Tuple[float, float] = PENALTY,
                 strategy_mode: str = "grid"):
        super().__init__(objectives, constraints, penalty, scenario="train")
        if strategy_mode not in ("grid", "joint"):
            raise ValueError(f"strategy_mode {strategy_mode!r} not in "
                             "('grid', 'joint')")
        self.wl = wl
        self.backend = get_backend(fidelity)
        self.fidelity = self.backend.name
        self._gnn_params = gnn_params
        self._params_fn = params_fn
        self.max_strategies = max_strategies
        self.n_wafers = n_wafers
        self.strategy_mode = strategy_mode

    def gnn_params(self) -> Optional[Dict]:
        return self._params_fn() if self._params_fn else self._gnn_params

    def metrics(self, designs: List[WSCDesign]) -> List[Dict[str, float]]:
        # joint mode: `designs` are JointDesign points — each is scored
        # under its pinned Strategy, no per-design grid argmin
        if self.strategy_mode == "joint":
            from repro.core.evaluator import evaluate_joint_batch
            rs = evaluate_joint_batch(
                designs, self.wl, fidelity=self.backend,
                gnn_params=self.gnn_params(), n_wafers=self.n_wafers,
                max_strategies=self.max_strategies)
            return self.metrics_from_results(rs)
        from repro.core.evaluator import evaluate_design_batch
        rs = evaluate_design_batch(
            designs, self.wl, fidelity=self.backend,
            gnn_params=self.gnn_params(), n_wafers=self.n_wafers,
            max_strategies=self.max_strategies)
        return self.metrics_from_results(rs)

    @staticmethod
    def metrics_from_results(rs) -> List[Dict[str, float]]:
        return [{
            "throughput": r.throughput,
            "power": r.power_w,
            "power_per_wafer": r.power_w / max(r.n_wafers, 1),
            "n_wafers": float(r.n_wafers),
            "feasible": r.feasible,
        } for r in rs]

    # -- fused analytical iteration (DESIGN.md §12) ------------------------

    def supports_fused(self) -> bool:
        """True when this objective can consume device-resident pick
        indices through the compiled analytical evaluator: analytical
        fidelity, no per-design wafer override semantics beyond what the
        fused path reproduces, and the compiled pipeline enabled."""
        from repro.core import eval_compiled
        return self.backend.name == "analytical" and eval_compiled.enabled()

    def eval_many_fused(self, pool_designs: Sequence[WSCDesign], js_dev,
                        q_eff: int
                        ) -> Tuple[List[int], List[Tuple[float, float]]]:
        """Evaluate the pool rows named by the device index vector
        `js_dev` (the compiled acquire scan's output) through the fused
        gather+evaluate program; returns (pick indices, folded ys) —
        bit-identical to `eval_many([pool_designs[j] for j in js])`."""
        if self.strategy_mode == "joint":
            from repro.core.evaluator import evaluate_pool_fused_joint
            js, rs = evaluate_pool_fused_joint(
                list(pool_designs), self.wl, js_dev, q_eff,
                gnn_params=self.gnn_params(), n_wafers=self.n_wafers,
                max_strategies=self.max_strategies)
            return js, self.fold_metrics(self.metrics_from_results(rs))
        from repro.core.evaluator import evaluate_pool_fused
        js, rs = evaluate_pool_fused(
            list(pool_designs), self.wl, js_dev, q_eff,
            gnn_params=self.gnn_params(), n_wafers=self.n_wafers,
            max_strategies=self.max_strategies)
        return js, self.fold_metrics(self.metrics_from_results(rs))


class ServingObjective(Objective):
    """Serving objective: request-level continuous-batching metrics (TTFT /
    TPOT / SLO goodput, DESIGN.md §8) through `evaluate_serving_batch`.
    Subsumes `serving.serving_objectives`; SLO constraints (`ttft`, `tpot`,
    `slo_attainment`) compose naturally."""

    def __init__(self, wl: LLMWorkload, mix, slo, *, slots: int = 8,
                 fidelity: Union[str, FidelityBackend] = "analytical",
                 gnn_params: Optional[Dict] = None,
                 params_fn: Optional[Callable[[], Optional[Dict]]] = None,
                 objectives: Optional[Sequence[ObjectiveSpec]] = None,
                 constraints: Sequence[ConstraintSpec] = (),
                 max_strategies: int = 24,
                 penalty: Tuple[float, float] = PENALTY):
        super().__init__(objectives, constraints, penalty,
                         scenario="serving")
        self.wl = wl
        self.mix = mix
        self.slo = slo
        self.slots = slots
        self.backend = get_backend(fidelity)
        self.fidelity = self.backend.name
        self._gnn_params = gnn_params
        self._params_fn = params_fn
        self.max_strategies = max_strategies

    def gnn_params(self) -> Optional[Dict]:
        return self._params_fn() if self._params_fn else self._gnn_params

    def metrics(self, designs: List[WSCDesign]) -> List[Dict[str, float]]:
        from repro.core.serving import evaluate_serving_batch
        rs = evaluate_serving_batch(
            designs, self.wl, self.mix, self.slo, slots=self.slots,
            fidelity=self.backend, gnn_params=self.gnn_params(),
            max_strategies=self.max_strategies)
        return [{
            "goodput": r.goodput_tok_s,
            "throughput": r.throughput_tok_s,
            "ttft": r.ttft_s, "ttft_max": r.ttft_max_s,
            "tpot": r.tpot_s, "tpot_max": r.tpot_max_s,
            "slo_attainment": r.slo_attainment,
            "power": r.power_w,
            "power_per_wafer": r.power_w / max(r.n_wafers, 1),
            "n_wafers": float(r.n_wafers),
            "feasible": r.feasible and np.isfinite(r.power_w),
        } for r in rs]


class HeteroServingObjective(Objective):
    """Heterogeneous (prefill/decode disaggregation) serving objective: each
    candidate design is scored as both stages of a split at the configured
    granularity / prefill ratio, under the coupled request model
    (`heterogeneity.evaluate_hetero_serving`)."""

    def __init__(self, wl: LLMWorkload, mix, slo, *, granularity: str,
                 prefill_ratio: float = 0.5, slots: int = 8,
                 n_wafers: int = 8,
                 fidelity: Union[str, FidelityBackend] = "analytical",
                 gnn_params: Optional[Dict] = None,
                 params_fn: Optional[Callable[[], Optional[Dict]]] = None,
                 objectives: Optional[Sequence[ObjectiveSpec]] = None,
                 constraints: Sequence[ConstraintSpec] = (),
                 penalty: Tuple[float, float] = PENALTY):
        super().__init__(objectives, constraints, penalty, scenario="hetero")
        self.wl = wl
        self.mix = mix
        self.slo = slo
        self.granularity = granularity
        self.prefill_ratio = prefill_ratio
        self.slots = slots
        self.n_wafers = n_wafers
        self.backend = get_backend(fidelity)
        self.fidelity = self.backend.name
        self._gnn_params = gnn_params
        self._params_fn = params_fn

    def gnn_params(self) -> Optional[Dict]:
        return self._params_fn() if self._params_fn else self._gnn_params

    def metrics(self, designs: List[WSCDesign]) -> List[Dict[str, float]]:
        from repro.core.heterogeneity import evaluate_hetero_serving
        out = []
        for d in designs:
            r = evaluate_hetero_serving(
                d, d, self.wl, self.granularity, self.prefill_ratio,
                self.mix, self.slo, slots=self.slots,
                n_wafers=self.n_wafers, fidelity=self.backend,
                gnn_params=self.gnn_params())
            out.append({
                "goodput": r.goodput_tok_s,
                "throughput": r.throughput_tok_s,
                "ttft": r.ttft_s, "tpot": r.tpot_s,
                "slo_attainment": r.slo_attainment,
                "power": r.power_w,
                "power_per_wafer": r.power_w / max(self.n_wafers, 1),
                "n_wafers": float(self.n_wafers),
                "kv_transfer_s": r.kv_transfer_s,
                "feasible": r.feasible and np.isfinite(r.power_w),
            })
        return out


class TraceServingObjective(Objective):
    """Trace-driven multi-tenant serving objective (DESIGN.md §14):
    candidates are scored by replaying a `RequestTrace` under an
    admission/routing policy through `traces.evaluate_trace_serving_batch`.
    The default objective pair is (worst-window interactive goodput,
    power-per-wafer) — which design keeps chat inside its tenant SLO
    through the worst load spike, at what power. Candidates may be
    `PolicyDesign`s (each carrying its own searched policy) or plain
    designs scored under `policy`; per-tenant goodput/attainment flow out
    as `tenant:<name>:*` metrics so constraints can pin a specific class."""

    def __init__(self, wl: LLMWorkload, trace, *, policy: str = "fifo",
                 slots: int = 8, window_steps: int = 64,
                 prefill_ratio: float = 0.5,
                 fidelity: Union[str, FidelityBackend] = "analytical",
                 gnn_params: Optional[Dict] = None,
                 params_fn: Optional[Callable[[], Optional[Dict]]] = None,
                 objectives: Optional[Sequence[ObjectiveSpec]] = None,
                 constraints: Sequence[ConstraintSpec] = (),
                 max_strategies: int = 24,
                 penalty: Tuple[float, float] = PENALTY):
        super().__init__(objectives, constraints, penalty,
                         scenario="trace_serving")
        self.wl = wl
        self.trace = trace
        self.policy = policy
        self.slots = slots
        self.window_steps = window_steps
        self.prefill_ratio = prefill_ratio
        self.backend = get_backend(fidelity)
        self.fidelity = self.backend.name
        self._gnn_params = gnn_params
        self._params_fn = params_fn
        self.max_strategies = max_strategies

    def gnn_params(self) -> Optional[Dict]:
        return self._params_fn() if self._params_fn else self._gnn_params

    def metrics(self, designs: List[WSCDesign]) -> List[Dict[str, float]]:
        from repro.core.traces import evaluate_trace_serving_batch
        rs = evaluate_trace_serving_batch(
            designs, self.wl, self.trace, slots=self.slots,
            policy=self.policy, window_steps=self.window_steps,
            prefill_ratio=self.prefill_ratio, fidelity=self.backend,
            gnn_params=self.gnn_params(),
            max_strategies=self.max_strategies)
        out = []
        for r in rs:
            m = {
                "goodput": r.goodput_tok_s,
                "interactive_goodput": r.interactive_goodput_tok_s,
                "worst_window_goodput": r.worst_window_goodput_tok_s,
                "throughput": r.throughput_tok_s,
                "ttft": r.ttft_s, "ttft_max": r.ttft_max_s,
                "tpot": r.tpot_s, "tpot_max": r.tpot_max_s,
                "slo_attainment": r.slo_attainment,
                "n_preemptions": float(r.n_preemptions),
                "power": r.power_w,
                "power_per_wafer": r.power_w / max(r.n_wafers, 1),
                "n_wafers": float(r.n_wafers),
                "feasible": r.feasible and np.isfinite(r.power_w),
            }
            for name, tm in r.per_tenant.items():
                m[f"tenant:{name}:goodput"] = tm["goodput_tok_s"]
                m[f"tenant:{name}:slo_attainment"] = tm["slo_attainment"]
            out.append(m)
        return out


class CallableObjective(Objective):
    """Compat adapter for legacy objective callables: scalar
    ``f(design) -> (y0, y1)`` functions and ``.batched``-marked batch
    functions. This is the one place the old attribute sniff survives."""

    def __init__(self, fn: Callable):
        super().__init__(objectives=(ObjectiveSpec("y0", "max", "identity"),
                                     ObjectiveSpec("y1", "min", "identity")))
        self.fn = fn
        self.fidelity = getattr(fn, "fidelity", None)

    def eval_many(self, designs: Sequence[WSCDesign]
                  ) -> List[Tuple[float, float]]:
        designs = list(designs)
        if getattr(self.fn, "batched", False):
            ys = self.fn(designs)
        else:
            ys = [self.fn(d) for d in designs]
        self.n_calls += 1
        self.n_evals += len(designs)
        return [(float(y[0]), float(y[1])) for y in ys]


def as_objective(f) -> Objective:
    """Coerce anything objective-shaped to the `Objective` protocol."""
    if isinstance(f, Objective):
        return f
    if hasattr(f, "eval_many"):                      # duck-typed protocol
        return f
    if callable(f):
        return CallableObjective(f)
    raise TypeError(f"not an objective: {f!r}")


__all__ = [
    "CallableObjective", "ConstraintSpec", "EvaluatorObjective",
    "HeteroServingObjective", "Objective", "ObjectiveSpec", "PENALTY",
    "ServingObjective", "TraceServingObjective", "as_objective",
    "default_objectives",
]
