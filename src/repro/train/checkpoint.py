"""Fault-tolerant checkpointing: atomic write (tmp + rename), latest-valid
resume, corrupted-checkpoint quarantine. Nested-dict pytrees of arrays are
stored as a single .npz with path-encoded keys — no pickle.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "\x1f"          # unit separator: never appears in our dict keys
_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree, prefix=()) -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, prefix + (str(k),)))
    else:
        out[_SEP.join(prefix)] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> Dict:
    root: Dict = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


def save_checkpoint(ckpt_dir: str, step: int, params, opt_state,
                    extra: Optional[Dict] = None) -> str:
    """Atomic: writes into step_<n>.tmp then renames to step_<n>."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, "params.npz"), **_flatten(params))
    np.savez(os.path.join(tmp, "opt_state.npz"), **_flatten(opt_state))
    meta = {"step": step, "time": time.time(), **(extra or {})}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def list_checkpoints(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and os.path.isdir(os.path.join(ckpt_dir, name)):
            steps.append(int(m.group(1)))
    return sorted(steps)


def _load_dir(path: str) -> Tuple[Dict, Dict, Dict]:
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    with np.load(os.path.join(path, "params.npz")) as z:
        params = _unflatten({k: z[k] for k in z.files})
    with np.load(os.path.join(path, "opt_state.npz")) as z:
        opt = _unflatten({k: z[k] for k in z.files})
    return params, opt, meta


def restore_latest(ckpt_dir: str, quarantine: bool = True
                   ) -> Optional[Tuple[Dict, Dict, Dict]]:
    """Restore the newest valid checkpoint; corrupted ones are renamed to
    *.corrupt and skipped (node-failure recovery path)."""
    for step in reversed(list_checkpoints(ckpt_dir)):
        path = os.path.join(ckpt_dir, f"step_{step}")
        try:
            return _load_dir(path)
        except Exception:
            if quarantine:
                dst = path + ".corrupt"
                if os.path.exists(dst):
                    shutil.rmtree(dst)
                os.replace(path, dst)
    return None


def to_device(tree, like=None, sharding_tree=None):
    """numpy tree -> jnp tree (optionally matching dtypes of `like` and
    shardings of `sharding_tree` for resharded/elastic restore)."""
    def put(path_val, like_val=None, shard=None):
        arr = jnp.asarray(path_val,
                          dtype=None if like_val is None else like_val.dtype)
        if shard is not None:
            arr = jax.device_put(arr, shard)
        return arr
    if like is None and sharding_tree is None:
        return jax.tree.map(put, tree)
    if sharding_tree is None:
        return jax.tree.map(put, tree, like)
    if like is None:
        return jax.tree.map(lambda t, s: put(t, None, s), tree, sharding_tree)
    return jax.tree.map(put, tree, like, sharding_tree)
