"""AdamW + gradient clipping + LR schedules in pure JAX (no optax)."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(c: AdamWConfig) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def fn(step):
        step = step.astype(jnp.float32)
        warm = c.peak_lr * jnp.minimum(1.0, step / max(c.warmup_steps, 1))
        prog = jnp.clip((step - c.warmup_steps)
                        / max(c.total_steps - c.warmup_steps, 1), 0.0, 1.0)
        cos = c.min_lr_ratio + (1 - c.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < c.warmup_steps, warm, c.peak_lr * cos)
    return fn


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                        tree), norm


def init_opt_state(params) -> Dict:
    zeros = lambda t: jax.tree.map(  # noqa: E731
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return {"step": jnp.zeros((), jnp.int32), "m": zeros(params),
            "v": zeros(params)}


def adamw_update(params, grads, opt_state: Dict, c: AdamWConfig
                 ) -> Tuple[Dict, Dict, Dict]:
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, c.clip_norm)
    step = opt_state["step"] + 1
    lr = lr_schedule(c)(step)
    b1, b2 = c.b1, c.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        pf = p.astype(jnp.float32)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        mhat = m / bc1
        vhat = v / bc2
        newp = pf - lr * (mhat / (jnp.sqrt(vhat) + c.eps)
                          + c.weight_decay * pf)
        return newp.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, {"step": step, "m": new_m, "v": new_v}, metrics
