"""GPipe-style pipeline parallelism (MaxText-style stacked-stage schedule).

The layer stack is split into S stages whose params are STACKED along a
leading stage dim; one `lax.scan` runs M + S - 1 schedule ticks. Per tick,
a vmap over the stage dim applies every stage to the microbatch currently
in its buffer slot, then the buffer rolls one slot (stage s -> s+1). When
the stage dim is sharded over a `pipe` mesh axis, the roll lowers to a
collective-permute between neighbouring stage devices and the vmap runs the
stages concurrently — a real pipeline in the compiled HLO. Autodiff through
the schedule yields the pipelined backward pass.

Used math-equivalence test: tests/test_pipeline.py (S-stage pipeline output
== sequential layer application).
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp


def split_stages(layer_params, n_layers: int, n_stages: int):
    """Stacked (L, ...) layer params -> (S, L/S, ...) stage-stacked params."""
    assert n_layers % n_stages == 0, (n_layers, n_stages)
    per = n_layers // n_stages
    return jax.tree.map(
        lambda a: a.reshape(n_stages, per, *a.shape[1:]), layer_params)


def gpipe(
    stage_params,                  # (S, L/S, ...) pytree
    x_mbs: jnp.ndarray,            # (M, b, ...) microbatch inputs
    stage_fn: Callable,            # (stage_params_slice, x) -> x
    n_stages: int,
) -> jnp.ndarray:
    """Run the pipeline; returns (M, b, ...) outputs in microbatch order."""
    M = x_mbs.shape[0]
    buf = jnp.zeros((n_stages,) + x_mbs.shape[1:], x_mbs.dtype)
    ticks = M + n_stages - 1

    vstage = jax.vmap(stage_fn, in_axes=(0, 0))

    def tick(carry, t):
        buf, outs = carry
        # inject the next microbatch into stage 0's slot
        mb_idx = jnp.minimum(t, M - 1)
        incoming = jax.lax.dynamic_index_in_dim(x_mbs, mb_idx, 0,
                                                keepdims=False)
        buf = buf.at[0].set(jnp.where(t < M, incoming, buf[0]))
        # every stage processes its current slot (concurrent under `pipe`
        # sharding of the leading dim)
        buf = vstage(stage_params, buf)
        # drain: stage S-1 finishes microbatch t-(S-1)
        out_idx = t - (n_stages - 1)
        outs = jax.lax.cond(
            out_idx >= 0,
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, buf[n_stages - 1], jnp.maximum(out_idx, 0), 0),
            lambda o: o,
            outs)
        # advance: stage s output -> stage s+1 input (collective-permute
        # when the stage dim is sharded over the `pipe` axis)
        buf = jnp.roll(buf, shift=1, axis=0)
        return (buf, outs), None

    outs0 = jnp.zeros_like(x_mbs)
    (_, outs), _ = jax.lax.scan(tick, (buf, outs0), jnp.arange(ticks))
    return outs


def pipeline_apply(layer_params, x: jnp.ndarray, block_fn: Callable,
                   n_layers: int, n_stages: int, microbatches: int
                   ) -> jnp.ndarray:
    """Convenience wrapper: split a (B, ...) batch into microbatches, build
    per-stage apply (inner scan over the stage's layers), run the pipeline,
    and restore batch order. block_fn(params_l, x) -> x is one layer."""
    B = x.shape[0]
    assert B % microbatches == 0
    stages = split_stages(layer_params, n_layers, n_stages)
    x_mbs = x.reshape(microbatches, B // microbatches, *x.shape[1:])

    def stage_fn(stage_p, xc):
        def body(c, p_l):
            return block_fn(p_l, c), None
        out, _ = jax.lax.scan(body, xc, stage_p)
        return out

    outs = gpipe(stages, x_mbs, stage_fn, n_stages)
    return outs.reshape(B, *x.shape[1:])
