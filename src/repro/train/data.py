"""Synthetic-but-learnable data pipeline.

A fixed order-1 Markov chain over the vocabulary (Zipf-ish stationary
distribution) gives training a real signal: cross-entropy decreases toward
the chain's conditional entropy, so end-to-end examples show genuine learning.
Host-side numpy; deterministic per (seed, step, host) so multi-host shards
never overlap and restarts are reproducible.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass
class MarkovLMDataset:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    branching: int = 4          # out-degree per state: lower = more learnable
    host_id: int = 0
    num_hosts: int = 1

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V, K = self.vocab, min(self.branching, self.vocab)
        self.succ = rng.integers(0, V, size=(V, K))          # successor table
        w = rng.dirichlet(np.ones(K) * 0.5, size=V)
        self.cum = np.cumsum(w, axis=1)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * self.num_hosts + self.host_id)
        B, S, V = self.batch, self.seq_len, self.vocab
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, V, size=B)
        u = rng.random((B, S))
        for t in range(S):
            cur = toks[:, t]
            choice = (u[:, t:t + 1] < self.cum[cur]).argmax(axis=1)
            toks[:, t + 1] = self.succ[cur, choice]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def conditional_entropy(self) -> float:
        """Entropy floor (nats/token) the model can converge to."""
        w = np.diff(np.concatenate(
            [np.zeros((self.vocab, 1)), self.cum], axis=1), axis=1)
        ent = -(w * np.log(np.maximum(w, 1e-12))).sum(axis=1)
        return float(ent.mean())


def synthetic_batch(rng: np.random.Generator, cfg, shape) -> Dict[str, np.ndarray]:
    """Uniform-random batch matching input_specs (for benchmarks/smoke)."""
    B, S = shape.global_batch, shape.seq_len
    out = {"tokens": rng.integers(0, cfg.vocab, size=(B, S), dtype=np.int64)
           .astype(np.int32)}
    if shape.kind == "train":
        out["labels"] = rng.integers(0, cfg.vocab, size=(B, S),
                                     dtype=np.int64).astype(np.int32)
    if cfg.family == "encdec":
        out["frames"] = rng.standard_normal(
            (B, cfg.encoder_len, cfg.d_model)).astype(np.float32)
    if cfg.family == "vlm" and shape.kind != "decode":
        out["patches"] = rng.standard_normal(
            (B, cfg.prefix_len, cfg.d_model)).astype(np.float32)
        text = S - cfg.prefix_len
        out["tokens"] = out["tokens"][:, :text]
        if "labels" in out:
            out["labels"] = out["labels"][:, :text]
    return out
