"""Train-step factory: microbatched grad accumulation + AdamW + optional
int8 gradient compression across the data axes.

The returned step is a pure function (params, opt_state, batch) ->
(params, opt_state, metrics); the launcher jits it with shardings.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.runtime import Runtime
from repro.train.optimizer import AdamWConfig, adamw_update


def _split_microbatches(batch: Dict, n_mb: int) -> Dict:
    def rs(x):
        B = x.shape[0]
        assert B % n_mb == 0, (B, n_mb)
        return x.reshape(n_mb, B // n_mb, *x.shape[1:])
    return jax.tree.map(rs, batch)


def make_train_step(
    cfg: ModelConfig,
    rt: Runtime,
    opt: AdamWConfig,
    microbatches: int = 1,
    grad_transform: Optional[Callable] = None,
) -> Callable:
    """grad_transform: optional fn(grads) -> grads applied before the update
    (e.g. dist.collectives.int8_compress_decompress for compressed DP)."""

    def loss_of(params, mb):
        loss, metrics = M.loss_fn(params, cfg, rt, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def train_step(params, opt_state, batch
                   ) -> Tuple[Dict, Dict, Dict]:
        if microbatches > 1:
            mbs = _split_microbatches(batch, microbatches)
            acc_dtype = rt.grad_acc_dtype

            def acc(carry, mb):
                gsum, lsum = carry
                (loss, _), grads = grad_fn(params, mb)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(acc_dtype), gsum, grads)
                return (gsum, lsum + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, rt.grad_acc_dtype), params)
            (gsum, lsum), _ = jax.lax.scan(
                acc, (zeros, jnp.float32(0.0)), mbs)
            grads = jax.tree.map(
                lambda g: (g.astype(jnp.float32) / microbatches), gsum)
            loss = lsum / microbatches
        else:
            (loss, _), grads = grad_fn(params, batch)

        if grad_transform is not None:
            grads = grad_transform(grads)

        params, opt_state, om = adamw_update(params, grads, opt_state, opt)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, rt: Runtime) -> Callable:
    def eval_step(params, batch):
        loss, metrics = M.loss_fn(params, cfg, rt, batch)
        return {"loss": loss, **metrics}
    return eval_step


@functools.lru_cache(maxsize=None)
def default_microbatches(arch_name: str, seq_len: int, global_batch: int) -> int:
    """Per-cell grad-accumulation defaults sized so activations fit v5e HBM
    (tuned by the dry-run memory analysis; see EXPERIMENTS.md §Dry-run and
    §Perf OPT-C — grok ships mb=8 after the FSDP re-gather hillclimb)."""
    big = {"grok-1-314b": 8, "qwen1.5-32b": 8, "mixtral-8x7b": 8,
           "gemma3-4b": 4, "paligemma-3b": 4}
    return big.get(arch_name, 2 if global_batch >= 256 else 1)
