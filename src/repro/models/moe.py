"""Top-k MoE layer (Mixtral/Grok-style, GShard-style capacity dispatch).

Shape-stable dispatch suitable for SPMD: tokens are scattered into a
(E, C, D) buffer (one slot per (token, choice) that fits capacity), expert
FFNs run as batched einsums over the expert dim (sharded over the `model`
axis = expert parallelism; the scatter/gather lowers to all-to-all under
SPMD), and outputs are combined with the router weights. Overflow tokens drop
(capacity_factor 1.25 keeps drops rare at LLM batch sizes).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import act_fn, dense_init
from repro.models.runtime import Runtime


def init_moe(key, cfg: ModelConfig, stack: tuple = ()) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (*stack, D, E)),
        "wi": dense_init(ks[1], (*stack, E, D, F)),
        "wo": dense_init(ks[2], (*stack, E, F, D)),
    }
    if cfg.glu:
        p["wg"] = dense_init(ks[3], (*stack, E, D, F))
    return p


def moe_mlp(h: jnp.ndarray, p: dict, cfg: ModelConfig, rt: Runtime
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    B, S, D = h.shape
    E, K = cfg.moe.num_experts, cfg.moe.top_k
    T = B * S
    ht = h.reshape(T, D)

    logits = (ht @ p["router"].astype(rt.compute_dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    top_w, top_i = jax.lax.top_k(probs, K)                     # (T, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)                               # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)

    C = max(1, int(cfg.moe.capacity_factor * K * T / E))
    C = min(C, T)
    if T <= 256:
        # tiny token counts (decode steps): capacity = T guarantees no drops,
        # keeping decode numerics identical to full-forward at negligible cost
        C = T

    # position of each (token, choice) within its expert queue
    flat_e = top_i.reshape(T * K)                              # (T*K,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # (T*K, E)
    pos_all = jnp.cumsum(onehot, axis=0) - onehot              # count of earlier
    pos = jnp.take_along_axis(pos_all, flat_e[:, None], axis=1)[:, 0]
    keep = pos < C
    slot = jnp.where(keep, pos, C)                             # overflow slot C

    # dispatch: (E, C+1, D); slot C is the trash row
    tok = jnp.repeat(ht, K, axis=0)                            # (T*K, D)
    buf = jnp.zeros((E, C + 1, D), rt.compute_dtype)
    buf = buf.at[flat_e, slot].set(tok.astype(rt.compute_dtype))
    xin = buf[:, :C]                                           # (E, C, D)
    if rt.moe_buf_spec is not None:
        xin = jax.lax.with_sharding_constraint(xin, rt.moe_buf_spec)

    f = act_fn(cfg.act)
    wi = p["wi"].astype(rt.compute_dtype)
    wo = p["wo"].astype(rt.compute_dtype)
    if cfg.glu:
        wg = p["wg"].astype(rt.compute_dtype)
        u = f(jnp.einsum("ecd,edf->ecf", xin, wg)) * \
            jnp.einsum("ecd,edf->ecf", xin, wi)
    else:
        u = f(jnp.einsum("ecd,edf->ecf", xin, wi))
    eout = jnp.einsum("ecf,efd->ecd", u, wo)                   # (E, C, D)

    # combine: gather each (token, choice) back and weight
    eout_pad = jnp.concatenate(
        [eout, jnp.zeros((E, 1, D), eout.dtype)], axis=1)      # trash row = 0
    gathered = eout_pad[flat_e, slot]                          # (T*K, D)
    w = (top_w.reshape(T * K) * keep).astype(rt.compute_dtype)
    out = (gathered * w[:, None]).reshape(T, K, D).sum(axis=1)
    return out.reshape(B, S, D), aux
