"""Attention module glue: projections + RoPE + kernel dispatch + KV caches.

Caches are position-explicit: every cache keeps a `kv_pos` int32 array beside
k/v so ring-buffer (sliding-window) caches and full caches share one masked
attention path (see kernels/flash_attention/ref.make_mask).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.ref import attention_ref, make_mask
from repro.models.layers import dense_init, rope
from repro.models.runtime import Runtime


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, stack: tuple = ()) -> dict:
    D, hd = cfg.d_model, cfg.hd()
    nq, nkv = cfg.n_heads, cfg.n_kv
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (*stack, D, nq * hd)),
        "wk": dense_init(ks[1], (*stack, D, nkv * hd)),
        "wv": dense_init(ks[2], (*stack, D, nkv * hd)),
        "wo": dense_init(ks[3], (*stack, nq * hd, D)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((*stack, nq * hd))
        p["bk"] = jnp.zeros((*stack, nkv * hd))
        p["bv"] = jnp.zeros((*stack, nkv * hd))
    return p


def _constrain_attn(x: jnp.ndarray, rt: Runtime, is_query: bool
                    ) -> jnp.ndarray:
    """Divisibility-aware constraint on (B, S, H, hd) attention activations:
    head-parallel over `model` when H divides it, sequence-parallel for q
    otherwise (always legal for our seq lengths), batch over dp axes when
    divisible. k/v that cannot head-shard stay batch-only — the GQA repeat
    resolves against head-sharded q. Without this, SPMD can replicate
    full-batch attention tensors when the flat H*hd weight sharding cuts
    head boundaries (e.g. 9-head smollm on a 16-wide model axis)."""
    if rt.mesh_axes is None:
        return x
    from jax.sharding import PartitionSpec as P
    axes = rt.mesh_axes
    dp = tuple(a for a in ("pod", "data") if a in axes)
    dp_size = 1
    for a in dp:
        dp_size *= axes[a]
    model = axes.get("model", 1)
    B, S, H, _ = x.shape
    batch_axes = dp if (dp_size > 1 and B % dp_size == 0) else None
    if model > 1 and H % model == 0:
        spec = P(batch_axes, None, "model", None)
    elif is_query and model > 1 and S % model == 0 and S >= model:
        spec = P(batch_axes, "model", None, None)
    else:
        spec = P(batch_axes, None, None, None)
    return jax.lax.with_sharding_constraint(x, spec)


def _proj_qkv(h, p, cfg: ModelConfig, rt: Runtime):
    B, S, _ = h.shape
    hd = cfg.hd()
    q = h @ p["wq"].astype(rt.compute_dtype)
    k = h @ p["wk"].astype(rt.compute_dtype)
    v = h @ p["wv"].astype(rt.compute_dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(rt.compute_dtype)
        k = k + p["bk"].astype(rt.compute_dtype)
        v = v + p["bv"].astype(rt.compute_dtype)
    q = _constrain_attn(q.reshape(B, S, cfg.n_heads, hd), rt, True)
    k = _constrain_attn(k.reshape(B, S, cfg.n_kv, hd), rt, False)
    v = _constrain_attn(v.reshape(B, S, cfg.n_kv, hd), rt, False)
    return q, k, v


# ---------------------------------------------------------------------------
# full-sequence self attention (train / prefill)
# ---------------------------------------------------------------------------


def self_attention(
    h: jnp.ndarray,                   # (B, S, D)
    p: dict,
    cfg: ModelConfig,
    rt: Runtime,
    positions: jnp.ndarray,           # (B, S)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    prefix_len: int = 0,              # prefix-LM: bidirectional first P tokens
) -> jnp.ndarray:
    q, k, v = _proj_qkv(h, p, cfg, rt)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if prefix_len > 0:
        # prefix-LM mask needs the general masked path
        out = _prefix_lm_attention(q, k, v, positions, prefix_len)
    else:
        out = fa_ops.mha(q, k, v, positions, positions, causal=causal,
                         window=window, use_pallas=rt.use_pallas,
                         interpret=rt.interpret)
    B, S = h.shape[:2]
    out = out.reshape(B, S, cfg.n_heads * cfg.hd())
    return out @ p["wo"].astype(rt.compute_dtype)


def _prefix_lm_attention(q, k, v, positions, prefix_len):
    base = make_mask(positions, positions, causal=True, window=None)
    prefix = positions[:, None, :] < prefix_len          # kv inside prefix
    both_prefix = prefix & (positions[:, :, None] < prefix_len)
    mask = base | both_prefix
    return _masked_attention(q, k, v, mask)


def _masked_attention(q, k, v, mask):
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    qf = q.astype(jnp.float32) * hd ** -0.5
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    if rep > 1:
        kf = jnp.repeat(kf, rep, axis=2)
        vf = jnp.repeat(vf, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    s = jnp.where(mask[:, None], s, -1e30)
    pm = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", pm, vf).astype(q.dtype)


# query-chunking threshold: chunk whenever the scores tensor would exceed
# ~Sq*Skv elements per (batch, head). Keeps prefill-32k/500k from
# materializing O(S^2) scores — the jnp analogue of flash blocking, with the
# same HBM traffic profile (K/V re-read once per q chunk).
_CHUNK_Q = 512
_CHUNK_THRESHOLD = 8192


def _attention_bf16_scores(q, k, v, q_pos, kv_pos, *, causal, window,
                           prefix_len=0):
    """attention_ref with bf16 score matmuls + fp32 MXU accumulation: no
    materialized fp32 Q/K/V copies (§Perf OPT-D). Same mask semantics."""
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    rep = Hq // Hkv
    kf, vf = k, v
    if rep > 1:
        kf = jnp.repeat(kf, rep, axis=2)
        vf = jnp.repeat(vf, rep, axis=2)
    qs = (q.astype(jnp.float32) * hd ** -0.5).astype(q.dtype)
    scores = jnp.einsum("bqhd,bkhd->bhqk", qs, kf,
                        preferred_element_type=jnp.float32)
    mask = make_mask(q_pos, kv_pos, causal=causal, window=window,
                     prefix_len=prefix_len)
    scores = jnp.where(mask[:, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), vf,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def _attend(q, k, v, q_pos, kv_pos, *, causal, window, prefix_len=0,
            bf16_scores=False):
    """Masked attention with automatic q-chunking for long sequences."""
    attn = _attention_bf16_scores if bf16_scores else attention_ref
    Sq = q.shape[1]
    if Sq < _CHUNK_THRESHOLD or Sq % _CHUNK_Q != 0:
        return attn(q, k, v, q_pos, kv_pos, causal=causal,
                    window=window, prefix_len=prefix_len)
    nq = Sq // _CHUNK_Q

    def chunk_fn(_, inp):
        qc, qpc = inp
        out = attn(qc, k, v, qpc, kv_pos, causal=causal,
                   window=window, prefix_len=prefix_len)
        return None, out

    qs = q.reshape(q.shape[0], nq, _CHUNK_Q, *q.shape[2:]).transpose(1, 0, 2, 3, 4)
    qps = q_pos.reshape(q_pos.shape[0], nq, _CHUNK_Q).transpose(1, 0, 2)
    _, outs = jax.lax.scan(chunk_fn, None, (qs, qps))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(q.shape)
    return out


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  n_layers: int, rt: Runtime,
                  window: Optional[int] = None) -> dict:
    """Cache for `n_layers` attention layers. With rt.ring_cache and a window,
    the buffer is only `window` slots (ring); otherwise full `max_len`."""
    W = max_len
    if rt.ring_cache and window is not None:
        W = min(window, max_len)
    hd = cfg.hd()
    return {
        "k": jnp.zeros((n_layers, batch, W, cfg.n_kv, hd), rt.compute_dtype),
        "v": jnp.zeros((n_layers, batch, W, cfg.n_kv, hd), rt.compute_dtype),
        "kv_pos": jnp.full((n_layers, batch, W), -1, jnp.int32),
    }


def _pos_vector(pos, B: int) -> jnp.ndarray:
    """Normalize scalar-or-(B,) position to (B,) int32 (per-slot positions
    enable continuous batching in the serving engine)."""
    p = jnp.asarray(pos, jnp.int32)
    if p.ndim == 0:
        p = jnp.broadcast_to(p, (B,))
    return p


def update_cache_layer(cache_l: dict, k_new: jnp.ndarray, v_new: jnp.ndarray,
                       pos, use_dus: bool = True) -> dict:
    """Insert S_new tokens starting at absolute position `pos` (scalar or
    per-batch (B,)) into a layer cache (B, W, Hkv, hd). Ring index = pos % W.

    Scalar `pos` with a contiguous non-wrapping span uses
    dynamic-update-slice: under SPMD a DUS keeps a sequence-sharded cache
    sharded (each shard masks locally), whereas a scatter forces the
    partitioner to all-gather the whole cache (measured: 291 GB/chip per
    decode step on gemma3-4b long_500k — see EXPERIMENTS.md §Perf).
    use_dus=False reproduces the scatter baseline."""
    B, W = cache_l["k"].shape[:2]
    S_new = k_new.shape[1]
    p = jnp.asarray(pos, jnp.int32)
    if p.ndim == 0 and use_dus:
        start = p % W
        # wrapping spans fall back to scatter (prefill into small ring);
        # S_new == 1 (decode) or aligned prefill never wraps
        if S_new == 1 or W % S_new == 0:
            kc = jax.lax.dynamic_update_slice_in_dim(
                cache_l["k"], k_new, start, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                cache_l["v"], v_new, start, axis=1)
            positions = (p + jnp.arange(S_new, dtype=jnp.int32))[None, :]
            pc = jax.lax.dynamic_update_slice_in_dim(
                cache_l["kv_pos"],
                jnp.broadcast_to(positions, (B, S_new)), start, axis=1)
            return {"k": kc, "v": vc, "kv_pos": pc}
    pv = _pos_vector(pos, B)                              # (B,)
    positions = pv[:, None] + jnp.arange(S_new)[None, :]  # (B, S_new)
    slots = positions % W
    bidx = jnp.arange(B)[:, None]
    kc = cache_l["k"].at[bidx, slots].set(k_new)
    vc = cache_l["v"].at[bidx, slots].set(v_new)
    pc = cache_l["kv_pos"].at[bidx, slots].set(positions.astype(jnp.int32))
    return {"k": kc, "v": vc, "kv_pos": pc}


def cached_attention(
    x: jnp.ndarray,                   # (B, S_new, D) new tokens' hidden
    p: dict,
    cfg: ModelConfig,
    rt: Runtime,
    cache_l: dict,
    pos: jnp.ndarray,                 # scalar: absolute position of x[:, 0]
    *,
    window: Optional[int] = None,
    prefix_len: int = 0,
) -> Tuple[jnp.ndarray, dict]:
    """Decode/chunked-prefill attention against a (possibly ring) cache.
    `pos` may be a scalar or a per-slot (B,) vector."""
    B, S_new, _ = x.shape
    q, k, v = _proj_qkv(x, p, cfg, rt)
    pv = _pos_vector(pos, B)
    positions = (pv[:, None] + jnp.arange(S_new)[None, :]).astype(jnp.int32)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    cache_l = update_cache_layer(cache_l, k, v, pos,
                                 use_dus=rt.opt_cache_dus)
    W = cache_l["k"].shape[1]
    p_scalar = jnp.asarray(pos).ndim == 0
    # is the cache sequence-sharded? (B too small to take the dp axes) —
    # then a dynamic-slice would force SPMD to gather the cache, so the
    # masked flash-decoding path (which never gathers) must win.
    seq_sharded = False
    if rt.mesh_axes is not None:
        dp_size = 1
        for a in ("pod", "data"):
            dp_size *= rt.mesh_axes.get(a, 1)
        seq_sharded = B % dp_size != 0 and W >= 65536
    if (S_new == 1 and rt.mesh_axes is not None and rt.opt_cache_dus
            and seq_sharded):
        out = _long_decode_attention(
            q, cache_l["k"], cache_l["v"], positions, cache_l["kv_pos"],
            rt, window=window)
    elif (rt.opt_cache_dus and p_scalar and S_new == 1
            and window is not None and W >= 4 * window):
        # windowed decode against a long batch-sharded cache: slice the
        # last `window` slots instead of reading (and masking) the whole
        # cache — the decode-side analogue of a ring buffer. O(W) ->
        # O(window) HBM reads (EXPERIMENTS.md §Perf OPT-A).
        start = jnp.clip(jnp.asarray(pos, jnp.int32) - window + 1, 0,
                         W - window)
        k_win = jax.lax.dynamic_slice_in_dim(cache_l["k"], start, window, 1)
        v_win = jax.lax.dynamic_slice_in_dim(cache_l["v"], start, window, 1)
        pos_win = jax.lax.dynamic_slice_in_dim(cache_l["kv_pos"], start,
                                               window, 1)
        out = _attend(q, k_win, v_win, positions, pos_win,
                      causal=True, window=window, prefix_len=prefix_len,
                      bf16_scores=rt.opt_bf16_scores)
    elif (S_new == 1 and W >= 65536 and rt.mesh_axes is not None
            and rt.opt_cache_dus):
        # long-context decode: flash-decoding-style sequence-parallel
        # attention (scores stay sharded on the cache's sequence dim; no
        # GQA repeat — see EXPERIMENTS.md §Perf OPT-A)
        out = _long_decode_attention(
            q, cache_l["k"], cache_l["v"], positions, cache_l["kv_pos"],
            rt, window=window)
    else:
        out = _attend(
            q, cache_l["k"], cache_l["v"], positions, cache_l["kv_pos"],
            causal=True, window=window, prefix_len=prefix_len,
            bf16_scores=rt.opt_bf16_scores)
    out = out.reshape(B, S_new, cfg.n_heads * cfg.hd())
    return out @ p["wo"].astype(rt.compute_dtype), cache_l


def _long_decode_attention(q, k, v, q_pos, kv_pos, rt: Runtime,
                           window: Optional[int] = None) -> jnp.ndarray:
    """One-token attention against a sequence-sharded cache without ever
    materializing a gathered K/V: grouped-head einsum (no jnp.repeat — the
    repeat's reshard is what forced SPMD to all-gather the fp32 cache) with
    explicit seq-sharded score constraints. Softmax/combine reductions over
    the sharded dim lower to tiny all-reduces (flash-decoding on SPMD)."""
    from jax.sharding import PartitionSpec as P

    B, Sq, Hq, hd = q.shape
    _, W, Hkv, _ = k.shape
    rep = Hq // Hkv
    axes = rt.mesh_axes
    dp = tuple(dpx for dpx in ("pod", "data") if dpx in axes)
    model = axes.get("model", 1)
    dp_size = 1
    for a in dp:
        dp_size *= axes[a]
    if model > 1 and Hkv % model == 0 and W % max(dp_size, 1) == 0:
        # KV heads shard over model (matches the cache's resident sharding
        # for wide-GQA archs — no reshard), sequence over dp
        kspec = P(None, dp if dp_size > 1 else None, "model", None)
        head_axes: Optional[str] = "model"
        seq_axes = dp
    else:
        seq_axes = tuple(dp) + ("model",)
        head_axes = None
        seq_ok = W % max(
            1, int(np.prod([axes[a] for a in seq_axes]))) == 0
        kspec = P(None, seq_axes if seq_ok else None, None, None)

    # keep K/V in their storage dtype — an fp32 upcast would materialize a
    # second copy of the whole cache in HBM (measured 51 GB/chip); the MXU
    # accumulates in fp32 via preferred_element_type
    qf = (q.astype(jnp.float32) * hd ** -0.5).astype(q.dtype)
    qf = qf.reshape(B, Hkv, rep, hd)
    kf = jax.lax.with_sharding_constraint(k, kspec)
    vf = jax.lax.with_sharding_constraint(v, kspec)
    scores = jnp.einsum("bgrd,bsgd->bgrs", qf, kf,
                        preferred_element_type=jnp.float32)  # (B,Hkv,rep,W)
    seq_ok = W % max(
        1, int(np.prod([axes[a] for a in seq_axes]))) == 0 if seq_axes else False
    sspec = P(None, head_axes, None,
              seq_axes if (seq_ok and seq_axes) else None)
    scores = jax.lax.with_sharding_constraint(scores, sspec)

    kv = kv_pos[:, None, None, :]                        # (B,1,1,W)
    qp = q_pos[:, 0][:, None, None, None]
    mask = (kv >= 0) & (kv <= qp)
    if window is not None:
        mask = mask & (kv > qp - window)
    scores = jnp.where(mask, scores, -1e30)
    m = jnp.max(scores, axis=-1, keepdims=True)          # psum over shards
    p_ = jnp.where(mask, jnp.exp(scores - m), 0.0)
    l = jnp.maximum(jnp.sum(p_, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bgrs,bsgd->bgrd", (p_ / l).astype(v.dtype), vf,
                     preferred_element_type=jnp.float32)  # partial+psum
    return out.reshape(B, 1, Hq, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# cross attention (enc-dec)
# ---------------------------------------------------------------------------


def init_cross_attention(key, cfg: ModelConfig, stack: tuple = ()) -> dict:
    return init_attention(key, cfg, stack)


def cross_attention(
    x: jnp.ndarray,                   # (B, Sq, D) decoder hidden
    p: dict,
    cfg: ModelConfig,
    rt: Runtime,
    enc_k: jnp.ndarray,               # (B, Senc, Hkv, hd) precomputed
    enc_v: jnp.ndarray,
) -> jnp.ndarray:
    B, Sq, _ = x.shape
    hd = cfg.hd()
    q = x @ p["wq"].astype(rt.compute_dtype)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(rt.compute_dtype)
    q = q.reshape(B, Sq, cfg.n_heads, hd)
    Senc = enc_k.shape[1]
    qpos = jnp.zeros((B, Sq), jnp.int32)
    kvpos = jnp.broadcast_to(jnp.arange(Senc)[None], (B, Senc)).astype(jnp.int32)
    out = _attend(q, enc_k, enc_v, qpos, kvpos, causal=False, window=None)
    out = out.reshape(B, Sq, cfg.n_heads * hd)
    return out @ p["wo"].astype(rt.compute_dtype)


def encode_cross_kv(enc_out: jnp.ndarray, p: dict, cfg: ModelConfig,
                    rt: Runtime) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Project encoder output once into cross-attention K/V."""
    B, Senc, _ = enc_out.shape
    hd = cfg.hd()
    k = enc_out @ p["wk"].astype(rt.compute_dtype)
    v = enc_out @ p["wv"].astype(rt.compute_dtype)
    if cfg.qkv_bias:
        k = k + p["bk"].astype(rt.compute_dtype)
        v = v + p["bv"].astype(rt.compute_dtype)
    return (k.reshape(B, Senc, cfg.n_kv, hd),
            v.reshape(B, Senc, cfg.n_kv, hd))
