"""Shared primitives: norms, RoPE, MLP, init helpers. Pure JAX, functional."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.runtime import Runtime

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * s).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + g.astype(jnp.float32))
    return out.astype(x.dtype)


def gated_rmsnorm(x: jnp.ndarray, z: jnp.ndarray, g: jnp.ndarray,
                  eps: float = 1e-6) -> jnp.ndarray:
    """Mamba-2 output norm: rmsnorm(x * silu(z))."""
    xf = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + g.astype(jnp.float32))
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x (B, S, H, hd), positions (B, S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, :, None] * freqs[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]           # (B, S, 1, half)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def mlp(h: jnp.ndarray, p: dict, cfg: ModelConfig, rt: Runtime) -> jnp.ndarray:
    """Gated (SwiGLU/GeGLU) or plain 2-layer MLP. h (B, S, D)."""
    f = act_fn(cfg.act)
    wi = p["wi"].astype(rt.compute_dtype)
    wo = p["wo"].astype(rt.compute_dtype)
    if cfg.glu:
        wg = p["wg"].astype(rt.compute_dtype)
        u = f(h @ wg) * (h @ wi)
    else:
        u = f(h @ wi)
    return u @ wo


def init_mlp(key, cfg: ModelConfig, d_ff: int, stack: tuple = ()) -> dict:
    D = cfg.d_model
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], (*stack, D, d_ff)),
         "wo": dense_init(ks[1], (*stack, d_ff, D))}
    if cfg.glu:
        p["wg"] = dense_init(ks[2], (*stack, D, d_ff))
    return p


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------


def causal_depthwise_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                          state: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Per-channel causal 1-D conv. x (B, S, C), w (K, C), b (C,).
    If `state` (B, K-1, C) is given, it is prepended (decode path)."""
    K = w.shape[0]
    xf = x.astype(jnp.float32)
    if state is not None:
        xf = jnp.concatenate([state.astype(jnp.float32), xf], axis=1)
    else:
        xf = jnp.pad(xf, ((0, 0), (K - 1, 0), (0, 0)))
    S = x.shape[1]
    out = sum(xf[:, i:i + S, :] * w.astype(jnp.float32)[i][None, None, :]
              for i in range(K))
    out = out + b.astype(jnp.float32)[None, None, :]
    return jax.nn.silu(out).astype(x.dtype)
