"""Runtime options threaded through every model forward pass."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Runtime:
    compute_dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    use_pallas: bool = False        # flash-attn / SSD Pallas kernels (TPU)
    interpret: bool = False         # Pallas interpret mode (CPU validation)
    remat: str = "block"            # none | block  (checkpoint each layer)
    ring_cache: bool = False        # windowed layers use ring-buffer KV caches
    ssd_chunk: int = 128
    # sharding constraint (PartitionSpec) for the MoE dispatch buffer
    # (E, C, D); prevents SPMD from replicating the capacity buffer. Set by
    # the launcher; None on single-device CPU runs.
    moe_buf_spec: Any = None
    # mesh axis sizes, e.g. {"pod": 2, "data": 16, "model": 16}; enables
    # divisibility-aware attention activation constraints (head-parallel when
    # heads divide the model axis, sequence-parallel otherwise). None = no
    # constraints (single-device runs).
    mesh_axes: Any = None
    # decode cache update via dynamic-update-slice (keeps sequence-sharded
    # caches sharded under SPMD). False reproduces the scatter baseline.
    opt_cache_dus: bool = True
    # SSD head-dim tensor parallelism (False reproduces the naive flat-TP
    # baseline that reshards the packed in_proj output every layer)
    opt_ssm_head_tp: bool = True
    # long-prefill attention computes scores from bf16 operands with fp32
    # MXU accumulation instead of materializing fp32 copies of Q/K/V
    # (halves the prefill score traffic; numerics validated in tests)
    opt_bf16_scores: bool = False
    # gradient-accumulation dtype for microbatched training (fp32 default;
    # bf16 halves the per-microbatch reduction bytes)
    grad_acc_dtype: Any = jnp.float32


CPU_TEST = Runtime(compute_dtype=jnp.float32, remat="none")
