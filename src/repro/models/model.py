"""Unified model facade for every assigned architecture.

Public API (all pure functions of (params, cfg, rt, ...)):
    init_params(rng, cfg)                      -> params pytree
    forward(params, cfg, rt, batch)            -> (logits, aux_loss)
    loss_fn(params, cfg, rt, batch)            -> (loss, metrics)
    init_cache(cfg, rt, batch_size, max_len)   -> cache pytree
    prefill(params, cfg, rt, batch, cache)     -> (last_logits, cache)
    decode_step(params, cfg, rt, tokens, pos, cache) -> (logits, cache)
    input_specs(cfg, shape)                    -> batch of ShapeDtypeStructs
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, hybrid
from repro.models.layers import embed_init, rmsnorm
from repro.models.mamba2 import (
    init_ssm_block,
    init_ssm_cache,
    ssm_block,
    ssm_block_decode,
    ssm_block_prefill,
)
from repro.models.runtime import Runtime
from repro.models.transformer import (
    decoder_stack,
    decoder_stack_decode,
    init_decoder_cache,
    init_decoder_layers,
)

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(rng, cfg: ModelConfig) -> Dict:
    ks = jax.random.split(rng, 4)
    p: Dict = {
        "embed": embed_init(ks[0], (cfg.vocab, cfg.d_model)),
        "final_ln": jnp.zeros((cfg.d_model,)),
    }
    if not cfg.tied_embeddings:
        p["unembed"] = embed_init(ks[1], (cfg.d_model, cfg.vocab))
    if cfg.family in ("dense", "moe", "vlm"):
        p["layers"] = init_decoder_layers(ks[2], cfg, cfg.num_layers)
    elif cfg.family == "ssm":
        p["layers"] = init_ssm_block(ks[2], cfg, (cfg.num_layers,))
    elif cfg.family == "hybrid":
        p["layers"] = hybrid.init_hybrid_layers(ks[2], cfg)
    elif cfg.family == "encdec":
        p["enc_layers"] = encdec.init_encoder_layers(ks[2], cfg)
        p["enc_ln"] = jnp.zeros((cfg.d_model,))
        p["dec_layers"] = encdec.init_decoder_layers_xattn(ks[3], cfg)
    else:
        raise ValueError(cfg.family)
    return p


def _embed(params, tokens, rt: Runtime):
    return params["embed"].astype(rt.compute_dtype)[tokens]


def _logits(params, x, rt: Runtime):
    xf = x.astype(jnp.float32)
    if "unembed" in params:
        return xf @ params["unembed"].astype(jnp.float32)
    return xf @ params["embed"].astype(jnp.float32).T


def _positions(B, S, start=0):
    pos = start + jnp.arange(S, dtype=jnp.int32)
    return jnp.broadcast_to(pos[None], (B, S))


def _ssm_stack(x, layers, cfg, rt):
    def body(xc, p_l):
        return ssm_block(xc, p_l, cfg, rt), None

    if rt.remat == "block":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    x, _ = jax.lax.scan(body, x, layers)
    return x


# ---------------------------------------------------------------------------
# forward / loss (training + full-sequence scoring)
# ---------------------------------------------------------------------------


def forward(params, cfg: ModelConfig, rt: Runtime, batch: Dict
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    aux = jnp.float32(0.0)
    if cfg.family in ("dense", "moe"):
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = _embed(params, tokens, rt)
        x, aux = decoder_stack(x, params["layers"], cfg, rt,
                               _positions(B, S), cfg.num_layers)
    elif cfg.family == "vlm":
        tokens = batch["tokens"]                     # (B, S_text)
        patches = batch["patches"].astype(rt.compute_dtype)
        B = tokens.shape[0]
        x = jnp.concatenate([patches, _embed(params, tokens, rt)], axis=1)
        S = x.shape[1]
        x, aux = decoder_stack(x, params["layers"], cfg, rt,
                               _positions(B, S), cfg.num_layers,
                               prefix_len=cfg.prefix_len)
    elif cfg.family == "ssm":
        tokens = batch["tokens"]
        x = _embed(params, tokens, rt)
        x = _ssm_stack(x, params["layers"], cfg, rt)
    elif cfg.family == "hybrid":
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = _embed(params, tokens, rt)
        x, aux = hybrid.hybrid_forward(x, params["layers"], cfg, rt,
                                       _positions(B, S))
    elif cfg.family == "encdec":
        tokens = batch["tokens"]
        B, S = tokens.shape
        enc_out = encdec.encode(batch["frames"], params["enc_layers"], cfg, rt)
        enc_out = rmsnorm(enc_out, params["enc_ln"], cfg.norm_eps)
        x = _embed(params, tokens, rt)
        x = encdec.decode_stack(x, params["dec_layers"], cfg, rt,
                                _positions(B, S), enc_out)
    else:
        raise ValueError(cfg.family)
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    return _logits(params, x, rt), aux


def loss_fn(params, cfg: ModelConfig, rt: Runtime, batch: Dict,
            aux_weight: float = 0.01) -> Tuple[jnp.ndarray, Dict]:
    logits, aux = forward(params, cfg, rt, batch)
    labels = batch["labels"]
    if cfg.family == "vlm":
        # labels cover text positions only; prefix positions are ignored
        logits = logits[:, cfg.prefix_len:]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = (nll * mask).sum() / denom
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux, "tokens": denom}


# ---------------------------------------------------------------------------
# cache / prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, rt: Runtime, batch: int, max_len: int) -> Dict:
    if cfg.family in ("dense", "moe", "vlm"):
        return {"attn": init_decoder_cache(cfg, batch, max_len,
                                           cfg.num_layers, rt)}
    if cfg.family == "ssm":
        return {"ssm": init_ssm_cache(cfg, batch, cfg.num_layers, rt)}
    if cfg.family == "hybrid":
        return hybrid.init_hybrid_cache(cfg, batch, max_len, rt)
    if cfg.family == "encdec":
        return encdec.init_encdec_cache(cfg, batch, max_len, rt)
    raise ValueError(cfg.family)


def prefill(params, cfg: ModelConfig, rt: Runtime, batch: Dict, cache: Dict
            ) -> Tuple[jnp.ndarray, Dict]:
    """Fill the cache from position 0; returns (last-token logits, cache)."""
    pos0 = jnp.int32(0)
    if cfg.family in ("dense", "moe"):
        tokens = batch["tokens"]
        x = _embed(params, tokens, rt)
        x, attn_cache = decoder_stack_decode(
            x, params["layers"], cfg, rt, cache["attn"], pos0, cfg.num_layers)
        cache = {"attn": attn_cache}
    elif cfg.family == "vlm":
        tokens = batch["tokens"]
        patches = batch["patches"].astype(rt.compute_dtype)
        x = jnp.concatenate([patches, _embed(params, tokens, rt)], axis=1)
        x, attn_cache = decoder_stack_decode(
            x, params["layers"], cfg, rt, cache["attn"], pos0,
            cfg.num_layers, prefix_len=cfg.prefix_len)
        cache = {"attn": attn_cache}
    elif cfg.family == "ssm":
        tokens = batch["tokens"]
        x = _embed(params, tokens, rt)

        def body(xc, inp):
            p_l, c_l = inp
            xc, nc = ssm_block_prefill(xc, p_l, cfg, rt, c_l)
            return xc, nc

        x, ssm_cache = jax.lax.scan(body, x, (params["layers"], cache["ssm"]))
        cache = {"ssm": ssm_cache}
    elif cfg.family == "hybrid":
        tokens = batch["tokens"]
        x = _embed(params, tokens, rt)
        x, cache = hybrid.hybrid_prefill(x, params["layers"], cfg, rt,
                                         cache, pos0)
    elif cfg.family == "encdec":
        tokens = batch["tokens"]
        enc_out = encdec.encode(batch["frames"], params["enc_layers"], cfg, rt)
        enc_out = rmsnorm(enc_out, params["enc_ln"], cfg.norm_eps)
        cache = encdec.fill_cross_cache(enc_out, params["dec_layers"], cfg,
                                        rt, cache)
        x = _embed(params, tokens, rt)
        x, cache = encdec.decode_stack_cached(x, params["dec_layers"], cfg,
                                              rt, cache, pos0)
    else:
        raise ValueError(cfg.family)
    x = rmsnorm(x[:, -1:], params["final_ln"], cfg.norm_eps)
    return _logits(params, x, rt)[:, 0], cache


def decode_step(params, cfg: ModelConfig, rt: Runtime, tokens: jnp.ndarray,
                pos, cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    """One autoregressive step. tokens (B, 1), pos scalar int32."""
    x = _embed(params, tokens, rt)
    if cfg.family in ("dense", "moe"):
        x, attn_cache = decoder_stack_decode(
            x, params["layers"], cfg, rt, cache["attn"], pos, cfg.num_layers)
        cache = {"attn": attn_cache}
    elif cfg.family == "vlm":
        x, attn_cache = decoder_stack_decode(
            x, params["layers"], cfg, rt, cache["attn"], pos,
            cfg.num_layers, prefix_len=cfg.prefix_len)
        cache = {"attn": attn_cache}
    elif cfg.family == "ssm":
        def body(xc, inp):
            p_l, c_l = inp
            xc, nc = ssm_block_decode(xc, p_l, cfg, rt, c_l)
            return xc, nc

        x, ssm_cache = jax.lax.scan(body, x, (params["layers"], cache["ssm"]))
        cache = {"ssm": ssm_cache}
    elif cfg.family == "hybrid":
        x, cache = hybrid.hybrid_decode(x, params["layers"], cfg, rt, cache,
                                        pos)
    elif cfg.family == "encdec":
        x, cache = encdec.decode_stack_cached(x, params["dec_layers"], cfg,
                                              rt, cache, pos)
    else:
        raise ValueError(cfg.family)
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    return _logits(params, x, rt)[:, 0], cache


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins; no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """ShapeDtypeStruct stand-ins for the batch of a given shape cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                 "labels": jax.ShapeDtypeStruct((B, S), i32)}
    elif shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    else:  # decode: one new token against a cache of length S
        batch = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    if cfg.family == "encdec":
        batch["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_len, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm" and shape.kind != "decode":
        batch["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.prefix_len, cfg.d_model), jnp.bfloat16)
        # text portion shrinks so total sequence == shape.seq_len
        text = S - cfg.prefix_len
        batch["tokens"] = jax.ShapeDtypeStruct((B, text), i32)
        if shape.kind == "train":
            batch["labels"] = jax.ShapeDtypeStruct((B, text), i32)
    return batch
