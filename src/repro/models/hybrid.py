"""Zamba2-style hybrid: Mamba-2 backbone + ONE shared attention(+MLP) block
applied after every `shared_attn_every` SSM layers (weights reused each
application). Segments of SSM layers are scanned; the shared block is unrolled
per application (n_app = L // every), each application with its own KV cache.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import (
    cached_attention,
    init_attention,
    init_kv_cache,
    self_attention,
)
from repro.models.layers import init_mlp, mlp, rmsnorm
from repro.models.mamba2 import (
    init_ssm_block,
    init_ssm_cache,
    ssm_block,
    ssm_block_decode,
)
from repro.models.runtime import Runtime


def n_applications(cfg: ModelConfig) -> int:
    return cfg.num_layers // cfg.shared_attn_every


def _tree_slice(tree, lo, hi):
    return jax.tree.map(lambda a: a[lo:hi], tree)


def init_hybrid_layers(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "ssm_layers": init_ssm_block(ks[0], cfg, (cfg.num_layers,)),
        "shared": {
            "ln1": jnp.zeros((cfg.d_model,)),
            "attn": init_attention(ks[1], cfg),
            "ln2": jnp.zeros((cfg.d_model,)),
            "mlp": init_mlp(ks[2], cfg, cfg.d_ff),
        },
    }


def _shared_block(x, shared, cfg: ModelConfig, rt: Runtime, positions):
    h = rmsnorm(x, shared["ln1"], cfg.norm_eps)
    x = x + self_attention(h, shared["attn"], cfg, rt, positions)
    h = rmsnorm(x, shared["ln2"], cfg.norm_eps)
    return x + mlp(h, shared["mlp"], cfg, rt)


def _scan_ssm(x, seg_params, cfg: ModelConfig, rt: Runtime):
    def body(xc, p_l):
        return ssm_block(xc, p_l, cfg, rt), None

    if rt.remat == "block":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    x, _ = jax.lax.scan(body, x, seg_params)
    return x


def hybrid_forward(x, layers: dict, cfg: ModelConfig, rt: Runtime, positions
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    every = cfg.shared_attn_every
    n_app = n_applications(cfg)
    rem = cfg.num_layers - n_app * every
    for i in range(n_app):
        seg = _tree_slice(layers["ssm_layers"], i * every, (i + 1) * every)
        x = _scan_ssm(x, seg, cfg, rt)
        x = _shared_block(x, layers["shared"], cfg, rt, positions)
    if rem:
        seg = _tree_slice(layers["ssm_layers"], n_app * every, cfg.num_layers)
        x = _scan_ssm(x, seg, cfg, rt)
    return x, jnp.float32(0.0)


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_hybrid_cache(cfg: ModelConfig, batch: int, max_len: int, rt: Runtime
                      ) -> dict:
    return {
        "ssm": init_ssm_cache(cfg, batch, cfg.num_layers, rt),
        "attn": init_kv_cache(cfg, batch, max_len, n_applications(cfg), rt),
    }


def _scan_ssm_decode(x, seg_params, seg_cache, cfg, rt):
    def body(xc, inp):
        p_l, cache_l = inp
        xc, new_cache = ssm_block_decode(xc, p_l, cfg, rt, cache_l)
        return xc, new_cache

    x, new_cache = jax.lax.scan(body, x, (seg_params, seg_cache))
    return x, new_cache


def hybrid_decode(x, layers: dict, cfg: ModelConfig, rt: Runtime,
                  cache: dict, pos) -> Tuple[jnp.ndarray, dict]:
    every = cfg.shared_attn_every
    n_app = n_applications(cfg)
    rem = cfg.num_layers - n_app * every
    new_ssm, new_attn = [], []
    for i in range(n_app):
        seg_p = _tree_slice(layers["ssm_layers"], i * every, (i + 1) * every)
        seg_c = _tree_slice(cache["ssm"], i * every, (i + 1) * every)
        x, nc = _scan_ssm_decode(x, seg_p, seg_c, cfg, rt)
        new_ssm.append(nc)
        h = rmsnorm(x, layers["shared"]["ln1"], cfg.norm_eps)
        attn_cache_i = jax.tree.map(lambda a: a[i], cache["attn"])
        a_out, attn_cache_i = cached_attention(
            h, layers["shared"]["attn"], cfg, rt, attn_cache_i, pos)
        x = x + a_out
        h = rmsnorm(x, layers["shared"]["ln2"], cfg.norm_eps)
        x = x + mlp(h, layers["shared"]["mlp"], cfg, rt)
        new_attn.append(attn_cache_i)
    if rem:
        seg_p = _tree_slice(layers["ssm_layers"], n_app * every, cfg.num_layers)
        seg_c = _tree_slice(cache["ssm"], n_app * every, cfg.num_layers)
        x, nc = _scan_ssm_decode(x, seg_p, seg_c, cfg, rt)
        new_ssm.append(nc)
    ssm_cache = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *new_ssm)
    attn_cache = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *new_attn)
    return x, {"ssm": ssm_cache, "attn": attn_cache}


def hybrid_prefill(x, layers: dict, cfg: ModelConfig, rt: Runtime,
                   cache: dict, pos) -> Tuple[jnp.ndarray, dict]:
    """Prefill: full-sequence SSM forward (final states captured) + cache fill
    for the shared attention applications."""
    from repro.models.mamba2 import ssm_block_prefill  # local import (cycle)
    every = cfg.shared_attn_every
    n_app = n_applications(cfg)
    rem = cfg.num_layers - n_app * every
    positions = pos + jnp.arange(x.shape[1])[None, :]
    positions = jnp.broadcast_to(positions, x.shape[:2]).astype(jnp.int32)
    new_ssm, new_attn = [], []

    def scan_prefill(xc, seg_p, seg_c):
        def body(xcc, inp):
            p_l, c_l = inp
            xcc, nc = ssm_block_prefill(xcc, p_l, cfg, rt, c_l)
            return xcc, nc
        return jax.lax.scan(body, xc, (seg_p, seg_c))

    for i in range(n_app):
        seg_p = _tree_slice(layers["ssm_layers"], i * every, (i + 1) * every)
        seg_c = _tree_slice(cache["ssm"], i * every, (i + 1) * every)
        x, nc = scan_prefill(x, seg_p, seg_c)
        new_ssm.append(nc)
        h = rmsnorm(x, layers["shared"]["ln1"], cfg.norm_eps)
        attn_cache_i = jax.tree.map(lambda a: a[i], cache["attn"])
        a_out, attn_cache_i = cached_attention(
            h, layers["shared"]["attn"], cfg, rt, attn_cache_i, pos)
        x = x + a_out
        h = rmsnorm(x, layers["shared"]["ln2"], cfg.norm_eps)
        x = x + mlp(h, layers["shared"]["mlp"], cfg, rt)
        new_attn.append(attn_cache_i)
    if rem:
        seg_p = _tree_slice(layers["ssm_layers"], n_app * every, cfg.num_layers)
        seg_c = _tree_slice(cache["ssm"], n_app * every, cfg.num_layers)
        x, nc = scan_prefill(x, seg_p, seg_c)
        new_ssm.append(nc)
    ssm_cache = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *new_ssm)
    attn_cache = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *new_attn)
    return x, {"ssm": ssm_cache, "attn": attn_cache}
