"""Mamba-2 (SSD) block: in_proj -> causal depthwise conv -> SSD -> gated norm
-> out_proj. Full-sequence (chunked scan / Pallas kernel) and single-token
recurrent decode paths. [arXiv:2405.21060]
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.models.layers import causal_depthwise_conv, dense_init, gated_rmsnorm, rmsnorm
from repro.models.runtime import Runtime


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    H = s.n_heads(cfg.d_model)
    return s, di, H, s.head_dim, s.state_dim


def init_ssm_block(key, cfg: ModelConfig, stack: tuple = ()) -> dict:
    s, di, H, P, N = _dims(cfg)
    D = cfg.d_model
    conv_ch = di + 2 * N
    proj_out = 2 * di + 2 * N + H          # z, x, B, C, dt
    ks = jax.random.split(key, 4)
    return {
        "ln": jnp.zeros((*stack, D)),
        "in_proj": dense_init(ks[0], (*stack, D, proj_out)),
        "conv_w": dense_init(ks[1], (*stack, s.conv_width, conv_ch), scale=0.3),
        "conv_b": jnp.zeros((*stack, conv_ch)),
        "A_log": jnp.broadcast_to(
            jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)), (*stack, H)).copy(),
        "D": jnp.ones((*stack, H)),
        "dt_bias": jnp.broadcast_to(
            jnp.log(jnp.expm1(0.01 * jnp.ones(H))), (*stack, H)).copy(),
        "norm": jnp.zeros((*stack, di)),
        "out_proj": dense_init(ks[2], (*stack, di, D)),
    }


def _split_proj(proj, cfg: ModelConfig):
    _, di, H, _, N = _dims(cfg)
    z = proj[..., :di]
    x = proj[..., di:2 * di]
    Bm = proj[..., 2 * di:2 * di + N]
    Cm = proj[..., 2 * di + N:2 * di + 2 * N]
    dt = proj[..., 2 * di + 2 * N:]
    return z, x, Bm, Cm, dt


def _constrain_heads(xh: jnp.ndarray, rt: Runtime) -> jnp.ndarray:
    """SSM tensor parallelism: SSD heads over `model`, batch over dp — each
    head's (P, N) recurrence is independent, so this is the clean TP axis
    (B/C are head-shared and stay replicated)."""
    if rt.mesh_axes is None or not rt.opt_ssm_head_tp:
        return xh
    from jax.sharding import PartitionSpec as P_

    axes = rt.mesh_axes
    dp = tuple(a for a in ("pod", "data") if a in axes)
    dp_size = 1
    for a in dp:
        dp_size *= axes[a]
    model = axes.get("model", 1)
    B, _, H = xh.shape[:3]
    batch_axes = dp if (dp_size > 1 and B % dp_size == 0) else None
    head_axes = "model" if (model > 1 and H % model == 0) else None
    spec = (P_(batch_axes, None, head_axes, None) if xh.ndim == 4
            else P_(batch_axes, None, head_axes))
    return jax.lax.with_sharding_constraint(xh, spec)


def ssm_block(x: jnp.ndarray, p: dict, cfg: ModelConfig, rt: Runtime
              ) -> jnp.ndarray:
    """Full-sequence forward. x (B, S, D) -> (B, S, D) residual added."""
    s, di, H, P, N = _dims(cfg)
    B, S, D = x.shape
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    proj = h @ p["in_proj"].astype(rt.compute_dtype)
    z, xs, Bm, Cm, dt = _split_proj(proj, cfg)

    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out = causal_depthwise_conv(conv_in, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = (conv_out[..., :di], conv_out[..., di:di + N],
                  conv_out[..., di + N:])

    xh = _constrain_heads(xs.reshape(B, S, H, P), rt)
    dtv = jax.nn.softplus(dt.astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, _ = ssd_ops.ssd(xh, dtv, A, Bm, Cm, p["D"], chunk=rt.ssd_chunk,
                       use_pallas=rt.use_pallas, interpret=rt.interpret)
    y = y.reshape(B, S, di)
    y = gated_rmsnorm(y, z, p["norm"], cfg.norm_eps)
    return x + y @ p["out_proj"].astype(rt.compute_dtype)


def ssm_block_prefill(x: jnp.ndarray, p: dict, cfg: ModelConfig, rt: Runtime,
                      cache_l: dict) -> Tuple[jnp.ndarray, dict]:
    """Full-sequence forward that also captures the decode cache (final SSD
    state + conv tail). x (B, S, D)."""
    s, di, H, P, N = _dims(cfg)
    B, S, D = x.shape
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    proj = h @ p["in_proj"].astype(rt.compute_dtype)
    z, xs, Bm, Cm, dt = _split_proj(proj, cfg)

    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)
    conv_out = causal_depthwise_conv(conv_in, p["conv_w"], p["conv_b"])
    K = s.conv_width
    if S >= K - 1:
        new_conv = conv_in[:, S - (K - 1):, :].astype(rt.compute_dtype)
    else:
        new_conv = jnp.concatenate(
            [cache_l["conv"][:, S:], conv_in.astype(rt.compute_dtype)], axis=1)
    xs, Bm, Cm = (conv_out[..., :di], conv_out[..., di:di + N],
                  conv_out[..., di + N:])
    xh = _constrain_heads(xs.reshape(B, S, H, P), rt)
    dtv = jax.nn.softplus(dt.astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, hT = ssd_ops.ssd(xh, dtv, A, Bm, Cm, p["D"], chunk=rt.ssd_chunk,
                        use_pallas=rt.use_pallas, interpret=rt.interpret)
    y = y.reshape(B, S, di)
    y = gated_rmsnorm(y, z, p["norm"], cfg.norm_eps)
    out = x + y @ p["out_proj"].astype(rt.compute_dtype)
    return out, {"conv": new_conv, "ssd": hT}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_ssm_cache(cfg: ModelConfig, batch: int, n_layers: int, rt: Runtime
                   ) -> dict:
    s, di, H, P, N = _dims(cfg)
    conv_ch = di + 2 * N
    return {
        "conv": jnp.zeros((n_layers, batch, s.conv_width - 1, conv_ch),
                          rt.compute_dtype),
        "ssd": jnp.zeros((n_layers, batch, H, P, N), jnp.float32),
    }


def ssm_block_decode(x: jnp.ndarray, p: dict, cfg: ModelConfig, rt: Runtime,
                     cache_l: dict) -> Tuple[jnp.ndarray, dict]:
    """Single-token recurrent step. x (B, 1, D)."""
    s, di, H, P, N = _dims(cfg)
    B = x.shape[0]
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    proj = h @ p["in_proj"].astype(rt.compute_dtype)
    z, xs, Bm, Cm, dt = _split_proj(proj, cfg)

    conv_in = jnp.concatenate([xs, Bm, Cm], axis=-1)       # (B, 1, ch)
    conv_out = causal_depthwise_conv(
        conv_in, p["conv_w"], p["conv_b"], state=cache_l["conv"])
    new_conv = jnp.concatenate([cache_l["conv"][:, 1:], conv_in], axis=1)

    xs, Bm, Cm = (conv_out[..., :di], conv_out[..., di:di + N],
                  conv_out[..., di + N:])
    xh = xs.reshape(B, H, P)
    dtv = jax.nn.softplus(dt.astype(jnp.float32)
                          + p["dt_bias"].astype(jnp.float32))[:, 0]   # (B, H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, new_state = ssd_ops.ssd_decode_step(
        cache_l["ssd"], xh, dtv, A, Bm[:, 0], Cm[:, 0], p["D"])
    y = y.reshape(B, 1, di)
    y = gated_rmsnorm(y, z, p["norm"], cfg.norm_eps)
    out = x + y @ p["out_proj"].astype(rt.compute_dtype)
    return out, {"conv": new_conv, "ssd": new_state}
