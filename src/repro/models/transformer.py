"""Decoder stacks (dense + MoE) with scan-over-layers and per-layer remat.

One block implementation serves dense (llama/qwen/smollm), local:global
patterned (gemma3), MoE (mixtral/grok) and VLM-decoder (paligemma) archs.
Params are stacked along a leading L dim so the stack is a single
`jax.lax.scan` — compile time is O(1) in depth.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import (
    cached_attention,
    init_attention,
    init_kv_cache,
    self_attention,
)
from repro.models.layers import init_mlp, mlp, rmsnorm
from repro.models.moe import init_moe, moe_mlp
from repro.models.runtime import Runtime


def global_flags(cfg: ModelConfig, n_layers: int) -> Optional[jnp.ndarray]:
    """(L,) bool: True where the layer uses global (full) attention."""
    if cfg.local_global_pattern is None:
        return None
    loc, glob = cfg.local_global_pattern
    period = loc + glob
    idx = jnp.arange(n_layers)
    return (idx % period) >= loc


def init_decoder_layers(key, cfg: ModelConfig, n_layers: int) -> dict:
    ks = jax.random.split(key, 2)
    stack = (n_layers,)
    p = {
        "ln1": jnp.zeros((n_layers, cfg.d_model)),
        "attn": init_attention(ks[0], cfg, stack),
        "ln2": jnp.zeros((n_layers, cfg.d_model)),
    }
    if cfg.family == "moe":
        p["moe"] = init_moe(ks[1], cfg, stack)
    else:
        p["mlp"] = init_mlp(ks[1], cfg, cfg.d_ff, stack)
    return p


def _attn_with_pattern(h, p_l, cfg: ModelConfig, rt: Runtime, positions,
                       flag, prefix_len):
    """Dispatch local(window) vs global attention on a traced per-layer flag."""
    if flag is None:
        return self_attention(h, p_l, cfg, rt, positions,
                              window=cfg.sliding_window, prefix_len=prefix_len)
    return jax.lax.cond(
        flag,
        lambda hh: self_attention(hh, p_l, cfg, rt, positions,
                                  window=None, prefix_len=prefix_len),
        lambda hh: self_attention(hh, p_l, cfg, rt, positions,
                                  window=cfg.sliding_window,
                                  prefix_len=prefix_len),
        h)


def decoder_block(x, p_l, cfg: ModelConfig, rt: Runtime, positions,
                  flag, prefix_len: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pre-norm block. Returns (x, aux_loss)."""
    h = rmsnorm(x, p_l["ln1"], cfg.norm_eps)
    x = x + _attn_with_pattern(h, p_l["attn"], cfg, rt, positions, flag,
                               prefix_len)
    h = rmsnorm(x, p_l["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        out, aux = moe_mlp(h, p_l["moe"], cfg, rt)
    else:
        out, aux = mlp(h, p_l["mlp"], cfg, rt), jnp.float32(0.0)
    return x + out, aux


def decoder_stack(x, layers: dict, cfg: ModelConfig, rt: Runtime, positions,
                  n_layers: int, prefix_len: int = 0
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Scan the stack. x (B, S, D) -> (x, total_aux_loss)."""
    flags = global_flags(cfg, n_layers)

    def body(carry, inp):
        xc, aux = carry
        p_l, flag = inp
        xc, a = decoder_block(xc, p_l, cfg, rt, positions, flag, prefix_len)
        return (xc, aux + a), None

    if rt.remat == "block":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    xs = (layers, flags if flags is not None
          else jnp.zeros((n_layers,), jnp.int32))
    if flags is None:
        def body_noflag(carry, p_l):
            return body(carry, (p_l, None))
        bodyfn, xs = body_noflag, layers
        if rt.remat == "block":
            # body already rematted; wrap shim only
            pass
    else:
        bodyfn = body

    (x, aux), _ = jax.lax.scan(bodyfn, (x, jnp.float32(0.0)), xs)
    return x, aux


# ---------------------------------------------------------------------------
# decode (one or few tokens against per-layer caches)
# ---------------------------------------------------------------------------


def init_decoder_cache(cfg: ModelConfig, batch: int, max_len: int,
                       n_layers: int, rt: Runtime) -> dict:
    window = cfg.sliding_window if cfg.local_global_pattern is None else None
    # patterned archs keep full-length caches in the baseline (see DESIGN §5)
    return init_kv_cache(cfg, batch, max_len, n_layers, rt, window=window)


def decoder_block_decode(x, p_l, cfg: ModelConfig, rt: Runtime, cache_l,
                         pos, flag, prefix_len: int = 0
                         ) -> Tuple[jnp.ndarray, dict, jnp.ndarray]:
    h = rmsnorm(x, p_l["ln1"], cfg.norm_eps)
    if flag is None:
        a_out, cache_l = cached_attention(h, p_l["attn"], cfg, rt, cache_l,
                                          pos, window=cfg.sliding_window,
                                          prefix_len=prefix_len)
    else:
        a_out, cache_l = jax.lax.cond(
            flag,
            lambda hh, cc: cached_attention(hh, p_l["attn"], cfg, rt, cc, pos,
                                            window=None,
                                            prefix_len=prefix_len),
            lambda hh, cc: cached_attention(hh, p_l["attn"], cfg, rt, cc, pos,
                                            window=cfg.sliding_window,
                                            prefix_len=prefix_len),
            h, cache_l)
    x = x + a_out
    h = rmsnorm(x, p_l["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        out, aux = moe_mlp(h, p_l["moe"], cfg, rt)
    else:
        out, aux = mlp(h, p_l["mlp"], cfg, rt), jnp.float32(0.0)
    return x + out, cache_l, aux


def decoder_stack_decode(x, layers: dict, cfg: ModelConfig, rt: Runtime,
                         cache: dict, pos, n_layers: int,
                         prefix_len: int = 0) -> Tuple[jnp.ndarray, dict]:
    flags = global_flags(cfg, n_layers)

    def body(xc, inp):
        if flags is None:
            p_l, cache_l = inp
            flag = None
        else:
            p_l, cache_l, flag = inp
        xc, cache_l, _ = decoder_block_decode(xc, p_l, cfg, rt, cache_l, pos,
                                              flag, prefix_len)
        return xc, cache_l

    xs = (layers, cache) if flags is None else (layers, cache, flags)
    x, new_cache = jax.lax.scan(body, x, xs)
    return x, new_cache
