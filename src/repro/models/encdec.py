"""Whisper-style encoder-decoder backbone (conv/mel frontend is a stub: the
assignment's `input_specs()` feeds precomputed frame embeddings).

Encoder: bidirectional self-attn stack over frames.
Decoder: causal self-attn + cross-attn + MLP, scanned, cache-able.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import (
    cached_attention,
    cross_attention,
    encode_cross_kv,
    init_attention,
    init_cross_attention,
    init_kv_cache,
    self_attention,
)
from repro.models.layers import init_mlp, mlp, rmsnorm
from repro.models.runtime import Runtime


def init_encoder_layers(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 2)
    stack = (cfg.encoder_layers,)
    return {
        "ln1": jnp.zeros((cfg.encoder_layers, cfg.d_model)),
        "attn": init_attention(ks[0], cfg, stack),
        "ln2": jnp.zeros((cfg.encoder_layers, cfg.d_model)),
        "mlp": init_mlp(ks[1], cfg, cfg.d_ff, stack),
    }


def init_decoder_layers_xattn(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 3)
    stack = (cfg.num_layers,)
    return {
        "ln1": jnp.zeros((cfg.num_layers, cfg.d_model)),
        "attn": init_attention(ks[0], cfg, stack),
        "lnx": jnp.zeros((cfg.num_layers, cfg.d_model)),
        "xattn": init_cross_attention(ks[1], cfg, stack),
        "ln2": jnp.zeros((cfg.num_layers, cfg.d_model)),
        "mlp": init_mlp(ks[2], cfg, cfg.d_ff, stack),
    }


def encode(frames: jnp.ndarray, enc_layers: dict, cfg: ModelConfig,
           rt: Runtime) -> jnp.ndarray:
    """frames (B, Senc, D) precomputed embeddings -> encoder output."""
    B, Senc, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(Senc)[None], (B, Senc)).astype(jnp.int32)
    x = frames.astype(rt.compute_dtype)

    def body(xc, p_l):
        h = rmsnorm(xc, p_l["ln1"], cfg.norm_eps)
        xc = xc + self_attention(h, p_l["attn"], cfg, rt, positions,
                                 causal=False)
        h = rmsnorm(xc, p_l["ln2"], cfg.norm_eps)
        return xc + mlp(h, p_l["mlp"], cfg, rt), None

    if rt.remat == "block":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    x, _ = jax.lax.scan(body, x, enc_layers)
    return x


def decode_stack(x, dec_layers: dict, cfg: ModelConfig, rt: Runtime,
                 positions, enc_out) -> jnp.ndarray:
    """Training/teacher-forcing decoder. Cross K/V projected per layer."""

    def body(xc, p_l):
        h = rmsnorm(xc, p_l["ln1"], cfg.norm_eps)
        xc = xc + self_attention(h, p_l["attn"], cfg, rt, positions)
        h = rmsnorm(xc, p_l["lnx"], cfg.norm_eps)
        ek, ev = encode_cross_kv(enc_out, p_l["xattn"], cfg, rt)
        xc = xc + cross_attention(h, p_l["xattn"], cfg, rt, ek, ev)
        h = rmsnorm(xc, p_l["ln2"], cfg.norm_eps)
        return xc + mlp(h, p_l["mlp"], cfg, rt), None

    if rt.remat == "block":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    x, _ = jax.lax.scan(body, x, dec_layers)
    return x


# ---------------------------------------------------------------------------
# decode with cache
# ---------------------------------------------------------------------------


def init_encdec_cache(cfg: ModelConfig, batch: int, max_len: int, rt: Runtime
                      ) -> dict:
    hd = cfg.hd()
    return {
        "self": init_kv_cache(cfg, batch, max_len, cfg.num_layers, rt),
        "cross_k": jnp.zeros(
            (cfg.num_layers, batch, cfg.encoder_len, cfg.n_kv, hd),
            rt.compute_dtype),
        "cross_v": jnp.zeros(
            (cfg.num_layers, batch, cfg.encoder_len, cfg.n_kv, hd),
            rt.compute_dtype),
    }


def fill_cross_cache(enc_out, dec_layers: dict, cfg: ModelConfig, rt: Runtime,
                     cache: dict) -> dict:
    """Project encoder output into every decoder layer's cross K/V once."""

    def body(_, p_l):
        ek, ev = encode_cross_kv(enc_out, p_l["xattn"], cfg, rt)
        return None, (ek, ev)

    _, (eks, evs) = jax.lax.scan(body, None, dec_layers)
    return dict(cache, cross_k=eks, cross_v=evs)


def decode_stack_cached(x, dec_layers: dict, cfg: ModelConfig, rt: Runtime,
                        cache: dict, pos) -> Tuple[jnp.ndarray, dict]:
    def body(xc, inp):
        p_l, self_c, ek, ev = inp
        h = rmsnorm(xc, p_l["ln1"], cfg.norm_eps)
        a, self_c = cached_attention(h, p_l["attn"], cfg, rt, self_c, pos)
        xc = xc + a
        h = rmsnorm(xc, p_l["lnx"], cfg.norm_eps)
        xc = xc + cross_attention(h, p_l["xattn"], cfg, rt, ek, ev)
        h = rmsnorm(xc, p_l["ln2"], cfg.norm_eps)
        return xc + mlp(h, p_l["mlp"], cfg, rt), self_c

    x, new_self = jax.lax.scan(
        body, x, (dec_layers, cache["self"], cache["cross_k"], cache["cross_v"]))
    return x, dict(cache, self=new_self)
