"""Production mesh construction. A FUNCTION (not a module constant) so that
importing this module never touches jax device state.

Single pod:  (16, 16)      axes ("data", "model")   = 256 chips (one v5e pod)
Multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") = 512 chips
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import numpy as np


def make_mesh_shape(shape: Tuple[int, ...], axes: Tuple[str, ...],
                    devices: Optional[Sequence] = None):
    import jax
    from jax.sharding import Mesh

    n = math.prod(shape)
    devs = list(devices) if devices is not None else list(jax.devices())
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devs)} — the dry-run "
            "sets XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax")
    try:
        return jax.make_mesh(shape, axes, devices=devs[:n])
    except TypeError:
        arr = np.array(devs[:n]).reshape(shape)
        return Mesh(arr, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_shape(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU tests (1 device by default)."""
    return make_mesh_shape((data, model), ("data", "model"))
