import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
# ShapeDtypeStruct stand-ins (no allocation), record memory/cost analysis and
# roofline terms. The two lines above MUST stay first — jax locks the device
# count on first init.
#
# Usage:
#     PYTHONPATH=src python -m repro.launch.dryrun                 # full sweep
#     ... --arch smollm-135m --shape train_4k --mesh single
#     ... --variant <name>      # hillclimb variants (see VARIANTS)

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPE_IDS, get_config, get_shape
from repro.dist import sharding as sh
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import model_flops, roofline_from_artifacts
from repro.models import model as M
from repro.models.runtime import Runtime
from repro.serve.serve_step import make_decode_step, make_prefill_step
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import default_microbatches, make_train_step

# archs whose attention is fully quadratic: long_500k is intractable by
# construction (see DESIGN.md §5) and recorded as SKIP(attn)
SKIP_LONG = {"whisper-small", "qwen1.5-32b", "qwen2-0.5b", "smollm-135m",
             "grok-1-314b", "paligemma-3b"}

# hillclimb variants (EXPERIMENTS.md §Perf documents each).
# "baseline" == paper-faithful lowering: scatter cache updates, naive flat
# TP on SSM projections, fp32 grad accumulation. "opt" variants layer the
# beyond-paper changes on top; each is measured separately in §Perf.
VARIANTS: Dict[str, Dict] = {
    "baseline": {"opt_cache_dus": False, "opt_ssm_head_tp": False},
    # OPT-A (decode): dynamic-update-slice cache writes keep seq-sharded
    # KV caches sharded (fixes the 291 GB/chip all-gather per decode step)
    "opt_dus": {},
    # OPT-A + ring-buffer KV caches for sliding-window layers
    "opt_ring": {"ring_cache": True},
    # OPT-B (SSM): head-dim tensor parallelism for SSD (fixes the packed
    # in_proj reshard storm: 208 collective-permutes / 1.2 TB per step)
    "opt_ssm": {},
    # OPT-B + smaller SSD chunk (decay-matrix HBM footprint ~ S x Q x H)
    "opt_ssm_q64": {"ssd_chunk": 64},
    "opt_ssm_q32": {"ssd_chunk": 32},
    # OPT-C (MoE train): fewer grad-accumulation microbatches cut the
    # per-microbatch FSDP re-gather + grad-reduction traffic
    "opt_mb8": {"mb_scale": 0.5},
    "opt_mb4": {"mb_scale": 0.25},
    # OPT-C + bf16 gradient accumulation (halves reduction bytes)
    "opt_mb4_bf16g": {"mb_scale": 0.25, "grad_bf16": True},
    # OPT-C + MoE dispatch buffer sharded over model too (the dispatch
    # scatter's all-reduce is the dominant grok collective)
    "opt_mb4_bufmod": {"mb_scale": 0.25, "moe_buf_model": True},
    # OPT-D (prefill): bf16 score einsums with fp32 MXU accumulation — no
    # materialized fp32 Q/K/V copies in the chunked prefill path
    "opt_bf16s": {"bf16_scores": True},
    # memory-for-compute: no per-layer remat
    "no_remat": {"remat": "none"},
}


def _mesh_tag(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def build_runtime(cfg, mesh, variant: Dict) -> Runtime:
    dp = sh.dp_axes(mesh)
    moe_spec = P(None, dp, None) if cfg.family == "moe" else None
    if cfg.family == "moe" and variant.get("moe_buf_model"):
        moe_spec = P(None, dp, "model")
    mesh_axes = {a: int(mesh.shape[a]) for a in mesh.axis_names}
    return Runtime(
        compute_dtype=jnp.bfloat16,
        remat=variant.get("remat", "block"),
        ring_cache=variant.get("ring_cache", False),
        ssd_chunk=variant.get("ssd_chunk", 128),
        moe_buf_spec=moe_spec,
        mesh_axes=mesh_axes,
        opt_cache_dus=variant.get("opt_cache_dus", True),
        opt_ssm_head_tp=variant.get("opt_ssm_head_tp", True),
        opt_bf16_scores=variant.get("bf16_scores", False),
        grad_acc_dtype=(jnp.bfloat16 if variant.get("grad_bf16")
                        else jnp.float32),
    )


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               variant_name: str = "baseline") -> Dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    variant = VARIANTS[variant_name]
    result: Dict = {
        "arch": arch, "shape": shape_name, "mesh": _mesh_tag(multi_pod),
        "variant": variant_name, "status": "ok",
    }

    if shape_name == "long_500k" and arch in SKIP_LONG:
        result["status"] = "SKIP(attn)"
        result["reason"] = ("full quadratic attention; long-context decode "
                            "intractable by construction (DESIGN.md §5)")
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rt = build_runtime(cfg, mesh, variant)

    key = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(lambda k: M.init_params(k, cfg), key)
    # legacy vs head-TP SSM variants share the weight layout; the variant
    # difference is the Runtime's activation constraints (opt_ssm_head_tp)
    p_spec = sh.param_specs(mesh, params_sds)
    p_shard = sh.to_shardings(mesh, p_spec)
    batch_sds = M.input_specs(cfg, shape)
    b_spec = sh.batch_specs(mesh, batch_sds)
    b_shard = sh.to_shardings(mesh, b_spec)

    t0 = time.time()
    if shape.kind == "train":
        mb = default_microbatches(arch, shape.seq_len, shape.global_batch)
        mb = max(1, int(mb * variant.get("mb_scale", 1.0)))
        result["microbatches"] = mb
        opt = AdamWConfig()
        step = make_train_step(cfg, rt, opt, microbatches=mb)
        opt_sds = jax.eval_shape(init_opt_state, params_sds)
        o_spec = sh.opt_state_specs(mesh, opt_sds, p_spec)
        o_shard = sh.to_shardings(mesh, o_spec)
        with mesh:
            jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_sds, opt_sds, batch_sds)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, rt, max_len=shape.seq_len)
        with mesh:
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(params_sds, batch_sds)
    else:  # decode
        step = make_decode_step(cfg, rt)
        cache_sds = jax.eval_shape(
            lambda: M.init_cache(cfg, rt, shape.global_batch, shape.seq_len))
        c_spec = sh.cache_specs(mesh, cache_sds)
        c_shard = sh.to_shardings(mesh, c_spec)
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
        with mesh:
            jitted = jax.jit(
                step,
                in_shardings=(p_shard, b_shard["tokens"],
                              sh.to_shardings(mesh, P()), c_shard),
                donate_argnums=(3,))
            lowered = jitted.lower(params_sds, batch_sds["tokens"], pos_sds,
                                   cache_sds)
    result["lower_s"] = round(time.time() - t0, 2)

    t0 = time.time()
    compiled = lowered.compile()
    result["compile_s"] = round(time.time() - t0, 2)

    # --- memory ------------------------------------------------------------
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            result["memory"] = {
                k: int(getattr(ma, k)) for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(ma, k)}
    except Exception as e:  # CPU backend may not support it
        result["memory_error"] = str(e)

    # --- analytic per-device state bytes (params/opt/cache after sharding) --
    def sharded_bytes(sds_tree, spec_tree):
        import math as _m
        total = 0
        for sds, spec in zip(jax.tree.leaves(sds_tree),
                             jax.tree.leaves(spec_tree,
                                             is_leaf=lambda x: isinstance(x, P))):
            shards = 1
            for entry in spec:
                if entry is None:
                    continue
                axes = (entry,) if isinstance(entry, str) else entry
                shards *= _m.prod(mesh.shape[a] for a in axes)
            total += sds.size * sds.dtype.itemsize // shards
        return total

    state = sharded_bytes(params_sds, p_spec)
    if shape.kind == "train":
        state += 2 * sharded_bytes(params_sds, p_spec)  # adam m, v (fp32)
    if shape.kind == "decode":
        state += sharded_bytes(cache_sds, c_spec)
    result["state_bytes_per_chip"] = int(state)

    # --- cost + roofline -----------------------------------------------------
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    result["cost"] = {k: float(v) for k, v in cost.items()
                      if isinstance(v, (int, float))
                      and k in ("flops", "bytes accessed", "transcendentals",
                                "optimal_seconds")}
    hlo = compiled.as_text()
    rl = roofline_from_artifacts(cost, hlo, n_chips,
                                 model_flops(cfg, shape))
    result["roofline"] = rl.to_dict()
    return result


def run(args) -> int:
    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPE_IDS)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                tag = f"{arch}__{shape_name}__{_mesh_tag(multi_pod)}__{args.variant}"
                out_path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(out_path):
                    print(f"[skip existing] {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                t0 = time.time()
                try:
                    res = lower_cell(arch, shape_name, multi_pod=multi_pod,
                                     variant_name=args.variant)
                except Exception:
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": _mesh_tag(multi_pod),
                           "variant": args.variant, "status": "FAIL",
                           "error": traceback.format_exc()}
                    failures += 1
                res["wall_s"] = round(time.time() - t0, 2)
                with open(out_path, "w") as f:
                    json.dump(res, f, indent=1)
                print(f"    -> {res['status']} ({res['wall_s']}s)", flush=True)
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="baseline", choices=list(VARIANTS))
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    failures = run(args)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
