"""Serving launcher: loads (or initializes) a model, spins up the
continuous-batching engine, runs a batch of synthetic requests and reports
throughput/latency stats.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --requests 8 --slots 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import model as M
from repro.models.runtime import CPU_TEST, Runtime
from repro.serve.engine import Request, ServeEngine
from repro.train import checkpoint as ckpt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore params from the latest checkpoint here")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if cfg.family in ("encdec", "vlm"):
        raise SystemExit("engine serves decoder-only families; use "
                         "serve_step.make_prefill_step/make_decode_step "
                         "directly for encdec/vlm")
    rt = CPU_TEST if args.reduced else Runtime()
    if args.ckpt_dir:
        restored = ckpt.restore_latest(args.ckpt_dir)
        if restored is None:
            raise SystemExit(f"no checkpoint under {args.ckpt_dir}")
        params_np, _, meta = restored
        params = ckpt.to_device(params_np)
        print(f"[serve] restored step {meta['step']} from {args.ckpt_dir}")
    else:
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        print("[serve] random-init params (pass --ckpt-dir for trained)")

    engine = ServeEngine(cfg, rt, params, slots=args.slots,
                         max_len=args.max_len)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=4 + (i % 5) * 3),
                    max_new_tokens=args.max_new,
                    temperature=args.temperature)
            for i in range(args.requests)]
    t0 = time.time()
    outs = engine.run(reqs)
    dt = time.time() - t0
    total = sum(len(v) for v in outs.values())
    print(f"[serve] {len(reqs)} requests -> {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s, {args.slots} slots)")
    for rid in sorted(outs)[:4]:
        print(f"  req {rid}: {outs[rid][:10]}{'...' if len(outs[rid]) > 10 else ''}")
    return outs


if __name__ == "__main__":
    main()
