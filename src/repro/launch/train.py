"""Production training launcher: mesh + sharded params + fault-tolerant
supervisor loop. On real TPU pods, run one process per host; on CPU this
drives the same code path with a 1-device mesh.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 300 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.dist import sharding as sh
from repro.dist.fault import TrainSupervisor
from repro.launch.mesh import make_mesh_shape
from repro.models import model as M
from repro.models.runtime import Runtime
from repro.train.data import MarkovLMDataset
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CI/demo)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", type=int, default=1,
                    help="mesh data axis (1 on single device)")
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--fail-at", default="",
                    help="comma-separated step indices at which to inject a "
                         "node failure (fault-tolerance demo/smoke test)")
    args = ap.parse_args(argv)
    try:
        fail_at = {int(s) for s in args.fail_at.split(",") if s.strip()}
    except ValueError:
        ap.error(f"--fail-at expects comma-separated step indices, "
                 f"got {args.fail_at!r}")
    bad = {s for s in fail_at if not 0 <= s < args.steps}
    if bad:
        ap.error(f"--fail-at steps {sorted(bad)} outside [0, {args.steps}): "
                 "the injected failure would never fire")

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if jax.process_count() > 1:
        # per-host batch assembly (MarkovLMDataset host_id/num_hosts +
        # make_array_from_process_local_data) and multi-writer checkpointing
        # are not wired up yet; fail loudly rather than train on broken
        # multi-process state
        raise SystemExit("multi-process launch is not supported yet: run "
                         "one process with all local devices")
    mesh = make_mesh_shape((args.data, args.model), ("data", "model"))
    rt = Runtime(compute_dtype=jnp.float32 if args.model * args.data == 1
                 else jnp.bfloat16,
                 remat="none" if args.reduced else "block",
                 mesh_axes={a: int(mesh.shape[a]) for a in mesh.axis_names}
                 if args.data * args.model > 1 else None)
    opt = AdamWConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                      total_steps=args.steps)
    ds = MarkovLMDataset(vocab=cfg.vocab, seq_len=args.seq, batch=args.batch,
                         seed=0)
    print(f"[train] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch}x{args.seq}, "
          f"entropy floor ~{ds.conditional_entropy():.3f} nats")

    rng = jax.random.PRNGKey(0)

    def init_fn():
        params = M.init_params(rng, cfg)
        return params, init_opt_state(params)

    step_raw = make_train_step(cfg, rt, opt, microbatches=args.microbatches)
    with mesh:
        params_sds = jax.eval_shape(lambda k: M.init_params(k, cfg), rng)
        p_spec = sh.param_specs(mesh, params_sds)
        p_shard = sh.to_shardings(mesh, p_spec)
        o_shard = sh.to_shardings(
            mesh, sh.opt_state_specs(mesh, None, p_spec))
        batch_sds = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), ds.batch_at(0))
        b_shard = sh.to_shardings(mesh, sh.batch_specs(mesh, batch_sds))
        # out_shardings must pin params/opt to the same layout as the inputs:
        # the step's outputs are fed straight back in (donated), and GSPMD
        # would otherwise pick its own output layout and reject the next call
        step_fn = jax.jit(step_raw, in_shardings=(p_shard, o_shard, b_shard),
                          out_shardings=(p_shard, o_shard, None),
                          donate_argnums=(0, 1))

        t_start = time.time()
        last = {"t": t_start, "step": 0, "seen": 0}

        def batches(step):
            b = ds.batch_at(step)
            return {k: jnp.asarray(v) for k, v in b.items()}

        def step_logged(params, opt_state, batch):
            t_before = time.time()
            params, opt_state, m = step_fn(params, opt_state, batch)
            s = int(opt_state["step"])
            if last["seen"] == 0:       # first step this process — may be a
                # cross-process resume at step N; window starts at this
                # step, not at process start (restore time is not tok/s)
                last["t"], last["step"] = t_before, s - 1
            elif s <= last["seen"]:     # supervisor rolled back and re-ran
                # window restarts after this step: its tokens aren't counted
                # (last["step"] = s), so its time mustn't be either
                last["t"], last["step"] = time.time(), s
            last["seen"] = s
            if s % args.log_every == 0:
                dt = time.time() - last["t"]
                tps = (s - last["step"]) * args.batch * args.seq / max(dt, 1e-9)
                print(f"  step {s:5d} loss {float(m['loss']):.4f} "
                      f"lr {float(m['lr']):.2e} gnorm "
                      f"{float(m['grad_norm']):.2f} tok/s {tps:.0f}",
                      flush=True)
                last["t"], last["step"] = time.time(), s
            return params, opt_state, m

        def injector(step):
            if step in fail_at:
                fail_at.discard(step)
                print(f"  [fault] injected failure before step {step}; "
                      "rolling back to latest checkpoint (fresh init if "
                      "none)", flush=True)
                return True
            return False

        sup = TrainSupervisor(ckpt_dir=args.ckpt_dir,
                              ckpt_every=args.ckpt_every,
                              run_tag=cfg.name,
                              shardings=(p_shard, o_shard))
        out = sup.run(init_fn, step_logged, batches, total_steps=args.steps,
                      failure_injector=injector if fail_at else None)
    final = (f"final loss {out['metrics'][-1]['loss']:.4f}" if out["metrics"]
             else "already complete (resumed at final checkpoint)")
    print(f"[train] done in {time.time()-t_start:.0f}s; {final}; "
          f"restarts {out['restarts']}; slow steps {out['slow_steps']}")
    return out


if __name__ == "__main__":
    main()
