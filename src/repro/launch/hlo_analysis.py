"""Trip-count-aware HLO analysis.

XLA's HloCostAnalysis counts a while-loop body ONCE — our stacks are
`lax.scan`s (layers, microbatches, q-chunks), so aggregate cost_analysis()
under-counts flops/bytes/collectives by the trip counts. This module parses
the post-optimization HLO text into computations, resolves while-loop trip
counts (from `backend_config={"known_trip_count":{"n":...}}`, falling back to
the condition computation's bound constant), walks the call graph multiplying
by trips, and accumulates:

  - dot flops (2 x prod(result dims) x K from dot shapes)
  - HBM bytes (per top-level op: result + operand bytes via symbol table;
    fusion bodies are excluded — only fusion boundaries touch HBM)
  - collective moved-bytes (ring accounting, per replica-group size)

Known limitations (documented in EXPERIMENTS.md): CPU-backend fusion
boundaries differ from TPU so byte counts are an upper bound; elementwise
flops are ignored (<2% of transformer flops).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# TYPE is either a tuple `(...)` (no ')' occurs inside: shapes use []{} and
# /*index=N*/ comments) or a single array type
_OP_RE = re.compile(
    r"^\s*(?:ROOT )?%?([\w\.\-]+) = "
    r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*)) ([\w\-]+)\((.*)$")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CALL_TARGET_RE = re.compile(
    r"(?:calls|body|to_apply|computation)=\{?%?([\w\.\-]+)")
_COND_TARGET_RE = re.compile(r"condition=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVE_BASES = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    return [(dt, [int(d) for d in dims.split(",")] if dims else [])
            for dt, dims in _SHAPE_RE.findall(type_str)]


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str                      # everything after the open paren

    @property
    def operands_str(self) -> str:
        return self.rest.split(")")[0]


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[Op] = dataclasses.field(default_factory=list)


def parse_hlo(text: str) -> Tuple[Dict[str, "Computation"], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.rstrip()
        if not stripped:
            continue
        if not line.startswith((" ", "\t")) and stripped.endswith("{") \
                and ("->" in stripped or stripped.startswith(("ENTRY", "%"))):
            is_entry = stripped.startswith("ENTRY")
            head = stripped[6:] if is_entry else stripped
            name = head.lstrip("%").split(" ")[0].split("(")[0]
            cur = Computation(name, is_entry)
            comps[name] = cur
            if is_entry:
                entry = name
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            cur.ops.append(Op(m.group(1), m.group(2), m.group(3), m.group(4)))
    return comps, entry


def _trip_count(op: Op, comps: Dict[str, Computation]) -> int:
    m = _TRIP_RE.search(op.rest)
    if m:
        return int(m.group(1))
    cm = _COND_TARGET_RE.search(op.rest)
    if cm and cm.group(1) in comps:
        consts = []
        for o in comps[cm.group(1)].ops:
            mm = _CONST_RE.search(o.opcode + "(" + o.rest)
            if o.opcode == "constant" and mm:
                consts.append(int(mm.group(1)))
        if consts:
            return max(consts)
    return 1


def _group_size(rest: str) -> int:
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPLICIT_RE.search(rest)
    if m:
        return max(len([e for e in m.group(1).split(",") if e.strip()]), 1)
    return 1


@dataclasses.dataclass
class HloStats:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_moved: float = 0.0
    collective_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_count: Dict[str, int] = dataclasses.field(default_factory=dict)
    while_trips: Dict[str, int] = dataclasses.field(default_factory=dict)

    def to_dict(self):
        return dataclasses.asdict(self)


def _dot_flops(op: Op, symtab: Dict[str, str]) -> float:
    res = _shape_dims(op.type_str)
    if not res:
        return 0.0
    out_elems = 1
    for d in res[0][1]:
        out_elems *= d
    operands = _OPERAND_RE.findall(op.operands_str)
    k = 1
    m = _DOT_DIMS_RE.search(op.rest)
    if m and operands:
        dims = _shape_dims(symtab.get(operands[0], ""))
        if dims:
            ldims = dims[0][1]
            for ci in (int(c) for c in m.group(1).split(",") if c):
                if ci < len(ldims):
                    k *= ldims[ci]
    return 2.0 * out_elems * k


_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "while", "call", "conditional",
               "partition-id", "replica-id", "iota"}


def analyze(text: str) -> HloStats:
    comps, entry = parse_hlo(text)
    if entry is None:
        entry = next(iter(comps))

    symtabs = {cn: {op.name: op.type_str for op in c.ops}
               for cn, c in comps.items()}

    # computations reachable only as fusion bodies / reducers: exclude
    sub_bodies = set()
    for c in comps.values():
        for op in c.ops:
            if op.opcode in ("fusion", "reduce", "scatter", "sort",
                             "reduce-window", "select-and-scatter",
                             "all-reduce", "reduce-scatter"):
                for t in _CALL_TARGET_RE.findall(op.rest):
                    sub_bodies.add(t)

    stats = HloStats()

    def walk(comp_name: str, mult: float):
        c = comps.get(comp_name)
        if c is None:
            return
        symtab = symtabs[comp_name]
        for op in c.ops:
            oc = op.opcode
            if oc == "while":
                trips = _trip_count(op, comps)
                bm = re.search(r"body=\{?%?([\w\.\-]+)", op.rest)
                if bm:
                    stats.while_trips[bm.group(1)] = trips
                    walk(bm.group(1), mult * trips)
                continue
            if oc in ("call", "conditional"):
                for t in _CALL_TARGET_RE.findall(op.rest):
                    if t in comps and t not in sub_bodies:
                        walk(t, mult)
                if oc == "conditional":
                    # branches: branch_computations={%a, %b}
                    bm = re.search(r"branch_computations=\{([^}]*)\}", op.rest)
                    if bm:
                        for t in _OPERAND_RE.findall(bm.group(1)):
                            walk(t, mult)
                continue
            if oc in ("dot", "dot-general"):
                stats.dot_flops += mult * _dot_flops(op, symtab)
            base = next((b for b in COLLECTIVE_BASES
                         if oc == b or oc == b + "-start"), None)
            if base is not None:
                nbytes = _shape_bytes(op.type_str)
                if oc.endswith("-start") and op.type_str.startswith("("):
                    nbytes //= 2          # (operand, result) tuple
                k = _group_size(op.rest)
                ring = max(k - 1, 0) / max(k, 1)
                if base == "all-reduce":
                    moved = 2.0 * ring * nbytes
                elif base == "collective-permute":
                    moved = float(nbytes)
                else:
                    moved = ring * nbytes
                stats.collective_moved += mult * moved
                stats.collective_by_op[base] = (
                    stats.collective_by_op.get(base, 0.0) + mult * moved)
                stats.collective_count[base] = (
                    stats.collective_count.get(base, 0) + 1)
            if oc in _SKIP_BYTES or oc.endswith("-done"):
                continue
            nbytes = _shape_bytes(op.type_str)
            for operand in _OPERAND_RE.findall(op.operands_str):
                if operand in symtab:
                    nbytes += _shape_bytes(symtab[operand])
            stats.hbm_bytes += mult * nbytes

    walk(entry, 1.0)
    return stats
