"""Roofline-term derivation from a compiled dry-run artifact.

    compute_s    = HLO_FLOPs_per_chip / PEAK_FLOPS
    memory_s     = HLO_bytes_per_chip / HBM_BW
    collective_s = moved_bytes_per_chip / ICI_BW   (per-op ring accounting)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis(). Collective bytes
are NOT in cost_analysis: we parse the post-optimization HLO text and sum,
per collective op, the ring-algorithm bytes each chip moves:
    all-reduce          2 (k-1)/k x bytes
    all-gather            (k-1)/k x result_bytes
    reduce-scatter        (k-1)/k x input_bytes
    all-to-all            (k-1)/k x bytes
    collective-permute    bytes
with k = replica-group size parsed from either explicit or iota groups.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# `%name = TYPE all-reduce(...)` — TYPE may be a tuple of array types
_OP_RE = re.compile(
    r"= *((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*)) +"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    op: str
    count: int = 0
    bytes_total: int = 0          # raw tensor bytes across occurrences
    moved_bytes: float = 0.0      # ring-accounted per-chip bytes


def parse_collectives(hlo_text: str) -> Dict[str, CollectiveStats]:
    stats = {op: CollectiveStats(op) for op in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        if "-done(" in line:      # async pair: count only the -start
            continue
        nbytes = _type_bytes(type_str)
        k = _group_size(line)
        ring = max(k - 1, 0) / max(k, 1)
        if op == "all-reduce":
            moved = 2.0 * ring * nbytes
        elif op == "collective-permute":
            moved = float(nbytes)
        else:
            moved = ring * nbytes
        s = stats[op]
        s.count += 1
        s.bytes_total += nbytes
        s.moved_bytes += moved
    return stats


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        entries = [e for e in m.group(1).split(",") if e.strip()]
        return max(len(entries), 1)
    return 1


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    collective_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_total: float
    useful_ratio: float           # model_flops / (HLO flops x chips)
    collectives: Dict[str, Dict]

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


def roofline_from_artifacts(
    cost: Dict,
    hlo_text: str,
    n_chips: int,
    model_flops_total: float,
) -> Roofline:
    """Trip-count-aware terms via hlo_analysis (lax.scan bodies multiplied by
    their trip counts); raw cost_analysis kept by the caller for reference."""
    from repro.launch.hlo_analysis import analyze

    stats = analyze(hlo_text)
    flops = stats.dot_flops
    raw_bytes = stats.hbm_bytes
    coll_bytes = stats.collective_moved

    compute_s = flops / PEAK_FLOPS
    memory_s = raw_bytes / HBM_BW
    collective_s = coll_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = model_flops_total / max(flops * n_chips, 1.0)
    return Roofline(
        flops_per_chip=flops,
        hbm_bytes_per_chip=raw_bytes,
        collective_bytes_per_chip=coll_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops_total=model_flops_total,
        useful_ratio=useful,
        collectives={
            op: {"op": op,
                 "count": stats.collective_count.get(op, 0),
                 "moved_bytes": stats.collective_by_op.get(op, 0.0)}
            for op in stats.collective_by_op},
    )


def model_flops(cfg, shape) -> float:
    """Useful model FLOPs for the cell: 6·N·D for training, 2·N·D for
    inference forward (N = active params, D = tokens processed)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * n * tokens
