"""Config system: ModelConfig / ShapeConfig dataclasses + registry.

Every assigned architecture registers a `ModelConfig` here via its own module
(src/repro/configs/<arch>.py). Shapes live in `shapes.py`. The same configs
drive (a) the JAX runtime (models/, train/, serve/, launch/dryrun.py) and
(b) the Theseus DSE Workload Compiler (core/workload.py).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    # capacity factor used by the dropless-ish dispatch (dense dispatch in ref)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128          # N: SSD state size per head
    head_dim: int = 64            # P: channels per SSD head
    expand: int = 2               # d_inner = expand * d_model
    conv_width: int = 4           # depthwise causal conv width
    chunk: int = 128              # SSD chunk length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    n_heads: int                  # query heads (0 for attention-free)
    n_kv: int                     # KV heads (GQA); == n_heads for MHA
    d_ff: int
    vocab: int
    # --- attention details -------------------------------------------------
    head_dim: Optional[int] = None          # default d_model // n_heads
    qkv_bias: bool = False                  # qwen-style
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None    # None = full attention
    # pattern of local:global layers, e.g. gemma3 (5, 1): 5 local then 1 global
    local_global_pattern: Optional[Tuple[int, int]] = None
    # --- MoE / SSM ----------------------------------------------------------
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2-style): a shared attention block applied every k layers
    shared_attn_every: Optional[int] = None
    # --- enc-dec / multimodal -----------------------------------------------
    encoder_layers: int = 0                 # whisper
    encoder_len: int = 0                    # fixed frontend length (audio frames)
    prefix_len: int = 0                     # vlm: image patch tokens prepended
    tied_embeddings: bool = True
    norm_eps: float = 1e-6
    act: str = "silu"                       # silu | gelu
    glu: bool = True                        # gated MLP (SwiGLU etc.)

    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """True if long-context (500k) decode is tractable: SSM/hybrid or
        sliding-window-dominated attention."""
        if self.family in ("ssm", "hybrid"):
            return True
        if self.sliding_window is not None:
            return True
        if self.local_global_pattern is not None:
            return True
        return False

    def param_count(self) -> int:
        """Approximate parameter count (used for 6ND model-flops + DSE)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.num_layers
        hd = self.hd()
        n_q, n_kv = self.n_heads, self.n_kv
        total = V * D  # embedding
        if not self.tied_embeddings:
            total += V * D

        def attn_block() -> int:
            p = D * n_q * hd + 2 * D * n_kv * hd + n_q * hd * D
            if self.qkv_bias:
                p += (n_q + 2 * n_kv) * hd
            return p

        def mlp_block(dff: int) -> int:
            return (3 if self.glu else 2) * D * dff

        if self.family == "ssm":
            s = self.ssm
            di = s.d_inner(D)
            nh = s.n_heads(D)
            per = (D * (2 * di + 2 * s.state_dim + nh)  # in_proj(z,x,B,C,dt)
                   + s.conv_width * (di + 2 * s.state_dim)
                   + di * D + 2 * D)
            total += L * per
        elif self.family == "hybrid":
            s = self.ssm
            di = s.d_inner(D)
            per = (D * (2 * di + 2 * s.state_dim + s.n_heads(D))
                   + s.conv_width * (di + 2 * s.state_dim) + di * D + 2 * D)
            total += L * per + L * mlp_block(F) // max(1, L)  # hybrid mlp folded in
            # one shared attention block (+ its mlp) reused
            total += attn_block() + mlp_block(F) + 4 * D
        elif self.family == "moe":
            per = attn_block() + self.moe.num_experts * mlp_block(F) \
                + D * self.moe.num_experts + 2 * D
            total += L * per
        else:  # dense / encdec / vlm decoders
            per = attn_block() + mlp_block(F) + 2 * D
            total += L * per
            if self.family == "encdec":
                # encoder layers + per-decoder-layer cross attention
                total += self.encoder_layers * (attn_block() + mlp_block(F) + 2 * D)
                total += L * attn_block()
        total += D  # final norm
        return int(total)

    def active_param_count(self) -> int:
        """Activated params per token (MoE counts top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.num_layers
        dense = self.param_count()
        unused = L * (self.moe.num_experts - self.moe.top_k) * \
            ((3 if self.glu else 2) * D * F)
        return int(dense - unused)


# ---------------------------------------------------------------------------
# Shape configuration (assigned input-shape set)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_ARCH_MODULES = {
    "whisper-small": "whisper_small",
    "qwen1.5-32b": "qwen15_32b",
    "qwen2-0.5b": "qwen2_05b",
    "smollm-135m": "smollm_135m",
    "gemma3-4b": "gemma3_4b",
    "mamba2-370m": "mamba2_370m",
    "mixtral-8x7b": "mixtral_8x7b",
    "grok-1-314b": "grok1_314b",
    "zamba2-1.2b": "zamba2_12b",
    "paligemma-3b": "paligemma_3b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.CONFIG


def reduced_config(arch_id: str) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.REDUCED
