from repro.configs.base import (  # noqa: F401
    ARCH_IDS,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    ShapeConfig,
    get_config,
    reduced_config,
)
from repro.configs.shapes import SHAPE_IDS, SHAPES, get_shape  # noqa: F401
