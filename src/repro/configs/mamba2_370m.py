"""mamba2-370m [ssm]: 48L d_model=1024, attention-free, vocab=50280, state=128.

SSD (state-space duality). [arXiv:2405.21060]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv=0,
    d_ff=0,
    vocab=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4, chunk=128),
    tied_embeddings=True,
)

REDUCED = ModelConfig(
    name="mamba2-370m-reduced",
    family="ssm",
    num_layers=2,
    d_model=64,
    n_heads=0,
    n_kv=0,
    d_ff=0,
    vocab=256,
    ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4, chunk=16),
    tied_embeddings=True,
)
