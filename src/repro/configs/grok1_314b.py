"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072.

8 experts top-2, head_dim=128. Largest assigned config — exercises
FSDP x TP x EP x pod sharding the hardest. [hf:xai-org/grok-1]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    d_ff=32768,
    vocab=131072,
    head_dim=128,
    moe=MoEConfig(num_experts=8, top_k=2),
    tied_embeddings=True,
)

REDUCED = ModelConfig(
    name="grok-1-314b-reduced",
    family="moe",
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_ff=128,
    vocab=256,
    head_dim=16,
    moe=MoEConfig(num_experts=4, top_k=2),
    tied_embeddings=True,
)
