"""qwen2-0.5b [dense]: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.

GQA + QKV bias, tied embeddings. [arXiv:2407.10671]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    n_heads=14,
    n_kv=2,
    d_ff=4864,
    vocab=151936,
    qkv_bias=True,
    tied_embeddings=True,
)

REDUCED = ModelConfig(
    name="qwen2-0.5b-reduced",
    family="dense",
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_ff=128,
    vocab=256,
    qkv_bias=True,
    tied_embeddings=True,
)
