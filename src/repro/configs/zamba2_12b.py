"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000.

Mamba2 backbone + a shared full-attention block applied every 6 layers
(ssm_state=64). SSM-dominated -> long_500k runs. [arXiv:2411.15242]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv=32,
    d_ff=8192,
    vocab=32000,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4, chunk=128),
    shared_attn_every=6,
    tied_embeddings=True,
)

REDUCED = ModelConfig(
    name="zamba2-1.2b-reduced",
    family="hybrid",
    num_layers=4,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_ff=128,
    vocab=256,
    ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4, chunk=16),
    shared_attn_every=2,
    tied_embeddings=True,
)
