"""gemma3-4b [dense]: 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.

5:1 local:global sliding-window pattern (window 1024), head_dim=256, 128k
context (sub-quadratic in 5/6 layers -> long_500k runs). [hf:google/gemma-3]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv=4,
    d_ff=10240,
    vocab=262144,
    head_dim=256,
    sliding_window=1024,
    local_global_pattern=(5, 1),
    act="gelu",
    tied_embeddings=True,
)

REDUCED = ModelConfig(
    name="gemma3-4b-reduced",
    family="dense",
    num_layers=3,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_ff=128,
    vocab=256,
    head_dim=16,
    sliding_window=8,
    local_global_pattern=(2, 1),
    act="gelu",
    tied_embeddings=True,
)
