"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.

8 experts top-2, sliding-window attention (4096) -> sub-quadratic, long_500k
runs. [arXiv:2401.04088]
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=14336,
    vocab=32000,
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2),
    tied_embeddings=False,
)

REDUCED = ModelConfig(
    name="mixtral-8x7b-reduced",
    family="moe",
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_ff=128,
    vocab=256,
    sliding_window=16,
    moe=MoEConfig(num_experts=4, top_k=2),
    tied_embeddings=False,
)
