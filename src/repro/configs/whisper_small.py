"""whisper-small [audio]: enc-dec, conv frontend stubbed (precomputed frames).

12L decoder + 12L encoder, d_model=768, 12H MHA, d_ff=3072, vocab=51865.
[arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,
    d_model=768,
    n_heads=12,
    n_kv=12,
    d_ff=3072,
    vocab=51865,
    encoder_layers=12,
    encoder_len=1500,          # 30 s audio -> 3000 mel frames -> conv stride 2
    qkv_bias=True,             # whisper uses bias on attention projections
    act="gelu",
    glu=False,
    tied_embeddings=True,
)

REDUCED = ModelConfig(
    name="whisper-small-reduced",
    family="encdec",
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_ff=128,
    vocab=256,
    encoder_layers=2,
    encoder_len=16,
    qkv_bias=True,
    act="gelu",
    glu=False,
    tied_embeddings=True,
)
