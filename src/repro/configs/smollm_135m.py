"""smollm-135m [dense]: 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.

Llama-arch small model; also the end-to-end ~100M training example arch.
[hf:HuggingFaceTB/SmolLM-135M]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    num_layers=30,
    d_model=576,
    n_heads=9,
    n_kv=3,
    d_ff=1536,
    vocab=49152,
    tied_embeddings=True,
)

REDUCED = ModelConfig(
    name="smollm-135m-reduced",
    family="dense",
    num_layers=3,
    d_model=96,
    n_heads=3,
    n_kv=1,
    d_ff=256,
    vocab=512,
    tied_embeddings=True,
)
