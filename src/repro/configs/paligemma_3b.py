"""paligemma-3b [vlm]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216.

SigLIP vision frontend is a stub per assignment: input_specs() provides 256
precomputed patch embeddings prepended to the text sequence. Gemma-2b text
backbone (head_dim=256). [arXiv:2407.07726]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv=1,
    d_ff=16384,
    vocab=257216,
    head_dim=256,
    prefix_len=256,
    act="gelu",
    tied_embeddings=True,
)

REDUCED = ModelConfig(
    name="paligemma-3b-reduced",
    family="vlm",
    num_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=1,
    d_ff=128,
    vocab=256,
    head_dim=16,
    prefix_len=4,
    act="gelu",
    tied_embeddings=True,
)
