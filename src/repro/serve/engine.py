"""Batched serving engine with continuous batching.

Fixed decode batch of `slots`; finished slots are immediately refilled from
the request queue (single-request prefill into a fresh B=1 cache, then the
K/V/state tensors are spliced into the batched cache at that slot). Per-slot
position vectors keep sequences independent. Straggler/pathological requests
are bounded by `max_new_tokens`.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.runtime import Runtime
from repro.serve.serve_step import make_decode_step, sample_logits


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (P,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    output: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, rt: Runtime, params,
                 slots: int = 4, max_len: int = 512,
                 eos_token: Optional[int] = None):
        if cfg.family in ("encdec", "vlm"):
            raise NotImplementedError(
                "engine supports decoder-only families; encdec/vlm use the "
                "prefill/decode steps directly")
        self.cfg, self.rt, self.params = cfg, rt, params
        self.slots, self.max_len = slots, max_len
        self.eos = eos_token
        self.queue: deque[Request] = deque()
        self.active: List[Optional[Request]] = [None] * slots
        self.pos = np.zeros(slots, np.int32)
        self.last_tok = np.zeros(slots, np.int32)
        self.cache = M.init_cache(cfg, rt, slots, max_len)
        self._decode = jax.jit(make_decode_step(cfg, rt), donate_argnums=(3,))
        self._prefill1 = jax.jit(self._prefill_one)
        self.rng = jax.random.PRNGKey(0)

    # -- internals ----------------------------------------------------------

    def _prefill_one(self, params, tokens):
        cache = M.init_cache(self.cfg, self.rt, 1, self.max_len)
        logits, cache = M.prefill(params, self.cfg, self.rt,
                                  {"tokens": tokens}, cache)
        return logits, cache

    def _splice_cache(self, slot: int, cache1):
        """Insert a B=1 cache into batch slot `slot` (axis 1 of every leaf
        below the layer axis ... caches are (L, B, ...))."""
        def splice(big, small):
            return big.at[:, slot:slot + 1].set(small)
        self.cache = jax.tree.map(splice, self.cache, cache1)

    def _admit(self):
        for slot in range(self.slots):
            if self.active[slot] is None and self.queue:
                req = self.queue.popleft()
                req.output = []
                toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
                logits, cache1 = self._prefill1(self.params, toks)
                self._splice_cache(slot, cache1)
                self.rng, k = jax.random.split(self.rng)
                first = int(sample_logits(logits, k, req.temperature)[0])
                req.output.append(first)
                self.active[slot] = req
                self.pos[slot] = len(req.prompt)
                self.last_tok[slot] = first

    # -- public -------------------------------------------------------------

    def submit(self, req: Request):
        assert len(req.prompt) + req.max_new_tokens <= self.max_len
        self.queue.append(req)

    def step(self) -> int:
        """One batched decode step; returns number of active slots."""
        self._admit()
        live = [s for s in range(self.slots) if self.active[s] is not None]
        if not live:
            return 0
        tokens = jnp.asarray(self.last_tok, jnp.int32)[:, None]
        pos = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(self.params, tokens, pos, self.cache)
        self.rng, k = jax.random.split(self.rng)
        # per-slot temperatures: empty slots decode greedily (discarded),
        # live slots honor their request's setting for every decode step,
        # not just the first token sampled at admission
        temps = np.zeros(self.slots, np.float32)
        for s in live:
            temps[s] = self.active[s].temperature
        nxt = np.asarray(sample_logits(logits, k, jnp.asarray(temps)))
        for s in live:
            req = self.active[s]
            tok = int(nxt[s])
            req.output.append(tok)
            self.pos[s] += 1
            self.last_tok[s] = tok
            done = (len(req.output) >= req.max_new_tokens
                    or (self.eos is not None and tok == self.eos))
            if done:
                self.active[s] = None
        return len(live)

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        for r in requests:
            self.submit(r)
        out: Dict[int, List[int]] = {}
        pending = {r.rid: r for r in requests}
        while pending:
            self.step()
            for rid, r in list(pending.items()):
                if r.output is not None and (
                        len(r.output) >= r.max_new_tokens
                        or (self.eos is not None and r.output
                            and r.output[-1] == self.eos)):
                    if all(r is not a for a in self.active):
                        out[rid] = r.output
                        del pending[rid]
        return out
