"""Batched serving engine with continuous batching.

Fixed decode batch of `slots`; finished slots are immediately refilled from
the request queue (single-request prefill into a fresh B=1 cache, then the
K/V/state tensors are spliced into the batched cache at that slot). Per-slot
position vectors keep sequences independent. Straggler/pathological requests
are bounded by `max_new_tokens`.

Timed, multi-tenant serving (DESIGN.md §14): every call to `step()` ticks a
discrete clock `t` (even when no slot is live), and a request only becomes
eligible once `t >= submit_at` — the engine counterpart of
`core.traces.trace_schedule`'s decode-step-indexed arrivals. The admission
`policy` mirrors the analytic scheduler exactly: "fifo" admits in
(submit_at, submission order); "priority" sorts eligible requests by tenant
priority first; "preempt" additionally lets a waiting request evict the
most-recently-admitted active *preemptible* (interactive=False) request of
strictly lower priority — the victim keeps its generated tokens and
re-prefills prompt + generated on re-admission. `replay_trace` replays a
`RequestTrace` end to end; tests/test_traces.py cross-validates the
recorded admit/finish steps bitwise against `trace_schedule`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.runtime import Runtime
from repro.serve.serve_step import make_decode_step, sample_logits

#: Admission policies the engine implements (the shared-pool subset of
#: `core.traces.POLICIES`; "disaggregated" is a routing choice above the
#: single-pool engine).
ENGINE_POLICIES = ("fifo", "priority", "preempt")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (P,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    output: Optional[List[int]] = None
    # timed multi-tenant submission
    submit_at: int = 0              # step at which the request arrives
    priority: int = 0               # higher wins under priority/preempt
    interactive: bool = True        # False = preemptible offline/batch
    # bookkeeping recorded by the engine (cross-validated vs trace_schedule)
    admit_step: int = -1            # step of FIRST admission
    finish_step: int = -1
    n_preemptions: int = 0
    seq: int = -1                   # submission order, set by submit()


class ServeEngine:
    def __init__(self, cfg: ModelConfig, rt: Runtime, params,
                 slots: int = 4, max_len: int = 512,
                 eos_token: Optional[int] = None, policy: str = "fifo"):
        if cfg.family in ("encdec", "vlm"):
            raise NotImplementedError(
                "engine supports decoder-only families; encdec/vlm use the "
                "prefill/decode steps directly")
        if policy not in ENGINE_POLICIES:
            raise ValueError(
                f"policy {policy!r} not in {ENGINE_POLICIES}")
        self.cfg, self.rt, self.params = cfg, rt, params
        self.slots, self.max_len = slots, max_len
        self.eos = eos_token
        self.policy = policy
        self.queue: List[Request] = []
        self.active: List[Optional[Request]] = [None] * slots
        self.pos = np.zeros(slots, np.int32)
        self.last_tok = np.zeros(slots, np.int32)
        self.cache = M.init_cache(cfg, rt, slots, max_len)
        self._decode = jax.jit(make_decode_step(cfg, rt), donate_argnums=(3,))
        self._prefill1 = jax.jit(self._prefill_one)
        self.rng = jax.random.PRNGKey(0)
        self.t = 0                    # discrete step clock (idle steps tick)
        self._n_admits = 0
        self._slot_admit = [-1] * slots   # admission event index per slot

    # -- internals ----------------------------------------------------------

    def _prefill_one(self, params, tokens):
        cache = M.init_cache(self.cfg, self.rt, 1, self.max_len)
        logits, cache = M.prefill(params, self.cfg, self.rt,
                                  {"tokens": tokens}, cache)
        return logits, cache

    def _splice_cache(self, slot: int, cache1):
        """Insert a B=1 cache into batch slot `slot` (axis 1 of every leaf
        below the layer axis ... caches are (L, B, ...))."""
        def splice(big, small):
            return big.at[:, slot:slot + 1].set(small)
        self.cache = jax.tree.map(splice, self.cache, cache1)

    def _key(self, req: Request):
        if self.policy == "fifo":
            return (req.submit_at, req.seq)
        return (-req.priority, req.submit_at, req.seq)

    def _admit_into(self, slot: int, req: Request):
        """Prefill `req` into `slot`. Fresh admission prefills the prompt
        and samples the first token; a preempted request re-prefills
        prompt + generated-so-far and resumes without sampling (the next
        token comes from the next decode step)."""
        resumed = bool(req.output)
        if not resumed:
            req.output = []
            toks = np.asarray(req.prompt, np.int32)
        else:
            # cache holds positions 0..pos-1; output[-1] rides as last_tok
            toks = np.concatenate([np.asarray(req.prompt, np.int32),
                                   np.asarray(req.output[:-1], np.int32)])
        logits, cache1 = self._prefill1(self.params,
                                        jnp.asarray(toks)[None, :])
        self._splice_cache(slot, cache1)
        if not resumed:
            self.rng, k = jax.random.split(self.rng)
            first = int(sample_logits(logits, k, req.temperature)[0])
            req.output.append(first)
            req.admit_step = self.t
        self.active[slot] = req
        self.pos[slot] = len(toks)
        self.last_tok[slot] = req.output[-1]
        self._slot_admit[slot] = self._n_admits
        self._n_admits += 1

    def _admit(self):
        elig = sorted((r for r in self.queue if r.submit_at <= self.t),
                      key=self._key)
        for req in list(elig):
            slot = next((s for s in range(self.slots)
                         if self.active[s] is None), None)
            if slot is None:
                break
            elig.remove(req)
            self.queue.remove(req)
            self._admit_into(slot, req)
        if self.policy != "preempt":
            return
        for req in elig:
            victims = [s for s in range(self.slots)
                       if self.active[s] is not None
                       and not self.active[s].interactive
                       and self.active[s].priority < req.priority]
            if not victims:
                continue
            slot = max(victims, key=lambda s: self._slot_admit[s])
            victim = self.active[slot]
            victim.n_preemptions += 1
            # victim keeps its progress and rejoins the queue; it is not
            # re-eligible until the next step (elig was snapshotted)
            self.queue.append(victim)
            self.queue.remove(req)
            self._admit_into(slot, req)

    # -- public -------------------------------------------------------------

    def submit(self, req: Request):
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt ({len(req.prompt)}) + "
                f"max_new_tokens ({req.max_new_tokens}) exceeds engine "
                f"max_len ({self.max_len})")
        if req.submit_at < 0:
            raise ValueError(
                f"request {req.rid}: submit_at must be >= 0 "
                f"(got {req.submit_at})")
        # monotone submission counter (queue length shrinks on admission)
        self._seq_ctr = getattr(self, "_seq_ctr", 0)
        req.seq = self._seq_ctr
        self._seq_ctr += 1
        self.queue.append(req)

    def step(self) -> int:
        """One clock tick: admissions, then — if any slot is live — one
        batched decode step. Idle ticks (future arrivals only) still
        advance the clock. Returns the number of live slots decoded."""
        self._admit()
        live = [s for s in range(self.slots) if self.active[s] is not None]
        if not live:
            self.t += 1
            return 0
        tokens = jnp.asarray(self.last_tok, jnp.int32)[:, None]
        pos = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(self.params, tokens, pos, self.cache)
        self.rng, k = jax.random.split(self.rng)
        # per-slot temperatures: empty slots decode greedily (discarded),
        # live slots honor their request's setting for every decode step,
        # not just the first token sampled at admission
        temps = np.zeros(self.slots, np.float32)
        for s in live:
            temps[s] = self.active[s].temperature
        nxt = np.asarray(sample_logits(logits, k, jnp.asarray(temps)))
        for s in live:
            req = self.active[s]
            tok = int(nxt[s])
            req.output.append(tok)
            self.pos[s] += 1
            self.last_tok[s] = tok
            done = (len(req.output) >= req.max_new_tokens
                    or (self.eos is not None and tok == self.eos))
            if done:
                req.finish_step = self.t
                self.active[s] = None
                self._slot_admit[s] = -1
        self.t += 1
        return len(live)

    def run(self, requests: List[Request]) -> Dict[int, List[int]]:
        for r in requests:
            self.submit(r)
        out: Dict[int, List[int]] = {}
        pending = {r.rid: r for r in requests}
        while pending:
            self.step()
            for rid, r in list(pending.items()):
                if r.output is not None and (
                        len(r.output) >= r.max_new_tokens
                        or (self.eos is not None and r.output
                            and r.output[-1] == self.eos)):
                    if all(r is not a for a in self.active):
                        out[rid] = r.output
                        del pending[rid]
        return out


def replay_trace(engine: ServeEngine, trace, *, rng=None,
                 temperature: float = 0.0) -> List[Request]:
    """Replay a `core.traces.RequestTrace` on a real engine: one `Request`
    per trace entry (synthetic prompts; arrival step -> `submit_at`, tenant
    -> priority/interactive, out length -> `max_new_tokens`), submitted in
    trace order and run to completion. Returns the requests with their
    engine-recorded `admit_step`/`finish_step`, which tests cross-validate
    bitwise against `trace_schedule(trace, engine.slots, engine.policy)`."""
    rng = np.random.default_rng(0) if rng is None else rng
    reqs = []
    for r in range(trace.n_requests):
        tc = trace.tenant_of(r)
        prompt = rng.integers(0, engine.cfg.vocab, trace.prompt_lens[r],
                              dtype=np.int32)
        reqs.append(Request(
            rid=r, prompt=prompt, max_new_tokens=int(trace.out_lens[r]),
            temperature=temperature, submit_at=int(trace.arrival_steps[r]),
            priority=tc.priority, interactive=tc.interactive))
    engine.run(reqs)
    return reqs
