"""Serve-step factories: prefill_step (cache built in-graph) + decode_step +
sampling. These are the functions the dry-run lowers for the decode/prefill
shape cells and the engine jits for real serving.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.runtime import Runtime


def make_prefill_step(cfg: ModelConfig, rt: Runtime, max_len: int) -> Callable:
    """(params, batch) -> (last_logits, cache). Cache is created inside the
    compiled graph (zeros), so input specs are just params + batch."""

    def prefill_step(params, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
        B = batch["tokens"].shape[0]
        cache = M.init_cache(cfg, rt, B, max_len)
        return M.prefill(params, cfg, rt, batch, cache)

    return prefill_step


def make_decode_step(cfg: ModelConfig, rt: Runtime) -> Callable:
    """(params, tokens (B,1), pos scalar|(B,), cache) -> (logits, cache)."""

    def decode_step(params, tokens, pos, cache):
        return M.decode_step(params, cfg, rt, tokens, pos, cache)

    return decode_step


def sample_logits(logits: jnp.ndarray, rng, temperature=0.0) -> jnp.ndarray:
    """Greedy (T=0) or temperature sampling. logits (B, V) -> (B,) int32.

    `temperature` is a scalar applied to every row, or a (B,) array of
    per-row temperatures (the engine's per-request setting): rows with
    T<=0 decode greedily, rows with T>0 sample categorically.
    """
    t = jnp.asarray(temperature, jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if t.ndim == 0:
        if float(t) <= 0.0:
            return greedy
        return jax.random.categorical(
            rng, logits.astype(jnp.float32) / t, axis=-1).astype(jnp.int32)
    safe_t = jnp.maximum(t, 1e-6)[:, None]
    sampled = jax.random.categorical(
        rng, logits.astype(jnp.float32) / safe_t, axis=-1).astype(jnp.int32)
    return jnp.where(t > 0.0, sampled, greedy)
