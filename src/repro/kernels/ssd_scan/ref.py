"""Pure-jnp oracle for the Mamba-2 SSD (state-space duality) scan.

Sequential recurrence (ground truth):
    h_t = exp(dt_t * A_h) * h_{t-1} + dt_t * (B_t outer x_t)     h: (H, P, N)
    y_t = C_t . h_t + D_h * x_t

Shapes (single group G=1, B/C shared across heads):
    x  (B, S, H, P)    dt (B, S, H)    A (H,)  negative
    Bm (B, S, N)       Cm (B, S, N)    D (H,)
Returns y (B, S, H, P) and final state (B, H, P, N).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ssd_ref(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    Bm: jnp.ndarray,
    Cm: jnp.ndarray,
    D: Optional[jnp.ndarray] = None,
    init_state: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    h0 = (jnp.zeros((Bsz, H, P, N), jnp.float32)
          if init_state is None else init_state.astype(jnp.float32))

    def step(h, inp):
        xt, dtt, bt, ct = inp          # (B,H,P) (B,H) (B,N) (B,N)
        decay = jnp.exp(dtt * Af[None, :])          # (B,H)
        dbx = jnp.einsum("bh,bhp,bn->bhpn", dtt, xt, bt)
        h = h * decay[:, :, None, None] + dbx
        y = jnp.einsum("bhpn,bn->bhp", h, ct)
        return h, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    hT, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)                       # (B,S,H,P)
    if D is not None:
        y = y + D.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype), hT


def ssd_chunked_ref(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    Bm: jnp.ndarray,
    Cm: jnp.ndarray,
    D: Optional[jnp.ndarray] = None,
    init_state: Optional[jnp.ndarray] = None,
    chunk: int = 128,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunk-parallel SSD (the algorithm the Pallas kernel implements):
    intra-chunk quadratic 'attention' form + inter-chunk state recurrence.
    Mathematically identical to ssd_ref; used as a second oracle and as the
    jnp fallback inside the model when the Pallas path is off."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        # dt=0 padding tokens are no-ops: exp(0*A)=1 decay, zero contribution
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        y, hT = ssd_chunked_ref(x, dt, A, Bm, Cm, None, init_state, chunk)
        y = y[:, :S]
        if D is not None:
            y = (y.astype(jnp.float32)
                 + D.astype(jnp.float32)[None, None, :, None]
                 * x[:, :S].astype(jnp.float32)).astype(y.dtype)
        return y, hT
    nc, Q = S // chunk, chunk

    xf = x.astype(jnp.float32).reshape(Bsz, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, Q, H)
    Bf = Bm.astype(jnp.float32).reshape(Bsz, nc, Q, N)
    Cf = Cm.astype(jnp.float32).reshape(Bsz, nc, Q, N)
    Af = A.astype(jnp.float32)

    dA = dtf * Af[None, None, None, :]               # (B,nc,Q,H) log-decay
    cum = jnp.cumsum(dA, axis=2)                     # L_t inclusive
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,Q,H) L_t-L_s
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    decay_m = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)

    # intra-chunk: y[t] = sum_{s<=t} (C_t.B_s) exp(L_t-L_s) dt_s x_s
    cb = jnp.einsum("bctn,bcsn->bcts", Cf, Bf)       # (B,nc,Q,Q)
    m = cb[:, :, :, :, None] * decay_m * dtf[:, :, None, :, :]
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", m, xf)

    # per-chunk final state contribution: sum_s exp(L_Q - L_s) dt_s B_s x_s
    tail = jnp.exp(cum[:, :, -1:, :] - cum)          # (B,nc,Q,H)
    chunk_state = jnp.einsum("bcqh,bcqh,bcqn,bcqhp->bchpn",
                             tail, dtf, Bf, xf)
    chunk_decay = jnp.exp(cum[:, :, -1, :])          # (B,nc,H) exp(L_Q)

    h0 = (jnp.zeros((Bsz, H, P, N), jnp.float32)
          if init_state is None else init_state.astype(jnp.float32))

    def inter(h, inp):
        cs, cd = inp                                 # (B,H,P,N),(B,H)
        h_in = h                                     # state BEFORE this chunk
        h = h * cd[:, :, None, None] + cs
        return h, h_in

    hT, h_prevs = jax.lax.scan(
        inter, h0, (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)            # (B,nc,H,P,N)

    # inter-chunk: y[t] += C_t . (exp(L_t) * h_prev)
    inter_y = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cf, h_prevs, jnp.exp(cum))
    y = (y_intra + inter_y).reshape(Bsz, S, H, P)
    if D is not None:
        y = y + D.astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
    return y.astype(x.dtype), hT


def ssd_decode_step_ref(
    state: jnp.ndarray,   # (B,H,P,N) fp32
    x: jnp.ndarray,       # (B,H,P)
    dt: jnp.ndarray,      # (B,H)
    A: jnp.ndarray,       # (H,)
    Bm: jnp.ndarray,      # (B,N)
    Cm: jnp.ndarray,      # (B,N)
    D: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token recurrent update (decode path)."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * A.astype(jnp.float32)[None, :])
    dbx = jnp.einsum("bh,bhp,bn->bhpn", dtf, xf, Bm.astype(jnp.float32))
    state = state * decay[:, :, None, None] + dbx
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(jnp.float32))
    if D is not None:
        y = y + D.astype(jnp.float32)[None, :, None] * xf
    return y.astype(x.dtype), state
