"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

TPU-native design:
  - grid (B, H, nC) with the chunk dimension LAST: TPU grids iterate the
    trailing dim sequentially, so the inter-chunk SSM state (P, N) is carried
    in VMEM scratch across chunk steps of one (b, h) program instance.
  - per step, one chunk of x (Q, P), dt (Q, 1), B/C (Q, N) is tiled into VMEM;
    the intra-chunk quadratic form runs on the MXU as (Q,N)x(N,Q) and
    (Q,Q)x(Q,P) matmuls — Q=128, P=64/128, N=64/128 are all MXU-aligned.
  - decay terms use cumulative-log-sum within the chunk (fp32), matching
    ssd_chunked_ref exactly.

The segment-sum decay matrix is the memory hot spot of SSD on GPUs; on TPU we
never materialize it in HBM — it lives only as a (Q, Q) VMEM tile.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref,
                h_scr, *, Q: int, P: int, N: int, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (Q, 1)
    a = a_ref[0, 0]                              # (1, 1) fp32 (negative)
    bmat = b_ref[0].astype(jnp.float32)          # (Q, N)
    cmat = c_ref[0].astype(jnp.float32)          # (Q, N)

    da = dt * a[0, 0]                            # (Q, 1) log-decay
    cum = jnp.cumsum(da, axis=0)                 # (Q, 1) inclusive L_t

    # intra-chunk quadratic form: m[t,s] = (C_t.B_s) exp(L_t - L_s) dt_s, s<=t
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())))  # (Q, Q)
    seg = cum - cum.reshape(1, Q)                # L_t - L_s
    ti = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    tri = si <= ti
    m = jnp.where(tri, cb * jnp.exp(seg) * dt.reshape(1, Q), 0.0)
    y_intra = jax.lax.dot_general(m, x, (((1,), (0,)), ((), ())))   # (Q, P)

    # inter-chunk: y[t] += C_t . (exp(L_t) * h_prev)   h_prev: (P, N)
    h_prev = h_scr[...]
    ch = jax.lax.dot_general(cmat, h_prev, (((1,), (1,)), ((), ())))  # (Q, P)
    y_inter = ch * jnp.exp(cum)                  # broadcast (Q,1)

    y_ref[0, 0, :, :] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h = exp(L_Q) h_prev + sum_s exp(L_Q - L_s) dt_s x_s B_s^T
    tail = jnp.exp(cum[Q - 1, 0] - cum) * dt     # (Q, 1)
    xw = x * tail                                # (Q, P)
    hc = jax.lax.dot_general(xw, bmat, (((0,), (0,)), ((), ())))    # (P, N)
    h_new = h_prev * jnp.exp(cum[Q - 1, 0]) + hc
    h_scr[...] = h_new

    @pl.when(ci == nc - 1)
    def _finish():
        hout_ref[0, 0, :, :] = h_new


def ssd_scan(
    x: jnp.ndarray,       # (B, S, H, P)
    dt: jnp.ndarray,      # (B, S, H)
    A: jnp.ndarray,       # (H,) negative
    Bm: jnp.ndarray,      # (B, S, N)   group-shared across heads
    Cm: jnp.ndarray,      # (B, S, N)
    D: Optional[jnp.ndarray] = None,   # (H,) skip
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan. Returns (y (B,S,H,P), final state (B,H,P,N))."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # pad dt with zeros -> exp(0 * A) = 1 decay, zero input contribution
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // Q

    xt = x.transpose(0, 2, 1, 3)                       # (B, H, S, P)
    dtt = dt.transpose(0, 2, 1)[..., None]             # (B, H, S, 1)
    af = A.astype(jnp.float32).reshape(1, H, 1, 1)
    af = jnp.broadcast_to(af, (1, H, 1, 1))

    kernel = functools.partial(_ssd_kernel, Q=Q, P=P, N=N, nc=nc)

    y, h = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, 1), lambda b, h, c: (0, h, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sp, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, af, Bm, Cm)

    y = y.transpose(0, 2, 1, 3)[:, :S]                 # (B, S, H, P)
    if D is not None:
        y = (y.astype(jnp.float32)
             + D.astype(jnp.float32)[None, None, :, None]
             * x[:, :S].astype(jnp.float32)).astype(y.dtype)
    return y, h
