"""jit'd public wrapper for the SSD scan."""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_chunked_ref, ssd_decode_step_ref


def ssd(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    Bm: jnp.ndarray,
    Cm: jnp.ndarray,
    D: Optional[jnp.ndarray] = None,
    *,
    chunk: int = 128,
    use_pallas: bool = False,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if use_pallas:
        return ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=interpret)
    return ssd_chunked_ref(x, dt, A, Bm, Cm, D, chunk=chunk)


ssd_decode_step = ssd_decode_step_ref
