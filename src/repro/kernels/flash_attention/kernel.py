"""Pallas TPU flash-attention kernel (GQA + causal + sliding window).

TPU-native design (not a CUDA port):
  - grid (B, Hq, nQ, nK); the trailing K dimension is sequential on TPU, so
    the online-softmax running state (m, l, acc) lives in VMEM scratch and is
    carried across the K steps of the same (b, h, qblk) program instance.
  - BlockSpecs tile q/k/v into VMEM: q (1,1,BQ,hd), k/v (1,1,BK,hd); the MXU
    sees (BQ,hd)x(hd,BK) and (BQ,BK)x(BK,hd) matmuls with BQ=BK 128-aligned.
  - GQA is an index-map trick: the k/v BlockSpec maps query head h to KV head
    h // (Hq//Hkv) — no materialized repeat, no extra HBM traffic.
  - causal/window masking: block-level early-out (pl.when) skips K tiles that
    are entirely masked, plus an in-block iota mask for the diagonal tiles.

Validated on CPU via interpret=True against ref.attention_ref (tests sweep
shapes/dtypes); compiled path targets TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr,
                  *, softmax_scale: float, causal: bool,
                  window: Optional[int], bq: int, bk: int, nk: int,
                  kv_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk

    # Block-level reachability: can any (q, k) pair in this tile interact?
    live = k_start < kv_len
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + bq - 1)
    if window is not None:
        live = jnp.logical_and(live, k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * softmax_scale   # (BQ, hd)
        k = k_ref[0, 0].astype(jnp.float32)                   # (BK, hd)
        v = v_ref[0, 0].astype(jnp.float32)                   # (BK, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (BQ, BK)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len
        if causal:
            mask = mask & (kpos <= qpos)
        if window is not None:
            mask = mask & (kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                   # (BQ, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0, 0, :, :] = (acc_scr[...]
                             / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,           # (B, Sq, Hq, hd)
    k: jnp.ndarray,           # (B, Skv, Hkv, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softmax_scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused attention for aligned self-attention (q_pos == kv_pos == iota).

    Decode-with-cache and ring-buffer caches go through ops.mha's masked
    path; this kernel covers the train/prefill hot spot.
    """
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    rep = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)

    pq = (-Sq) % bq
    pk = (-Skv) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    Sq_p, Skv_p = Sq + pq, Skv + pk
    nq, nk = Sq_p // bq, Skv_p // bk

    qt = q.transpose(0, 2, 1, 3)   # (B, Hq, Sq, hd)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, softmax_scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, nk=nk, kv_len=Skv)

    def q_map(b, h, i, j):
        return (b, h, i, 0)

    def kv_map(b, h, i, j):
        return (b, h // rep, j, 0)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), q_map),
            pl.BlockSpec((1, 1, bk, hd), kv_map),
            pl.BlockSpec((1, 1, bk, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)[:, :Sq]
