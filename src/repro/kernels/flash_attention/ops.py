"""jit'd public wrapper for fused attention.

`mha()` routes between:
  - the Pallas flash kernel (aligned self-attention: train / prefill), and
  - the jnp masked oracle (decode-with-cache / arbitrary position vectors),
chosen by `use_pallas` (default off on CPU; launch/train flips it on for TPU).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref


def mha(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_pos: jnp.ndarray,
    kv_pos: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softmax_scale: Optional[float] = None,
    use_pallas: bool = False,
    interpret: bool = False,
) -> jnp.ndarray:
    if use_pallas:
        # the kernel assumes aligned iota positions (self-attention)
        return flash_attention(
            q, k, v, causal=causal, window=window,
            softmax_scale=softmax_scale, interpret=interpret)
    return attention_ref(
        q, k, v, q_pos, kv_pos,
        causal=causal, window=window, softmax_scale=softmax_scale)
