"""Pure-jnp oracle for fused attention (GQA + causal + sliding window).

Layout: BSHD — q (B, Sq, Hq, hd), k/v (B, Skv, Hkv, hd).
Masking is position-based so the same oracle covers training (positions =
iota), prefill, and decode-with-cache (arbitrary q/kv position vectors,
including ring-buffer caches where kv slots hold non-monotone positions).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def make_mask(
    q_pos: jnp.ndarray,      # (B, Sq) int32
    kv_pos: jnp.ndarray,     # (B, Skv) int32; negative = invalid slot
    causal: bool = True,
    window: Optional[int] = None,
    prefix_len: int = 0,     # prefix-LM: bidirectional among first P positions
) -> jnp.ndarray:
    """Boolean (B, Sq, Skv) mask: True = may attend."""
    q = q_pos[:, :, None]
    kv = kv_pos[:, None, :]
    mask = kv >= 0
    if causal:
        cm = kv <= q
        if prefix_len > 0:
            cm = cm | ((kv < prefix_len) & (q < prefix_len))
        mask = mask & cm
    if window is not None:
        mask = mask & ((kv > q - window) | (kv < prefix_len))
    return mask


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_pos: jnp.ndarray,
    kv_pos: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    prefix_len: int = 0,
    softmax_scale: Optional[float] = None,
) -> jnp.ndarray:
    """Reference attention. Returns (B, Sq, Hq, hd) in q.dtype."""
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    rep = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else hd ** -0.5

    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if rep > 1:
        kf = jnp.repeat(kf, rep, axis=2)
        vf = jnp.repeat(vf, rep, axis=2)

    scores = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
    mask = make_mask(q_pos, kv_pos, causal=causal, window=window,
                     prefix_len=prefix_len)
    scores = jnp.where(mask[:, None, :, :], scores, -1e30)
    probs = jnp.nan_to_num(
        jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True)))
    probs = probs / jnp.maximum(jnp.sum(probs, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vf)
    return out.astype(q.dtype)
