"""Mesh-aware sharding rule engine.

Produces `PartitionSpec` trees for params, optimizer state, KV/SSM caches
and input batches across every assigned arch, on both production mesh
geometries (single pod ("data", "model") and multi-pod ("pod", "data",
"model")). Rules are name-based (leaf key + path context), shape-agnostic
to leading stack dims, and *divisibility-guarded*: an axis is only ever
assigned to a dim it divides, so every emitted spec is legal by
construction. Documented fallbacks:

  * expert parallelism -> TP-within-expert when num_experts does not divide
    the model axis (E dim replicated, F sharded over "model", D over dp);
  * vocab dims stay replicated when the vocab does not divide "model"
    (whisper's 51865);
  * batch-1 long-context caches sequence-shard over every mesh axis
    (("data", "model") on a single pod) because neither batch nor the
    narrow-GQA head dim can take an axis.

The mesh argument is duck-typed: only `.shape` (a mapping axis -> size) and
`.axis_names` are read, so unit tests can pass a shim instead of building
512 fake devices. `to_shardings` is the only function that needs a real
`jax.sharding.Mesh`.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

# data-parallel mesh axes in mesh order (pod-major)
DP_AXES = ("pod", "data")

# column-parallel matmuls (..., D_in, D_out): out dim over "model", in over dp
_COL_PARALLEL = {"wq", "wk", "wv", "wi", "wg", "in_proj"}
# row-parallel matmuls (..., D_in, D_out): in dim over "model", out over dp
_ROW_PARALLEL = {"wo", "out_proj"}
# vectors whose last dim follows the "model" (TP) sharding of their matmul
_VEC_MODEL = {"bq", "bk", "bv", "conv_b", "A_log", "D", "dt_bias", "norm"}
# KV-cache-like leaves laid out (L, B, W, H_kv, hd)
_KV_LEAVES = {"k", "v", "cross_k", "cross_v"}


def _mesh_dp(mesh) -> Tuple[str, ...]:
    """The mesh's data-parallel axes, in mesh (pod-major) order."""
    return tuple(a for a in DP_AXES if a in mesh.axis_names)


def dp_axes(mesh) -> Union[str, Tuple[str, ...], None]:
    """The mesh's data-parallel axes ("data", or ("pod", "data"))."""
    axes = _mesh_dp(mesh)
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def _is_spec(x) -> bool:
    return isinstance(x, P)


class _SpecBuilder:
    """Accumulates per-dim axis assignments under the two legality rules:
    each mesh axis at most once per spec, axis product divides the dim."""

    def __init__(self, mesh, shape: Sequence[int]):
        self.mesh = mesh
        self.shape = tuple(shape)
        self.entries: list = [None] * len(self.shape)
        self.used: set = set()

    def assign(self, dim: int, axes) -> bool:
        if axes is None or not -len(self.shape) <= dim < len(self.shape):
            return False                # scalar leaves stay replicated
        if isinstance(axes, str):
            axes = (axes,)
        axes = tuple(a for a in axes
                     if a in self.mesh.axis_names and a not in self.used)
        if not axes:
            return False
        size = 1
        for a in axes:
            size *= int(self.mesh.shape[a])
        if dim < 0:
            dim += len(self.shape)
        if self.entries[dim] is not None or self.shape[dim] % size != 0:
            return False
        self.entries[dim] = axes[0] if len(axes) == 1 else axes
        self.used.update(axes)
        return True

    def assign_dp(self, dim: int) -> bool:
        """Shard `dim` over the dp axes, widest divisible subset first."""
        dp = _mesh_dp(self.mesh)
        if self.assign(dim, dp):
            return True
        for a in reversed(dp):          # prefer the wider "data" axis
            if self.assign(dim, a):
                return True
        return False

    def assign_seq(self, dim: int) -> bool:
        """Spread `dim` over every remaining mesh axis (dp + model),
        shrinking the axis set until one divides."""
        dp = _mesh_dp(self.mesh)
        candidates = [dp + ("model",)]
        candidates += [dp, ("model",)]
        candidates += [(a,) for a in reversed(dp)]
        for axes in candidates:
            if axes and self.assign(dim, axes):
                return True
        return False

    def spec(self) -> P:
        return P(*self.entries)


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return tuple(out)


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def _param_spec_one(mesh, path: Tuple[str, ...], sds) -> P:
    name = path[-1]
    b = _SpecBuilder(mesh, sds.shape)
    if name == "embed":                       # (V, D)
        b.assign(0, "model")                  # vocab TP; replicated if odd
        b.assign_dp(1)
    elif name == "unembed":                   # (D, V)
        b.assign(1, "model")
        b.assign_dp(0)
    elif "moe" in path:
        if name == "router":                  # (..., D, E)
            b.assign(-1, "model")             # only when E divides (rare)
            b.assign_dp(-2)
        elif name in ("wi", "wg"):            # (..., E, D, F)
            if b.assign(-3, "model"):         # expert parallelism
                b.assign_dp(-2)
            else:                             # EP illegal: TP-within-expert
                b.assign(-1, "model")
                b.assign_dp(-2)
        elif name == "wo":                    # (..., E, F, D)
            if b.assign(-3, "model"):
                b.assign_dp(-1)
            else:
                b.assign(-2, "model")
                b.assign_dp(-1)
    elif name in _COL_PARALLEL and sds.ndim >= 2:
        b.assign(-1, "model")
        b.assign_dp(-2)
    elif name in _ROW_PARALLEL and sds.ndim >= 2:
        b.assign(-2, "model")
        b.assign_dp(-1)
    elif name == "conv_w":                    # (..., K, ch)
        b.assign(-1, "model")
    elif name in _VEC_MODEL:
        b.assign(-1, "model")
    # everything else (norm gains, final_ln, ...) stays replicated
    return b.spec()


def param_specs(mesh, params_sds):
    """PartitionSpec tree matching the structure of an `init_params` tree
    (or its `eval_shape`). The legacy-vs-head-TP SSM variants share this
    weight layout; their difference lives in the Runtime activation
    constraints (`Runtime.opt_ssm_head_tp`)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, sds: _param_spec_one(mesh, _path_names(path), sds),
        params_sds)


def opt_state_specs(mesh, opt_sds, param_spec_tree):
    """Adam m/v mirror the param sharding; the step counter is replicated.
    `opt_sds` is accepted for signature symmetry and may be None."""
    del opt_sds
    return {"step": P(), "m": param_spec_tree, "v": param_spec_tree}


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def _cache_spec_one(mesh, path: Tuple[str, ...], sds) -> P:
    name = path[-1]
    b = _SpecBuilder(mesh, sds.shape)
    if name in _KV_LEAVES:                    # (L, B, W, H_kv, hd)
        batch_ok = b.assign_dp(1)
        head_ok = b.assign(3, "model")
        if not batch_ok and not head_ok:
            b.assign_seq(2)                   # B=1 long context: seq-shard
        elif not head_ok:
            b.assign(2, "model")              # narrow GQA: seq takes model
        elif not batch_ok:
            b.assign_seq(2)
    elif name == "kv_pos":                    # (L, B, W)
        # fallback only: cache_specs overwrites this with the sibling k's
        # (L, B, W) layout so mask reads never reshard against the cache
        if not b.assign_dp(1):
            b.assign_seq(2)
    elif name == "conv":                      # (L, B, K-1, ch)
        b.assign_dp(1)
        b.assign(-1, "model")
    elif name == "ssd":                       # (L, B, H, P, N)
        b.assign_dp(1)
        b.assign(2, "model")
    return b.spec()


def cache_specs(mesh, cache_sds):
    """PartitionSpec tree for an `init_cache` tree: batch over dp when
    divisible, KV heads over "model" when divisible, sequence over whatever
    is left (everything, for batch-1 long-context caches). `kv_pos` always
    mirrors its sibling `k`'s (L, B, W) layout — a divergent kv_pos would
    cost an all-gather per decode step when the mask meets the scores."""
    specs = jax.tree_util.tree_map_with_path(
        lambda path, sds: _cache_spec_one(mesh, _path_names(path), sds),
        cache_sds)

    def align(node):
        if isinstance(node, dict):
            if isinstance(node.get("kv_pos"), P) and isinstance(
                    node.get("k"), P):
                k = node["k"]
                node["kv_pos"] = P(k[0], k[1], k[2])
            for child in node.values():
                align(child)

    align(specs)
    return specs


# ---------------------------------------------------------------------------
# batches
# ---------------------------------------------------------------------------


def batch_specs(mesh, batch_sds):
    """Inputs shard their leading batch dim over the dp axes (replicated
    when the batch is too small, e.g. batch-1 long-context decode)."""
    def one(sds):
        b = _SpecBuilder(mesh, sds.shape)
        b.assign_dp(0)
        return b.spec()
    return jax.tree.map(one, batch_sds)


# ---------------------------------------------------------------------------
# spec tree -> shardings
# ---------------------------------------------------------------------------


def to_shardings(mesh, spec_tree):
    """PartitionSpec tree (or single spec) -> NamedSharding tree. Needs a
    real `jax.sharding.Mesh` (the only function here that does)."""
    from jax.sharding import NamedSharding
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=_is_spec)
