"""Strategy-shardability oracle backed by the sharding rule engine.

Joint (strategy, architecture) exploration proposes parallelization
strategies directly, so something has to answer "could this strategy
actually be *instantiated* on the runtime?" before the analytical
evaluator spends a step-model pass on it. This module answers with the
production sharding rules themselves (`repro.dist.sharding`): a proposed
(tp, dp, ep) is feasible iff `param_specs` / `batch_specs` lay the model
out on a ("data", "model") = (dp, tp) mesh without leaving a mesh axis
dead —

  * `batch_specs` must shard the global batch over the full "data" axis
    (dp > batch, or dp not dividing it, wastes the axis: infeasible);
  * `param_specs` must consume the "model" axis in at least one weight
    when tp > 1 (a tp wider than every shardable dim is dead silicon);
  * ep > 1 requires expert weights whose E dim the expert axis divides
    (the rule engine's EP -> TP-within-expert fallback exists for odd
    vocab-style mismatches, not for strategies *claiming* expert
    parallelism that cannot exist).

DSE workloads (`LLMWorkload`) are not registered runtime configs, so the
oracle synthesizes a same-shape `ModelConfig` (dense or MoE) and runs
`jax.eval_shape` over `init_params` — abstract shapes only, no weights
are materialized, and both the shape tree and every verdict are memoized
(workloads and strategies are frozen/hashable).

The mesh passed to the rule engine is the same duck-typed shim the unit
tests use: only `.shape` (a mapping) and `.axis_names` are read.
"""
from __future__ import annotations

import functools
from typing import Tuple, Union

import jax

from repro.configs import ModelConfig, MoEConfig
from repro.dist import sharding as sh


class ShimMesh:
    """Duck-typed mesh: only `.shape` (mapping) and `.axis_names` are
    read by the spec rules — no devices are built."""

    def __init__(self, shape_map):
        self.shape = dict(shape_map)
        self.axis_names = tuple(shape_map)


@functools.lru_cache(maxsize=256)
def model_config_for_workload(wl) -> ModelConfig:
    """Synthesize the runtime `ModelConfig` matching an `LLMWorkload`'s
    shape (dense or MoE decoder): the oracle and `export_train_config`
    both need a config the model code accepts."""
    moe = None
    if getattr(wl, "moe_experts", 0):
        moe = MoEConfig(num_experts=wl.moe_experts,
                        top_k=max(wl.moe_topk, 1))
    return ModelConfig(
        name=f"dse-{wl.name}",
        family="moe" if moe is not None else "dense",
        num_layers=wl.n_layers,
        d_model=wl.d_model,
        n_heads=wl.n_heads,
        n_kv=wl.n_kv,
        d_ff=wl.d_ff,
        vocab=wl.vocab,
        moe=moe,
    )


@functools.lru_cache(maxsize=64)
def _param_shapes(cfg: ModelConfig):
    from repro.models import model as M
    return jax.eval_shape(lambda k: M.init_params(k, cfg),
                          jax.random.PRNGKey(0))


def _spec_axes(spec) -> set:
    out = set()
    for entry in spec:
        if entry is None:
            continue
        out.update((entry,) if isinstance(entry, str) else entry)
    return out


@functools.lru_cache(maxsize=4096)
def check_strategy(cfg_or_wl, tp: int, dp: int, ep: int = 1,
                   batch: int = 1, seq: int = 1) -> Tuple[bool, str]:
    """Shardability verdict for (tp, dp, ep) on `cfg_or_wl` (a
    `ModelConfig` or an `LLMWorkload`). Returns (ok, reason); reason is
    "" on success, else the first failing check:

        "ep_experts"  ep does not divide the expert count (or no experts)
        "dp_batch"    the "data" axis cannot shard the global batch
        "tp_dead"     tp > 1 but no weight consumes the "model" axis
    """
    cfg = (cfg_or_wl if isinstance(cfg_or_wl, ModelConfig)
           else model_config_for_workload(cfg_or_wl))

    n_exp = cfg.moe.num_experts if cfg.moe is not None else 0
    if ep > 1 and (n_exp == 0 or n_exp % ep != 0):
        return False, "ep_experts"

    mesh = ShimMesh({"data": int(dp), "model": int(tp)})

    if dp > 1:
        b_sds = jax.ShapeDtypeStruct((int(batch), int(seq)), "int32")
        b_spec = sh.batch_specs(mesh, {"tokens": b_sds})["tokens"]
        if "data" not in _spec_axes(b_spec):
            return False, "dp_batch"

    if tp > 1:
        specs = sh.param_specs(mesh, _param_shapes(cfg))
        from jax.sharding import PartitionSpec as P
        leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        if not any("model" in _spec_axes(s) for s in leaves):
            return False, "tp_dead"

    return True, ""


def strategy_shardable(wl, strategy,
                       cfg: Union[ModelConfig, None] = None
                       ) -> Tuple[bool, str]:
    """Oracle entry point for a `Strategy` against a workload: checks the
    (tp, dp, ep) mesh layout with the workload's global batch/seq. `cfg`
    overrides the synthesized config (used when the workload came from a
    registered arch)."""
    return check_strategy(cfg if cfg is not None else wl,
                          strategy.tp, strategy.dp, strategy.ep,
                          batch=wl.batch, seq=wl.seq)


__all__ = ["ShimMesh", "check_strategy", "model_config_for_workload",
           "strategy_shardable"]
