"""Compressed gradient collectives: int8 quantization with error feedback.

Data-parallel gradient reductions dominate cross-pod traffic on the wafer
(PAPERS.md: WATOS/TEMP co-design), so gradients are quantized to int8 with
a single fp32 scale per tensor before the all-reduce — a 4x byte reduction
against fp32 accumulation. Plain quantization biases the update; the error
feedback (EF-SGD style) residual carries each step's rounding error into
the next step, so the *sum* of compressed gradients over steps tracks the
sum of true gradients and the bias does not accumulate.

All functions are pure pytree -> pytree; the caller threads the residual
state (see `TrainSupervisor` / `make_train_step(grad_transform=...)`).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization. Returns (q int8, scale f32)
    with x ~= q * scale and |x - q*scale| <= scale/2 (round-to-nearest)."""
    xf = jnp.asarray(x, jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grads(grads: Dict, err: Optional[Dict] = None
                   ) -> Tuple[Dict, Dict]:
    """One error-feedback compression round.

    Each leaf is corrected by the previous round's residual, quantized to
    int8 (the wire format of the compressed all-reduce), dequantized, and
    the fresh rounding error becomes the next residual. Pass `err=None` on
    the first step. Returns (compressed_grads, new_err)."""
    if err is None:
        err = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        finite = jnp.isfinite(corrected).all()
        q, scale = quantize_int8(
            jnp.where(jnp.isfinite(corrected), corrected, 0.0))
        deq = dequantize_int8(q, scale)
        # a non-finite leaf (bf16 overflow step) passes through uncompressed
        # and holds its residual, so one bad step cannot poison error
        # feedback forever
        sent = jnp.where(finite, deq.astype(g.dtype), g)
        # residual measured against what was actually sent (incl. the cast
        # to g.dtype) — for bf16 grads the cast rounding must be fed back
        # too, or the sum of compressed grads drifts from the true sum
        new_e = jnp.where(finite, corrected - sent.astype(jnp.float32), e)
        return sent.astype(g.dtype), new_e

    # tree.map validates grads/err share a structure (a stale residual from
    # a different param tree fails loudly instead of mispairing leaves);
    # tree_transpose splits the (sent, residual) pairs without guessing at
    # leaf types, so tuple-containing gradient pytrees stay correct
    pairs = jax.tree.map(one, grads, err)
    outer = jax.tree.structure(grads)
    inner = jax.tree.structure((0, 0))
    return jax.tree_util.tree_transpose(outer, inner, pairs)


def int8_compress_decompress(grads: Dict) -> Dict:
    """Stateless round-trip (no error feedback) — drop-in `grad_transform`
    for `make_train_step`, simulating the numerics of a compressed
    all-reduce inside a jitted step."""
    compressed, _ = compress_grads(grads, None)
    return compressed


def compressed_bytes(grads: Dict) -> int:
    """Wire bytes of one compressed reduction (int8 payload + fp32 scale
    per tensor), for roofline/traffic accounting."""
    total = 0
    for g in jax.tree.leaves(grads):
        total += int(g.size) + 4
    return total
