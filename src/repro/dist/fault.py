"""Fault tolerance: checkpoint-resume supervisor + straggler detection.

`TrainSupervisor` owns the outer training loop: it restores the newest
valid checkpoint on start, runs the (jitted) step function, checkpoints
every `ckpt_every` completed steps, and — on an (injected or real) failure
— rolls back to the latest checkpoint, trims the metric log to the resume
point, and re-runs, so the returned metric log is contiguous across any
number of restarts. Corrupted checkpoints are quarantined by
`checkpoint.restore_latest` and the supervisor falls back to the previous
one (or a fresh init when none survive).

`StragglerPolicy` flags slow steps against an EMA of healthy step times;
flagged steps never contaminate the baseline.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.train import checkpoint as ckpt


class StragglerPolicy:
    """Tolerance-based slow-step detection. A step is a straggler when its
    duration exceeds `tolerance` x the EMA of previous healthy steps."""

    def __init__(self, tolerance: float = 3.0, ema_alpha: float = 0.2,
                 warmup_steps: int = 1, seed_steps: int = 3):
        self.tolerance = float(tolerance)
        self.ema_alpha = float(ema_alpha)
        self.warmup_steps = int(warmup_steps)
        self.seed_steps = max(int(seed_steps), 1)
        self.ema: Optional[float] = None
        self.slow_steps = 0
        self._seen = 0
        self._seed: list = []

    def observe(self, duration_s: float) -> bool:
        """Record one step duration; True when it is a straggler."""
        d = float(duration_s)
        self._seen += 1
        if self._seen <= self.warmup_steps:
            # warmup steps carry jit compilation; seeding the EMA with them
            # would blind detection for the early run
            return False
        if self.ema is None:
            # seed from the median of the first few steady steps so a
            # single transient stall cannot inflate the baseline
            self._seed.append(d)
            if len(self._seed) >= self.seed_steps:
                self.ema = float(np.median(self._seed))
            return False
        if d > self.tolerance * self.ema:
            self.slow_steps += 1
            return True
        self.ema = (1 - self.ema_alpha) * self.ema + self.ema_alpha * d
        return False


class TrainSupervisor:
    """Fault-tolerant outer loop around a pure train step.

    run(init_fn, step_fn, batches, total_steps, failure_injector=None):
      * init_fn() -> (params, opt_state)            fresh state
      * step_fn(params, opt_state, batch) -> (params, opt_state, metrics)
      * batches(step) -> batch pytree               deterministic per step
      * failure_injector(step) -> bool              True = crash before step
        (tests inject node failures; production wires real health checks)

    Returns {"params", "opt_state", "metrics", "restarts", "slow_steps"}.
    `metrics` is one dict per step, contiguous in `step` across restarts.
    """

    def __init__(self, ckpt_dir: str, ckpt_every: int = 50,
                 straggler: Optional[StragglerPolicy] = None,
                 max_restarts: int = 100, max_futile_restarts: int = 3,
                 run_tag: Optional[str] = None, shardings=None):
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = max(int(ckpt_every), 1)
        self.straggler = straggler or StragglerPolicy()
        self.max_restarts = max_restarts
        # optional (param_shardings, opt_shardings) trees: restored numpy
        # state is placed onto them before re-entering the jitted step, so
        # donation stays usable and no implicit re-transfer happens
        self.shardings = shardings
        # consecutive exception-restarts at the SAME step before giving up
        # (a deterministic bug should surface, not retry max_restarts times)
        self.max_futile_restarts = max(int(max_futile_restarts), 1)
        # identity stamped into checkpoint meta; resuming a dir written by a
        # different run_tag (e.g. another arch) fails loudly instead of
        # loading shape-mismatched state
        self.run_tag = run_tag

    # -- state (re)loading --------------------------------------------------

    def _resume_or_init(self, init_fn):
        restored = ckpt.restore_latest(self.ckpt_dir)
        if restored is None:
            params, opt_state = init_fn()
            return params, opt_state, 0
        params, opt_state, meta = restored
        tag = meta.get("run_tag")
        if self.run_tag is not None and tag != self.run_tag:
            # a missing tag is a mismatch too: untagged state is exactly as
            # likely to be shape-incompatible as a wrongly-tagged one
            raise RuntimeError(
                f"checkpoint dir {self.ckpt_dir!r} belongs to run "
                f"{tag!r}, not {self.run_tag!r}; refusing to resume — "
                "use a fresh --ckpt-dir")
        if self.shardings is not None:
            params = ckpt.to_device(params, sharding_tree=self.shardings[0])
            opt_state = ckpt.to_device(opt_state,
                                       sharding_tree=self.shardings[1])
        return params, opt_state, int(meta["step"])

    def _save(self, step, params, opt_state):
        extra = {"run_tag": self.run_tag} if self.run_tag else None
        ckpt.save_checkpoint(self.ckpt_dir, step, params, opt_state,
                             extra=extra)

    # -- main loop ----------------------------------------------------------

    def run(self, init_fn: Callable, step_fn: Callable,
            batches: Callable[[int], Dict], total_steps: int,
            failure_injector: Optional[Callable[[int], bool]] = None
            ) -> Dict:
        restarts = 0
        metrics: List[Dict] = []
        params, opt_state, step = self._resume_or_init(init_fn)
        last_saved = step
        last_fail_step, futile = -1, 0

        while step < total_steps:
            if failure_injector is not None and failure_injector(step):
                futile = futile + 1 if step == last_fail_step else 1
                last_fail_step = step
                restarts += 1
                if restarts > self.max_restarts or \
                        futile >= self.max_futile_restarts:
                    raise RuntimeError(
                        f"persistent failure at step {step} "
                        f"(restarts={restarts}, consecutive={futile})")
                params, opt_state, step = self._resume_or_init(init_fn)
                metrics = [m for m in metrics if m["step"] < step]
                continue

            t0 = time.time()
            try:
                params, opt_state, m = step_fn(params, opt_state,
                                               batches(step))
                entry = {"step": step}
                for k, v in m.items():
                    entry[k] = float(np.asarray(v))  # blocks until step done
            except Exception as e:
                # real failure path (device fault, OOM, ...): same rollback
                # as an injected one, bounded by max_restarts; repeated
                # failure of the SAME step is deterministic, not transient —
                # surface it instead of burning max_restarts retries
                futile = futile + 1 if step == last_fail_step else 1
                last_fail_step = step
                restarts += 1
                if restarts > self.max_restarts or \
                        futile >= self.max_futile_restarts:
                    raise
                print(f"[supervisor] step {step} failed "
                      f"({type(e).__name__}: {e}); rolling back "
                      f"(restart {restarts}/{self.max_restarts})", flush=True)
                params, opt_state, step = self._resume_or_init(init_fn)
                metrics = [m_ for m_ in metrics if m_["step"] < step]
                continue
            metrics.append(entry)
            self.straggler.observe(time.time() - t0)

            step += 1
            if step % self.ckpt_every == 0:
                self._save(step, params, opt_state)
                last_saved = step

        if last_saved < total_steps:
            self._save(total_steps, params, opt_state)
        return {"params": params, "opt_state": opt_state, "metrics": metrics,
                "restarts": restarts,
                "slow_steps": self.straggler.slow_steps}
