"""Distributed-training substrate: sharding rules, compressed collectives,
fault tolerance. Pure-python spec logic — importing this package never
touches jax device state (the launchers build meshes themselves)."""
from repro.dist import collectives, fault, sharding  # noqa: F401
