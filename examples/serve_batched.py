"""Serve a small model with batched requests through the continuous-batching
engine (prefill into fresh slots, per-slot positions, slot reuse).

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro.configs import reduced_config
from repro.models import model as M
from repro.models.runtime import CPU_TEST as RT
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = reduced_config("qwen2-0.5b")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(cfg, RT, params, slots=4, max_len=128)

    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=4 + 3 * i),
                    max_new_tokens=8 + (i % 3) * 4,
                    temperature=0.0 if i % 2 == 0 else 0.8)
            for i in range(8)]
    t0 = time.time()
    outs = engine.run(reqs)
    dt = time.time() - t0
    total = sum(len(v) for v in outs.values())
    for rid, toks in sorted(outs.items()):
        print(f"request {rid} ({len(reqs[rid].prompt)} prompt toks) "
              f"-> {toks}")
    print(f"\n{len(reqs)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s on CPU) with 4 slots")


if __name__ == "__main__":
    main()
