"""Theseus DSE case study: explore WSC designs for GPT-175B training with
MFMOBO (analytical + GNN fidelities), print the Pareto set and compare
against the H100-like / WSE2-like / Dojo-like baselines.

    PYTHONPATH=src python examples/dse_case_study.py [--quick] \
        [--fidelity analytical|gnn|sim]

With `--fidelity gnn` the high-fidelity stage runs the batched GNN backend
with *online calibration*: the model starts untrained and is fine-tuned on
simulator traces from the Pareto neighborhood at the f1 -> f0 handover
(repro.core.calibration). `--fidelity sim` runs the cycle-approximate
simulator itself as f0 through its batched backend.
"""
import argparse

from repro.core.baselines import DOJO_LIKE, WSE2_LIKE, gpu_cluster_eval
from repro.core.evaluator import (batched_objectives, evaluate_design,
                                  registered_backends)
from repro.core.mfmobo import run_mfmobo
from repro.core.validator import validate
from repro.core.workload import GPT_BENCHMARKS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--benchmark", type=int, default=7,
                    help="index into the GPT benchmark table (7 = 175B)")
    ap.add_argument("--fidelity", default="analytical",
                    choices=registered_backends(),
                    help="fidelity backend for the f0 (high-fidelity) stage")
    args = ap.parse_args()

    wl = GPT_BENCHMARKS[1 if args.quick else args.benchmark]
    print(f"workload: {wl.name} training, batch {wl.batch} x seq {wl.seq}, "
          f"GPU budget {wl.gpu_budget}, f0 fidelity: {args.fidelity}")

    f1 = batched_objectives(wl, "analytical")
    on_handover = None
    if args.fidelity == "gnn":
        import jax

        from repro.core.calibration import GNNCalibrator
        from repro.core.noc_gnn import init_gnn

        cal = GNNCalibrator(init_gnn(jax.random.PRNGKey(0)), wl,
                            n_designs=3 if args.quick else 6,
                            epochs=5 if args.quick else 20)
        f0 = cal.objectives()
        on_handover = cal.on_handover
    else:
        f0 = batched_objectives(wl, args.fidelity)
    tr = run_mfmobo(f0, f1, d0=2, d1=3, k=3,
                    N0=6 if args.quick else 14,
                    N1=8 if args.quick else 18,
                    n_candidates=64, q=2 if args.quick else 4, seed=0,
                    on_handover=on_handover)
    front = tr.pareto()
    print(f"\nexplored {len(tr.ys)} high-fidelity designs; "
          f"hypervolume {tr.hv[0]:.2f} -> {tr.hv[-1]:.2f}")
    best_i = max(range(len(tr.ys)), key=lambda i: tr.ys[i][0])
    print(f"best design: {tr.designs[best_i].describe()}")
    print(f"  throughput {tr.ys[best_i][0]:.0f} tok/s, "
          f"power {tr.ys[best_i][1]/1e3:.1f} kW/wafer")

    gpu_t, gpu_p = gpu_cluster_eval(wl)
    print(f"\nbaselines at matched total area:")
    print(f"  H100-like cluster: {gpu_t:.0f} tok/s, {gpu_p/1e3:.0f} kW")
    for name, d in (("WSE2-like", WSE2_LIKE), ("Dojo-like", DOJO_LIKE)):
        v = validate(d)
        r = evaluate_design(v.design if v.ok else d, wl, max_strategies=8)
        print(f"  {name}: {r.throughput:.0f} tok/s, {r.power_w/1e3:.1f} kW "
              f"(strategy {r.strategy})")


if __name__ == "__main__":
    main()
