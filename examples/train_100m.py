"""End-to-end driver: train the full smollm-135m (~135M params) for a few
hundred steps with checkpoint/restart. On CPU this is slow; pass --steps to
shorten, or run on a TPU host unchanged (add --data/--model mesh axes).

    PYTHONPATH=src python examples/train_100m.py --steps 300
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()
    train_main([
        "--arch", "smollm-135m",
        "--steps", str(args.steps),
        "--batch", str(args.batch),
        "--seq", str(args.seq),
        "--lr", "1e-3",
        "--ckpt-dir", "/tmp/repro_ckpt_100m",
        "--ckpt-every", "50",
    ])


if __name__ == "__main__":
    main()
