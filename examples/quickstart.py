"""Quickstart: build a model, train it a little, generate from it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.models import model as M
from repro.models.runtime import CPU_TEST as RT
from repro.serve.engine import Request, ServeEngine
from repro.train.data import MarkovLMDataset
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step


def main():
    cfg = reduced_config("smollm-135m")
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.2f}M params)")

    rng = jax.random.PRNGKey(0)
    params = M.init_params(rng, cfg)
    ds = MarkovLMDataset(vocab=cfg.vocab, seq_len=32, batch=8, seed=1)
    step = jax.jit(make_train_step(
        cfg, RT, AdamWConfig(peak_lr=3e-3, warmup_steps=5, total_steps=80),
        microbatches=2))
    ost = init_opt_state(params)
    for i in range(80):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(i).items()}
        params, ost, m = step(params, ost, batch)
        if i % 20 == 0:
            print(f"  step {i:3d} loss {float(m['loss']):.3f}")
    print(f"  final loss {float(m['loss']):.3f} "
          f"(floor ~{ds.conditional_entropy():.3f})")

    engine = ServeEngine(cfg, RT, params, slots=2, max_len=64)
    outs = engine.run([Request(rid=i, prompt=np.arange(4 + i) % cfg.vocab,
                               max_new_tokens=8) for i in range(3)])
    for rid, toks in sorted(outs.items()):
        print(f"  request {rid}: {toks}")


if __name__ == "__main__":
    main()
