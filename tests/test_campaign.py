"""Campaign API (DESIGN.md §9): spec JSON round-trip, checkpoint/resume
bit-identity, constraint handling, budget exactness, candidate-sampling
failure modes, per-stage cache accounting, CLI."""
import dataclasses
import glob
import json
import os
import types

import numpy as np
import pytest

from repro.core import components as C
from repro.core.design_space import encode_batch
from repro.core.evaluator import clear_eval_cache, eval_cache_stats
from repro.core.workload import GPT_BENCHMARKS
from repro.explore import (
    Campaign,
    CampaignSpec,
    ConstraintSpec,
    EvaluatorObjective,
    FidelitySchedule,
    LoopConfig,
    ObjectiveSpec,
    ServingSpec,
    as_objective,
    resolve_workload,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def quick_spec(**over) -> CampaignSpec:
    kw = dict(
        name="t-quick", workload="GPT-1.7B", scenario="train",
        strategy="mfmobo",
        fidelity=FidelitySchedule(f1="analytical", f0="analytical",
                                  d1=2, d0=2, k=2),
        n_evals_f0=5, n_evals_f1=6, q=2, n_candidates=16,
        max_strategies=6, seed=7)
    kw.update(over)
    return CampaignSpec(**kw)


# --------------------------- spec serialization -----------------------------


def test_spec_json_roundtrip_exact():
    spec = quick_spec(
        constraints=(ConstraintSpec("power_per_wafer", "<=", 4000.0),),
        objectives=(ObjectiveSpec("throughput", "max", "log1p"),
                    ObjectiveSpec("power_per_wafer", "min", "neg_log")),
        workload_overrides={"batch": 256},
        serving=None)
    blob = spec.to_json()
    again = CampaignSpec.from_json(blob)
    assert again == spec
    # and through a dict cycle with json in the middle
    assert CampaignSpec.from_dict(json.loads(
        json.dumps(spec.to_dict()))) == spec


def test_spec_file_roundtrip(tmp_path):
    spec = quick_spec(serving=ServingSpec(n_requests=4, out_len=8),
                      scenario="serving", strategy="mobo")
    p = tmp_path / "c.json"
    spec.to_json(str(p))
    assert CampaignSpec.from_json(str(p)) == spec


def test_spec_rejects_unknowns_and_bad_refs():
    with pytest.raises(ValueError, match="unknown campaign spec fields"):
        CampaignSpec.from_dict({"name": "x", "workload": "GPT-1.7B",
                                "frobnicate": 1})
    with pytest.raises(ValueError, match="unknown workload ref"):
        quick_spec(workload="GPT-9999B").validate()
    with pytest.raises(ValueError, match="unknown scenario"):
        quick_spec(scenario="overclock").validate()
    with pytest.raises(ValueError, match="needs a `serving` spec"):
        quick_spec(scenario="serving", strategy="mobo").validate()
    with pytest.raises(ValueError, match="constraint metric"):
        quick_spec(constraints=(
            ConstraintSpec("ttft", "<=", 1.0),)).validate()


def test_shipped_example_specs_parse_and_validate():
    from repro.explore import FleetSpec
    paths = sorted(glob.glob(os.path.join(REPO, "examples", "campaigns",
                                          "*.json")))
    assert len(paths) >= 4, "expected shipped example campaign specs"
    for p in paths:
        with open(p) as f:
            raw = json.load(f)
        if "campaigns" in raw or "grid" in raw:       # fleet grid spec
            fleet = FleetSpec.from_json(p).validate()
            assert len(fleet.campaigns) > 0
            continue
        spec = CampaignSpec.from_json(p).validate()
        assert spec.loop_config().total_evals() > 0


def test_resolve_workload_config_ref_and_overrides():
    spec = quick_spec(workload="smollm-135m@decode_32k",
                      scenario="inference",
                      workload_overrides={"batch": 8, "seq": 512})
    wl = resolve_workload(spec)
    assert wl.phase == "decode" and wl.batch == 8 and wl.seq == 512
    # train scenario pins the phase
    assert resolve_workload(quick_spec()).phase == "train"


# --------------------------- campaign execution -----------------------------


@pytest.fixture(scope="module")
def quick_run():
    clear_eval_cache()
    return Campaign(quick_spec()).run()


def test_campaign_budget_and_trace(quick_run):
    spec = quick_spec()
    assert quick_run.finished
    # exact budgets: N0 f0-points recorded, N0+N1 total evaluations
    assert len(quick_run.trace.ys) == spec.n_evals_f0
    assert quick_run.n_evals == spec.n_evals_f0 + spec.n_evals_f1
    assert quick_run.hv_final >= quick_run.trace.hv[0]
    assert quick_run.candidates_per_sec > 0


def test_campaign_stage_cache_recorded(quick_run):
    sc = quick_run.stage_cache
    assert set(sc) == {"f0", "f1"}
    for stage in ("f0", "f1"):
        assert sc[stage]["hits"] + sc[stage]["misses"] > 0
        assert 0.0 <= sc[stage]["hit_rate"] <= 1.0
        assert sc[stage]["entries_added"] >= 0
    # trace carries the same accounting (satellite: handover cost visible)
    assert quick_run.trace.stage_cache["f1"]["misses"] > 0
    assert set(quick_run.trace.cache_hit_rates()) == {"f0", "f1"}


def test_checkpoint_resume_bit_identical(tmp_path):
    """A campaign interrupted mid-run and resumed from its checkpoint
    reproduces the uninterrupted trace bit-for-bit at the same seed."""
    full = Campaign(quick_spec()).run()
    ck = str(tmp_path / "c.ckpt.pkl")
    partial = Campaign(quick_spec()).run(checkpoint_path=ck, max_steps=2)
    assert not partial.finished
    assert len(partial.trace.ys) < len(full.trace.ys)
    resumed = Campaign.resume(ck).run(checkpoint_path=ck)
    assert resumed.finished
    assert [tuple(y) for y in resumed.trace.ys] == \
        [tuple(y) for y in full.trace.ys]
    assert resumed.trace.hv == full.trace.hv
    assert all(np.array_equal(a, b)
               for a, b in zip(resumed.trace.xs, full.trace.xs))
    assert resumed.trace.designs == full.trace.designs


def test_serving_campaign_constraints_exclude_from_front():
    """SLO-violating candidates are mapped to the penalty point and never
    enter the Pareto front."""
    spec = quick_spec(
        scenario="serving", strategy="random", n_evals_f0=6, q=6,
        serving=ServingSpec(n_requests=4, prompt_len=256, out_len=8,
                            slots=2, ttft_s=1e9, tpot_s=1e9),
        max_strategies=6, seed=1)
    base = Campaign(spec).run()
    goods = [y for y in base.trace.ys if y[0] > 0]
    assert len(goods) >= 2, "need some feasible serving designs"
    # bind on the median power so some candidates violate
    cap = float(np.median([y[1] for y in goods]))
    spec_c = dataclasses.replace(
        spec, constraints=(ConstraintSpec("power_per_wafer", "<=", cap),))
    cam = Campaign(spec_c)
    res = cam.run()
    assert cam.f0.n_violations > 0
    assert res.objective_stats["f0"]["n_constraint_violations"] > 0
    # violating candidates land on the penalty point...
    for y in res.trace.ys:
        assert y[0] == 0.0 or y[1] <= cap
    # ...and the reported front only contains constraint-satisfying points
    assert res.front, "front should not be empty"
    for p in res.front:
        assert p["power_per_wafer"] <= cap


def test_resume_restores_objective_counters(tmp_path):
    """Counters (violations/infeasible) survive checkpoint/resume, so a
    resumed campaign reports the same cumulative stats as an uninterrupted
    one."""
    spec = quick_spec(
        constraints=(ConstraintSpec("power_per_wafer", "<=", 1000.0),))
    full = Campaign(spec)
    full_res = full.run()
    assert full.f0.n_violations + full.f0.n_infeasible > 0, \
        "cap should bind for this seed"
    ck = str(tmp_path / "c.ckpt.pkl")
    Campaign(spec).run(checkpoint_path=ck, max_steps=3)
    resumed = Campaign.resume(ck).run(checkpoint_path=ck)
    assert resumed.objective_stats == full_res.objective_stats


def test_validate_rejects_swapped_objective_directions():
    spec = quick_spec(objectives=(
        ObjectiveSpec("power_per_wafer", "min", "neg_log"),
        ObjectiveSpec("throughput", "max", "log1p")))
    with pytest.raises(ValueError, match="must be .max, min."):
        spec.validate()
    # transforms the loop would silently not apply must not validate
    spec = quick_spec(objectives=(
        ObjectiveSpec("throughput", "max", "identity"),
        ObjectiveSpec("power_per_wafer", "min", "neg_log")))
    with pytest.raises(ValueError, match="transforms must be"):
        spec.validate()


def test_hetero_objective_reads_live_params():
    """Hetero campaigns must see calibrated params: the objective
    dereferences params_fn at call time, not a construction-time
    snapshot."""
    from repro.explore import HeteroServingObjective

    box = {"params": None}
    sv = ServingSpec(n_requests=2, prompt_len=128, out_len=4, slots=2,
                     ttft_s=1e9, tpot_s=1e9)
    obj = HeteroServingObjective(
        GPT_BENCHMARKS[0], sv.mix(), sv.slo(), granularity="reticle",
        params_fn=lambda: box["params"])
    assert obj.gnn_params() is None
    box["params"] = {"w": 1}
    assert obj.gnn_params() == {"w": 1}      # live, not a snapshot


def test_periodic_checkpoint_carries_wall_time(tmp_path):
    """wall_s is flushed into the state before each periodic checkpoint, so
    a crash-resume doesn't under-report wall time (and overstate
    candidates/sec)."""
    from repro.explore.runner import ExplorationLoop, LoopConfig

    f = synthetic_fns()
    cfg = LoopConfig(strategy="mobo", N0=6, d0=2, q=2, n_candidates=12,
                     seed=0)
    loop = ExplorationLoop(cfg, f)
    ck = str(tmp_path / "w.ckpt")
    seen = []
    loop.run(checkpoint_every=1,
             checkpoint_cb=lambda: seen.append(
                 (loop.save_state(ck), loop.state.wall_s)))
    walls = [w for _, w in seen]
    assert walls[0] > 0.0                    # first periodic ckpt, not 0
    assert all(b >= a for a, b in zip(walls, walls[1:]))
    _, state, _ = ExplorationLoop.load_state(ck)
    assert state.wall_s == pytest.approx(loop.state.wall_s)


def test_hetero_objective_emits_every_advertised_metric():
    """Every metric known_metrics() advertises for a scenario must exist in
    the objective's metrics dicts (constraints on them must not KeyError)."""
    from benchmarks.common import sample_valid_designs
    from repro.explore import HeteroServingObjective, ServingObjective

    sv = ServingSpec(n_requests=2, prompt_len=128, out_len=4, slots=2,
                     ttft_s=1e9, tpot_s=1e9)
    wl = GPT_BENCHMARKS[0]
    cases = {
        "hetero": HeteroServingObjective(
            wl, sv.mix(), sv.slo(), granularity="reticle", n_wafers=4),
        "serving": ServingObjective(wl, sv.mix(), sv.slo(), slots=2,
                                    max_strategies=4),
        "train": EvaluatorObjective(wl, max_strategies=4),
    }
    d = sample_valid_designs(1, seed=6)
    for scenario, obj in cases.items():
        known = quick_spec(scenario=scenario, serving=sv).known_metrics()
        m = obj.metrics(d)[0]
        missing = set(known) - set(m)
        assert not missing, f"{scenario}: metrics missing {missing}"


def test_constraint_spec_semantics():
    c = ConstraintSpec("ttft", "<=", 2.0)
    assert c.ok({"ttft": 1.5}) and not c.ok({"ttft": 2.5})
    with pytest.raises(KeyError, match="not produced"):
        c.ok({"goodput": 1.0})
    with pytest.raises(ValueError, match="constraint op"):
        ConstraintSpec("ttft", "==", 2.0)


def test_evaluator_objective_metrics_and_penalty():
    wl = GPT_BENCHMARKS[0]
    from benchmarks.common import sample_valid_designs
    designs = sample_valid_designs(4, seed=2)
    free = EvaluatorObjective(wl, "analytical", max_strategies=6)
    ys = free.eval_many(designs)
    capped = EvaluatorObjective(
        wl, "analytical", max_strategies=6,
        constraints=(ConstraintSpec("power_per_wafer", "<=", -1.0),))
    ys_c = capped.eval_many(designs)
    # everything violates an impossible cap -> all penalty points
    assert all(y == (0.0, C.WAFER_POWER_W) for y in ys_c)
    assert capped.n_violations == sum(1 for y in ys if y[0] > 0)
    # legacy calling conventions survive on the protocol object
    assert free.batched and free.fidelity == "analytical"
    assert free(designs[0]) == ys[0]


# --------------------------- loop regressions -------------------------------


def synthetic_fns():
    def f(designs):
        U = encode_batch(designs)
        return [(float(1e5 * (1 + u[1] + u[4])),
                 float(5e3 * (0.5 + u[1] ** 2))) for u in U]
    f.batched = True
    return f


def test_budget_never_overshoots_with_q():
    """Regression (ISSUE 5): with q > 1 and budgets not divisible by q,
    the final batch is clamped so traces honor N0/N1 exactly."""
    from repro.core.mfmobo import run_mfmobo, run_mobo

    f = synthetic_fns()
    tr = run_mobo(f, d0=3, N=10, q=4, n_candidates=24, seed=0)
    assert len(tr.ys) == 10 and tr.n_evals == 10
    tr = run_mfmobo(f, f, d0=2, d1=2, k=2, N0=5, N1=6, q=4,
                    n_candidates=24, seed=0)
    assert len(tr.ys) == 5          # exactly the f0 budget
    assert tr.n_evals == 11         # N0 + N1, not a q-multiple overshoot


def test_priors_exceeding_budget_raise():
    from repro.core.mfmobo import run_mfmobo, run_mobo

    f = synthetic_fns()
    with pytest.raises(ValueError, match="priors"):
        run_mobo(f, d0=8, N=4)
    with pytest.raises(ValueError, match="priors"):
        run_mfmobo(f, f, d0=9, N0=4)
    with pytest.raises(ValueError, match="unknown strategy"):
        LoopConfig(strategy="anneal").validate()


def test_valid_candidates_raises_when_space_rejects(monkeypatch):
    """Regression (ISSUE 5): `_valid_candidates` must not silently return a
    short (or empty) candidate set — it tops up across rounds and raises a
    clear error when the validator rejects (nearly) everything."""
    import repro.core.mfmobo as M

    rng = np.random.default_rng(0)
    monkeypatch.setattr(M, "validate_batch", lambda ds: [
        types.SimpleNamespace(ok=False, design=d) for d in ds])
    with pytest.raises(RuntimeError, match="valid candidates") as ei:
        M._valid_candidates(rng, 8, max_tries=2)
    assert "acceptance rate" in str(ei.value)    # satellite: rate surfaced

    # sparse acceptance still tops up to exactly n
    calls = {"n": 0}

    def sparse_batch(ds):
        out = []
        for d in ds:
            calls["n"] += 1
            out.append(types.SimpleNamespace(ok=calls["n"] % 3 == 0, design=d))
        return out
    monkeypatch.setattr(M, "validate_batch", sparse_batch)
    xs, ds = M._valid_candidates(np.random.default_rng(1), 8, max_tries=8)
    assert len(xs) == len(ds) == 8


def test_eval_cache_stats_entries():
    """Satellite: `eval_cache_stats()` exposes a live entry count."""
    from repro.core.evaluator import evaluate_design
    clear_eval_cache()
    s0 = eval_cache_stats()
    assert s0["entries"] == 0 and s0["size"] == 0
    from benchmarks.common import sample_valid_designs
    d = sample_valid_designs(1, seed=3)[0]
    evaluate_design(d, GPT_BENCHMARKS[0], max_strategies=4)
    s1 = eval_cache_stats()
    assert s1["entries"] == s1["size"] == 1
    assert s1["misses"] == 1


def test_as_objective_coercions():
    scalar_calls = []

    def scalar(d):
        scalar_calls.append(d)
        return 1.0, 2.0

    from benchmarks.common import sample_valid_designs
    designs = sample_valid_designs(3, seed=4)
    obj = as_objective(scalar)
    assert obj.eval_many(designs) == [(1.0, 2.0)] * 3
    assert len(scalar_calls) == 3            # scalar loop
    batched = synthetic_fns()
    obj_b = as_objective(batched)
    assert len(obj_b.eval_many(designs)) == 3
    assert as_objective(obj_b) is obj_b      # idempotent
    with pytest.raises(TypeError):
        as_objective(42)


def test_cli_validate_and_run(tmp_path):
    from repro.explore.__main__ import main

    spec = quick_spec(n_evals_f0=4, n_evals_f1=5, q=2,
                      fidelity=FidelitySchedule(d1=2, d0=2, k=1))
    p = tmp_path / "spec.json"
    spec.to_json(str(p))
    assert main(["--validate", str(p)]) == 0
    out = tmp_path / "r.json"
    ck = tmp_path / "c.pkl"
    assert main([str(p), "--out", str(out), "--checkpoint", str(ck)]) == 0
    res = json.loads(out.read_text())
    assert res["finished"] and res["n_evals"] == 9
    assert res["spec"]["name"] == "t-quick"
    assert "stage_cache" in res and "hv" in res
    # resume path: run 1 step elsewhere, then --resume completes it
    ck2 = tmp_path / "c2.pkl"
    out2 = tmp_path / "r2.json"
    assert main([str(p), "--out", str(out2), "--checkpoint", str(ck2),
                 "--max-steps", "1"]) == 0
    assert not json.loads(out2.read_text())["finished"]
    assert main(["--resume", str(ck2), "--out", str(out2)]) == 0
    res2 = json.loads(out2.read_text())
    assert res2["finished"]
    assert res2["hv"] == res["hv"]           # same spec, same seed
