"""Fidelity backend registry + batched graph-fidelity equivalence, and the
online-calibration loop."""
import jax
import numpy as np
import pytest

from repro.core import fidelity as F
from repro.core.compiler import compile_chunk, row_allgather_pattern
from repro.core.design_space import WSCDesign, decode
from repro.core.evaluator import (
    clear_eval_cache,
    evaluate_design,
    evaluate_design_batch,
    gnn_params_token,
)
from repro.core.noc_gnn import featurize_transfer, init_gnn
from repro.core.noc_sim import packets_for_transfer, simulate, simulate_many
from repro.core.validator import validate
from repro.core.workload import GPT_BENCHMARKS


# --------------------------- registry ---------------------------------------


def test_unknown_fidelity_raises_with_registered_list():
    with pytest.raises(ValueError) as ei:
        F.get_backend("cycle_exact")
    msg = str(ei.value)
    assert "cycle_exact" in msg
    for name in ("analytical", "gnn", "sim"):
        assert name in msg
    # the same failure surfaces through the public entry points
    d = validate(WSCDesign()).design
    with pytest.raises(ValueError):
        evaluate_design(d, GPT_BENCHMARKS[0], fidelity="anaytical")
    with pytest.raises(ValueError):
        evaluate_design_batch([d], GPT_BENCHMARKS[0], fidelity="")


def test_builtins_registered_and_instances_pass_through():
    assert F.registered_backends() == ("analytical", "gnn", "sim")
    backend = F.get_backend("sim")
    assert F.get_backend(backend) is backend


def test_register_custom_backend_roundtrip():
    class Fixed:
        name = "fixed-latency"

        def chunk_latency(self, graph, design, gnn_params=None):
            return 123.0

        def evaluate_batch(self, geom, wl, n_wafers, max_strategies=24,
                           gnn_params=None):
            ax = F.build_candidate_axis(geom, wl, n_wafers, max_strategies)
            return F._finish(ax, wl, np.full(len(ax.didx), 123.0))

    try:
        F.register_backend(Fixed())
        assert "fixed-latency" in F.registered_backends()
        d = validate(WSCDesign()).design
        clear_eval_cache()
        r = evaluate_design_batch([d], GPT_BENCHMARKS[0],
                                  fidelity="fixed-latency",
                                  max_strategies=4)[0]
        assert r.feasible
    finally:
        F._REGISTRY.pop("fixed-latency", None)


# --------------------------- params-version token ---------------------------


def test_params_token_is_monotonic_and_never_aliases():
    clear_eval_cache()
    assert gnn_params_token(None) is None
    p1 = {"w": np.zeros(3)}
    t1 = gnn_params_token(p1)
    assert gnn_params_token(p1) == t1          # stable while pinned
    # overflow the pin table: p1's pin is evicted, its token retired
    extras = [{"w": np.zeros(1)} for _ in range(40)]
    tokens = [gnn_params_token(p) for p in extras]
    assert len(set(tokens)) == len(tokens)     # all distinct
    t1b = gnn_params_token(p1)
    assert t1b != t1                           # re-pinned => fresh token
    assert t1b > max(tokens)                   # strictly monotonic counter


# --------------------------- pattern tables ---------------------------------


def test_row_allgather_pattern_matches_compiled_featurization():
    """The memoized pattern tables reproduce featurize_transfer /
    packets_for_transfer structure bit-for-bit on a compiled chunk."""
    d = validate(WSCDesign()).design
    wl = GPT_BENCHMARKS[0]
    g = compile_chunk(d, wl, tp=16, mb_tokens=2048, cores_per_chunk=64)
    gh, gw = g.array
    pat = row_allgather_pattern(gh, gw)
    for t_idx in range(len(g.transfers)):
        if not g.transfers[t_idx].pairs:
            continue
        ref = featurize_transfer(g, d, t_idx)
        np.testing.assert_array_equal(pat.senders, ref.senders)
        np.testing.assert_array_equal(pat.receivers, ref.receivers)
        pkts = packets_for_transfer(g, d, t_idx)
        flits = {p.flits for p in pkts}
        assert len(flits) == 1                 # uniform per transfer
        fl = flits.pop()
        interval = g.ops[g.transfers[t_idx].src_op].tile.out_interval_cycles
        np.testing.assert_array_equal(pat.src, [p.src for p in pkts])
        np.testing.assert_array_equal(pat.dst, [p.dst for p in pkts])
        np.testing.assert_allclose(pat.seq * interval,
                                   [p.inject for p in pkts])
        dur = max(g.ops[g.transfers[t_idx].src_op].tile.cycles, 1.0)
        lanes = F._GridLanes(pattern=pat, u_lane=np.zeros(1, np.int64),
                             flits=np.array([float(fl)]),
                             interval=np.array([interval]),
                             dur=np.array([dur]),
                             noc_bw=np.array([float(d.noc_bw)]))
        node_x, edge_x = F._pattern_features(lanes)
        np.testing.assert_array_equal(node_x[0], ref.node_x)
        np.testing.assert_array_equal(edge_x[0], ref.edge_x)


def test_row_decomposition_makespan_invariant():
    """A transfer's sim makespan on the (gh, gw) grid equals the (1, gw)
    single-row makespan — the invariant the batched graph backends use."""
    d = validate(WSCDesign()).design
    wl = GPT_BENCHMARKS[0]
    g = compile_chunk(d, wl, tp=16, mb_tokens=2048, cores_per_chunk=64)
    gh, gw = g.array
    assert gh > 1
    for t_idx in (0, len(g.transfers) - 1):
        if not g.transfers[t_idx].pairs:
            continue
        pkts = packets_for_transfer(g, d, t_idx)
        full = simulate(pkts, gw).makespan
        row = [p for p in pkts if p.src < gw]          # row 0 only
        assert np.isclose(simulate(row, gw).makespan, full)


# --------------------------- batched vs scalar ------------------------------


@pytest.mark.parametrize("fidelity", ["gnn", "sim"])
def test_graph_fidelity_batch_matches_scalar(fidelity):
    wl = GPT_BENCHMARKS[0]
    rng = np.random.default_rng(42)
    designs = []
    while len(designs) < 4:
        r = validate(decode(rng.random(13)))
        if r.ok:
            designs.append(r.design)
    params = init_gnn(jax.random.PRNGKey(0)) if fidelity == "gnn" else None
    clear_eval_cache()
    serial = [evaluate_design(d, wl, fidelity=fidelity, gnn_params=params,
                              max_strategies=6) for d in designs]
    clear_eval_cache()
    batch = evaluate_design_batch(designs, wl, fidelity=fidelity,
                                  gnn_params=params, max_strategies=6)
    for a, b in zip(serial, batch):
        assert a.feasible == b.feasible
        assert a.n_wafers == b.n_wafers
        if a.feasible:
            assert a.strategy == b.strategy
            assert np.isclose(a.throughput, b.throughput, rtol=1e-5)
            assert np.isclose(a.power_w, b.power_w, rtol=1e-5)


def test_gnn_without_params_degrades_to_analytical():
    d = validate(WSCDesign()).design
    wl = GPT_BENCHMARKS[0]
    clear_eval_cache()
    a = evaluate_design_batch([d], wl, fidelity="analytical",
                              max_strategies=6)[0]
    g = evaluate_design_batch([d], wl, fidelity="gnn", max_strategies=6)[0]
    assert np.isclose(a.throughput, g.throughput)


# --------------------------- calibration ------------------------------------


def test_pareto_neighborhood_prefers_front():
    from repro.core.calibration import pareto_neighborhood
    designs = [validate(WSCDesign(mac_num=2 ** i)).design
               for i in (6, 7, 8, 9)]
    # design 1 dominates 0; 2 and 3 trade off
    ys = [(100.0, 5000.0), (200.0, 4000.0), (300.0, 6000.0), (50.0, 1000.0)]
    picked = pareto_neighborhood(designs, ys, 2)
    assert designs[0] not in picked
    assert len(picked) == 2


def test_calibrator_on_handover_finetunes_params():
    from repro.core.calibration import GNNCalibrator
    wl = GPT_BENCHMARKS[0]
    designs = [validate(WSCDesign()).design,
               validate(WSCDesign(mac_num=256)).design]
    ys = [(100.0, 5000.0), (120.0, 6000.0)]
    p0 = init_gnn(jax.random.PRNGKey(1))
    cal = GNNCalibrator(p0, wl, n_designs=1, epochs=2, patience=None)
    f0 = cal.objectives()
    assert getattr(f0, "batched", False) and f0.fidelity == "gnn"
    cal.on_handover(designs, ys)
    assert len(cal.records) == 1
    rec = cal.records[0]
    assert rec.n_graphs > 0 and len(rec.history.train_loss) > 0
    assert rec.history.val_loss and rec.history.val_kendall_tau
    assert cal.params is not p0               # fine-tuned copy
    # fresh params => fresh cache namespace
    assert gnn_params_token(cal.params) != gnn_params_token(p0)


def test_simulate_many_matches_scalar_bitwise():
    from repro.core.noc_sim import Packet
    rng = np.random.default_rng(3)
    lanes, Ws = [], []
    for _ in range(5):
        W = int(rng.integers(2, 6))
        n = int(rng.integers(1, 30))
        pkts = [Packet(src=int(rng.integers(0, W * 3)),
                       dst=int(rng.integers(0, W * 3)),
                       flits=int(rng.integers(1, 9)),
                       inject=float(rng.integers(0, 5)))
                for _ in range(n)]
        lanes.append(pkts)
        Ws.append(W)
    batch = simulate_many(lanes, Ws)
    for pkts, W, got in zip(lanes, Ws, batch):
        ref = simulate(pkts, W)
        assert got.makespan == ref.makespan
        assert got.avg_latency == ref.avg_latency
        assert set(got.link_wait) == set(ref.link_wait)
        for k in ref.link_wait:
            assert got.link_wait[k] == ref.link_wait[k]
