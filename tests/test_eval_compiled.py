"""Compiled analytical evaluator (repro.core.eval_compiled, DESIGN.md §12).

The jitted pipeline must be *bit-identical* to the retained NumPy oracles
(`AnalyticalBackend.evaluate_batch_ref`, `feasible_strategy_arrays_ref`):
the fused propose→evaluate iteration feeds the same eval cache and the
same campaign traces as the unfused path, so any drift — even 1 ulp —
forks the checkpoint/resume history. The fixture
tests/data/fig8_trace_pr7_baseline.json was generated at the pre-change
HEAD (PR 7, pure NumPy evaluation); the campaign test replays it through
the fused compiled loop and demands hex equality.
"""
import dataclasses
import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

from repro.core import eval_compiled
from repro.core.compiler import feasible_strategy_arrays_ref
from repro.core.design_space import DesignBatch, decode_batch
from repro.core.fidelity import AnalyticalBackend
from repro.core.workload import GPT_BENCHMARKS, inference_workload

DATA = os.path.join(os.path.dirname(__file__), "data")


def _designs(seed: int, n: int):
    rng = np.random.default_rng(seed)
    designs = decode_batch(rng.random((n, 13)))
    nw = rng.integers(1, 9, size=n).astype(np.int64)
    return designs, DesignBatch.from_designs(designs), nw


def _hex(v) -> str:
    return float(np.float64(v)).hex()


def _result_fingerprint(r):
    """Every float hex-exact, plus the discrete fields."""
    out = {"feasible": r.feasible, "n_wafers": r.n_wafers,
           "reason": r.reason,
           "strategy": None if r.strategy is None else list(
               dataclasses.astuple(r.strategy))}
    if r.feasible:
        out.update(throughput=_hex(r.throughput), power_w=_hex(r.power_w),
                   step_time_s=_hex(r.step.step_time_s),
                   pipeline_eff=_hex(r.step.pipeline_eff),
                   energy_j=_hex(r.step.energy_j),
                   breakdown={k: _hex(v)
                              for k, v in r.step.breakdown.items()})
    return out


@pytest.mark.parametrize("wl_case", ["train", "prefill", "decode"])
def test_compiled_matches_numpy_ref_bit_exact(wl_case):
    wl = GPT_BENCHMARKS[0]
    if wl_case != "train":
        wl = inference_workload(wl, wl_case, 8, 2048)
    designs, geom, nw = _designs(7, 16)
    be = AnalyticalBackend()
    ref = be.evaluate_batch_ref(geom, wl, nw, max_strategies=24)
    got = eval_compiled.evaluate_batch_compiled(geom, wl, nw,
                                                max_strategies=24)
    assert len(ref) == len(got) == 16
    for i, (r, g) in enumerate(zip(ref, got)):
        assert _result_fingerprint(r) == _result_fingerprint(g), f"row {i}"


def test_compiled_matches_ref_across_strategy_caps():
    wl = GPT_BENCHMARKS[4]
    designs, geom, nw = _designs(3, 8)
    be = AnalyticalBackend()
    for ms in (8, 24):
        ref = be.evaluate_batch_ref(geom, wl, nw, max_strategies=ms)
        got = eval_compiled.evaluate_batch_compiled(geom, wl, nw,
                                                    max_strategies=ms)
        for r, g in zip(ref, got):
            assert _result_fingerprint(r) == _result_fingerprint(g)


def test_strategy_grid_selection_matches_ref():
    """The baked pow2-padded grid + in-program mask reproduce
    `feasible_strategy_arrays_ref` exactly: same mask, same sorted order,
    same cap, same (1,1,1,1) fallback. Pad rows must never be selectable."""
    wl = GPT_BENCHMARKS[0]
    prog = eval_compiled._program_for(wl, 24)
    # pad rows are engineered infeasible under any budget
    g = len(feasible_strategy_arrays_ref(wl, 2 ** 62, np.inf, 10 ** 9))
    assert prog._tp_o.shape[0] >= g
    assert (prog._need_o[g:] == np.inf).all()
    designs, geom, nw = _designs(11, 8)
    for i in range(8):
        tc = int(geom.total_cores[i]) * int(nw[i])
        sram = float(geom.buffer_kb[i]) * 1024.0 * geom.total_cores[i] * nw[i]
        dram = (float(geom.dram_gb_per_reticle[i]) * 1e9
                * int(geom.n_reticles[i]) * int(nw[i]))
        budget = sram + dram
        ref = feasible_strategy_arrays_ref(wl, tc, budget, 24)
        # host-side replay of the in-program mask over the baked grid
        mask = ((prog._chunks_o * prog._tp_o <= tc) & (prog._tp_o <= tc)
                & (prog._need_o <= budget))
        sel = np.flatnonzero(mask)[:24]
        if len(sel) == 0:
            got = np.array([[1, 1, 1, 1]], np.int64)
        else:
            got = np.stack([prog._tp_o[sel], prog._pp_o[sel],
                            prog._dp_o[sel], prog._mb_o[sel]], axis=1)
        assert (ref == got).all(), f"design {i}"


def test_warm_no_retrace_within_bucket():
    """`warm_optimizer_kernels(workload=...)` pre-compiles the evaluator
    buckets; any batch size inside a warmed bucket must then run without
    a single new trace (the PR 6 no-retrace contract, extended to the
    evaluator)."""
    from repro.core.mfmobo import warm_optimizer_kernels

    wl = GPT_BENCHMARKS[0]
    warmed = warm_optimizer_kernels(8, n_candidates=16, q=2, workload=wl,
                                    n_designs_max=16, max_strategies=24)
    assert warmed >= 1
    # memoized: a second warm compiles nothing new
    assert warm_optimizer_kernels(8, n_candidates=16, q=2, workload=wl,
                                  n_designs_max=16, max_strategies=24) == 0
    # force= re-warms through the memo
    assert warm_optimizer_kernels(8, n_candidates=16, q=2, workload=wl,
                                  n_designs_max=16, max_strategies=24,
                                  force=True) > 0
    prog = eval_compiled._program_for(wl, 24)
    before = prog._jit._cache_size()
    for n in (3, 5, 8, 11, 16):            # buckets 4/8/8/16/16 — all warm
        designs, geom, nw = _designs(n, n)
        eval_compiled.evaluate_batch_compiled(geom, wl, nw)
    assert prog._jit._cache_size() == before, "retrace inside warmed bucket"


def test_fused_dispatch_matches_batch_path():
    """dispatch_fused_eval (device-resident gather of pool rows) returns
    the same EvalResults as evaluating the gathered designs directly."""
    import jax.numpy as jnp

    wl = GPT_BENCHMARKS[0]
    designs, geom, nw = _designs(5, 12)
    js = np.array([7, 2, 9, 2], np.int64)
    pend = eval_compiled.dispatch_fused_eval(geom, wl, nw,
                                             jnp.asarray(js), 24)
    fused = pend.finish(nw[js], q=4)
    direct = eval_compiled.evaluate_batch_compiled(
        DesignBatch.from_designs([designs[j] for j in js]), wl, nw[js], 24)
    assert len(fused) == 4
    for f, d in zip(fused, direct):
        assert _result_fingerprint(f) == _result_fingerprint(d)


def test_campaign_trace_identity_vs_pr7_baseline():
    """Fixed-seed fig8 campaigns through the fused compiled loop replay
    the PR 7 (NumPy, unfused) trace hex-for-hex: same proposals, same
    objective values, same hypervolume curve, same calibration metric."""
    import jax

    from benchmarks.fig8_explorer import method_specs
    from repro.core.evaluator import clear_eval_cache
    from repro.core.noc_gnn import init_gnn
    from repro.explore import Campaign

    with open(os.path.join(DATA, "fig8_trace_pr7_baseline.json")) as f:
        base = json.load(f)
    s = base["settings"]
    assert eval_compiled.enabled(), "compiled path must be on for this test"
    params = init_gnn(jax.random.PRNGKey(base["gnn_init_seed"]))
    specs = method_specs(base["workload"], base["seed"], N0=s["N0"],
                         N1=s["N1"], cand=s["cand"], q=s["q"],
                         quick=s["quick"])
    for m, spec in specs.items():
        clear_eval_cache()
        r = Campaign(spec, gnn_params=params).run()
        tr = r.trace
        exp = base["methods"][m]
        assert tr.n_evals == exp["n_evals"], m
        got_ys = [[_hex(a), _hex(b)] for a, b in tr.ys]
        assert got_ys == exp["ys_hex"], f"{m}: objective values drifted"
        assert [_hex(h) for h in tr.hv] == exp["hv_hex"], m
        assert [[_hex(v) for v in x] for x in tr.xs] == exp["xs_hex"], m
        got_tau = [_hex(c["val_kendall_tau"]) for c in r.calibration]
        assert got_tau == exp["calibration_val_kendall_tau"], m


def test_host_lane_sharding_identical_results():
    """With --xla_force_host_platform_device_count=2 the batch path runs
    pmap-sharded across 2 XLA host lanes — and must produce byte-identical
    results. Needs a subprocess: lane count is fixed at jax init."""
    designs, geom, nw = _designs(13, 8)
    be = AnalyticalBackend()
    wl = GPT_BENCHMARKS[0]
    ref = be.evaluate_batch_ref(geom, wl, nw, max_strategies=24)
    ref_fp = [_result_fingerprint(r) for r in ref]

    child = """
import json, sys
import numpy as np
from repro.core import eval_compiled
from tests.test_eval_compiled import _designs, _result_fingerprint
from repro.core.workload import GPT_BENCHMARKS
import jax
assert jax.local_device_count() == 2, jax.local_device_count()
designs, geom, nw = _designs(13, 8)
got = eval_compiled.evaluate_batch_compiled(geom, GPT_BENCHMARKS[0], nw, 24)
print(json.dumps({"fp": [_result_fingerprint(g) for g in got],
                  "lanes": eval_compiled.lane_stats()}))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2").strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        [root, os.path.join(root, "src")]
        + [p for p in env.get("PYTHONPATH", "").split(os.pathsep) if p])
    env["REPRO_COMPILED_EVAL"] = "1"
    out = subprocess.run([sys.executable, "-c", child], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    assert payload["fp"] == ref_fp, "sharded results drifted from oracle"
    assert payload["lanes"]["n_lanes"] == 2
    assert payload["lanes"]["sharded_calls"] >= 1
    assert payload["lanes"]["rows_sharded"] >= 8


def test_eval_cache_set_many():
    """Batch cache writes: one `set_many` call lands every entry, bumps
    the batched-write counters, and — for the disk backend — appends one
    segment record run that a fresh process replays."""
    from repro.core.evalcache import DiskSegmentEvalCache, InMemoryEvalCache

    from repro.core.evalcache import attribute_cache_traffic

    mem = InMemoryEvalCache()
    with attribute_cache_traffic() as traffic:
        n = mem.set_many([(f"k{i}", i * i) for i in range(5)])
    assert n == 5
    assert traffic["entries_added"] == 5
    st = mem.stats()
    assert st["set_many_calls"] == 1
    assert st["set_many_entries"] == 5
    assert mem.get("k3") == 9 and st["entries"] == 5

    with tempfile.TemporaryDirectory() as d:
        disk = DiskSegmentEvalCache(d)
        disk.set_many([(f"k{i}", {"v": i}) for i in range(4)])
        disk.put("extra", {"v": 99})
        st = disk.stats()
        assert st["set_many_calls"] == 1 and st["set_many_entries"] == 4
        disk.close()
        fresh = DiskSegmentEvalCache(d)      # replays the segment files
        assert fresh.get("k2") == {"v": 2}
        assert fresh.get("extra") == {"v": 99}
        fresh.close()
