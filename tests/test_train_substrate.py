"""Optimizer, data pipeline, checkpointing, fault tolerance, compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.dist.collectives import compress_grads, dequantize_int8, quantize_int8
from repro.dist.fault import StragglerPolicy, TrainSupervisor
from repro.models import model as M
from repro.models.runtime import CPU_TEST as RT
from repro.train import checkpoint as ckpt
from repro.train.data import MarkovLMDataset
from repro.train.optimizer import (
    AdamWConfig,
    adamw_update,
    clip_by_global_norm,
    init_opt_state,
    lr_schedule,
)
from repro.train.train_step import make_train_step


# --------------------------- optimizer ------------------------------------


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = AdamWConfig(peak_lr=0.2, warmup_steps=0, total_steps=200,
                      weight_decay=0.0, clip_norm=10.0)
    state = init_opt_state(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, opt)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_lr_schedule_shape():
    c = AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                    min_lr_ratio=0.1)
    f = lr_schedule(c)
    assert float(f(jnp.int32(0))) == 0.0
    assert abs(float(f(jnp.int32(10))) - 1.0) < 1e-6
    assert float(f(jnp.int32(100))) <= 0.11
    assert float(f(jnp.int32(5))) == pytest.approx(0.5)


def test_clip_by_global_norm():
    tree = {"a": jnp.ones(4) * 3.0, "b": jnp.ones(9) * 4.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    from repro.train.optimizer import global_norm
    assert float(norm) == pytest.approx(np.sqrt(36 + 144), rel=1e-5)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_microbatch_accumulation_matches_full_batch():
    cfg = reduced_config("qwen2-0.5b")
    rng = jax.random.PRNGKey(0)
    params = M.init_params(rng, cfg)
    opt = AdamWConfig(peak_lr=1e-3, clip_norm=1e9, weight_decay=0.0)
    batch = {"tokens": jax.random.randint(rng, (4, 16), 0, cfg.vocab),
             "labels": jax.random.randint(rng, (4, 16), 0, cfg.vocab)}
    p1, _, m1 = make_train_step(cfg, RT, opt, microbatches=1)(
        params, init_opt_state(params), batch)
    p2, _, m2 = make_train_step(cfg, RT, opt, microbatches=2)(
        params, init_opt_state(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


# --------------------------- data -----------------------------------------


def test_data_deterministic_and_host_disjoint():
    ds0 = MarkovLMDataset(vocab=64, seq_len=16, batch=4, seed=7)
    ds0b = MarkovLMDataset(vocab=64, seq_len=16, batch=4, seed=7)
    np.testing.assert_array_equal(ds0.batch_at(3)["tokens"],
                                  ds0b.batch_at(3)["tokens"])
    h0 = MarkovLMDataset(vocab=64, seq_len=16, batch=4, seed=7,
                         host_id=0, num_hosts=2)
    h1 = MarkovLMDataset(vocab=64, seq_len=16, batch=4, seed=7,
                         host_id=1, num_hosts=2)
    assert not np.array_equal(h0.batch_at(3)["tokens"],
                              h1.batch_at(3)["tokens"])
    # labels are next-token shifted
    b = ds0.batch_at(0)
    assert b["tokens"].shape == b["labels"].shape == (4, 16)
    assert 0.0 < ds0.conditional_entropy() < np.log(64)


# --------------------------- checkpointing ---------------------------------


def _tiny_state():
    params = {"layer": {"w": np.arange(6, dtype=np.float32).reshape(2, 3)},
              "b": np.ones(3, np.float32)}
    opt = {"step": np.int32(5), "m": {"layer": {"w": np.zeros((2, 3))},
                                      "b": np.zeros(3)},
           "v": {"layer": {"w": np.zeros((2, 3))}, "b": np.zeros(3)}}
    return params, opt


def test_checkpoint_roundtrip(tmp_path):
    params, opt = _tiny_state()
    ckpt.save_checkpoint(str(tmp_path), 10, params, opt)
    restored = ckpt.restore_latest(str(tmp_path))
    assert restored is not None
    p2, o2, meta = restored
    assert meta["step"] == 10
    np.testing.assert_array_equal(p2["layer"]["w"], params["layer"]["w"])
    assert int(o2["step"]) == 5


def test_checkpoint_corruption_quarantine(tmp_path):
    params, opt = _tiny_state()
    ckpt.save_checkpoint(str(tmp_path), 1, params, opt)
    ckpt.save_checkpoint(str(tmp_path), 2, params, opt)
    # corrupt the newest checkpoint
    with open(os.path.join(str(tmp_path), "step_2", "params.npz"), "wb") as f:
        f.write(b"garbage")
    p2, o2, meta = ckpt.restore_latest(str(tmp_path))
    assert meta["step"] == 1                       # fell back
    assert os.path.isdir(os.path.join(str(tmp_path), "step_2.corrupt"))


def test_supervisor_restart_after_failures(tmp_path):
    """Crash mid-training twice; supervisor must resume from checkpoints and
    finish with a contiguous metric log."""
    cfg = reduced_config("smollm-135m")
    rng = jax.random.PRNGKey(0)
    ds = MarkovLMDataset(vocab=cfg.vocab, seq_len=16, batch=4, seed=2)
    opt = AdamWConfig(peak_lr=1e-3)
    step_fn = jax.jit(make_train_step(cfg, RT, opt))

    def init_fn():
        return M.init_params(rng, cfg), init_opt_state(M.init_params(rng, cfg))

    def batches(step):
        return {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}

    fail_at = {7, 13}

    def injector(step):
        if step in fail_at:
            fail_at.discard(step)
            return True
        return False

    sup = TrainSupervisor(ckpt_dir=str(tmp_path), ckpt_every=5)
    out = sup.run(init_fn, step_fn, batches, total_steps=16,
                  failure_injector=injector)
    assert out["restarts"] == 2
    steps_seen = [m["step"] for m in out["metrics"]]
    assert steps_seen[-1] == 15
    assert ckpt.list_checkpoints(str(tmp_path))[-1] == 16


def test_straggler_policy():
    p = StragglerPolicy(tolerance=2.0)
    for _ in range(10):
        p.observe(1.0)
    assert p.observe(5.0) is True
    assert p.slow_steps == 1
    assert p.observe(1.1) is False


# --------------------------- compression -----------------------------------


def test_int8_quantization_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(size=512) * 3)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x)).max()
    assert err <= float(s) / 2 + 1e-6


def test_compression_error_feedback_unbiased():
    """With error feedback, the SUM of compressed grads over steps tracks
    the sum of true grads (bias does not accumulate)."""
    rng = np.random.default_rng(1)
    g_true = {"w": jnp.asarray(rng.normal(size=256).astype(np.float32))}
    err = None
    acc = np.zeros(256)
    for step in range(20):
        g = {"w": g_true["w"] * (1 + 0.01 * step)}
        cg, err = compress_grads(g, err)
        acc += np.asarray(cg["w"])
    true_acc = np.asarray(sum(
        np.asarray(g_true["w"]) * (1 + 0.01 * s) for s in range(20)))
    rel = np.abs(acc - true_acc).max() / np.abs(true_acc).max()
    assert rel < 0.02
