"""Config integrity: every assigned arch loads, matches its advertised
geometry, and its parameter count lands near the advertised size."""
import pytest

from repro.configs import ARCH_IDS, get_config, reduced_config

EXPECT = {
    "whisper-small": dict(layers=12, d_model=768, heads=12, kv=12,
                          d_ff=3072, vocab=51865),
    "qwen1.5-32b": dict(layers=64, d_model=5120, heads=40, kv=40,
                        d_ff=27392, vocab=152064),
    "qwen2-0.5b": dict(layers=24, d_model=896, heads=14, kv=2,
                       d_ff=4864, vocab=151936),
    "smollm-135m": dict(layers=30, d_model=576, heads=9, kv=3,
                        d_ff=1536, vocab=49152),
    "gemma3-4b": dict(layers=34, d_model=2560, heads=8, kv=4,
                      d_ff=10240, vocab=262144),
    "mamba2-370m": dict(layers=48, d_model=1024, heads=0, kv=0,
                        d_ff=0, vocab=50280),
    "mixtral-8x7b": dict(layers=32, d_model=4096, heads=32, kv=8,
                         d_ff=14336, vocab=32000),
    "grok-1-314b": dict(layers=64, d_model=6144, heads=48, kv=8,
                        d_ff=32768, vocab=131072),
    "zamba2-1.2b": dict(layers=38, d_model=2048, heads=32, kv=32,
                        d_ff=8192, vocab=32000),
    "paligemma-3b": dict(layers=18, d_model=2048, heads=8, kv=1,
                         d_ff=16384, vocab=257216),
}

# advertised sizes (params); tolerance is generous because frontends are
# stubs and architectural details (biases/norms) differ slightly
SIZES = {
    "whisper-small": (0.244e9, 0.25),
    "qwen1.5-32b": (32.5e9, 0.25),
    "qwen2-0.5b": (0.5e9, 0.4),
    "smollm-135m": (0.135e9, 0.25),
    "gemma3-4b": (4.3e9, 0.4),
    "mamba2-370m": (0.37e9, 0.3),
    "mixtral-8x7b": (46.7e9, 0.25),
    "grok-1-314b": (314e9, 0.25),
    "zamba2-1.2b": (1.2e9, 0.45),
    "paligemma-3b": (2.9e9, 0.4),     # text tower only (vision is a stub)
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_config_geometry(arch):
    cfg = get_config(arch)
    e = EXPECT[arch]
    assert cfg.num_layers == e["layers"]
    assert cfg.d_model == e["d_model"]
    assert cfg.n_heads == e["heads"]
    assert cfg.n_kv == e["kv"]
    assert cfg.d_ff == e["d_ff"]
    assert cfg.vocab == e["vocab"]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_near_advertised(arch):
    cfg = get_config(arch)
    target, tol = SIZES[arch]
    n = cfg.param_count()
    assert abs(n - target) / target < tol, (arch, n, target)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_same_family(arch):
    cfg, red = get_config(arch), reduced_config(arch)
    assert cfg.family == red.family
    assert red.d_model <= 128 and red.num_layers <= 4
    if cfg.moe:
        assert red.moe and red.moe.top_k == cfg.moe.top_k
    if cfg.ssm:
        assert red.ssm is not None
    if cfg.local_global_pattern:
        assert red.local_global_pattern is not None


def test_moe_active_params():
    cfg = get_config("mixtral-8x7b")
    assert cfg.active_param_count() < cfg.param_count()
    # ~12.9B active for mixtral
    assert 9e9 < cfg.active_param_count() < 16e9
