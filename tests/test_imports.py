"""Every module under src/repro must import.

Regression guard for the bug class where tests or launchers reference a
package that was never committed (repro.dist originally shipped that way):
a missing module now fails here instead of crashing collection elsewhere
or lying dormant until launch time.
"""
import importlib
import pathlib

import jax
import pytest

import repro

# Initialize the jax backend *before* importing repro.launch.dryrun: that
# module sets XLA_FLAGS=--xla_force_host_platform_device_count=512 at import
# for standalone use, which must not re-shape this test process's devices.
jax.devices()

_ROOT = pathlib.Path(list(repro.__path__)[0])


def _all_modules():
    mods = []
    for py in sorted(_ROOT.rglob("*.py")):
        rel = py.relative_to(_ROOT.parent)
        parts = list(rel.with_suffix("").parts)
        if parts[-1] == "__init__":
            parts = parts[:-1]
        mods.append(".".join(parts))
    return mods


MODULES = _all_modules()


def test_module_list_nonempty():
    assert len(MODULES) > 50, MODULES  # the repo has ~90 modules


@pytest.mark.parametrize("mod", MODULES)
def test_module_imports(mod):
    importlib.import_module(mod)
