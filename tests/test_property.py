"""Property-based tests (hypothesis) on system invariants.

`hypothesis` is a dev-only dependency (pip install -e .[dev]); when it is
absent the whole module skips at collection instead of crashing tier-1."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.ehvi import ehvi_2d
from repro.core.pareto import hypervolume_2d, pareto_front
from repro.kernels.flash_attention.ref import attention_ref, make_mask
from repro.models.attention import update_cache_layer

SETTINGS = dict(max_examples=25, deadline=None)


# --------------------------- attention masks --------------------------------


@given(sq=st.integers(1, 12), skv=st.integers(1, 16),
       window=st.one_of(st.none(), st.integers(1, 8)))
@settings(**SETTINGS)
def test_mask_causality(sq, skv, window):
    qp = jnp.broadcast_to(jnp.arange(sq), (1, sq))
    kp = jnp.broadcast_to(jnp.arange(skv), (1, skv))
    m = np.asarray(make_mask(qp, kp, causal=True, window=window))[0]
    ii, jj = np.meshgrid(np.arange(sq), np.arange(skv), indexing="ij")
    assert not (m & (jj > ii)).any()                     # no future peeking
    if window is not None:
        assert not (m & (jj <= ii - window)).any()       # window respected


@given(seed=st.integers(0, 10_000))
@settings(**SETTINGS)
def test_attention_rows_are_convex_combinations(seed):
    """Each output is inside the convex hull of V rows: max |out| <= max |V|."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    B, S, H, hd = 1, 8, 2, 4
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    out = np.asarray(attention_ref(q, k, v, pos, pos, causal=True))
    assert np.abs(out).max() <= np.abs(np.asarray(v)).max() + 1e-5


@given(w=st.integers(2, 8), steps=st.integers(1, 20))
@settings(**SETTINGS)
def test_ring_cache_keeps_newest_positions(w, steps):
    """After writing positions 0..steps-1 into a ring of W slots, the cache
    holds exactly the newest min(steps, W) positions."""
    cache = {"k": jnp.zeros((1, w, 1, 2)), "v": jnp.zeros((1, w, 1, 2)),
             "kv_pos": jnp.full((1, w), -1, jnp.int32)}
    for t in range(steps):
        kn = jnp.full((1, 1, 1, 2), float(t))
        cache = update_cache_layer(cache, kn, kn, jnp.int32(t))
    held = set(np.asarray(cache["kv_pos"][0]).tolist()) - {-1}
    expect = set(range(max(0, steps - w), steps))
    assert held == expect
    # slot contents match their recorded position
    for slot, p in enumerate(np.asarray(cache["kv_pos"][0])):
        if p >= 0:
            assert float(cache["k"][0, slot, 0, 0]) == float(p)


# --------------------------- pareto / EHVI ----------------------------------


@given(n=st.integers(1, 20), seed=st.integers(0, 999))
@settings(**SETTINGS)
def test_pareto_front_is_mutually_nondominated(n, seed):
    pts = np.random.default_rng(seed).random((n, 2))
    front = pareto_front(pts)
    for i in range(len(front)):
        for j in range(len(front)):
            if i != j:
                assert not (np.all(front[j] >= front[i])
                            and np.any(front[j] > front[i]))


@given(n=st.integers(1, 15), seed=st.integers(0, 999))
@settings(**SETTINGS)
def test_hypervolume_monotone_under_adding_points(n, seed):
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 2))
    ref = np.array([0.0, 0.0])
    hv1 = hypervolume_2d(pts[:-1], ref) if n > 1 else 0.0
    hv2 = hypervolume_2d(pts, ref)
    assert hv2 >= hv1 - 1e-12
    assert hv2 <= 1.0 + 1e-9                    # points live in unit square


@given(seed=st.integers(0, 999))
@settings(**SETTINGS)
def test_ehvi_nonnegative_and_sigma_monotone_when_dominated(seed):
    rng = np.random.default_rng(seed)
    front = rng.random((4, 2)) + 1.0
    ref = np.array([0.0, 0.0])
    mu = rng.random((1, 2))                      # dominated region
    lo = ehvi_2d(mu, np.array([[0.05, 0.05]]), front, ref)[0]
    hi = ehvi_2d(mu, np.array([[1.0, 1.0]]), front, ref)[0]
    assert lo >= -1e-12 and hi >= -1e-12
    assert hi >= lo - 1e-9   # more uncertainty -> more improvement chance


# --------------------------- batched evaluation -----------------------------


@given(seed=st.integers(0, 10_000),
       wl_kind=st.sampled_from(["train", "prefill", "decode"]))
@settings(max_examples=12, deadline=None)
def test_evaluate_design_batch_matches_scalar(seed, wl_kind):
    """The vectorized (design, strategy) pipeline reproduces the scalar
    graph-based evaluator on random valid designs and workloads."""
    from repro.core.design_space import decode
    from repro.core.evaluator import (clear_eval_cache, evaluate_design,
                                      evaluate_design_batch)
    from repro.core.validator import validate
    from repro.core.workload import GPT_BENCHMARKS, inference_workload
    from hypothesis import assume

    rng = np.random.default_rng(seed)
    r = validate(decode(rng.random(13)))
    assume(r.ok)
    d = r.design
    wl = GPT_BENCHMARKS[0]
    if wl_kind != "train":
        wl = inference_workload(wl, wl_kind, batch=64)
    clear_eval_cache()
    a = evaluate_design(d, wl, max_strategies=12)
    clear_eval_cache()
    b = evaluate_design_batch([d], wl, max_strategies=12)[0]
    assert a.feasible == b.feasible
    assert a.n_wafers == b.n_wafers
    if a.feasible:
        assert a.strategy == b.strategy
        assert np.isclose(a.throughput, b.throughput, rtol=1e-6)
        assert np.isclose(a.power_w, b.power_w, rtol=1e-6)
        assert np.isclose(a.step.step_time_s, b.step.step_time_s, rtol=1e-6)


@given(seed=st.integers(0, 10_000),
       fidelity=st.sampled_from(["gnn", "sim"]))
@settings(max_examples=8, deadline=None)
def test_graph_fidelity_batch_matches_scalar(seed, fidelity):
    """The pattern-space batched gnn/sim backends reproduce the scalar
    graph-walking evaluator on random valid designs — same winning strategy,
    objectives equal to float tolerance."""
    from repro.core.design_space import decode
    from repro.core.evaluator import (clear_eval_cache, evaluate_design,
                                      evaluate_design_batch)
    from repro.core.noc_gnn import init_gnn
    from repro.core.validator import validate
    from repro.core.workload import GPT_BENCHMARKS
    from hypothesis import assume

    rng = np.random.default_rng(seed)
    r = validate(decode(rng.random(13)))
    assume(r.ok)
    d = r.design
    wl = GPT_BENCHMARKS[0]
    params = init_gnn(jax.random.PRNGKey(0)) if fidelity == "gnn" else None
    clear_eval_cache()
    a = evaluate_design(d, wl, fidelity=fidelity, gnn_params=params,
                        max_strategies=4)
    clear_eval_cache()
    b = evaluate_design_batch([d], wl, fidelity=fidelity, gnn_params=params,
                              max_strategies=4)[0]
    assert a.feasible == b.feasible
    assert a.n_wafers == b.n_wafers
    if a.feasible:
        assert a.strategy == b.strategy
        assert np.isclose(a.throughput, b.throughput, rtol=1e-5)
        assert np.isclose(a.power_w, b.power_w, rtol=1e-5)


@given(seed=st.integers(0, 10_000), w=st.integers(2, 6),
       n=st.integers(1, 24))
@settings(**SETTINGS)
def test_simulate_batch_matches_scalar_bitwise(seed, w, n):
    """The lockstep multi-lane simulator is bit-identical to the scalar
    event-ordered simulator on random packet sets (small grids)."""
    from repro.core.noc_sim import Packet, simulate, simulate_many

    rng = np.random.default_rng(seed)
    pkts = [Packet(src=int(rng.integers(0, w * w)),
                   dst=int(rng.integers(0, w * w)),
                   flits=int(rng.integers(1, 12)),
                   inject=float(rng.integers(0, 6)))
            for _ in range(n)]
    ref = simulate(pkts, w)
    got = simulate_many([pkts], [w])[0]
    assert got.makespan == ref.makespan
    assert got.avg_latency == ref.avg_latency
    assert got.link_wait == ref.link_wait
    assert got.link_util == ref.link_util


@given(seed=st.integers(0, 10_000), n_graphs=st.integers(1, 4))
@settings(max_examples=10, deadline=None)
def test_gnn_forward_batch_matches_scalar_forward(seed, n_graphs):
    """Padded vmapped forward == per-graph forward on heterogeneous graphs
    (masked segment sums make the padding inert)."""
    from repro.core.noc_gnn import (gnn_forward, gnn_forward_batch, init_gnn,
                                    pad_link_graphs)
    from repro.core.compiler import compile_chunk
    from repro.core.design_space import decode
    from repro.core.noc_gnn import featurize_transfer
    from repro.core.validator import validate
    from repro.core.workload import GPT_BENCHMARKS
    from hypothesis import assume

    rng = np.random.default_rng(seed)
    r = validate(decode(rng.random(13)))
    assume(r.ok)
    d = r.design
    wl = GPT_BENCHMARKS[0]
    graphs = []
    for cores in rng.choice([4, 8, 16, 32, 64], size=n_graphs):
        g = compile_chunk(d, wl, tp=16, mb_tokens=1024,
                          cores_per_chunk=int(cores))
        for t in range(len(g.transfers)):
            if g.transfers[t].pairs:
                graphs.append(featurize_transfer(g, d, t))
                break
    assume(graphs)
    params = init_gnn(jax.random.PRNGKey(1))
    batch = pad_link_graphs(graphs)
    out = gnn_forward_batch(params, batch)
    for i, g in enumerate(graphs):
        ref = np.asarray(gnn_forward(
            jax.tree.map(jnp.asarray, params), jnp.asarray(g.node_x),
            jnp.asarray(g.edge_x), jnp.asarray(g.senders),
            jnp.asarray(g.receivers), int(g.n_nodes)))
        np.testing.assert_allclose(out[i, :len(g.links)], ref,
                                   rtol=1e-5, atol=1e-5)


@given(seed=st.integers(0, 10_000))
@settings(**SETTINGS)
def test_qehvi_q1_matches_scalar_ehvi_argmax(seed):
    """Greedy q-EHVI with q=1 is exactly the scalar EHVI acquisition."""
    from repro.core.mfmobo import (_acquire_batch, _fit_models, _hv_ref,
                                   _obj_space)

    rng = np.random.default_rng(seed)
    X = rng.random((12, 5))
    Y = np.stack([1e5 * (1 + X[:, 1] + 0.3 * rng.random(12)),
                  5e3 * (0.5 + X[:, 3])], 1)
    models = _fit_models(X, Y)
    ev = _obj_space([tuple(r) for r in Y])
    ref = _hv_ref(15000.0)
    cand = rng.random((32, 5))
    # scalar reference: argmax of the plain EHVI scores
    from repro.core.pareto import pareto_front
    g_t, g_p = models
    mu = np.stack([g_t.predict(cand)[0], g_p.predict(cand)[0]], 1)
    sg = np.stack([g_t.predict(cand)[1], g_p.predict(cand)[1]], 1)
    scores = ehvi_2d(mu, sg, pareto_front(ev), ref)
    j_ref = int(np.argmax(scores))
    js = _acquire_batch(models, cand, ev, ref, q=1)
    assert js == [j_ref]
    # q>1 extends (not replaces) the q=1 choice with distinct indices
    js4 = _acquire_batch(models, cand, ev, ref, q=4)
    assert js4[0] == j_ref and len(set(js4)) == 4


# --------------------------- optimizer --------------------------------------


@given(seed=st.integers(0, 999), clip=st.floats(0.1, 5.0))
@settings(**SETTINGS)
def test_clip_never_increases_norm(seed, clip):
    from repro.train.optimizer import clip_by_global_norm, global_norm
    rng = np.random.default_rng(seed)
    tree = {"a": jnp.asarray(rng.normal(size=7)),
            "b": {"c": jnp.asarray(rng.normal(size=(3, 2)))}}
    clipped, norm = clip_by_global_norm(tree, clip)
    assert float(global_norm(clipped)) <= max(clip, float(norm)) + 1e-5
