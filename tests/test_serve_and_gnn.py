"""Serving engine behaviour + NoC-GNN learning sanity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.models import model as M
from repro.models.runtime import CPU_TEST as RT
from repro.serve.engine import Request, ServeEngine
from repro.serve.serve_step import sample_logits


def test_engine_matches_manual_greedy_decode():
    """Engine output for a single request == hand-rolled prefill+decode."""
    cfg = reduced_config("qwen2-0.5b")
    rng = jax.random.PRNGKey(0)
    params = M.init_params(rng, cfg)
    prompt = np.arange(6, dtype=np.int32) % cfg.vocab
    n_new = 5

    # manual greedy
    cache = M.init_cache(cfg, RT, 1, 64)
    logits, cache = M.prefill(params, cfg, RT,
                              {"tokens": jnp.asarray(prompt)[None]}, cache)
    toks = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(n_new - 1):
        logits, cache = M.decode_step(
            params, cfg, RT, jnp.asarray([[toks[-1]]], jnp.int32),
            jnp.int32(pos), cache)
        toks.append(int(jnp.argmax(logits[0])))
        pos += 1

    eng = ServeEngine(cfg, RT, params, slots=2, max_len=64)
    outs = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=n_new)])
    assert outs[0] == toks


def test_engine_continuous_batching_isolation():
    """Two concurrent requests give the same outputs as served alone."""
    cfg = reduced_config("smollm-135m")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    p1 = np.arange(5, dtype=np.int32) % cfg.vocab
    p2 = (np.arange(9, dtype=np.int32) * 3) % cfg.vocab

    solo1 = ServeEngine(cfg, RT, params, slots=2, max_len=64).run(
        [Request(0, p1, 4)])[0]
    solo2 = ServeEngine(cfg, RT, params, slots=2, max_len=64).run(
        [Request(0, p2, 4)])[0]
    both = ServeEngine(cfg, RT, params, slots=2, max_len=64).run(
        [Request(0, p1, 4), Request(1, p2, 4)])
    assert both[0] == solo1
    assert both[1] == solo2


def test_sampling_greedy_vs_temperature():
    logits = jnp.asarray([[0.0, 5.0, 1.0]])
    assert int(sample_logits(logits, jax.random.PRNGKey(0), 0.0)[0]) == 1
    # temperature draws vary but stay in-range
    draws = {int(sample_logits(logits, jax.random.PRNGKey(s), 2.0)[0])
             for s in range(20)}
    assert draws.issubset({0, 1, 2}) and len(draws) > 1


def test_gnn_learns_waiting_times():
    """Training reduces loss and beats an untrained model on held-out data."""
    from repro.core.compiler import compile_chunk
    from repro.core.noc_gnn import (
        featurize_transfer,
        gnn_forward,
        init_gnn,
        train_gnn,
    )
    from repro.core.validator import validate
    from repro.core.design_space import WSCDesign
    from repro.core.workload import GPT_BENCHMARKS

    d = validate(WSCDesign()).design
    wl = GPT_BENCHMARKS[0]
    data = []
    for tp, mbt in ((16, 4096), (64, 1024), (16, 1024)):
        g = compile_chunk(d, wl, tp=tp, mb_tokens=mbt, cores_per_chunk=64)
        for t in range(len(g.transfers)):
            if g.transfers[t].pairs:
                data.append(featurize_transfer(g, d, t, with_target=True))
    train, held = data[:-2], data[-2:]
    p0 = init_gnn(jax.random.PRNGKey(0))
    p1, hist = train_gnn(p0, train, epochs=30)

    def err(params, graphs):
        tot = 0.0
        for g in graphs:
            pred = np.asarray(gnn_forward(
                jax.tree.map(jnp.asarray, params), g.node_x, g.edge_x,
                g.senders, g.receivers, g.n_nodes))
            tot += float(np.mean((np.log1p(pred) - np.log1p(g.target)) ** 2))
        return tot
    assert hist.train_loss[-1] < hist.train_loss[0]
    # must fit the training distribution; held-out should not blow up
    assert err(p1, train) < err(p0, train)
    assert err(p1, held) < err(p0, held) * 1.25
