"""SSD-scan Pallas kernel + chunked oracle vs the sequential recurrence:
shape/dtype/chunk sweep, decode-step consistency, interpret=True on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import (
    ssd_chunked_ref,
    ssd_decode_step_ref,
    ssd_ref,
)

CASES = [
    # (B, S, H, P, N, chunk)
    (1, 32, 2, 8, 8, 8),
    (2, 64, 4, 16, 16, 16),
    (1, 100, 2, 16, 8, 32),      # padding path (100 % 32 != 0)
    (2, 128, 2, 32, 16, 128),    # single chunk
]


def _inputs(case, dtype=jnp.float32, seed=0):
    B, S, H, P, N, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N), dtype)
    Cm = jax.random.normal(ks[4], (B, S, N), dtype)
    D = jnp.linspace(0.2, 1.0, H)
    return x, dt, A, Bm, Cm, D


@pytest.mark.parametrize("case", CASES)
def test_chunked_matches_sequential(case):
    x, dt, A, Bm, Cm, D = _inputs(case)
    y0, h0 = ssd_ref(x, dt, A, Bm, Cm, D)
    y1, h1 = ssd_chunked_ref(x, dt, A, Bm, Cm, D, chunk=case[-1])
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_matches_sequential(case, dtype):
    x, dt, A, Bm, Cm, D = _inputs(case, dtype)
    y0, h0 = ssd_ref(x, dt, A, Bm, Cm, D)
    y2, h2 = ssd_scan(x, dt, A, Bm, Cm, D, chunk=case[-1], interpret=True)
    tol = 3e-4 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(y2, np.float32),
                               np.asarray(y0, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h0),
                               rtol=tol, atol=tol)


def test_decode_step_consistency():
    """Running the recurrence one token at a time reproduces the scan."""
    case = (2, 16, 2, 8, 8, 8)
    x, dt, A, Bm, Cm, D = _inputs(case, seed=3)
    y_full, h_full = ssd_ref(x, dt, A, Bm, Cm, D)
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    h = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        y_t, h = ssd_decode_step_ref(h, x[:, t], dt[:, t], A, Bm[:, t],
                                     Cm[:, t], D)
        ys.append(y_t)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full),
                               rtol=2e-4, atol=2e-4)
