"""CPU smoke test for the production training launch path.

Drives `repro.launch.train` exactly as the CLI would (reduced config,
8 steps, 1-device mesh) with a node failure injected mid-run: the
supervisor must roll back to the last checkpoint, re-run, and finish with
a contiguous metric log and a final checkpoint at `total_steps`.
"""
import numpy as np

from repro.launch import train as launch_train
from repro.train import checkpoint as ckpt


def test_train_launch_resumes_after_injected_failure(tmp_path):
    out = launch_train.main([
        "--arch", "smollm-135m", "--reduced", "--steps", "8",
        "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "3", "--fail-at", "5", "--log-every", "100",
    ])
    assert out["restarts"] == 1
    steps = [m["step"] for m in out["metrics"]]
    assert steps == list(range(8)), "metric log must be contiguous"
    assert np.isfinite([m["loss"] for m in out["metrics"]]).all()
    assert ckpt.list_checkpoints(str(tmp_path))[-1] == 8
