"""Optimized attention paths vs the fp32 oracle (EXPERIMENTS.md §Perf)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.kernels.flash_attention.ref import attention_ref
from repro.models import model as M
from repro.models.attention import _attention_bf16_scores
from repro.models.runtime import CPU_TEST as RT


@pytest.mark.parametrize("window", [None, 16])
def test_bf16_scores_matches_oracle(window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, Hq, Hkv, hd = 2, 64, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, Hq, hd), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.bfloat16)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    a = _attention_bf16_scores(q, k, v, pos, pos, causal=True, window=window)
    b = attention_ref(q, k, v, pos, pos, causal=True, window=window)
    err = np.abs(np.asarray(a, np.float32) - np.asarray(b, np.float32)).max()
    assert err < 3e-2, err


def test_opt_bf16_scores_decode_consistency():
    """End-to-end decode with the bf16-score runtime flag stays close to the
    fp32 path."""
    cfg = reduced_config("qwen2-0.5b")
    rt_opt = dataclasses.replace(RT, opt_bf16_scores=True,
                                 compute_dtype=jnp.bfloat16)
    rt_ref = dataclasses.replace(RT, compute_dtype=jnp.bfloat16)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, 12), 0, cfg.vocab)

    def roll(rt):
        cache = M.init_cache(cfg, rt, 1, 32)
        logits, cache = M.prefill(params, cfg, rt,
                                  {"tokens": tokens[:, :8]}, cache)
        outs = [np.asarray(logits)]
        for t in range(8, 12):
            logits, cache = M.decode_step(params, cfg, rt,
                                          tokens[:, t:t + 1],
                                          jnp.int32(t), cache)
            outs.append(np.asarray(logits))
        return np.stack(outs)

    a, b = roll(rt_opt), roll(rt_ref)
    # same argmax everywhere; logits close
    np.testing.assert_array_equal(a.argmax(-1), b.argmax(-1))
    assert np.abs(a - b).max() < 0.5
