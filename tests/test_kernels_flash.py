"""Flash-attention Pallas kernel vs the pure-jnp oracle: shape/dtype sweep,
causal + sliding-window + GQA, interpret=True on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

CASES = [
    # (B, S, Hq, Hkv, hd, causal, window)
    (1, 64, 4, 4, 16, True, None),
    (2, 128, 4, 2, 32, True, None),          # GQA 2x
    (1, 96, 8, 1, 16, True, None),           # MQA, ragged seq vs blocks
    (2, 128, 4, 4, 64, True, 32),            # sliding window
    (1, 256, 2, 2, 16, False, None),         # bidirectional
    (1, 80, 3, 1, 16, True, 24),             # non-pow2 heads + window
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(case, dtype):
    B, S, Hq, Hkv, hd, causal, window = case
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2 ** 31), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, hd), dtype)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    ref = attention_ref(q, k, v, pos, pos, causal=causal, window=window)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=32, block_k=32, interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_block_size_invariance():
    B, S, H, hd = 1, 128, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    outs = [flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                            interpret=True)
            for bq, bk in ((16, 16), (32, 64), (128, 128))]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   rtol=1e-5, atol=1e-5)


def test_flash_fully_masked_rows_are_finite():
    """Window smaller than block: early tokens attend only to themselves;
    no NaNs from empty softmax rows."""
    B, S, H, hd = 1, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    out = flash_attention(q, k, v, causal=True, window=1,
                          block_q=32, block_k=32, interpret=True)
    assert np.isfinite(np.asarray(out)).all()
