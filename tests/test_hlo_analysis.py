"""Trip-count-aware HLO analyzer on synthetic and real compiled modules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze, parse_hlo

SYNTH = """
HloModule test, num_partitions=4

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups=[2,2]<=[4], to_apply=%add
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]{1,0}) tuple(%z, %a)
  %w = (s32[], f32[8,8]{1,0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_synthetic_trip_counts_and_flops():
    st = analyze(SYNTH)
    # dot flops: 2*8*8*8 = 1024 per trip x 7 trips
    assert st.dot_flops == 7 * 1024
    # all-reduce: group size 2, 256B tensor -> 2*(1/2)*256 = 256 B x 7
    assert st.collective_moved == 7 * 256
    assert st.while_trips == {"body": 7}


def test_real_compiled_module_flops_accuracy():
    """Compile a scanned matmul stack and compare analyzer flops to truth."""
    L, n, d = 5, 32, 16

    def f(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, ws)
        return out.sum()

    ws = jax.ShapeDtypeStruct((L, d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((n, d), jnp.float32)
    txt = jax.jit(f).lower(ws, x).compile().as_text()
    st = analyze(txt)
    true_flops = L * 2 * n * d * d
    assert abs(st.dot_flops - true_flops) / true_flops < 0.05
    assert st.while_trips and list(st.while_trips.values())[0] == L


def test_parse_hlo_finds_entry():
    comps, entry = parse_hlo(SYNTH)
    assert entry == "main"
    assert "body" in comps and "cond" in comps
    assert len(comps["body"].ops) >= 6
