"""Theseus DSE core: yield models, design space, validator, tile eval,
compiler, NoC models, chunk eval."""
import math

import numpy as np
import pytest

from repro.core import components as C
from repro.core.compiler import Strategy, compile_chunk, enumerate_strategies
from repro.core.design_space import WSCDesign, decode, encode, sample
from repro.core.evaluator import evaluate_design, wafers_for_budget
from repro.core.noc_analytical import chunk_latency_cycles
from repro.core.noc_sim import Packet, chunk_latency_cycles_sim, simulate
from repro.core.tile_eval import evaluate_tile
from repro.core.validator import validate
from repro.core.workload import GEMMOp, GPT_BENCHMARKS, from_model_config
from repro.core.yield_model import (
    binomial_redundancy_yield,
    core_yield_grid,
    mc_row_redundancy_yield,
    min_spares_for_target,
    murphy_yield,
)


# --------------------------- yield -----------------------------------------


def test_murphy_monotone_decreasing_in_area():
    ys = [murphy_yield(a) for a in (1, 10, 100, 400)]
    assert all(ys[i] > ys[i + 1] for i in range(len(ys) - 1))
    assert 0.99 < murphy_yield(1.0) <= 1.0


def test_binomial_matches_mc_uniform():
    """Eq. 4 closed form vs Monte Carlo with uniform yields, column spares:
    p=8 operational + 2 spares, reticle OK iff >= 8 good."""
    y = 0.97
    analytic = binomial_redundancy_yield(8, 2, y)
    rng = np.random.default_rng(0)
    good = (rng.random((200000, 10)) < y).sum(axis=1)
    mc = float((good >= 8).mean())
    assert analytic == pytest.approx(mc, abs=5e-3)


def test_stress_holes_hurt_corner_cores():
    ys = core_yield_grid(1.0, 1.0, (8, 8), (8.0, 8.0))
    assert ys[0, 0] < ys[4, 4]           # corner core near screw hole
    assert ys.min() > 0.5


def test_row_redundancy_improves_yield():
    ys = core_yield_grid(2.0, 2.0, (8, 8), (16.0, 16.0), tsv_region_mm2=4.0)
    y0 = mc_row_redundancy_yield(ys, 0)
    y2 = mc_row_redundancy_yield(ys, 2)
    assert y2 > y0


def test_die_stitching_needs_more_redundancy():
    """KGD (InFO) only needs the reticle to yield; stitching needs the whole
    wafer: spares(stitching) >= spares(infosow)."""
    args = (1.5, 1.5, (10, 10), (15.0, 15.0), 2.0, 64)
    s_info, _ = min_spares_for_target(*args, "infosow")
    s_stitch, _ = min_spares_for_target(*args, "die_stitching")
    assert s_info >= 0
    assert s_stitch == -1 or s_stitch >= s_info


# --------------------------- design space ----------------------------------


def test_decode_respects_candidate_ranges():
    rng = np.random.default_rng(0)
    for u in sample(rng, 64):
        d = decode(u)
        assert d.dataflow in ("WS", "IS", "OS")
        assert 8 <= d.mac_num <= 4096 and d.mac_num & (d.mac_num - 1) == 0
        assert 32 <= d.buffer_kb <= 2048
        assert 0.2 <= d.inter_reticle_bw_ratio <= 2.0
        assert 0.25 <= d.dram_bw_tbps_per_100mm2 <= 4.0


def test_encode_decode_roundtrip():
    rng = np.random.default_rng(1)
    for u in sample(rng, 16):
        d = decode(u)
        d2 = decode(encode(d))
        assert d2.mac_num == d.mac_num
        assert d2.dataflow == d.dataflow
        assert d2.core_array == d.core_array
        assert d2.integration == d.integration


def test_validator_reasons():
    huge = WSCDesign(mac_num=4096, buffer_kb=2048, buffer_bw=2048,
                     core_array=(32, 32), reticle_array=(12, 12))
    r = validate(huge)
    assert not r.ok and r.reason in ("reticle_area", "tsv_stress",
                                     "sram_infeasible", "wafer_area")
    ok = validate(WSCDesign())
    assert ok.ok and ok.design.spares_per_row >= 0
    assert ok.wafer_yield >= 0.9


def test_tsv_stress_constraint():
    d = WSCDesign(use_stacked_dram=True, dram_bw_tbps_per_100mm2=4.0)
    ratio = d.tsv_area_mm2() / d.reticle_area_mm2()
    assert ratio <= C.TSV_AREA_RATIO_MAX + 1e-6   # 4 TB/s sits inside 1.5%


# --------------------------- tile eval --------------------------------------


def test_tile_eval_compute_bound_scaling():
    op = GEMMOp("g", 256, 256, 256)
    small = evaluate_tile(op, mac=64, buffer_kb=256, buffer_bw=4096,
                          dataflow="WS")
    big = evaluate_tile(op, mac=1024, buffer_kb=256, buffer_bw=4096,
                        dataflow="WS")
    assert big.cycles < small.cycles          # more MACs -> fewer cycles
    assert small.cycles >= 256 * 256 * 256 / 64 * 0.9


def test_tile_eval_memory_bound():
    op = GEMMOp("g", 4, 4096, 4096)           # GEMV-ish: low intensity
    r = evaluate_tile(op, mac=4096, buffer_kb=64, buffer_bw=64,
                      dataflow="WS")
    compute = math.ceil(4096 / 64) * math.ceil(4096 / 64) * 4
    assert r.cycles > compute                  # SRAM-bandwidth bound


@pytest.mark.parametrize("df", ["WS", "IS", "OS"])
def test_tile_eval_dataflows_all_finite(df):
    r = evaluate_tile(GEMMOp("g", 128, 512, 256), 256, 128, 1024, df)
    assert r.cycles > 0 and 0 < r.util <= 1.0
    assert r.sram_read_bits > 0


# --------------------------- compiler / NoC --------------------------------


def _design():
    return validate(WSCDesign()).design


def test_compile_chunk_transfer_conservation():
    d = _design()
    wl = GPT_BENCHMARKS[0]
    g = compile_chunk(d, wl, tp=16, mb_tokens=2048, cores_per_chunk=64)
    assert g.n_cores == 64
    for t, node in zip(g.transfers, g.ops[:-1]):
        total = t.total_bytes()
        gw = g.array[1]
        expect = node.op.out_bytes() * (gw - 1)    # row all-gather traffic
        assert total == pytest.approx(expect, rel=1e-6)


def test_strategies_respect_resources():
    d = _design()
    wl = GPT_BENCHMARKS[0]
    total = d.total_cores()
    for s in enumerate_strategies(d, wl, n_wafers=1):
        assert s.chunks() * s.tp <= total
        assert wl.batch % (s.dp * s.microbatches) == 0


def test_noc_sim_congestion_increases_wait():
    light = [Packet(0, 7, 4, i * 50.0) for i in range(4)]
    heavy = [Packet(0, 7, 64, 0.0) for _ in range(16)]
    r_light = simulate(light, W=8)
    r_heavy = simulate(heavy, W=8)
    wait_l = sum(r_light.link_wait.values())
    wait_h = sum(r_heavy.link_wait.values())
    assert wait_h > wait_l
    assert r_heavy.makespan >= 16 * 64       # serialization on first link


def test_analytical_within_factor_of_sim():
    d = _design()
    wl = GPT_BENCHMARKS[0]
    g = compile_chunk(d, wl, tp=16, mb_tokens=2048, cores_per_chunk=64)
    ana = chunk_latency_cycles(g, d)
    sim = chunk_latency_cycles_sim(g, d)
    assert 0.2 < ana / sim < 5.0


# --------------------------- evaluator --------------------------------------


def test_evaluate_design_feasible_and_scales():
    d = _design()
    wl = GPT_BENCHMARKS[0]
    r1 = evaluate_design(d, wl, n_wafers=1, max_strategies=8)
    r4 = evaluate_design(d, wl, n_wafers=4, max_strategies=8)
    assert r1.feasible and r4.feasible
    assert r4.throughput > r1.throughput        # more silicon helps
    assert r1.power_w > 0


def test_injection_rates_zero_cycle_guard():
    d = _design()
    wl = GPT_BENCHMARKS[0]
    g = compile_chunk(d, wl, tp=16, mb_tokens=2048, cores_per_chunk=64)
    r = g.injection_rates(d.noc_bw)
    assert r.shape == (g.n_cores,) and np.isfinite(r).all()
    # zero-runtime chunk: no cycles to average over -> zero injection
    import dataclasses as _dc
    empty = _dc.replace(g, ops=[])
    assert (empty.injection_rates(d.noc_bw) == 0).all()


# --------------------------- batched backend --------------------------------


def test_decode_encode_batch_match_scalar():
    from repro.core.design_space import decode_batch, encode_batch

    rng = np.random.default_rng(5)
    U = sample(rng, 32)
    ds = decode_batch(U)
    assert ds == [decode(u) for u in U]
    E = encode_batch(ds)
    for i, d in enumerate(ds):
        assert np.allclose(E[i], encode(d), atol=1e-12)


def test_design_batch_geometry_matches_scalar_methods():
    from repro.core.design_space import DesignBatch

    rng = np.random.default_rng(6)
    ds = [r.design for r in (validate(decode(u)) for u in sample(rng, 48))
          if r.ok]
    g = DesignBatch.from_designs(ds)
    for i, d in enumerate(ds):
        assert g.total_cores[i] == d.total_cores()
        assert np.isclose(g.core_area_mm2[i], d.core_area_mm2(), rtol=1e-12)
        assert np.isclose(g.reticle_area_mm2[i], d.reticle_area_mm2(),
                          rtol=1e-12)
        assert np.isclose(g.wafer_area_mm2[i], d.wafer_area_mm2(), rtol=1e-12)
        assert np.isclose(g.inter_reticle_bw_Bps[i], d.inter_reticle_bw_Bps())
        assert np.isclose(g.static_power_w[i], d.static_power_w(), rtol=1e-12)
        assert np.isclose(g.dram_gb_per_reticle[i], d.dram_gb_per_reticle())
    sub = g.take(np.array([1, 1, 0]))
    assert sub.designs == [ds[1], ds[1], ds[0]]
    assert (sub.total_cores == g.total_cores[[1, 1, 0]]).all()


def test_tile_batch_matches_scalar():
    from repro.core.tile_eval import DATAFLOW_CODE, evaluate_tile_batch

    rng = np.random.default_rng(7)
    Ms, Ks, Ns = (rng.integers(1, 3000, 64) for _ in range(3))
    macs = 2 ** rng.integers(3, 13, 64)
    bkb = 2 ** rng.integers(5, 12, 64)
    bbw = 2 ** rng.integers(5, 13, 64)
    codes = rng.integers(0, 3, 64)
    inv = {v: k for k, v in DATAFLOW_CODE.items()}
    out = evaluate_tile_batch(Ms, Ks, Ns, macs, bkb.astype(float), bbw, codes)
    for i in range(64):
        r = evaluate_tile(GEMMOp("g", int(Ms[i]), int(Ks[i]), int(Ns[i])),
                          int(macs[i]), int(bkb[i]), int(bbw[i]),
                          inv[int(codes[i])])
        assert np.isclose(out["cycles"][i], r.cycles, rtol=1e-12)
        assert np.isclose(out["sram_read_bits"][i], r.sram_read_bits,
                          rtol=1e-12)
        assert np.isclose(out["out_interval_cycles"][i],
                          r.out_interval_cycles, rtol=1e-12)


def test_feasible_strategy_arrays_match_scalar_enumeration():
    from repro.core.compiler import feasible_strategy_arrays, strategy_sort_key

    d = _design()
    for wl in (GPT_BENCHMARKS[0], GPT_BENCHMARKS[2]):
        for nw in (1, 4):
            # memory_model="grid": feasible_strategy_arrays bakes the frozen
            # legacy memory check (the grid-mode replay contract); the v2
            # recompute-aware default is exercised by tests/test_joint_dse.py
            ref = sorted(enumerate_strategies(d, wl, n_wafers=nw,
                                              memory_model="grid"),
                         key=strategy_sort_key)[:24]
            total = d.total_cores() * nw
            budget = (d.buffer_kb * 1024.0 * total
                      + d.dram_gb_per_reticle() * 1e9 * d.n_reticles() * nw)
            arr = feasible_strategy_arrays(wl, total, budget, 24)
            got = [Strategy(int(a), int(b), int(c), int(m))
                   for a, b, c, m in arr]
            assert got == ref


def test_evaluate_design_batch_matches_scalar_and_is_cached():
    from repro.core.evaluator import (clear_eval_cache, eval_cache_stats,
                                      evaluate_design_batch)

    rng = np.random.default_rng(8)
    ds = [r.design for r in (validate(decode(u)) for u in sample(rng, 24))
          if r.ok][:8]
    wl = GPT_BENCHMARKS[0]
    clear_eval_cache()
    batch = evaluate_design_batch(ds, wl, max_strategies=12)
    clear_eval_cache()
    for d, b in zip(ds, batch):
        a = evaluate_design(d, wl, max_strategies=12)
        assert a.feasible == b.feasible
        if a.feasible:
            assert a.strategy == b.strategy
            assert np.isclose(a.throughput, b.throughput, rtol=1e-6)
            assert np.isclose(a.power_w, b.power_w, rtol=1e-6)
    # cross-call cache: scalar results above now serve the batch entrypoint
    before = eval_cache_stats()["hits"]
    again = evaluate_design_batch(ds, wl, max_strategies=12)
    assert eval_cache_stats()["hits"] == before + len(ds)
    assert [r.throughput for r in again] == [r.throughput for r in batch]


def test_chunk_latency_closed_form_matches_graph():
    from repro.core.compiler import grid_for_batch
    from repro.core.noc_analytical import chunk_latency_cycles_closed

    d = _design()
    wl = GPT_BENCHMARKS[0]
    for tp, mbt, cpc in ((16, 2048, 64), (4, 512, 17), (1, 128, 1)):
        g = compile_chunk(d, wl, tp=tp, mb_tokens=mbt, cores_per_chunk=cpc)
        ref = chunk_latency_cycles(g, d)
        tiles = np.array([[o.tile.cycles] for o in g.ops])
        outb = np.array([[o.op.out_bytes()] for o in g.ops])
        gh, gw = grid_for_batch(np.asarray([min(cpc, 64)]))
        got = chunk_latency_cycles_closed(tiles, outb, gh, gw,
                                          np.asarray([d.noc_bw]))[0]
        assert np.isclose(ref, got, rtol=1e-12)


def test_workload_bridge_from_model_config():
    from repro.configs import get_config, get_shape
    cfg = get_config("mixtral-8x7b")
    wl = from_model_config(cfg, get_shape("train_4k"))
    assert wl.moe_experts == 8 and wl.moe_topk == 2
    assert wl.seq == 4096 and wl.phase == "train"
    assert wl.tokens_per_step() == 256 * 4096
