"""Fleet-scale campaign execution (DESIGN.md §11): pluggable eval-cache
backends (LRU bounds, on-disk segment sharing, traffic attribution),
checkpoint retention + corrupt-head fallback, per-process kernel-warm
memoization, async proposal-mode determinism and mid-flight resume, and
the multiprocess fleet scheduler (shared persistent cache, crash-requeue
+ checkpoint-resume)."""
import dataclasses
import glob
import os
import pickle
import threading

import numpy as np
import pytest

from repro.core.evalcache import (
    DiskSegmentEvalCache,
    InMemoryEvalCache,
    attribute_cache_traffic,
)
from repro.core import evaluator
from repro.explore import (
    Campaign,
    CampaignSpec,
    ExplorationLoop,
    FidelitySchedule,
    FleetSpec,
    LoopConfig,
    expand_grid,
    run_fleet,
)
from repro.explore.fleet import _CRASH_ENV


def quick_spec(**over) -> CampaignSpec:
    kw = dict(
        name="fleet-quick", workload="GPT-1.7B", scenario="train",
        strategy="mfmobo",
        fidelity=FidelitySchedule(f1="analytical", f0="analytical",
                                  d1=2, d0=2, k=2),
        n_evals_f0=5, n_evals_f1=6, q=2, n_candidates=16,
        max_strategies=6, seed=7)
    kw.update(over)
    return CampaignSpec(**kw)


# --------------------------- eval-cache backends ----------------------------


def test_inmemory_lru_eviction_and_stats():
    c = InMemoryEvalCache(max_entries=3)
    for i in range(3):
        c.put(("k", i), i)
    assert c.get(("k", 0)) == 0              # refreshes k0's recency
    c.put(("k", 3), 3)                       # evicts k1 (LRU), not k0
    assert c.get(("k", 1)) is None
    assert c.get(("k", 0)) == 0 and c.get(("k", 3)) == 3
    s = c.stats()
    assert s["entries"] == 3 and s["evictions"] == 1
    assert s["hits"] == 3 and s["misses"] == 1
    assert s["max_entries"] == 3
    with pytest.raises(ValueError):
        InMemoryEvalCache(max_entries=0)


def test_disk_segment_cache_shares_across_instances(tmp_path):
    d = str(tmp_path / "cache")
    a = DiskSegmentEvalCache(d)
    b = DiskSegmentEvalCache(d)              # a second "process"
    a.put(("design", 1, "f0"), (10.0, 20.0))
    # b misses in memory, merges a's segment on the miss path, then hits
    assert b.get(("design", 1, "f0")) == (10.0, 20.0)
    assert b.stats()["merged_in"] == 1
    b.put(("design", 2, "f0"), (30.0, 40.0))
    assert a.get(("design", 2, "f0")) == (30.0, 40.0)
    assert a.stats()["segments"] == 2
    # a cold third instance rebuilds the merged view from disk alone
    c = DiskSegmentEvalCache(d)
    assert c.get(("design", 1, "f0")) is not None
    assert c.get(("design", 2, "f0")) is not None
    for x in (a, b, c):
        x.close()


def test_disk_segment_cache_tolerates_torn_tail(tmp_path):
    d = str(tmp_path / "cache")
    a = DiskSegmentEvalCache(d)
    a.put(("k", 1), 1.0)
    a.put(("k", 2), 2.0)
    a.close()
    seg = glob.glob(os.path.join(d, "seg-*"))[0]
    with open(seg, "ab") as f:               # crashed writer mid-append
        f.write(b"\x80\x05torn")
    b = DiskSegmentEvalCache(d)
    assert b.get(("k", 1)) == 1.0 and b.get(("k", 2)) == 2.0
    b.close()


def test_disk_segment_cache_clear_keeps_disk_purge_deletes(tmp_path):
    d = str(tmp_path / "cache")
    a = DiskSegmentEvalCache(d)
    a.put(("k", 1), 1.0)
    a.clear()                                 # memory only
    assert glob.glob(os.path.join(d, "seg-*"))
    b = DiskSegmentEvalCache(d)               # peers still see the entry
    assert b.get(("k", 1)) == 1.0
    b.close()
    a.purge()                                 # explicit disk reset
    assert not glob.glob(os.path.join(d, "seg-*"))


def test_attribute_cache_traffic_is_thread_local():
    c = InMemoryEvalCache()
    c.put(("seed",), 0)
    accs = {}

    def worker(tag, hit_key, miss_key):
        with attribute_cache_traffic() as acc:
            c.get(hit_key)
            c.get(miss_key)
            c.put(("new", tag), 1)
            accs[tag] = acc

    ts = [threading.Thread(target=worker,
                           args=(t, ("seed",), ("nope", t)))
          for t in range(4)]
    with attribute_cache_traffic() as outer:
        for t in ts:
            t.start()
        for t in ts:
            t.join()
    # each thread sees exactly its own traffic; the outer block none of it
    for t in range(4):
        assert accs[t] == {"hits": 1, "misses": 1, "entries_added": 1}
    assert outer == {"hits": 0, "misses": 0, "entries_added": 0}


def test_evaluator_backend_swap_and_stats(tmp_path):
    prev = evaluator.get_eval_cache_backend()
    try:
        be = evaluator.configure_eval_cache(max_entries=2)
        assert evaluator.get_eval_cache_backend() is be
        s = evaluator.eval_cache_stats()
        assert s["entries"] == 0 and "evictions" in s
        disk = evaluator.configure_eval_cache(
            cache_dir=str(tmp_path / "ec"), max_entries=8)
        assert isinstance(disk, DiskSegmentEvalCache)
        assert evaluator.eval_cache_stats()["segments"] == 0
    finally:
        evaluator.set_eval_cache_backend(prev)


def test_gnn_params_digest_is_content_stable():
    import jax
    from repro.core.noc_gnn import init_gnn
    p1 = init_gnn(jax.random.PRNGKey(0))
    p2 = init_gnn(jax.random.PRNGKey(0))
    p3 = init_gnn(jax.random.PRNGKey(1))
    # same content -> same digest even across distinct objects (unlike the
    # monotonic pin token, which is object-identity based)
    assert evaluator.gnn_params_digest(p1) == evaluator.gnn_params_digest(p2)
    assert evaluator.gnn_params_digest(p1) != evaluator.gnn_params_digest(p3)
    assert evaluator.gnn_params_token(p1) != evaluator.gnn_params_token(p2)


# --------------------------- checkpoint retention ---------------------------


def _tiny_loop(**over):
    cfg = dict(strategy="mobo", N0=6, d0=2, q=2, n_candidates=8, seed=3)
    cfg.update(over)

    def f(d):
        return (1000.0, 2000.0)

    return ExplorationLoop(LoopConfig(**cfg), f)


def test_save_state_retains_last_n_and_prunes(tmp_path):
    loop = _tiny_loop()
    ck = str(tmp_path / "w.ckpt")
    while loop.step():
        loop.save_state(ck, keep=3)
    hist = sorted(glob.glob(ck + ".step*"))
    assert len(hist) == 2                      # keep-1 history + the head
    assert os.path.exists(ck)
    # keep<=1 reverts to single-file behavior
    loop2 = _tiny_loop()
    ck2 = str(tmp_path / "s.ckpt")
    while loop2.step():
        loop2.save_state(ck2, keep=1)
    assert not glob.glob(ck2 + ".step*")


def test_load_state_falls_back_on_corrupt_head(tmp_path):
    loop = _tiny_loop()
    ck = str(tmp_path / "w.ckpt")
    while loop.step():
        loop.save_state(ck, keep=3)
    good_cfg, good_state, _ = ExplorationLoop.load_state(ck)
    with open(ck, "wb") as f:
        f.write(b"definitely not a pickle")
    cfg, state, _ = ExplorationLoop.load_state(ck)
    assert cfg == good_cfg
    # fallback is the newest retained history snapshot — one save behind
    # the (corrupt) head, and a strict prefix of its trace
    assert state.steps == good_state.steps - 1
    assert state.trace.ys == good_state.trace.ys[:len(state.trace.ys)]
    # nothing loadable at all -> the head's error propagates
    for p in glob.glob(ck + ".step*"):
        os.remove(p)
    with pytest.raises(Exception):
        ExplorationLoop.load_state(ck)


def test_load_state_reads_v1_checkpoints(tmp_path):
    loop = _tiny_loop()
    while loop.step():
        pass
    st = loop.state
    for f in ("inflight", "dispatch_seq"):    # simulate a pre-async state
        delattr(st, f)
    blob = {"version": 1, "cfg": dataclasses.asdict(loop.cfg),
            "state": st, "extra": {}}
    p = str(tmp_path / "v1.ckpt")
    with open(p, "wb") as f:
        pickle.dump(blob, f)
    _, state, _ = ExplorationLoop.load_state(p)
    assert state.inflight == [] and state.dispatch_seq == 0


# --------------------------- warm memoization -------------------------------


def test_warm_optimizer_kernels_memoized_per_process():
    from repro.core.mfmobo import warm_optimizer_kernels
    n1 = warm_optimizer_kernels(4, n_candidates=12, q=2)
    n2 = warm_optimizer_kernels(4, n_candidates=12, q=2)
    assert n1 >= 1 and n2 == 0                # second call skips everything
    assert warm_optimizer_kernels(4, n_candidates=12, q=2, force=True) == n1


# --------------------------- async proposal mode ----------------------------


def test_async_depth_validation():
    with pytest.raises(ValueError, match="async_depth"):
        LoopConfig(async_depth=-1).validate()


@pytest.mark.parametrize("strategy", ["mfmobo", "mobo"])
def test_async_mode_is_deterministic_and_exact(strategy):
    over = ({} if strategy == "mfmobo"
            else dict(strategy="mobo", n_evals_f0=6))
    spec = quick_spec(async_depth=2, **over)
    r1 = Campaign(spec).run()
    r2 = Campaign(spec).run()
    assert r1.finished and r2.finished
    # fixed seed + fixed (state-driven) interleaving replays the trace
    assert r1.trace.ys == r2.trace.ys
    assert r1.trace.hv == r2.trace.hv
    assert [x.tolist() for x in r1.trace.xs] == [x.tolist()
                                                 for x in r2.trace.xs]
    # async mode still honors the budgets exactly
    assert r1.n_evals == spec.loop_config().total_evals()
    assert len(r1.trace.ys) == spec.n_evals_f0


def test_async_resume_mid_flight_matches_uninterrupted(tmp_path):
    spec = quick_spec(async_depth=2)
    full = Campaign(spec).run()
    ck = str(tmp_path / "a.ckpt")
    c = Campaign(spec)
    c.run(checkpoint_path=ck, checkpoint_every=1, max_steps=4)
    assert not c.loop.finished
    # the checkpoint legitimately carries in-flight batches (futures are
    # process-local and not pickled; the resume path re-dispatches them)
    resumed = Campaign.resume(ck).run()
    assert resumed.trace.ys == full.trace.ys
    assert resumed.trace.hv == full.trace.hv
    assert resumed.n_evals == full.n_evals


def test_sync_mode_untouched_by_async_fields():
    # async_depth=0 must consume the identical rng stream as the loop did
    # before async mode existed: pin against the thin legacy wrapper
    from repro.core.mfmobo import run_mfmobo

    def f(d):
        return (float(d.mac_num) / 2.0, 1500.0)

    spec = quick_spec(async_depth=0)
    tr = run_mfmobo(f, f, d0=2, d1=2, k=2, N0=5, N1=6, q=2,
                    n_candidates=16, seed=7)
    res = Campaign(spec).run()      # different objective, same rng stream
    assert len(res.trace.ys) == len(tr.ys)


# --------------------------- fleet spec + scheduler -------------------------


def test_fleet_spec_roundtrip_grid_and_validation(tmp_path):
    fs = FleetSpec(name="t", campaigns=(quick_spec(),), workers=2,
                   cache_dir="x", checkpoint_every=4)
    again = FleetSpec.from_json(fs.to_json())
    assert again == fs
    grid = expand_grid({"base": quick_spec().to_dict(),
                        "strategies": ["mfmobo", "random"],
                        "seeds": [0, 1]})
    assert len(grid) == 4
    assert len({c.name for c in grid}) == 4
    with pytest.raises(ValueError, match="unique"):
        FleetSpec(name="d", campaigns=(quick_spec(), quick_spec())
                  ).validate()
    with pytest.raises(ValueError, match="no campaigns"):
        FleetSpec(name="e", campaigns=()).validate()
    with pytest.raises(ValueError, match="unknown fleet spec fields"):
        FleetSpec.from_dict({"name": "x", "campaigns": [], "bogus": 1})


def _fleet_campaigns():
    a = quick_spec(name="fa", seed=0, async_depth=1)
    b = quick_spec(name="fb", seed=0, strategy="random", n_evals_f0=4, q=4)
    return a, b


def test_fleet_runs_grid_with_shared_cache(tmp_path):
    a, b = _fleet_campaigns()
    fs = FleetSpec(name="t-fleet", campaigns=(a, b), workers=2,
                   cache_dir=str(tmp_path / "ec"),
                   checkpoint_dir=str(tmp_path / "ck"), checkpoint_every=2)
    res = run_fleet(fs)
    assert res.errors == [] and res.crashes == 0
    assert all(c is not None for c in res.campaigns)
    assert res.n_evals == (a.loop_config().total_evals()
                           + b.loop_config().total_evals())
    assert res.fleet_candidates_per_sec > 0
    # both workers wrote segments into the shared persistent cache
    assert len(glob.glob(str(tmp_path / "ec" / "seg-*"))) >= 1
    # result dicts are JSON-serializable artifacts
    out = str(tmp_path / "fleet.json")
    res.save(out)
    assert os.path.getsize(out) > 0


def test_fleet_warm_second_pass_hits_shared_cache(tmp_path):
    _, b = _fleet_campaigns()
    fs = FleetSpec(name="t-warm", campaigns=(b,), workers=1,
                   cache_dir=str(tmp_path / "ec"))
    cold = run_fleet(fs)
    warm = run_fleet(dataclasses.replace(fs, name="t-warm2"))
    sc_cold = cold.campaigns[0]["stage_cache"]["f0"]
    sc_warm = warm.campaigns[0]["stage_cache"]["f0"]
    assert sc_warm["hits"] > sc_cold["hits"]
    # the warm campaign re-evaluates the same candidates: >50% f0 hit-rate
    assert sc_warm["hit_rate"] > 0.5


def test_fleet_killed_worker_resumes_to_identical_front(tmp_path):
    a, _ = _fleet_campaigns()
    ref = Campaign(a).run()
    fs = FleetSpec(name="t-crash", campaigns=(a,), workers=1,
                   checkpoint_dir=str(tmp_path / "ck"), checkpoint_every=1)
    marker = str(tmp_path / "crashed.marker")
    os.environ[_CRASH_ENV] = f"{a.name}:{marker}"
    try:
        res = run_fleet(fs)
    finally:
        del os.environ[_CRASH_ENV]
    assert os.path.exists(marker), "crash hook never fired"
    assert res.crashes == 1
    c = res.campaigns[0]
    assert c["resumed"] is True
    assert c["hv"] == list(ref.trace.hv)
    assert c["n_evals"] == ref.n_evals
    assert [f["throughput"] for f in c["front"]] == [
        f["throughput"] for f in ref.front]
