"""Trace-driven multi-tenant serving (repro.core.traces, DESIGN.md §14):
generators, the event-skip scheduler vs its per-step reference, policy
semantics, windowed goodput metrics, the searchable policy axis, and the
ServeEngine cross-validation (ISSUE 10 satellites S1-S4)."""
import json
import time

import numpy as np
import pytest

from repro.core.serving import (
    _continuous_batch_schedule_ref,
    continuous_batch_schedule,
)
from repro.core.traces import (
    DEFAULT_TENANT,
    POLICIES,
    POOL_POLICIES,
    PolicyDesign,
    RequestTrace,
    TenantClass,
    _trace_schedule_ref,
    diurnal_trace,
    evaluate_trace_serving_batch,
    poisson_trace,
    sample_policy_candidates,
    spike_trace,
    synth_trace,
    trace_schedule,
    trace_serving_metrics,
)
from repro.core.workload import GPT_BENCHMARKS, RequestMix

TWO_TENANTS = (
    TenantClass("chat", ttft_s=5.0, tpot_s=0.1, priority=2,
                interactive=True),
    TenantClass("batch", ttft_s=1e4, tpot_s=1e3, priority=0,
                interactive=False),
)


def _sched_equal(a, b):
    assert a.n_steps == b.n_steps
    assert a.n_decode_steps == b.n_decode_steps
    assert a.n_preemptions == b.n_preemptions
    for f in ("admit_step", "finish_step", "decode_tokens",
              "event_step", "event_req", "event_ctx", "first_event"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f), f)


# ---------------------------------------------------------------------------
# trace generators + serialization
# ---------------------------------------------------------------------------


def test_generators_deterministic_and_sorted():
    for kind in ("poisson", "spike", "diurnal"):
        t1 = synth_trace(kind, 40, seed=3, tenants=TWO_TENANTS)
        t2 = synth_trace(kind, 40, seed=3, tenants=TWO_TENANTS)
        assert t1 == t2
        assert t1.n_requests == 40
        arr = np.asarray(t1.arrival_steps)
        assert (np.diff(arr) >= 0).all()
        assert synth_trace(kind, 40, seed=4, tenants=TWO_TENANTS) != t1


def test_trace_json_round_trip(tmp_path):
    t = spike_trace(24, tenants=TWO_TENANTS, shares=(0.5, 0.5), seed=9)
    rt = RequestTrace.from_json(t.to_json())
    assert rt == t
    p = tmp_path / "trace.json"
    t.to_json(str(p))
    assert RequestTrace.from_json(str(p)) == t
    # payload is plain JSON with tenant classes embedded
    d = json.loads(t.to_json())
    assert {tc["name"] for tc in d["tenants"]} == {"chat", "batch"}


def test_trace_tenant_views():
    t = spike_trace(30, tenants=TWO_TENANTS, shares=(0.5, 0.5), seed=1)
    prio = t.priorities()
    inter = t.interactive_mask()
    for r in range(t.n_requests):
        tc = t.tenant_of(r)
        assert prio[r] == tc.priority
        assert inter[r] == tc.interactive
    # single-tenant default: everyone interactive at priority 0
    u = poisson_trace(10, seed=0)
    assert u.interactive_mask().all() and (u.priorities() == 0).all()


def test_from_mix_is_all_arrived_at_zero():
    mix = RequestMix.sampled(np.random.default_rng(0), 12, (4, 64), (2, 9))
    t = RequestTrace.from_mix(mix)
    assert (np.asarray(t.arrival_steps) == 0).all()
    assert t.mix() == mix
    assert t.tenants == (DEFAULT_TENANT,)
    assert mix.as_trace() == t


def test_bad_traces_rejected():
    with pytest.raises(ValueError):
        RequestTrace((1, 0), (4, 4), (2, 2), (0, 0), (DEFAULT_TENANT,))
    with pytest.raises(ValueError):
        synth_trace("lognormal", 8)
    with pytest.raises(ValueError):
        poisson_trace(8, rate=0.0)


# ---------------------------------------------------------------------------
# S1: continuous_batch_schedule is the degenerate (all-at-zero, fifo) case
# ---------------------------------------------------------------------------


def test_degenerate_trace_matches_batch_schedule_bitwise():
    rng = np.random.default_rng(7)
    for _ in range(8):
        mix = RequestMix.sampled(rng, int(rng.integers(1, 24)),
                                 (1, 96), (1, 13))
        for slots in (1, 3, 8):
            s = continuous_batch_schedule(mix, slots)
            r = _continuous_batch_schedule_ref(mix, slots)
            assert s.n_decode_steps == r.n_decode_steps
            np.testing.assert_array_equal(s.admit_step, r.admit_step)
            np.testing.assert_array_equal(s.finish_step, r.finish_step)
            np.testing.assert_array_equal(s.decode_tokens, r.decode_tokens)


# ---------------------------------------------------------------------------
# event-skip scheduler == per-step reference (bitwise)
# ---------------------------------------------------------------------------


def test_fast_schedule_matches_reference_bitwise():
    for seed in range(6):
        for kind in ("poisson", "spike", "diurnal"):
            t = synth_trace(kind, 24, seed=seed, tenants=TWO_TENANTS,
                            shares=(0.5, 0.5))
            for slots in (1, 2, 5):
                for pol in POOL_POLICIES:
                    _sched_equal(trace_schedule(t, slots, pol),
                                 _trace_schedule_ref(t, slots, pol))


def test_schedule_rejects_bad_args():
    t = poisson_trace(4, seed=0)
    with pytest.raises(ValueError):
        trace_schedule(t, 0, "fifo")
    with pytest.raises(ValueError):
        trace_schedule(t, 4, "lifo")


# ---------------------------------------------------------------------------
# policy semantics
# ---------------------------------------------------------------------------


def _contended_trace():
    # 4 batch requests arrive first and occupy both slots; a chat request
    # arrives while they are still decoding
    return RequestTrace(
        arrival_steps=(0, 0, 0, 0, 2),
        prompt_lens=(16, 16, 16, 16, 16),
        out_lens=(12, 12, 12, 12, 4),
        tenant_ids=(1, 1, 1, 1, 0),
        tenants=TWO_TENANTS)


def test_priority_admits_interactive_before_waiting_batch():
    t = _contended_trace()
    fifo = trace_schedule(t, 2, "fifo")
    prio = trace_schedule(t, 2, "priority")
    # fifo: chat waits behind both queued batch requests
    assert prio.admit_step[4] <= fifo.admit_step[4]
    assert prio.n_preemptions == fifo.n_preemptions == 0
    # priority jumps the queue but never evicts: batch 2/3 admit later
    assert prio.admit_step[2] >= fifo.admit_step[2]


def test_preempt_evicts_batch_and_preserves_tokens():
    t = _contended_trace()
    s = trace_schedule(t, 2, "preempt")
    assert s.n_preemptions >= 1
    # chat admitted at its arrival step (a batch victim was evicted)
    assert s.admit_step[4] == 2
    # every request still emits exactly out_len tokens
    np.testing.assert_array_equal(
        np.asarray(s.decode_tokens),
        np.maximum(np.asarray(t.out_lens) - 1, 1))
    # the victim finishes later than it would have unpreempted
    fifo = trace_schedule(t, 2, "fifo")
    assert s.finish_step.max() >= fifo.finish_step.max()
    assert max(s.finish_step) < s.n_steps


# ---------------------------------------------------------------------------
# S3: event-skip performance guard
# ---------------------------------------------------------------------------


def test_event_skip_schedules_10k_diurnal_under_1s():
    t = diurnal_trace(10_000, rate=0.5, period=512, amplitude=0.9,
                      tenants=TWO_TENANTS, shares=(0.5, 0.5), seed=0)
    t0 = time.perf_counter()
    s = trace_schedule(t, 8, "preempt")
    dt = time.perf_counter() - t0
    assert (np.asarray(s.admit_step) >= 0).all()
    assert dt < 1.0, f"10k-request diurnal schedule took {dt:.2f}s"


# ---------------------------------------------------------------------------
# windowed goodput metrics
# ---------------------------------------------------------------------------


def test_trace_metrics_shapes_and_slo_binding():
    t = spike_trace(32, tenants=TWO_TENANTS, shares=(0.5, 0.5), seed=2)
    s = trace_schedule(t, 4, "fifo")
    tp = np.array([0.05, 0.05])
    td = np.array([0.01, 10.0])          # candidate 1: hopeless tpot
    m = trace_serving_metrics(s, t, tp, 512, td, window_steps=16)
    for k in ("goodput", "interactive_goodput", "worst_window_goodput",
              "throughput", "slo_attainment"):
        assert m[k].shape == (2,), k
    assert m["ttft"].shape == m["tpot"].shape == (2, t.n_requests)
    assert m["goodput"][0] >= m["interactive_goodput"][0] >= 0
    # slow candidate misses every chat SLO: zero interactive goodput
    assert m["interactive_goodput"][1] == 0.0
    assert m["worst_window_goodput"][1] == 0.0
    # worst-window rate can't beat the zero-SLO throughput ceiling
    assert (m["worst_window_goodput"] <= m["throughput"] + 1e-9).all()


def test_trace_metrics_huge_slo_goodput_equals_throughput():
    lax = (TenantClass("a", ttft_s=1e9, tpot_s=1e9),)
    t = poisson_trace(16, tenants=lax, seed=5)
    s = trace_schedule(t, 4, "fifo")
    m = trace_serving_metrics(s, t, np.array([0.1]), 256,
                              np.array([0.02]), window_steps=32)
    np.testing.assert_allclose(m["goodput"], m["throughput"])
    assert m["slo_attainment"][0] == 1.0


# ---------------------------------------------------------------------------
# evaluator + searchable policy axis
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_pool():
    from benchmarks.common import sample_valid_designs
    return sample_valid_designs(3, seed=5)


def test_evaluate_trace_serving_batch_all_policies(small_pool):
    wl = GPT_BENCHMARKS[7]
    t = spike_trace(20, tenants=TWO_TENANTS, shares=(0.5, 0.5), seed=3)
    cands = [PolicyDesign(small_pool[i % len(small_pool)], pol)
             for i, pol in enumerate(POLICIES)]
    res = evaluate_trace_serving_batch(cands, wl, t, slots=4,
                                       window_steps=16, max_strategies=8)
    assert [r.policy for r in res] == list(POLICIES)
    for r in res:
        if r.feasible:
            assert r.throughput_tok_s > 0 and r.power_w > 0
            assert r.n_steps >= r.n_decode_steps > 0
            assert set(r.per_tenant) == {"chat", "batch"}
    # plain designs default to the call's policy
    plain = evaluate_trace_serving_batch(small_pool[:1], wl, t, slots=4,
                                         policy="priority",
                                         window_steps=16, max_strategies=8)
    assert plain[0].policy == "priority"


def test_sample_policy_candidates_axis():
    rng = np.random.default_rng(0)
    pts, cands = sample_policy_candidates(rng, 16)
    assert pts.shape == (16, 14)
    assert (0.0 <= pts).all() and (pts <= 1.0).all()
    assert all(isinstance(c, PolicyDesign) for c in cands)
    assert {c.policy for c in cands} <= set(POLICIES)
    assert "policy=" in cands[0].describe()
    # restricted menu decodes only into the allowed policies
    _, only = sample_policy_candidates(np.random.default_rng(1), 16,
                                       policies=("priority",))
    assert {c.policy for c in only} == {"priority"}


# ---------------------------------------------------------------------------
# campaign integration (TraceSpec)
# ---------------------------------------------------------------------------


def _trace_spec(policy="search", **kw):
    from repro.explore import CampaignSpec, FidelitySchedule, TraceSpec
    return CampaignSpec(
        name="t", workload="GPT-175B", scenario="trace_serving",
        strategy="random", fidelity=FidelitySchedule(f0="analytical",
                                                     d0=2, k=0),
        n_evals_f0=4, q=2, seed=3, max_strategies=8,
        trace=TraceSpec(kind="spike", n_requests=12, seed=1, slots=4,
                        window_steps=16, policy=policy,
                        tenants=({"name": "chat", "ttft_s": 9.0,
                                  "tpot_s": 0.5, "priority": 2,
                                  "interactive": True, "share": 0.5},
                                 {"name": "batch", "ttft_s": 1e4,
                                  "tpot_s": 1e3, "priority": 0,
                                  "interactive": False, "share": 0.5}),
                        **kw))


def test_trace_spec_round_trip_and_validation():
    from repro.explore import CampaignSpec
    spec = _trace_spec()
    spec.validate()
    assert CampaignSpec.from_json(spec.to_json()) == spec
    mets = spec.known_metrics()
    assert {"worst_window_goodput", "tenant:chat:goodput",
            "tenant:batch:slo_attainment"} <= set(mets)
    with pytest.raises(ValueError):
        _trace_spec(policy="lifo").validate()
    with pytest.raises(ValueError):
        # restricting the policy menu only makes sense under search
        _trace_spec(policy="fifo", policies=("fifo", "priority")).validate()
    import dataclasses
    no_trace = dataclasses.replace(spec, trace=None)
    with pytest.raises(ValueError):
        no_trace.validate()


def test_trace_campaign_searches_policy_axis():
    from repro.explore import Campaign
    res = Campaign(_trace_spec()).run()
    assert res.trace.n_evals == 4
    assert all(isinstance(d, PolicyDesign) for d in res.trace.designs)
    for f in res.front:
        assert f["design"]["policy"] in POLICIES
        assert "policy=" in f["describe"]


# ---------------------------------------------------------------------------
# S2 + S4: the real engine — submit validation and trace replay
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_model():
    jax = pytest.importorskip("jax")
    from repro.configs import reduced_config
    from repro.models import model as M
    cfg = reduced_config("smollm-135m")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    return cfg, params


def _engine(tiny_model, **kw):
    from repro.models.runtime import CPU_TEST as RT
    from repro.serve.engine import ServeEngine
    cfg, params = tiny_model
    return ServeEngine(cfg, RT, params, max_len=64, **kw)


def test_submit_rejects_oversized_and_bad_requests(tiny_model):
    from repro.serve.engine import Request
    eng = _engine(tiny_model, slots=2)
    long_prompt = np.zeros(60, dtype=np.int32)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(0, long_prompt, max_new_tokens=10))
    with pytest.raises(ValueError, match="submit_at"):
        eng.submit(Request(1, np.zeros(4, np.int32), 2, submit_at=-1))
    with pytest.raises(ValueError):
        _engine(tiny_model, slots=2, policy="lifo")
    eng.submit(Request(2, np.zeros(4, np.int32), 2))  # still usable


def _replay_trace():
    # narrow prompt/out ranges keep jit retraces bounded
    return spike_trace(
        12, rate=0.4, spike_factor=6.0, spike_len=8, gap_len=24,
        tenants=TWO_TENANTS, shares=(0.5, 0.5),
        prompt_ranges=((4, 8), (4, 8)), out_ranges=((2, 5), (4, 8)),
        seed=11)


def test_engine_respects_arrival_order_under_contention(tiny_model):
    from repro.serve.engine import replay_trace
    t = _replay_trace()
    eng = _engine(tiny_model, slots=2, policy="fifo")
    reqs = replay_trace(eng, t)
    admits = np.array([r.admit_step for r in reqs])
    assert (admits >= 0).all()
    assert (admits >= np.asarray(t.arrival_steps)).all()
    # fifo: admission order == arrival order (rid-tiebroken)
    order = np.argsort(admits, kind="stable")
    np.testing.assert_array_equal(order, np.arange(len(reqs)))


@pytest.mark.parametrize("policy", POOL_POLICIES)
def test_engine_replay_matches_trace_schedule_bitwise(tiny_model, policy):
    from repro.serve.engine import replay_trace
    t = _replay_trace()
    eng = _engine(tiny_model, slots=3, policy=policy)
    reqs = replay_trace(eng, t)
    s = trace_schedule(t, 3, policy)
    np.testing.assert_array_equal([r.admit_step for r in reqs],
                                  s.admit_step)
    np.testing.assert_array_equal([r.finish_step for r in reqs],
                                  s.finish_step)
    assert sum(r.n_preemptions for r in reqs) == s.n_preemptions
    for r in reqs:
        assert len(r.output) == r.max_new_tokens


def test_engine_preempted_request_decodes_same_tokens(tiny_model):
    from repro.serve.engine import replay_trace
    t = _replay_trace()
    s = trace_schedule(t, 3, "preempt")
    assert s.n_preemptions >= 1, "trace must exercise preemption"
    eng = _engine(tiny_model, slots=3, policy="preempt")
    rng = np.random.default_rng(4)
    reqs = replay_trace(eng, t, rng=rng)
    victims = [r for r in reqs if r.n_preemptions > 0]
    assert victims
    # greedy decode is deterministic: an evicted-and-resumed request must
    # produce the same tokens it would have produced uncontended
    from repro.serve.engine import Request
    for v in victims:
        solo = _engine(tiny_model, slots=1).run(
            [Request(0, v.prompt, v.max_new_tokens)])[0]
        assert solo == v.output
