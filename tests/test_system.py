"""End-to-end behaviour tests: train a reduced model on learnable synthetic
data (loss must approach the generator's entropy floor direction), then serve
it through the batched engine."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.models import model as M
from repro.models.runtime import CPU_TEST as RT
from repro.serve.engine import Request, ServeEngine
from repro.train.data import MarkovLMDataset
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step


def test_train_learns_and_serves():
    cfg = reduced_config("smollm-135m")
    rng = jax.random.PRNGKey(0)
    params = M.init_params(rng, cfg)
    ds = MarkovLMDataset(vocab=cfg.vocab, seq_len=32, batch=8, seed=1)
    opt = AdamWConfig(peak_lr=3e-3, warmup_steps=5, total_steps=60,
                      weight_decay=0.0)
    step = jax.jit(make_train_step(cfg, RT, opt, microbatches=2))
    ost = init_opt_state(params)
    losses = []
    for i in range(60):
        b = ds.batch_at(i)
        params, ost, met = step(params, ost,
                                {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(met["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0] - 0.8, (losses[0], losses[-1])

    eng = ServeEngine(cfg, RT, params, slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=np.arange(4 + i) % cfg.vocab,
                    max_new_tokens=5) for i in range(3)]
    outs = eng.run(reqs)
    assert set(outs) == {0, 1, 2}
    assert all(len(v) == 5 for v in outs.values())
