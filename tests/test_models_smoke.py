"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward + one train step on CPU, asserting output shapes and no NaNs;
plus prefill/decode consistency against the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, reduced_config
from repro.models import model as M
from repro.models.runtime import CPU_TEST as RT
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step


def _batch(cfg, rng, B=2, S=24, with_labels=True):
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab)}
    if with_labels:
        batch["labels"] = jax.random.randint(rng, (B, S), 0, cfg.vocab)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            rng, (B, cfg.encoder_len, cfg.d_model))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            rng, (B, cfg.prefix_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = reduced_config(arch)
    rng = jax.random.PRNGKey(0)
    params = M.init_params(rng, cfg)
    B, S = 2, 24
    batch = _batch(cfg, rng, B, S)
    logits, aux = M.forward(params, cfg, RT, batch)
    exp_len = S + (cfg.prefix_len if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_len, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = reduced_config(arch)
    rng = jax.random.PRNGKey(1)
    params = M.init_params(rng, cfg)
    step = make_train_step(cfg, RT, AdamWConfig(peak_lr=1e-3))
    ost = init_opt_state(params)
    batch = _batch(cfg, rng)
    new_params, ost, met = step(params, ost, batch)
    assert np.isfinite(float(met["loss"]))
    assert np.isfinite(float(met["grad_norm"]))
    # params must actually change
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()),
                     params, new_params))
    assert delta > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    cfg = reduced_config(arch)
    rng = jax.random.PRNGKey(2)
    params = M.init_params(rng, cfg)
    B, S = 2, 16
    batch = _batch(cfg, rng, B, S, with_labels=False)
    logits_full, _ = M.forward(params, cfg, RT, batch)

    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S - 1]
    cache = M.init_cache(cfg, RT, B, max_len=32)
    last_logits, cache = M.prefill(params, cfg, RT, pre, cache)
    off = cfg.prefix_len if cfg.family == "vlm" else 0
    ref = logits_full[:, off + S - 2]
    np.testing.assert_allclose(np.asarray(last_logits), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)

    pos = jnp.int32(off + S - 1)
    dec_logits, _ = M.decode_step(params, cfg, RT,
                                  batch["tokens"][:, S - 1:S], pos, cache)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["gemma3-4b", "mixtral-8x7b"])
def test_ring_cache_matches_full_cache(arch):
    """Sliding-window archs: ring-buffer cache must reproduce full-cache
    decode logits once the window is the binding constraint."""
    import dataclasses

    cfg = reduced_config(arch)
    rt_ring = dataclasses.replace(RT, ring_cache=True)
    rng = jax.random.PRNGKey(3)
    params = M.init_params(rng, cfg)
    B, S = 1, 12
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab)

    def roll(rt):
        cache = M.init_cache(cfg, rt, B, max_len=32)
        logits, cache = M.prefill(params, cfg, rt,
                                  {"tokens": tokens[:, :4]}, cache)
        outs = [logits]
        for t in range(4, S):
            logits, cache = M.decode_step(params, cfg, rt,
                                          tokens[:, t:t + 1],
                                          jnp.int32(t), cache)
            outs.append(logits)
        return np.stack([np.asarray(o) for o in outs])

    full = roll(RT)
    ring = roll(rt_ring)
    if arch == "mixtral-8x7b":      # every layer windowed -> exact match
        np.testing.assert_allclose(ring, full, rtol=2e-4, atol=2e-4)
    else:
        # gemma3 keeps full-length caches in baseline mode for its global
        # layers; ring mode only legal when pattern is uniform — shapes only
        assert ring.shape == full.shape
