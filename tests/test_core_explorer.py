"""GP surrogate, EHVI, Pareto/hypervolume, MFMOBO loop."""
import numpy as np
import pytest

from repro.core.ehvi import ehvi_2d
from repro.core.gp import GP
from repro.core.pareto import hypervolume_2d, pareto_front, pareto_mask


def test_gp_fits_smooth_function():
    rng = np.random.default_rng(0)
    X = rng.random((40, 3))
    y = np.sin(3 * X[:, 0]) + X[:, 1] ** 2
    gp = GP.fit(X, y, iters=60)
    Xs = rng.random((20, 3))
    ys = np.sin(3 * Xs[:, 0]) + Xs[:, 1] ** 2
    mu, sd = gp.predict(Xs)
    rmse = float(np.sqrt(np.mean((mu - ys) ** 2)))
    assert rmse < 0.25
    assert (sd > 0).all()


def test_gp_uncertainty_grows_off_data():
    X = np.random.default_rng(1).random((20, 2)) * 0.3   # data in a corner
    y = X.sum(1)
    gp = GP.fit(X, y, iters=60)
    _, sd_near = gp.predict(X[:5])
    _, sd_far = gp.predict(np.ones((5, 2)) * 0.95)
    assert sd_far.mean() > sd_near.mean()


def test_pareto_front_2d():
    pts = np.array([[1, 5], [2, 4], [3, 3], [2, 2], [0, 6], [3, 1]])
    mask = pareto_mask(pts)
    front = pts[mask]
    assert {tuple(p) for p in front} == {(0, 6), (1, 5), (2, 4), (3, 3)}


def test_hypervolume_known_case():
    ref = [0.0, 0.0]
    pts = np.array([[2.0, 1.0], [1.0, 2.0]])
    # union of 2x1 and 1x2 rectangles = 3
    assert hypervolume_2d(pts, ref) == pytest.approx(3.0)
    assert hypervolume_2d(np.zeros((0, 2)), ref) == 0.0
    # dominated point adds nothing
    pts2 = np.vstack([pts, [[1.0, 1.0]]])
    assert hypervolume_2d(pts2, ref) == pytest.approx(3.0)


def test_ehvi_monotone_in_mean():
    front = np.array([[2.0, 2.0]])
    ref = np.array([0.0, 0.0])
    sig = np.array([[0.3, 0.3]])
    lo = ehvi_2d(np.array([[1.0, 1.0]]), sig, front, ref)[0]
    hi = ehvi_2d(np.array([[3.0, 3.0]]), sig, front, ref)[0]
    assert hi > lo >= 0.0


def test_ehvi_zero_for_deeply_dominated():
    front = np.array([[5.0, 5.0]])
    ref = np.array([0.0, 0.0])
    v = ehvi_2d(np.array([[1.0, 1.0]]), np.array([[0.05, 0.05]]), front,
                ref)[0]
    assert v < 1e-6


def test_gp_condition_on_fantasy_update():
    """Rank-1 conditioning pins the posterior near the fantasized value and
    shrinks uncertainty there, without touching hyperparameters."""
    rng = np.random.default_rng(4)
    X = rng.random((25, 3))
    y = np.sin(3 * X[:, 0]) + X[:, 1]
    gp = GP.fit(X, y, iters=50)
    xs = rng.random(3)
    mu0, sd0 = gp.predict(xs[None])
    gp2 = gp.condition_on(xs, float(mu0[0]) + 0.3)
    mu1, sd1 = gp2.predict(xs[None])
    assert sd1[0] < sd0[0]
    assert mu1[0] > mu0[0]                      # pulled toward the fantasy
    assert gp2.params is gp.params              # no refit
    mu2, sd2 = gp2.predict(X[:5])
    assert np.isfinite(mu2).all() and (sd2 > 0).all()


def test_mobo_batched_proposals_with_batch_eval_fn():
    """q>1 proposals + a batch-aware objective: the loop evaluates whole
    batches in one call and still only spends the evaluation budget."""
    from repro.core.mfmobo import run_mobo
    from repro.core.design_space import encode_batch

    calls = {"n": 0, "sizes": []}

    def f(designs):
        calls["n"] += 1
        calls["sizes"].append(len(designs))
        U = encode_batch(designs)
        return [(float(1e5 * (1 + u[1] + u[4])),
                 float(5e3 * (0.5 + u[1] ** 2))) for u in U]
    f.batched = True

    tr = run_mobo(f, d0=3, N=9, n_candidates=32, q=3, seed=0)
    assert len(tr.ys) == 9
    assert tr.hv[-1] >= tr.hv[0]
    assert max(calls["sizes"]) == 3             # proposals arrive as batches
    assert sum(calls["sizes"]) == 9


def test_mfmobo_loop_improves_hypervolume():
    """MFMOBO on a cheap synthetic 2-objective problem over the WSC space:
    maximize (throughput-proxy, -power-proxy) from the encoded vector."""
    from repro.core.mfmobo import run_mfmobo, run_random
    from repro.core.design_space import encode

    def f_hi(design):
        u = encode(design)
        thpt = 1e5 * (1 + u[1] + u[4] - 0.5 * abs(u[1] - 0.6))
        power = 5000 * (0.5 + u[1] ** 2 + 0.3 * u[3])
        return float(thpt), float(power)

    def f_lo(design):
        t, p = f_hi(design)
        return t * 1.1, p * 0.95               # biased-but-correlated

    tr = run_mfmobo(f_hi, f_lo, d0=2, d1=2, k=2, N0=7, N1=7,
                    n_candidates=48, seed=0)
    assert len(tr.hv) >= 5
    assert tr.hv[-1] >= tr.hv[0]               # monotone non-decreasing
    rnd = run_random(f_hi, N=7, seed=0)
    assert tr.hv[-1] >= 0.8 * rnd.hv[-1]       # sanity: not catastrophically worse
