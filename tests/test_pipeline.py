"""GPipe schedule correctness: pipeline output == sequential application,
and gradients flow through the schedule."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train.pipeline import gpipe, pipeline_apply, split_stages


def _block(p_l, x):
    return jnp.tanh(x @ p_l["w"] + p_l["b"])


def _make(L=8, d=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    return {"w": jax.random.normal(ks[0], (L, d, d)) * 0.3,
            "b": jax.random.normal(ks[1], (L, d)) * 0.1}


def _sequential(params, x):
    def body(c, p_l):
        return _block(p_l, c), None
    out, _ = jax.lax.scan(body, x, params)
    return out


def test_pipeline_matches_sequential():
    L, d, B = 8, 16, 12
    params = _make(L, d)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, d))
    ref = _sequential(params, x)
    for stages, mbs in ((2, 4), (4, 6), (8, 3)):
        if B % mbs:
            continue
        out = pipeline_apply(params, x, _block, L, stages, mbs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_pipeline_gradients_flow():
    L, d, B = 4, 8, 8
    params = _make(L, d, seed=2)
    x = jax.random.normal(jax.random.PRNGKey(3), (B, d))
    tgt = jax.random.normal(jax.random.PRNGKey(4), (B, d))

    def loss_pipe(p):
        out = pipeline_apply(p, x, _block, L, n_stages=2, microbatches=4)
        return jnp.mean((out - tgt) ** 2)

    def loss_seq(p):
        return jnp.mean((_sequential(p, x) - tgt) ** 2)

    g_pipe = jax.grad(loss_pipe)(params)
    g_seq = jax.grad(loss_seq)(params)
    for a, b in zip(jax.tree.leaves(g_pipe), jax.tree.leaves(g_seq)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_split_stages_shapes():
    params = _make(8, 4)
    st = split_stages(params, 8, 4)
    assert st["w"].shape == (4, 2, 4, 4)
    assert st["b"].shape == (4, 2, 4)
