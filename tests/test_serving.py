"""Request-level serving model (repro.core.serving) + ISSUE 4 bugfix
regressions: per-request decode temperature, prefill KV length under
sharding, wafer-granularity area accounting, DRAM-energy consistency."""
import dataclasses

import numpy as np
import pytest

from repro.core.design_space import DesignBatch, WSCDesign
from repro.core.chunk_eval import evaluate_step_batch
from repro.core.heterogeneity import (
    evaluate_hetero_serving,
    wafer_split,
)
from repro.core.serving import (
    ServingSLO,
    continuous_batch_schedule,
    disaggregated_metrics,
    evaluate_serving,
    evaluate_serving_batch,
    serving_metrics,
    serving_objectives,
)
from repro.core.validator import validate
from repro.core.workload import (
    GPT_BENCHMARKS,
    RequestMix,
    inference_workload,
)

STACKED = WSCDesign(use_stacked_dram=True, dram_bw_tbps_per_100mm2=2.0)


# ---------------------------------------------------------------------------
# discrete continuous-batching schedule
# ---------------------------------------------------------------------------


def test_schedule_uniform_two_waves():
    mix = RequestMix.uniform(8, prompt_len=128, out_len=5)
    s = continuous_batch_schedule(mix, slots=4)
    # two waves of 4; each request decodes out_len-1 = 4 steps
    assert s.n_decode_steps == 8
    assert list(s.admit_step) == [0, 0, 0, 0, 4, 4, 4, 4]
    assert list(s.finish_step) == [3, 3, 3, 3, 7, 7, 7, 7]


def test_schedule_engine_semantics_min_one_decode_step():
    # max_new_tokens=1 still costs one decode step (ServeEngine's done
    # check runs after the post-admission decode)
    mix = RequestMix.uniform(1, prompt_len=16, out_len=1)
    s = continuous_batch_schedule(mix, slots=4)
    assert s.n_decode_steps == 1 and s.decode_tokens[0] == 1


def test_schedule_bounds_random_mixes():
    rng = np.random.default_rng(0)
    for _ in range(10):
        mix = RequestMix.sampled(rng, int(rng.integers(1, 20)),
                                 (1, 64), (1, 17))
        slots = int(rng.integers(1, 6))
        s = continuous_batch_schedule(mix, slots)
        # list-scheduling bounds: makespan within [max load, load/slots + max]
        total = int(s.decode_tokens.sum())
        assert s.n_decode_steps >= max(int(s.decode_tokens.max()),
                                       -(-total // slots))
        assert s.n_decode_steps <= total
        assert (s.finish_step >= s.admit_step).all()


def test_request_mix_validation():
    with pytest.raises(ValueError):
        RequestMix((4, 5), (1,))
    with pytest.raises(ValueError):
        RequestMix((), ())
    with pytest.raises(ValueError):
        RequestMix((4,), (0,))


# ---------------------------------------------------------------------------
# wall-clock metrics (synthetic step times)
# ---------------------------------------------------------------------------


def _mix_and_sched():
    mix = RequestMix.uniform(6, prompt_len=100, out_len=5)
    return mix, continuous_batch_schedule(mix, slots=3)


def test_slo_non_binding_goodput_equals_throughput():
    mix, sched = _mix_and_sched()
    m = serving_metrics(sched, mix, ServingSLO(1e9, 1e9),
                        np.array([0.1]), 100, np.array([0.01]))
    assert m["slo_attainment"][0] == 1.0
    assert m["goodput"][0] == pytest.approx(m["throughput"][0])


def test_slo_binding_zero_goodput():
    mix, sched = _mix_and_sched()
    m = serving_metrics(sched, mix, ServingSLO(1e-6, 1e-6),
                        np.array([0.1]), 100, np.array([0.01]))
    assert m["slo_attainment"][0] == 0.0
    assert m["goodput"][0] == 0.0 and m["throughput"][0] > 0


def test_ttft_waves_and_prefill_stall():
    mix, sched = _mix_and_sched()
    t_p, t_d = 0.5, 0.01
    m = serving_metrics(sched, mix, ServingSLO(1e9, 1e9),
                        np.array([t_p]), 100, np.array([t_d]))
    ttft, tpot = m["ttft"][0], m["tpot"][0]
    # wave 2 waits for wave 1's decode + all prior prefills
    assert ttft[3] > ttft[2] > ttft[0]
    assert ttft[0] == pytest.approx(t_p)
    # a wave's first request observes decode stalled by its wave peers'
    # prefills (admitted at the same step, serially, before the decode)
    assert (tpot >= t_d - 1e-12).all()
    assert tpot[0] > t_d
    # last wave decodes without further admissions: pure step time
    assert tpot[-1] == pytest.approx(t_d)


def test_prefill_time_scales_with_prompt_length():
    mix = RequestMix((100, 200), (4, 4))
    sched = continuous_batch_schedule(mix, slots=2)
    m = serving_metrics(sched, mix, ServingSLO(1e9, 1e9),
                        np.array([1.0]), 100, np.array([0.0]))
    # both admitted at step 0: TTFT = cumulative prefill, second is 1+2
    assert m["ttft"][0][0] == pytest.approx(1.0)
    assert m["ttft"][0][1] == pytest.approx(3.0)


def test_candidate_axis_broadcast():
    mix, sched = _mix_and_sched()
    m = serving_metrics(sched, mix, ServingSLO(1e9, 1e9),
                        np.array([0.1, 0.2]), 100, np.array([0.01, 0.02]))
    assert m["ttft"].shape == (2, mix.n_requests)
    assert m["goodput"].shape == (2,)
    # slower candidate is slower everywhere
    assert (m["ttft"][1] > m["ttft"][0]).all()
    assert m["throughput"][1] < m["throughput"][0]


# ---------------------------------------------------------------------------
# end-to-end serving evaluation (through the fidelity registry)
# ---------------------------------------------------------------------------


def test_evaluate_serving_batch_gpt175b():
    wl = GPT_BENCHMARKS[7]
    d = validate(STACKED).design
    mix = RequestMix.uniform(8, prompt_len=2048, out_len=32)
    slo = ServingSLO(ttft_s=60.0, tpot_s=1.0)
    r = evaluate_serving_batch([d], wl, mix, slo, slots=4,
                               max_strategies=8)[0]
    assert r.feasible
    assert r.goodput_tok_s <= r.throughput_tok_s + 1e-9
    assert 0.0 < r.ttft_s <= r.ttft_max_s
    assert 0.0 < r.tpot_s <= r.tpot_max_s
    assert np.isfinite(r.power_w) and r.power_w > 0
    assert r.n_decode_steps == continuous_batch_schedule(mix, 4).n_decode_steps
    # scalar wrapper agrees
    r2 = evaluate_serving(d, wl, mix, slo, slots=4, max_strategies=8)
    assert r2.goodput_tok_s == pytest.approx(r.goodput_tok_s)


def test_evaluate_serving_unknown_fidelity_raises():
    wl = GPT_BENCHMARKS[0]
    d = validate(WSCDesign()).design
    mix = RequestMix.uniform(2, 128, 4)
    with pytest.raises(ValueError, match="registered"):
        evaluate_serving_batch([d], wl, mix, ServingSLO(1, 1),
                               fidelity="bogus")


def test_serving_objectives_batch_aware():
    wl = GPT_BENCHMARKS[0]
    mix = RequestMix.uniform(4, 512, 8)
    f = serving_objectives(wl, mix, ServingSLO(60.0, 1.0), slots=2)
    assert f.batched and f.fidelity == "analytical"
    ds = [validate(WSCDesign()).design, validate(STACKED).design]
    ys = f(ds)
    assert len(ys) == 2
    assert all(len(y) == 2 and y[1] > 0 for y in ys)
    y0 = f(ds[0])
    assert y0[0] == pytest.approx(ys[0][0])


def test_forwarders_agree():
    from repro.core import evaluator, fidelity
    wl = GPT_BENCHMARKS[0]
    d = validate(WSCDesign()).design
    mix = RequestMix.uniform(3, 256, 4)
    slo = ServingSLO(30.0, 1.0)
    a = evaluator.evaluate_serving_batch([d], wl, mix, slo, slots=2)[0]
    b = fidelity.evaluate_serving_batch([d], wl, mix, slo, slots=2)[0]
    assert a.goodput_tok_s == pytest.approx(b.goodput_tok_s)


# ---------------------------------------------------------------------------
# disaggregated (hetero) coupled request model
# ---------------------------------------------------------------------------


def test_disaggregated_no_prefill_stall_on_decode():
    mix = RequestMix.uniform(4, 100, 5)
    m = disaggregated_metrics(mix, ServingSLO(1e9, 1e9), slots=2,
                              t_prefill=np.full(4, 0.5),
                              kv_s=np.zeros(4), t_decode=0.01)
    # second wave exists, but decode never stalls for prefill: the last
    # request's TPOT is bounded by step time plus its slot wait amortized
    assert m["n_decode_steps"] >= 8
    assert m["throughput_tok_s"] > 0


def test_disaggregated_kv_transfer_delays_admission():
    mix = RequestMix.uniform(2, 100, 3)
    slow = disaggregated_metrics(mix, ServingSLO(1e9, 1e9), slots=2,
                                 t_prefill=np.full(2, 0.1),
                                 kv_s=np.full(2, 5.0), t_decode=0.01)
    fast = disaggregated_metrics(mix, ServingSLO(1e9, 1e9), slots=2,
                                 t_prefill=np.full(2, 0.1),
                                 kv_s=np.zeros(2), t_decode=0.01)
    assert slow["total_time_s"] > fast["total_time_s"] + 4.0
    # TTFT comes from the prefill stage and is unaffected by KV shipping
    assert slow["ttft_s"] == pytest.approx(fast["ttft_s"])


def test_evaluate_hetero_serving_runs_all_granularities():
    wl = inference_workload(GPT_BENCHMARKS[1], "decode", batch=32, seq=2048)
    d = validate(STACKED).design
    mix = RequestMix.uniform(6, 1024, 16)
    slo = ServingSLO(30.0, 1.0)
    for gran in ("core", "reticle", "wafer"):
        h = evaluate_hetero_serving(d, d, wl, gran, 0.5, mix, slo,
                                    slots=4, n_wafers=4)
        assert h.feasible and h.throughput_tok_s > 0
        assert h.goodput_tok_s <= h.throughput_tok_s + 1e-9
        assert h.ttft_s > 0 and h.tpot_s > 0


# ---------------------------------------------------------------------------
# regression: wafer-granularity area accounting (ISSUE 4 satellite)
# ---------------------------------------------------------------------------


def test_wafer_split_respects_area_budget():
    for n in (2, 3, 8, 16):
        for ratio in (0.0, 0.01, 0.3, 0.5, 0.7, 0.99, 1.0):
            nw_p, nw_d = wafer_split(n, ratio)
            assert nw_p + nw_d == n          # never n + 1 extra silicon
            assert nw_p >= 1 and nw_d >= 1
    with pytest.raises(ValueError):
        wafer_split(1, 0.5)


# ---------------------------------------------------------------------------
# regression: prefill KV length under dp/microbatch sharding
# ---------------------------------------------------------------------------


def test_prefill_kv_len_independent_of_token_sharding():
    wl = inference_workload(GPT_BENCHMARKS[0], "prefill", batch=32, seq=2048)
    full = wl.layer_ops(tp=1)
    split = wl.layer_ops(tp=1, mb_tokens=wl.tokens_per_step() // 8)
    # scores: (M, hd) x (hd, kv_len); attnv: (M, kv_len) x (kv_len, hd)
    for ops in (full, split):
        assert ops[1].name == "scores" and ops[1].N == wl.seq
        assert ops[2].name == "attnv" and ops[2].K == wl.seq
    # per-token attention FLOPs must not shrink with the split
    assert split[1].flops() == pytest.approx(full[1].flops() / 8)


def test_train_kv_len_is_full_seq():
    wl = GPT_BENCHMARKS[0]                   # train phase
    ops = wl.layer_ops(tp=1, mb_tokens=wl.tokens_per_step() // 16)
    assert ops[1].N == wl.seq and ops[2].K == wl.seq


@pytest.mark.parametrize("phase", ["train", "prefill", "decode"])
def test_layer_ops_scalar_batched_parity(phase):
    base = GPT_BENCHMARKS[0]
    wl = base if phase == "train" else inference_workload(
        base, phase, batch=32, seq=2048)
    tps = np.array([1, 4, 16, 64])
    mbs = np.array([wl.tokens_per_step(), wl.tokens_per_step() // 4,
                    wl.tokens_per_step() // 16, 128])
    batched = wl.layer_ops_batch(tps, mbs)
    for c, (tp, mb) in enumerate(zip(tps, mbs)):
        ops = wl.layer_ops(tp=int(tp), mb_tokens=int(mb))
        for i, op in enumerate(ops):
            assert batched["M"][i, c] == op.M, (phase, op.name)
            assert batched["K"][i, c] == op.K, (phase, op.name)
            assert batched["N"][i, c] == op.N, (phase, op.name)


# ---------------------------------------------------------------------------
# regression: DRAM-energy capacity term (legacy keyword)
# ---------------------------------------------------------------------------


def _step_batch(wl, nw, **kw):
    d = validate(WSCDesign()).design
    geom = DesignBatch.from_designs([d])
    one = np.array([1])
    return evaluate_step_batch(
        geom, wl, one, one, one, one,
        np.array([1e6]), np.array([1e12]), np.array([1e9]),
        np.array([nw]), **kw)


def test_dram_energy_legacy_matches_default_when_consistent():
    # train, one wafer, no KV: the capacity terms coincide, so both modes
    # must be bit-identical
    wl = GPT_BENCHMARKS[7]
    a = _step_batch(wl, 1)
    b = _step_batch(wl, 1, legacy_dram_energy=True)
    assert a["energy_j"][0] == b["energy_j"][0]


def test_dram_energy_nw_factor_fixed():
    # multi-wafer: the legacy capacity term sized the SRAM pool per wafer
    # (no nw) while the latency term used nw wafers — the default now uses
    # the same per-system pool for both, so it charges at most the legacy
    # energy, and strictly less when the pools straddle the weights
    wl = GPT_BENCHMARKS[7]
    a = _step_batch(wl, 8)
    b = _step_batch(wl, 8, legacy_dram_energy=True)
    assert a["energy_j"][0] < b["energy_j"][0]
    # and the latency-side DRAM term is identical in both modes
    assert a["dram_s"][0] == b["dram_s"][0]


def test_decode_kv_streaming_in_dram_traffic():
    # decode streams the KV cache per token: DRAM time must exceed the
    # pure weight-spill time of the same design under the same strategy
    wl_d = inference_workload(GPT_BENCHMARKS[7], "decode", batch=32,
                              seq=2048)
    wl_t = GPT_BENCHMARKS[7]
    a = _step_batch(wl_d, 1)
    b = _step_batch(wl_t, 1)
    assert a["dram_s"][0] > b["dram_s"][0] / 3.0   # bwd_mult aside, KV adds
    # prefill now writes its KV cache: nonzero DRAM traffic even when
    # weights alone would spill the same amount
    wl_p = inference_workload(GPT_BENCHMARKS[7], "prefill", batch=32,
                              seq=2048)
    c = _step_batch(wl_p, 1)
    assert c["dram_s"][0] > 0


# ---------------------------------------------------------------------------
# cross-validation against the real ServeEngine (tiny config) + the
# per-request temperature regression (ISSUE 4 satellite)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_model():
    jax = pytest.importorskip("jax")
    from repro.configs import reduced_config
    from repro.models import model as M
    cfg = reduced_config("smollm-135m")
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    return cfg, params


def _drain_counting_steps(eng):
    steps = 0
    while eng.queue or any(a is not None for a in eng.active):
        if eng.step():
            steps += 1
    return steps


def test_engine_step_count_matches_analytical_schedule(tiny_model):
    from repro.models.runtime import CPU_TEST as RT
    from repro.serve.engine import Request, ServeEngine
    cfg, params = tiny_model
    prompts = [np.arange(4 + i, dtype=np.int32) % cfg.vocab
               for i in range(5)]
    outs = [6, 3, 9, 5, 7]
    eng = ServeEngine(cfg, RT, params, slots=2, max_len=64)
    for i, (p, o) in enumerate(zip(prompts, outs)):
        eng.submit(Request(i, p, o))
    engine_steps = _drain_counting_steps(eng)

    mix = RequestMix(tuple(len(p) for p in prompts), tuple(outs))
    analytical = continuous_batch_schedule(mix, slots=2).n_decode_steps
    # acceptance bound: within 10% of the real engine (currently exact)
    assert abs(engine_steps - analytical) <= max(1, 0.1 * engine_steps)


def test_engine_decode_honors_per_request_temperature(tiny_model):
    from repro.models.runtime import CPU_TEST as RT
    from repro.serve.engine import Request, ServeEngine
    cfg, params = tiny_model
    p0 = np.arange(4, dtype=np.int32) % cfg.vocab
    p1 = (np.arange(6, dtype=np.int32) * 3) % cfg.vocab
    greedy0 = ServeEngine(cfg, RT, params, slots=2, max_len=64).run(
        [Request(0, p0, 10)])[0]
    greedy1 = ServeEngine(cfg, RT, params, slots=2, max_len=64).run(
        [Request(0, p1, 10)])[0]
    both = ServeEngine(cfg, RT, params, slots=2, max_len=64).run(
        [Request(0, p0, 10, temperature=0.0),
         Request(1, p1, 10, temperature=8.0)])
    # the greedy request is untouched by its hot neighbor
    assert both[0] == greedy0
    # the hot request actually samples on DECODE steps too (it used to
    # sample only its first token, then decode greedily forever)
    assert both[1][1:] != greedy1[1:]


def test_sample_logits_per_row_temperatures():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.serve.serve_step import sample_logits
    logits = jnp.asarray([[0.0, 5.0, 1.0], [0.0, 5.0, 1.0]])
    temps = jnp.asarray([0.0, 3.0])
    outs = {tuple(int(x) for x in
                  np.asarray(sample_logits(logits, jax.random.PRNGKey(s),
                                           temps)))
            for s in range(25)}
    # row 0 (T=0) is always the argmax; row 1 (T>0) varies across seeds
    assert all(o[0] == 1 for o in outs)
    assert len({o[1] for o in outs}) > 1
    assert all(0 <= o[1] <= 2 for o in outs)
