"""Property tests for the compiled MFMOBO hot path (DESIGN.md §10).

Every jitted/vectorized program is checked against the retained NumPy
reference it replaced:

    GP.fit / predict / condition_on   vs  gp_ref.NumpyGP (eager loop)
    ehvi_2d (padded jit kernel)       vs  ehvi_2d_ref (strip integration)
    _acquire_batch (lax.scan greedy)  vs  gp_ref.acquire_batch_ref
    validate_batch                    vs  scalar validate (exact)
    row_redundancy_yield (exact DP)   vs  brute force + MC oracle
    min_spares_for_target_batch       vs  scalar min_spares_for_target

plus checkpoint-purity regressions: a LoopState pickle must never contain
device arrays, and re-running the compiled acquire on warmed buckets must
not retrace.
"""
from __future__ import annotations

import itertools
import pickle

import numpy as np
import pytest

import repro.core.mfmobo as M
from repro.core.design_space import DIMS, decode_batch, sample
from repro.core.ehvi import ehvi_2d, ehvi_2d_ref
from repro.core.gp import GP, bucket_size
from repro.core.gp_ref import NumpyGP, acquire_batch_ref
from repro.core.pareto import pareto_front
from repro.core.validator import validate, validate_batch
from repro.core.yield_model import (mc_row_redundancy_yield,
                                    min_spares_for_target,
                                    min_spares_for_target_batch,
                                    row_redundancy_yield)


def _toy(rng, n, d=4):
    X = rng.random((n, d))
    y = np.sin(3.0 * X[:, 0]) + 0.5 * X[:, 1] + 0.05 * rng.standard_normal(n)
    return X, y


# ---------------------------------------------------------------------------
# bucketing
# ---------------------------------------------------------------------------


def test_bucket_size_pow2_and_monotone():
    assert [bucket_size(n) for n in (1, 2, 8, 9, 16, 17, 100)] == \
        [8, 8, 8, 16, 16, 32, 128]
    assert bucket_size(3, minimum=4) == 4
    sizes = [bucket_size(n) for n in range(1, 200)]
    assert all(b >= a for a, b in zip(sizes, sizes[1:]))
    assert all(s & (s - 1) == 0 for s in sizes)


# ---------------------------------------------------------------------------
# GP vs NumPy reference
# ---------------------------------------------------------------------------


def test_gp_fit_predict_matches_reference():
    rng = np.random.default_rng(0)
    for n in (3, 8, 13):
        X, y = _toy(rng, n)
        Xs = rng.random((17, X.shape[1]))
        gp = GP.fit(X, y)
        ref = NumpyGP.fit(X, y)
        mu, sd = gp.predict(Xs)
        mu_r, sd_r = ref.predict(Xs)
        # fp32 padded-jit fit vs fp64 eager fit: same optimizer trajectory
        np.testing.assert_allclose(mu, mu_r, rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(sd, sd_r, rtol=2e-3, atol=2e-3)


def test_gp_fit_pair_matches_separate_fits():
    rng = np.random.default_rng(1)
    X, y1 = _toy(rng, 9)
    y2 = -y1 + 0.1 * rng.standard_normal(len(y1))
    g1, g2 = GP.fit_pair(X, (y1, y2))
    s1, s2 = GP.fit(X, y1), GP.fit(X, y2)
    Xs = rng.random((11, X.shape[1]))
    for g, s in ((g1, s1), (g2, s2)):
        np.testing.assert_allclose(g.predict(Xs)[0], s.predict(Xs)[0],
                                   rtol=1e-5, atol=1e-5)


def test_gp_condition_on_matches_reference():
    rng = np.random.default_rng(2)
    X, y = _toy(rng, 7)
    Xs = rng.random((9, X.shape[1]))
    gp, ref = GP.fit(X, y), NumpyGP.fit(X, y)
    # chain several rank-1 updates across a bucket boundary (7 -> 12 obs)
    for k in range(5):
        x_new = rng.random(X.shape[1])
        y_new = float(np.sin(3.0 * x_new[0]))
        gp = gp.condition_on(x_new, y_new)
        ref = ref.condition_on(x_new, y_new)
        mu, sd = gp.predict(Xs)
        mu_r, sd_r = ref.predict(Xs)
        np.testing.assert_allclose(mu, mu_r, rtol=5e-3, atol=5e-3)
        np.testing.assert_allclose(sd, sd_r, rtol=5e-3, atol=5e-3)
    assert gp.n == 12


def test_gp_dtype_argument_controls_buffers():
    rng = np.random.default_rng(3)
    X, y = _toy(rng, 6)
    assert GP.fit(X, y).dtype == np.float32
    assert GP.fit(X, y, dtype=np.float32).X.dtype == np.float32


def test_gp_with_capacity_is_exact():
    rng = np.random.default_rng(4)
    X, y = _toy(rng, 6)
    gp = GP.fit(X, y)
    big = gp.with_capacity(32)
    Xs = rng.random((5, X.shape[1]))
    np.testing.assert_array_equal(np.asarray(gp.predict(Xs)),
                                  np.asarray(big.predict(Xs)))


# ---------------------------------------------------------------------------
# EHVI vs NumPy reference
# ---------------------------------------------------------------------------


def test_ehvi_matches_reference_random_fronts():
    rng = np.random.default_rng(5)
    for trial in range(10):
        n, f = int(rng.integers(1, 40)), int(rng.integers(0, 9))
        mu = rng.normal(0, 2, (n, 2))
        sg = rng.uniform(0.05, 1.5, (n, 2))
        front = rng.normal(0, 2, (f, 2))
        ref = np.array([-3.0, -3.0])
        got = ehvi_2d(mu, sg, front, ref)
        # the jit kernel Pareto-filters internally; the reference expects a
        # clean front
        want = ehvi_2d_ref(mu, sg, pareto_front(front) if f else front, ref)
        scale = np.maximum(np.abs(want), 1.0)
        np.testing.assert_allclose(got / scale, want / scale, atol=5e-5)
        assert (got >= 0).all()


def test_ehvi_pareto_filter_internal():
    """The jit kernel filters dominated points itself — feeding it a raw
    (unfiltered) set must equal feeding the reference the filtered front,
    so the acquisition scan can hand it its raw fantasy buffer."""
    rng = np.random.default_rng(6)
    pts = rng.normal(0, 1, (12, 2))
    mu, sg = rng.normal(0, 1, (5, 2)), rng.uniform(0.1, 1.0, (5, 2))
    ref = np.array([-4.0, -4.0])
    np.testing.assert_allclose(ehvi_2d(mu, sg, pts, ref),
                               ehvi_2d_ref(mu, sg, pareto_front(pts), ref),
                               rtol=1e-4, atol=1e-5)


def test_ehvi_output_is_writable_float64():
    out = ehvi_2d(np.zeros((2, 2)), np.ones((2, 2)),
                  np.zeros((0, 2)), np.array([-1.0, -1.0]))
    assert out.dtype == np.float64
    out[0] = -1.0   # _acquire mutates scores in place; must not raise


# ---------------------------------------------------------------------------
# greedy q-EHVI acquisition vs NumPy reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("q", [1, 2, 4])
def test_acquire_batch_matches_reference(q):
    rng = np.random.default_rng(7 + q)
    n, d, c = 9, len(DIMS), 40
    X = rng.random((n, d))
    Y = np.stack([1e3 * (1 + rng.random(n)), 1e3 * (2 + rng.random(n))], 1)
    models = M._fit_models(X, Y)
    ref_models = [NumpyGP.fit(X, np.log1p(np.maximum(Y[:, 0], 0.0))),
                  NumpyGP.fit(X, -np.log(np.maximum(Y[:, 1], 1.0)))]
    ev = M.obj_space([tuple(y) for y in Y])
    cand = rng.random((c, d))
    ref = M.hv_ref(15000.0)
    js = M._acquire_batch(models, cand, ev, ref, q=q)
    js_ref = acquire_batch_ref(ref_models, cand, ev, ref, q=q)
    assert js == js_ref
    assert len(set(js)) == q


def test_acquire_batch_no_retrace_within_bucket():
    """Repeated proposals inside one capacity bucket reuse one compiled
    program (the ≥10x fig8 win depends on it)."""
    cache_size = getattr(M._acquire_scan_jit, "_cache_size", None)
    if cache_size is None:
        pytest.skip("jax version does not expose _cache_size")
    rng = np.random.default_rng(8)
    d = len(DIMS)
    ref = M.hv_ref(15000.0)
    for n in (5, 6, 7):   # all land in the same pow2 bucket
        X = rng.random((n, d))
        Y = np.stack([1e3 * (1 + rng.random(n)),
                      1e3 * (2 + rng.random(n))], 1)
        models = M._fit_models(X, Y)
        ev = M.obj_space([tuple(y) for y in Y])
        M._acquire_batch(models, rng.random((16, d)), ev, ref, q=2)
        if n == 5:
            first = cache_size()
    assert cache_size() == first


def test_warm_optimizer_kernels_covers_campaign_buckets():
    n_buckets = M.warm_optimizer_kernels(18, n_candidates=16, q=2)
    assert n_buckets >= 2   # at least the 8- and 16-obs buckets


# ---------------------------------------------------------------------------
# batched validator / yield vs scalar references
# ---------------------------------------------------------------------------


def test_validate_batch_matches_scalar_exactly():
    rng = np.random.default_rng(9)
    designs = decode_batch(sample(rng, 160))
    batch = validate_batch(designs)
    for d, rb in zip(designs, batch):
        rs = validate(d)
        assert rb.ok == rs.ok
        assert rb.reason == rs.reason
        assert rb.design == rs.design            # includes resolved spares
        if rb.ok:
            assert rb.wafer_yield == rs.wafer_yield   # bitwise


def test_row_redundancy_yield_exact_and_matches_mc():
    rng = np.random.default_rng(10)
    ys = rng.uniform(0.6, 0.99, (4, 5))
    # exact Poisson-binomial by brute-force enumeration over fail patterns
    for spares in (0, 1, 2):
        want = 1.0
        for row in ys:
            p_ok = 0.0
            for fails in itertools.product([0, 1], repeat=len(row)):
                if sum(fails) <= spares:
                    p = np.prod([1 - y if f else y
                                 for f, y in zip(fails, row)])
                    p_ok += p
            want *= p_ok
        got = row_redundancy_yield(ys, spares)
        np.testing.assert_allclose(got, want, rtol=1e-12)
        mc = mc_row_redundancy_yield(ys, spares, n_samples=4000, seed=0)
        assert abs(got - mc) < 0.05


def test_min_spares_batch_matches_scalar():
    rng = np.random.default_rng(11)
    n = 24
    ch = rng.uniform(1.0, 6.0, n)
    cw = rng.uniform(1.0, 6.0, n)
    arr = rng.integers(2, 9, n)
    nret = rng.integers(1, 30, n)
    infosow = rng.random(n) < 0.5
    rh, rw = ch * arr, cw * arr
    tsv = rng.uniform(0.0, 5.0, n)
    spares_b, wy_b = min_spares_for_target_batch(
        ch, cw, arr, arr, rh, rw, tsv, nret, infosow)
    for i in range(n):
        s, wy = min_spares_for_target(
            float(ch[i]), float(cw[i]), (int(arr[i]), int(arr[i])),
            (float(rh[i]), float(rw[i])), float(tsv[i]), int(nret[i]),
            "infosow" if infosow[i] else "die_stitching")
        assert spares_b[i] == s
        assert wy_b[i] == wy   # bitwise: scalar delegates to the batch path


# ---------------------------------------------------------------------------
# checkpoint purity
# ---------------------------------------------------------------------------


def test_loop_state_pickle_is_host_side():
    """LoopState checkpoints must hold only host types — never jax device
    arrays (they poison pickles and break resume across backends)."""
    import jax

    from repro.explore.runner import ExplorationLoop, LoopConfig

    def f(d):
        return (1e3 + d.mac_num, 5e2 + d.buffer_kb)

    cfg = LoopConfig(strategy="mobo", N0=6, d0=3, q=2, n_candidates=12,
                     seed=0)
    loop = ExplorationLoop(cfg, f)
    for _ in range(2):
        loop.step()
    blob = pickle.dumps(loop.state)

    def walk(o, seen=None):
        seen = seen if seen is not None else set()
        if id(o) in seen:
            return
        seen.add(id(o))
        assert not isinstance(o, jax.Array), f"device array in state: {o!r}"
        if isinstance(o, dict):
            for v in o.values():
                walk(v, seen)
        elif isinstance(o, (list, tuple, set)):
            for v in o:
                walk(v, seen)
        elif hasattr(o, "__dict__"):
            for v in vars(o).values():
                walk(v, seen)

    walk(pickle.loads(blob))
    assert len(loop.state.Y0) > 0
