"""Sharding rule engine: divisibility fallbacks + every assigned arch gets
legal specs on the production mesh geometry (tested against a mesh shim —
no 512 fake devices needed in the unit-test process)."""
import types

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, get_shape
from repro.dist import sharding as sh
from repro.models import model as M
from repro.models.runtime import Runtime


class MeshShim:
    """Duck-typed mesh: only .shape (mapping) and .axis_names are used by
    the spec rules."""

    def __init__(self, shape_map):
        self.shape = shape_map
        self.axis_names = tuple(shape_map)


SINGLE = MeshShim({"data": 16, "model": 16})
MULTI = MeshShim({"pod": 2, "data": 16, "model": 16})


def _check_spec_legal(mesh, sds, spec):
    used = set()
    for dim, entry in enumerate(spec):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        size = 1
        for a in axes:
            assert a in mesh.axis_names, (spec, a)
            assert a not in used, f"axis {a} used twice in {spec}"
            used.add(a)
            size *= mesh.shape[a]
        assert sds.shape[dim] % size == 0, (sds.shape, spec, dim)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["pod1", "pod2"])
def test_param_specs_legal(arch, mesh):
    cfg = get_config(arch)
    sds = jax.eval_shape(lambda k: M.init_params(k, cfg),
                         jax.random.PRNGKey(0))
    specs = sh.param_specs(mesh, sds)
    flat_s = jax.tree.leaves(sds)
    flat_p = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for s, p in zip(flat_s, flat_p):
        _check_spec_legal(mesh, s, p)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_id", ["decode_32k", "long_500k"])
def test_cache_specs_legal(arch, shape_id):
    cfg = get_config(arch)
    shape = get_shape(shape_id)
    if shape_id == "long_500k" and not cfg.subquadratic:
        pytest.skip("full attention: long_500k cell is skipped by design")
    rt = Runtime()
    sds = jax.eval_shape(
        lambda: M.init_cache(cfg, rt, shape.global_batch, shape.seq_len))
    specs = sh.cache_specs(SINGLE, sds)
    for s, p in zip(jax.tree.leaves(sds),
                    jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))):
        _check_spec_legal(SINGLE, s, p)


def test_tp_within_expert_fallback():
    """8 experts cannot shard over a 16-wide model axis: EP must fall back
    to TP-within-expert (F over model, D over dp)."""
    cfg = get_config("mixtral-8x7b")
    sds = jax.eval_shape(lambda k: M.init_params(k, cfg),
                         jax.random.PRNGKey(0))
    specs = sh.param_specs(SINGLE, sds)
    def axes_of(entry):
        if entry is None:
            return set()
        return {entry} if isinstance(entry, str) else set(entry)

    wi_spec = specs["layers"]["moe"]["wi"]      # (L, E, D, F)
    assert wi_spec[1] is None                   # E=8 not divisible by 16
    assert axes_of(wi_spec[3]) == {"model"}     # TP on F instead
    assert axes_of(wi_spec[2]) == {"data"}


def test_seq_sharding_for_batch1_cache():
    """long_500k (B=1): sequence dim must spread over data+model axes."""
    cfg = get_config("gemma3-4b")
    rt = Runtime()
    sds = jax.eval_shape(lambda: M.init_cache(cfg, rt, 1, 524288))
    specs = sh.cache_specs(SINGLE, sds)
    k_spec = specs["attn"]["k"]                 # (L, B, W, Hkv, hd)
    assert k_spec[1] is None                    # B=1 unshardable
    assert k_spec[2] == ("data", "model")       # kv heads 4 can't take model


def test_vocab_not_divisible_falls_back():
    """whisper vocab 51865 is odd: embed must not shard the vocab dim."""
    cfg = get_config("whisper-small")
    sds = jax.eval_shape(lambda k: M.init_params(k, cfg),
                         jax.random.PRNGKey(0))
    specs = sh.param_specs(SINGLE, sds)
    assert specs["embed"][0] is None
