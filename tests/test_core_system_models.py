"""Heterogeneity, GPU/WSC baselines, and the full arch-pool workload bridge."""
import pytest

from repro.configs import ARCH_IDS, get_config, get_shape
from repro.core.baselines import DOJO_LIKE, WSE2_LIKE, GPUSpec, gpu_cluster_eval
from repro.core.design_space import WSCDesign
from repro.core.evaluator import evaluate_design
from repro.core.heterogeneity import evaluate_hetero
from repro.core.validator import validate
from repro.core.workload import GPT_BENCHMARKS, from_model_config, inference_workload


def test_gpu_baseline_monotone_in_gpus():
    import dataclasses
    wl = GPT_BENCHMARKS[0]
    t1, _ = gpu_cluster_eval(wl)
    t2, _ = gpu_cluster_eval(dataclasses.replace(wl, gpu_budget=wl.gpu_budget * 2))
    assert t2 > t1


def test_gpu_decode_fixed_batch_saturates():
    """Paper premise: at fixed batch, decode throughput stops scaling with
    same-area GPU count (the under-utilization WSCs exploit)."""
    import dataclasses
    wl = inference_workload(GPT_BENCHMARKS[7], "decode", batch=32, seq=2048)
    t1, _ = gpu_cluster_eval(dataclasses.replace(wl, gpu_budget=1000))
    t2, _ = gpu_cluster_eval(dataclasses.replace(wl, gpu_budget=4000))
    assert t2 <= t1 * 1.05


def test_wsc_baselines_validate_and_evaluate():
    wl = GPT_BENCHMARKS[0]
    for d in (WSE2_LIKE, DOJO_LIKE):
        v = validate(d)
        assert v.ok, v.reason
        r = evaluate_design(v.design, wl, max_strategies=8)
        assert r.feasible and r.throughput > 0


def test_mqa_improves_gpu_decode():
    wl = inference_workload(GPT_BENCHMARKS[7], "decode", batch=32, seq=2048)
    t_mha, _ = gpu_cluster_eval(wl, mqa=False)
    t_mqa, _ = gpu_cluster_eval(wl, mqa=True)
    assert t_mqa > t_mha


def test_heterogeneity_granularities_all_run():
    wl = inference_workload(GPT_BENCHMARKS[1], "decode", batch=32, seq=2048)
    d = validate(WSCDesign(use_stacked_dram=True,
                           dram_bw_tbps_per_100mm2=2.0)).design
    results = {}
    for gran in ("core", "reticle", "wafer"):
        h = evaluate_hetero(d, d, wl, gran, 0.5, n_wafers=4)
        assert h.throughput > 0 and h.power_w > 0
        results[gran] = h
    # wafer-level KV transfer is the slowest path (paper §IX-E)
    assert results["wafer"].kv_transfer_s >= results["reticle"].kv_transfer_s


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_workload_bridge_all_archs(arch):
    cfg = get_config(arch)
    for shape_id in ("train_4k", "decode_32k"):
        wl = from_model_config(cfg, get_shape(shape_id))
        assert wl.flops_per_step() > 0
        assert wl.params_bytes() > 0
        ops = wl.layer_ops(tp=4)
        assert len(ops) == 6
        assert all(o.flops() > 0 for o in ops)
